"""Unit tests: single-device APSS core (oracle, blocked, matches, pruning)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.apss import (
    apss_blocked,
    apss_reference,
    normalize_rows,
    similarity_topk,
)
from repro.core.graph import match_set, matches_to_coo
from repro.core.matches import (
    Matches,
    dedupe_candidates,
    extract_matches,
    matches_from_candidates,
    merge_matches,
)
from repro.core.pruning import (
    block_prune_mask,
    block_upper_bounds,
    block_maxweight_bounds,
    local_threshold,
    prune_stats,
)

T, K = 0.35, 16


def test_reference_matches_bruteforce(corpus):
    ref = apss_reference(jnp.asarray(corpus), T, K)
    S = corpus @ corpus.T
    np.fill_diagonal(S, 0.0)
    want = int((S >= T).sum())
    assert int(ref.counts.sum()) == want
    # every reported value really is ≥ T and equals the true similarity
    rows, cols, w = matches_to_coo(ref, undirected=False)
    np.testing.assert_allclose(w, S[rows, cols], rtol=1e-5)
    assert (w >= T).all()


@pytest.mark.parametrize("block_rows", [16, 32, 128])
def test_blocked_equals_reference(corpus, block_rows):
    ref = apss_reference(jnp.asarray(corpus), T, K)
    blk = apss_blocked(jnp.asarray(corpus), T, K, block_rows=block_rows)
    assert match_set(blk) == match_set(ref)
    np.testing.assert_array_equal(blk.counts, ref.counts)


def test_blocked_with_prune_stats(corpus):
    m, stats = apss_blocked(
        jnp.asarray(corpus), T, K, block_rows=32, with_prune_stats=True
    )
    ref = apss_reference(jnp.asarray(corpus), T, K)
    assert match_set(m) == match_set(ref)
    assert 0.0 < float(stats.live_fraction) <= 1.0


def test_similarity_topk_join():
    rng = np.random.default_rng(1)
    Q = rng.standard_normal((37, 24)).astype(np.float32)
    C = rng.standard_normal((53, 24)).astype(np.float32)
    Q = np.asarray(normalize_rows(jnp.asarray(Q)))
    C = np.asarray(normalize_rows(jnp.asarray(C)))
    got = similarity_topk(jnp.asarray(Q), jnp.asarray(C), 0.2, k=8, block_rows=16)
    S = Q @ C.T
    np.testing.assert_array_equal(
        np.asarray(got.counts), (S >= 0.2).sum(1).astype(np.int32)
    )
    # top-1 is the argmax wherever some match exists
    has = np.asarray(got.counts) > 0
    np.testing.assert_array_equal(
        np.asarray(got.indices[:, 0])[has], S.argmax(1)[has]
    )


def test_extract_matches_excludes_self():
    S = jnp.eye(8, dtype=jnp.float32)  # only self-similarities
    m = extract_matches(S, 0.5, 4)
    assert int(m.counts.sum()) == 0
    assert (np.asarray(m.indices) == -1).all()


def test_extract_matches_capacity_overflow_visible():
    S = jnp.ones((4, 10), jnp.float32)
    m = extract_matches(S, 0.5, 3, exclude_self=False)
    assert (np.asarray(m.counts) == 10).all()
    assert bool(m.overflowed().all())


def test_merge_matches_disjoint_columns():
    S = jnp.asarray(np.random.default_rng(2).random((6, 40)), jnp.float32)
    full = extract_matches(S, 0.5, 8, exclude_self=False)
    a = extract_matches(S[:, :20], 0.5, 8, exclude_self=False)
    b = extract_matches(S[:, 20:], 0.5, 8, col_offset=20, exclude_self=False)
    merged = merge_matches(a, b)
    np.testing.assert_array_equal(merged.counts, full.counts)
    np.testing.assert_allclose(merged.values, full.values, rtol=1e-6)


def test_dedupe_candidates():
    vals = jnp.asarray([[1.0, 2.0, 1.0, 3.0]], jnp.float32)
    idx = jnp.asarray([[5, 7, 5, -1]], jnp.int32)
    v, i = dedupe_candidates(vals, idx)
    live = np.asarray(i[0])
    assert sorted(x for x in live if x >= 0) == [5, 7]
    assert (np.asarray(v[0])[live == 5] == 1.0).all()


def test_matches_from_candidates_threshold_and_self():
    vals = jnp.asarray([[0.9, 0.2, 0.7]], jnp.float32)
    idx = jnp.asarray([[0, 1, 2]], jnp.int32)
    m = matches_from_candidates(vals, idx, 0.5, 4, row_offset=0)
    # index 0 == row 0 → self-excluded; 0.2 below threshold
    assert int(m.counts[0]) == 1
    assert int(m.indices[0, 0]) == 2


def test_block_bounds_are_upper_bounds(corpus):
    b = 16
    maxw = block_maxweight_bounds(jnp.asarray(corpus), b)
    ub = np.asarray(block_upper_bounds(maxw, maxw))
    S = corpus @ corpus.T
    nb = corpus.shape[0] // b
    for i in range(nb):
        for j in range(nb):
            true_max = S[i * b:(i + 1) * b, j * b:(j + 1) * b].max()
            assert ub[i, j] >= true_max - 1e-5


def test_prune_mask_never_kills_matches(corpus):
    b = 16
    mask = np.asarray(
        block_prune_mask(jnp.asarray(corpus), jnp.asarray(corpus), T, b)
    )
    S = corpus @ corpus.T
    np.fill_diagonal(S, 0.0)
    nb = corpus.shape[0] // b
    for i in range(nb):
        for j in range(nb):
            if not mask[i, j]:
                blockmax = S[i * b:(i + 1) * b, j * b:(j + 1) * b].max()
                assert blockmax < T


def test_local_threshold_lemma1_form():
    assert float(local_threshold(0.8, 4)) == pytest.approx(0.2)


def test_prune_stats_counts():
    mask = jnp.asarray([[True, False], [True, True]])
    s = prune_stats(mask)
    assert int(s.live_blocks) == 3 and int(s.total_blocks) == 4


def test_apss_blocked_kernel_path(corpus):
    """Pallas-kernel-backed self-join == oracle (interpret mode)."""
    ref = apss_reference(jnp.asarray(corpus), T, K)
    got = apss_blocked(jnp.asarray(corpus), T, K, block_rows=128, use_kernel=True)
    assert match_set(got) == match_set(ref)
    np.testing.assert_array_equal(got.counts, ref.counts)
