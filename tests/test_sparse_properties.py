"""Property-based sparse↔dense equivalence (hypothesis; skips cleanly
without it, mirroring tests/test_apss_properties.py).

The generator space deliberately covers the representation's contractual
edge cases: empty rows, duplicate coordinates (which sum, by the COO
convention), non-tile-multiple row counts, and densities from ~0.1% to
dense-ish — across the traceable blocked join AND the host-compacted
inverted-index worklist path.
"""

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.apss import apss_reference  # noqa: E402
from repro.core.graph import match_set  # noqa: E402
from repro.core.pruning import sparse_block_prune_mask  # noqa: E402
from repro.core.sparse import (  # noqa: E402
    from_dense,
    pad_rows_sparse,
    sparse_similarity_topk,
    to_dense,
)
from repro.kernels.apss_block.sparse import apss_sparse_compacted  # noqa: E402
from test_sparse import random_csr  # noqa: E402  (pytest rootdir import)

SET = settings(max_examples=15, deadline=None)


def _equiv(got, ref):
    assert match_set(got) == match_set(ref)
    np.testing.assert_array_equal(np.asarray(got.counts), np.asarray(ref.counts))


@SET
@given(
    seed=st.integers(0, 10_000),
    n=st.integers(20, 49),  # deliberately non-tile-multiple
    t=st.floats(0.15, 0.6),
)
def test_sparse_join_equals_dense_reference(seed, n, t):
    sp = random_csr(seed, n, 40, 6)
    ref = apss_reference(to_dense(sp), t, 32)
    _equiv(
        sparse_similarity_topk(sp, sp, t, 32, block_rows=16, exclude_self=True),
        ref,
    )


@SET
@given(seed=st.integers(0, 10_000), t=st.floats(0.15, 0.6))
def test_compacted_equals_dense_reference(seed, t):
    sp = random_csr(seed, 48, 40, 6)
    ref = apss_reference(to_dense(sp), t, 32)
    _equiv(apss_sparse_compacted(sp, t, 32, block_m=16, lane_pad=8), ref)


@SET
@given(
    seed=st.integers(0, 10_000),
    density=st.sampled_from([0.002, 0.01, 0.1, 0.4]),
    t=st.floats(0.15, 0.7),
)
def test_density_sweep_equivalence(seed, density, t):
    rng = np.random.default_rng(seed)
    D = np.abs(rng.standard_normal((40, 64))).astype(np.float32)
    D *= rng.random((40, 64)) < density
    sp = from_dense(D)
    ref = apss_reference(jnp.asarray(D), t, 32)
    _equiv(
        sparse_similarity_topk(sp, sp, t, 32, block_rows=16, exclude_self=True),
        ref,
    )


@SET
@given(seed=st.integers(0, 10_000), t=st.floats(0.1, 0.9))
def test_sparse_prune_mask_never_loses_a_match(seed, t):
    sp = random_csr(seed, 32, 24, 5)
    spp, _ = pad_rows_sparse(sp, 8)
    mask = np.asarray(sparse_block_prune_mask(spp, spp, t, 8))
    S = np.asarray(to_dense(spp))
    S = S @ S.T
    np.fill_diagonal(S, 0.0)
    for i, j in zip(*np.nonzero(S >= t)):
        assert mask[i // 8, j // 8], (i, j)


@SET
@given(seed=st.integers(0, 10_000))
def test_from_dense_roundtrip(seed):
    rng = np.random.default_rng(seed)
    D = rng.random((17, 23)).astype(np.float32)
    D *= rng.random((17, 23)) < 0.3
    np.testing.assert_allclose(np.asarray(to_dense(from_dense(D))), D, rtol=1e-6)
