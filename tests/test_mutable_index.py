"""Metamorphic mutation tests for MutableAPSSIndex (fixed-seed twin).

The invariant under test: after ANY interleaving of append / delete /
query / compact, the standing similarity graph and query results are
**bit-equal** to a fresh index built from the surviving rows in their
original order. A host-side reference model tracks (gid, raw row) pairs;
the oracle is simply a fresh ``MutableAPSSIndex`` over those rows, with
its 0..n-1 gids translated through the survivor list.

``tests/test_mutable_properties.py`` is the hypothesis-driven twin of the
metamorphic sequence test; this file is the fixed-seed version that runs
everywhere (the ``test_sparse.py`` pattern), plus the edges: empty deltas,
delete-everything, duplicate-row ties, the kernel lane, the no-retrace
guard, durability meta checks, and the server LRU-invalidation regression.
"""

import numpy as np
import pytest

from repro.core.apss import apss_reference
from repro.core.sparse import from_dense, to_dense
from repro.planner import telemetry
from repro.robust.faults import Fault, FaultPlan
from repro.serving import MutableAPSSIndex, RetrievalServer
from repro.obs import compile as obs_compile

T = 0.15
K = 8
M = 24
CAP = 16  # pinned ELL width: sparse bit-equality requires equal caps


def _rows(rng, n, sparse=False):
    """Raw (pre-normalization) rows; sparse-ish rows zero most entries."""
    D = rng.normal(size=(n, M)).astype(np.float32)
    if sparse:
        mask = rng.random((n, M)) < 0.25
        # every row keeps at least one coordinate
        mask[np.arange(n), rng.integers(0, M, n)] = True
        D = np.where(mask, D, 0.0).astype(np.float32)
    return D


def _fresh(rows_by_gid, kind):
    """The oracle: rebuild from scratch over surviving rows in gid order."""
    gids = [g for g, _ in rows_by_gid]
    if rows_by_gid:
        D = np.stack([r for _, r in rows_by_gid])
    else:
        D = np.zeros((0, M), np.float32)
    oracle = MutableAPSSIndex(
        D if len(rows_by_gid) else None,
        threshold=T, k=K, kind=kind, cap=CAP,
    )
    return oracle, np.asarray(gids, np.int64)


def _translate(indices, surv):
    """Oracle physical gids (0..n-1) → the mutated index's global ids."""
    return np.where(indices >= 0, surv[np.maximum(indices, 0)], -1)


def _assert_state_equal(mi, model, queries):
    """Graph AND query results bit-equal between mutated index and oracle."""
    oracle, surv = _fresh(model, mi.kind or "dense")
    gids, g = mi.graph()
    assert np.array_equal(gids, surv)
    if len(model):
        ogids, og = oracle.graph()
        assert np.array_equal(g.values, og.values)
        assert np.array_equal(g.indices, _translate(og.indices, surv))
        assert np.array_equal(g.counts, og.counts)
    r = mi.query(queries)
    ro = oracle.query(queries)
    assert np.array_equal(r.values, ro.values)
    if len(model):
        assert np.array_equal(r.indices, _translate(ro.indices, surv))
    assert np.array_equal(r.counts, ro.counts)


@pytest.mark.parametrize("kind", ["dense", "sparse"])
def test_append_then_delete_then_compact_bit_equal(kind):
    rng = np.random.default_rng(0)
    D = _rows(rng, 48, sparse=kind == "sparse")
    Q = _rows(rng, 5, sparse=kind == "sparse")
    mi = MutableAPSSIndex(D[:32], threshold=T, k=K, kind=kind, cap=CAP)
    model = [(g, D[g]) for g in range(32)]
    _assert_state_equal(mi, model, Q)
    mi.append(D[32:])
    model += [(g, D[g]) for g in range(32, 48)]
    _assert_state_equal(mi, model, Q)
    mi.delete([3, 9, 40])
    model = [(g, r) for g, r in model if g not in (3, 9, 40)]
    _assert_state_equal(mi, model, Q)
    mi.compact()
    _assert_state_equal(mi, model, Q)


@pytest.mark.parametrize("kind", ["dense", "sparse"])
@pytest.mark.parametrize("seed", [1, 2, 3])
def test_metamorphic_random_sequences(kind, seed):
    """Random interleaved append/delete/query/compact, oracle-checked
    after EVERY step — the headline metamorphic property, fixed-seed."""
    rng = np.random.default_rng(seed)
    sparse = kind == "sparse"
    Q = _rows(rng, 4, sparse=sparse)
    mi = MutableAPSSIndex(threshold=T, k=K, kind=kind, cap=CAP)
    model = []
    for _ in range(14):
        live = [g for g, _ in model]
        op = rng.choice(["append", "delete", "compact", "query"])
        if op == "append" or not live:
            n_new = int(rng.integers(1, 9))
            raw = _rows(rng, n_new, sparse=sparse)
            gids = mi.append(raw)
            model += list(zip(gids, raw))
        elif op == "delete":
            n_del = int(rng.integers(1, min(4, len(live)) + 1))
            victims = sorted(
                int(g) for g in rng.choice(live, size=n_del, replace=False)
            )
            mi.delete(victims)
            model = [(g, r) for g, r in model if g not in set(victims)]
        elif op == "compact":
            mi.compact()
        _assert_state_equal(mi, model, Q)


@pytest.mark.parametrize("kind", ["dense", "sparse"])
def test_sparse_input_path_matches_dense_payload(kind):
    """Appending a SparseCorpus is identical to appending its dense form
    (the WAL canonicalizes to raw dense either way)."""
    rng = np.random.default_rng(4)
    D = _rows(rng, 24, sparse=True)
    sp = from_dense(D)
    a = MutableAPSSIndex(threshold=T, k=K, kind=kind, cap=CAP)
    a.append(sp)
    b = MutableAPSSIndex(D, threshold=T, k=K, kind=kind, cap=CAP)
    ga, gb = a.graph()[1], b.graph()[1]
    assert np.array_equal(ga.values, gb.values)
    assert np.array_equal(ga.indices, gb.indices)
    assert np.asarray(to_dense(sp)).shape == D.shape


def test_graph_values_match_brute_force_reference():
    """Anchor against the O(n²) oracle: the standing graph is the paper's
    all-pairs result (values/counts; tolerance-based, not bit)."""
    rng = np.random.default_rng(5)
    D = _rows(rng, 40)
    mi = MutableAPSSIndex(D[:24], threshold=T, k=K)
    mi.append(D[24:])
    ref = apss_reference(D / np.linalg.norm(D, axis=1, keepdims=True), T, K)
    _, g = mi.graph()
    assert np.array_equal(g.counts, np.asarray(ref.counts))
    finite = g.values > -np.inf
    assert np.array_equal(finite, np.asarray(ref.values) > -np.inf)
    assert np.allclose(g.values[finite], np.asarray(ref.values)[finite],
                       atol=1e-5)


def test_duplicate_rows_tie_break_is_canonical():
    """Exact duplicate rows force score ties; the (value desc, position
    asc) canonical order must make mutated == rebuilt bit-for-bit."""
    rng = np.random.default_rng(6)
    base = _rows(rng, 12)
    dup = np.concatenate([base, base[:5]])  # 5 exact duplicates
    mi = MutableAPSSIndex(base, threshold=T, k=K)
    mi.append(base[:5])
    model = [(g, dup[g]) for g in range(17)]
    mi.delete([2])  # deleting one twin re-ranks its duplicate's row
    model = [(g, r) for g, r in model if g != 2]
    _assert_state_equal(mi, model, base[:3])
    mi.compact()
    _assert_state_equal(mi, model, base[:3])


def test_empty_delta_and_empty_delete_are_noops():
    rng = np.random.default_rng(7)
    mi = MutableAPSSIndex(_rows(rng, 16), threshold=T, k=K)
    v = mi.version
    assert mi.append(np.zeros((0, M), np.float32)) == []
    assert mi.delete([]) == 0
    assert mi.version == v


def test_delete_everything_then_revive():
    rng = np.random.default_rng(8)
    D = _rows(rng, 16)
    mi = MutableAPSSIndex(D, threshold=T, k=K)
    mi.delete(list(range(16)))
    assert mi.n == 0
    r = mi.query(D[:3])
    assert np.all(r.indices == -1) and np.all(r.counts == 0)
    gids = mi.append(D[:8])
    assert gids == list(range(16, 24))  # gids are never reused
    _assert_state_equal(mi, list(zip(gids, D[:8])), D[:3])


def test_auto_compact_on_tombstone_fraction():
    rng = np.random.default_rng(9)
    D = _rows(rng, 32)
    mi = MutableAPSSIndex(D, threshold=T, k=K, compact_threshold=0.25)
    with telemetry.CommLog() as log:
        mi.delete(list(range(8)))  # 8/32 = exactly the threshold
    assert log.counters["serving.compactions"] == 1
    assert mi._ndead == 0
    _assert_state_equal(mi, [(g, D[g]) for g in range(8, 32)], D[:3])


def test_input_validation():
    rng = np.random.default_rng(10)
    mi = MutableAPSSIndex(_rows(rng, 16), threshold=T, k=K)
    with pytest.raises(ValueError, match="non-finite"):
        mi.append(np.full((2, M), np.nan, np.float32))
    with pytest.raises(ValueError, match="!= index m"):
        mi.append(np.ones((2, M + 1), np.float32))
    with pytest.raises(KeyError, match="unknown"):
        mi.delete([99])
    with pytest.raises(ValueError, match="duplicate"):
        mi.delete([1, 1])
    with pytest.raises(ValueError, match="power of two"):
        MutableAPSSIndex(threshold=T, block_rows=48)


# -- kernel lane -------------------------------------------------------------


def test_kernel_lane_matches_oracle_kernel():
    """The dense kernel path serves through the zero-copy APSSIndex view;
    random (tie-free) data must be bit-equal to the oracle's kernel path."""
    rng = np.random.default_rng(11)
    D = _rows(rng, 96)
    mi = MutableAPSSIndex(D[:64], threshold=T, k=K, block_rows=64)
    mi.append(D[64:])
    mi.delete([0, 70])
    keep = [i for i in range(96) if i not in (0, 70)]
    oracle = MutableAPSSIndex(D[keep], threshold=T, k=K, block_rows=64)
    surv = np.asarray(keep, np.int64)
    Q = _rows(rng, 5)
    r = mi.query(Q, use_kernel=True)
    ro = oracle.query(Q, use_kernel=True)
    assert np.array_equal(r.values, ro.values)
    assert np.array_equal(r.indices, _translate(ro.indices, surv))
    rx = mi.query(Q)  # and the kernel lane agrees with the XLA lane
    assert np.array_equal(r.values, rx.values)
    assert np.array_equal(r.indices, rx.indices)


def test_kernel_lane_guards():
    rng = np.random.default_rng(12)
    mi = MutableAPSSIndex(_rows(rng, 16), threshold=T, k=K)
    with pytest.raises(ValueError, match="threshold > 0"):
        # tombstoned rows are zero vectors in the kernel view: t <= 0
        # would match them, so the lane refuses
        mi.query(_rows(rng, 2), threshold=0.0, use_kernel=True)
    ms = MutableAPSSIndex(_rows(rng, 16, sparse=True), threshold=T, k=K,
                          kind="sparse")
    with pytest.raises(NotImplementedError, match="layout-stable"):
        ms.query(_rows(rng, 2), use_kernel=True)


# -- no-retrace guard --------------------------------------------------------


def test_no_retrace_on_repeated_same_shape_appends():
    """Same-shape appends within capacity must trace NOTHING new: deltas
    are pow2-bucketed, worklists pow2-padded, and row counts / liveness
    enter the jitted inners as traced values (the pad_worklist trick
    extended to the delta-join path)."""
    rng = np.random.default_rng(13)
    mi = MutableAPSSIndex(_rows(rng, 16), threshold=T, k=K, block_rows=64)
    Q = _rows(rng, 4)
    for _ in range(2):  # warmup: trace every delta-join shape once
        mi.append(_rows(rng, 8))
        mi.query(Q)
    with obs_compile.assert_no_retrace("serving.mutable", "serving.query"):
        for _ in range(2):  # rows 32→40→48, all within the 64-row capacity
            mi.append(_rows(rng, 8))
            mi.query(Q)
        mi.delete([int(mi.graph()[0][0])])
        mi.delete([int(mi.graph()[0][0])])


# -- durability meta ---------------------------------------------------------


def test_reopen_restores_bit_identical_state(tmp_path):
    rng = np.random.default_rng(14)
    D = _rows(rng, 48)
    d = str(tmp_path / "idx")
    mi = MutableAPSSIndex(D[:32], threshold=T, k=K, directory=d)
    mi.append(D[32:])
    mi.delete([1, 33])
    g1 = mi.graph()
    re = MutableAPSSIndex(corpus=None, threshold=T, k=K, directory=d)
    g2 = re.graph()
    assert np.array_equal(g1[0], g2[0])
    assert np.array_equal(g1[1].values, g2[1].values)
    assert np.array_equal(g1[1].indices, g2[1].indices)
    assert np.array_equal(g1[1].counts, g2[1].counts)
    Q = _rows(rng, 3)
    ra, rb = mi.query(Q), re.query(Q)
    assert np.array_equal(ra.values, rb.values)
    assert np.array_equal(ra.indices, rb.indices)


def test_reopen_guards(tmp_path):
    rng = np.random.default_rng(15)
    d = str(tmp_path / "idx")
    MutableAPSSIndex(_rows(rng, 16), threshold=T, k=K, directory=d)
    with pytest.raises(ValueError, match="corpus=None to resume"):
        MutableAPSSIndex(_rows(rng, 8), threshold=T, k=K, directory=d)
    with pytest.raises(ValueError, match="meta mismatch"):
        MutableAPSSIndex(corpus=None, threshold=0.9, k=K, directory=d)


# -- telemetry ---------------------------------------------------------------


def test_delta_join_telemetry():
    rng = np.random.default_rng(16)
    D = _rows(rng, 96)
    with telemetry.CommLog() as log:
        mi = MutableAPSSIndex(D[:64], threshold=T, k=K)
        mi.append(D[64:])
        mi.delete([0])
        mi.compact()
    assert log.counters["serving.appends"] == 2
    assert log.counters["serving.deletes"] == 1
    assert log.counters["serving.compactions"] == 1
    recs = log.by_variant("serving/delta-join")
    assert len(recs) == 2
    second = recs[1]
    assert second.extra["delta"] == 32
    assert second.extra["model_flops"] == telemetry.delta_join_flops(
        32, 96, mi._mlanes
    )
    assert 0.0 < second.extra["live_fraction_rows"] <= 1.0
    assert second.live_tiles is not None and second.flops > 0


# -- server LRU invalidation regression (ISSUE 7 satellite) ------------------


def test_server_cache_invalidates_on_mutation():
    """REGRESSION: the LRU is keyed by (query digest, index version) — a
    post-append query must never return a pre-append answer."""
    rng = np.random.default_rng(17)
    D = _rows(rng, 80)
    mi = MutableAPSSIndex(D[:64], threshold=T, k=K)
    srv = RetrievalServer(mi, threshold=T, k=K, max_batch=4)
    q = D[0] / np.linalg.norm(D[0])
    r1 = srv.serve([q])[0]
    assert srv.serve([q])[0].cached  # same version: cache hit
    mi.append(D[64:])
    r3 = srv.serve([q])[0]
    assert not r3.cached  # version bumped: entry is invisible to fresh gets
    assert r3.count >= r1.count
    r4 = srv.serve([q])[0]  # re-cached at the new version
    assert r4.cached and np.array_equal(r4.values, r3.values)
    mi.delete([int(mi.graph()[0][-1])])
    assert not srv.serve([q])[0].cached  # deletes invalidate too


def test_server_stale_tier_may_serve_pre_mutation():
    """The ONLY sanctioned path to a pre-mutation answer: every scoring
    tier down, explicit stale tier, status='stale'."""
    rng = np.random.default_rng(18)
    D = _rows(rng, 80)
    mi = MutableAPSSIndex(D[:64], threshold=T, k=K)
    srv = RetrievalServer(mi, threshold=T, k=K, max_batch=4, max_retries=0)
    q = D[0] / np.linalg.norm(D[0])
    warm = srv.serve([q])[0]
    mi.append(D[64:])
    srv.fault_plan = FaultPlan([Fault("error", scope="serving.xla", times=9)])
    rs = srv.serve([q])[0]
    assert rs.status == "stale" and rs.cached
    assert np.array_equal(rs.values, warm.values)
    assert srv.stats.stale == 1
