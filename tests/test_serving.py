"""Serving-path tests: decode == full forward (all attention/FFN variants),
cache bookkeeping, the LM server loop, and sequence-sharded decode on a mesh."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.transformer import (
    TransformerConfig,
    cache_specs,
    decode_step,
    init_transformer,
    make_cache,
    transformer_logits,
)

COMMON = dict(
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    vocab_size=256, dtype=jnp.float32, q_chunk=16, kv_chunk=16, loss_chunk=16,
)


def _decode_all(cfg, params, tokens, max_len):
    cache = make_cache(cfg, tokens.shape[0], max_len)
    dec = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t))
    logits = None
    for i in range(tokens.shape[1]):
        logits, cache = dec(params, cache, tokens[:, i])
    return logits, cache


@pytest.mark.parametrize(
    "name,extra",
    [
        ("gqa", dict(qk_norm=True)),
        ("mla", dict(attention="mla", n_kv_heads=4, q_lora_rank=32,
                     kv_lora_rank=16, qk_nope_dim=16, qk_rope_dim=8,
                     v_head_dim=16)),
        ("moe", dict(moe=True, n_experts=8, top_k=2, d_ff_expert=32,
                     n_shared_experts=2, dense_residual=True,
                     first_k_dense=1, capacity_factor=8.0)),
    ],
)
def test_decode_matches_full_forward(name, extra):
    cfg = TransformerConfig(name=f"t-{name}", **{**COMMON, **extra})
    params = init_transformer(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(1), (2, 24), 0, 256)
    logits, cache = _decode_all(cfg, params, tokens, 32)
    full = jax.jit(lambda p, t: transformer_logits(p, cfg, t))(params, tokens)
    np.testing.assert_allclose(
        logits, full[:, -1, :], atol=5e-4, rtol=5e-3
    )
    np.testing.assert_array_equal(np.asarray(cache["length"]), 24)


def test_decode_cache_isolated_between_sequences():
    cfg = TransformerConfig(name="t", **COMMON)
    params = init_transformer(jax.random.key(0), cfg)
    t1 = jax.random.randint(jax.random.key(1), (1, 16), 0, 256)
    t2 = jax.random.randint(jax.random.key(2), (1, 16), 0, 256)
    both = jnp.concatenate([t1, t2], axis=0)
    logits_b, _ = _decode_all(cfg, params, both, 24)
    logits_1, _ = _decode_all(cfg, params, t1, 24)
    np.testing.assert_allclose(logits_b[0], logits_1[0], atol=1e-4, rtol=1e-4)


def test_sequence_sharded_decode_on_mesh(mesh4x2):
    """long_500k pattern at toy scale: KV cache sequence dim sharded over
    the mesh; GSPMD-partitioned decode must equal the single-device one."""
    cfg = TransformerConfig(name="t", **COMMON)
    params = init_transformer(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(1), (2, 16), 0, 256)

    # single-device truth
    want, _ = _decode_all(cfg, params, tokens, 32)

    # sequence-sharded: cache seq dim over ("data",) (batch 2 not shardable)
    cache = make_cache(cfg, 2, 32)
    specs = cache_specs(cfg, seq_axes=("data",), batch_axes=())
    c_sh = jax.tree.map(
        lambda s: NamedSharding(mesh4x2, s), specs,
        is_leaf=lambda x: isinstance(x, P),
    )
    cache = jax.tree.map(
        lambda x, s: jax.device_put(x, s), cache, c_sh,
        is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, P),
    )
    dec = jax.jit(
        lambda p, c, t: decode_step(p, cfg, c, t),
        in_shardings=(None, c_sh, None),
        out_shardings=(None, c_sh),
    )
    logits = None
    for i in range(tokens.shape[1]):
        logits, cache = dec(params, cache, tokens[:, i])
    np.testing.assert_allclose(logits, want, atol=5e-4, rtol=5e-3)


def test_lm_server_generates():
    from repro.launch.serve import LMServer

    cfg = TransformerConfig(name="t", **COMMON)
    srv = LMServer(cfg, max_batch=2, max_len=64)
    slot = srv.add_request(np.asarray([3, 5, 7]))
    out = srv.generate(slot, 5)
    assert len(out) == 5
    assert all(0 <= t < cfg.padded_vocab for t in out)
