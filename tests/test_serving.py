"""Serving-path tests.

LM half: decode == full forward (all attention/FFN variants), cache
bookkeeping, the LM server loop, and sequence-sharded decode on a mesh.

Retrieval half (``repro.serving``): APSSIndex build-once/reuse (trace
counters prove the second query rebuilds nothing), rectangular exactness
vs the brute-force oracle across densities/thresholds/k, batched-server ==
one-shot calls, LRU cache behaviour, and sharded partial-merge correctness
on 8 virtual devices."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.transformer import (
    TransformerConfig,
    cache_specs,
    decode_step,
    init_transformer,
    make_cache,
    transformer_logits,
)

COMMON = dict(
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    vocab_size=256, dtype=jnp.float32, q_chunk=16, kv_chunk=16, loss_chunk=16,
)


def _decode_all(cfg, params, tokens, max_len):
    cache = make_cache(cfg, tokens.shape[0], max_len)
    dec = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t))
    logits = None
    for i in range(tokens.shape[1]):
        logits, cache = dec(params, cache, tokens[:, i])
    return logits, cache


@pytest.mark.parametrize(
    "name,extra",
    [
        ("gqa", dict(qk_norm=True)),
        ("mla", dict(attention="mla", n_kv_heads=4, q_lora_rank=32,
                     kv_lora_rank=16, qk_nope_dim=16, qk_rope_dim=8,
                     v_head_dim=16)),
        ("moe", dict(moe=True, n_experts=8, top_k=2, d_ff_expert=32,
                     n_shared_experts=2, dense_residual=True,
                     first_k_dense=1, capacity_factor=8.0)),
    ],
)
def test_decode_matches_full_forward(name, extra):
    cfg = TransformerConfig(name=f"t-{name}", **{**COMMON, **extra})
    params = init_transformer(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(1), (2, 24), 0, 256)
    logits, cache = _decode_all(cfg, params, tokens, 32)
    full = jax.jit(lambda p, t: transformer_logits(p, cfg, t))(params, tokens)
    np.testing.assert_allclose(
        logits, full[:, -1, :], atol=5e-4, rtol=5e-3
    )
    np.testing.assert_array_equal(np.asarray(cache["length"]), 24)


def test_decode_cache_isolated_between_sequences():
    cfg = TransformerConfig(name="t", **COMMON)
    params = init_transformer(jax.random.key(0), cfg)
    t1 = jax.random.randint(jax.random.key(1), (1, 16), 0, 256)
    t2 = jax.random.randint(jax.random.key(2), (1, 16), 0, 256)
    both = jnp.concatenate([t1, t2], axis=0)
    logits_b, _ = _decode_all(cfg, params, both, 24)
    logits_1, _ = _decode_all(cfg, params, t1, 24)
    np.testing.assert_allclose(logits_b[0], logits_1[0], atol=1e-4, rtol=1e-4)


def test_sequence_sharded_decode_on_mesh(mesh4x2):
    """long_500k pattern at toy scale: KV cache sequence dim sharded over
    the mesh; GSPMD-partitioned decode must equal the single-device one."""
    cfg = TransformerConfig(name="t", **COMMON)
    params = init_transformer(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(1), (2, 16), 0, 256)

    # single-device truth
    want, _ = _decode_all(cfg, params, tokens, 32)

    # sequence-sharded: cache seq dim over ("data",) (batch 2 not shardable)
    cache = make_cache(cfg, 2, 32)
    specs = cache_specs(cfg, seq_axes=("data",), batch_axes=())
    c_sh = jax.tree.map(
        lambda s: NamedSharding(mesh4x2, s), specs,
        is_leaf=lambda x: isinstance(x, P),
    )
    cache = jax.tree.map(
        lambda x, s: jax.device_put(x, s), cache, c_sh,
        is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, P),
    )
    dec = jax.jit(
        lambda p, c, t: decode_step(p, cfg, c, t),
        in_shardings=(None, c_sh, None),
        out_shardings=(None, c_sh),
    )
    logits = None
    for i in range(tokens.shape[1]):
        logits, cache = dec(params, cache, tokens[:, i])
    np.testing.assert_allclose(logits, want, atol=5e-4, rtol=5e-3)


def test_lm_server_generates():
    from repro.launch.serve import LMServer

    cfg = TransformerConfig(name="t", **COMMON)
    srv = LMServer(cfg, max_batch=2, max_len=64)
    slot = srv.add_request(np.asarray([3, 5, 7]))
    out = srv.generate(slot, 5)
    assert len(out) == 5
    assert all(0 <= t < cfg.padded_vocab for t in out)


# ===========================================================================
# Retrieval serving: build-once APSSIndex + batched query-time top-k
# ===========================================================================

from repro.core.apss import normalize_rows  # noqa: E402
from repro.core.matches import extract_matches  # noqa: E402
from repro.core.sparse import from_dense  # noqa: E402
from repro.serving import (  # noqa: E402
    RetrievalServer,
    build_index,
    query_topk,
)
from repro.obs import compile as obs_compile  # noqa: E402


def _corpus_queries(n, m, density, nq, seed=0):
    rng = np.random.default_rng(seed)
    C = np.abs(rng.standard_normal((n, m))).astype(np.float32)
    C *= rng.random((n, m)) < density
    Q = np.abs(rng.standard_normal((nq, m))).astype(np.float32)
    Q *= rng.random((nq, m)) < density
    Cn = np.asarray(normalize_rows(jnp.asarray(C)))
    Qn = np.asarray(normalize_rows(jnp.asarray(Q)))
    return Cn, Qn


def _rect_oracle(Qn, Cn, t, k):
    """Brute-force rectangular oracle: dense Q·Cᵀ, threshold, top-k."""
    S = jnp.einsum("qm,cm->qc", jnp.asarray(Qn), jnp.asarray(Cn),
                   preferred_element_type=jnp.float32)
    return extract_matches(S, t, k, exclude_self=False)


def _check_rect(got, ref, nq):
    np.testing.assert_array_equal(
        np.asarray(got.counts), np.asarray(ref.counts)[:nq]
    )
    gv, gi = np.asarray(got.values), np.asarray(got.indices)
    rv, ri = np.asarray(ref.values), np.asarray(ref.indices)
    for r in range(nq):
        assert set(gi[r][gi[r] >= 0]) == set(ri[r][ri[r] >= 0]), r
        np.testing.assert_allclose(
            np.sort(gv[r]), np.sort(rv[r]), atol=1e-5
        )


@pytest.mark.parametrize("density", [0.02, 0.1, 0.3])
@pytest.mark.parametrize("threshold,k", [(0.2, 8), (0.5, 4)])
def test_query_topk_rect_exact_dense_and_sparse(density, threshold, k):
    Cn, Qn = _corpus_queries(220, 96, density, 11, seed=int(density * 100))
    ref = _rect_oracle(Qn, Cn, threshold, k)
    for corpus in (Cn, from_dense(Cn)):
        index = build_index(corpus, block_rows=64, normalize=False)
        got = query_topk(index, jnp.asarray(Qn), threshold, k, block_q=16)
        _check_rect(got, ref, 11)


def test_query_topk_negative_threshold_exact():
    """t ≤ 0: every tile is live (bounds are ≥ 0) and every pair matches —
    the pruning must degrade to full scoring, not drop zero-sim pairs."""
    Cn, Qn = _corpus_queries(96, 64, 0.1, 5, seed=3)
    ref = _rect_oracle(Qn, Cn, -0.5, 6)
    index = build_index(from_dense(Cn), block_rows=32, normalize=False)
    got = query_topk(index, jnp.asarray(Qn), -0.5, 6, block_q=8)
    _check_rect(got, ref, 5)


@pytest.mark.parametrize("kind", ["dense", "sparse"])
def test_query_topk_kernel_matches_xla(kind):
    Cn, Qn = _corpus_queries(256, 128, 0.1, 8, seed=9)
    corpus = Cn if kind == "dense" else from_dense(Cn)
    index = build_index(corpus, block_rows=128, normalize=False)
    ref = _rect_oracle(Qn, Cn, 0.3, 8)
    got = query_topk(
        index, jnp.asarray(Qn), 0.3, 8, block_q=128, use_kernel=True
    )
    _check_rect(got, ref, 8)


def test_index_built_once_and_reused_no_retrace():
    """The serving contract: the SECOND query (same shapes) traces nothing
    and rebuilds no support structure — all index leaves enter the jitted
    inners as arguments, and the worklist bucket absorbs live-tile drift."""
    import repro.serving.index as sindex

    Cn, Qn = _corpus_queries(220, 96, 0.1, 8, seed=5)
    index = build_index(from_dense(Cn), block_rows=64, normalize=False)

    got0 = query_topk(index, jnp.asarray(Qn), 0.3, 8, block_q=16)
    # Support-structure builders must not run during queries at all, and
    # no serving entry point may re-trace (public contract API).
    orig = sindex.block_support_gather
    sindex.block_support_gather = None  # any call would TypeError
    try:
        with obs_compile.assert_no_retrace("serving.query"):
            got1 = query_topk(index, jnp.asarray(Qn * 0.7), 0.3, 8, block_q=16)
    finally:
        sindex.block_support_gather = orig
    # Scaled queries keep the same candidate structure admissible but must
    # rescore: results reflect the new values.
    assert np.all(np.asarray(got1.counts) <= np.asarray(got0.counts))
    ref = _rect_oracle(Qn * 0.7, Cn, 0.3, 8)
    _check_rect(got1, ref, 8)


def test_query_batches_hit_worklist_bucket_cache():
    """Different query batches with different live-tile counts land in the
    same power-of-two bucket → zero new traces after warm-up."""
    Cn, _ = _corpus_queries(220, 96, 0.1, 4, seed=6)
    index = build_index(Cn, block_rows=64, normalize=False)
    rng = np.random.default_rng(0)
    traced = []
    for i in range(4):
        Q = np.abs(rng.standard_normal((4, 96))).astype(np.float32)
        Q *= rng.random((4, 96)) < (0.05 + 0.1 * i)
        Qn = np.asarray(normalize_rows(jnp.asarray(Q)))
        before = sum(obs_compile.snapshot().values())
        query_topk(index, jnp.asarray(Qn), 0.25, 4, block_q=8)
        traced.append(sum(obs_compile.snapshot().values()) - before)
    # O(log tiles) buckets, not O(calls): at most the first two calls may
    # compile (distinct bucket sizes); later batches must all be cache hits.
    assert traced[-1] == 0 and traced[-2] == 0, traced


def test_retrieval_server_batched_equals_oneshot():
    Cn, Qn = _corpus_queries(220, 96, 0.12, 10, seed=7)
    index = build_index(Cn, block_rows=64, normalize=False)
    srv = RetrievalServer(
        index, threshold=0.3, k=8, max_batch=4, normalize=False, block_q=8
    )
    results = srv.serve([Qn[i] for i in range(10)])
    assert len(results) == 10 and srv.stats.steps == 3  # 4+4+2 in 3 batches
    for i, res in enumerate(results):
        one = query_topk(index, jnp.asarray(Qn[i][None]), 0.3, 8, block_q=8)
        assert res.count == int(np.asarray(one.counts)[0]), i
        oi = np.asarray(one.indices)[0]
        assert set(res.indices[res.indices >= 0]) == set(oi[oi >= 0]), i
        np.testing.assert_allclose(
            np.sort(res.values), np.sort(np.asarray(one.values)[0]), atol=1e-6
        )


def test_retrieval_server_lru_cache():
    Cn, Qn = _corpus_queries(96, 64, 0.15, 3, seed=8)
    index = build_index(Cn, block_rows=32, normalize=False)
    srv = RetrievalServer(
        index, threshold=0.2, k=4, max_batch=2, normalize=False,
        block_q=4, cache_size=2,
    )
    first = srv.serve([Qn[0], Qn[1]])
    again = srv.serve([Qn[0]])  # repeat → cache, no step
    assert not first[0].cached and again[0].cached
    assert srv.stats.cache_hits == 1
    np.testing.assert_array_equal(first[0].indices, again[0].indices)
    # Capacity 2: touching a third distinct query evicts the LRU entry.
    srv.serve([Qn[2]])
    assert not srv.serve([Qn[1]])[0].cached  # evicted → recomputed


@pytest.mark.parametrize("kind", ["dense", "sparse"])
def test_sharded_index_partial_merge_exact(mesh8, kind):
    """8-way sharded placement: per-shard top-k partials merged host-side
    must equal the single-host result AND the oracle (global ids, corpus
    padding in the last shard masked)."""
    Cn, Qn = _corpus_queries(220, 96, 0.12, 9, seed=11)  # 220 % 8 ≠ 0 → pad
    corpus = Cn if kind == "dense" else from_dense(Cn)
    index = build_index(corpus, block_rows=16, mesh=mesh8, normalize=False)
    assert index.n_padded % (8 * 16) == 0
    ref = _rect_oracle(Qn, Cn, 0.3, 8)
    got = query_topk(index, jnp.asarray(Qn), 0.3, 8)
    _check_rect(got, ref, 9)


def test_sharded_index_second_query_no_retrace(mesh8):
    Cn, Qn = _corpus_queries(128, 64, 0.15, 4, seed=12)
    index = build_index(Cn, block_rows=16, mesh=mesh8, normalize=False)
    query_topk(index, jnp.asarray(Qn), 0.3, 4)
    with obs_compile.assert_no_retrace("serving.query"):
        query_topk(index, jnp.asarray(Qn * 0.5), 0.3, 4)
