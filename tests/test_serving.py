"""Serving-path tests.

LM half: decode == full forward (all attention/FFN variants), cache
bookkeeping, the LM server loop, and sequence-sharded decode on a mesh.

Retrieval half (``repro.serving``): APSSIndex build-once/reuse (trace
counters prove the second query rebuilds nothing), rectangular exactness
vs the brute-force oracle across densities/thresholds/k, batched-server ==
one-shot calls, LRU cache behaviour, and sharded partial-merge correctness
on 8 virtual devices."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.transformer import (
    TransformerConfig,
    cache_specs,
    decode_step,
    init_transformer,
    make_cache,
    transformer_logits,
)

COMMON = dict(
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    vocab_size=256, dtype=jnp.float32, q_chunk=16, kv_chunk=16, loss_chunk=16,
)


def _decode_all(cfg, params, tokens, max_len):
    cache = make_cache(cfg, tokens.shape[0], max_len)
    dec = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t))
    logits = None
    for i in range(tokens.shape[1]):
        logits, cache = dec(params, cache, tokens[:, i])
    return logits, cache


@pytest.mark.parametrize(
    "name,extra",
    [
        ("gqa", dict(qk_norm=True)),
        ("mla", dict(attention="mla", n_kv_heads=4, q_lora_rank=32,
                     kv_lora_rank=16, qk_nope_dim=16, qk_rope_dim=8,
                     v_head_dim=16)),
        ("moe", dict(moe=True, n_experts=8, top_k=2, d_ff_expert=32,
                     n_shared_experts=2, dense_residual=True,
                     first_k_dense=1, capacity_factor=8.0)),
    ],
)
def test_decode_matches_full_forward(name, extra):
    cfg = TransformerConfig(name=f"t-{name}", **{**COMMON, **extra})
    params = init_transformer(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(1), (2, 24), 0, 256)
    logits, cache = _decode_all(cfg, params, tokens, 32)
    full = jax.jit(lambda p, t: transformer_logits(p, cfg, t))(params, tokens)
    np.testing.assert_allclose(
        logits, full[:, -1, :], atol=5e-4, rtol=5e-3
    )
    np.testing.assert_array_equal(np.asarray(cache["length"]), 24)


def test_decode_cache_isolated_between_sequences():
    cfg = TransformerConfig(name="t", **COMMON)
    params = init_transformer(jax.random.key(0), cfg)
    t1 = jax.random.randint(jax.random.key(1), (1, 16), 0, 256)
    t2 = jax.random.randint(jax.random.key(2), (1, 16), 0, 256)
    both = jnp.concatenate([t1, t2], axis=0)
    logits_b, _ = _decode_all(cfg, params, both, 24)
    logits_1, _ = _decode_all(cfg, params, t1, 24)
    np.testing.assert_allclose(logits_b[0], logits_1[0], atol=1e-4, rtol=1e-4)


def test_sequence_sharded_decode_on_mesh(mesh4x2):
    """long_500k pattern at toy scale: KV cache sequence dim sharded over
    the mesh; GSPMD-partitioned decode must equal the single-device one."""
    cfg = TransformerConfig(name="t", **COMMON)
    params = init_transformer(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(1), (2, 16), 0, 256)

    # single-device truth
    want, _ = _decode_all(cfg, params, tokens, 32)

    # sequence-sharded: cache seq dim over ("data",) (batch 2 not shardable)
    cache = make_cache(cfg, 2, 32)
    specs = cache_specs(cfg, seq_axes=("data",), batch_axes=())
    c_sh = jax.tree.map(
        lambda s: NamedSharding(mesh4x2, s), specs,
        is_leaf=lambda x: isinstance(x, P),
    )
    cache = jax.tree.map(
        lambda x, s: jax.device_put(x, s), cache, c_sh,
        is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, P),
    )
    dec = jax.jit(
        lambda p, c, t: decode_step(p, cfg, c, t),
        in_shardings=(None, c_sh, None),
        out_shardings=(None, c_sh),
    )
    logits = None
    for i in range(tokens.shape[1]):
        logits, cache = dec(params, cache, tokens[:, i])
    np.testing.assert_allclose(logits, want, atol=5e-4, rtol=5e-3)


def test_lm_server_generates():
    from repro.launch.serve import LMServer

    cfg = TransformerConfig(name="t", **COMMON)
    srv = LMServer(cfg, max_batch=2, max_len=64)
    slot = srv.add_request(np.asarray([3, 5, 7]))
    out = srv.generate(slot, 5)
    assert len(out) == 5
    assert all(0 <= t < cfg.padded_vocab for t in out)


# ===========================================================================
# Retrieval serving: build-once APSSIndex + batched query-time top-k
# ===========================================================================

from repro.core.apss import normalize_rows  # noqa: E402
from repro.core.matches import extract_matches  # noqa: E402
from repro.core.sparse import from_dense  # noqa: E402
from repro.serving import (  # noqa: E402
    RetrievalServer,
    build_index,
    query_topk,
)
from repro.obs import compile as obs_compile  # noqa: E402


def _corpus_queries(n, m, density, nq, seed=0):
    rng = np.random.default_rng(seed)
    C = np.abs(rng.standard_normal((n, m))).astype(np.float32)
    C *= rng.random((n, m)) < density
    Q = np.abs(rng.standard_normal((nq, m))).astype(np.float32)
    Q *= rng.random((nq, m)) < density
    Cn = np.asarray(normalize_rows(jnp.asarray(C)))
    Qn = np.asarray(normalize_rows(jnp.asarray(Q)))
    return Cn, Qn


def _rect_oracle(Qn, Cn, t, k):
    """Brute-force rectangular oracle: dense Q·Cᵀ, threshold, top-k."""
    S = jnp.einsum("qm,cm->qc", jnp.asarray(Qn), jnp.asarray(Cn),
                   preferred_element_type=jnp.float32)
    return extract_matches(S, t, k, exclude_self=False)


def _check_rect(got, ref, nq):
    np.testing.assert_array_equal(
        np.asarray(got.counts), np.asarray(ref.counts)[:nq]
    )
    gv, gi = np.asarray(got.values), np.asarray(got.indices)
    rv, ri = np.asarray(ref.values), np.asarray(ref.indices)
    for r in range(nq):
        assert set(gi[r][gi[r] >= 0]) == set(ri[r][ri[r] >= 0]), r
        np.testing.assert_allclose(
            np.sort(gv[r]), np.sort(rv[r]), atol=1e-5
        )


@pytest.mark.parametrize("density", [0.02, 0.1, 0.3])
@pytest.mark.parametrize("threshold,k", [(0.2, 8), (0.5, 4)])
def test_query_topk_rect_exact_dense_and_sparse(density, threshold, k):
    Cn, Qn = _corpus_queries(220, 96, density, 11, seed=int(density * 100))
    ref = _rect_oracle(Qn, Cn, threshold, k)
    for corpus in (Cn, from_dense(Cn)):
        index = build_index(corpus, block_rows=64, normalize=False)
        got = query_topk(index, jnp.asarray(Qn), threshold, k, block_q=16)
        _check_rect(got, ref, 11)


def test_query_topk_negative_threshold_exact():
    """t ≤ 0: every tile is live (bounds are ≥ 0) and every pair matches —
    the pruning must degrade to full scoring, not drop zero-sim pairs."""
    Cn, Qn = _corpus_queries(96, 64, 0.1, 5, seed=3)
    ref = _rect_oracle(Qn, Cn, -0.5, 6)
    index = build_index(from_dense(Cn), block_rows=32, normalize=False)
    got = query_topk(index, jnp.asarray(Qn), -0.5, 6, block_q=8)
    _check_rect(got, ref, 5)


@pytest.mark.parametrize("kind", ["dense", "sparse"])
def test_query_topk_kernel_matches_xla(kind):
    Cn, Qn = _corpus_queries(256, 128, 0.1, 8, seed=9)
    corpus = Cn if kind == "dense" else from_dense(Cn)
    index = build_index(corpus, block_rows=128, normalize=False)
    ref = _rect_oracle(Qn, Cn, 0.3, 8)
    got = query_topk(
        index, jnp.asarray(Qn), 0.3, 8, block_q=128, use_kernel=True
    )
    _check_rect(got, ref, 8)


def test_index_built_once_and_reused_no_retrace():
    """The serving contract: the SECOND query (same shapes) traces nothing
    and rebuilds no support structure — all index leaves enter the jitted
    inners as arguments, and the worklist bucket absorbs live-tile drift."""
    import repro.serving.index as sindex

    Cn, Qn = _corpus_queries(220, 96, 0.1, 8, seed=5)
    index = build_index(from_dense(Cn), block_rows=64, normalize=False)

    got0 = query_topk(index, jnp.asarray(Qn), 0.3, 8, block_q=16)
    # Support-structure builders must not run during queries at all, and
    # no serving entry point may re-trace (public contract API).
    orig = sindex.block_support_gather
    sindex.block_support_gather = None  # any call would TypeError
    try:
        with obs_compile.assert_no_retrace("serving.query"):
            got1 = query_topk(index, jnp.asarray(Qn * 0.7), 0.3, 8, block_q=16)
    finally:
        sindex.block_support_gather = orig
    # Scaled queries keep the same candidate structure admissible but must
    # rescore: results reflect the new values.
    assert np.all(np.asarray(got1.counts) <= np.asarray(got0.counts))
    ref = _rect_oracle(Qn * 0.7, Cn, 0.3, 8)
    _check_rect(got1, ref, 8)


def test_query_batches_hit_worklist_bucket_cache():
    """Different query batches with different live-tile counts land in the
    same power-of-two bucket → zero new traces after warm-up."""
    Cn, _ = _corpus_queries(220, 96, 0.1, 4, seed=6)
    index = build_index(Cn, block_rows=64, normalize=False)
    rng = np.random.default_rng(0)
    traced = []
    for i in range(4):
        Q = np.abs(rng.standard_normal((4, 96))).astype(np.float32)
        Q *= rng.random((4, 96)) < (0.05 + 0.1 * i)
        Qn = np.asarray(normalize_rows(jnp.asarray(Q)))
        before = sum(obs_compile.snapshot().values())
        query_topk(index, jnp.asarray(Qn), 0.25, 4, block_q=8)
        traced.append(sum(obs_compile.snapshot().values()) - before)
    # O(log tiles) buckets, not O(calls): at most the first two calls may
    # compile (distinct bucket sizes); later batches must all be cache hits.
    assert traced[-1] == 0 and traced[-2] == 0, traced


def test_retrieval_server_batched_equals_oneshot():
    Cn, Qn = _corpus_queries(220, 96, 0.12, 10, seed=7)
    index = build_index(Cn, block_rows=64, normalize=False)
    srv = RetrievalServer(
        index, threshold=0.3, k=8, max_batch=4, normalize=False, block_q=8
    )
    results = srv.serve([Qn[i] for i in range(10)])
    assert len(results) == 10 and srv.stats.steps == 3  # 4+4+2 in 3 batches
    for i, res in enumerate(results):
        one = query_topk(index, jnp.asarray(Qn[i][None]), 0.3, 8, block_q=8)
        assert res.count == int(np.asarray(one.counts)[0]), i
        oi = np.asarray(one.indices)[0]
        assert set(res.indices[res.indices >= 0]) == set(oi[oi >= 0]), i
        np.testing.assert_allclose(
            np.sort(res.values), np.sort(np.asarray(one.values)[0]), atol=1e-6
        )


def test_retrieval_server_lru_cache():
    Cn, Qn = _corpus_queries(96, 64, 0.15, 3, seed=8)
    index = build_index(Cn, block_rows=32, normalize=False)
    srv = RetrievalServer(
        index, threshold=0.2, k=4, max_batch=2, normalize=False,
        block_q=4, cache_size=2,
    )
    first = srv.serve([Qn[0], Qn[1]])
    again = srv.serve([Qn[0]])  # repeat → cache, no step
    assert not first[0].cached and again[0].cached
    assert srv.stats.cache_hits == 1
    np.testing.assert_array_equal(first[0].indices, again[0].indices)
    # Capacity 2: touching a third distinct query evicts the LRU entry.
    srv.serve([Qn[2]])
    assert not srv.serve([Qn[1]])[0].cached  # evicted → recomputed


@pytest.mark.parametrize("kind", ["dense", "sparse"])
def test_sharded_index_partial_merge_exact(mesh8, kind):
    """8-way sharded placement: per-shard top-k partials merged host-side
    must equal the single-host result AND the oracle (global ids, corpus
    padding in the last shard masked)."""
    Cn, Qn = _corpus_queries(220, 96, 0.12, 9, seed=11)  # 220 % 8 ≠ 0 → pad
    corpus = Cn if kind == "dense" else from_dense(Cn)
    index = build_index(corpus, block_rows=16, mesh=mesh8, normalize=False)
    assert index.n_padded % (8 * 16) == 0
    ref = _rect_oracle(Qn, Cn, 0.3, 8)
    got = query_topk(index, jnp.asarray(Qn), 0.3, 8)
    _check_rect(got, ref, 9)


def test_sharded_index_second_query_no_retrace(mesh8):
    Cn, Qn = _corpus_queries(128, 64, 0.15, 4, seed=12)
    index = build_index(Cn, block_rows=16, mesh=mesh8, normalize=False)
    query_topk(index, jnp.asarray(Qn), 0.3, 4)
    with obs_compile.assert_no_retrace("serving.query"):
        query_topk(index, jnp.asarray(Qn * 0.5), 0.3, 4)


# ===========================================================================
# ISSUE 10: traced early-exit, sharded-pruning kernel, per-batch plans,
# continuous batching
# ===========================================================================


def _check_early_exit_bitexact(ref, got, k):
    """Early exit is bit-exact on values AND indices; its counts saturate
    at k (the while_loop stops counting once every row holds k)."""
    np.testing.assert_array_equal(np.asarray(ref.values), np.asarray(got.values))
    np.testing.assert_array_equal(
        np.asarray(ref.indices), np.asarray(got.indices)
    )
    np.testing.assert_array_equal(
        np.minimum(np.asarray(ref.counts), k), np.asarray(got.counts)
    )


@pytest.mark.parametrize("density", [0.02, 0.1, 0.3])
@pytest.mark.parametrize("threshold,k", [(0.2, 8), (0.5, 4), (-0.5, 6)])
def test_query_topk_early_exit_exact(density, threshold, k):
    """EE vs the full scan across densities × thresholds × both corpus
    representations: identical values and indices, counts saturated at k
    (the full scan itself is oracle-checked above)."""
    Cn, Qn = _corpus_queries(220, 96, density, 11, seed=int(density * 100))
    for corpus in (Cn, from_dense(Cn)):
        index = build_index(corpus, block_rows=64, normalize=False)
        ref = query_topk(index, jnp.asarray(Qn), threshold, k, block_q=16)
        got = query_topk(
            index, jnp.asarray(Qn), threshold, k, block_q=16, early_exit=True
        )
        _check_early_exit_bitexact(ref, got, k)


def test_query_topk_early_exit_kernel_exact():
    """The Pallas EE kernel (interpret mode off-TPU) matches the scan EE
    path and the full scan."""
    Cn, Qn = _corpus_queries(256, 128, 0.1, 8, seed=9)
    index = build_index(Cn, block_rows=128, normalize=False)
    ref = query_topk(index, jnp.asarray(Qn), 0.3, 8, block_q=128)
    got = query_topk(
        index, jnp.asarray(Qn), 0.3, 8, block_q=128,
        early_exit=True, use_kernel=True,
    )
    _check_early_exit_bitexact(ref, got, 8)


def test_early_exit_skips_tiles_on_overlap_clusters():
    """The regime EE is for (DESIGN.md §12): clustered corpus with a weak
    shared vocabulary — cross-cluster tiles stay live (mask can't drop
    them) but lose to within-cluster top-k, so the ub-ordered scan skips
    them. Skips must be > 0 AND the result bit-exact."""
    from repro.data.sparse import perturbed_queries, sparse_clustered_corpus
    from repro.obs.metrics import MetricsRegistry

    sp = sparse_clustered_corpus(
        2048, 1024, 16.0, n_clusters=16, seed=2, overlap_dims=8
    )
    index = build_index(sp, block_rows=64, normalize=False)
    Q = jnp.asarray(perturbed_queries(sp, 64, seed=3))
    with MetricsRegistry() as reg:
        ref = query_topk(index, Q, 0.01, 8)
        got = query_topk(index, Q, 0.01, 8, early_exit=True)
    skipped = int(reg.counters.get("serving.early_exit_skipped_tiles", 0))
    assert skipped > 0
    _check_early_exit_bitexact(ref, got, 8)


def test_early_exit_no_retrace_across_batches():
    """EE inners carry nq_valid as a traced scalar: different batch sizes
    within one block_q bucket must not re-trace."""
    Cn, Qn = _corpus_queries(220, 96, 0.1, 8, seed=5)
    index = build_index(Cn, block_rows=64, normalize=False)
    query_topk(index, jnp.asarray(Qn), 0.3, 8, block_q=16, early_exit=True)
    with obs_compile.assert_no_retrace("serving.query"):
        query_topk(
            index, jnp.asarray(Qn[:5] * 0.7), 0.3, 8, block_q=16,
            early_exit=True,
        )


def test_sharded_early_exit_not_implemented(mesh8):
    Cn, Qn = _corpus_queries(128, 64, 0.15, 4, seed=12)
    index = build_index(Cn, block_rows=16, mesh=mesh8, normalize=False)
    with pytest.raises(NotImplementedError):
        query_topk(index, jnp.asarray(Qn), 0.3, 4, early_exit=True)


@pytest.mark.parametrize("kind", ["dense", "sparse"])
def test_sharded_query_pruned_parity(mesh8, kind):
    """Sharded-query pruning (tentpole a): per-shard compacted worklists
    through one shard_map must equal the unsharded path and the oracle."""
    Cn, Qn = _corpus_queries(220, 96, 0.12, 9, seed=11)
    corpus = Cn if kind == "dense" else from_dense(Cn)
    index = build_index(corpus, block_rows=16, mesh=mesh8, normalize=False)
    flat = build_index(corpus, block_rows=16, normalize=False)
    ref = _rect_oracle(Qn, Cn, 0.3, 8)
    got_sh = query_topk(index, jnp.asarray(Qn), 0.3, 8)
    got_fl = query_topk(flat, jnp.asarray(Qn), 0.3, 8)
    _check_rect(got_sh, ref, 9)
    np.testing.assert_array_equal(
        np.asarray(got_sh.counts), np.asarray(got_fl.counts)
    )


def test_sharded_query_kernel_parity(mesh8):
    """The sharded kernel path (3-row worklists carrying global packet
    ids) must match the sharded scan path exactly."""
    Cn, Qn = _corpus_queries(256, 96, 0.12, 8, seed=13)
    index = build_index(Cn, block_rows=32, mesh=mesh8, normalize=False)
    ref = _rect_oracle(Qn, Cn, 0.3, 8)
    got = query_topk(index, jnp.asarray(Qn), 0.3, 8, use_kernel=True)
    _check_rect(got, ref, 8)


def test_plan_query_topk_sanity():
    """Per-batch plans (tentpole c): exact BlockStats in, a concrete
    (block_q, use_kernel) out, telemetry record emitted, and the planned
    call stays exact."""
    from repro.planner import telemetry
    from repro.planner.costmodel import QueryPlan, plan_query_topk

    Cn, Qn = _corpus_queries(220, 96, 0.1, 8, seed=21)
    index = build_index(Cn, block_rows=64, normalize=False)
    with telemetry.CommLog() as log:
        plan = plan_query_topk(index, 8, 0.3, k=8)
    assert isinstance(plan, QueryPlan)
    assert plan.block_q in (8, 16, 32, 64, 128)
    assert plan.predicted_us > 0
    assert 0.0 <= plan.live_block_fraction <= 1.0
    assert not plan.use_kernel  # CPU: kernel tier not offered
    assert any(
        getattr(r, "variant", None) == "serving/plan" for r in log.records
    )
    ref = _rect_oracle(Qn, Cn, 0.3, 8)
    _check_rect(query_topk(index, jnp.asarray(Qn), 0.3, 8, plan=plan), ref, 8)
    _check_rect(
        query_topk(index, jnp.asarray(Qn), 0.3, 8, plan="auto"), ref, 8
    )


def test_continuous_server_equals_oneshot():
    """Continuous batching (tentpole d): slot-granularity admission changes
    scheduling, never results — every response equals the one-shot call."""
    from repro.serving import ContinuousRetrievalServer

    Cn, Qn = _corpus_queries(220, 96, 0.12, 10, seed=7)
    index = build_index(Cn, block_rows=64, normalize=False)
    with ContinuousRetrievalServer(
        index, workers=2, threshold=0.3, k=8, max_batch=4,
        normalize=False, block_q=8,
    ) as srv:
        results = srv.serve([Qn[i] for i in range(10)])
    assert len(results) == 10
    assert all(r.status == "ok" for r in results)
    for i, res in enumerate(results):
        one = query_topk(index, jnp.asarray(Qn[i][None]), 0.3, 8, block_q=8)
        assert res.count == int(np.asarray(one.counts)[0]), i
        oi = np.asarray(one.indices)[0]
        assert set(res.indices[res.indices >= 0]) == set(oi[oi >= 0]), i
        np.testing.assert_allclose(
            np.sort(res.values), np.sort(np.asarray(one.values)[0]), atol=1e-6
        )


def test_continuous_server_chaos_slow_slot_sheds_late_keeps_exact():
    """A FaultPlan-delayed slot stalls one batch; requests that expire in
    the queue behind it are shed, claimed requests complete exactly —
    shed-late-keep-exact, the degraded-tier contract under continuous
    admission."""
    from repro.robust.faults import Fault, FaultPlan
    from repro.serving import ContinuousRetrievalServer

    Cn, Qn = _corpus_queries(96, 64, 0.15, 12, seed=8)
    index = build_index(Cn, block_rows=32, normalize=False)
    plan = FaultPlan([Fault("delay", "serving", step=0, seconds=0.4)])
    with ContinuousRetrievalServer(
        index, workers=1, threshold=0.2, k=4, max_batch=2,
        normalize=False, block_q=4, deadline_s=0.15, fault_plan=plan,
        cache_size=0,
    ) as srv:
        rids = [srv.submit(Qn[i]) for i in range(12)]
        results = [srv.result(r) for r in rids]
    assert plan.fired.get("delay:serving", 0) == 1
    statuses = {r.status for r in results}
    assert "shed" in statuses and "ok" in statuses
    for i, res in enumerate(results):
        if res.status != "ok":
            assert res.count == 0
            continue
        one = query_topk(index, jnp.asarray(Qn[i][None]), 0.2, 4, block_q=4)
        assert res.count == int(np.asarray(one.counts)[0]), i


def test_continuous_server_result_unknown_rid_raises():
    from repro.serving import ContinuousRetrievalServer

    Cn, Qn = _corpus_queries(96, 64, 0.15, 2, seed=9)
    index = build_index(Cn, block_rows=32, normalize=False)
    with ContinuousRetrievalServer(
        index, workers=1, threshold=0.2, k=4, max_batch=2, normalize=False,
        block_q=4,
    ) as srv:
        rid = srv.submit(Qn[0])
        srv.result(rid)
        with pytest.raises(KeyError):
            srv.result(rid + 999)
