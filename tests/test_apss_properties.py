"""Property-based tests (hypothesis) for the system's invariants.

The load-bearing guarantees of the paper's method:
  1. Lemma 1 (local pruning soundness): for ANY dimension partition, a
     global match has a partial score ≥ t/p on at least one shard.
  2. Block bounds are true upper bounds → pruning never loses a match.
  3. Threshold monotonicity: raising t can only shrink the match set.
  4. Permutation equivariance of the match structure.
  5. Symmetry: i matches j ⇔ j matches i (counts are symmetric).
"""

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.apss import apss_reference, normalize_rows
from repro.core.graph import match_set
from repro.core.matches import dedupe_candidates
from repro.core.pruning import (
    block_maxweight_bounds,
    block_prune_mask,
    block_upper_bounds,
    local_threshold,
)

SET = settings(max_examples=25, deadline=None)


def _corpus(seed: int, n: int, m: int, density: float) -> np.ndarray:
    rng = np.random.default_rng(seed)
    D = np.abs(rng.standard_normal((n, m))).astype(np.float32)
    D *= rng.random((n, m)) < density
    D[0, 0] = 1.0  # guarantee at least one nonzero row
    return np.asarray(normalize_rows(jnp.asarray(D)))


@SET
@given(
    seed=st.integers(0, 10_000),
    p=st.sampled_from([2, 3, 4, 8]),
    t=st.floats(0.05, 0.9),
)
def test_lemma1_local_pruning_soundness(seed, p, t):
    """For a random partition of dims into p shards, every global match has
    local partial ≥ t/p somewhere (the paper's Lemma 1)."""
    D = _corpus(seed, 24, 32, 0.4)
    rng = np.random.default_rng(seed + 1)
    assignment = rng.integers(0, p, size=D.shape[1])
    S = D @ D.T
    t_loc = float(local_threshold(t, p))
    partials = np.stack(
        [D[:, assignment == shard] @ D[:, assignment == shard].T
         if (assignment == shard).any()
         else np.zeros_like(S)
         for shard in range(p)]
    )
    ii, jj = np.where((S >= t) & ~np.eye(len(D), dtype=bool))
    for i, j in zip(ii, jj):
        assert partials[:, i, j].max() >= t_loc - 1e-6


@SET
@given(seed=st.integers(0, 10_000), t=st.floats(0.1, 0.95))
def test_block_bounds_sound(seed, t):
    D = _corpus(seed, 32, 24, 0.5)
    b = 8
    ub = np.asarray(
        block_upper_bounds(
            block_maxweight_bounds(jnp.asarray(D), b),
            block_maxweight_bounds(jnp.asarray(D), b),
        )
    )
    mask = np.asarray(block_prune_mask(jnp.asarray(D), jnp.asarray(D), t, b))
    S = D @ D.T
    nb = len(D) // b
    for i in range(nb):
        for j in range(nb):
            blk = S[i * b:(i + 1) * b, j * b:(j + 1) * b]
            assert ub[i, j] >= blk.max() - 1e-5
            if not mask[i, j]:
                # pruned ⇒ provably no match in the tile
                off_diag = blk.copy()
                if i == j:
                    np.fill_diagonal(off_diag, 0.0)
                assert off_diag.max() < t


@SET
@given(
    seed=st.integers(0, 10_000),
    t1=st.floats(0.1, 0.5),
    dt=st.floats(0.01, 0.4),
)
def test_threshold_monotonicity(seed, t1, dt):
    D = _corpus(seed, 40, 24, 0.4)
    lo = apss_reference(jnp.asarray(D), t1, 64)
    hi = apss_reference(jnp.asarray(D), t1 + dt, 64)
    assert match_set(hi) <= match_set(lo)
    assert (np.asarray(hi.counts) <= np.asarray(lo.counts)).all()


@SET
@given(seed=st.integers(0, 10_000))
def test_permutation_equivariance(seed):
    D = _corpus(seed, 30, 20, 0.5)
    t = 0.3
    perm = np.random.default_rng(seed + 7).permutation(len(D))
    base = apss_reference(jnp.asarray(D), t, 64)
    permd = apss_reference(jnp.asarray(D[perm]), t, 64)
    inv = np.empty_like(perm)
    inv[perm] = np.arange(len(perm))
    remapped = {
        (min(inv[i], inv[j]), max(inv[i], inv[j]))
        for i, j in (
            (perm[a], perm[b]) for a, b in match_set(permd)
        )
    }
    # match_set of permuted data, mapped back, equals the original set
    got = {(min(perm[a], perm[b]), max(perm[a], perm[b])) for a, b in match_set(permd)}
    want = match_set(base)
    assert got == want


@SET
@given(seed=st.integers(0, 10_000), t=st.floats(0.1, 0.8))
def test_symmetry(seed, t):
    D = _corpus(seed, 40, 24, 0.4)
    ref = apss_reference(jnp.asarray(D), t, 64)
    idx = np.asarray(ref.indices)
    for i in range(idx.shape[0]):
        for j in idx[i]:
            if j >= 0:
                assert i in idx[j], (i, j)


@SET
@given(
    seed=st.integers(0, 10_000),
    c=st.integers(2, 12),
)
def test_dedupe_idempotent_and_sum_preserving(seed, c):
    rng = np.random.default_rng(seed)
    idx = rng.integers(-1, 6, size=(4, c)).astype(np.int32)
    vals = rng.random((4, c)).astype(np.float32)
    # equal-index entries must carry equal values (the vertical-compressed
    # contract: duplicates hold identical accumulated scores)
    for r in range(4):
        for u in np.unique(idx[r]):
            if u >= 0:
                vals[r, idx[r] == u] = vals[r, idx[r] == u][0]
    v1, i1 = dedupe_candidates(jnp.asarray(vals), jnp.asarray(idx))
    # per row: surviving index set == unique non-negative input indices
    for r in range(4):
        want = set(int(u) for u in np.unique(idx[r]) if u >= 0)
        got = set(int(u) for u in np.asarray(i1[r]) if u >= 0)
        assert got == want
