"""Fault-injection suite (ISSUE 6): resumable sweeps + checkpoint integrity.

Recovery is proven for the three fault classes the acceptance criteria
name — kill-mid-sweep (resume on the same AND a reshaped mesh, bit-identical
to an uninterrupted run), checkpoint corruption (detected at load, restore
falls back one kept step), and straggler eviction (StepTimer report → a
smaller mesh → resumed sweep still exact). Every fault comes from a seeded
``FaultPlan`` so each failure is deterministic and each test asserts the
fault actually fired.
"""

import os

import jax
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.checkpoint import (
    CheckpointCorruptionError,
    CheckpointManager,
    load_checkpoint,
    save_checkpoint,
)
from repro.core.apss import apss_reference
from repro.distributed.straggler import StepTimer
from repro.planner import telemetry
from repro.robust import (
    Fault,
    FaultPlan,
    InjectedFault,
    ResumableSweep,
    SweepKilled,
    mesh_after_eviction,
)

T, K, BN = 0.35, 16, 32


def _matches_equal(a, b):
    return (
        np.array_equal(np.asarray(a.values), np.asarray(b.values))
        and np.array_equal(np.asarray(a.indices), np.asarray(b.indices))
        and np.array_equal(np.asarray(a.counts), np.asarray(b.counts))
    )


# ---------------------------------------------------------------------------
# FaultPlan determinism + semantics
# ---------------------------------------------------------------------------


def test_chaos_plan_is_deterministic():
    a = FaultPlan.chaos(7, steps=16, kill=True)
    b = FaultPlan.chaos(7, steps=16, kill=True)
    assert a.faults == b.faults
    c = FaultPlan.chaos(8, steps=16, kill=True)
    assert a.faults != c.faults


def test_fault_times_are_consumed():
    plan = FaultPlan([Fault("error", scope="s", times=2)])
    for _ in range(2):
        with pytest.raises(InjectedFault):
            plan.fail_point("s")
    plan.fail_point("s")  # exhausted: no-op
    assert plan.fired["error:s"] == 2
    assert not plan.armed("error", "s")


def test_unmatched_hooks_are_noops():
    plan = FaultPlan([Fault("kill", step=3)])
    plan.kill_point(2)
    plan.fail_point("anything")
    assert plan.delay("sweep", step=0) == 0.0
    x = np.ones(4)
    assert plan.corrupt_array(x, step=0) is x
    assert plan.total_fired == 0


def test_corrupt_array_is_seeded():
    mk = lambda: FaultPlan([Fault("corrupt", scope="sweep.caravan")], seed=5)
    x = np.linspace(0, 1, 32, dtype=np.float32)
    a = mk().corrupt_array(x.copy(), step=3)
    b = mk().corrupt_array(x.copy(), step=3)
    assert np.array_equal(a, b)
    assert not np.array_equal(a, x)


# ---------------------------------------------------------------------------
# Resumable sweep: exactness
# ---------------------------------------------------------------------------


def test_sweep_matches_oracle(corpus, tmp_path):
    ref = apss_reference(corpus, T, K)
    got = ResumableSweep(
        corpus, threshold=T, k=K, block_rows=BN, directory=str(tmp_path)
    ).run()
    assert _matches_equal(got, ref)


def test_sweep_mesh_bit_identical_to_single_device(corpus, tmp_path, mesh8):
    """The mesh only changes placement — same bits as the 1-device run."""
    solo = ResumableSweep(
        corpus, threshold=T, k=K, block_rows=BN, directory=str(tmp_path / "s")
    ).run()
    dist = ResumableSweep(
        corpus, threshold=T, k=K, block_rows=BN,
        directory=str(tmp_path / "d"), mesh=mesh8,
    ).run()
    assert _matches_equal(solo, dist)


def test_sweep_meta_mismatch_refuses_resume(corpus, tmp_path):
    ResumableSweep(
        corpus, threshold=T, k=K, block_rows=BN, directory=str(tmp_path)
    ).run()
    with pytest.raises(ValueError, match="meta mismatch"):
        ResumableSweep(
            corpus, threshold=0.5, k=K, block_rows=BN,
            directory=str(tmp_path),
        )


# ---------------------------------------------------------------------------
# Fault type 1: kill mid-sweep → resume (same mesh, reshaped mesh)
# ---------------------------------------------------------------------------


def test_kill_then_resume_same_mesh(corpus, tmp_path):
    ref = ResumableSweep(
        corpus, threshold=T, k=K, block_rows=BN, directory=str(tmp_path / "r")
    ).run()
    plan = FaultPlan([Fault("kill", step=2)])
    d = str(tmp_path / "k")
    with pytest.raises(SweepKilled):
        ResumableSweep(
            corpus, threshold=T, k=K, block_rows=BN, directory=d,
            fault_plan=plan,
        ).run()
    assert plan.fired["kill:sweep"] == 1
    with telemetry.CommLog() as log:
        got = ResumableSweep(
            corpus, threshold=T, k=K, block_rows=BN, directory=d
        ).run()
    assert _matches_equal(got, ref)
    # the fault suite's headline counter: steps recovered from disk
    assert log.counters["sweep.resumed_steps"] == 2
    assert log.counters["sweep.checkpoints"] > 0


def test_kill_then_resume_reshaped_mesh(corpus, tmp_path, mesh8):
    """Kill on 8 devices, resume on 4 — Matches identical to uninterrupted."""
    ref = ResumableSweep(
        corpus, threshold=T, k=K, block_rows=BN, directory=str(tmp_path / "r")
    ).run()
    d = str(tmp_path / "k")
    killer = ResumableSweep(
        corpus, threshold=T, k=K, block_rows=BN, directory=d, mesh=mesh8,
        fault_plan=FaultPlan([Fault("kill", step=3)]),
    )
    with pytest.raises(SweepKilled):
        killer.run()
    smaller = Mesh(np.array(jax.devices()[:4]), ("data",))
    resumed = killer.resume_on(smaller)
    got = resumed.run()
    assert resumed.resumed_from == 3
    assert _matches_equal(got, ref)


# ---------------------------------------------------------------------------
# Fault type 2: corruption — traveling packet + checkpoint leaf
# ---------------------------------------------------------------------------


def test_corrupted_caravan_changes_result(corpus, tmp_path):
    """The harness really damages in-flight partials: a corrupted caravan
    survives merging and the final Matches differ from the oracle (at-rest
    checksums cannot see in-flight damage — that is exactness-check
    territory, pinned here)."""
    ref = apss_reference(corpus, T, K)
    plan = FaultPlan([Fault("corrupt", scope="sweep.caravan", step=1)])
    got = ResumableSweep(
        corpus, threshold=T, k=K, block_rows=BN,
        directory=str(tmp_path), fault_plan=plan,
    ).run()
    assert plan.fired["corrupt:sweep.caravan"] == 1
    assert not _matches_equal(got, ref)


def test_checksum_detects_bitflip(tmp_path):
    state = {"w": np.arange(64, dtype=np.float32).reshape(8, 8)}
    final = save_checkpoint(state, str(tmp_path), 1)
    leaf = os.path.join(final, "w.npy")
    FaultPlan(seed=3).corrupt_file(leaf)
    with pytest.raises(CheckpointCorruptionError, match="checksum mismatch"):
        load_checkpoint(str(tmp_path), 1)


def test_restore_falls_back_past_corrupt_step(corpus, tmp_path):
    """Newest checkpoint corrupt → restore(fallback=True) walks back one
    kept step and the resumed sweep still matches the oracle exactly."""
    ref = apss_reference(corpus, T, K)
    d = str(tmp_path)
    killer = ResumableSweep(
        corpus, threshold=T, k=K, block_rows=BN, directory=d,
        fault_plan=FaultPlan([Fault("kill", step=3)]),
    )
    with pytest.raises(SweepKilled):
        killer.run()
    latest = killer.manager.latest_step()
    assert latest == 3
    step_dir = os.path.join(d, f"step_{latest:010d}")
    leaf = [f for f in os.listdir(step_dir) if f.endswith(".npy")][0]
    FaultPlan(seed=1).corrupt_file(os.path.join(step_dir, leaf))
    with pytest.raises(CheckpointCorruptionError):
        killer.manager.restore(step=latest)
    with pytest.warns(UserWarning, match="falling back"):
        resumed = ResumableSweep(
            corpus, threshold=T, k=K, block_rows=BN, directory=d
        )
        got = resumed.run()
    assert resumed.resumed_from == 2  # one checkpoint window lost, not the job
    assert _matches_equal(got, ref)


def test_restore_raises_when_all_corrupt(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save({"x": np.ones(4, np.float32)}, 1)
    for s in mgr.all_steps():
        step_dir = os.path.join(str(tmp_path), f"step_{s:010d}")
        leaf = [f for f in os.listdir(step_dir) if f.endswith(".npy")][0]
        FaultPlan(seed=s).corrupt_file(os.path.join(step_dir, leaf))
    with pytest.warns(UserWarning):
        with pytest.raises(CheckpointCorruptionError, match="every kept"):
            mgr.restore(fallback=True)


# ---------------------------------------------------------------------------
# Satellite: async checkpoint writes must never fail silently
# ---------------------------------------------------------------------------


def test_async_write_error_surfaces_on_wait(tmp_path, monkeypatch):
    from repro.checkpoint import checkpointer

    mgr = CheckpointManager(str(tmp_path), keep=2)

    def boom(state, directory, step):
        raise OSError("disk full")

    monkeypatch.setattr(checkpointer, "save_checkpoint", boom)
    mgr.save({"x": np.ones(4, np.float32)}, 1, blocking=False)
    with pytest.raises(OSError, match="disk full"):
        mgr.wait()
    mgr.wait()  # error is raised once, then cleared


def test_async_write_error_surfaces_on_next_save(tmp_path, monkeypatch):
    from repro.checkpoint import checkpointer

    mgr = CheckpointManager(str(tmp_path), keep=2)
    real = checkpointer.save_checkpoint

    def boom(state, directory, step):
        raise OSError("quota exceeded")

    monkeypatch.setattr(checkpointer, "save_checkpoint", boom)
    mgr.save({"x": np.ones(4, np.float32)}, 1, blocking=False)
    monkeypatch.setattr(checkpointer, "save_checkpoint", real)
    with pytest.raises(OSError, match="quota exceeded"):
        mgr.save({"x": np.ones(4, np.float32)}, 2)


# ---------------------------------------------------------------------------
# Fault type 3 (sweep side): straggler eviction feeds a smaller mesh
# ---------------------------------------------------------------------------


def test_evict_report_shrinks_mesh_and_resume_is_exact(corpus, tmp_path, mesh8):
    ref = apss_reference(corpus, T, K)
    d = str(tmp_path)
    killer = ResumableSweep(
        corpus, threshold=T, k=K, block_rows=BN, directory=d, mesh=mesh8,
        fault_plan=FaultPlan([Fault("kill", step=2)]),
    )
    with pytest.raises(SweepKilled):
        killer.run()
    # Synthetic straggler ledger: rank 5 is 10x the median step time.
    timer = StepTimer(tolerance=1.5)
    for rank in range(8):
        for _ in range(4):
            timer.record(rank, 1.0 if rank == 5 else 0.1)
    report = timer.report()
    assert report.evict == [5]
    smaller = mesh_after_eviction(mesh8, report)
    assert smaller.devices.size == 7
    got = killer.resume_on(smaller).run()
    assert _matches_equal(got, ref)


def test_mesh_after_eviction_noop_without_stragglers(mesh8):
    timer = StepTimer()
    for rank in range(8):
        timer.record(rank, 0.1)
    assert mesh_after_eviction(mesh8, timer.report()) is mesh8


def test_sweep_records_step_times(corpus, tmp_path):
    timer = StepTimer()
    sweep = ResumableSweep(
        corpus, threshold=T, k=K, block_rows=BN, directory=str(tmp_path),
        timer=timer,
    )
    sweep.run()
    assert len(timer.history[0]) == sweep.B  # one wall time per ring step


# ---------------------------------------------------------------------------
# Fault type 4 (ISSUE 7): mutation durability — kill mid-append, WAL rot
# ---------------------------------------------------------------------------


def _mutable_graph_equal(a, b):
    ga, gb = a.graph(), b.graph()
    return np.array_equal(ga[0], gb[0]) and _matches_equal(ga[1], gb[1])


@pytest.mark.parametrize("seam", ["mutable.append", "mutable.commit"])
def test_mutable_kill_mid_append_resumes_bit_identical(tmp_path, seam):
    """Kill between WAL write and apply ('mutable.append') or between
    apply and snapshot ('mutable.commit'): reopening replays the logged
    op and lands bit-identical to the uninterrupted run."""
    from repro.serving import MutableAPSSIndex

    rng = np.random.default_rng(20)
    D = rng.normal(size=(64, 16)).astype(np.float32)
    ref = MutableAPSSIndex(D, threshold=T, k=K)
    plan = FaultPlan([Fault("kill", scope=seam, step=2)])
    d = str(tmp_path / "kill")
    mi = MutableAPSSIndex(
        D[:48], threshold=T, k=K, directory=d, fault_plan=plan
    )
    with pytest.raises(SweepKilled):
        mi.append(D[48:])
    assert plan.fired[f"kill:{seam}"] == 1
    with telemetry.CommLog() as log:
        resumed = MutableAPSSIndex(corpus=None, threshold=T, k=K, directory=d)
    assert log.counters["mutable.replayed_ops"] == 1
    assert _mutable_graph_equal(resumed, ref)
    # and the resumed index keeps working: next op takes the next WAL seq
    resumed.delete([0])
    ref.delete([0])
    assert _mutable_graph_equal(resumed, ref)


def test_mutable_corrupt_log_walks_back_one_op(tmp_path):
    """Bit-rot in the newest WAL entry: reopening detects the digest
    mismatch, warns, walks back exactly that op, and stays serviceable."""
    from repro.serving import MutableAPSSIndex

    rng = np.random.default_rng(21)
    D = rng.normal(size=(64, 16)).astype(np.float32)
    d = str(tmp_path / "rot")
    # kill before the snapshot so op 2 exists ONLY in the log...
    plan = FaultPlan([Fault("kill", scope="mutable.commit", step=2)])
    mi = MutableAPSSIndex(
        D[:48], threshold=T, k=K, directory=d, fault_plan=plan
    )
    with pytest.raises(SweepKilled):
        mi.append(D[48:])
    # ...then rot one byte of its payload on disk
    step_dir = os.path.join(d, "log", "step_%010d" % 2)
    leaf = [f for f in os.listdir(step_dir) if f.endswith(".npy")][0]
    FaultPlan(seed=1).corrupt_file(os.path.join(step_dir, leaf))
    with telemetry.CommLog() as log:
        with pytest.warns(UserWarning, match="walking back"):
            walked = MutableAPSSIndex(
                corpus=None, threshold=T, k=K, directory=d
            )
    assert log.counters["mutable.log_walkback"] == 1
    assert "mutable.replayed_ops" not in log.counters
    # state equals the pre-op oracle — the corrupt append never happened
    assert _mutable_graph_equal(
        walked, MutableAPSSIndex(D[:48], threshold=T, k=K)
    )
    # the walked-back seq is reusable: redoing the append works and
    # matches the uninterrupted end state
    walked.append(D[48:])
    assert _mutable_graph_equal(
        walked, MutableAPSSIndex(D, threshold=T, k=K)
    )


def test_mutable_snapshot_fallback_counts(tmp_path):
    """Corrupting the newest SNAPSHOT (not the log) falls back one kept
    snapshot and replays the op gap from the WAL."""
    from repro.serving import MutableAPSSIndex

    rng = np.random.default_rng(22)
    D = rng.normal(size=(64, 16)).astype(np.float32)
    d = str(tmp_path / "snaprot")
    mi = MutableAPSSIndex(D[:48], threshold=T, k=K, directory=d)
    mi.append(D[48:])
    state_dir = os.path.join(d, "state")
    step_dir = os.path.join(state_dir, "step_%010d" % 2)
    leaf = [f for f in os.listdir(step_dir) if f.endswith(".npy")][0]
    FaultPlan(seed=2).corrupt_file(os.path.join(step_dir, leaf))
    with telemetry.CommLog() as log:
        with pytest.warns(UserWarning, match="falling back"):
            resumed = MutableAPSSIndex(
                corpus=None, threshold=T, k=K, directory=d
            )
    assert log.counters["mutable.restore_fallback"] == 1
    assert log.counters["mutable.replayed_ops"] == 1  # op 2 redone from WAL
    assert _mutable_graph_equal(resumed, mi)
