"""CI infrastructure as code (ISSUE 5 satellites): the BENCH schema gate
(``benchmarks/check_schema.py``, formerly an inline workflow heredoc) and
the tier-1 shard partition (``tests/conftest.py``) are real, unit-tested
modules — a schema or sharding bug fails tier-1 locally, not just a CI run
three pushes later.
"""

import json
import pathlib

import pytest

import conftest
from benchmarks import check_schema
from benchmarks.check_schema import SchemaError, check


# -- a minimal valid BENCH artifact ------------------------------------------


def _entry(config="blocked[sparse,b=128]", us=10.0):
    return {
        "config": config, "predicted_s": 0.01, "measured_us": us,
        "wire_bytes": 0, "flops": 1.0, "compute_s": 0.01, "comm_s": 0.0,
    }


def _corpus_rec(entries, within=True):
    return {
        "summary": {"density": 0.005},
        "chosen": entries[0]["config"],
        "chosen_predicted": entries[0]["config"],
        "entries": entries,
        "best_measured": entries[0]["config"],
        "chosen_over_best": 1.0 if within else 3.0,
        "chosen_within_2x": within,
    }


def _valid_doc():
    return {
        "density": 0.01, "live_tile_fraction": 0.5, "variants": {},
        "sparse_sweep": {"entries": [{
            "density": 0.001, "live_tile_fraction_sparse": 0.1,
            "live_tile_fraction_dense": 0.2, "total_matches": 5,
            "variants": {"dense-fused": 1.0, "sparse-xla": 2.0},
        }]},
        "serving": {
            "index_build_us": 1.0, "index_bytes": 10, "rebuild": {},
            "amortized_speedup_batch64": 3.0,
            "batches": {
                b: {"us_per_call": 1, "us_per_query": 1, "qps": 1,
                    "total_matches": 1,
                    "latency_us": {"p50": 900.0, "p95": 1200.0,
                                   "p99": 1500.0, "samples": 20}}
                for b in ("1", "8", "64")
            },
            "servers": {
                regime: {
                    "step": {"qps": 5000.0, "p50_us": 3000.0,
                             "p95_us": 9000.0, "p99_us": 20000.0,
                             "requests": 192},
                    "continuous": {"qps": 6000.0, "p50_us": 2000.0,
                                   "p95_us": 6000.0, "p99_us": 9000.0,
                                   "requests": 192},
                }
                for regime in ("8", "64")
            },
            "early_exit": {
                "n": 2048, "m": 1024, "threshold": 0.01, "k": 8,
                "skipped_tiles": 28, "bit_exact": True,
            },
            "qps_batch64": 6000.0,
            "p99_us": 9000.0,
        },
        "planner": {
            "profile": {"matmul_gflops": 1, "gather_gflops": 1,
                        "score_cost_ns": 1, "device_kind": "cpu"},
            "corpora": {
                "sparse_lowdens": _corpus_rec([_entry()]),
                "dense": _corpus_rec([_entry("blocked[dense,b=128]")]),
            },
            "mesh2d": {
                "mesh": {"data": 4, "model": 2},
                "corpora": {"sparse_lowdens": _corpus_rec([
                    _entry("2d/compressed[sparse,b=128]"),
                    _entry("2d/allreduce[dense,b=128]"),
                ])},
            },
        },
        "mutable": {
            "n": 1024, "m": 128, "threshold": 0.2, "k": 16, "block": 64,
            "deltas": [
                {"delta": 16, "delta_fraction": 1 / 64, "append_s": 0.01,
                 "rebuild_s": 0.08, "speedup": 8.0},
                {"delta": 256, "delta_fraction": 1 / 4, "append_s": 0.03,
                 "rebuild_s": 0.06, "speedup": 2.0},
            ],
        },
        "provenance": {
            "git_sha": "deadbeef" * 5, "timestamp": "2026-08-09T00:00:00Z",
            "device_kind": "cpu", "device_count": 8, "jax_version": "0.4.37",
        },
    }


def _audit_lane(gated_ok=True):
    def _e(family, ratio=1.0):
        return {
            "family": family, "predicted_flops": 1e6,
            "hlo_flops": ratio * 1e6, "flop_ratio": ratio,
            "predicted_link_bytes": 0.0, "hlo_link_bytes": 0.0,
            "predicted_hbm_bytes": 1e4, "hlo_hbm_bytes": 2e4,
            "compile": {"t_compile_s": 0.1, "total_bytes": 4096},
        }

    return {
        "gated_ok": gated_ok,
        "gated_families": ["blocked[dense]", "horizontal/ring[dense]"],
        "entries": [_e("blocked[dense]"), _e("horizontal/ring[dense]")],
    }


def test_valid_doc_passes():
    check(_valid_doc())


@pytest.mark.parametrize("path", [
    ("sparse_sweep",),
    ("serving", "batches", "64"),
    ("serving", "batches", "8", "latency_us"),
    ("serving", "batches", "1", "latency_us", "p99"),
    ("planner", "profile", "gather_gflops"),
    ("planner", "mesh2d"),
    ("planner", "corpora", "sparse_lowdens", "entries", 0, "measured_us"),
    ("mutable",),
    ("mutable", "deltas", 0, "speedup"),
    ("provenance",),
    ("provenance", "git_sha"),
    ("provenance", "jax_version"),
])
def test_missing_key_fails_with_path(path):
    doc = _valid_doc()
    node = doc
    for k in path[:-1]:
        node = node[k]
    del node[path[-1]]
    with pytest.raises(SchemaError):
        check(doc)


def test_serving_latency_histogram_lane():
    """The serving lane must carry a per-call latency distribution with
    ordered quantiles — a mean alone can't regress on tail latency."""
    doc = _valid_doc()
    doc["serving"]["batches"]["64"]["latency_us"]["p50"] = 2000.0  # > p99
    with pytest.raises(SchemaError, match=r"p50 .* exceeds p99"):
        check(doc)
    doc = _valid_doc()
    doc["serving"]["batches"]["8"]["latency_us"]["p50"] = 0.0
    with pytest.raises(SchemaError, match="p50 must be positive"):
        check(doc)


def test_serving_server_curve_lane():
    """The QPS/p99 curve (ISSUE 10): both regimes × both servers present,
    percentiles ordered, and continuous ≤ step on p99 at the largest
    regime — the tentpole's headline claim, gated."""
    doc = _valid_doc()
    del doc["serving"]["servers"]["64"]["continuous"]
    with pytest.raises(SchemaError, match="continuous"):
        check(doc)
    doc = _valid_doc()
    srv = doc["serving"]["servers"]["8"]["step"]
    srv["p95_us"] = srv["p99_us"] + 1.0  # unordered
    with pytest.raises(SchemaError, match="unordered"):
        check(doc)
    doc = _valid_doc()
    doc["serving"]["servers"]["64"]["continuous"]["p99_us"] = 1e9
    with pytest.raises(SchemaError, match="exceeds"):
        check(doc)
    # at the SMALL regime step may legitimately win — not gated
    doc = _valid_doc()
    doc["serving"]["servers"]["8"]["continuous"]["p99_us"] = 1e9
    check(doc)


def test_serving_early_exit_lane():
    """Early exit must skip live tiles AND stay bit-exact."""
    doc = _valid_doc()
    doc["serving"]["early_exit"]["skipped_tiles"] = 0
    with pytest.raises(SchemaError, match="skipped no live tiles"):
        check(doc)
    doc = _valid_doc()
    doc["serving"]["early_exit"]["bit_exact"] = False
    with pytest.raises(SchemaError, match="diverged"):
        check(doc)
    doc = _valid_doc()
    del doc["serving"]["early_exit"]["skipped_tiles"]
    with pytest.raises(SchemaError, match="early_exit"):
        check(doc)


def test_within_2x_gate_applies_to_single_device_lanes_only():
    """The corpora lanes hard-gate chosen_within_2x; the mesh2d lane records
    it but doesn't gate (8 virtual devices share one socket — collective
    timings there are pathological by construction)."""
    doc = _valid_doc()
    doc["planner"]["mesh2d"]["corpora"]["sparse_lowdens"] = _corpus_rec(
        [_entry("2d/compressed[sparse,b=128]"),
         _entry("2d/allreduce[dense,b=128]")],
        within=False,
    )
    check(doc)  # mesh2d miss: recorded, not fatal
    doc = _valid_doc()
    doc["planner"]["corpora"]["dense"] = _corpus_rec(
        [_entry("blocked[dense,b=128]")], within=False
    )
    with pytest.raises(SchemaError, match=r"chosen plan"):
        check(doc)


def test_mesh2d_requires_both_2d_representations():
    """The mesh lane must measure the 2-D family in BOTH representations —
    a missing sparse entry means the planner gate regressed."""
    for drop in ("sparse", "dense"):
        doc = _valid_doc()
        rec = doc["planner"]["mesh2d"]["corpora"]["sparse_lowdens"]
        rec["entries"] = [e for e in rec["entries"] if drop not in e["config"]]
        with pytest.raises(SchemaError, match=f"2d-{drop}"):
            check(doc)


def test_sparse_regime_gate():
    doc = _valid_doc()
    doc["planner"]["corpora"]["sparse_lowdens"]["summary"]["density"] = 0.2
    with pytest.raises(SchemaError, match="sparse regime"):
        check(doc)


def test_mutable_lane_gates_small_delta_speedup():
    """The live-corpus acceptance bar (ISSUE 7): some delta <= n/16 must
    show append+delta-join >= 5x faster than a full rebuild."""
    doc = _valid_doc()
    doc["mutable"]["deltas"][0]["speedup"] = 3.0
    with pytest.raises(SchemaError, match=">= 5x"):
        check(doc)
    # a big-delta lane alone can't satisfy the gate either
    doc = _valid_doc()
    doc["mutable"]["deltas"] = [doc["mutable"]["deltas"][1]]
    with pytest.raises(SchemaError, match="no delta <= n/16"):
        check(doc)
    # the n/4 lane is informational: its speedup is not gated
    doc = _valid_doc()
    doc["mutable"]["deltas"][1]["speedup"] = 0.9
    check(doc)


def test_audit_lane_is_optional_but_checked_when_present():
    doc = _valid_doc()
    check(doc)  # no audit lane: fine
    doc["audit"] = _audit_lane()
    check(doc)
    doc["audit"] = _audit_lane(gated_ok=False)
    with pytest.raises(SchemaError, match="FLOP ratio gate"):
        check(doc)
    doc["audit"] = _audit_lane()
    doc["audit"]["entries"] = doc["audit"]["entries"][:1]  # ring missing
    with pytest.raises(SchemaError, match="gated families missing"):
        check(doc)
    doc["audit"] = _audit_lane()
    del doc["audit"]["entries"][0]["hlo_flops"]
    with pytest.raises(SchemaError, match=r"audit\.entries\[0\]"):
        check(doc)


def test_history_record_schema():
    from benchmarks.check_schema import check_history_record

    rec = {
        "git_sha": "abc123", "timestamp": "2026-08-09T00:00:00Z",
        "device_kind": "cpu", "jax_version": "0.4.37",
        "metrics": {"variants.fused.us_per_call": 10.0},
    }
    check_history_record(rec)
    with pytest.raises(SchemaError, match="empty metric"):
        check_history_record({**rec, "metrics": {}})
    with pytest.raises(SchemaError, match="non-negative"):
        check_history_record({**rec, "metrics": {"x": -1.0}})
    with pytest.raises(SchemaError, match="missing keys"):
        check_history_record({k: v for k, v in rec.items() if k != "git_sha"})


# -- perf-regression sentinel -------------------------------------------------


def _bench_doc(scale=1.0, sha="sha0", qps=5000.0):
    """A minimal artifact with the lanes the sentinel extracts."""
    return {
        "variants": {
            "fused": {"us_per_call": 100.0 * scale},
            "fused-compacted": {"us_per_call": 40.0 * scale},
        },
        "sparse_sweep": {"entries": [{
            "density_requested": 0.01,
            "variants": {"sparse-xla": {"us_per_call": 50.0 * scale}},
        }]},
        "serving": {
            "index_build_us": 500.0 * scale,
            "batches": {"8": {"us_per_query": 20.0 * scale}},
            "qps_batch64": qps,
            "p99_us": 9000.0 * scale,
        },
        "mutable": {"deltas": [{"delta": 16, "append_s": 0.01 * scale}]},
        "provenance": {
            "git_sha": sha, "timestamp": "t", "device_kind": "cpu",
            "jax_version": "0.4.37",
        },
    }


def test_sentinel_extracts_stable_metrics():
    from benchmarks.check_schema import check_history_record
    from benchmarks.sentinel import extract_metrics, record

    m = extract_metrics(_bench_doc())
    assert m["variants.fused.us_per_call"] == 100.0
    assert m["sparse_sweep.d=0.01.sparse-xla.us_per_call"] == 50.0
    assert m["serving.batch=8.us_per_query"] == 20.0
    assert m["serving.qps_batch64"] == 5000.0
    assert m["serving.p99_us"] == 9000.0
    assert m["mutable.delta=16.append_s"] == 0.01
    # the record the sentinel appends satisfies the history schema
    import tempfile

    with tempfile.TemporaryDirectory() as d:
        rec = record(_bench_doc(), f"{d}/h.jsonl")
    check_history_record(rec)


def test_sentinel_passes_without_baseline_and_flags_2x_slowdown(tmp_path):
    """The acceptance scenario: seed a history, re-check unchanged (PASS),
    then check a synthetic 2x slowdown (FAIL naming the metrics)."""
    from benchmarks import sentinel

    hist = str(tmp_path / "BENCH_history.jsonl")
    # no history at all: check passes (nothing to regress from)
    assert sentinel.check(_bench_doc(), hist)["ok"]
    for i in range(3):  # seed three baseline runs
        sentinel.record(_bench_doc(sha=f"base{i}"), hist)
    ok = sentinel.check(_bench_doc(scale=1.1, sha="pr"), hist)
    assert ok["ok"] and ok["checked"] >= 5  # 10% drift: inside tolerance
    bad = sentinel.check(_bench_doc(scale=2.0, sha="pr"), hist)
    assert not bad["ok"]
    flagged = {r["metric"] for r in bad["regressions"]}
    assert "variants.fused.us_per_call" in flagged
    assert all(r["ratio"] == pytest.approx(2.0) for r in bad["regressions"])


def test_sentinel_qps_is_gated_higher_is_better(tmp_path):
    """``serving.qps_batch64`` inverts: a throughput DROP below
    baseline/tolerance is the regression; latency drift on the same run
    still gates the usual way."""
    from benchmarks import sentinel

    assert "serving.qps_batch64" in sentinel.HIGHER_IS_BETTER
    hist = str(tmp_path / "h.jsonl")
    for i in range(3):
        sentinel.record(_bench_doc(sha=f"base{i}"), hist)
    # QPS doubled: an improvement, not a regression
    assert sentinel.check(_bench_doc(sha="pr", qps=10000.0), hist)["ok"]
    # QPS halved: flagged, with the inverted ratio
    bad = sentinel.check(_bench_doc(sha="pr", qps=2500.0), hist)
    assert not bad["ok"]
    flagged = {r["metric"]: r for r in bad["regressions"]}
    assert set(flagged) == {"serving.qps_batch64"}
    assert flagged["serving.qps_batch64"]["ratio"] == pytest.approx(2.0)


def test_sentinel_rerecord_same_sha_replaces_not_duplicates(tmp_path):
    from benchmarks import sentinel

    hist = str(tmp_path / "h.jsonl")
    sentinel.record(_bench_doc(sha="a"), hist)
    sentinel.record(_bench_doc(scale=3.0, sha="a"), hist)  # supersedes
    records = sentinel.load_history(hist)
    assert len(records) == 1
    assert records[0]["metrics"]["variants.fused.us_per_call"] == 300.0


def test_sentinel_baseline_excludes_own_sha_and_other_devices(tmp_path):
    from benchmarks import sentinel

    hist = str(tmp_path / "h.jsonl")
    sentinel.record(_bench_doc(sha="mine"), hist)  # own prior run
    other = _bench_doc(scale=0.1, sha="gpu-run")
    other["provenance"]["device_kind"] = "gpu"
    sentinel.record(other, hist)
    # only baselines: own sha (excluded) + gpu (excluded) → no baseline
    res = sentinel.check(_bench_doc(scale=5.0, sha="mine"), hist)
    assert res["ok"] and res["baseline_records"] == 0


def test_sentinel_cli(tmp_path, capsys):
    from benchmarks import sentinel

    art = tmp_path / "bench.json"
    hist = str(tmp_path / "h.jsonl")
    art.write_text(json.dumps(_bench_doc(sha="base")))
    assert sentinel.main(["record", "--artifact", str(art),
                          "--history", hist]) == 0
    slow = tmp_path / "slow.json"
    slow.write_text(json.dumps(_bench_doc(scale=2.0, sha="pr")))
    assert sentinel.main(["check", "--artifact", str(slow),
                          "--history", hist]) == 1
    err = capsys.readouterr().err
    assert "REGRESSION" in err and "variants.fused.us_per_call" in err
    # unchanged re-run passes
    same = tmp_path / "same.json"
    same.write_text(json.dumps(_bench_doc(sha="pr2")))
    assert sentinel.main(["check", "--artifact", str(same),
                          "--history", hist]) == 0


def test_cli_roundtrip(tmp_path, capsys):
    good = tmp_path / "good.json"
    good.write_text(json.dumps(_valid_doc()))
    assert check_schema.main([str(good)]) == 0
    bad_doc = _valid_doc()
    del bad_doc["serving"]
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(bad_doc))
    assert check_schema.main([str(bad)]) == 1
    err = capsys.readouterr().err
    assert "$.serving" in err or "serving" in err


def test_repo_bench_artifact_is_valid():
    """The committed BENCH_apss.json must satisfy the same gate CI applies
    to the smoke artifact — schema changes ship with a regenerated
    artifact, never ahead of it."""
    path = pathlib.Path(__file__).resolve().parent.parent / "BENCH_apss.json"
    check(json.loads(path.read_text()))


def test_error_messages_are_path_qualified():
    doc = _valid_doc()
    del doc["planner"]["mesh2d"]["corpora"]["sparse_lowdens"]["entries"][1][
        "wire_bytes"
    ]
    with pytest.raises(SchemaError, match=r"mesh2d\.corpora\.sparse_lowdens"):
        check(doc)


# -- tier-1 sharding ----------------------------------------------------------


def test_shard_assignment_is_a_partition():
    """Every test file lands in exactly one shard, for any shard count."""
    files = [f"tests/test_{name}.py" for name in (
        "apss_core", "apss_distributed", "sparse", "sparse_2d", "planner",
        "telemetry", "serving", "kernels", "ci_infra",
    )]
    for num in (2, 3, 5):
        buckets = [[] for _ in range(num)]
        for f in files:
            buckets[conftest.shard_of(f, num)].append(f)
        assert sorted(sum(buckets, [])) == sorted(files)  # exhaustive
        assert all(
            conftest.shard_of(f, num) == conftest.shard_of(f, num)
            for f in files
        )  # deterministic


def test_shard_of_is_stable():
    """Pinned values: the assignment must never drift across Python or
    pytest versions (a silent re-partition would un-run half the suite
    until every matrix cell is green again)."""
    import zlib

    f = "tests/test_sparse_2d.py"
    assert conftest.shard_of(f, 2) == zlib.crc32(f.encode()) % 2


def test_modifyitems_respects_env(monkeypatch):
    class Item:
        def __init__(self, nodeid):
            self.nodeid = nodeid

    class Hook:
        def __init__(self):
            self.deselected = []

        def pytest_deselected(self, items):
            self.deselected.extend(items)

    class Config:
        def __init__(self):
            self.hook = Hook()

    all_items = [Item(f"tests/test_{i}.py::test_x") for i in range(10)]
    # no env → untouched
    monkeypatch.delenv("PYTEST_NUM_SHARDS", raising=False)
    items = list(all_items)
    conftest.pytest_collection_modifyitems(Config(), items)
    assert items == all_items
    # 2 shards → disjoint + exhaustive, deselected reported
    kept = []
    for shard in ("1", "2"):
        monkeypatch.setenv("PYTEST_NUM_SHARDS", "2")
        monkeypatch.setenv("PYTEST_SHARD", shard)
        items = list(all_items)
        cfg = Config()
        conftest.pytest_collection_modifyitems(cfg, items)
        assert len(items) + len(cfg.hook.deselected) == len(all_items)
        kept.extend(i.nodeid for i in items)
    assert sorted(kept) == sorted(i.nodeid for i in all_items)
    # out-of-range shard id fails loudly
    monkeypatch.setenv("PYTEST_SHARD", "3")
    with pytest.raises(pytest.UsageError):
        conftest.pytest_collection_modifyitems(Config(), list(all_items))


def test_ci_workflow_wires_the_gate():
    """The workflow must call the schema module (not a heredoc), set the
    virtual-device count job-wide, and fan the matrix out over python and
    jax versions with sharded tier-1."""
    wf = (
        pathlib.Path(__file__).resolve().parent.parent
        / ".github" / "workflows" / "ci.yml"
    ).read_text()
    assert "benchmarks.check_schema" in wf
    assert "benchmarks.bench_mutable" in wf  # the live-corpus lane feeds the gate
    assert "xla_force_host_platform_device_count=8" in wf
    assert "fail-fast: false" in wf
    assert "PYTEST_NUM_SHARDS" in wf
    assert '"3.10"' in wf and '"3.11"' in wf
    assert "upload-artifact" in wf
    assert "ruff check" in wf and "ruff format --check" in wf
    assert "python - <<" not in wf  # the heredoc is gone for good
    # observability artifacts: the bench/chaos lanes emit a Chrome trace +
    # metrics snapshot and upload them per matrix cell
    assert "--trace-out" in wf and "--metrics-out" in wf
    # compile audit + perf-regression sentinel (ISSUE 9): the bench smoke
    # carries --audit (gated by check_schema), and the sentinel checks then
    # records against a history persisted across runs via actions/cache
    assert "--audit" in wf
    assert "benchmarks.sentinel check" in wf
    assert "benchmarks.sentinel record" in wf
    assert "actions/cache" in wf
    assert "BENCH_history" in wf
    assert wf.index("sentinel check") < wf.index("sentinel record")
    # serving-load lane (ISSUE 10): the bench_serve smoke feeds the
    # QPS/p99 curve + early-exit gates; one live run per ref; manual runs
    assert "benchmarks.bench_serve" in wf
    assert "--smoke" in wf
    assert "concurrency:" in wf
    assert "cancel-in-progress: true" in wf
    assert "workflow_dispatch" in wf
    # format drift blocks: no advisory escape hatch left in the lint job
    assert "continue-on-error" not in wf.split("tier1:")[0]
