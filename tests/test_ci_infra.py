"""CI infrastructure as code (ISSUE 5 satellites): the BENCH schema gate
(``benchmarks/check_schema.py``, formerly an inline workflow heredoc) and
the tier-1 shard partition (``tests/conftest.py``) are real, unit-tested
modules — a schema or sharding bug fails tier-1 locally, not just a CI run
three pushes later.
"""

import json
import pathlib

import pytest

import conftest
from benchmarks import check_schema
from benchmarks.check_schema import SchemaError, check


# -- a minimal valid BENCH artifact ------------------------------------------


def _entry(config="blocked[sparse,b=128]", us=10.0):
    return {
        "config": config, "predicted_s": 0.01, "measured_us": us,
        "wire_bytes": 0, "flops": 1.0, "compute_s": 0.01, "comm_s": 0.0,
    }


def _corpus_rec(entries, within=True):
    return {
        "summary": {"density": 0.005},
        "chosen": entries[0]["config"],
        "chosen_predicted": entries[0]["config"],
        "entries": entries,
        "best_measured": entries[0]["config"],
        "chosen_over_best": 1.0 if within else 3.0,
        "chosen_within_2x": within,
    }


def _valid_doc():
    return {
        "density": 0.01, "live_tile_fraction": 0.5, "variants": {},
        "sparse_sweep": {"entries": [{
            "density": 0.001, "live_tile_fraction_sparse": 0.1,
            "live_tile_fraction_dense": 0.2, "total_matches": 5,
            "variants": {"dense-fused": 1.0, "sparse-xla": 2.0},
        }]},
        "serving": {
            "index_build_us": 1.0, "index_bytes": 10, "rebuild": {},
            "amortized_speedup_batch64": 3.0,
            "batches": {
                b: {"us_per_call": 1, "us_per_query": 1, "qps": 1,
                    "total_matches": 1,
                    "latency_us": {"p50": 900.0, "p95": 1200.0,
                                   "p99": 1500.0, "samples": 20}}
                for b in ("1", "8", "64")
            },
        },
        "planner": {
            "profile": {"matmul_gflops": 1, "gather_gflops": 1,
                        "score_cost_ns": 1, "device_kind": "cpu"},
            "corpora": {
                "sparse_lowdens": _corpus_rec([_entry()]),
                "dense": _corpus_rec([_entry("blocked[dense,b=128]")]),
            },
            "mesh2d": {
                "mesh": {"data": 4, "model": 2},
                "corpora": {"sparse_lowdens": _corpus_rec([
                    _entry("2d/compressed[sparse,b=128]"),
                    _entry("2d/allreduce[dense,b=128]"),
                ])},
            },
        },
        "mutable": {
            "n": 1024, "m": 128, "threshold": 0.2, "k": 16, "block": 64,
            "deltas": [
                {"delta": 16, "delta_fraction": 1 / 64, "append_s": 0.01,
                 "rebuild_s": 0.08, "speedup": 8.0},
                {"delta": 256, "delta_fraction": 1 / 4, "append_s": 0.03,
                 "rebuild_s": 0.06, "speedup": 2.0},
            ],
        },
    }


def test_valid_doc_passes():
    check(_valid_doc())


@pytest.mark.parametrize("path", [
    ("sparse_sweep",),
    ("serving", "batches", "64"),
    ("serving", "batches", "8", "latency_us"),
    ("serving", "batches", "1", "latency_us", "p99"),
    ("planner", "profile", "gather_gflops"),
    ("planner", "mesh2d"),
    ("planner", "corpora", "sparse_lowdens", "entries", 0, "measured_us"),
    ("mutable",),
    ("mutable", "deltas", 0, "speedup"),
])
def test_missing_key_fails_with_path(path):
    doc = _valid_doc()
    node = doc
    for k in path[:-1]:
        node = node[k]
    del node[path[-1]]
    with pytest.raises(SchemaError):
        check(doc)


def test_serving_latency_histogram_lane():
    """The serving lane must carry a per-call latency distribution with
    ordered quantiles — a mean alone can't regress on tail latency."""
    doc = _valid_doc()
    doc["serving"]["batches"]["64"]["latency_us"]["p50"] = 2000.0  # > p99
    with pytest.raises(SchemaError, match=r"p50 .* exceeds p99"):
        check(doc)
    doc = _valid_doc()
    doc["serving"]["batches"]["8"]["latency_us"]["p50"] = 0.0
    with pytest.raises(SchemaError, match="p50 must be positive"):
        check(doc)


def test_within_2x_gate_applies_to_single_device_lanes_only():
    """The corpora lanes hard-gate chosen_within_2x; the mesh2d lane records
    it but doesn't gate (8 virtual devices share one socket — collective
    timings there are pathological by construction)."""
    doc = _valid_doc()
    doc["planner"]["mesh2d"]["corpora"]["sparse_lowdens"] = _corpus_rec(
        [_entry("2d/compressed[sparse,b=128]"),
         _entry("2d/allreduce[dense,b=128]")],
        within=False,
    )
    check(doc)  # mesh2d miss: recorded, not fatal
    doc = _valid_doc()
    doc["planner"]["corpora"]["dense"] = _corpus_rec(
        [_entry("blocked[dense,b=128]")], within=False
    )
    with pytest.raises(SchemaError, match=r"chosen plan"):
        check(doc)


def test_mesh2d_requires_both_2d_representations():
    """The mesh lane must measure the 2-D family in BOTH representations —
    a missing sparse entry means the planner gate regressed."""
    for drop in ("sparse", "dense"):
        doc = _valid_doc()
        rec = doc["planner"]["mesh2d"]["corpora"]["sparse_lowdens"]
        rec["entries"] = [e for e in rec["entries"] if drop not in e["config"]]
        with pytest.raises(SchemaError, match=f"2d-{drop}"):
            check(doc)


def test_sparse_regime_gate():
    doc = _valid_doc()
    doc["planner"]["corpora"]["sparse_lowdens"]["summary"]["density"] = 0.2
    with pytest.raises(SchemaError, match="sparse regime"):
        check(doc)


def test_mutable_lane_gates_small_delta_speedup():
    """The live-corpus acceptance bar (ISSUE 7): some delta <= n/16 must
    show append+delta-join >= 5x faster than a full rebuild."""
    doc = _valid_doc()
    doc["mutable"]["deltas"][0]["speedup"] = 3.0
    with pytest.raises(SchemaError, match=">= 5x"):
        check(doc)
    # a big-delta lane alone can't satisfy the gate either
    doc = _valid_doc()
    doc["mutable"]["deltas"] = [doc["mutable"]["deltas"][1]]
    with pytest.raises(SchemaError, match="no delta <= n/16"):
        check(doc)
    # the n/4 lane is informational: its speedup is not gated
    doc = _valid_doc()
    doc["mutable"]["deltas"][1]["speedup"] = 0.9
    check(doc)


def test_cli_roundtrip(tmp_path, capsys):
    good = tmp_path / "good.json"
    good.write_text(json.dumps(_valid_doc()))
    assert check_schema.main([str(good)]) == 0
    bad_doc = _valid_doc()
    del bad_doc["serving"]
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(bad_doc))
    assert check_schema.main([str(bad)]) == 1
    err = capsys.readouterr().err
    assert "$.serving" in err or "serving" in err


def test_repo_bench_artifact_is_valid():
    """The committed BENCH_apss.json must satisfy the same gate CI applies
    to the smoke artifact — schema changes ship with a regenerated
    artifact, never ahead of it."""
    path = pathlib.Path(__file__).resolve().parent.parent / "BENCH_apss.json"
    check(json.loads(path.read_text()))


def test_error_messages_are_path_qualified():
    doc = _valid_doc()
    del doc["planner"]["mesh2d"]["corpora"]["sparse_lowdens"]["entries"][1][
        "wire_bytes"
    ]
    with pytest.raises(SchemaError, match=r"mesh2d\.corpora\.sparse_lowdens"):
        check(doc)


# -- tier-1 sharding ----------------------------------------------------------


def test_shard_assignment_is_a_partition():
    """Every test file lands in exactly one shard, for any shard count."""
    files = [f"tests/test_{name}.py" for name in (
        "apss_core", "apss_distributed", "sparse", "sparse_2d", "planner",
        "telemetry", "serving", "kernels", "ci_infra",
    )]
    for num in (2, 3, 5):
        buckets = [[] for _ in range(num)]
        for f in files:
            buckets[conftest.shard_of(f, num)].append(f)
        assert sorted(sum(buckets, [])) == sorted(files)  # exhaustive
        assert all(
            conftest.shard_of(f, num) == conftest.shard_of(f, num)
            for f in files
        )  # deterministic


def test_shard_of_is_stable():
    """Pinned values: the assignment must never drift across Python or
    pytest versions (a silent re-partition would un-run half the suite
    until every matrix cell is green again)."""
    import zlib

    f = "tests/test_sparse_2d.py"
    assert conftest.shard_of(f, 2) == zlib.crc32(f.encode()) % 2


def test_modifyitems_respects_env(monkeypatch):
    class Item:
        def __init__(self, nodeid):
            self.nodeid = nodeid

    class Hook:
        def __init__(self):
            self.deselected = []

        def pytest_deselected(self, items):
            self.deselected.extend(items)

    class Config:
        def __init__(self):
            self.hook = Hook()

    all_items = [Item(f"tests/test_{i}.py::test_x") for i in range(10)]
    # no env → untouched
    monkeypatch.delenv("PYTEST_NUM_SHARDS", raising=False)
    items = list(all_items)
    conftest.pytest_collection_modifyitems(Config(), items)
    assert items == all_items
    # 2 shards → disjoint + exhaustive, deselected reported
    kept = []
    for shard in ("1", "2"):
        monkeypatch.setenv("PYTEST_NUM_SHARDS", "2")
        monkeypatch.setenv("PYTEST_SHARD", shard)
        items = list(all_items)
        cfg = Config()
        conftest.pytest_collection_modifyitems(cfg, items)
        assert len(items) + len(cfg.hook.deselected) == len(all_items)
        kept.extend(i.nodeid for i in items)
    assert sorted(kept) == sorted(i.nodeid for i in all_items)
    # out-of-range shard id fails loudly
    monkeypatch.setenv("PYTEST_SHARD", "3")
    with pytest.raises(pytest.UsageError):
        conftest.pytest_collection_modifyitems(Config(), list(all_items))


def test_ci_workflow_wires_the_gate():
    """The workflow must call the schema module (not a heredoc), set the
    virtual-device count job-wide, and fan the matrix out over python and
    jax versions with sharded tier-1."""
    wf = (
        pathlib.Path(__file__).resolve().parent.parent
        / ".github" / "workflows" / "ci.yml"
    ).read_text()
    assert "benchmarks.check_schema" in wf
    assert "benchmarks.bench_mutable" in wf  # the live-corpus lane feeds the gate
    assert "xla_force_host_platform_device_count=8" in wf
    assert "fail-fast: false" in wf
    assert "PYTEST_NUM_SHARDS" in wf
    assert '"3.10"' in wf and '"3.11"' in wf
    assert "upload-artifact" in wf
    assert "ruff check" in wf and "ruff format --check" in wf
    assert "python - <<" not in wf  # the heredoc is gone for good
    # observability artifacts: the bench/chaos lanes emit a Chrome trace +
    # metrics snapshot and upload them per matrix cell
    assert "--trace-out" in wf and "--metrics-out" in wf
