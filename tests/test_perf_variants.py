"""§Perf feature tests: EP MoE dispatch, grad accumulation, bf16 wire
format, hlo_analysis loop awareness."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import make_mesh
from repro.configs import get_arch
from repro.launch.train import make_lm_train_step
from repro.models.moe import init_moe, moe_ffn, moe_ffn_ep
from repro.models.transformer import init_transformer
from repro.optim import adamw_init


@pytest.fixture(scope="module")
def mesh2x4():
    return make_mesh((2, 4), ("data", "model"))


def test_ep_moe_matches_dense_dispatch(mesh2x4):
    """Replicated-activation EP == scatter dispatch when nothing drops."""
    params = init_moe(jax.random.key(0), 32, 16, 8, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (64, 32), jnp.float32)
    base = jax.jit(
        lambda p, x: moe_ffn(p, x, top_k=2, capacity_factor=16.0)
    )(params, x)
    ep = jax.jit(
        lambda p, x: moe_ffn_ep(
            p, x, top_k=2, capacity_factor=16.0, mesh=mesh2x4,
            data_axes=("data",),
        )
    )(params, x)
    np.testing.assert_allclose(base.y, ep.y, atol=1e-5)
    assert float(ep.dropped_frac) == 0.0


def test_ep_moe_gradients_flow(mesh2x4):
    params = init_moe(jax.random.key(0), 32, 16, 8, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (64, 32), jnp.float32)
    g = jax.grad(
        lambda p: jnp.sum(
            moe_ffn_ep(
                p, x, top_k=2, capacity_factor=16.0, mesh=mesh2x4,
                data_axes=("data",),
            ).y ** 2
        )
    )(params)
    assert all(bool(jnp.any(x != 0)) for x in jax.tree.leaves(g))


def test_grad_accum_matches_single_step():
    cfg1 = get_arch("qwen3-1.7b").make_smoke_config()
    cfg4 = dataclasses.replace(cfg1, grad_accum=4)
    params = init_transformer(jax.random.key(0), cfg1)
    opt = adamw_init(params)
    tokens = jax.random.randint(jax.random.key(1), (8, 64), 0, cfg1.vocab_size)
    p1, _, m1 = jax.jit(make_lm_train_step(cfg1))(params, opt, {"tokens": tokens})
    p4, _, m4 = jax.jit(make_lm_train_step(cfg4))(params, opt, {"tokens": tokens})
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=1e-5
        )


def test_bf16_wire_apss_exact(corpus, mesh4x2):
    """bf16 blocks travel as u16; matches stay within bf16 tolerance of
    the f32 oracle (bound: one bf16 rounding of the inputs)."""
    from repro.core.apss import apss_reference
    from repro.core.distributed import apss_2d
    from repro.core.graph import match_set

    D32 = jnp.asarray(corpus)
    D16 = D32.astype(jnp.bfloat16)
    # threshold far from any true similarity: rounding can't flip matches
    ref = apss_reference(D32, 0.35, 16)
    got = jax.jit(
        lambda d: apss_2d(d, 0.35, 16, mesh4x2, accumulation="allreduce",
                          block_rows=16)
    )(D16)
    a, b = match_set(got), match_set(ref)
    # allow borderline flips only (|sim - t| < bf16 eps·scale)
    diff = a ^ b
    assert len(diff) <= max(2, len(b) // 50), (len(diff), len(b))


def test_hlo_analysis_loop_multiplication():
    from repro.launch.hlo_analysis import analyze

    def loop(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        return jax.lax.scan(body, x, None, length=7)[0]

    sds = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    hlo = jax.jit(loop).lower(sds, sds).compile().as_text()
    a = analyze(hlo)
    assert a["flops"] == pytest.approx(7 * 2 * 128 ** 3, rel=0.01)
    assert a["max_multiplier"] >= 7


def test_dryrun_overrides_parse():
    from repro.launch.dryrun import apply_overrides

    cfg = get_arch("qwen3-1.7b").make_smoke_config()
    out = apply_overrides(cfg, ["grad_accum=4", "bf16_probs=True"])
    assert out.grad_accum == 4 and out.bf16_probs is True
    d = apply_overrides({"a": 1}, ["a=2", "b=x"])
    assert d == {"a": 2, "b": "x"}
