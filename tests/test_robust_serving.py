"""Degraded-mode serving + adversarial-input contract (ISSUE 6).

The acceptance bar: under injected slow-shard load the server sheds or
degrades past-deadline requests while in-budget requests still return EXACT
results, with the ``shed`` / ``degraded`` / ``retries`` / ``stale`` counters
asserted both on :class:`ServerStats` and in the telemetry log.
"""

import numpy as np
import pytest

from repro.core.apss import apss_reference
from repro.planner import telemetry
from repro.robust import Fault, FaultPlan
from repro.serving.index import build_index
from repro.serving.server import RetrievalServer

T, K = 0.35, 8


@pytest.fixture(scope="module")
def index(request):
    corpus = request.getfixturevalue("corpus")
    return build_index(corpus, block_rows=32, normalize=False)


@pytest.fixture(scope="module")
def corpus_np(request):
    return np.asarray(request.getfixturevalue("corpus"))


def _serve_one(srv, q):
    return srv.result(srv.submit(q))


# ---------------------------------------------------------------------------
# Adversarial input: the contract is reject-or-sanitize, never garbage
# ---------------------------------------------------------------------------


def test_nan_query_rejected(index):
    srv = RetrievalServer(index, threshold=T, k=K)
    q = np.zeros(index.m, np.float32)
    q[3] = np.nan
    with pytest.raises(ValueError, match="non-finite"):
        srv.submit(q)


def test_inf_query_rejected(index):
    srv = RetrievalServer(index, threshold=T, k=K)
    q = np.zeros(index.m, np.float32)
    q[0] = -np.inf
    with pytest.raises(ValueError, match="non-finite"):
        srv.submit(q)


def test_non_numeric_dtype_rejected(index):
    srv = RetrievalServer(index, threshold=T, k=K)
    with pytest.raises(ValueError, match="not numeric"):
        srv.submit(np.array(["x"] * index.m))
    with pytest.raises(ValueError, match="not numeric"):
        srv.submit(np.ones(index.m, np.complex64))


def test_integer_query_cast(index):
    """Numeric non-float dtypes are cast, not rejected."""
    srv = RetrievalServer(index, threshold=T, k=K)
    res = _serve_one(srv, np.zeros(index.m, np.int32))
    assert res.status == "ok"


def test_zero_vector_normalized_to_empty_result(index):
    """All-zero query + normalize=True: normalize_rows keeps it zero (eps
    floor — no divide-by-zero NaNs), it matches nothing, and the result is
    a well-formed empty."""
    srv = RetrievalServer(index, threshold=T, k=K, normalize=True)
    res = _serve_one(srv, np.zeros(index.m, np.float32))
    assert res.status == "ok"
    assert res.count == 0
    assert np.all(np.asarray(res.indices) == -1)
    assert not np.isnan(np.asarray(res.values)).any()


def test_wrong_dim_rejected(index):
    srv = RetrievalServer(index, threshold=T, k=K)
    with pytest.raises(ValueError, match="query dim"):
        srv.submit(np.zeros(index.m + 1, np.float32))


# ---------------------------------------------------------------------------
# Deadlines + admission control under injected slow-shard load
# ---------------------------------------------------------------------------


def test_slow_shard_sheds_late_keeps_exact(index, corpus_np):
    """Acceptance criterion 3: a delay fault stalls the step; the
    tight-deadline request is shed, the in-budget request's answer equals
    the oracle's top-k for that row."""
    plan = FaultPlan([Fault("delay", scope="serving", step=0, seconds=0.05)])
    srv = RetrievalServer(
        index, threshold=T, k=K, cache_size=0, fault_plan=plan,
    )
    with telemetry.CommLog() as log:
        rid_late = srv.submit(corpus_np[0], deadline_s=0.01)
        rid_ok = srv.submit(corpus_np[1])
        while srv._pending:
            srv.step()
    late, ok = srv.result(rid_late), srv.result(rid_ok)
    assert plan.fired["delay:serving"] == 1
    assert late.status == "shed"
    assert late.count == 0
    assert ok.status == "ok"
    # Retrieval semantics: the query is external, so its identical corpus
    # row is a legitimate (self-inclusive) match.
    ref = apss_reference(corpus_np, T, K, exclude_self=False)
    assert np.array_equal(np.asarray(ok.indices), np.asarray(ref.indices[1]))
    assert np.array_equal(np.asarray(ok.values), np.asarray(ref.values[1]))
    assert srv.stats.shed == 1
    assert log.counters["serving.shed"] == 1


def test_admission_budget_sheds_overflow(index, corpus_np):
    srv = RetrievalServer(
        index, threshold=T, k=K, cache_size=0, max_pending=2,
    )
    with telemetry.CommLog() as log:
        rids = [srv.submit(corpus_np[i]) for i in range(5)]
        statuses = [srv.result(r).status for r in rids]
    assert statuses == ["ok", "ok", "shed", "shed", "shed"]
    assert srv.stats.shed == 3
    assert log.counters["serving.shed"] == 3


# ---------------------------------------------------------------------------
# Degradation ladder: kernel → XLA → stale cache, with retries
# ---------------------------------------------------------------------------


def test_kernel_tier_down_degrades_to_xla_exact(index, corpus_np):
    """Persistent kernel-tier failure: one retry (counted), then degrade to
    the XLA tier — the answer is still exact."""
    plan = FaultPlan([Fault("error", scope="serving.kernel", times=-1)])
    srv = RetrievalServer(
        index, threshold=T, k=K, cache_size=0, use_kernel=True,
        max_retries=1, backoff_s=0.001, fault_plan=plan,
    )
    with telemetry.CommLog() as log:
        res = _serve_one(srv, corpus_np[2])
    assert res.status == "ok"
    ref = apss_reference(corpus_np, T, K, exclude_self=False)
    assert np.array_equal(np.asarray(res.indices), np.asarray(ref.indices[2]))
    assert srv.stats.retries == 1
    assert srv.stats.degraded == 1
    assert log.counters["serving.retries"] == 1
    assert log.counters["serving.degraded"] == 1


def test_transient_error_recovers_via_retry(index, corpus_np):
    """A once-off failure is absorbed by the retry, no degradation."""
    plan = FaultPlan([Fault("error", scope="serving.xla", times=1)])
    srv = RetrievalServer(
        index, threshold=T, k=K, cache_size=0,
        max_retries=2, backoff_s=0.001, fault_plan=plan,
    )
    res = _serve_one(srv, corpus_np[3])
    assert res.status == "ok"
    assert srv.stats.retries == 1
    assert srv.stats.degraded == 0


def test_all_tiers_down_serves_stale_then_fails_on_miss(index, corpus_np):
    """ttl_s=0 makes every cache entry stale immediately: fresh submits
    miss, but when scoring is down the stale entry still answers — and a
    query never seen before fails explicitly instead of hanging."""
    srv = RetrievalServer(
        index, threshold=T, k=K, ttl_s=0.0, max_retries=0,
    )
    warm = _serve_one(srv, corpus_np[4])
    assert warm.status == "ok"
    srv.fault_plan = FaultPlan(
        [Fault("error", scope="serving.xla", times=-1)]
    )
    with telemetry.CommLog() as log:
        stale = _serve_one(srv, corpus_np[4])
        miss = _serve_one(srv, corpus_np[5])
    assert stale.status == "stale"
    assert stale.cached
    assert np.array_equal(np.asarray(stale.indices), np.asarray(warm.indices))
    assert miss.status == "failed"
    assert miss.count == 0
    assert srv.stats.stale == 1
    assert log.counters["serving.stale"] == 1
    assert log.counters["serving.degraded"] == 2


def test_normalize_still_applied_on_unnormalized_queries(index, corpus_np):
    """Degraded-mode plumbing must not bypass the normalize contract."""
    srv = RetrievalServer(index, threshold=T, k=K, cache_size=0)
    scaled = _serve_one(srv, corpus_np[6] * 7.5)
    plain = _serve_one(srv, corpus_np[6])
    assert np.array_equal(np.asarray(scaled.indices), np.asarray(plain.indices))
    assert np.allclose(np.asarray(scaled.values), np.asarray(plain.values))
