"""Execution planner: cost-model-driven auto-selection (ISSUE 4 acceptance).

The load-bearing assertions: ``variant="auto"`` picks the sparse path on a
low-density corpus and a dense configuration on a dense one, and whatever
the planner picks executes exactly (every variant is exact, so planning can
never change results — only cost).
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.apss import apss_reference, normalize_rows, similarity_topk
from repro.core.graph import match_set
from repro.core.sparse import to_dense
from repro.data.sparse import sparse_zipfian_corpus
from repro.planner import (
    CalibrationProfile,
    VariantConfig,
    default_profile,
    estimate_cost,
    plan_apss,
)
from repro.planner import calibrate as calibrate_mod
from repro.planner.plan import candidate_configs, execute, summarize_corpus

T, K = 0.5, 16


@pytest.fixture(scope="module")
def lowdens():
    """Paper-regime corpus: density ≈ 0.6%."""
    return sparse_zipfian_corpus(512, 2048, 12.0, seed=0)


@pytest.fixture(scope="module")
def middens():
    """20% density: sparse representation offered, but gather-dot loses."""
    rng = np.random.default_rng(1)
    D = np.abs(rng.standard_normal((256, 128))).astype(np.float32)
    D *= rng.random((256, 128)) < 0.2
    return np.asarray(normalize_rows(jnp.asarray(D)))


def _check_exact(got, ref):
    assert match_set(got) == match_set(ref)
    np.testing.assert_array_equal(np.asarray(got.counts), np.asarray(ref.counts))


# -- the acceptance assertions ------------------------------------------------


def test_auto_picks_sparse_on_low_density(lowdens):
    plan = plan_apss(lowdens, T, K, profile=default_profile(), include_kernel=False)
    assert plan.config.sparse, plan.describe()
    # and the model's reasoning is visible: sparse flops ≪ dense flops
    sparse_best = next(e for e in plan.estimates if e.config.sparse)
    dense_best = next(e for e in plan.estimates if not e.config.sparse)
    assert sparse_best.flops < dense_best.flops


def test_auto_picks_dense_on_dense(middens):
    plan = plan_apss(middens, T, K, profile=default_profile(), include_kernel=False)
    # both representations were candidates; the dense one won on cost
    assert any(e.config.sparse for e in plan.estimates)
    assert not plan.config.sparse, plan.describe()


def test_auto_picks_dense_schedule_on_dense_mesh(middens, mesh8):
    plan = plan_apss(
        middens, T, K, mesh8, profile=default_profile(), include_kernel=False
    )
    assert not plan.config.sparse, plan.describe()
    got = plan.run()
    _check_exact(got, apss_reference(jnp.asarray(middens), T, K))


def test_variant_auto_dispatch_exact_sparse(lowdens):
    got = similarity_topk(lowdens, lowdens, T, K, exclude_self=True, variant="auto")
    ref = apss_reference(to_dense(lowdens), T, K)
    _check_exact(got, ref)


def test_variant_auto_dispatch_exact_dense(middens):
    D = jnp.asarray(middens)
    got = similarity_topk(D, D, T, K, exclude_self=True, variant="auto")
    _check_exact(got, apss_reference(D, T, K))


def test_variant_auto_guards():
    rng = np.random.default_rng(2)
    D = jnp.asarray(rng.standard_normal((32, 16)).astype(np.float32))
    Q = jnp.asarray(rng.standard_normal((8, 16)).astype(np.float32))
    with pytest.raises(ValueError, match="self-join"):
        similarity_topk(Q, D, T, K, exclude_self=True, variant="auto")
    with pytest.raises(ValueError, match="exclude_self"):
        similarity_topk(D, D, T, K, variant="auto")
    with pytest.raises(ValueError, match="variant"):
        similarity_topk(D, D, T, K, variant="ring")


def test_apss_distribution_auto(corpus, mesh8):
    from repro.core.distributed import apss

    D = jnp.asarray(corpus)
    got = apss(
        D, 0.35, K, mesh8, distribution="auto",
        profile=default_profile(), include_kernel=False,
    )
    _check_exact(got, apss_reference(D, 0.35, K))


# -- corpus summary -----------------------------------------------------------


def test_summarize_sparse_never_densifies(lowdens):
    s = summarize_corpus(lowdens, T)
    assert s.sparse_input
    assert s.n == 512 and s.m == 2048
    assert 0.004 < s.density < 0.01
    assert s.cap == lowdens.cap
    assert s.zipf_alpha > 0.4          # the generator's skew is visible
    assert 0.0 < s.live_fraction <= 1.0
    assert len(s.tile_counts) >= 1


def test_summarize_dense(middens):
    s = summarize_corpus(middens, T)
    assert not s.sparse_input
    assert s.density == pytest.approx(0.2, abs=0.05)
    assert s.cap <= 128
    assert s.imbalance(4) >= 1.0


def test_summarize_index_uses_exact_stats(lowdens):
    from repro.serving import build_index

    index = build_index(lowdens, block_rows=64, normalize=False)
    s = summarize_corpus(index, T)
    assert s.sparse_input and s.n == 512
    assert 0.0 <= s.live_fraction <= 1.0


# -- candidate enumeration / cost model ---------------------------------------


def test_candidates_respect_constraints(lowdens, mesh8):
    s = summarize_corpus(lowdens, T)
    cfgs = candidate_configs(s, mesh8, K, include_kernel=False)
    kinds = {c.kind for c in cfgs}
    assert {"blocked", "horizontal", "vertical"} <= kinds
    for c in cfgs:
        if c.kind == "vertical":
            assert s.n % c.block_rows == 0
            if c.accumulation == "scatter":
                assert c.block_rows % 8 == 0
        assert not c.use_kernel  # include_kernel=False


def test_every_candidate_priced_finite(lowdens, mesh8):
    s = summarize_corpus(lowdens, T)
    prof = default_profile()
    for c in candidate_configs(s, mesh8, K, include_kernel=False):
        e = estimate_cost(c, s, dict(mesh8.shape), prof, K)
        assert np.isfinite(e.total_s) and e.total_s > 0, c.name
        assert e.wire_bytes >= 0 and e.flops > 0


def test_blocked_priced_single_device_under_mesh(lowdens, mesh8):
    """A blocked config runs all rows on one device regardless of the mesh:
    its estimate must not shrink by p (which would bias the planner against
    every distributed variant)."""
    s = summarize_corpus(lowdens, T)
    prof = default_profile()
    cfg = VariantConfig("blocked", True, 128)
    with_mesh = estimate_cost(cfg, s, dict(mesh8.shape), prof, K)
    without = estimate_cost(cfg, s, None, prof, K)
    assert with_mesh.flops == without.flops
    assert with_mesh.total_s == without.total_s


def test_vertical_requires_m_divisible(mesh8):
    """Dense vertical shards columns P(None, axis): m % p != 0 must be
    filtered at enumeration, not crash at dispatch."""
    rng = np.random.default_rng(5)
    raw = np.abs(rng.standard_normal((128, 100))).astype(np.float32)
    D = np.asarray(normalize_rows(jnp.asarray(raw)))
    s = summarize_corpus(D, T)
    cfgs = candidate_configs(s, mesh8, K, include_kernel=False)
    assert cfgs and not any(c.kind == "vertical" for c in cfgs)


def test_plan_on_index_returns_valid_rows_only():
    """Indexes pad rows to the block multiple (and lane-pad dense feature
    axes); planning from an index must run on the VALID corpus, not the
    padded one."""
    from repro.data.sparse import sparse_zipfian_corpus as szc
    from repro.serving import build_index

    sp = szc(200, 512, 8.0, seed=6)
    index = build_index(sp, block_rows=64, normalize=False)  # pads 200 → 256
    assert index.n_padded == 256
    plan = plan_apss(index, T, K, profile=default_profile(), include_kernel=False)
    got = plan.run()
    assert got.counts.shape[0] == 200
    _check_exact(got, apss_reference(to_dense(sp), T, K))


def test_costmodel_wire_matches_telemetry(corpus, mesh8):
    """The model's wire prediction IS the telemetry formula: predicted bytes
    for the ring equal the instrumented record of an actual run."""
    from repro.core.distributed import apss_horizontal
    from repro.planner import CommLog

    D = jnp.asarray(corpus)
    s = summarize_corpus(D, 0.35)
    cfg = VariantConfig("horizontal", False, 128, schedule="ring")
    est = estimate_cost(cfg, s, dict(mesh8.shape), default_profile(), K)
    with CommLog() as log:
        apss_horizontal(D, 0.35, K, mesh8, schedule="ring", block_rows=16)
    assert est.wire_bytes == log.last.wire_bytes
    assert est.hop_count == log.last.hop_count


def test_profile_roundtrip(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_CALIB_DIR", str(tmp_path))
    calibrate_mod._MEMO.clear()
    prof = calibrate_mod.calibrate(n=64, m=128, cap=8, iters=1, save=True)
    assert prof.matmul_gflops > 0 and prof.gather_gflops > 0
    assert calibrate_mod.profile_path().exists()
    calibrate_mod._MEMO.clear()
    loaded = calibrate_mod.get_profile()
    assert loaded.matmul_gflops == pytest.approx(prof.matmul_gflops)
    assert loaded.device_kind == prof.device_kind
    calibrate_mod._MEMO.clear()
    monkeypatch.delenv("REPRO_CALIB_DIR")


def test_get_profile_defaults_without_cache(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_CALIB_DIR", str(tmp_path / "empty"))
    calibrate_mod._MEMO.clear()
    prof = calibrate_mod.get_profile()
    ref = default_profile()
    assert prof.matmul_gflops == ref.matmul_gflops  # deterministic defaults
    calibrate_mod._MEMO.clear()
    monkeypatch.delenv("REPRO_CALIB_DIR")


# -- plan execution & integration ---------------------------------------------


def test_execute_representation_conversion(middens):
    """A sparse config on a dense input converts host-side and stays exact."""
    cfg = VariantConfig("blocked", True, 64)
    got = execute(cfg, middens, T, K)
    _check_exact(got, apss_reference(jnp.asarray(middens), T, K))


def test_autotune_promotes_measured_winner():
    sp = sparse_zipfian_corpus(256, 1024, 8.0, seed=3)
    plan = plan_apss(
        sp, T, K, profile=default_profile(), include_kernel=False,
        autotune=True,
    )
    assert plan.autotuned
    measured = [e for e in plan.estimates if e.measured_s is not None]
    assert len(measured) >= 2
    assert plan.estimates[0].measured_s == min(e.measured_s for e in measured)
    _check_exact(plan.run(), apss_reference(to_dense(sp), T, K))


def test_build_index_with_plan(lowdens):
    from repro.serving import build_index, query_topk

    plan = plan_apss(lowdens, T, K, profile=default_profile(), include_kernel=False)
    index = build_index(lowdens, normalize=False, plan=plan)
    assert index.block_rows == plan.config.block_rows
    assert index.is_sparse == plan.config.sparse
    # plan on a dense view converts the corpus to the planned representation
    D = to_dense(lowdens)
    index2 = build_index(D, normalize=False, plan=plan)
    assert index2.is_sparse == plan.config.sparse
    Q = np.asarray(D[:4])
    got = query_topk(index2, jnp.asarray(Q), T, K)
    ref = query_topk(index, jnp.asarray(Q), T, K)
    assert match_set(got) == match_set(ref)


def test_plan_describe_and_dict(lowdens):
    plan = plan_apss(lowdens, T, K, profile=default_profile(), include_kernel=False)
    text = plan.describe()
    assert plan.config.name in text
    assert "density" in text
    d = plan.as_dict()
    assert d["chosen"] == plan.config.name
    assert d["estimates"] and {"config", "predicted_s", "wire_bytes"} <= d[
        "estimates"
    ][0].keys()
    assert d["summary"]["n"] == 512
