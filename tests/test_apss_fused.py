"""Fused streaming match extraction: exactness on adversarial shapes plus
the load-bearing memory regression — the kernel-backed self-join must never
materialize an (n, n) score matrix in HBM."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.apss import (
    apss_blocked,
    apss_reference,
    normalize_rows,
    similarity_topk,
)
from repro.core.distributed import apss_horizontal
from repro.core.graph import match_set
from repro.kernels.apss_block.ops import apss_fused, apss_fused_compacted

T, K = 0.35, 16
RNG = np.random.default_rng(7)


def _corp(n, m, density=0.3, seed=None):
    rng = np.random.default_rng(seed) if seed is not None else RNG
    D = np.abs(rng.standard_normal((n, m))).astype(np.float32)
    D *= rng.random((n, m)) < density
    return np.asarray(normalize_rows(jnp.asarray(D)))


def _check(got, ref):
    assert match_set(got) == match_set(ref)
    np.testing.assert_array_equal(np.asarray(got.counts), np.asarray(ref.counts))
    # Directed per-row index sets (undirected match_set can't see a row
    # reported with a wrong — e.g. self-referential — partner id when the
    # true pair is also covered from the other direction). Rows at capacity
    # may legitimately differ under value ties, so compare below capacity.
    gi, ri = np.asarray(got.indices), np.asarray(ref.indices)
    under = np.asarray(ref.counts) <= ref.capacity
    for r in np.nonzero(under)[0]:
        assert set(gi[r][gi[r] >= 0]) == set(ri[r][ri[r] >= 0]), r


# -- exactness ----------------------------------------------------------------


@pytest.mark.parametrize(
    "n,m",
    [
        (256, 256),   # tile-multiple
        (130, 100),   # both axes ragged → row/col/feature padding
        (257, 513),   # off-by-one over tile boundaries
    ],
)
def test_fused_selfjoin_adversarial_shapes(n, m):
    D = jnp.asarray(_corp(n, m, seed=n))
    ref = apss_reference(D, T, K)
    got = apss_fused(D, D, T, K, block_m=128, block_n=128, block_k=128)
    _check(got, ref)


def test_fused_equals_blocked_use_kernel(corpus):
    D = jnp.asarray(corpus)
    ref = apss_reference(D, T, K)
    got = apss_blocked(D, T, K, block_rows=128, use_kernel=True)
    _check(got, ref)


def test_fused_all_pruned_mask_is_empty():
    """An explicitly dead mask yields zero matches and zero counts even
    though the raw scores pass the threshold."""
    D = jnp.asarray(_corp(128, 96, seed=1))
    mask = jnp.zeros((1, 1), jnp.int32)
    got = apss_fused(D, D, 0.0, K, block_mask=mask, block_m=128, block_n=128)
    assert int(got.counts.sum()) == 0
    assert (np.asarray(got.indices) == -1).all()
    assert not np.isfinite(np.asarray(got.values)).any()


def test_fused_threshold_above_any_bound_prunes_everything():
    """t > m bounds every tile dead (ub ≤ m for unit rows): the auto-mask
    kills the whole grid and the result must equal the (empty) oracle."""
    D = jnp.asarray(_corp(64, 48, seed=2))
    t = float(D.shape[1] + 1)
    ref = apss_reference(D, t, K)
    got = apss_fused(D, D, t, K, block_m=128, block_n=128)
    _check(got, ref)
    assert int(got.counts.sum()) == 0


def test_fused_overflow_rows_counts_stay_exact():
    """Rows with more matches than capacity: counts are exact (> k, flagged
    by overflowed()), the k slots hold true top values."""
    one = np.zeros((1, 64), np.float32)
    one[0, 0] = 1.0
    D = jnp.asarray(np.repeat(one, 32, axis=0))  # 32 identical unit rows
    k = 4
    got = apss_fused(D, D, 0.5, k, block_m=128, block_n=128)
    assert (np.asarray(got.counts) == 31).all()
    assert bool(got.overflowed().all())
    np.testing.assert_allclose(np.asarray(got.values), 1.0)


def test_fused_join_with_offsets_matches_xla_path():
    Q = jnp.asarray(_corp(37, 80, seed=3))
    C = jnp.asarray(_corp(90, 80, seed=4))
    want = similarity_topk(Q, C, 0.2, 8, block_rows=16, col_offset=100)
    got = similarity_topk(
        Q, C, 0.2, 8, block_rows=16, col_offset=100, use_kernel=True
    )
    np.testing.assert_array_equal(got.counts, want.counts)
    np.testing.assert_array_equal(got.indices, want.indices)
    np.testing.assert_allclose(
        np.asarray(got.values), np.asarray(want.values), atol=1e-6
    )


# -- compacted (live-tile worklist) path --------------------------------------


@pytest.mark.parametrize("n,m", [(256, 256), (130, 100)])
def test_compacted_selfjoin_exact(n, m):
    D = jnp.asarray(_corp(n, m, seed=n + 1))
    ref = apss_reference(D, T, K)
    got = apss_fused_compacted(D, T, K, block_m=128, block_k=128)
    _check(got, ref)


def test_compacted_zipfian_corpus_exact():
    from repro.data.synthetic import synthetic_corpus

    D = jnp.asarray(synthetic_corpus(256, 384, 20, seed=5))
    ref = apss_reference(D, 0.4, K)
    got = apss_fused_compacted(D, 0.4, K, block_m=128, block_k=128)
    _check(got, ref)


def test_worklist_adaptive_ordering_sorts_by_ub_and_is_order_invariant():
    """The compacted worklist is sorted by maxweight upper bound descending
    (paper's adaptive ordering); results must be identical to the unsorted
    worklist — each tile packet folds into an exact running top-k, so
    ordering only changes WHERE matches are found early, never WHAT."""
    from repro.core.pruning import block_prune_mask
    from repro.kernels.apss_block.ops import _compacted_inner, compact_worklist

    D = jnp.asarray(_corp(256, 128, seed=11))
    mask, ub = block_prune_mask(D, D, T, 64, 64, return_ub=True)
    wl_sorted = compact_worklist(mask, ub)
    wl_plain = compact_worklist(mask)
    assert wl_sorted.shape == wl_plain.shape and wl_sorted.shape[1] > 1
    # Same tile set, sorted by symmetrized ub descending.
    assert set(map(tuple, wl_sorted.T)) == set(map(tuple, wl_plain.T))
    u = np.asarray(ub)
    u = np.maximum(u, u.T)
    key = u[wl_sorted[0], wl_sorted[1]]
    assert np.all(key[:-1] >= key[1:] - 1e-6)

    def run(wl):
        v, i, c = _compacted_inner(
            D, jnp.asarray(wl), threshold=T, k=K, block_m=64, block_k=128,
            n_valid=256, grid_m=4, interpret=True,
        )
        return np.asarray(v), np.asarray(i), np.asarray(c)

    va, ia, ca = run(wl_sorted)
    vb, ib, cb = run(wl_plain)
    np.testing.assert_array_equal(ca, cb)
    np.testing.assert_allclose(np.sort(va, axis=-1), np.sort(vb, axis=-1))
    for r in range(va.shape[0]):
        assert set(ia[r][ia[r] >= 0]) == set(ib[r][ib[r] >= 0]), r


def test_compacted_all_pruned_returns_empty():
    D = jnp.asarray(_corp(64, 48, seed=6))
    t = float(D.shape[1] + 1)
    got = apss_fused_compacted(D, t, K, block_m=128, block_k=128)
    assert got.values.shape == (64, K)
    assert int(got.counts.sum()) == 0


def test_compacted_mirror_packet_reports_partner_id():
    """Regression: a match covered ONLY by the mirror orientation of an
    upper-triangular tile must report the partner's id, not the row's own
    (gcol.T vs grow.T in the backward packet)."""
    rng = np.random.default_rng(11)
    D = np.array(_corp(256, 64, density=1.0, seed=11))
    # Make rows 5 and 200 (different 128-blocks) near-duplicates.
    D[200] = D[5] + 0.01 * np.abs(rng.standard_normal(64)).astype(np.float32)
    D = np.asarray(normalize_rows(jnp.asarray(D)))
    t = 0.98
    S = D @ D.T
    np.fill_diagonal(S, 0.0)
    assert (S[200] >= t).sum() == 1 and S[200].argmax() == 5  # setup holds
    got = apss_fused_compacted(jnp.asarray(D), t, 8, block_m=128, block_k=64)
    assert int(got.counts[200]) == 1
    assert int(got.indices[200, 0]) == 5
    assert int(got.indices[5, 0]) == 200


def test_compacted_overflow_rows_counts_stay_exact():
    one = np.zeros((1, 64), np.float32)
    one[0, 0] = 1.0
    D = jnp.asarray(np.repeat(one, 32, axis=0))
    got = apss_fused_compacted(D, 0.5, 4, block_m=128, block_k=128)
    assert (np.asarray(got.counts) == 31).all()
    assert bool(got.overflowed().all())


# -- memory regression: no O(n²) HBM buffer on the kernel path ----------------


def test_fused_path_never_materializes_nxn():
    """The jaxpr of the kernel-backed self-join must contain no (n, n) f32
    intermediate — the score matrix lives only in VMEM tiles. The dense
    seed kernel is the positive control (guards the string probe itself)."""
    n, m = 512, 256
    D = jnp.asarray(_corp(n, m, seed=8))

    fused = str(
        jax.make_jaxpr(
            lambda d: apss_blocked(d, T, K, block_rows=128, use_kernel=True)
        )(D)
    )
    assert f"f32[{n},{n}]" not in fused

    from repro.kernels.apss_block.ops import apss_block_matmul

    dense = str(
        jax.make_jaxpr(
            lambda d: apss_block_matmul(
                d, d, T, block_m=128, block_n=128, block_k=128
            )
        )(D)
    )
    assert f"f32[{n},{n}]" in dense  # positive control


def test_fused_output_buffers_are_n_by_k():
    n = 512
    D = jnp.asarray(_corp(n, 256, seed=9))
    got = apss_blocked(D, T, K, block_rows=128, use_kernel=True)
    assert got.values.shape == (n, K)
    assert got.indices.shape == (n, K)
    assert got.counts.shape == (n,)


# -- distributed schedules on the fused kernel --------------------------------


@pytest.mark.parametrize("schedule", ["allgather", "ring", "halfring"])
def test_horizontal_schedules_use_kernel_exact(corpus, mesh8, schedule):
    D = jnp.asarray(corpus)
    ref = apss_reference(D, T, K)
    got = jax.jit(
        lambda d: apss_horizontal(
            d, T, K, mesh8, schedule=schedule, block_rows=16, use_kernel=True
        )
    )(D)
    _check(got, ref)
