"""launch.hlo_analysis loop awareness on REAL lowered HLO (ISSUE 9,
satellite 4): scan trip counts, nested-loop multipliers, collective link
factors — the analyzer the compile audit (repro.obs.audit) stands on."""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import make_mesh, shard_map
from repro.launch.hlo_analysis import analyze


def _hlo(fn, *shapes):
    args = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_of_matmuls_counts_trip_count():
    """A scanned matmul body must be billed T times, not once — the whole
    reason ``compiled.cost_analysis()`` is not enough."""
    T, n = 9, 64

    def loop(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None

        return jax.lax.scan(body, x, None, length=T)[0]

    a = analyze(_hlo(loop, (n, n), (n, n)))
    assert a["flops"] == pytest.approx(T * 2 * n**3, rel=0.02)
    assert a["max_multiplier"] >= T


def test_scan_over_stacked_operands_counts_leading_dim():
    """Trip count from scanning an actual array (the sparse tile-worklist
    shape: scan over (T, ...) stacked operands)."""
    T, n = 6, 32

    def loop(xs, w):
        def body(c, x):
            return c + x @ w, None

        return jax.lax.scan(body, jnp.zeros((n, n), jnp.float32), xs)[0]

    a = analyze(_hlo(loop, (T, n, n), (n, n)))
    assert a["flops"] == pytest.approx(T * 2 * n**3, rel=0.02)
    assert a["max_multiplier"] >= T


def test_nested_scans_multiply_trip_counts():
    outer, inner, n = 3, 5, 32

    def loop(x, w):
        def outer_body(c, _):
            def inner_body(ci, _):
                return jnp.tanh(ci @ w), None

            return jax.lax.scan(inner_body, c, None, length=inner)[0], None

        return jax.lax.scan(outer_body, x, None, length=outer)[0]

    a = analyze(_hlo(loop, (n, n), (n, n)))
    assert a["flops"] == pytest.approx(outer * inner * 2 * n**3, rel=0.02)
    assert a["max_multiplier"] >= outer * inner


def test_loop_multiplier_scales_hbm_too():
    """A matmul inside a scan re-reads its operands every iteration; the
    HBM census must scale with the trip count as the FLOPs do."""
    n = 64

    def once(x, w):
        return jnp.tanh(x @ w)

    def looped(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None

        return jax.lax.scan(body, x, None, length=8)[0]

    h1 = analyze(_hlo(once, (n, n), (n, n)))["hbm_bytes"]
    h8 = analyze(_hlo(looped, (n, n), (n, n)))["hbm_bytes"]
    assert h8 > 4 * h1  # 8 iterations must bill several times one pass


@pytest.fixture(scope="module")
def mesh8d():
    return make_mesh((8,), ("data",))


def _spmd_hlo(fn, mesh, in_specs, out_specs, *shapes):
    f = shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    args = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    return jax.jit(f).lower(*args).compile().as_text()


def test_all_gather_link_factor(mesh8d):
    """all-gather of a (g·r, c) array: per-device link = (g-1)/g × the
    gathered payload."""
    g, r, c = 8, 16, 32

    def f(x):
        return jax.lax.all_gather(x, "data", axis=0, tiled=True)

    a = analyze(_spmd_hlo(f, mesh8d, P("data", None), P(None, None),
                          (g * r, c)))
    payload = g * r * c * 4
    assert a["collectives"]["all-gather"]["count"] >= 1
    assert a["link_bytes"] == pytest.approx(payload * (g - 1) / g, rel=0.01)


def test_all_reduce_link_factor(mesh8d):
    """psum: ring all-reduce moves 2(g-1)/g × the payload per device."""
    g, r, c = 8, 16, 32

    def f(x):
        return jax.lax.psum(x, "data")

    a = analyze(_spmd_hlo(f, mesh8d, P("data", None), P(None, None),
                          (g * r, c)))
    payload = r * c * 4  # per-device operand after the shard split
    assert a["collectives"]["all-reduce"]["count"] >= 1
    assert a["link_bytes"] == pytest.approx(
        2 * payload * (g - 1) / g, rel=0.01
    )


def test_collective_permute_link_is_payload(mesh8d):
    """A ring step sends exactly its payload once per device."""
    g, r, c = 8, 16, 32

    def f(x):
        perm = [(i, (i + 1) % g) for i in range(g)]
        return jax.lax.ppermute(x, "data", perm)

    a = analyze(_spmd_hlo(f, mesh8d, P("data", None), P("data", None),
                          (g * r, c)))
    payload = r * c * 4
    assert a["collectives"]["collective-permute"]["count"] >= 1
    assert a["link_bytes"] == pytest.approx(payload, rel=0.01)


def test_collective_inside_loop_is_multiplied(mesh8d):
    """The ring schedule shape: a ppermute inside a scan must be billed
    once per step — link bytes scale with the trip count."""
    g, r, c, steps = 8, 16, 32, 7

    def f(x):
        perm = [(i, (i + 1) % g) for i in range(g)]

        def body(carry, _):
            return jax.lax.ppermute(carry, "data", perm), None

        return jax.lax.scan(body, x, None, length=steps)[0]

    a = analyze(_spmd_hlo(f, mesh8d, P("data", None), P("data", None),
                          (g * r, c)))
    payload = r * c * 4
    assert a["link_bytes"] == pytest.approx(steps * payload, rel=0.05)
