"""Telemetry-backed wire-volume tests (ISSUE 4 satellite).

The ROADMAP noted the schedules' wire-volume claims were "verified by
construction, not measured". These tests execute the instrumented variants
under a :class:`~repro.planner.telemetry.CommLog` and assert the recorded
per-hop byte counts — halfring moves ~half the ring's block bytes, the
sparse ring's per-hop bytes scale with ``cap/m`` — turning the claims into
executed assertions (the same formulas parameterize the planner's cost
models, so these tests pin the model too).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.apss import apss_blocked, normalize_rows
from repro.core.distributed import (
    apss_2d,
    apss_horizontal,
    apss_horizontal_hierarchical,
)
from repro.core.sparse import from_dense
from repro.planner import CommLog
from repro.planner.telemetry import (
    csr_block_bytes,
    dense_block_bytes,
    enabled,
)

T, K = 0.35, 16
P8 = 8


def _dense(n=128, m=1024, dens=0.3, seed=0):
    rng = np.random.default_rng(seed)
    D = np.abs(rng.standard_normal((n, m))).astype(np.float32)
    D *= rng.random((n, m)) < dens
    return np.asarray(normalize_rows(jnp.asarray(D)))


def test_commlog_scoping():
    assert not enabled()
    with CommLog() as log:
        assert enabled()
        assert log.records == []
    assert not enabled()


def test_halfring_halves_ring_bytes(mesh8):
    """S = Sᵀ: the halfring's traveling blocks make p//2 hops vs the ring's
    p-1 — block bytes EXACTLY in that ratio; the caravan overhead keeps the
    total under 0.65× for m ≫ k."""
    D = jnp.asarray(_dense())
    with CommLog() as log:
        apss_horizontal(D, T, K, mesh8, schedule="ring", block_rows=16)
        apss_horizontal(D, T, K, mesh8, schedule="halfring", block_rows=16)
    ring, half = log.records
    rb = ring.bytes_by_payload()["dense_block"]
    hb = half.bytes_by_payload()["dense_block"]
    assert hb * (P8 - 1) == rb * (P8 // 2)
    ratio = half.wire_bytes / ring.wire_bytes
    assert 0.5 <= ratio < 0.65, ratio


def test_sparse_ring_bytes_scale_with_cap(mesh8):
    """The sparse ring's per-hop payload is the CSR triple: bytes/hop drop
    from O(n_loc·m) to O(n_loc·cap) — a factor ≈ (2·cap)/m vs dense."""
    m = 1024
    D = _dense(128, m, 0.05, seed=1)
    sp = from_dense(D)
    n_loc = 128 // P8
    with CommLog() as log:
        apss_horizontal(jnp.asarray(D), T, K, mesh8, schedule="ring", block_rows=16)
        apss_horizontal(sp, T, K, mesh8, "data", schedule="ring", block_rows=16)
    dense_r, sparse_r = log.records
    assert dense_r.hops[0].bytes_per_hop == dense_block_bytes(n_loc, m)
    assert sparse_r.hops[0].bytes_per_hop == csr_block_bytes(n_loc, sp.cap)
    ratio = sparse_r.wire_bytes / dense_r.wire_bytes
    assert ratio == pytest.approx(
        csr_block_bytes(n_loc, sp.cap) / dense_block_bytes(n_loc, m)
    )
    assert ratio < 0.25  # 5% density: cap ≪ m

    # Widening cap (inert padding slots) scales the slot payload linearly.
    sp2 = from_dense(D, cap=2 * sp.cap)
    with CommLog() as log2:
        apss_horizontal(sp2, T, K, mesh8, "data", schedule="ring", block_rows=16)
    b1 = sparse_r.hops[0].bytes_per_hop - n_loc * 4  # minus the nnz vector
    b2 = log2.last.hops[0].bytes_per_hop - n_loc * 4
    assert b2 == 2 * b1


def test_sparse_halfring_halves_csr_hops(mesh8):
    """The wire-halving is schedule-level: the CSR triple rides it too."""
    sp = from_dense(_dense(128, 1024, 0.05, seed=2))
    with CommLog() as log:
        apss_horizontal(sp, T, K, mesh8, "data", schedule="ring", block_rows=16)
        apss_horizontal(sp, T, K, mesh8, "data", schedule="halfring", block_rows=16)
    ring, half = log.records
    assert (
        half.bytes_by_payload()["csr_block"] * (P8 - 1)
        == ring.bytes_by_payload()["csr_block"] * (P8 // 2)
    )


def test_hierarchical_hop_economy():
    """Nested ring: same p-1 total block hops as a flat ring, but the slow
    (outer/pod) axis carries only s_outer - 1 of them."""
    from repro.compat import make_mesh

    D = jnp.asarray(_dense(128, 256, 0.3, seed=3))
    mesh = make_mesh((2, 4), ("pod", "data"))
    with CommLog() as log:
        apss_horizontal_hierarchical(
            D, T, K, mesh, ("pod", "data"), block_rows=16
        )
    rec = log.last
    by_axis = {h.axis: h.hops for h in rec.hops}
    assert by_axis == {"pod": 1, "data": 6}  # (2-1)·1 and (4-1)·2
    assert sum(by_axis.values()) == P8 - 1   # flat-ring total, redistributed


def test_raising_call_records_nothing():
    """Validation runs BEFORE the telemetry record: a rejected call must
    not log wire bytes for an execution that never happened."""
    from repro.compat import make_mesh

    sp = from_dense(_dense(128, 96, 0.2, seed=9))
    mesh = make_mesh((2, 4), ("pod", "data"))
    with CommLog() as log:
        with pytest.raises(ValueError):
            apss_horizontal_hierarchical(
                sp, T, K, mesh, ("pod", "data"), use_kernel=True
            )
    assert log.records == []


def test_blocked_live_fraction_recorded():
    """The sparse worklist path reports its live-tile fraction + histogram
    (no extra device work: the worklist is already host-materialized)."""
    sp = from_dense(_dense(1024, 512, 0.02, seed=4))
    with CommLog() as log:
        apss_blocked(sp, 0.5, K, block_rows=128, use_kernel=True)
    rec = log.last
    assert rec.variant == "blocked/sparse-kernel"
    assert rec.live_tiles is not None and rec.total_tiles == 8 * 8
    assert 0.0 <= rec.live_fraction <= 1.0
    assert len(rec.tile_counts) == 8
    assert rec.imbalance >= 1.0


def test_serving_query_records_live_fraction():
    from repro.serving import build_index, query_topk

    sp = from_dense(_dense(256, 512, 0.05, seed=5))
    index = build_index(sp, block_rows=64, normalize=False)
    Q = _dense(8, 512, 0.05, seed=6)
    with CommLog() as log:
        query_topk(index, jnp.asarray(Q), 0.4, K)
    rec = log.last
    assert rec.variant == "serving/query"
    assert rec.total_tiles == 1 * (256 // 64)  # one query block × 4 corpus blocks
    assert rec.live_tiles is not None and 0 <= rec.live_tiles <= rec.total_tiles
    assert rec.extra["batch"] == 8


def test_sparse_2d_composed_wire_volume():
    """Composed 2-D schedule: the row-axis ring ships the per-cell CSR pair
    exactly ``q-1`` times at ``csr_cell_bytes(n_loc, cap_loc)`` per hop
    (``cap_loc`` the REALIZED post-split width, not the global cap); the
    inner accumulation hops are representation-agnostic — identical to the
    dense record's — and run once per ring step (hop counts ×q)."""
    from repro.compat import make_mesh
    from repro.core.distributed import apss_2d
    from repro.core.sparse import shard_dims
    from repro.planner.telemetry import csr_cell_bytes

    D = _dense(128, 1024, 0.05, seed=8)
    sp = from_dense(D)
    mesh = make_mesh((4, 2), ("data", "model"))
    q, r, n_loc = 4, 2, 32
    cap_loc = shard_dims(sp, r)[0].shape[-1]
    with CommLog() as log:
        apss_2d(
            jnp.asarray(D), T, K, mesh, accumulation="compressed",
            block_rows=16,
        )
        apss_2d(sp, T, K, mesh, accumulation="compressed", block_rows=16)
    dense_rec, sparse_rec = log.records
    assert sparse_rec.sparse and sparse_rec.extra["cap_loc"] == cap_loc

    (ring,) = [h for h in sparse_rec.hops if h.payload == "csr_cell"]
    assert ring.op == "ppermute" and ring.axis == "data"
    assert ring.hops == q - 1
    assert ring.bytes_per_hop == csr_cell_bytes(n_loc, cap_loc)

    inner_s = [h for h in sparse_rec.hops if h.payload != "csr_cell"]
    inner_d = [h for h in dense_rec.hops if h.payload != "dense_block"]
    assert inner_s == inner_d  # candidate ids/scores never carry the corpus
    assert inner_s and all(h.hops % q == 0 for h in inner_s)

    # The sparse cell undercuts the dense cell's (n_loc, m/r) payload.
    db = dense_rec.bytes_by_payload()["dense_block"]
    sb = sparse_rec.bytes_by_payload()["csr_cell"]
    assert sb < 0.25 * db  # 5% density: 8·cap_loc ≪ 4·m/r


def test_vertical_compressed_vs_allreduce_volume(mesh8_model):
    """Lemma-1 compaction: the compressed accumulation's collective volume
    is O(p·C) per row vs the allreduce's O(n) — the paper's 10-100× score
    volume reduction, visible in the recorded bytes."""
    D = jnp.asarray(_dense(128, 96, 0.3, seed=7))
    from repro.core.distributed import apss_vertical

    with CommLog() as log:
        apss_vertical(
            D, T, K, mesh8_model, accumulation="allreduce", block_rows=32
        )
        apss_vertical(
            D, T, K, mesh8_model, accumulation="compressed", block_rows=32,
            candidate_capacity=8,
        )
    allred, comp = log.records
    assert comp.wire_bytes < allred.wire_bytes


def test_2d_step_times_one_entry_per_ring_step(mesh4x2):
    """ISSUE 6 satellite: the checkerboard sweep's StepTicker lands one
    per-step wall time in the telemetry record — q entries for a q-step
    row-axis ring — and every (row, col) rank ticks every step."""
    import jax

    D = jnp.asarray(_dense(128, 96, 0.3, seed=11))
    q = mesh4x2.shape["data"]
    with CommLog() as log:
        m = apss_2d(D, T, K, mesh4x2, block_rows=16)
        jax.block_until_ready(m.values)
    rec = log.last
    times = rec.step_times
    assert times is not None and len(times) == q
    assert all(t > 0 for t in times[1:])  # step 0 absorbs compile time
    ticker = rec.step_ticker
    assert ticker.n_steps == q
    ranks = {r for r, _, _ in ticker.ticks}
    assert ranks == set(range(8))
    # the ledger bridge: per-rank deltas feed StragglerReport
    report = ticker.to_step_timer().report()
    assert set(report.rank_ema) == set(range(8))


def test_hierarchical_step_times_count_all_computes():
    """Nested (2, 4) ring: ∏sizes = 8 computes per rank, numbered by the
    traced step counter riding the carry."""
    import jax

    from repro.compat import make_mesh

    D = jnp.asarray(_dense(128, 96, 0.3, seed=12))
    mesh = make_mesh((2, 4), ("pod", "data"))
    with CommLog() as log:
        m = apss_horizontal_hierarchical(
            D, T, K, mesh, ("pod", "data"), block_rows=16
        )
        jax.block_until_ready(m.values)
    times = log.last.step_times
    assert times is not None and len(times) == 8


def test_step_times_none_without_ticker():
    """Variants that don't wire a ticker report None, not garbage."""
    D = jnp.asarray(_dense(64, 96, 0.3, seed=13))
    with CommLog() as log:
        apss_blocked(D, T, K, block_rows=32)
    assert log.last.step_times is None


def test_counter_seam_scopes_to_active_logs():
    from repro.planner import telemetry as tm

    tm.incr("x.y")  # no active log: no-op, no error
    with CommLog() as outer:
        with CommLog() as inner:
            tm.incr("x.y", 2)
        tm.incr("x.y")
    assert inner.counters["x.y"] == 2
    assert outer.counters["x.y"] == 3
