"""Hypothesis-driven metamorphic properties for MutableAPSSIndex.

The same invariant as ``tests/test_mutable_index.py`` — any interleaving
of append/delete/query/compact is bit-indistinguishable from a fresh
rebuild over the surviving rows — but with hypothesis searching the op
space instead of a handful of fixed seeds. Skips cleanly where hypothesis
is not installed (the fixed-seed twin still covers the property).

Ops are drawn as small integer codes + seeds; the actual row data comes
from a seeded numpy Generator so examples shrink to minimal op sequences,
not minimal float arrays.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.serving import MutableAPSSIndex  # noqa: E402

T = 0.15
K = 6
M = 16
CAP = 12

# (op_code, arg): 0 = append `arg+1` rows, 1 = delete `arg+1` live rows,
# 2 = compact. Deletes/compacts on an empty index degrade to appends.
_OPS = st.lists(
    st.tuples(st.integers(0, 2), st.integers(0, 5)),
    min_size=1,
    max_size=8,
)


def _rows(rng, n, sparse):
    D = rng.normal(size=(n, M)).astype(np.float32)
    if sparse:
        mask = rng.random((n, M)) < 0.3
        mask[np.arange(n), rng.integers(0, M, n)] = True
        D = np.where(mask, D, 0.0).astype(np.float32)
    return D


def _check(mi, model, Q, kind):
    gids = np.asarray([g for g, _ in model], np.int64)
    D = (
        np.stack([r for _, r in model])
        if model
        else np.zeros((0, M), np.float32)
    )
    oracle = MutableAPSSIndex(
        D if model else None, threshold=T, k=K, kind=kind, cap=CAP
    )
    mg, g = mi.graph()
    assert np.array_equal(mg, gids)
    if model:
        _, og = oracle.graph()
        ti = np.where(og.indices >= 0, gids[np.maximum(og.indices, 0)], -1)
        assert np.array_equal(g.values, og.values)
        assert np.array_equal(g.indices, ti)
        assert np.array_equal(g.counts, og.counts)
    r, ro = mi.query(Q), oracle.query(Q)
    assert np.array_equal(r.values, ro.values)
    assert np.array_equal(r.counts, ro.counts)
    if model:
        ti = np.where(ro.indices >= 0, gids[np.maximum(ro.indices, 0)], -1)
        assert np.array_equal(r.indices, ti)


@settings(max_examples=12, deadline=None)
@given(ops=_OPS, seed=st.integers(0, 2**16), sparse=st.booleans())
def test_any_mutation_sequence_equals_fresh_rebuild(ops, seed, sparse):
    rng = np.random.default_rng(seed)
    kind = "sparse" if sparse else "dense"
    Q = _rows(rng, 3, sparse)
    mi = MutableAPSSIndex(threshold=T, k=K, kind=kind, cap=CAP)
    model = []
    for code, arg in ops:
        live = [g for g, _ in model]
        if code == 0 or not live:
            raw = _rows(rng, arg + 1, sparse)
            model += list(zip(mi.append(raw), raw))
        elif code == 1:
            n_del = min(arg + 1, len(live))
            victims = sorted(
                int(g) for g in rng.choice(live, n_del, replace=False)
            )
            mi.delete(victims)
            model = [(g, r) for g, r in model if g not in set(victims)]
        else:
            mi.compact()
        _check(mi, model, Q, kind)
