"""Model-vs-HLO audit (ISSUE 9 tentpole): coverage of every plannable
variant family, the dense FLOP-ratio gate, the sparse-intermediate note,
and the drift feed. One full ``run_audit`` (module fixture) backs every
assertion — the audit itself is the expensive part, not the checks."""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import json

import pytest

from repro.obs import drift
from repro.obs.audit import FLOP_RATIO_BAND, GATED_FAMILIES, run_audit


@pytest.fixture(scope="module")
def report():
    return run_audit()  # defaults: n=64, m=64, 8 virtual devices


def test_audit_covers_every_plannable_family(report):
    fams = set(report.families())
    # Single-device + 1-axis mesh families, both representations.
    for rep in ("dense", "sparse"):
        assert f"blocked[{rep}]" in fams
        for sched in ("allgather", "ring", "halfring"):
            assert f"horizontal/{sched}[{rep}]" in fams
        for acc in ("allreduce", "scatter", "compressed", "recursive"):
            assert f"vertical/{acc}[{rep}]" in fams
        # 2-axis mesh families.
        assert f"hierarchical[{rep}]" in fams
        for acc in ("allreduce", "compressed"):
            assert f"2d/{acc}[{rep}]" in fams
    # Serving + mutable delta-join entries from captured real call sites.
    assert "serving.query_topk[dense]" in fams
    assert "serving.query_topk[sparse]" in fams
    assert "mutable.delta_join[dense]" in fams


def test_dense_blocked_and_ring_flops_within_band(report):
    """The acceptance bar: HLO-derived FLOPs of the dense blocked and
    dense ring families within 1.5x of the telemetry model."""
    for fam in GATED_FAMILIES:
        r = report.entry(fam).flop_ratio
        assert r is not None, fam
        assert 1.0 / FLOP_RATIO_BAND <= r <= FLOP_RATIO_BAND, (fam, r)
    assert report.gated_ok()


def test_collective_link_bytes_match_wire_model(report):
    """The ring's wire volume: HLO link bytes within 2x of the hop-model
    prediction (same per-device convention on both sides)."""
    e = report.entry("horizontal/ring[dense]")
    assert e.predicted_link_bytes > 0 and e.hlo_link_bytes > 0
    assert 0.5 <= e.link_ratio <= 2.0, e.link_ratio


def test_sparse_blocked_quantifies_scan_intermediate(report):
    """The documented gap: the sparse XLA scan's (T, block, S) gathered
    slab is quantified in the entry notes (ROADMAP in-kernel gather)."""
    e = report.entry("blocked[sparse]")
    assert any("gather intermediate" in n and "ROADMAP" in n
               for n in e.notes), e.notes


def test_every_entry_carries_compile_record(report):
    for e in report.entries:
        assert e.record.t_compile_s > 0, e.family
        assert e.record.argument_bytes > 0, e.family
        assert e.hlo_flops > 0, e.family


def test_residuals_feed_drift_as_audit_source(report):
    res = report.residuals()
    assert len(res) == len(report.entries)
    assert all(r.source == "audit" for r in res)
    rep = drift.drift_report(res, band=4.0)
    # every audited family appears; the dense families anchor the median
    assert set(rep.per_variant) == set(report.families())
    assert rep.per_variant["blocked[dense]"] == pytest.approx(
        report.entry("blocked[dense]").flop_ratio
    )


def test_report_serializes(report):
    d = report.as_dict()
    text = json.dumps(d)  # fully JSON-ready
    assert "gated_ok" in d and d["entries"]
    assert "flop_ratio" in d["entries"][0]
    assert len(text) > 100
    desc = report.describe()
    assert "blocked[dense]" in desc and "gate[" in desc
