"""Sparse APSS subsystem: representation roundtrips, inverted-index pruning
soundness, and sparse↔dense exactness of every scoring path (single-device
XLA + Pallas worklist kernel + all distributed sparse variants) across
densities, empty rows, duplicate coordinates, and non-tile-multiple shapes.

The contract under test (DESIGN.md §5): every sparse path produces the
identical ``match_set`` and exact ``counts`` as ``apss_reference`` on the
densified corpus.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.apss import (
    apss_blocked,
    apss_reference,
    normalize_rows,
    similarity_topk,
)
from repro.core.graph import match_set
from repro.core.pruning import (
    sparse_block_prune_mask,
    sparse_block_support,
    sparse_candidate_mask,
)
from repro.core.sparse import (
    SparseCorpus,
    density,
    from_dense,
    normalize_sparse,
    pad_rows_sparse,
    shard_dims,
    sparse_similarity_topk,
    to_dense,
)
from repro.data.sparse import sparse_clustered_corpus, sparse_zipfian_corpus
from repro.kernels.apss_block.sparse import apss_sparse_compacted

T, K = 0.3, 16


def _dense_corpus(n, m, dens, seed=0, empty_rows=()):
    rng = np.random.default_rng(seed)
    D = np.abs(rng.standard_normal((n, m))).astype(np.float32)
    D *= rng.random((n, m)) < dens
    for r in empty_rows:
        D[r] = 0
    return np.asarray(normalize_rows(jnp.asarray(D)))


def _check(got, ref):
    assert match_set(got) == match_set(ref)
    np.testing.assert_array_equal(np.asarray(got.counts), np.asarray(ref.counts))


# -- representation -----------------------------------------------------------


def test_from_dense_to_dense_roundtrip():
    D = _dense_corpus(40, 64, 0.2, seed=1, empty_rows=(3, 39))
    sp = from_dense(D)
    np.testing.assert_allclose(np.asarray(to_dense(sp)), D, rtol=1e-6)
    assert sp.shape == D.shape
    assert np.asarray(sp.nnz).sum() == (D != 0).sum()


def test_duplicate_coordinates_sum_in_to_dense():
    # COO convention: duplicate (row, dim) slots sum.
    sp = SparseCorpus(
        jnp.asarray([[2, 2, 5], [0, 0, 0]], jnp.int32),
        jnp.asarray([[1.0, 2.0, 4.0], [3.0, 0.0, 0.0]], jnp.float32),
        jnp.asarray([3, 1], jnp.int32),
        m=8,
    )
    d = np.asarray(to_dense(sp))
    assert d[0, 2] == 3.0 and d[0, 5] == 4.0 and d[1, 0] == 3.0


def test_generators_are_normalized_and_never_dense():
    for sp in (
        sparse_zipfian_corpus(50, 4096, 6, seed=2),
        sparse_clustered_corpus(50, 4096, 6, n_clusters=16, seed=2),
    ):
        # O(n · cap) memory: capacity tracks realized nnz, not m.
        assert sp.cap < 64 < sp.m
        nrm = np.linalg.norm(np.asarray(to_dense(sp)), axis=1)
        np.testing.assert_allclose(nrm, 1.0, rtol=1e-5)
        assert 0 < density(sp) < 0.02


def test_normalize_sparse_matches_dense_normalize():
    D = _dense_corpus(16, 32, 0.3, seed=4) * 5.0
    sp = normalize_sparse(from_dense(D))
    np.testing.assert_allclose(
        np.asarray(to_dense(sp)),
        np.asarray(normalize_rows(jnp.asarray(D))),
        rtol=1e-5,
    )


def test_shard_dims_partition_is_lossless():
    D = _dense_corpus(24, 48, 0.3, seed=5)
    sp = from_dense(D)
    idx_s, val_s, nnz_s, m_loc = shard_dims(sp, 4)
    assert m_loc == 12
    back = np.zeros_like(D)
    for d in range(4):
        loc = SparseCorpus(
            jnp.asarray(idx_s[d]), jnp.asarray(val_s[d]),
            jnp.asarray(nnz_s[d]), m_loc,
        )
        back[:, d * m_loc:(d + 1) * m_loc] += np.asarray(to_dense(loc))
    np.testing.assert_allclose(back, D, rtol=1e-6)


# -- inverted-index candidate generation + sparse bounds ----------------------


def test_sparse_candidate_mask_is_support_intersection():
    sp = sparse_clustered_corpus(64, 256, 6, n_clusters=8, seed=3)
    spp, _ = pad_rows_sparse(sp, 8)
    sup = sparse_block_support(spp, 8)
    cand = np.asarray(sparse_candidate_mask(sup, sup))
    want = (np.asarray(sup).astype(np.int32) @ np.asarray(sup).T) > 0
    np.testing.assert_array_equal(cand, want)
    # 8 disjoint dimension bands over 8 row blocks ⇒ only diagonal tiles.
    assert cand.sum() < cand.size


def test_sparse_prune_mask_sound_and_matches_dense_bound():
    D = _dense_corpus(64, 96, 0.15, seed=6)
    sp = from_dense(D)
    b = 8
    mask = np.asarray(sparse_block_prune_mask(sp, sp, T, b))
    S = D @ D.T
    for i in range(64 // b):
        for j in range(64 // b):
            if not mask[i, j]:
                blk = S[i * b:(i + 1) * b, j * b:(j + 1) * b].copy()
                if i == j:
                    np.fill_diagonal(blk, 0.0)
                assert blk.max() < T  # pruned ⇒ provably matchless


# -- single-device exactness --------------------------------------------------


@pytest.mark.parametrize("dens", [0.001, 0.01, 0.1])
def test_blocked_sparse_exact_across_densities(dens):
    sp = sparse_zipfian_corpus(96, 2048, max(2, dens * 2048), seed=7)
    ref = apss_reference(to_dense(sp), T, K)
    _check(sparse_similarity_topk(sp, sp, T, K, block_rows=32, exclude_self=True), ref)
    _check(apss_blocked(sp, T, K, block_rows=32), ref)


@pytest.mark.parametrize("use_kernel", [False, True])
def test_sparse_compacted_exact(use_kernel):
    sp = sparse_clustered_corpus(96, 512, 8, n_clusters=8, seed=8)
    ref = apss_reference(to_dense(sp), 0.4, K)
    got = apss_sparse_compacted(
        sp, 0.4, K, block_m=16, lane_pad=8, use_kernel=use_kernel
    )
    _check(got, ref)


@pytest.mark.parametrize("n", [33, 96, 100])  # non-tile-multiple shapes
def test_sparse_compacted_ragged_shapes(n):
    D = _dense_corpus(n, 80, 0.15, seed=n, empty_rows=(0, n - 1))
    sp = from_dense(D)
    ref = apss_reference(jnp.asarray(D), T, K)
    _check(apss_sparse_compacted(sp, T, K, block_m=16, lane_pad=8), ref)
    _check(sparse_similarity_topk(sp, sp, T, K, block_rows=16, exclude_self=True), ref)


def test_sparse_compacted_all_pruned_returns_empty():
    sp = from_dense(_dense_corpus(32, 64, 0.1, seed=9))
    got = apss_sparse_compacted(sp, 1.5, K, block_m=16, lane_pad=8)
    assert int(np.asarray(got.counts).sum()) == 0
    assert (np.asarray(got.indices) == -1).all()


def test_sparse_rectangular_join_with_offsets():
    Q = from_dense(_dense_corpus(24, 64, 0.2, seed=10))
    C = from_dense(_dense_corpus(40, 64, 0.2, seed=11))
    S = np.asarray(to_dense(Q)) @ np.asarray(to_dense(C)).T
    got = similarity_topk(Q, C, T, K, block_rows=16, col_offset=100)
    counts = (S >= T).sum(axis=1)
    np.testing.assert_array_equal(np.asarray(got.counts), counts)
    idx = np.asarray(got.indices)
    for r in range(24):
        want = {int(c) + 100 for c in np.nonzero(S[r] >= T)[0]}
        assert set(idx[r][idx[r] >= 0]) == want


def test_blocked_sparse_prune_stats():
    sp = sparse_clustered_corpus(64, 512, 8, n_clusters=8, seed=12)
    m, stats = apss_blocked(sp, 0.4, K, block_rows=16, with_prune_stats=True)
    _check(m, apss_reference(to_dense(sp), 0.4, K))
    assert 0 < int(stats.live_blocks) <= int(stats.total_blocks)


def test_sparse_use_kernel_rejects_rectangular():
    Q = from_dense(_dense_corpus(16, 32, 0.3, seed=13))
    with pytest.raises(ValueError):
        similarity_topk(Q, Q, T, K, use_kernel=True)


# -- distributed sparse variants ----------------------------------------------


@pytest.fixture(scope="module")
def sparse128():
    D = _dense_corpus(128, 96, 0.15, seed=14, empty_rows=(17,))
    return from_dense(D), apss_reference(jnp.asarray(D), T, K)


@pytest.mark.parametrize("schedule", ["allgather", "ring", "halfring"])
def test_sparse_horizontal_exact(mesh8, sparse128, schedule):
    from repro.core.distributed import apss_horizontal

    sp, ref = sparse128
    got = apss_horizontal(sp, T, K, mesh8, "data", schedule=schedule, block_rows=16)
    _check(got, ref)


def test_sparse_halfring_ring_parity(mesh8, sparse128):
    """The CSR triple travels the halfring caravan exactly like dense
    blocks: match-for-match parity with the sparse ring (ROADMAP item)."""
    from repro.core.distributed import apss_horizontal

    sp, _ = sparse128
    ring = apss_horizontal(sp, T, K, mesh8, "data", schedule="ring", block_rows=16)
    half = apss_horizontal(
        sp, T, K, mesh8, "data", schedule="halfring", block_rows=16
    )
    assert match_set(half) == match_set(ring)
    np.testing.assert_array_equal(
        np.asarray(half.counts), np.asarray(ring.counts)
    )
    np.testing.assert_allclose(
        np.sort(np.asarray(half.values), axis=-1),
        np.sort(np.asarray(ring.values), axis=-1),
        atol=1e-6,
    )


def test_sparse_halfring_odd_device_count(sparse128):
    """Odd p exercises the final-offset backward orientation (the even-p
    schedule skips it)."""
    import jax

    from repro.core.distributed import apss_horizontal
    from repro.core.sparse import pad_rows_sparse

    sp, ref = sparse128
    devs = jax.devices()[:5]
    mesh = jax.sharding.Mesh(np.array(devs), ("data",))
    spp, n = pad_rows_sparse(sp, 5)  # 128 → 130 rows over 5 devices
    got = apss_horizontal(
        spp, T, K, mesh, "data", schedule="halfring", block_rows=13
    )
    got = jax.tree.map(lambda x: x[:n], got)
    _check(got, ref)


@pytest.mark.parametrize(
    "accumulation", ["allreduce", "scatter", "compressed", "recursive"]
)
def test_sparse_vertical_exact(mesh8_model, sparse128, accumulation):
    from repro.core.distributed import apss_vertical

    sp, ref = sparse128
    got = apss_vertical(
        sp, T, K, mesh8_model, "model", accumulation=accumulation, block_rows=16
    )
    _check(got, ref)


def test_sparse_2d_exact(mesh4x2, sparse128):
    """The last cell of the variant matrix: sparse ring ∘ posting-list-
    sharded accumulation (full coverage in tests/test_sparse_2d.py)."""
    from repro.core.distributed import apss_2d

    sp, ref = sparse128
    got = apss_2d(
        sp, T, K, mesh4x2, accumulation="compressed", block_rows=16,
        candidate_capacity=128,
    )
    _check(got, ref)


def test_sparse_hierarchical_exact(sparse128):
    """ROADMAP item closed: the CSR triple rides the nested pod ring."""
    from repro.compat import make_mesh
    from repro.core.distributed import apss_horizontal_hierarchical

    sp, ref = sparse128
    mesh = make_mesh((2, 4), ("pod", "data"))
    got = apss_horizontal_hierarchical(
        sp, T, K, mesh, ("pod", "data"), block_rows=16
    )
    _check(got, ref)


def test_sparse_hierarchical_3level_exact(sparse128):
    from repro.compat import make_mesh
    from repro.core.distributed import apss_horizontal_hierarchical

    sp, ref = sparse128
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    got = apss_horizontal_hierarchical(
        sp, T, K, mesh, ("pod", "data", "model"), block_rows=16
    )
    _check(got, ref)


def test_sparse_hierarchical_ring_parity(mesh8, sparse128):
    """The nested pod ring visits the same (local, visiting) block pairs as
    the flat sparse ring, just in hierarchical hop order: match-for-match
    parity, row-aligned (both shard rows in flat row-major rank order)."""
    from repro.compat import make_mesh
    from repro.core.distributed import apss_horizontal, apss_horizontal_hierarchical

    sp, _ = sparse128
    ring = apss_horizontal(sp, T, K, mesh8, "data", schedule="ring", block_rows=16)
    mesh = make_mesh((2, 4), ("pod", "data"))
    hier = apss_horizontal_hierarchical(
        sp, T, K, mesh, ("pod", "data"), block_rows=16
    )
    assert match_set(hier) == match_set(ring)
    np.testing.assert_array_equal(
        np.asarray(hier.counts), np.asarray(ring.counts)
    )
    np.testing.assert_allclose(
        np.sort(np.asarray(hier.values), axis=-1),
        np.sort(np.asarray(ring.values), axis=-1),
        atol=1e-6,
    )


# -- adversarial CSR structure (shared with tests/test_sparse_properties.py) --


def random_csr(seed, n, m, cap, *, dup_prob=0.3, empty_prob=0.2):
    """Raw CSR with adversarial structure: duplicate coordinates (which by
    convention sum) and empty rows, not necessarily normalized."""
    rng = np.random.default_rng(seed)
    nnz = rng.integers(0, cap + 1, size=n).astype(np.int32)
    nnz[rng.random(n) < empty_prob] = 0
    idx = rng.integers(0, m, size=(n, cap)).astype(np.int32)
    if rng.random() < dup_prob and cap > 1:
        idx[:, 1] = idx[:, 0]  # force duplicates in every non-trivial row
    val = (rng.random((n, cap)).astype(np.float32) * 0.8).astype(np.float32)
    mask = np.arange(cap)[None, :] < nnz[:, None]
    return SparseCorpus(
        jnp.asarray(np.where(mask, idx, 0)),
        jnp.asarray(np.where(mask, val, 0.0)),
        jnp.asarray(nnz),
        m,
    )


def test_duplicate_concentration_is_not_pruned():
    """Regression: per-slot maxweight under-bounds duplicates. Row 0 stores
    dim 3 as two 0.5 slots (effective weight 1.0); a per-slot max of 0.5
    would prune the cross-block tile at t=0.8 and silently drop the match."""
    idx = np.zeros((32, 2), np.int32)
    val = np.zeros((32, 2), np.float32)
    idx[0] = [3, 3]; val[0] = [0.5, 0.5]   # block 0: effective (3, 1.0)
    idx[16] = [3, 0]; val[16] = [1.0, 0.0]  # block 1
    sp = SparseCorpus(
        jnp.asarray(idx), jnp.asarray(val),
        jnp.asarray([2] + [0] * 15 + [1] + [0] * 15, dtype=jnp.int32), m=8,
    )
    t = 0.8
    mask = np.asarray(sparse_block_prune_mask(sp, sp, t, 16))
    assert mask[0, 1] and mask[1, 0]  # the tile with the true match is live
    ref = apss_reference(to_dense(sp), t, 4)
    assert int(np.asarray(ref.counts).sum()) == 2
    _check(apss_sparse_compacted(sp, t, 4, block_m=16, lane_pad=8), ref)
    _check(
        apss_sparse_compacted(sp, t, 4, block_m=16, lane_pad=8, use_kernel=True),
        ref,
    )


def test_negative_threshold_keeps_zero_similarity_pairs():
    """Regression: at t ≤ 0 zero-similarity pairs (disjoint support) match;
    an explicit support-intersection conjunct in the prune mask would
    unsoundly drop them."""
    D = np.zeros((32, 16), np.float32)
    D[:16, 0] = 1.0   # block 0 uses dim 0 only
    D[16:, 8] = 1.0   # block 1 uses dim 8 only — zero sim across blocks
    sp = from_dense(D)
    t = -0.5
    mask = np.asarray(sparse_block_prune_mask(sp, sp, t, 16))
    assert mask.all()
    ref = apss_reference(jnp.asarray(D), t, 32)
    _check(apss_sparse_compacted(sp, t, 32, block_m=16, lane_pad=8), ref)
    _check(sparse_similarity_topk(sp, sp, t, 32, block_rows=16, exclude_self=True), ref)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_adversarial_csr_join_equals_dense_reference(seed):
    """Non-hypothesis twin of the property test: duplicates + empty rows +
    ragged n, fixed seeds (runs even where hypothesis is absent)."""
    sp = random_csr(seed, 20 + 7 * seed, 40, 6)
    ref = apss_reference(to_dense(sp), 0.3, 32)
    _check(
        sparse_similarity_topk(sp, sp, 0.3, 32, block_rows=16, exclude_self=True),
        ref,
    )
    _check(apss_sparse_compacted(sp, 0.3, 32, block_m=16, lane_pad=8), ref)
