"""Compile-time observability: retrace registry, no-retrace contracts,
AOT lower/compile records, call-site capture, metrics merge (ISSUE 9)."""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import glob

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs import FlightRecorder, MetricsRegistry, RetraceError
from repro.obs import compile as obs_compile
from repro.obs.compile import CompileMonitor
from repro.obs.metrics import Histogram
from repro.obs.trace import Tracer


# -- registry ----------------------------------------------------------------


def test_mark_counts_and_snapshot_is_a_copy():
    mon = CompileMonitor()
    mon.mark("a")
    mon.mark("a")
    mon.mark("b")
    snap = mon.snapshot()
    assert snap == {"a": 2, "b": 1}
    snap["a"] = 99
    assert mon.counts["a"] == 2  # snapshot is detached


def test_serving_trace_counts_aliases_the_public_registry():
    """Back-compat: the legacy TRACE_COUNTS name IS the monitor's Counter."""
    from repro.serving import query

    assert query.TRACE_COUNTS is obs_compile.MONITOR.counts


def test_registered_groups_resolve_to_entry_points():
    mon = CompileMonitor()
    mon.register_entry_points("grp", "x", "y")
    c = mon.assert_no_retrace("grp", "z")
    assert c.names == ("x", "y", "z")
    assert obs_compile.entry_points("serving.query")  # registered on import
    assert obs_compile.entry_points("serving.mutable")


# -- contracts ---------------------------------------------------------------


def test_contract_passes_when_nothing_retraces():
    mon = CompileMonitor()
    mon.mark("warm")
    with mon.assert_no_retrace("warm"):
        pass  # no marks inside


def test_contract_raises_at_mark_time():
    mon = CompileMonitor()
    with pytest.raises(RetraceError, match="'hot'"):
        with mon.assert_no_retrace("hot"):
            mon.mark("hot")


def test_contract_watches_everything_when_unnamed():
    mon = CompileMonitor()
    with pytest.raises(RetraceError):
        with mon.assert_no_retrace():
            mon.mark("anything-at-all")


def test_contract_ignores_unwatched_names():
    mon = CompileMonitor()
    with mon.assert_no_retrace("only-this"):
        mon.mark("something-else")


def test_contract_exit_catches_direct_counter_bumps():
    """Legacy `TRACE_COUNTS[x] += 1` bypasses mark(); the exit check
    still catches it via the shared Counter object."""
    mon = CompileMonitor()
    with pytest.raises(RetraceError):
        with mon.assert_no_retrace("legacy"):
            mon.counts["legacy"] += 1


def test_shape_varying_call_trips_contract_and_dumps_flight_record(tmp_path):
    """The acceptance scenario: a jitted entry point warmed at one shape,
    then fed a new shape under an active contract — RetraceError at the
    call, with a flight record dumped for the post-mortem."""
    mon = CompileMonitor()

    @jax.jit
    def entry(x):
        mon.mark("entry")  # trace-time side effect == compilation count
        return x * 2.0

    entry(jnp.zeros((4,)))  # warm at shape (4,)
    with FlightRecorder(directory=str(tmp_path)) as fr:
        with mon.assert_no_retrace("entry"):
            entry(jnp.zeros((4,)))  # cache hit: fine
            with pytest.raises(RetraceError, match="entry"):
                entry(jnp.zeros((8,)))  # new shape: re-trace
    assert fr.dumps and fr.dumps[0][0] == "compile.retrace.entry"
    files = glob.glob(str(tmp_path / "flight_*compile*retrace*entry*.json"))
    assert files, "expected a flight_NNN_compile.retrace.entry dump on disk"


def test_query_topk_hot_path_contract_is_active():
    """Public-API version of the serving no-retrace discipline: warm
    query_topk, then assert the whole serving.query group under a
    contract — and show a shape-breaking query WOULD trip it."""
    from repro.data.sparse import perturbed_queries, sparse_clustered_corpus
    from repro.serving import build_index, query_topk

    sp = sparse_clustered_corpus(128, 64, 6.0, n_clusters=4, seed=0)
    index = build_index(sp, block_rows=32, normalize=False)
    Q = perturbed_queries(sp, 4, seed=1)
    query_topk(index, Q, 0.3, 4)
    with obs_compile.assert_no_retrace("serving.query"):
        query_topk(index, Q, 0.3, 4)  # repeat: no new traces
    with pytest.raises(RetraceError):
        with obs_compile.assert_no_retrace("serving.query"):
            # block_q is a static argument: a new value MUST re-trace
            query_topk(index, Q, 0.3, 4, block_q=16)


def test_mutable_append_delete_contract_is_active():
    from repro.serving.mutable import MutableAPSSIndex

    rng = np.random.default_rng(0)

    def rows(n):
        X = np.abs(rng.standard_normal((n, 32))).astype(np.float32)
        return X / np.linalg.norm(X, axis=1, keepdims=True)

    mi = MutableAPSSIndex(rows(16), threshold=0.2, k=4, block_rows=64)
    Q = rows(4)
    for _ in range(2):  # warm every delta-join/query/delete shape once
        mi.append(rows(8))
        mi.query(Q)
        mi.delete([int(mi.graph()[0][0])])
    with obs_compile.assert_no_retrace("serving.mutable"):
        mi.append(rows(8))
        mi.query(Q)
        mi.delete([int(mi.graph()[0][0])])


# -- AOT lower/compile -------------------------------------------------------


def test_lower_and_compile_records_times_and_memory():
    mon = CompileMonitor()

    @jax.jit
    def f(x):
        return (x @ x.T).sum()

    with Tracer() as tr:
        compiled, rec = mon.lower_and_compile(
            f, jnp.ones((16, 8)), name="matmul16x8"
        )
    assert rec.name == "matmul16x8"
    assert rec.t_lower_s >= 0 and rec.t_compile_s > 0
    assert rec.total_bytes == (
        rec.argument_bytes + rec.output_bytes + rec.temp_bytes
    )
    assert rec.argument_bytes >= 16 * 8 * 4  # the input buffer at least
    assert mon.records == [rec]
    assert float(compiled(jnp.ones((16, 8)))) == pytest.approx(16 * 16 * 8)
    spans = [s.name for s in tr.walk()]
    assert "compile/matmul16x8" in spans
    d = rec.as_dict()
    assert d["total_bytes"] == rec.total_bytes


# -- call-site capture -------------------------------------------------------


def test_capture_calls_first_offer_wins_and_nests():
    obs_compile.offer_capture("x", None)  # no context: dropped
    with obs_compile.capture_calls() as outer:
        obs_compile.offer_capture("x", "first", 1, a=2)
        obs_compile.offer_capture("x", "second")
        with obs_compile.capture_calls() as inner:
            obs_compile.offer_capture("x", "inner-first")
        obs_compile.offer_capture("y", "why")
    assert outer["x"].fn == "first"
    assert outer["x"].args == (1,) and outer["x"].kwargs == {"a": 2}
    assert outer["y"].fn == "why"
    assert inner["x"].fn == "inner-first"
    assert obs_compile._CAPTURE is None  # context fully unwound


def test_captured_serving_call_lowers_to_the_real_program():
    """The audit seam end-to-end: capture the query inner from a real
    query_topk call, AOT-compile it, and check the compiled program
    reproduces the hot path's scores."""
    from repro.data.sparse import perturbed_queries, sparse_clustered_corpus
    from repro.serving import build_index, query_topk

    sp = sparse_clustered_corpus(128, 64, 6.0, n_clusters=4, seed=3)
    index = build_index(sp, block_rows=32, normalize=False)
    Q = perturbed_queries(sp, 4, seed=4)
    with obs_compile.capture_calls() as calls:
        got = query_topk(index, Q, 0.3, 4)
    assert "serving.sparse_inner" in calls
    call = calls["serving.sparse_inner"]
    mon = CompileMonitor()
    compiled, rec = mon.lower_and_compile(
        call.fn, *call.args, name="cap", **call.kwargs
    )
    assert rec.t_compile_s > 0
    assert "dot" in compiled.as_text() or "convolution" in compiled.as_text()
    assert got.values.shape[0] == Q.shape[0]


# -- metrics merge (satellite: CI matrix-cell aggregation) -------------------


def test_histogram_merge_matches_combined_stream():
    a, b, ref = Histogram(), Histogram(), Histogram()
    rng = np.random.default_rng(0)
    xs = rng.lognormal(0.0, 2.0, 400)
    ys = rng.lognormal(1.0, 1.0, 300)
    for x in xs:
        a.observe(x)
        ref.observe(x)
    for y in ys:
        b.observe(y)
        ref.observe(y)
    b.observe(0.0)
    ref.observe(0.0)
    a.merge(b)
    assert a.count == ref.count and a.zeros == ref.zeros
    assert a.total == pytest.approx(ref.total)
    assert a.min == ref.min and a.max == ref.max
    assert a.buckets == ref.buckets
    for q in (0.5, 0.9, 0.99):
        assert a.quantile(q) == pytest.approx(ref.quantile(q))


def test_histogram_merge_rejects_mismatched_bases():
    with pytest.raises(ValueError, match="base"):
        Histogram().merge(Histogram(base=2.0))


def test_registry_merge_aggregates_matrix_cells():
    cell1, cell2 = MetricsRegistry(), MetricsRegistry()
    cell1.incr("serving.requests", 10)
    cell2.incr("serving.requests", 5)
    cell1.gauge("queue_depth", 3)
    cell2.gauge("queue_depth", 7)
    cell2.gauge("only2", 1)
    for v in (0.1, 0.2):
        cell1.observe("latency_s", v)
    for v in (0.4, 0.8):
        cell2.observe("latency_s", v)
    cell2.observe("only2_s", 1.0)
    cell1.merge(cell2)
    assert cell1.counters["serving.requests"] == 15
    assert cell1.gauges == {"queue_depth": 7, "only2": 1}  # last-wins
    h = cell1.histogram("latency_s")
    assert h.count == 4 and h.max == 0.8 and h.min == pytest.approx(0.1)
    assert cell1.histogram("only2_s").count == 1
