"""Every (arch × shape) cell must BUILD (abstract shapes + shardings) on a
mesh — catches config/spec regressions in seconds without the 512-device
compile (which lives in launch/dryrun.py)."""

import jax
import pytest

from repro.compat import make_mesh
from repro.configs import ASSIGNED, get_arch
from repro.distributed.sharding import use_mesh


@pytest.fixture(scope="module")
def small_mesh():
    # axis names match production; sizes divide all assigned shapes
    return make_mesh((2, 2), ("data", "model"))


ALL_CELLS = [
    (a, s) for a in ASSIGNED + ["apss"] for s in get_arch(a).shapes
]


@pytest.mark.parametrize("arch_name,shape_name", ALL_CELLS)
def test_cell_builds(arch_name, shape_name, small_mesh):
    arch = get_arch(arch_name)
    cell = arch.cell(shape_name)
    cfg = arch.make_config()
    with use_mesh(small_mesh):
        build = cell.build(cfg, small_mesh)
    # args are abstract (no allocation), shardings present, fn callable
    assert callable(build.fn)
    leaves = jax.tree.leaves(build.args)
    assert leaves and all(isinstance(x, jax.ShapeDtypeStruct) for x in leaves)
    sh = jax.tree.leaves(build.in_shardings)
    assert sh and all(hasattr(s, "spec") for s in sh)
    assert "model_flops" in build.static_info or build.static_info
