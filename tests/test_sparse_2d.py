"""Sparse 2-D checkerboard (ISSUE 5 tentpole): the sparse horizontal ring
composed with posting-list-sharded vertical accumulation in ``apss_2d``.

The contract: on any mesh shape and density, ``apss_2d`` on a
:class:`SparseCorpus` returns exactly the dense-oracle matches (the same
exactness bar every other sparse path meets), the per-cell pruning bounds
stay sound under the dimension split (Lemma 1 + the cell-norm ≤ 1
argument, DESIGN.md §5), and the planner both enumerates and correctly
dispatches the new family.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import make_mesh
from repro.core.apss import apss_reference, normalize_rows
from repro.core.distributed import apss, apss_2d, apss_horizontal
from repro.core.graph import match_set
from repro.core.pruning import checkerboard_live_mask
from repro.core.sparse import dim_slices, from_dense, shard_dims, to_dense
from repro.data.sparse import sparse_clustered_corpus

T, K = 0.3, 16


def _dense_corpus(n, m, dens, seed=0):
    rng = np.random.default_rng(seed)
    D = np.abs(rng.standard_normal((n, m))).astype(np.float32)
    D *= rng.random((n, m)) < dens
    return np.asarray(normalize_rows(jnp.asarray(D)))


def _check(got, ref):
    assert match_set(got) == match_set(ref)
    np.testing.assert_array_equal(np.asarray(got.counts), np.asarray(ref.counts))


# -- exactness across densities × mesh shapes ---------------------------------


@pytest.mark.parametrize("mesh_shape", [(2, 4), (4, 2), (2, 2)])
@pytest.mark.parametrize("dens", [1e-3, 1e-2, 0.1])
def test_sparse_2d_exact_vs_dense_oracle(dens, mesh_shape):
    """Every (density × checkerboard shape) cell matches the oracle for both
    accumulations — including the near-empty 1e-3 regime where most rows
    have a single component."""
    D = _dense_corpus(128, 1024, dens, seed=3)
    sp = from_dense(D)
    mesh = make_mesh(mesh_shape, ("data", "model"))
    ref = apss_reference(jnp.asarray(D), T, K)
    for acc in ("allreduce", "compressed"):
        got = apss_2d(
            sp, T, K, mesh, accumulation=acc, block_rows=16,
            candidate_capacity=128,
        )
        _check(got, ref)


def test_sparse_2d_parity_with_sparse_ring(mesh8):
    """The composed checkerboard and the 1-D sparse ring are two routes to
    the same exact answer — parity pins the composition against the
    already-trusted sparse horizontal path."""
    sp = from_dense(_dense_corpus(128, 1024, 0.01, seed=4))
    ring = apss_horizontal(
        sp, T, K, mesh8, "data", schedule="ring", block_rows=16
    )
    mesh = make_mesh((4, 2), ("data", "model"))
    twod = apss_2d(
        sp, T, K, mesh, accumulation="compressed", block_rows=16,
        candidate_capacity=128,
    )
    _check(twod, ring)


def test_sparse_2d_overflow_reported():
    """Tiny candidate capacity must trip the overflow counter — capacity
    truncation is visible in the composed schedule too, never silent."""
    sp = from_dense(_dense_corpus(128, 96, 0.15, seed=5))
    mesh = make_mesh((4, 2), ("data", "model"))
    _, stats = apss_2d(
        sp, 0.05, K, mesh, accumulation="compressed", block_rows=16,
        candidate_capacity=4, return_stats=True,
    )
    assert int(stats.overflow_rows) > 0


def test_sparse_2d_divisibility_errors():
    """Host pre-split constraints fail loudly: m % r and n % q both raise."""
    mesh = make_mesh((4, 2), ("data", "model"))
    with pytest.raises(ValueError, match="multiple"):
        apss_2d(from_dense(_dense_corpus(64, 99, 0.2, seed=6)), T, K, mesh)
    with pytest.raises(ValueError, match="multiple"):
        apss_2d(from_dense(_dense_corpus(66, 96, 0.2, seed=6)), T, K, mesh)


# -- per-cell pruning soundness (Lemma 1 under the dimension split) -----------


def test_checkerboard_live_mask_sound_and_prunes():
    """The OR-union of per-cell masks at t/r keeps every tile containing a
    global match (Lemma 1: some slice sees partial ≥ t/r) while still
    pruning dead tiles on a clustered corpus — local pruning survives the
    composition. Per-cell minsize runs in its unit-norm form even though
    cell norms are < 1, which only over-bounds (cell-norm ≤ 1 argument)."""
    sp = sparse_clustered_corpus(128, 2048, 8.0, n_clusters=8, seed=7)
    t, bs, r = 0.4, 16, 4
    cells = dim_slices(sp, r)
    assert len(cells) == r and all(c.m == 2048 // r for c in cells)
    live = np.asarray(checkerboard_live_mask(cells, t, bs))
    Dn = np.asarray(to_dense(sp))
    S = Dn @ Dn.T
    np.fill_diagonal(S, 0.0)
    nb = 128 // bs
    has_match = S.reshape(nb, bs, nb, bs).max(axis=(1, 3)) >= t
    assert not (has_match & ~live).any()  # soundness: no match in a dead tile
    assert (~live).any()                  # and it actually prunes


def test_dim_slices_partition_is_lossless():
    sp = from_dense(_dense_corpus(32, 64, 0.3, seed=8))
    cells = dim_slices(sp, 4)
    back = np.concatenate([np.asarray(to_dense(c)) for c in cells], axis=1)
    np.testing.assert_allclose(back, np.asarray(to_dense(sp)), rtol=1e-6)


# -- planner integration ------------------------------------------------------


def test_planner_enumerates_and_prices_2d_sparse(mesh4x2):
    """`candidate_configs` emits the 2-D-sparse family (the last planner
    gate), every such config prices finite, and the sparse cell's modeled
    ring wire undercuts its dense twin's."""
    from repro.planner import default_profile, estimate_cost
    from repro.planner.plan import candidate_configs, summarize_corpus

    sp = from_dense(_dense_corpus(128, 1024, 0.01, seed=9))
    s = summarize_corpus(sp, T)
    cfgs = candidate_configs(s, mesh4x2, K, include_kernel=False)
    twod = {(c.sparse, c.accumulation): c for c in cfgs if c.kind == "2d"}
    assert (True, "compressed") in twod and (False, "compressed") in twod
    prof = default_profile()
    ests = {
        key: estimate_cost(c, s, dict(mesh4x2.shape), prof, K)
        for key, c in twod.items()
    }
    for e in ests.values():
        assert np.isfinite(e.total_s) and e.total_s > 0
    assert (
        ests[(True, "compressed")].wire_bytes
        < ests[(False, "compressed")].wire_bytes
    )


def test_distribution_auto_runs_sparse_on_2d_mesh(mesh4x2):
    """`distribution="auto"` prices the full (representation × distribution)
    matrix on a 2-axis mesh and whatever it picks stays exact."""
    from repro.planner import default_profile

    sp = from_dense(_dense_corpus(128, 1024, 0.01, seed=10))
    got = apss(
        sp, T, K, mesh4x2, distribution="auto",
        profile=default_profile(), include_kernel=False,
    )
    _check(got, apss_reference(to_dense(sp), T, K))


def test_sparse_2d_cell_cap_is_realized_split_width():
    """The traveling cell pair is exactly as wide as the realized per-cell
    max row count — the host pre-split's whole point (a traced-side split
    would pad every cell to the global cap)."""
    sp = from_dense(_dense_corpus(64, 96, 0.2, seed=11))
    idx_s, _, nnz_s, m_loc = shard_dims(sp, 2)
    assert m_loc == 48
    assert idx_s.shape[-1] == int(nnz_s.max())  # tight, not global-cap padded
    assert idx_s.shape[-1] <= sp.cap
