"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracles."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.apss import normalize_rows
from repro.kernels.apss_block.ops import apss_block_matmul
from repro.kernels.apss_block.ref import apss_block_reference
from repro.kernels.decode_attention.ops import (
    combine_partials,
    decode_attention,
    decode_attention_partials,
)
from repro.kernels.decode_attention.ref import (
    combine_partials_reference,
    decode_attention_reference,
)
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_reference

RNG = np.random.default_rng(42)


def _corp(n, m, dtype):
    D = np.abs(RNG.standard_normal((n, m))).astype(np.float32)
    D *= RNG.random((n, m)) < 0.25
    D = np.asarray(normalize_rows(jnp.asarray(D)))
    return jnp.asarray(D, dtype)


# -- apss_block ---------------------------------------------------------------


@pytest.mark.parametrize(
    "n1,n2,m,bm,bn,bk",
    [
        (256, 256, 512, 128, 128, 256),
        (256, 128, 512, 128, 128, 512),
        (300, 200, 700, 128, 128, 256),   # ragged → padding
        (512, 512, 1024, 256, 256, 512),  # production tile
        (128, 128, 128, 128, 128, 128),
    ],
)
@pytest.mark.parametrize("t", [0.2, 0.5])
def test_apss_block_shapes(n1, n2, m, bm, bn, bk, t):
    X, Y = _corp(n1, m, jnp.float32), _corp(n2, m, jnp.float32)
    got = apss_block_matmul(X, Y, t, block_m=bm, block_n=bn, block_k=bk)
    want = apss_block_reference(X, Y, t)
    np.testing.assert_allclose(got, want, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_apss_block_dtypes(dtype):
    X = _corp(256, 512, dtype)
    got = apss_block_matmul(X, X, 0.3, block_m=128, block_n=128, block_k=256)
    want = apss_block_reference(X.astype(jnp.float32), X.astype(jnp.float32), 0.3)
    atol = 1e-5 if dtype == jnp.float32 else 2e-2
    # bf16 inputs can flip borderline threshold decisions; compare the
    # confidently-matched region only.
    gotf = np.asarray(got, np.float32)
    wantf = np.asarray(want)
    confident = np.abs(wantf - 0.3) > 0.02
    np.testing.assert_allclose(gotf[confident], wantf[confident], atol=atol)


def test_apss_block_mask_skips_blocks():
    """An explicitly dead mask zeroes the tile even if scores pass t."""
    X = _corp(256, 256, jnp.float32)
    mask = jnp.zeros((2, 2), jnp.int32).at[0, 0].set(1)
    got = apss_block_matmul(
        X, X, 0.0, block_mask=mask, block_m=128, block_n=128, block_k=256
    )
    g = np.asarray(got)
    assert (g[128:, :] == 0).all() and (g[:, 128:] == 0).all()
    want = apss_block_reference(X, X, 0.0, block_mask=mask, block_m=128, block_n=128)
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_apss_block_auto_mask_exact(corpus):
    """Auto bound mask must not change results (bounds are sound)."""
    X = jnp.asarray(np.repeat(corpus, 2, axis=0)[:256, :96])
    Xp = jnp.pad(X, ((0, 0), (0, 160)))
    blocks = dict(block_m=128, block_n=128, block_k=128)
    a = apss_block_matmul(Xp, Xp, 0.4, auto_mask=True, **blocks)
    b = apss_block_matmul(Xp, Xp, 0.4, auto_mask=False, **blocks)
    np.testing.assert_allclose(a, b, atol=1e-5)


# -- flash_attention ----------------------------------------------------------


@pytest.mark.parametrize(
    "B,Hq,Hkv,S,D",
    [
        (2, 4, 2, 256, 64),
        (1, 8, 8, 256, 128),   # MHA
        (2, 16, 4, 128, 64),   # GQA 4:1
        (1, 4, 1, 512, 64),    # MQA
        (1, 2, 2, 300, 32),    # ragged seq → causal padding
    ],
)
def test_flash_attention_shapes(B, Hq, Hkv, S, D):
    q = jnp.asarray(RNG.standard_normal((B, Hq, S, D)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, Hkv, S, D)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, Hkv, S, D)), jnp.float32)
    got = flash_attention(q, k, v, block_q=128, block_k=128)
    want = attention_reference(q, k, v)
    np.testing.assert_allclose(got, want, atol=2e-5)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-5), (jnp.bfloat16, 2e-2)])
def test_flash_attention_dtypes(dtype, atol):
    q = jnp.asarray(RNG.standard_normal((1, 4, 128, 64)), dtype)
    k = jnp.asarray(RNG.standard_normal((1, 2, 128, 64)), dtype)
    v = jnp.asarray(RNG.standard_normal((1, 2, 128, 64)), dtype)
    got = flash_attention(q, k, v, block_q=128, block_k=128)
    want = attention_reference(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32)
    )
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want), atol=atol
    )


def test_flash_attention_noncausal():
    q = jnp.asarray(RNG.standard_normal((1, 2, 256, 32)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 2, 256, 32)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, 2, 256, 32)), jnp.float32)
    got = flash_attention(q, k, v, causal=False, block_q=128, block_k=128)
    want = attention_reference(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, atol=2e-5)


# -- decode_attention -----------------------------------------------------------


@pytest.mark.parametrize(
    "B,Hq,Hkv,L,D",
    [(2, 4, 2, 512, 64), (3, 8, 8, 1000, 128), (1, 8, 1, 256, 64)],
)
def test_decode_attention_shapes(B, Hq, Hkv, L, D):
    q = jnp.asarray(RNG.standard_normal((B, Hq, D)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, Hkv, L, D)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, Hkv, L, D)), jnp.float32)
    lens = jnp.asarray(RNG.integers(1, L + 1, size=(B,)), jnp.int32)
    got = decode_attention(q, k, v, lens, block_k=256)
    want = decode_attention_reference(q, k, v, lens)
    np.testing.assert_allclose(got, want, atol=2e-5)


@pytest.mark.parametrize("P", [2, 4, 8])
def test_decode_sharded_combine_exact(P):
    """Sequence-sharded partials combine to the monolithic answer — the
    long_500k decode path's correctness core."""
    B, Hq, Hkv, L, D = 2, 8, 4, 1024, 64
    q = jnp.asarray(RNG.standard_normal((B, Hq, D)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, Hkv, L, D)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, Hkv, L, D)), jnp.float32)
    lens = np.asarray([L, L // 3], np.int32)
    accs, ms, ls = [], [], []
    shard = L // P
    for s in range(P):
        loc_len = np.clip(lens - s * shard, 0, shard).astype(np.int32)
        a, m, l = decode_attention_partials(
            q, k[:, :, s * shard:(s + 1) * shard],
            v[:, :, s * shard:(s + 1) * shard],
            jnp.asarray(loc_len), block_k=128,
        )
        accs.append(a), ms.append(m), ls.append(l)
    got = combine_partials(jnp.stack(accs), jnp.stack(ms), jnp.stack(ls))
    want = decode_attention_reference(q, k, v, jnp.asarray(lens))
    np.testing.assert_allclose(got, want, atol=2e-5)
    ref_comb = combine_partials_reference(
        jnp.stack(accs), jnp.stack(ms), jnp.stack(ls)
    )
    np.testing.assert_allclose(got, ref_comb, atol=1e-6)
