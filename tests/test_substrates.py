"""Substrate tests: optimizer, checkpoint/fault-tolerance, compression,
elastic re-mesh, data determinism, dedup, straggler ledger."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.data import (
    GraphPipeline,
    LMDataPipeline,
    RecsysPipeline,
    corpus_stats,
    dedup_corpus,
    synthetic_corpus,
)
from repro.data.sampler import neighbor_sample, sampled_shape
from repro.distributed import StepTimer
from repro.distributed.elastic import make_elastic_mesh, reshard_tree
from repro.optim import (
    adamw_init,
    adamw_update,
    compress_tree,
    compression_init,
    cosine_schedule,
)


# -- optimizer ----------------------------------------------------------------


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(params)
    def loss(p):
        return jnp.sum(p["w"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(
            g, opt, params, lr=0.1, weight_decay=0.0
        )
    assert float(loss(params)) < 1e-2


def test_cosine_schedule_shape():
    assert float(cosine_schedule(0, 1.0, 10, 100)) < 0.2          # warmup
    assert float(cosine_schedule(10, 1.0, 10, 100)) == pytest.approx(1.0)
    assert float(cosine_schedule(100, 1.0, 10, 100)) == pytest.approx(0.1)


def test_bf16_params_f32_moments():
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    opt = adamw_init(params)
    assert opt.m["w"].dtype == jnp.float32
    g = {"w": jnp.ones((4,), jnp.bfloat16)}
    p2, opt2, _ = adamw_update(g, opt, params, lr=1e-2)
    assert p2["w"].dtype == jnp.bfloat16


# -- checkpoint / fault tolerance ----------------------------------------------


def test_checkpoint_roundtrip_all_dtypes():
    state = {
        "bf16": jnp.ones((3, 4), jnp.bfloat16) * 1.5,
        "f32": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "i32": jnp.asarray([1, 2, 3], jnp.int32),
        "nested": {"scalar": jnp.asarray(7, jnp.int32)},
    }
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(state, d, 5)
        back = load_checkpoint(d, 5, like=state)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(back)):
            assert str(a.dtype) == str(b.dtype)
            np.testing.assert_array_equal(
                np.asarray(a, np.float32), np.asarray(b, np.float32)
            )


def test_checkpoint_manager_keep_k_and_resume():
    state = {"x": jnp.ones((2,))}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for s in (1, 2, 3, 4):
            mgr.save({"x": state["x"] * s}, s)
        assert mgr.all_steps() == [3, 4]
        restored, step = mgr.restore(like=state)
        assert step == 4
        np.testing.assert_allclose(restored["x"], 4.0)


def test_checkpoint_atomicity_no_tmp_left():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=3)
        mgr.save({"x": jnp.zeros(3)}, 1, blocking=False)
        mgr.wait()
        assert not any(p.endswith(".tmp") for p in os.listdir(d))
        assert mgr.latest_step() == 1


def test_train_loop_resume(tmp_path):
    """Auto-resume: a second train_loop continues from the checkpoint."""
    from repro.launch.train import train_loop

    d = str(tmp_path / "ck")
    train_loop(arch="gat-cora", steps=4, ckpt_dir=d, ckpt_every=2, log_every=100)
    mgr = CheckpointManager(d)
    assert mgr.latest_step() == 4
    out = train_loop(arch="gat-cora", steps=6, ckpt_dir=d, ckpt_every=2, log_every=100)
    assert np.isfinite(out["loss"])
    assert mgr.latest_step() == 6


# -- gradient compression -------------------------------------------------------


def test_compression_error_feedback_invariant(mesh8):
    rng = np.random.default_rng(0)
    grads = {"w": jnp.asarray(rng.standard_normal((8, 8192)), jnp.float32)}
    state = compression_init({"w": grads["w"][0]})

    def f(g, err):
        synced, new = compress_tree(g, state._replace(error=err), "data", ratio=0.05)
        recon = jax.tree.map(
            lambda s, e: s + jax.lax.pmean(e, "data"), synced, new.error
        )
        return recon

    recon = jax.jit(
        shard_map(
            f, mesh=mesh8, in_specs=(P("data"), P()), out_specs=P(),
            check_vma=False,
        )
    )(grads, state.error)
    np.testing.assert_allclose(
        np.asarray(recon["w"]).reshape(-1), grads["w"].mean(0), atol=1e-5
    )


def test_compression_volume_accounting():
    from repro.optim.compression import compression_comm_bytes

    g = {"big": jnp.zeros((1 << 20,)), "small": jnp.zeros((64,))}
    acc = compression_comm_bytes(g, ratio=0.01, p=16)
    assert acc["compressed_bytes"] < acc["dense_bytes"]


# -- elastic ---------------------------------------------------------------------


def test_elastic_reshard_to_smaller_mesh():
    state = {"w": np.arange(32, dtype=np.float32).reshape(8, 4)}
    specs = {"w": P("data", "model")}
    big = make_elastic_mesh(8, model_parallel=2)
    small = make_elastic_mesh(4, model_parallel=2)
    on_big = reshard_tree(state, specs, big)
    on_small = reshard_tree(state, specs, small)
    np.testing.assert_array_equal(np.asarray(on_big["w"]), state["w"])
    np.testing.assert_array_equal(np.asarray(on_small["w"]), state["w"])
    assert len(on_small["w"].sharding.device_set) == 4


def test_elastic_mesh_keeps_model_parallel():
    m = make_elastic_mesh(7, model_parallel=2)
    assert m.shape["model"] == 2 and m.shape["data"] == 3


# -- data ------------------------------------------------------------------------


def test_pipelines_deterministic_and_step_dependent():
    lm = LMDataPipeline(vocab_size=1000, batch_size=4, seq_len=16, seed=3)
    assert (lm.get_batch(7)["tokens"] == lm.get_batch(7)["tokens"]).all()
    assert (lm.get_batch(7)["tokens"] != lm.get_batch(8)["tokens"]).any()
    rs = RecsysPipeline(n_items=100, batch_size=4, kind="seq")
    b = rs.get_batch(0)
    assert b["item_ids"].shape == (4, 50)
    assert (b["item_ids"][b["mask"]] == 100).all()  # [MASK] token rows


def test_corpus_stats_match_construction():
    D = synthetic_corpus(100, 400, 12.0, seed=1)
    st = corpus_stats(D)
    assert st.n == 100 and st.m == 400
    assert 6 <= st.avg_vector_size <= 20
    norms = np.linalg.norm(D, axis=1)
    np.testing.assert_allclose(norms[norms > 0], 1.0, rtol=1e-5)


def test_dedup_finds_planted_duplicates():
    D = synthetic_corpus(64, 256, 10.0, seed=2)
    dup = np.concatenate([D, D[:8]])
    keep, dup_of = dedup_corpus(dup, threshold=0.999)
    assert keep[:64].all()
    assert not keep[64:].any()
    np.testing.assert_array_equal(dup_of[64:], np.arange(8))


def test_sampler_static_shapes():
    pipe = GraphPipeline(n_nodes=300, n_edges=2400, d_feat=8)
    indptr, idx = pipe.csr()
    g = pipe.full_graph()
    seeds = np.arange(16)
    batch = neighbor_sample(indptr, idx, seeds, (4, 3), g["features"], g["labels"])
    n, e = sampled_shape(16, (4, 3))
    assert batch["features"].shape == (n, 8)
    assert batch["edge_src"].shape == (e,)
    assert batch["label_mask"][:16].all() and not batch["label_mask"][16:].any()


# -- straggler --------------------------------------------------------------------


def test_straggler_detection():
    t = StepTimer(tolerance=1.5)
    for r in range(8):
        for _ in range(5):
            t.record(r, 0.1 if r != 5 else 0.25)
    rep = t.report()
    assert rep.evict == [5]
