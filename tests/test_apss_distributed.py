"""Integration tests: every distributed APSS variant is exact vs the oracle
on an 8-device mesh (the paper's Algs. 3-7 + TPU extensions)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import make_mesh
from repro.core.apss import apss_reference
from repro.core.distributed import (
    apss,
    apss_2d,
    apss_horizontal,
    apss_horizontal_hierarchical,
    apss_vertical,
)
from repro.core.graph import match_set

T, K = 0.35, 16


@pytest.fixture(scope="module")
def ref(corpus):
    return jax.jit(lambda d: apss_reference(d, T, K))(jnp.asarray(corpus))


def _check(got, ref):
    assert match_set(got) == match_set(ref)
    np.testing.assert_array_equal(np.asarray(got.counts), np.asarray(ref.counts))


@pytest.mark.parametrize("schedule", ["allgather", "ring", "halfring"])
def test_horizontal(corpus, mesh8, ref, schedule):
    got = jax.jit(
        lambda d: apss_horizontal(
            d, T, K, mesh8, schedule=schedule, block_rows=16
        )
    )(jnp.asarray(corpus))
    _check(got, ref)


@pytest.mark.parametrize(
    "accumulation", ["allreduce", "scatter", "compressed", "recursive"]
)
def test_vertical(corpus, mesh8_model, ref, accumulation):
    got = jax.jit(
        lambda d: apss_vertical(
            d, T, K, mesh8_model, accumulation=accumulation,
            block_rows=32, candidate_capacity=128,
        )
    )(jnp.asarray(corpus))
    _check(got, ref)


def test_vertical_overflow_reported(corpus, mesh8_model):
    # Tiny capacity must trip the overflow counter (visible truncation).
    _, stats = jax.jit(
        lambda d: apss_vertical(
            d, 0.05, K, mesh8_model, accumulation="compressed",
            block_rows=32, candidate_capacity=4, return_stats=True,
        )
    )(jnp.asarray(corpus))
    assert int(stats.overflow_rows) > 0


@pytest.mark.parametrize("accumulation", ["allreduce", "compressed"])
def test_2d(corpus, mesh4x2, ref, accumulation):
    got = jax.jit(
        lambda d: apss_2d(
            d, T, K, mesh4x2, accumulation=accumulation,
            block_rows=16, candidate_capacity=128,
        )
    )(jnp.asarray(corpus))
    _check(got, ref)


def test_hierarchical_2level(corpus, ref):
    mesh = make_mesh((2, 4), ("pod", "data"))
    got = jax.jit(
        lambda d: apss_horizontal_hierarchical(
            d, T, K, mesh, ("pod", "data"), block_rows=16
        )
    )(jnp.asarray(corpus))
    _check(got, ref)


def test_hierarchical_3level(corpus, ref):
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    got = jax.jit(
        lambda d: apss_horizontal_hierarchical(
            d, T, K, mesh, ("pod", "data", "model"), block_rows=16
        )
    )(jnp.asarray(corpus))
    _check(got, ref)


def test_dispatcher(corpus, mesh4x2, ref):
    got = jax.jit(
        lambda d: apss(
            d, T, K, mesh4x2, distribution="2d", block_rows=16,
            candidate_capacity=128,
        )
    )(jnp.asarray(corpus))
    _check(got, ref)


def test_odd_device_count_ring_and_halfring(corpus, ref):
    """Non-power-of-two processor counts (paper runs 3-, 5-way too)."""
    devs = jax.devices()[:5]
    mesh = jax.sharding.Mesh(np.array(devs), ("data",))
    # 128 rows / 5 devices doesn't divide — pad rows to 130.
    D = np.concatenate([corpus, np.zeros((2, corpus.shape[1]), np.float32)])
    for schedule in ["ring", "halfring"]:
        got = jax.jit(
            lambda d: apss_horizontal(
                d, T, K, mesh, schedule=schedule, block_rows=13
            )
        )(jnp.asarray(D))
        got = jax.tree.map(lambda x: x[: corpus.shape[0]], got)
        _check(got, ref)
