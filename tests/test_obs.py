"""Observability layer tests (ISSUE 8): span-tree shape, histogram
accuracy, Chrome-trace validity, flight-recorder triggers, cost-model
drift detection, and — load-bearing for everything else — the overhead
guard: with no active sink the instrumented hot paths allocate nothing,
plant no callbacks, and add zero device work.
"""

import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.apss import normalize_rows
from repro.obs import (
    FlightRecorder,
    MetricsRegistry,
    Tracer,
    drift,
    export,
    metrics,
    recorder,
    trace,
)
from repro.obs.metrics import Histogram
from repro.planner import telemetry
from repro.planner.costmodel import CalibrationProfile
from repro.planner.telemetry import ApssStats, CollectiveHop

T, K = 0.35, 16


def _dense(n=128, m=96, dens=0.3, seed=0):
    rng = np.random.default_rng(seed)
    D = np.abs(rng.standard_normal((n, m))).astype(np.float32)
    D *= rng.random((n, m)) < dens
    return np.asarray(normalize_rows(jnp.asarray(D)))


# -- span tree ----------------------------------------------------------------


def test_span_tree_nesting_shape():
    with Tracer() as tr:
        with trace.span("plan", autotune=False):
            with trace.span("inner", i=0):
                trace.event("mark", x=1)
            with trace.span("inner", i=1):
                pass
        with trace.span("execute"):
            trace.annotate(config="blocked")
    names = [s.name for s in tr.walk()]
    assert names == ["trace", "plan", "inner", "inner", "execute"]
    plan, execute = tr.root.children
    assert [c.attrs["i"] for c in plan.children] == [0, 1]
    assert plan.children[0].events[0][1] == "mark"
    assert execute.attrs["config"] == "blocked"
    # every span closed, times monotonic within a parent
    for s in tr.walk():
        assert s.t1 is not None and s.t1 >= s.t0
    assert plan.t1 <= execute.t0


def test_span_tree_survives_exceptions():
    """A raising body closes its span with status="error"; a child whose
    __exit__ was skipped by the unwind is closed by its ancestor."""
    with Tracer() as tr:
        with pytest.raises(ValueError):
            with trace.span("outer"):
                with trace.span("inner"):
                    raise ValueError("boom")
        with trace.span("after"):
            pass
    outer, after = tr.root.children
    assert outer.status == "error" and "boom" in outer.error
    (inner,) = outer.children
    assert inner.t1 is not None  # closed despite the unwind
    assert after.parent is tr.root  # stack recovered, not nested under outer


def test_tracer_enters_private_commlog():
    """Tracing alone turns on the telemetry seam (records + tickers)."""
    assert not telemetry.enabled()
    with Tracer() as tr:
        assert telemetry.enabled()
        with trace.span("call"):
            telemetry.record(ApssStats(variant="blocked/fused", n=8, m=8))
    assert not telemetry.enabled()
    (call,) = tr.root.children
    assert [r.variant for r in call.records] == ["blocked/fused"]


# -- overhead guard -----------------------------------------------------------


def test_disabled_span_is_shared_noop_singleton():
    assert not trace.enabled()
    assert trace.span("a") is trace.span("b", x=1) is trace.NULL_SPAN
    with trace.span("a") as s:
        assert s is None
    trace.event("nothing", x=1)  # must not raise
    trace.annotate(y=2)
    metrics.observe("serving.latency_s", 0.1)  # no registry: dropped
    recorder.trigger("no-op")


def test_no_sinks_means_no_new_traces_and_no_callbacks():
    """The instrumented serving hot path adds zero device work when no
    sink is active: a repeat query re-traces nothing (the retrace
    registry), and the jaxpr of the instrumented sweep carries a
    debug_callback ONLY when telemetry is on (the StepTicker seam)."""
    from repro.core.distributed import apss_2d
    from repro.data.sparse import perturbed_queries, sparse_clustered_corpus
    from repro.obs import compile as obs_compile
    from repro.serving import build_index, query_topk

    sp = sparse_clustered_corpus(256, 128, 8.0, n_clusters=4, seed=0)
    index = build_index(sp, block_rows=64, normalize=False)
    Q = perturbed_queries(sp, 4, seed=1)
    jax.block_until_ready(query_topk(index, Q, T, K).values)
    with obs_compile.assert_no_retrace():  # watch EVERY entry point
        jax.block_until_ready(query_topk(index, Q, T, K).values)

    from repro.compat import make_mesh

    mesh = make_mesh((4, 2), ("data", "model"))
    D = jnp.asarray(_dense(64, 96, seed=3))

    # fresh callable per trace: jax's tracing cache would otherwise skip
    # re-running the Python body (the documented telemetry caveat)
    def fresh():
        return lambda d: apss_2d(d, T, K, mesh, block_rows=16).values

    off = str(jax.make_jaxpr(fresh())(D))
    assert "debug_callback" not in off  # no ticker planted when disabled
    with telemetry.CommLog():
        on = str(jax.make_jaxpr(fresh())(D))
    assert "debug_callback" in on  # the seam exists when enabled


# -- histogram ----------------------------------------------------------------


def test_histogram_quantiles_track_numpy():
    rng = np.random.default_rng(42)
    samples = rng.lognormal(mean=0.0, sigma=1.0, size=5000)
    h = Histogram()
    for v in samples:
        h.observe(float(v))
    for q in (0.5, 0.9, 0.95, 0.99):
        want = float(np.quantile(samples, q))
        got = h.quantile(q)
        # exponential buckets are ~19% wide; midpoint reads stay well
        # inside 15% relative error
        assert abs(got - want) / want < 0.15, (q, got, want)
    snap = h.snapshot()
    assert snap["count"] == 5000
    assert snap["min"] == pytest.approx(samples.min())
    assert snap["max"] == pytest.approx(samples.max())
    assert snap["mean"] == pytest.approx(samples.mean())


def test_histogram_edge_cases():
    h = Histogram()
    assert math.isnan(h.quantile(0.5))
    h.observe(0.0)   # zero bucket
    h.observe(-1.0)  # clamped into zero bucket too
    h.observe(2.0)
    assert h.count == 3 and h.zeros == 2
    assert h.quantile(0.0) == 0.0  # zeros dominate the low quantiles
    # top quantile reads the bucket's geometric midpoint — within one
    # bucket width (~19%) of the observed max
    assert h.quantile(1.0) == pytest.approx(2.0, rel=0.19)


def test_registry_absorbs_telemetry_counters_and_derives_hit_rate():
    with MetricsRegistry() as reg:
        telemetry.incr("serving.requests", 4)
        telemetry.incr("serving.cache_hits")
        metrics.observe("serving.latency_s", 0.010)
        metrics.gauge("queue.depth", 3)
    snap = reg.snapshot()
    assert snap["counters"]["serving.requests"] == 4
    assert snap["derived"]["serving.cache_hit_rate"] == 0.25
    assert snap["gauges"]["queue.depth"] == 3
    assert snap["histograms"]["serving.latency_s"]["count"] == 1
    prom = reg.to_prometheus()
    assert "repro_serving_requests_total 4" in prom
    assert 'repro_serving_latency_s{quantile="0.99"}' in prom


# -- chrome trace export ------------------------------------------------------


def test_chrome_trace_is_valid_and_monotonic(tmp_path):
    with Tracer() as tr:
        with trace.span("plan"):
            with trace.span("plan/inner"):
                trace.event("mark")
        with trace.span("serving/step", step=0):
            pass
    with MetricsRegistry() as reg:
        reg.incr("x")
    path = tmp_path / "trace.json"
    doc = export.write_chrome_trace(str(path), tr, reg)
    assert json.loads(path.read_text()) == doc  # valid JSON round-trip
    events = doc["traceEvents"]
    assert events, "no events emitted"
    for e in events:
        assert {"name", "ph", "pid", "tid"} <= e.keys()
        if e["ph"] in ("X", "i"):
            assert e["ts"] >= 0
        if e["ph"] == "X":
            assert e["dur"] >= 0
    ts = [e["ts"] for e in events if e["ph"] in ("X", "i")]
    assert ts == sorted(ts)  # monotonic event stream
    # one metadata track per top-level phase, names preserved
    names = {e["args"]["name"] for e in events if e["ph"] == "M"}
    assert names == {"plan", "serving"}
    assert doc["otherData"]["metrics"]["counters"]["x"] == 1


def test_write_metrics_formats(tmp_path):
    with MetricsRegistry() as reg:
        reg.incr("a.b", 2)
    jpath = tmp_path / "m.json"
    export.write_metrics(str(jpath), reg)
    assert json.loads(jpath.read_text())["counters"]["a.b"] == 2
    ppath = tmp_path / "m.prom"
    export.write_metrics(str(ppath), reg)
    assert "repro_a_b_total 2" in ppath.read_text()


# -- ring-step materialization (integration) ---------------------------------


def test_trace_materializes_ring_steps_matching_ticker(mesh4x2):
    """plan->execute is not required — any traced checkerboard run emits an
    ApssStats with a StepTicker, and finalize() turns its ticks into
    ring_step child spans whose count and extent match the ticker."""
    from repro.core.distributed import apss_2d

    D = jnp.asarray(_dense(128, 96, seed=11))
    q = mesh4x2.shape["data"]
    # registry entered FIRST so it outlives the tracer: finalize() (tracer
    # exit) observes the step-time/skew histograms into any live registry
    with MetricsRegistry() as reg, Tracer() as tr:
        with trace.span("apss_2d"):
            m = apss_2d(D, T, K, mesh4x2, block_rows=16)
            jax.block_until_ready(m.values)
    (sp,) = tr.root.children
    (rec,) = sp.records
    steps = [c for c in sp.children if c.name == "ring_step"]
    assert len(steps) == q
    assert [c.attrs["i"] for c in steps] == list(range(q))
    assert all(c.attrs["variant"] == rec.variant for c in steps)
    # span extents reproduce the ticker's per-step deltas exactly
    want = rec.step_ticker.step_times()
    got = [c.duration_s for c in steps]
    assert got == pytest.approx(want, rel=1e-6)
    assert all(c.attrs["ranks"] == 8 for c in steps)
    # and the step-time/skew histograms were observed into the registry
    assert reg.histograms["sweep.step_time_s"].count == q
    assert reg.histograms["sweep.step_skew_s"].count == q


# -- flight recorder ----------------------------------------------------------


def test_flight_recorder_dumps_on_injected_fault(tmp_path):
    from repro.robust import Fault, FaultPlan
    from repro.robust.faults import SweepKilled
    from repro.robust.sweep import ResumableSweep

    D = _dense(64, 32, seed=5)
    plan = FaultPlan([Fault("kill", scope="sweep", step=1)])
    with FlightRecorder(directory=str(tmp_path / "fr")) as fr, Tracer():
        sweep = ResumableSweep(
            D, threshold=T, k=8, block_rows=16,
            directory=str(tmp_path / "ckpt"), fault_plan=plan,
        )
        with pytest.raises(SweepKilled):
            sweep.run()
    assert plan.fired["kill:sweep"] == 1  # the fault actually triggered
    (reason, payload, path) = fr.dumps[0]
    assert reason == "fault:kill:sweep"
    assert payload["attrs"]["step"] == 1
    # the lead-up survived: step-0 spans are in the frozen buffer
    assert any(e["kind"] == "span" for e in payload["events"])
    dumped = json.loads(open(path).read())
    assert dumped["reason"] == "fault:kill:sweep"


def test_flight_recorder_ring_buffer_bounded():
    with FlightRecorder(capacity=4) as fr:
        for i in range(10):
            recorder.note("event", f"e{i}")
        payload = fr.trigger("overflow-check")
    assert len(payload["events"]) == 4
    assert payload["events"][0]["name"] == "e6"  # oldest dropped first


def test_recorder_triggers_on_checkpoint_corruption_fallback(tmp_path):
    from repro.checkpoint import CheckpointManager
    from repro.robust import FaultPlan

    mgr = CheckpointManager(str(tmp_path), keep=3)
    state = {"x": np.arange(8, dtype=np.float32)}
    mgr.save(state, step=1)
    mgr.save({"x": state["x"] + 1}, step=2)
    # flip a byte in the newest checkpoint's leaf
    leaf = tmp_path / "step_0000000002" / "x.npy"
    FaultPlan(seed=3).corrupt_file(str(leaf))
    with FlightRecorder() as fr:
        restored, step = mgr.restore(like=state, fallback=True)
    assert step == 1
    assert [r for r, _, _ in fr.dumps] == ["checkpoint.corruption_fallback"]


# -- drift --------------------------------------------------------------------


def _stats(variant="blocked/fused", flops=4e9, wire=0, hops=0):
    hop = (
        (CollectiveHop(op="ppermute", payload="dense_block", axis="data",
                       bytes_per_hop=wire // max(hops, 1), hops=hops),)
        if hops else ()
    )
    return ApssStats(variant=variant, n=1024, m=1024, flops=flops, hops=hop)


def test_predict_seconds_matches_profile_arithmetic():
    prof = CalibrationProfile(matmul_gflops=40.0, overhead_us=0.0)
    s = _stats(flops=40e9)  # exactly one second of matmul at 40 GF/s
    assert drift.predict_seconds(s, prof) == pytest.approx(1.0)
    # overlapped schedules take max(compute, comm), sequential ones add
    ring = _stats(variant="horizontal/ring", flops=40e9, wire=4_000_000_000,
                  hops=4)
    seq = _stats(variant="vertical/allreduce", flops=40e9,
                 wire=4_000_000_000, hops=4)
    p_ring = drift.predict_seconds(ring, prof)
    p_seq = drift.predict_seconds(seq, prof)
    assert p_seq > p_ring  # comm hidden under compute for the ring family


def test_drift_report_flags_perturbed_profile():
    """A profile whose throughput constant rotted by 100x yields residual
    ratios ~100x and a STALE verdict naming the recalibration entry point;
    the honest profile stays fresh on the same measurements."""
    fresh_prof = CalibrationProfile(matmul_gflops=40.0, overhead_us=0.0)
    records = [_stats(flops=f) for f in (10e9, 20e9, 40e9)]
    measured = [f / 40e9 for f in (10e9, 20e9, 40e9)]  # truth at 40 GF/s
    residuals = [
        drift.Residual(
            variant=r.variant,
            predicted_s=drift.predict_seconds(r, fresh_prof),
            measured_s=m,
        )
        for r, m in zip(records, measured)
    ]
    rep = drift.drift_report(residuals, profile=fresh_prof)
    assert not rep.stale
    assert rep.median_ratio == pytest.approx(1.0)

    stale_prof = CalibrationProfile(matmul_gflops=4000.0, overhead_us=0.0)
    residuals = [
        drift.Residual(
            variant=r.variant,
            predicted_s=drift.predict_seconds(r, stale_prof),
            measured_s=m,
        )
        for r, m in zip(records, measured)
    ]
    rep = drift.drift_report(residuals, profile=stale_prof)
    assert rep.stale
    assert rep.median_ratio == pytest.approx(100.0)
    assert rep.per_variant["blocked/fused"] == pytest.approx(100.0)
    assert "calibrate" in rep.recommendation
    assert "blocked/fused" in rep.recommendation
    d = rep.as_dict()
    assert d["stale"] and d["n_residuals"] == 3
    assert "STALE" in rep.describe()


def test_residuals_from_trace_joins_records_to_spans():
    clock = iter(np.arange(0.0, 100.0, 0.5))
    with Tracer(clock=lambda: float(next(clock))) as tr:
        with trace.span("execute"):
            telemetry.record(_stats(flops=40e9))
    prof = CalibrationProfile(matmul_gflops=40.0, overhead_us=0.0)
    (res,) = drift.residuals_from_trace(tr, prof)
    assert res.variant == "blocked/fused"
    assert res.predicted_s == pytest.approx(1.0)
    assert res.measured_s == pytest.approx(0.5)  # one clock step in-span
    assert res.source == "trace"


def test_residuals_from_estimates_skip_unmeasured():
    from repro.planner.plan import plan_apss

    sp = _dense(64, 64, seed=9)
    plan = plan_apss(sp, T, K, None, include_kernel=False)
    assert drift.residuals_from_estimates(plan.estimates) == []
    plan.estimates[0].measured_s = plan.estimates[0].total_s * 2
    (res,) = drift.residuals_from_estimates(plan.estimates)
    assert res.ratio == pytest.approx(2.0)
    assert res.source == "estimate"


# -- telemetry LIFO regression (satellite 1) ----------------------------------


def test_commlog_exit_is_lifo_not_remove_first():
    """Regression: __exit__ used _STACK.remove(self), which strips the
    FIRST occurrence — re-entering the same log nested (legal: each entry
    just means "receive records") corrupted the stack order and detached
    the still-active inner entry."""
    log = telemetry.CommLog()
    with log:
        with log:  # nested re-entry of the SAME log
            assert telemetry.enabled()
        # inner exit must pop the inner entry, leaving the outer active
        assert telemetry.enabled()
        telemetry.record(ApssStats(variant="x", n=1, m=1))
        assert log.records  # outer entry still receiving
    assert not telemetry.enabled()


def test_commlog_out_of_order_exit_raises():
    a, b = telemetry.CommLog(), telemetry.CommLog()
    a.__enter__()
    b.__enter__()
    with pytest.raises(RuntimeError, match="LIFO"):
        a.__exit__(None, None, None)
    # cleanup: unwind in the legal order
    b.__exit__(None, None, None)
    a.__exit__(None, None, None)
    assert not telemetry.enabled()
