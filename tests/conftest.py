"""Test env: force 8 virtual CPU devices so the distributed (shard_map)
tests can exercise real multi-device lowering in-process.

NOTE: this is 8, NOT the dry-run's 512 — the production-mesh compile path is
exercised only via ``launch/dryrun.py`` in its own process (see DESIGN.md).
Single-device tests simply use device 0 and are unaffected.
This must run before jax/jaxlib first parse XLA_FLAGS, hence conftest.
"""

import os
import zlib

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402
import pytest  # noqa: E402


def shard_of(path: str, num_shards: int) -> int:
    """Stable file → shard assignment for CI tier-1 sharding.

    crc32, not ``hash()``: assignment must agree across processes and
    Python versions (PYTHONHASHSEED randomizes str hash). Sharding is by
    test FILE so a module-scoped fixture is built in exactly one shard
    (session-scoped fixtures are per-process either way: every shard that
    collects a file using one builds its own copy).
    """
    return zlib.crc32(path.encode()) % num_shards


def pytest_collection_modifyitems(config, items):
    """Optional tier-1 sharding for the CI matrix.

    ``PYTEST_NUM_SHARDS=N`` + ``PYTEST_SHARD=1..N`` select a stable,
    disjoint, exhaustive partition of the test files; unset (the default,
    and every local run) leaves collection untouched.
    """
    num = int(os.environ.get("PYTEST_NUM_SHARDS", "1") or 1)
    if num <= 1:
        return
    shard = int(os.environ.get("PYTEST_SHARD", "1"))
    if not 1 <= shard <= num:
        raise pytest.UsageError(
            f"PYTEST_SHARD={shard} out of range 1..{num}"
        )
    keep, drop = [], []
    for item in items:
        fname = item.nodeid.split("::", 1)[0]
        (keep if shard_of(fname, num) == shard - 1 else drop).append(item)
    if drop:
        items[:] = keep
        config.hook.pytest_deselected(items=drop)


@pytest.fixture(scope="session")
def corpus():
    """A small power-law-ish normalized corpus (paper-style data)."""
    import jax.numpy as jnp

    from repro.core.apss import normalize_rows

    rng = np.random.default_rng(0)
    n, m = 128, 96
    D = np.abs(rng.standard_normal((n, m))).astype(np.float32)
    D *= rng.random((n, m)) < 0.3
    return np.asarray(normalize_rows(jnp.asarray(D)))


@pytest.fixture(scope="session")
def mesh8():
    from repro.compat import make_mesh

    return make_mesh((8,), ("data",))


@pytest.fixture(scope="session")
def mesh8_model():
    from repro.compat import make_mesh

    return make_mesh((8,), ("model",))


@pytest.fixture(scope="session")
def mesh4x2():
    from repro.compat import make_mesh

    return make_mesh((4, 2), ("data", "model"))
