"""Test env: force 8 virtual CPU devices so the distributed (shard_map)
tests can exercise real multi-device lowering in-process.

NOTE: this is 8, NOT the dry-run's 512 — the production-mesh compile path is
exercised only via ``launch/dryrun.py`` in its own process (see DESIGN.md).
Single-device tests simply use device 0 and are unaffected.
This must run before jax/jaxlib first parse XLA_FLAGS, hence conftest.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def corpus():
    """A small power-law-ish normalized corpus (paper-style data)."""
    import jax.numpy as jnp

    from repro.core.apss import normalize_rows

    rng = np.random.default_rng(0)
    n, m = 128, 96
    D = np.abs(rng.standard_normal((n, m))).astype(np.float32)
    D *= rng.random((n, m)) < 0.3
    return np.asarray(normalize_rows(jnp.asarray(D)))


@pytest.fixture(scope="session")
def mesh8():
    from repro.compat import make_mesh

    return make_mesh((8,), ("data",))


@pytest.fixture(scope="session")
def mesh8_model():
    from repro.compat import make_mesh

    return make_mesh((8,), ("model",))


@pytest.fixture(scope="session")
def mesh4x2():
    from repro.compat import make_mesh

    return make_mesh((4, 2), ("data", "model"))
