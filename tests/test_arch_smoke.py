"""Per-architecture smoke tests: REDUCED config of the same family, one
forward/train step on CPU, asserting output shapes + no NaNs (the full
configs are exercised only via the dry-run)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_arch
from repro.data import GraphPipeline, LMDataPipeline, RecsysPipeline
from repro.launch.train import (
    make_gat_train_step,
    make_lm_train_step,
    make_recsys_train_step,
)
from repro.models import gnn, recsys
from repro.models.transformer import init_transformer, prefill
from repro.optim import adamw_init

LM_ARCHS = ["qwen3-1.7b", "minicpm3-4b", "qwen3-8b", "arctic-480b", "deepseek-moe-16b"]
RS_ARCHS = ["two-tower-retrieval", "bert4rec", "din", "bst"]


def _finite_tree(tree) -> bool:
    return all(
        bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(tree)
        if jnp.issubdtype(x.dtype, jnp.floating)
    )


def test_all_assigned_archs_registered():
    assert len(ASSIGNED) == 10
    for a in ASSIGNED:
        arch = get_arch(a)
        assert len(arch.shapes) == 4, (a, arch.shapes.keys())


@pytest.mark.parametrize("arch_name", LM_ARCHS)
def test_lm_smoke_train_step(arch_name):
    arch = get_arch(arch_name)
    cfg = arch.make_smoke_config()
    params = init_transformer(jax.random.key(0), cfg)
    opt = adamw_init(params)
    pipe = LMDataPipeline(vocab_size=cfg.vocab_size, batch_size=2, seq_len=64)
    batch = jax.tree.map(jnp.asarray, pipe.get_batch(0))
    step = jax.jit(make_lm_train_step(cfg))
    new_params, new_opt, metrics = step(params, opt, batch)
    assert metrics["loss"].shape == ()
    assert np.isfinite(float(metrics["loss"]))
    assert _finite_tree(new_params)
    assert int(new_opt.step) == 1
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params))
    )
    assert moved


@pytest.mark.parametrize("arch_name", LM_ARCHS)
def test_lm_smoke_prefill(arch_name):
    arch = get_arch(arch_name)
    cfg = arch.make_smoke_config()
    params = init_transformer(jax.random.key(0), cfg)
    tokens = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 32)), jnp.int32
    )
    logits = jax.jit(lambda p, t: prefill(p, cfg, t))(params, tokens)
    assert logits.shape == (2, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch_name", RS_ARCHS)
def test_recsys_smoke_train_step(arch_name):
    arch = get_arch(arch_name)
    cfg = arch.make_smoke_config()
    if isinstance(cfg, recsys.TwoTowerConfig):
        params = recsys.init_two_tower(jax.random.key(0), cfg)
        pipe = RecsysPipeline(n_items=cfg.n_items, batch_size=8,
                              history_len=cfg.history_len,
                              n_user_fields=cfg.n_user_fields,
                              user_vocab=cfg.user_vocab, kind="two-tower")
    elif isinstance(cfg, recsys.Bert4RecConfig):
        params = recsys.init_bert4rec(jax.random.key(0), cfg)
        pipe = RecsysPipeline(n_items=cfg.n_items, batch_size=8,
                              history_len=cfg.seq_len, kind="seq")
    elif isinstance(cfg, recsys.DINConfig):
        params = recsys.init_din(jax.random.key(0), cfg)
        pipe = RecsysPipeline(n_items=cfg.n_items, batch_size=8,
                              history_len=cfg.seq_len, kind="ctr")
    else:
        params = recsys.init_bst(jax.random.key(0), cfg)
        pipe = RecsysPipeline(n_items=cfg.n_items, batch_size=8,
                              history_len=cfg.seq_len - 1, kind="ctr")
    batch = jax.tree.map(jnp.asarray, pipe.get_batch(0))
    opt = adamw_init(params)
    step = jax.jit(make_recsys_train_step(cfg))
    new_params, new_opt, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert _finite_tree(new_params)


def test_gnn_smoke_train_step():
    arch = get_arch("gat-cora")
    cfg = arch.make_smoke_config()
    params = gnn.init_gat(jax.random.key(0), cfg)
    pipe = GraphPipeline(n_nodes=256, n_edges=2048, d_feat=cfg.d_feat,
                         n_classes=cfg.n_classes)
    batch = jax.tree.map(jnp.asarray, pipe.full_graph())
    opt = adamw_init(params)
    step = jax.jit(make_gat_train_step(cfg))
    new_params, _, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    logits = gnn.gat_forward(new_params, cfg, batch)
    assert logits.shape == (256, cfg.n_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_gnn_sampled_minibatch_step():
    """minibatch_lg path: neighbor sampler → padded batch → train step."""
    arch = get_arch("gat-cora")
    cfg = arch.make_smoke_config()
    from repro.data.sampler import neighbor_sample

    pipe = GraphPipeline(n_nodes=500, n_edges=5000, d_feat=cfg.d_feat,
                         n_classes=cfg.n_classes)
    indptr, idx = pipe.csr()
    g = pipe.full_graph()
    batch = neighbor_sample(
        indptr, idx, np.arange(16), (5, 3), g["features"], g["labels"]
    )
    batch = {k: jnp.asarray(v) for k, v in batch.items() if k != "node_ids"}
    params = gnn.init_gat(jax.random.key(0), cfg)
    opt = adamw_init(params)
    _, _, metrics = jax.jit(make_gat_train_step(cfg))(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))


def test_two_tower_retrieval_smoke():
    """retrieval_cand path at reduced scale, via the APSS core."""
    cfg = get_arch("two-tower-retrieval").make_smoke_config()
    params = recsys.init_two_tower(jax.random.key(0), cfg)
    pipe = RecsysPipeline(n_items=cfg.n_items, batch_size=1,
                          history_len=cfg.history_len,
                          n_user_fields=cfg.n_user_fields,
                          user_vocab=cfg.user_vocab, kind="two-tower")
    batch = jax.tree.map(jnp.asarray, pipe.get_batch(0))
    m = recsys.retrieval_scores(
        params, cfg, batch, jnp.arange(cfg.n_items), k=16
    )
    assert m.values.shape == (1, 16)
    assert int(m.counts[0]) >= 0
    assert bool(jnp.all(jnp.isfinite(m.values[m.indices >= 0])))
