"""Online retrieval serving: build-once APSS index + query-time top-k.

The paper's kernel is the symmetric all-pairs self-join, but its essential
machinery — partial indexing, maxweight pruning, posting-list candidate
generation — is exactly what an online retrieval server needs when the
corpus is fixed and queries stream in. This package amortizes all of it:

- :mod:`repro.serving.index`  — :class:`APSSIndex`: every support structure
  (normalized padded CSR, block maxweight vectors, tile-granular posting
  lists, ``bdims``/``bx`` support compaction, minsize bounds) built ONCE
  per corpus, optionally ``device_put``-sharded across a mesh.
- :mod:`repro.serving.query`  — :func:`query_topk`: the rectangular
  (queries × corpus) pruned scoring path; candidates from the prebuilt
  inverted index, query-side maxweight pruning against precomputed corpus
  block maxima, live tiles only, no index rebuild inside jit.
- :mod:`repro.serving.server` — :class:`RetrievalServer`: request batching
  at step boundaries, one jit'd ``query_topk`` per step, sharded partial
  merge, LRU result cache; :class:`ContinuousRetrievalServer`: the same
  lifecycle with slot-granularity admission — worker threads pull batches
  the moment requests arrive, so a straggling batch no longer quantizes
  every queued request's p99 (DESIGN.md §12).
- :mod:`repro.serving.mutable` — :class:`MutableAPSSIndex`: a live corpus
  over the same machinery — WAL-backed append/delete log, delta similarity
  join keeping a standing top-k graph current at cost proportional to the
  delta, tombstones + threshold-triggered compaction, bit-identical to a
  fresh rebuild after any mutation sequence.

See DESIGN.md §6 for the index layout and the amortization model, §9 for
the live-corpus log and delta join.
"""

from repro.serving.index import APSSIndex, build_index  # noqa: F401
from repro.serving.mutable import MutableAPSSIndex  # noqa: F401
from repro.serving.query import query_topk  # noqa: F401
from repro.serving.server import (  # noqa: F401
    ContinuousRetrievalServer,
    RetrievalResult,
    RetrievalServer,
)
