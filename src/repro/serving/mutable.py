"""MutableAPSSIndex: a live corpus with delta similarity joins (ISSUE 7).

``APSSIndex`` is immutable — every corpus change pays a full rebuild. A
production system has rows arriving continuously, so this module wraps the
same machinery with an append/delete log:

- :meth:`MutableAPSSIndex.append` normalizes the delta, packs it after the
  existing rows, recomputes :class:`~repro.core.pruning.BlockStats` for the
  touched window of blocks only, and runs the **delta join** —
  ``(new × existing) ∪ (new × new)`` — through the rectangular worklist
  scorers to keep a standing top-k similarity graph current at cost
  proportional to the delta, not the corpus.
- :meth:`MutableAPSSIndex.delete` sets tombstones (rows are zeroed on
  device and masked out of every join by a live-row mask honored alongside
  ``live_tile_mask``), repairs exactly the graph rows that referenced a
  deleted neighbor, and triggers :meth:`compact` when the tombstone
  fraction crosses a threshold.

**Bit-equality contract** (the metamorphic harness's invariant): after ANY
interleaving of append/delete/compact, the graph and query results are
bit-identical to a fresh index built from the surviving rows in the same
order. Three design rules make this hold:

1. *Canonical top-k order.* Every merge respects the strict total order
   (value desc, physical position asc): worklists are plain ascending
   ``(i, j)`` (``compact_rect_worklist`` with no ``ub``), the packet fold
   concatenates buffer-before-packet (``lax.top_k`` ties break on earliest
   concat position), and host merges use a stable argsort — equivalent to
   ``lax.top_k``. Appends pack at the end and compaction preserves order,
   so physical order always equals gid order among live rows and the
   tie-break is layout-independent.
2. *Layout-independent score bits.* Dense tiles contract over the fixed
   lane-padded feature axis; sparse tiles score with
   :func:`~repro.core.sparse.gather_dot` over each column row's own ELL
   slots (NOT the per-block support compaction, whose reduction grouping
   depends on which rows share a block). Either way a pair's score depends
   only on the two rows' contents — identical bits before and after
   deletes or compaction. Sparse bit-equality additionally requires the
   same ELL ``cap`` on both sides (pin ``cap=``); widening appends inert
   zero slots but changes the chunk count, which is not guaranteed stable.
3. *Scoring extra tiles is harmless.* Stats are updated exactly for append
   windows and left stale (upper bounds over a superset) across deletes —
   sound either way; a tile live here but dead in the fresh rebuild is
   provably matchless, its packet is all-empty, and empty entries are
   neutralized before every merge.

**Durability** (the robust seam): with ``directory=``, every mutation is
written to a write-ahead log (one ``CheckpointManager`` step per op,
``keep=0`` — digests included) *before* it is applied, and a state
snapshot lands after. Reopening with ``corpus=None`` restores the newest
intact snapshot and replays the log tail — a kill between WAL write and
snapshot resumes bit-identically. A corrupt log entry walks back exactly
that op (``mutable.log_walkback``) instead of poisoning the state.

Retrace discipline: capacity grows in powers of two, deltas are bucketed
to powers of two, worklists are bucket-padded (``pad_worklist``), and the
valid-row count / live mask / window start enter the jitted inners as
traced arguments — repeated same-shape appends trace nothing new
(asserted under an ``obs.compile.assert_no_retrace("serving.mutable")``
contract by ``tests/test_mutable_index.py``).
"""

from __future__ import annotations

import functools
import json
import os
import shutil
import warnings

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.checkpoint import (
    CheckpointCorruptionError,
    CheckpointManager,
    load_checkpoint,
)
from repro.core.apss import normalize_rows
from repro.core.matches import Matches
from repro.core.pruning import (
    BlockStats,
    dense_block_stats,
    live_tile_mask,
    sparse_block_stats,
)
from repro.core.sparse import (
    SparseCorpus,
    from_dense,
    gather_dot,
    normalize_sparse,
    to_dense,
)
from repro.kernels.apss_block.fused import (
    NEG_LARGE,
    _rect_tile_packets,
    _topk_sort,
)
from repro.kernels.apss_block.ops import (
    _pick_bk,
    compact_rect_worklist,
    fold_rect_packets,
    pad_worklist,
)
from repro.obs import compile as obs_compile
from repro.obs import trace
from repro.planner import telemetry
from repro.serving.index import APSSIndex
from repro.serving.query import _query_mask, query_topk

obs_compile.register_entry_points(
    "serving.mutable",
    "mutable_update", "mutable_full_stats", "mutable_self_mask",
    "mutable_zero_rows", "mutable_dense_inner", "mutable_sparse_inner",
    "mutable_sparse_self_inner",
)

_META = "meta.json"


def _p2(x: int) -> int:
    """Smallest power of two ≥ x (x ≥ 1)."""
    return 1 << max(0, (int(x) - 1).bit_length())


# ---------------------------------------------------------------------------
# Jitted state updates (all mark the retrace registry at trace time only)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("block_rows", "wb"))
def _update_dense(C, maxw, mw, mnnz, delta, nv, w0, *, block_rows, wb):
    """Write a bucketed delta at row ``nv``; recompute the ``wb``-block
    stats window starting at row ``w0`` (covers every touched block)."""
    obs_compile.mark("mutable_update")
    C = lax.dynamic_update_slice(C, delta, (nv, 0))
    W = lax.dynamic_slice(C, (w0, 0), (wb * block_rows, C.shape[1]))
    ws = dense_block_stats(W, block_rows)
    b0 = w0 // block_rows
    maxw = lax.dynamic_update_slice(maxw, ws.maxw, (b0, 0))
    mw = lax.dynamic_update_slice(mw, ws.mw, (b0,))
    mnnz = lax.dynamic_update_slice(mnnz, ws.max_nnz, (b0,))
    return C, maxw, mw, mnnz


@functools.partial(jax.jit, static_argnames=("block_rows", "wb", "m"))
def _update_sparse(
    idx, val, nnz, maxw, mw, mnnz, didx, dval, dnnz, nv, w0, *,
    block_rows, wb, m,
):
    """Sparse twin of :func:`_update_dense` over the ELL triple."""
    obs_compile.mark("mutable_update")
    idx = lax.dynamic_update_slice(idx, didx, (nv, 0))
    val = lax.dynamic_update_slice(val, dval, (nv, 0))
    nnz = lax.dynamic_update_slice(nnz, dnnz, (nv,))
    rows = wb * block_rows
    Wi = lax.dynamic_slice(idx, (w0, 0), (rows, idx.shape[1]))
    Wv = lax.dynamic_slice(val, (w0, 0), (rows, val.shape[1]))
    Wn = lax.dynamic_slice(nnz, (w0,), (rows,))
    ws = sparse_block_stats(SparseCorpus(Wi, Wv, Wn, m), block_rows)
    b0 = w0 // block_rows
    maxw = lax.dynamic_update_slice(maxw, ws.maxw, (b0, 0))
    mw = lax.dynamic_update_slice(mw, ws.mw, (b0,))
    mnnz = lax.dynamic_update_slice(mnnz, ws.max_nnz, (b0,))
    return idx, val, nnz, maxw, mw, mnnz


@functools.partial(jax.jit, static_argnames=("block_rows",))
def _full_dense_stats(C, *, block_rows):
    obs_compile.mark("mutable_full_stats")
    return dense_block_stats(C, block_rows)


@functools.partial(jax.jit, static_argnames=("block_rows", "m"))
def _full_sparse_stats(idx, val, nnz, *, block_rows, m):
    obs_compile.mark("mutable_full_stats")
    return sparse_block_stats(SparseCorpus(idx, val, nnz, m), block_rows)


@functools.partial(jax.jit, static_argnames=("threshold", "use_minsize"))
def _self_mask(maxw, mw, mnnz, *, threshold, use_minsize):
    """Corpus-vs-corpus live mask for the reverse join (old × new)."""
    obs_compile.mark("mutable_self_mask")
    st = BlockStats(maxw, mw, mnnz)
    return live_tile_mask(
        st, st, threshold, use_minsize=use_minsize, normalized=True
    )


@jax.jit
def _zero_rows(x, phys):
    """Zero rows at ``phys`` (padded entries point past the array: dropped).

    The pad value MUST be out of range — jnp scatters clamp by default,
    which would silently re-zero the last row instead of no-op'ing.
    """
    obs_compile.mark("mutable_zero_rows")
    return x.at[phys].set(0, mode="drop")


# ---------------------------------------------------------------------------
# Jitted tile scorers. These are the mutable siblings of the
# serving/query.py inners: same packet/fold machinery, but column liveness
# and per-query self-exclusion positions are TRACED vectors (they change
# every mutation; static arguments would retrace per append).
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit,
    static_argnames=("threshold", "k", "block_q", "block_c", "grid_q"),
)
def _mut_dense_inner(
    Qp, C, col_live, qpos, ij, tvalid, *,
    threshold, k, block_q, block_c, grid_q,
):
    """Dense rect scorer with traced liveness + self-exclusion.

    ``qpos[r]`` is query row r's own physical corpus position (−1 = not a
    corpus row): the matching column is masked so a corpus row never
    matches itself. Dead/padding columns (``col_live`` False) are masked to
    ``NEG_LARGE`` so they fail any real threshold, including t ≤ 0.
    """
    obs_compile.mark("mutable_dense_inner")
    m = Qp.shape[1]
    ncap = C.shape[0]
    Qb = Qp.reshape(grid_q, block_q, m)
    Cb = C.reshape(-1, block_c, m)
    liveb = col_live.reshape(-1, block_c)
    qposb = qpos.reshape(grid_q, block_q)

    def tile(_, t):
        i, j = ij[0, t], ij[1, t]
        s = jnp.einsum(
            "qm,cm->qc", Qb[i], Cb[j], preferred_element_type=jnp.float32
        )
        gcol = j * block_c + lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(liveb[j][None, :], s, NEG_LARGE)
        s = jnp.where(qposb[i][:, None] == gcol, NEG_LARGE, s)
        return _, _rect_tile_packets(
            s, j, threshold=threshold, k=k, block_q=block_q,
            block_c=block_c, nc_valid=ncap, topk=_topk_sort,
        )

    _, (fv, fi, fc) = lax.scan(tile, 0, jnp.arange(ij.shape[1]))
    return fold_rect_packets(
        ij, tvalid, fv, fi, fc[..., 0], grid_q=grid_q, block_q=block_q, k=k
    )


@functools.partial(
    jax.jit,
    static_argnames=("threshold", "k", "block_q", "block_c", "grid_q"),
)
def _mut_sparse_inner(
    Qp, idx, val, col_live, qpos, ij, tvalid, *,
    threshold, k, block_q, block_c, grid_q,
):
    """Sparse rect scorer: dense query block × raw ELL corpus block.

    Scores via :func:`gather_dot` over each corpus row's OWN cap slots —
    the reduction grouping is a property of the row, not of the block it
    lives in, so bits survive deletes and compaction (module doc, rule 2).
    """
    obs_compile.mark("mutable_sparse_inner")
    cap = idx.shape[1]
    ncap = idx.shape[0]
    Qb = Qp.astype(jnp.float32).reshape(grid_q, block_q, -1)
    Ib = idx.reshape(-1, block_c, cap)
    Vb = val.reshape(-1, block_c, cap)
    liveb = col_live.reshape(-1, block_c)
    qposb = qpos.reshape(grid_q, block_q)

    def tile(_, t):
        i, j = ij[0, t], ij[1, t]
        s = gather_dot(Qb[i], Ib[j], Vb[j])
        gcol = j * block_c + lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(liveb[j][None, :], s, NEG_LARGE)
        s = jnp.where(qposb[i][:, None] == gcol, NEG_LARGE, s)
        return _, _rect_tile_packets(
            s, j, threshold=threshold, k=k, block_q=block_q,
            block_c=block_c, nc_valid=ncap, topk=_topk_sort,
        )

    _, (fv, fi, fc) = lax.scan(tile, 0, jnp.arange(ij.shape[1]))
    return fold_rect_packets(
        ij, tvalid, fv, fi, fc[..., 0], grid_q=grid_q, block_q=block_q, k=k
    )


@functools.partial(
    jax.jit, static_argnames=("threshold", "k", "block_c", "grid_q", "m")
)
def _mut_sparse_self_inner(
    idx, val, col_live, ij, tvalid, *, threshold, k, block_c, grid_q, m,
):
    """Sparse reverse join: corpus row blocks as queries, densified per
    live tile (O(live tiles · block · m), never O(corpus · m))."""
    obs_compile.mark("mutable_sparse_self_inner")
    cap = idx.shape[1]
    ncap = idx.shape[0]
    Ib = idx.reshape(-1, block_c, cap)
    Vb = val.reshape(-1, block_c, cap)
    liveb = col_live.reshape(-1, block_c)

    def tile(_, t):
        i, j = ij[0, t], ij[1, t]
        r = jnp.arange(block_c, dtype=jnp.int32)[:, None]
        qd = jnp.zeros((block_c, m), jnp.float32).at[r, Ib[i]].add(Vb[i])
        s = gather_dot(qd, Ib[j], Vb[j])
        grow = i * block_c + lax.broadcasted_iota(jnp.int32, s.shape, 0)
        gcol = j * block_c + lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(liveb[j][None, :], s, NEG_LARGE)
        s = jnp.where(grow == gcol, NEG_LARGE, s)
        return _, _rect_tile_packets(
            s, j, threshold=threshold, k=k, block_q=block_c,
            block_c=block_c, nc_valid=ncap, topk=_topk_sort,
        )

    _, (fv, fi, fc) = lax.scan(tile, 0, jnp.arange(ij.shape[1]))
    return fold_rect_packets(
        ij, tvalid, fv, fi, fc[..., 0], grid_q=grid_q, block_q=block_c, k=k
    )


def _np_merge(gv, gi, pv, pi, k):
    """Host merge of graph rows with packet rows, canonical order.

    Stable argsort on negated values == ``lax.top_k`` (k best, ties to the
    earliest concat position). Old entries come first in the concat and
    always reference lower physical positions than a packet's new columns,
    so the tie-break matches the canonical (value desc, position asc).
    """
    av = np.concatenate([gv, pv], axis=1)
    ai = np.concatenate([gi, pi], axis=1)
    sel = np.argsort(-av, axis=1, kind="stable")[:, :k]
    v = np.take_along_axis(av, sel, axis=1)
    i = np.take_along_axis(ai, sel, axis=1)
    return v, np.where(v > -np.inf, i, -1)


class MutableAPSSIndex:
    """Live-corpus APSS index: append/delete log + standing top-k graph.

    Args:
      corpus: optional initial rows — dense ``(n, m)`` or a
        :class:`SparseCorpus`; applied as the first append. Must be None
        when reopening an existing ``directory`` (the state on disk wins).
      threshold / k: the standing graph's match threshold and capacity,
        fixed for the index's lifetime (recorded in ``meta.json``).
      kind: ``"dense"`` / ``"sparse"``; inferred from the first corpus
        when omitted (SparseCorpus ⇒ sparse).
      block_rows: row-block size (power of two) for stats and tiles.
      cap: pin the sparse ELL width. Bit-equality across instances
        requires equal caps (module doc, rule 2); unpinned caps widen on
        demand.
      compact_threshold: tombstone fraction that triggers auto-compaction
        inside :meth:`delete`.
      directory: WAL + snapshot root (``<dir>/log``, ``<dir>/state``);
        None disables durability.
      keep: snapshots kept (the WAL keeps every entry).
      fault_plan: a ``robust.faults.FaultPlan`` — kill seams fire at
        ``"mutable.append"`` (post-WAL, pre-apply) and ``"mutable.commit"``
        (post-apply, pre-snapshot).
    """

    def __init__(
        self,
        corpus=None,
        *,
        threshold: float,
        k: int = 32,
        kind: str | None = None,
        block_rows: int = 64,
        cap: int | None = None,
        compact_threshold: float = 0.25,
        directory: str | None = None,
        keep: int = 3,
        fault_plan=None,
    ):
        if block_rows & (block_rows - 1):
            raise ValueError(f"block_rows must be a power of two: {block_rows}")
        self.threshold = float(threshold)
        self.k = int(k)
        self.block_rows = int(block_rows)
        self.compact_threshold = float(compact_threshold)
        self.fault_plan = fault_plan
        self._kind = kind
        self._cap_param = cap
        self._m = None
        self._mlanes = None
        self._cap = cap
        # device state (None until the first append / restore)
        self._C = None
        self._idx = self._val = self._nnz = None
        self._maxw = self._mw = self._mnnz = None
        # host state
        self._ncap = 0
        self._nv = 0
        self._ndead = 0
        self._next_gid = 0
        self._gids = np.zeros(0, np.int64)
        self._live = np.zeros(0, bool)
        self._phys: dict[int, int] = {}
        self._gv = np.zeros((0, self.k), np.float32)
        self._gi = np.zeros((0, self.k), np.int64)
        self._gc = np.zeros(0, np.int64)
        self.version = 0
        self._op_seq = 0
        self._replaying = False
        self._view = None
        self._view_version = -1
        # durability
        self._dir = directory
        self._log_mgr = self._state_mgr = None
        if directory is not None:
            self._log_dir = os.path.join(directory, "log")
            self._state_dir = os.path.join(directory, "state")
            self._log_mgr = CheckpointManager(self._log_dir, keep=0)
            self._state_mgr = CheckpointManager(self._state_dir, keep=keep)
            self._check_meta()
        has_state = self._log_mgr is not None and (
            self._log_mgr.all_steps() or self._state_mgr.all_steps()
        )
        if has_state:
            if corpus is not None:
                raise ValueError(
                    f"directory {directory} already holds index state; "
                    "pass corpus=None to resume"
                )
            self._restore_and_replay()
        elif corpus is not None:
            self.append(corpus)

    # -- properties ---------------------------------------------------------

    @property
    def m(self) -> int | None:
        return self._m

    @property
    def kind(self) -> str | None:
        return self._kind

    @property
    def is_sparse(self) -> bool:
        return self._kind == "sparse"

    @property
    def n(self) -> int:
        """Live row count."""
        return self._nv - self._ndead

    def __repr__(self) -> str:
        return (
            f"MutableAPSSIndex(kind={self._kind}, live={self.n}, "
            f"dead={self._ndead}, version={self.version})"
        )

    # -- meta / durability helpers ------------------------------------------

    def _meta_dict(self) -> dict:
        return {
            "kind": self._kind, "m": self._m, "k": self.k,
            "threshold": self.threshold, "block_rows": self.block_rows,
            "cap": self._cap_param,
            "compact_threshold": self.compact_threshold,
        }

    def _check_meta(self) -> None:
        path = os.path.join(self._dir, _META)
        if not os.path.exists(path):
            return
        with open(path) as f:
            meta = json.load(f)
        for key in ("k", "threshold", "block_rows", "compact_threshold"):
            if meta[key] != getattr(self, key):
                raise ValueError(
                    f"meta mismatch for {key}: directory has {meta[key]}, "
                    f"constructor got {getattr(self, key)}"
                )
        if self._kind is not None and meta["kind"] != self._kind:
            raise ValueError(
                f"meta mismatch for kind: directory has {meta['kind']}, "
                f"constructor got {self._kind}"
            )
        self._kind = meta["kind"]
        self._m = meta["m"]
        self._cap_param = meta["cap"]
        if self._cap is None:
            self._cap = meta["cap"]

    def _write_meta(self) -> None:
        if self._dir is None:
            return
        path = os.path.join(self._dir, _META)
        if not os.path.exists(path):
            with open(path, "w") as f:
                json.dump(self._meta_dict(), f)

    def _log(self, entry: dict, seq: int) -> None:
        if self._log_mgr is not None and not self._replaying:
            self._log_mgr.save(entry, seq)

    def _kill(self, seq: int, scope: str) -> None:
        if self.fault_plan is not None and not self._replaying:
            self.fault_plan.kill_point(seq, scope)

    def _state_dict(self) -> dict:
        d = {
            "gids": self._gids, "live": self._live,
            "gv": self._gv, "gi": self._gi, "gc": self._gc,
            "maxw": np.asarray(self._maxw), "mw": np.asarray(self._mw),
            "mnnz": np.asarray(self._mnnz),
            "meta_ints": np.array(
                [self._nv, self._next_gid, self._op_seq, self.version,
                 self._ndead], np.int64,
            ),
        }
        if self.is_sparse:
            d["sidx"] = np.asarray(self._idx)
            d["sval"] = np.asarray(self._val)
            d["snnz"] = np.asarray(self._nnz)
        else:
            d["C"] = np.asarray(self._C)
        return d

    def _load_state(self, d: dict) -> None:
        self._gids = np.asarray(d["gids"], np.int64)
        self._live = np.asarray(d["live"], bool)
        self._gv = np.asarray(d["gv"], np.float32)
        self._gi = np.asarray(d["gi"], np.int64)
        self._gc = np.asarray(d["gc"], np.int64)
        self._maxw = jnp.asarray(d["maxw"])
        self._mw = jnp.asarray(d["mw"])
        self._mnnz = jnp.asarray(d["mnnz"])
        nv, ng, seq, ver, nd = (int(x) for x in d["meta_ints"])
        self._nv, self._next_gid, self._op_seq = nv, ng, seq
        self.version, self._ndead = ver, nd
        if self.is_sparse:
            self._idx = jnp.asarray(d["sidx"])
            self._val = jnp.asarray(d["sval"])
            self._nnz = jnp.asarray(d["snnz"])
            self._ncap = self._idx.shape[0]
            self._cap = self._idx.shape[1]
        else:
            self._C = jnp.asarray(d["C"])
            self._ncap = self._C.shape[0]
            self._mlanes = self._C.shape[1]
        self._phys = {
            int(g): int(p)
            for p, g in enumerate(self._gids)
            if g >= 0 and self._live[p]
        }

    def _snapshot(self) -> None:
        if self._state_mgr is not None:
            self._state_mgr.save(self._state_dict(), self._op_seq)

    def _restore_and_replay(self) -> None:
        with trace.span("mutable/replay"):
            self._restore_and_replay_inner()

    def _restore_and_replay_inner(self) -> None:
        latest = self._state_mgr.latest_step()
        state, step = self._state_mgr.restore(fallback=True)
        if state is not None:
            self._load_state(state)
            if step != latest:
                telemetry.incr("mutable.restore_fallback")
        replayed = 0
        for seq in sorted(self._log_mgr.all_steps()):
            if seq <= self._op_seq:
                continue
            if seq != self._op_seq + 1:
                break  # a hole in the log: stop at the contiguous prefix
            try:
                entry = load_checkpoint(self._log_dir, seq)
            except CheckpointCorruptionError as e:
                warnings.warn(
                    f"mutation log entry {seq} corrupt ({e}); "
                    "walking back this op",
                    stacklevel=2,
                )
                telemetry.incr("mutable.log_walkback")
                break
            op = int(np.asarray(entry["op"]))
            self._replaying = True
            try:
                if op == 1:
                    self._apply_append(np.asarray(entry["rows"], np.float32))
                elif op == 2:
                    self._apply_delete(np.asarray(entry["ids"], np.int64))
                elif op == 3:
                    self._compact()
                else:  # pragma: no cover - defensive
                    raise ValueError(f"unknown log op {op}")
            finally:
                self._replaying = False
            self._op_seq = seq
            replayed += 1
        if replayed:
            telemetry.incr("mutable.replayed_ops", replayed)
        # Drop log entries past the applied prefix (the walked-back op and
        # anything after): future ops must be able to reuse those steps —
        # CheckpointManager.save skips existing step dirs.
        for s in self._log_mgr.all_steps():
            if s > self._op_seq:
                shutil.rmtree(
                    os.path.join(self._log_dir, f"step_{s:010d}"),
                    ignore_errors=True,
                )
        if replayed:
            self._snapshot()

    # -- layout / capacity --------------------------------------------------

    def _coerce_rows(self, rows) -> np.ndarray:
        """Any accepted delta → raw (pre-normalization) dense f32 host array.

        The WAL stores exactly this canonical payload, so replay applies
        the same bytes the original call did.
        """
        if isinstance(rows, SparseCorpus):
            if self._kind is None:
                self._kind = "sparse"
            raw = np.asarray(to_dense(rows), np.float32)
        else:
            raw = np.asarray(rows, np.float32)
        if raw.ndim != 2:
            raise ValueError(f"rows must be 2-D, got shape {raw.shape}")
        if not np.all(np.isfinite(raw)):
            raise ValueError("rows contain non-finite values (NaN/inf)")
        if self._kind is None:
            self._kind = "dense"
        if self._m is None:
            self._m = int(raw.shape[1])
            self._write_meta()
        if raw.shape[1] != self._m:
            raise ValueError(f"rows dim {raw.shape[1]} != index m {self._m}")
        return raw

    def _init_arrays(self) -> None:
        if self._ncap:
            return
        self._ncap = self.block_rows
        nb = self._ncap // self.block_rows
        if self.is_sparse:
            cap = self._cap or 1
            self._cap = cap
            self._idx = jnp.zeros((self._ncap, cap), jnp.int32)
            self._val = jnp.zeros((self._ncap, cap), jnp.float32)
            self._nnz = jnp.zeros((self._ncap,), jnp.int32)
            width = self._m
        else:
            self._mlanes = self._m + (-self._m) % _pick_bk(self._m, 512)
            self._C = jnp.zeros((self._ncap, self._mlanes), jnp.float32)
            width = self._mlanes
        self._maxw = jnp.zeros((nb, width), jnp.float32)
        self._mw = jnp.zeros((nb,), jnp.float32)
        self._mnnz = jnp.zeros((nb,), jnp.int32)
        self._grow_host(self._ncap)

    def _grow_host(self, ncap: int) -> None:
        old = self._gids.shape[0]
        if ncap <= old:
            return
        pad = ncap - old
        self._gids = np.concatenate([self._gids, np.full(pad, -1, np.int64)])
        self._live = np.concatenate([self._live, np.zeros(pad, bool)])
        self._gv = np.concatenate(
            [self._gv, np.full((pad, self.k), -np.inf, np.float32)]
        )
        self._gi = np.concatenate(
            [self._gi, np.full((pad, self.k), -1, np.int64)]
        )
        self._gc = np.concatenate([self._gc, np.zeros(pad, np.int64)])

    def _ensure_capacity(self, need: int) -> None:
        """Grow every capacity array to a power-of-two row count ≥ need.

        MUST run before the delta's ``dynamic_update_slice`` — JAX clamps
        start indices, so an overflowing write would silently shift.
        """
        if need <= self._ncap:
            return
        ncap = self._ncap
        while ncap < need:
            ncap *= 2
        pad = ncap - self._ncap
        nbpad = pad // self.block_rows
        if self.is_sparse:
            self._idx = jnp.pad(self._idx, ((0, pad), (0, 0)))
            self._val = jnp.pad(self._val, ((0, pad), (0, 0)))
            self._nnz = jnp.pad(self._nnz, (0, pad))
        else:
            self._C = jnp.pad(self._C, ((0, pad), (0, 0)))
        self._maxw = jnp.pad(self._maxw, ((0, nbpad), (0, 0)))
        self._mw = jnp.pad(self._mw, (0, nbpad))
        self._mnnz = jnp.pad(self._mnnz, (0, nbpad))
        self._grow_host(ncap)
        self._ncap = ncap

    def _widen_cap(self, need: int) -> None:
        """Widen the ELL layout with inert zero slots (sparse only).

        Documented caveat: widening changes gather_dot's chunk count, so
        bit-equality across different realized caps is NOT guaranteed —
        pin ``cap=`` when bit-stability matters.
        """
        if need <= self._cap:
            return
        pad = need - self._cap
        self._idx = jnp.pad(self._idx, ((0, 0), (0, pad)))
        self._val = jnp.pad(self._val, ((0, 0), (0, pad)))
        self._cap = need

    def _stats(self) -> BlockStats:
        return BlockStats(self._maxw, self._mw, self._mnnz)

    def _gid_of(self, pi: np.ndarray) -> np.ndarray:
        """Physical column ids (−1 empty) → global ids."""
        return np.where(pi >= 0, self._gids[np.maximum(pi, 0)], -1)

    # -- public mutations ---------------------------------------------------

    def append(self, rows) -> list[int]:
        """Append a batch of rows; returns their new global ids.

        WAL-first: the raw delta is logged, then applied (normalize → pack
        → window stats → delta join into the graph), then snapshotted.
        An empty delta is a no-op (no log entry, no version bump).
        """
        raw = self._coerce_rows(rows)
        if raw.shape[0] == 0:
            return []
        with trace.span("mutable/append", rows=int(raw.shape[0])):
            seq = self._op_seq + 1
            self._log({"op": np.int64(1), "rows": raw}, seq)
            self._kill(seq, "mutable.append")
            gids = self._apply_append(raw)
            self._op_seq = seq
            self._kill(seq, "mutable.commit")
            self._snapshot()
            telemetry.incr("serving.appends")
            return gids

    def delete(self, ids) -> int:
        """Tombstone rows by global id; repairs the graph exactly.

        Raises ``KeyError`` for unknown/dead ids (before logging anything).
        Returns the number of rows deleted. Auto-compacts when the dead
        fraction reaches ``compact_threshold``.
        """
        ids = np.asarray(list(ids), np.int64).reshape(-1)
        if len(set(ids.tolist())) != ids.shape[0]:
            raise ValueError("duplicate ids in delete batch")
        for g in ids:
            if int(g) not in self._phys:
                raise KeyError(f"unknown or already-deleted id {int(g)}")
        if ids.shape[0] == 0:
            return 0
        with trace.span("mutable/delete", rows=int(ids.shape[0])):
            seq = self._op_seq + 1
            self._log({"op": np.int64(2), "ids": ids}, seq)
            self._kill(seq, "mutable.append")
            self._apply_delete(ids)
            self._op_seq = seq
            self._kill(seq, "mutable.commit")
            self._snapshot()
            telemetry.incr("serving.deletes")
            return int(ids.shape[0])

    def compact(self) -> None:
        """Rewrite live rows contiguously (order preserved) and rebuild
        stats; logged as its own op so resume replays it."""
        with trace.span("mutable/compact"):
            seq = self._op_seq + 1
            self._log({"op": np.int64(3)}, seq)
            self._kill(seq, "mutable.append")
            self._compact()
            self._op_seq = seq
            self._kill(seq, "mutable.commit")
            self._snapshot()

    # -- mutation internals -------------------------------------------------

    def _apply_append(self, raw: np.ndarray) -> list[int]:
        self._coerce_rows(raw)  # replay path: sets kind/m/meta
        self._init_arrays()
        rb = raw.shape[0]
        rbp = _p2(max(8, rb))
        br = self.block_rows
        nv0 = self._nv
        self._ensure_capacity(nv0 + rbp)
        nb = self._ncap // br
        # window of blocks whose stats the delta can touch (+2, not +1:
        # a sub-block delta can still straddle a block boundary)
        wb = min(nb, rbp // br + 2)
        w0 = max(0, min(nv0 // br, nb - wb)) * br

        if self.is_sparse:
            sp = from_dense(raw)
            self._widen_cap(sp.cap)
            if sp.cap < self._cap:
                sp = SparseCorpus(
                    jnp.pad(sp.indices, ((0, 0), (0, self._cap - sp.cap))),
                    jnp.pad(sp.values, ((0, 0), (0, self._cap - sp.cap))),
                    sp.nnz, self._m,
                )
            spn = normalize_sparse(sp)
            didx = jnp.pad(spn.indices, ((0, rbp - rb), (0, 0)))
            dval = jnp.pad(spn.values, ((0, rbp - rb), (0, 0)))
            dnnz = jnp.pad(spn.nnz, (0, rbp - rb))
            (self._idx, self._val, self._nnz,
             self._maxw, self._mw, self._mnnz) = _update_sparse(
                self._idx, self._val, self._nnz,
                self._maxw, self._mw, self._mnnz,
                didx, dval, dnnz, jnp.int32(nv0), jnp.int32(w0),
                block_rows=br, wb=wb, m=self._m,
            )
            Qp = jnp.pad(to_dense(spn), ((0, rbp - rb), (0, 0)))
            depth = self._cap
        else:
            deltan = np.asarray(
                normalize_rows(jnp.asarray(raw, jnp.float32))
            )
            deltap = np.zeros((rbp, self._mlanes), np.float32)
            deltap[:rb, : self._m] = deltan
            deltap = jnp.asarray(deltap)
            self._C, self._maxw, self._mw, self._mnnz = _update_dense(
                self._C, self._maxw, self._mw, self._mnnz,
                deltap, jnp.int32(nv0), jnp.int32(w0),
                block_rows=br, wb=wb,
            )
            Qp = deltap
            depth = self._mlanes

        gids = list(range(self._next_gid, self._next_gid + rb))
        self._gids[nv0:nv0 + rb] = gids
        self._live[nv0:nv0 + rb] = True
        for g, p in zip(gids, range(nv0, nv0 + rb)):
            self._phys[g] = p
        self._next_gid += rb
        self._nv = nv0 + rb
        self.version += 1

        # ---- forward join: new rows × all live rows (incl. new) ----
        t = self.threshold
        bqf = min(rbp, br)
        gqf = rbp // bqf
        mask = np.asarray(_query_mask(
            Qp, self._stats(), threshold=t, block_q=bqf,
            use_minsize=True, normalized=True,
        )[0])
        col_any = self._live.reshape(nb, br).any(axis=1)
        qpos_f = np.full(rbp, -1, np.int32)
        qpos_f[:rb] = nv0 + np.arange(rb)
        wlf = compact_rect_worklist(mask & col_any[None, :])
        tf = 0
        if wlf is not None:
            tf = wlf.shape[1]
            ij, tv = pad_worklist(wlf)
            args = (jnp.asarray(self._live), jnp.asarray(qpos_f),
                    jnp.asarray(ij), jnp.asarray(tv))
            inner_kwargs = dict(
                threshold=t, k=self.k, block_q=bqf, block_c=br, grid_q=gqf,
            )
            if self.is_sparse:
                obs_compile.offer_capture(
                    "mutable.sparse_inner", _mut_sparse_inner,
                    Qp, self._idx, self._val, *args, **inner_kwargs,
                )
                fv, fi, fc = _mut_sparse_inner(
                    Qp, self._idx, self._val, *args, **inner_kwargs,
                )
            else:
                obs_compile.offer_capture(
                    "mutable.dense_inner", _mut_dense_inner,
                    Qp, self._C, *args, **inner_kwargs,
                )
                fv, fi, fc = _mut_dense_inner(
                    Qp, self._C, *args, **inner_kwargs,
                )
            pv = np.asarray(fv)[:rb]
            pi = np.asarray(fi)[:rb]
            pc = np.asarray(fc)[:rb]
        else:
            pv = np.full((rb, self.k), -np.inf, np.float32)
            pi = np.full((rb, self.k), -1, np.int32)
            pc = np.zeros(rb, np.int32)
        self._gv[nv0:self._nv] = pv
        self._gi[nv0:self._nv] = self._gid_of(pi)
        self._gc[nv0:self._nv] = pc

        # ---- reverse join: live OLD rows × new rows ----
        tr = 0
        if nv0 > 0:
            old_live = self._live.copy()
            old_live[nv0:] = False
            if old_live.any():
                mask_s = np.asarray(_self_mask(
                    self._maxw, self._mw, self._mnnz,
                    threshold=t, use_minsize=True,
                ))
                row_any_old = old_live.reshape(nb, br).any(axis=1)
                col_new = np.zeros(nb, bool)
                col_new[nv0 // br:(self._nv - 1) // br + 1] = True
                wlr = compact_rect_worklist(
                    mask_s & row_any_old[:, None] & col_new[None, :]
                )
                if wlr is not None:
                    tr = wlr.shape[1]
                    col_live_rev = np.zeros(self._ncap, bool)
                    col_live_rev[nv0:self._nv] = True
                    ij, tv = pad_worklist(wlr)
                    clr = jnp.asarray(col_live_rev)
                    ijj, tvj = jnp.asarray(ij), jnp.asarray(tv)
                    if self.is_sparse:
                        rv, ri, rc = _mut_sparse_self_inner(
                            self._idx, self._val, clr, ijj, tvj,
                            threshold=t, k=self.k, block_c=br, grid_q=nb,
                            m=self._m,
                        )
                    else:
                        rv, ri, rc = _mut_dense_inner(
                            self._C, self._C, clr,
                            jnp.arange(self._ncap, dtype=jnp.int32),
                            ijj, tvj,
                            threshold=t, k=self.k, block_q=br, block_c=br,
                            grid_q=nb,
                        )
                    # merge ONLY into live old rows: new × new is already
                    # covered by the forward join (no double count)
                    rows = np.nonzero(old_live)[0]
                    rv = np.asarray(rv)[rows]
                    ri = self._gid_of(np.asarray(ri)[rows])
                    rc = np.asarray(rc)[rows]
                    v, i = _np_merge(
                        self._gv[rows], self._gi[rows], rv, ri, self.k
                    )
                    self._gv[rows] = v
                    self._gi[rows] = i
                    self._gc[rows] += rc

        if telemetry.enabled():
            total = mask.size + (nb * nb if nv0 > 0 else 0)
            telemetry.record(telemetry.ApssStats(
                variant="serving/delta-join",
                n=self.n, m=self._m, block_rows=br, sparse=self.is_sparse,
                flops=2.0 * (tf * bqf + tr * br) * br * depth,
                live_tiles=tf + tr, total_tiles=total,
                extra={
                    "delta": rb,
                    "live_fraction_rows": self.n / max(1, self._nv),
                    "model_flops": telemetry.delta_join_flops(
                        rb, self.n, depth
                    ),
                },
            ))
        return gids

    def _apply_delete(self, ids: np.ndarray) -> None:
        phys = np.array([self._phys[int(g)] for g in ids], np.int64)
        dead_set = {int(g) for g in ids}
        # A deleted row whose exact count exceeds k has neighbors missing
        # from its buffer — the affected set is unknowable, so rescore
        # every surviving row (exactness beats delta cost here).
        full_rescore = bool(np.any(self._gc[phys] > self.k))
        if full_rescore:
            affected = [
                int(g) for g in self._phys if int(g) not in dead_set
            ]
        else:
            neigh: set[int] = set()
            for p in phys:
                neigh.update(
                    int(g) for g in self._gi[p] if g >= 0
                )
            affected = [
                g for g in neigh
                if g not in dead_set and g in self._phys
            ]
        # tombstone + zero device rows (zeroed rows keep stale stats sound:
        # stats stay upper bounds over a superset)
        self._live[phys] = False
        self._gids[phys] = -1
        for g in ids:
            del self._phys[int(g)]
        self._ndead += int(phys.shape[0])
        pp = np.full(_p2(max(8, phys.shape[0])), self._ncap, np.int64)
        pp[: phys.shape[0]] = phys
        ppj = jnp.asarray(pp, jnp.int32)
        if self.is_sparse:
            self._idx = _zero_rows(self._idx, ppj)
            self._val = _zero_rows(self._val, ppj)
            self._nnz = _zero_rows(self._nnz, ppj)
        else:
            self._C = _zero_rows(self._C, ppj)
        self._gv[phys] = -np.inf
        self._gi[phys] = -1
        self._gc[phys] = 0
        self.version += 1

        if affected:
            aff_phys = np.sort(
                np.array([self._phys[g] for g in affected], np.int64)
            )
            na = aff_phys.shape[0]
            abp = _p2(max(8, na))
            idxp = np.zeros(abp, np.int32)
            idxp[:na] = aff_phys
            qpos = np.full(abp, -1, np.int32)
            qpos[:na] = aff_phys
            ij_take = jnp.asarray(idxp)
            if self.is_sparse:
                qi = jnp.take(self._idx, ij_take, axis=0)
                qv = jnp.take(self._val, ij_take, axis=0)
                r = jnp.arange(abp, dtype=jnp.int32)[:, None]
                Qa = jnp.zeros((abp, self._m), jnp.float32).at[r, qi].add(qv)
            else:
                Qa = jnp.take(self._C, ij_take, axis=0)
            bqa = min(abp, self.block_rows)
            gqa = abp // bqa
            nb = self._ncap // self.block_rows
            mask = np.asarray(_query_mask(
                Qa, self._stats(), threshold=self.threshold, block_q=bqa,
                use_minsize=True, normalized=True,
            )[0])
            col_any = self._live.reshape(nb, self.block_rows).any(axis=1)
            wl = compact_rect_worklist(mask & col_any[None, :])
            if wl is not None:
                ij, tv = pad_worklist(wl)
                args = (jnp.asarray(self._live), jnp.asarray(qpos),
                        jnp.asarray(ij), jnp.asarray(tv))
                if self.is_sparse:
                    fv, fi, fc = _mut_sparse_inner(
                        Qa, self._idx, self._val, *args,
                        threshold=self.threshold, k=self.k, block_q=bqa,
                        block_c=self.block_rows, grid_q=gqa,
                    )
                else:
                    fv, fi, fc = _mut_dense_inner(
                        Qa, self._C, *args,
                        threshold=self.threshold, k=self.k, block_q=bqa,
                        block_c=self.block_rows, grid_q=gqa,
                    )
                nv_ = np.asarray(fv)[:na]
                ni = self._gid_of(np.asarray(fi)[:na])
                nc = np.asarray(fc)[:na]
            else:
                nv_ = np.full((na, self.k), -np.inf, np.float32)
                ni = np.full((na, self.k), -1, np.int64)
                nc = np.zeros(na, np.int64)
            # REPLACE the affected rows: a fresh canonical rescore equals
            # what a from-scratch rebuild would compute for them
            self._gv[aff_phys] = nv_
            self._gi[aff_phys] = ni
            self._gc[aff_phys] = nc

        if self._nv and self._ndead / self._nv >= self.compact_threshold:
            self._compact()

    def _compact(self) -> None:
        """Pack live rows contiguously in physical order (gid order) and
        rebuild exact stats. No rescoring: row contents, gids, and the
        graph are all preserved — only physical positions change, and
        order-preservation keeps the canonical tie-break intact."""
        self._init_arrays()
        order = np.nonzero(self._live)[0]
        nl = order.shape[0]
        ncap, br = self._ncap, self.block_rows
        if self.is_sparse:
            si = np.zeros((ncap, self._cap), np.int32)
            sv = np.zeros((ncap, self._cap), np.float32)
            sn = np.zeros(ncap, np.int32)
            si[:nl] = np.asarray(self._idx)[order]
            sv[:nl] = np.asarray(self._val)[order]
            sn[:nl] = np.asarray(self._nnz)[order]
            self._idx = jnp.asarray(si)
            self._val = jnp.asarray(sv)
            self._nnz = jnp.asarray(sn)
            st = _full_sparse_stats(
                self._idx, self._val, self._nnz, block_rows=br, m=self._m
            )
        else:
            C = np.zeros((ncap, self._mlanes), np.float32)
            C[:nl] = np.asarray(self._C)[order]
            self._C = jnp.asarray(C)
            st = _full_dense_stats(self._C, block_rows=br)
        self._maxw, self._mw, self._mnnz = st.maxw, st.mw, st.max_nnz
        gids = np.full(ncap, -1, np.int64)
        gids[:nl] = self._gids[order]
        live = np.zeros(ncap, bool)
        live[:nl] = True
        gv = np.full((ncap, self.k), -np.inf, np.float32)
        gi = np.full((ncap, self.k), -1, np.int64)
        gc = np.zeros(ncap, np.int64)
        gv[:nl] = self._gv[order]
        gi[:nl] = self._gi[order]
        gc[:nl] = self._gc[order]
        self._gids, self._live = gids, live
        self._gv, self._gi, self._gc = gv, gi, gc
        self._phys = {int(g): p for p, g in enumerate(gids[:nl])}
        self._nv, self._ndead = nl, 0
        self.version += 1
        telemetry.incr("serving.compactions")

    # -- queries ------------------------------------------------------------

    def graph(self) -> tuple[np.ndarray, Matches]:
        """The standing similarity graph over live rows.

        Returns ``(gids, Matches)``: live global ids in physical (== gid)
        order, and per-row top-k matches whose indices are GLOBAL ids
        (int64, −1 padded) with exact counts.
        """
        order = np.nonzero(self._live)[0]
        return self._gids[order].copy(), Matches(
            values=self._gv[order].copy(),
            indices=self._gi[order].copy(),
            counts=self._gc[order].copy(),
        )

    def as_index(self) -> APSSIndex:
        """A read-only :class:`APSSIndex` view for the kernel query path
        (dense only; zero-copy — dead rows are already zeroed)."""
        if self.is_sparse:
            raise NotImplementedError(
                "sparse kernel path needs the per-block support compaction, "
                "which is not layout-stable under mutation; use the XLA path"
            )
        if self._view is None or self._view_version != self.version:
            self._view = APSSIndex(
                self._C, self._stats(), None, None,
                n=self._nv, m=self._m, block_rows=self.block_rows,
                kind="dense", normalized=True,
            )
            self._view_version = self.version
        return self._view

    def query(
        self,
        Q,
        threshold: float | None = None,
        k: int | None = None,
        *,
        block_q: int | None = None,
        use_kernel: bool = False,
        use_minsize: bool = True,
        interpret: bool | None = None,
    ) -> Matches:
        """Top-k live neighbors for a dense query batch ``(B, m)``.

        Returns host Matches whose indices are GLOBAL ids (int64). The XLA
        path masks dead rows explicitly (sound at any threshold); the
        kernel path serves through :meth:`as_index`, where dead rows are
        merely zero vectors, so it requires ``threshold > 0``.
        """
        t = self.threshold if threshold is None else float(threshold)
        kk = self.k if k is None else int(k)
        if isinstance(Q, SparseCorpus):
            Q = to_dense(Q)
        Q = np.asarray(Q, np.float32)
        if Q.ndim != 2 or (self._m is not None and Q.shape[1] != self._m):
            raise ValueError(f"Q must be (B, {self._m}); got {Q.shape}")
        B = Q.shape[0]
        if self.n == 0 or B == 0:
            return Matches(
                values=np.full((B, kk), -np.inf, np.float32),
                indices=np.full((B, kk), -1, np.int64),
                counts=np.zeros(B, np.int32),
            )
        if use_kernel:
            if t <= 0:
                raise ValueError(
                    "use_kernel requires threshold > 0: the kernel view "
                    "cannot mask tombstoned (zeroed) rows, which match "
                    "everything at t <= 0"
                )
            m = query_topk(
                self.as_index(), jnp.asarray(Q), t, kk,
                block_q=block_q or 128, use_kernel=True,
                use_minsize=use_minsize, interpret=interpret,
            )
            pi = np.asarray(m.indices)
            return Matches(
                values=np.asarray(m.values),
                indices=self._gid_of(pi),
                counts=np.asarray(m.counts),
            )
        br = self.block_rows
        Bp = _p2(max(8, B))
        bq = min(Bp, _p2(block_q) if block_q else br, br)
        gq = Bp // bq
        width = self._m if self.is_sparse else self._mlanes
        Qp = np.zeros((Bp, width), np.float32)
        Qp[:B, : self._m] = Q
        Qp = jnp.asarray(Qp)
        nb = self._ncap // br
        mask = np.asarray(_query_mask(
            Qp, self._stats(), threshold=t, block_q=bq,
            use_minsize=use_minsize, normalized=True,
        )[0])
        col_any = self._live.reshape(nb, br).any(axis=1)
        wl = compact_rect_worklist(mask & col_any[None, :])
        if wl is None:
            return Matches(
                values=np.full((B, kk), -np.inf, np.float32),
                indices=np.full((B, kk), -1, np.int64),
                counts=np.zeros(B, np.int32),
            )
        ij, tv = pad_worklist(wl)
        args = (
            jnp.asarray(self._live),
            jnp.full((Bp,), -1, jnp.int32),
            jnp.asarray(ij), jnp.asarray(tv),
        )
        if self.is_sparse:
            fv, fi, fc = _mut_sparse_inner(
                Qp, self._idx, self._val, *args,
                threshold=t, k=kk, block_q=bq, block_c=br, grid_q=gq,
            )
        else:
            fv, fi, fc = _mut_dense_inner(
                Qp, self._C, *args,
                threshold=t, k=kk, block_q=bq, block_c=br, grid_q=gq,
            )
        return Matches(
            values=np.asarray(fv)[:B],
            indices=self._gid_of(np.asarray(fi)[:B]),
            counts=np.asarray(fc)[:B],
        )
