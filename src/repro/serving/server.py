"""RetrievalServer: batched query-time APSS over a build-once index.

Modeled on ``launch.serve.LMServer``'s slot/latch pattern: requests join a
padded query batch at the next ``step()`` boundary, ONE jit'd
:func:`~repro.serving.query.query_topk` call serves the whole batch (the
batch is always padded to ``max_batch`` rows, so the compiled executable is
reused forever), and per-request results latch into their slots. A tiny
LRU cache keyed on the query-vector hash short-circuits repeat queries —
the classic head-of-zipf serving win — without touching the device.

For mesh-sharded indexes the underlying ``query_topk`` runs the per-shard
scoring path and merges partial top-k host-side; the server is agnostic.

Degraded-mode contract (DESIGN.md §8): under overload or scoring failure
the server prefers a *worse answer now* over a perfect answer too late —

- **admission control**: past ``max_pending`` queued requests, new submits
  are shed immediately (``status="shed"``, empty result);
- **deadlines**: requests whose deadline lapses before their batch is
  scored are shed at the step boundary; in-budget requests in the same
  batch still get exact results;
- **degradation ladder**: each scoring tier (Pallas kernel → XLA scan) is
  retried ``max_retries`` times with exponential backoff, then the server
  degrades to the next tier; when every tier fails, a stale LRU entry (one
  past ``ttl_s``, ineligible for fresh hits) still answers
  (``status="stale"``), and only cache misses fail.

Every event increments a counter here AND in the active telemetry log
(``planner.telemetry.incr``: ``serving.shed`` / ``serving.degraded`` /
``serving.retries`` / ``serving.stale``). Adversarial input is rejected at
``submit`` — non-numeric dtypes and non-finite (NaN/inf) queries raise
``ValueError``; all-zero queries under ``normalize=True`` are served (they
normalize to zero, match nothing, and return an empty result).
"""

from __future__ import annotations

import collections
import hashlib
import threading
import time
from typing import NamedTuple, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.apss import normalize_rows
from repro.obs import metrics, recorder, trace
from repro.planner import telemetry
from repro.serving.index import APSSIndex
from repro.serving.mutable import MutableAPSSIndex
from repro.serving.query import query_topk


class RetrievalResult(NamedTuple):
    """One request's top-k neighbors (host numpy; ready to serialize)."""

    values: np.ndarray   # (k,) f32 similarities, -inf padded
    indices: np.ndarray  # (k,) i32 corpus row ids, -1 padded
    count: int           # exact #corpus rows ≥ threshold (may exceed k)
    cached: bool         # served from the LRU cache
    status: str = "ok"   # "ok" | "shed" | "stale" | "failed"


class ServerStats(NamedTuple):
    requests: int
    steps: int
    cache_hits: int
    shed: int = 0        # admission-control + deadline rejections
    degraded: int = 0    # scoring-tier downgrades (kernel → XLA → stale)
    retries: int = 0     # same-tier retry attempts
    stale: int = 0       # answers served from an expired cache entry


class RetrievalServer:
    """Batched online retrieval over a prebuilt :class:`APSSIndex`.

    Args:
      index: built once via :func:`~repro.serving.index.build_index`, or a
        live :class:`~repro.serving.mutable.MutableAPSSIndex` — mutations
        bump its ``version``, which invalidates every cached answer
        (result indices are then global row ids, stable across
        compaction).
      threshold / k: fixed per server (one compiled executable).
      max_batch: padded batch width; requests beyond it wait for the next
        step boundary.
      normalize: L2-normalize incoming queries (the paper's ``||x|| = 1``
        contract; cache keys hash the raw bytes BEFORE normalization so
        clients need not normalize consistently).
      cache_size: LRU entries; 0 disables the cache.
      use_kernel: route tile scoring through the rectangular Pallas
        kernels (single-host indexes; TPU); on failure the server degrades
        to the XLA scan tier instead of erroring.
      deadline_s: default per-request deadline (None = no deadline);
        requests not scored within it are shed at the next step boundary.
      max_pending: admission budget — submits past this queue depth are
        shed immediately (None = unbounded).
      max_retries / backoff_s: per-tier retry policy around the jitted
        scoring call (exponential backoff starting at ``backoff_s``).
      ttl_s: cache freshness horizon. Entries older than this no longer
        satisfy submit-time hits but remain eligible for the stale-answer
        tier when every scoring tier is down (None = never stale).
      fault_plan: a ``robust.faults.FaultPlan`` for chaos testing — armed
        ``delay`` faults (scope ``"serving"``) stall the step like a slow
        shard; ``error`` faults (scope ``"serving.kernel"`` /
        ``"serving.xla"``) fail scoring tiers.
    """

    def __init__(
        self,
        index: APSSIndex,
        *,
        threshold: float,
        k: int = 32,
        max_batch: int = 8,
        normalize: bool = True,
        cache_size: int = 256,
        use_kernel: bool = False,
        block_q: Optional[int] = None,
        deadline_s: Optional[float] = None,
        max_pending: Optional[int] = None,
        max_retries: int = 1,
        backoff_s: float = 0.01,
        ttl_s: Optional[float] = None,
        fault_plan=None,
    ):
        self.index = index
        self.threshold = float(threshold)
        self.k = int(k)
        self.max_batch = int(max_batch)
        self.normalize = bool(normalize)
        self.use_kernel = bool(use_kernel)
        # Pad every batch to one query block: the jitted scoring path then
        # sees a single (block_q, m) shape for the server's lifetime.
        self.block_q = int(block_q or max(8, self.max_batch))
        self.cache_size = int(cache_size)
        self.deadline_s = deadline_s
        self.max_pending = max_pending
        self.max_retries = int(max_retries)
        self.backoff_s = float(backoff_s)
        self.ttl_s = ttl_s
        self.fault_plan = fault_plan
        # entries: (result, born, index version at scoring time) — fresh
        # hits require the version to still match, so a mutation
        # invalidates every prior entry without touching the dict
        self._cache: collections.OrderedDict[
            str, tuple[RetrievalResult, float, int]
        ] = collections.OrderedDict()
        # pending entries: (rid, query, cache_key, absolute deadline | inf,
        # submit time — the request-latency clock start)
        self._pending: collections.deque[
            tuple[int, np.ndarray, str, float, float]
        ] = collections.deque()
        self._results: dict[int, RetrievalResult] = {}
        self._next_id = 0
        self._requests = 0
        self._steps = 0
        self._cache_hits = 0
        self._shed = 0
        self._degraded = 0
        self._retries = 0
        self._stale = 0

    # -- input contract -----------------------------------------------------

    def _coerce_query(self, query) -> np.ndarray:
        """Validate + coerce one query to finite f32 ``(m,)``.

        The adversarial-input contract (pinned by
        ``tests/test_robust_serving.py``): non-numeric dtypes and
        non-finite values are *rejected* (a NaN poisons every score it
        touches and -inf sorts unpredictably through top-k — garbage in a
        result no client can detect); numeric dtypes are cast; all-zero
        vectors are *accepted* (``normalize_rows`` keeps them zero — they
        simply match nothing).
        """
        q = np.asarray(query)
        if q.dtype.kind not in "fiub":
            raise ValueError(
                f"query dtype {q.dtype} is not numeric "
                "(float/int/bool accepted)"
            )
        q = np.asarray(q, np.float32).reshape(-1)
        if q.shape[0] != self.index.m:
            raise ValueError(f"query dim {q.shape[0]} != index m {self.index.m}")
        if not np.all(np.isfinite(q)):
            raise ValueError("query contains non-finite values (NaN/inf)")
        return q

    def _empty_result(self, status: str) -> RetrievalResult:
        v = np.full((self.k,), -np.inf, np.float32)
        i = np.full((self.k,), -1, np.int32)
        v.setflags(write=False)
        i.setflags(write=False)
        return RetrievalResult(
            values=v, indices=i, count=0, cached=False, status=status
        )

    def _shed_request(self, rid: int) -> None:
        self._shed += 1
        telemetry.incr("serving.shed")
        trace.event("shed", rid=rid)
        self._results[rid] = self._empty_result("shed")

    # -- request lifecycle --------------------------------------------------

    def submit(self, query, *, deadline_s: Optional[float] = None) -> int:
        """Enqueue one query vector ``(m,)``; returns a request id.

        Cache hits latch their result immediately and never join a batch.
        Submits past the admission budget latch a ``status="shed"`` result
        instead of queueing (overload must fail fast, not pile up).
        """
        q = self._coerce_query(query)
        rid = self._next_id
        self._next_id += 1
        self._requests += 1
        telemetry.incr("serving.requests")
        key = self._cache_key(q)
        hit = self._cache_get(key)
        if hit is not None:
            self._cache_hits += 1
            telemetry.incr("serving.cache_hits")
            trace.event("cache_hit", rid=rid)
            if metrics.enabled():
                metrics.observe("serving.latency_s", 0.0)
            self._results[rid] = hit._replace(cached=True)
            return rid
        if self.max_pending is not None and len(self._pending) >= self.max_pending:
            self._shed_request(rid)
            return rid
        trace.event("admit", rid=rid)
        budget = deadline_s if deadline_s is not None else self.deadline_s
        now = time.monotonic()
        deadline = now + budget if budget is not None else np.inf
        self._pending.append((rid, q, key, deadline, now))
        return rid

    # -- tiered scoring ------------------------------------------------------

    def _tiers(self) -> list[tuple[str, bool]]:
        tiers = [("kernel", True)] if self.use_kernel else []
        tiers.append(("xla", False))
        return tiers

    def _score_batch(self, Qj):
        """Run the degradation ladder; returns ``(matches | None, tier)``.

        Each tier gets ``1 + max_retries`` attempts with exponential
        backoff; a tier that stays down degrades to the next. ``None``
        means every tier failed — the caller falls to stale answers.
        """
        tiers = self._tiers()
        for nth, (tier, use_k) in enumerate(tiers):
            delay = self.backoff_s
            for attempt in range(1 + self.max_retries):
                try:
                    if self.fault_plan is not None:
                        self.fault_plan.fail_point(f"serving.{tier}")
                    if isinstance(self.index, MutableAPSSIndex):
                        m = self.index.query(
                            np.asarray(Qj), self.threshold, self.k,
                            block_q=self.block_q, use_kernel=use_k,
                        )
                    else:
                        m = query_topk(
                            self.index, Qj, self.threshold, self.k,
                            block_q=self.block_q, use_kernel=use_k,
                        )
                    if nth > 0:
                        self._degraded += 1
                        telemetry.incr("serving.degraded")
                        trace.event("degrade", tier=tier)
                        recorder.trigger("serving.tier_down", tier=tier)
                    return m, tier
                except Exception:
                    if attempt < self.max_retries:
                        self._retries += 1
                        telemetry.incr("serving.retries")
                        trace.event("retry", tier=tier, attempt=attempt + 1)
                        time.sleep(delay)
                        delay *= 2
        self._degraded += 1
        telemetry.incr("serving.degraded")
        trace.event("degrade", tier="stale")
        recorder.trigger("serving.tier_down", tier="stale")
        return None, "stale"

    def step(self) -> int:
        """Serve up to ``max_batch`` pending requests with ONE jit'd call.

        Returns the number of requests finished this step (scored + shed;
        0 = idle). Past-deadline requests are shed *before* the batch is
        assembled, so a slow previous step never wastes scoring work on
        answers nobody is waiting for.
        """
        if not self._pending:
            return 0
        with trace.span("serving/step", step=self._steps):
            return self._step_inner()

    def _step_inner(self) -> int:
        if self.fault_plan is not None:
            # Chaos seam: an armed delay here models a slow shard/step.
            self.fault_plan.delay("serving", step=self._steps)
        now = time.monotonic()
        shed_count = 0
        keep: collections.deque = collections.deque()
        while self._pending:
            rid, q, key, deadline, born = self._pending.popleft()
            if deadline < now:
                self._shed_request(rid)
                shed_count += 1
            else:
                keep.append((rid, q, key, deadline, born))
        self._pending = keep
        if not self._pending:
            return shed_count
        batch = [
            self._pending.popleft()
            for _ in range(min(self.max_batch, len(self._pending)))
        ]
        trace.event("batch", size=len(batch), queued=len(self._pending))
        if metrics.enabled():
            metrics.observe(
                "serving.batch_occupancy", len(batch) / self.max_batch
            )
        Q = np.zeros((self.max_batch, self.index.m), np.float32)
        for slot, (_, q, _, _, _) in enumerate(batch):
            Q[slot] = q
        Qj = jnp.asarray(Q)
        if self.normalize:
            Qj = normalize_rows(Qj)
        with trace.span("serving/score", batch=len(batch)):
            m, tier = self._score_batch(Qj)
            trace.annotate(tier=tier)
        self._steps += 1

        def latch(rid: int, born: float, res: RetrievalResult) -> None:
            self._results[rid] = res
            if metrics.enabled():
                metrics.observe(
                    "serving.latency_s", time.monotonic() - born
                )

        if m is None:
            # Every scoring tier is down: stale cache answers beat no
            # answers; true misses fail explicitly.
            for rid, _, key, _, born in batch:
                stale = self._cache_get(key, stale_ok=True)
                if stale is not None:
                    self._stale += 1
                    telemetry.incr("serving.stale")
                    latch(rid, born, stale._replace(
                        cached=True, status="stale"
                    ))
                else:
                    latch(rid, born, self._empty_result("failed"))
            return len(batch) + shed_count
        values = np.asarray(m.values)
        indices = np.asarray(m.indices)
        counts = np.asarray(m.counts)
        trace.event("merge", batch=len(batch))
        for slot, (rid, _, key, _, born) in enumerate(batch):
            # Per-request copies, frozen: the cache and every client hold
            # the same arrays, so in-place mutation by one caller would
            # otherwise corrupt later cache hits — make it raise instead.
            v = values[slot].copy()
            i = indices[slot].copy()
            v.setflags(write=False)
            i.setflags(write=False)
            res = RetrievalResult(
                values=v, indices=i, count=int(counts[slot]), cached=False
            )
            latch(rid, born, res)
            self._cache_put(key, res)
        return len(batch) + shed_count

    def result(self, rid: int) -> RetrievalResult:
        """Pop a finished request's result (steps until it is ready)."""
        while rid not in self._results:
            if not self.step():
                raise KeyError(f"unknown request id {rid}")
        return self._results.pop(rid)

    def serve(self, queries: Sequence) -> list[RetrievalResult]:
        """Convenience: submit all, drain in batches, return in order."""
        rids = [self.submit(q) for q in queries]
        while self._pending:
            self.step()
        return [self.result(r) for r in rids]

    def close(self) -> None:
        """Nothing to stop (no background workers); here so callers can
        treat step and continuous servers uniformly."""

    # -- LRU cache ----------------------------------------------------------

    def _cache_key(self, q: np.ndarray) -> str:
        h = hashlib.blake2b(q.tobytes(), digest_size=16)
        h.update(np.float32(self.threshold).tobytes())
        h.update(np.int32(self.k).tobytes())
        return h.hexdigest()

    def _index_version(self) -> int:
        """Mutable indexes bump ``version`` per mutation; immutable = 0."""
        return int(getattr(self.index, "version", 0))

    def _cache_get(
        self, key: str, *, stale_ok: bool = False
    ) -> Optional[RetrievalResult]:
        """Fresh hits only by default: in-TTL AND scored against the
        current index version (a post-mutation query must never see a
        pre-mutation answer). ``stale_ok`` ignores both — the explicit
        last-resort tier when every scoring tier is down."""
        if self.cache_size <= 0:
            return None
        hit = self._cache.get(key)
        if hit is None:
            return None
        res, born, version = hit
        if not stale_ok:
            if version != self._index_version():
                return None
            if self.ttl_s is not None and time.monotonic() - born > self.ttl_s:
                return None
        self._cache.move_to_end(key)
        return res

    def _cache_put(self, key: str, res: RetrievalResult) -> None:
        if self.cache_size <= 0:
            return
        self._cache[key] = (res, time.monotonic(), self._index_version())
        self._cache.move_to_end(key)
        while len(self._cache) > self.cache_size:
            self._cache.popitem(last=False)

    @property
    def stats(self) -> ServerStats:
        return ServerStats(
            requests=self._requests,
            steps=self._steps,
            cache_hits=self._cache_hits,
            shed=self._shed,
            degraded=self._degraded,
            retries=self._retries,
            stale=self._stale,
        )


class ContinuousRetrievalServer(RetrievalServer):
    """Slot-granularity (continuous-batching) retrieval server.

    :class:`RetrievalServer` quantizes latency to ``step()`` boundaries:
    every admitted request waits for the caller's next step, and one slow
    batch (a straggling peer, an armed chaos delay) holds EVERY queued
    request behind it — the classic step-latch p99 cliff. This subclass
    keeps the whole request lifecycle — admission control, deadlines,
    version-keyed LRU, the kernel→XLA→stale degradation ladder — and
    replaces only the latch: ``workers`` background threads pull up to
    ``max_batch`` requests the moment any are pending (LMServer's
    slot-recycling idea applied to retrieval batches), so

    - a request's service time starts at SUBMIT, not at the next step
      boundary, and
    - with ``workers ≥ 2`` a straggling batch delays only its own
      occupants: the other worker keeps draining fresh arrivals, which is
      precisely the p99 win ``benchmarks/bench_serve.py`` measures.

    Threading contract: one lock guards the queue/results/cache/counters;
    scoring runs OUTSIDE the lock (concurrent jit dispatch is safe — the
    compiled executable is shared). Workers emit ``trace.event``\\ s only
    (``admit``/``slot``/``exit``), never spans: the tracer keeps one open-
    span stack, which cross-thread spans would interleave. Deadline sheds
    happen at batch ASSEMBLY, same as the step server — a straggler never
    wastes scoring work on answers nobody is waiting for. ``step()`` is a
    no-op here (workers drain continuously); use ``result()``/``serve()``,
    and ``close()`` (or the context manager) to stop the workers.
    """

    def __init__(self, index: APSSIndex, *, workers: int = 2, **kwargs):
        super().__init__(index, **kwargs)
        self._lock = threading.Lock()
        self._work_ready = threading.Condition(self._lock)
        self._done = threading.Condition(self._lock)
        self._stop = False
        self._batch_seq = 0  # chaos seam: the continuous analog of _steps
        self._inflight: set[int] = set()  # claimed by a worker, not latched
        self._workers = [
            threading.Thread(
                target=self._worker_loop, name=f"retrieval-slot-{i}",
                daemon=True,
            )
            for i in range(max(1, int(workers)))
        ]
        for w in self._workers:
            w.start()

    # -- lifecycle -----------------------------------------------------------

    def close(self) -> None:
        """Stop the worker threads (idempotent). Pending requests are left
        queued — ``close()`` is shutdown, not drain; call ``serve``/
        ``result`` first if completion matters."""
        with self._lock:
            if self._stop:
                return
            self._stop = True
            self._work_ready.notify_all()
        for w in self._workers:
            w.join()

    def __enter__(self) -> "ContinuousRetrievalServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- request lifecycle ---------------------------------------------------

    def submit(self, query, *, deadline_s: Optional[float] = None) -> int:
        """Enqueue one query; a worker picks it up immediately.

        Same admission/cache/validation contract as the step server, made
        thread-safe; the only behavioral difference is that admission WAKES
        a worker instead of waiting for a step boundary.
        """
        q = self._coerce_query(query)
        key = self._cache_key(q)
        with self._lock:
            rid = self._next_id
            self._next_id += 1
            self._requests += 1
            telemetry.incr("serving.requests")
            hit = self._cache_get(key)
            if hit is not None:
                self._cache_hits += 1
                telemetry.incr("serving.cache_hits")
                trace.event("cache_hit", rid=rid)
                if metrics.enabled():
                    metrics.observe("serving.latency_s", 0.0)
                self._results[rid] = hit._replace(cached=True)
                self._done.notify_all()
                return rid
            if (
                self.max_pending is not None
                and len(self._pending) >= self.max_pending
            ):
                self._shed_request(rid)
                self._done.notify_all()
                return rid
            trace.event("admit", rid=rid, queued=len(self._pending))
            budget = deadline_s if deadline_s is not None else self.deadline_s
            now = time.monotonic()
            deadline = now + budget if budget is not None else np.inf
            self._pending.append((rid, q, key, deadline, now))
            self._work_ready.notify()
        return rid

    def step(self) -> int:
        """No-op: workers drain the queue continuously."""
        return 0

    def result(self, rid: int, timeout_s: Optional[float] = None) -> RetrievalResult:
        """Block until ``rid``'s result latches, then pop it."""
        deadline = (
            time.monotonic() + timeout_s if timeout_s is not None else None
        )
        with self._lock:
            while rid not in self._results:
                if (
                    rid >= self._next_id
                    or (
                        rid not in self._inflight
                        and all(p[0] != rid for p in self._pending)
                    )
                ):
                    raise KeyError(f"unknown request id {rid}")
                if self._stop:
                    raise RuntimeError("server closed while request pending")
                wait = None
                if deadline is not None:
                    wait = deadline - time.monotonic()
                    if wait <= 0:
                        raise TimeoutError(f"result({rid}) timed out")
                self._done.wait(timeout=wait)
            return self._results.pop(rid)

    def serve(self, queries: Sequence) -> list[RetrievalResult]:
        """Submit all, block until every result latches, return in order."""
        rids = [self.submit(q) for q in queries]
        return [self.result(r) for r in rids]

    # -- worker loop ---------------------------------------------------------

    def _take_batch(self):
        """Under the lock: shed expired requests, then claim up to
        ``max_batch``. Returns ``(batch, seq)`` or ``None`` at shutdown."""
        while True:
            if self._stop:
                return None
            now = time.monotonic()
            while self._pending and self._pending[0][3] < now:
                rid = self._pending.popleft()[0]
                self._shed_request(rid)
                self._done.notify_all()
            if self._pending:
                batch = [
                    self._pending.popleft()
                    for _ in range(min(self.max_batch, len(self._pending)))
                ]
                self._inflight.update(b[0] for b in batch)
                seq = self._batch_seq
                self._batch_seq += 1
                return batch, seq
            self._work_ready.wait()

    def _worker_loop(self) -> None:
        while True:
            with self._lock:
                taken = self._take_batch()
                if taken is None:
                    return
                batch, seq = taken
                trace.event(
                    "slot", seq=seq, size=len(batch),
                    queued=len(self._pending),
                )
                if metrics.enabled():
                    metrics.observe(
                        "serving.batch_occupancy",
                        len(batch) / self.max_batch,
                    )
            # Scoring runs UNLOCKED: a straggling batch (chaos delay, slow
            # tier) must not stop sibling workers from draining arrivals.
            if self.fault_plan is not None:
                self.fault_plan.delay("serving", step=seq)
            Q = np.zeros((self.max_batch, self.index.m), np.float32)
            for slot, (_, q, _, _, _) in enumerate(batch):
                Q[slot] = q
            Qj = jnp.asarray(Q)
            if self.normalize:
                Qj = normalize_rows(Qj)
            m, tier = self._score_batch(Qj)
            with self._lock:
                self._steps += 1
                self._latch_batch(batch, m, tier, seq)
                self._done.notify_all()

    def _latch_batch(self, batch, m, tier, seq) -> None:
        now = time.monotonic()

        def latch(rid: int, born: float, res: RetrievalResult) -> None:
            self._results[rid] = res
            self._inflight.discard(rid)
            trace.event("exit", rid=rid, seq=seq, status=res.status, tier=tier)
            if metrics.enabled():
                metrics.observe("serving.latency_s", now - born)

        if m is None:
            for rid, _, key, _, born in batch:
                stale = self._cache_get(key, stale_ok=True)
                if stale is not None:
                    self._stale += 1
                    telemetry.incr("serving.stale")
                    latch(rid, born, stale._replace(cached=True, status="stale"))
                else:
                    latch(rid, born, self._empty_result("failed"))
            return
        values = np.asarray(m.values)
        indices = np.asarray(m.indices)
        counts = np.asarray(m.counts)
        for slot, (rid, _, key, _, born) in enumerate(batch):
            v = values[slot].copy()
            i = indices[slot].copy()
            v.setflags(write=False)
            i.setflags(write=False)
            res = RetrievalResult(
                values=v, indices=i, count=int(counts[slot]), cached=False
            )
            latch(rid, born, res)
            self._cache_put(key, res)
