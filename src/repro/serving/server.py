"""RetrievalServer: batched query-time APSS over a build-once index.

Modeled on ``launch.serve.LMServer``'s slot/latch pattern: requests join a
padded query batch at the next ``step()`` boundary, ONE jit'd
:func:`~repro.serving.query.query_topk` call serves the whole batch (the
batch is always padded to ``max_batch`` rows, so the compiled executable is
reused forever), and per-request results latch into their slots. A tiny
LRU cache keyed on the query-vector hash short-circuits repeat queries —
the classic head-of-zipf serving win — without touching the device.

For mesh-sharded indexes the underlying ``query_topk`` runs the per-shard
scoring path and merges partial top-k host-side; the server is agnostic.
"""

from __future__ import annotations

import collections
import hashlib
from typing import NamedTuple, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.apss import normalize_rows
from repro.serving.index import APSSIndex
from repro.serving.query import query_topk


class RetrievalResult(NamedTuple):
    """One request's top-k neighbors (host numpy; ready to serialize)."""

    values: np.ndarray   # (k,) f32 similarities, -inf padded
    indices: np.ndarray  # (k,) i32 corpus row ids, -1 padded
    count: int           # exact #corpus rows ≥ threshold (may exceed k)
    cached: bool         # served from the LRU cache


class ServerStats(NamedTuple):
    requests: int
    steps: int
    cache_hits: int


class RetrievalServer:
    """Batched online retrieval over a prebuilt :class:`APSSIndex`.

    Args:
      index: built once via :func:`~repro.serving.index.build_index`.
      threshold / k: fixed per server (one compiled executable).
      max_batch: padded batch width; requests beyond it wait for the next
        step boundary.
      normalize: L2-normalize incoming queries (the paper's ``||x|| = 1``
        contract; cache keys hash the raw bytes BEFORE normalization so
        clients need not normalize consistently).
      cache_size: LRU entries; 0 disables the cache.
      use_kernel: route tile scoring through the rectangular Pallas
        kernels (single-host indexes; TPU).
    """

    def __init__(
        self,
        index: APSSIndex,
        *,
        threshold: float,
        k: int = 32,
        max_batch: int = 8,
        normalize: bool = True,
        cache_size: int = 256,
        use_kernel: bool = False,
        block_q: Optional[int] = None,
    ):
        self.index = index
        self.threshold = float(threshold)
        self.k = int(k)
        self.max_batch = int(max_batch)
        self.normalize = bool(normalize)
        self.use_kernel = bool(use_kernel)
        # Pad every batch to one query block: the jitted scoring path then
        # sees a single (block_q, m) shape for the server's lifetime.
        self.block_q = int(block_q or max(8, self.max_batch))
        self.cache_size = int(cache_size)
        self._cache: collections.OrderedDict[str, RetrievalResult] = (
            collections.OrderedDict()
        )
        self._pending: collections.deque[tuple[int, np.ndarray, str]] = (
            collections.deque()
        )
        self._results: dict[int, RetrievalResult] = {}
        self._next_id = 0
        self._requests = 0
        self._steps = 0
        self._cache_hits = 0

    # -- request lifecycle --------------------------------------------------

    def submit(self, query) -> int:
        """Enqueue one query vector ``(m,)``; returns a request id.

        Cache hits latch their result immediately and never join a batch.
        """
        q = np.asarray(query, np.float32).reshape(-1)
        if q.shape[0] != self.index.m:
            raise ValueError(f"query dim {q.shape[0]} != index m {self.index.m}")
        rid = self._next_id
        self._next_id += 1
        self._requests += 1
        key = self._cache_key(q)
        hit = self._cache_get(key)
        if hit is not None:
            self._cache_hits += 1
            self._results[rid] = hit._replace(cached=True)
        else:
            self._pending.append((rid, q, key))
        return rid

    def step(self) -> int:
        """Serve up to ``max_batch`` pending requests with ONE jit'd call.

        Returns the number of requests served this step (0 = idle).
        """
        if not self._pending:
            return 0
        batch = [
            self._pending.popleft()
            for _ in range(min(self.max_batch, len(self._pending)))
        ]
        Q = np.zeros((self.max_batch, self.index.m), np.float32)
        for slot, (_, q, _) in enumerate(batch):
            Q[slot] = q
        Qj = jnp.asarray(Q)
        if self.normalize:
            Qj = normalize_rows(Qj)
        m = query_topk(
            self.index, Qj, self.threshold, self.k,
            block_q=self.block_q, use_kernel=self.use_kernel,
        )
        values = np.asarray(m.values)
        indices = np.asarray(m.indices)
        counts = np.asarray(m.counts)
        for slot, (rid, _, key) in enumerate(batch):
            # Per-request copies, frozen: the cache and every client hold
            # the same arrays, so in-place mutation by one caller would
            # otherwise corrupt later cache hits — make it raise instead.
            v = values[slot].copy()
            i = indices[slot].copy()
            v.setflags(write=False)
            i.setflags(write=False)
            res = RetrievalResult(
                values=v, indices=i, count=int(counts[slot]), cached=False
            )
            self._results[rid] = res
            self._cache_put(key, res)
        self._steps += 1
        return len(batch)

    def result(self, rid: int) -> RetrievalResult:
        """Pop a finished request's result (steps until it is ready)."""
        while rid not in self._results:
            if not self.step():
                raise KeyError(f"unknown request id {rid}")
        return self._results.pop(rid)

    def serve(self, queries: Sequence) -> list[RetrievalResult]:
        """Convenience: submit all, drain in batches, return in order."""
        rids = [self.submit(q) for q in queries]
        while self._pending:
            self.step()
        return [self.result(r) for r in rids]

    # -- LRU cache ----------------------------------------------------------

    def _cache_key(self, q: np.ndarray) -> str:
        h = hashlib.blake2b(q.tobytes(), digest_size=16)
        h.update(np.float32(self.threshold).tobytes())
        h.update(np.int32(self.k).tobytes())
        return h.hexdigest()

    def _cache_get(self, key: str) -> Optional[RetrievalResult]:
        if self.cache_size <= 0:
            return None
        hit = self._cache.get(key)
        if hit is not None:
            self._cache.move_to_end(key)
        return hit

    def _cache_put(self, key: str, res: RetrievalResult) -> None:
        if self.cache_size <= 0:
            return
        self._cache[key] = res
        self._cache.move_to_end(key)
        while len(self._cache) > self.cache_size:
            self._cache.popitem(last=False)

    @property
    def stats(self) -> ServerStats:
        return ServerStats(
            requests=self._requests,
            steps=self._steps,
            cache_hits=self._cache_hits,
        )
