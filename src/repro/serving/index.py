"""APSSIndex: every corpus-side support structure, built once.

Today's self-join paths rebuild block maxima, posting-list supports and
``bdims``/``bx`` compaction on every call — fine for one batch job, wasteful
for a server answering a stream of queries against a FIXED corpus. DISCO
(Zadeh & Goel 2012) and the adaptive similarity-join line both land on the
same conclusion: amortize index construction, adapt candidate generation.

:class:`APSSIndex` is a registered pytree holding

- the (row-normalized, block-padded) corpus — dense array or the padded-CSR
  triple of :class:`~repro.core.sparse.SparseCorpus`,
- :class:`~repro.core.pruning.BlockStats` — per-block per-dimension
  maxweight vectors (whose support IS the tile-granular posting lists /
  inverted index), per-block max weight, and exact per-block max nnz for
  the minsize bound,
- for sparse corpora, the per-block support compaction ``bdims (nb, S)`` /
  ``bx (nb, bm, S)`` consumed by the CSR tile kernels,

so :func:`~repro.serving.query.query_topk` can evaluate the paper's bounds
query-side only and score live tiles straight away. Static metadata
(block size, valid row count, mesh placement) rides the pytree aux field,
so jit'd consumers retrace only when the corpus *shape* changes — never
per query.

With ``mesh=``, corpus rows are ``device_put``-sharded over ``axis_name``
(``P(axis, None)`` — block-aligned row shards) and queries are served by
the per-shard scoring path (``query.py``): each device scores the
replicated query batch against its local shard; partial top-k results are
merged host-side. The small block stats stay REPLICATED (``P()``), which
is what makes sharded-query pruning work: the host evaluates the global
bounds once, slices each shard's block range (:meth:`shard_block_range`)
out of the live mask, and ships every device its own compacted worklist —
a shard scores only its live tiles, never its whole local corpus.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.apss import normalize_rows, pad_rows
from repro.core.pruning import (
    BlockStats,
    dense_block_stats,
    sparse_block_stats,
)
from repro.core.sparse import (
    SparseCorpus,
    normalize_sparse,
    pad_rows_sparse,
)
from repro.kernels.apss_block.sparse import block_support_gather


@jax.tree_util.register_pytree_node_class
class APSSIndex:
    """Build-once retrieval index over a fixed corpus (see module doc).

    Children (traced): corpus leaves, block stats, support compaction.
    Aux (static): ``n`` valid rows, ``m`` dims, ``block_rows``, ``kind``
    (``"dense"``/``"sparse"``), ``normalized``, mesh placement.
    """

    def __init__(
        self,
        corpus,              # (ncp, m) dense | (indices, values, nnz) CSR triple
        stats: BlockStats,   # corpus-side block pruning summaries
        bdims,               # (nb, S) i32 sparse support lists | None
        bx,                  # (nb, bm, S) f32 support-densified blocks | None
        *,
        n: int,
        m: int,
        block_rows: int,
        kind: str,
        normalized: bool,
        mesh: Optional[Mesh] = None,
        axis_name: str = "data",
    ):
        self.corpus = corpus
        self.stats = stats
        self.bdims = bdims
        self.bx = bx
        self.n = int(n)
        self.m = int(m)
        self.block_rows = int(block_rows)
        self.kind = kind
        self.normalized = bool(normalized)
        self.mesh = mesh
        self.axis_name = axis_name

    def tree_flatten(self):
        children = (self.corpus, self.stats, self.bdims, self.bx)
        aux = (
            self.n, self.m, self.block_rows, self.kind, self.normalized,
            self.mesh, self.axis_name,
        )
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        n, m, block_rows, kind, normalized, mesh, axis_name = aux
        corpus, stats, bdims, bx = children
        return cls(
            corpus, stats, bdims, bx,
            n=n, m=m, block_rows=block_rows, kind=kind,
            normalized=normalized, mesh=mesh, axis_name=axis_name,
        )

    @property
    def is_sparse(self) -> bool:
        return self.kind == "sparse"

    @property
    def n_padded(self) -> int:
        if self.is_sparse:
            return self.corpus[0].shape[0]
        return self.corpus.shape[0]

    @property
    def n_blocks(self) -> int:
        return self.n_padded // self.block_rows

    @property
    def n_shards(self) -> int:
        return 1 if self.mesh is None else int(self.mesh.shape[self.axis_name])

    @property
    def nb_local(self) -> int:
        """Corpus blocks owned by each shard (= ``n_blocks`` unsharded).

        Build-time padding is to ``p · block_rows`` multiples, so the
        division is always exact and shard ``s`` owns the contiguous global
        block range ``[s · nb_local, (s+1) · nb_local)``.
        """
        return self.n_blocks // self.n_shards

    def shard_block_range(self, s: int) -> tuple[int, int]:
        """Global ``[lo, hi)`` corpus-block ids owned by shard ``s``.

        The corpus-side :class:`BlockStats` are replicated (``P()``), so the
        query path slices this range out of the GLOBAL live mask to compact
        a per-shard worklist — each device then scores only its own live
        tiles instead of every local tile (``query._sharded_query``).
        """
        lo = s * self.nb_local
        return lo, lo + self.nb_local

    def stats_host(self) -> tuple[np.ndarray, np.ndarray]:
        """Host copies of the small per-block stat vectors ``(mw, max_nnz)``.

        Cached on the instance: the per-batch query planner
        (``planner.costmodel.plan_query_topk``) consults these exact bounds
        on every plan decision — zero sampling, but also zero device
        round-trips after the first call.
        """
        cached = getattr(self, "_stats_host", None)
        if cached is None:
            cached = (
                np.asarray(self.stats.mw),
                np.asarray(self.stats.max_nnz),
            )
            self._stats_host = cached
        return cached

    def sparse_corpus(self) -> SparseCorpus:
        """The padded corpus as a :class:`SparseCorpus` view (sparse kind)."""
        assert self.is_sparse, "dense index has no CSR triple"
        idx, val, nnz = self.corpus
        return SparseCorpus(idx, val, nnz, self.m)

    def __repr__(self) -> str:
        placed = f", sharded={self.axis_name}" if self.mesh is not None else ""
        return (
            f"APSSIndex(kind={self.kind}, n={self.n}, m={self.m}, "
            f"block_rows={self.block_rows}{placed})"
        )


def build_index(
    corpus,
    *,
    block_rows: int = 256,
    normalize: bool = True,
    assume_normalized: bool = True,
    mesh: Optional[Mesh] = None,
    axis_name: str = "data",
    lane_pad: int = 128,
    plan=None,
) -> APSSIndex:
    """Build every corpus-side structure ONCE (host + one XLA pass).

    ``corpus`` is a dense ``(n, m)`` array or a
    :class:`~repro.core.sparse.SparseCorpus`. Rows are L2-normalized and
    padded to ``block_rows`` (to ``p · block_rows`` under a mesh, so every
    shard is block-aligned).

    ``normalize=False`` skips the normalization pass for corpora whose
    rows are ALREADY unit-norm (the repo's generators and ``normalize_*``
    outputs); ``assume_normalized`` then records whether that's true — it
    gates the minsize pruning bound, which needs ``||y|| = 1``
    (``core.pruning``). Pass ``assume_normalized=False`` only for a
    genuinely unnormalized corpus served as-is (weaker pruning, still
    exact).

    This is the ONLY place serving-side support structures are computed;
    ``query_topk`` consumes the returned pytree and never rebuilds
    (asserted by ``tests/test_serving.py`` via trace counters).

    ``plan=`` takes a planner decision (a ``planner.Plan`` or bare
    ``VariantConfig``): its ``block_rows`` becomes the index block size and
    the corpus is converted to the planned representation (dense ↔ padded
    CSR) before building — so ``build_index(corpus,
    plan=plan_apss(corpus, t, k))`` materializes the layout the cost model
    actually priced.
    """
    if plan is not None:
        cfg = getattr(plan, "config", plan)
        block_rows = cfg.block_rows
        if cfg.sparse and not isinstance(corpus, SparseCorpus):
            from repro.core.sparse import from_dense

            corpus = from_dense(np.asarray(corpus))
        elif not cfg.sparse and isinstance(corpus, SparseCorpus):
            from repro.core.sparse import to_dense

            corpus = to_dense(corpus)
    normalized = True if normalize else assume_normalized
    if isinstance(corpus, SparseCorpus):
        return _build_sparse(
            corpus, block_rows=block_rows, normalize=normalize,
            normalized=normalized, mesh=mesh, axis_name=axis_name,
            lane_pad=lane_pad,
        )
    return _build_dense(
        jnp.asarray(corpus), block_rows=block_rows, normalize=normalize,
        normalized=normalized, mesh=mesh, axis_name=axis_name,
    )


def _row_multiple(block_rows: int, mesh, axis_name: str) -> int:
    if mesh is None:
        return block_rows
    return block_rows * mesh.shape[axis_name]


def _build_dense(
    C, *, block_rows, normalize, normalized, mesh, axis_name
) -> APSSIndex:
    n, m = C.shape
    if normalize:
        C = normalize_rows(C)
    Cp, _ = pad_rows(C, _row_multiple(block_rows, mesh, axis_name))
    # Lane-pad the feature axis ONCE to the kernel tile the query path will
    # pick, so per-query scoring never touches corpus-sized memory (the
    # jitted inner's pad becomes a no-op). Zero columns change no score,
    # bound, or nnz count.
    from repro.kernels.apss_block.ops import _pick_bk

    bk = _pick_bk(m, 512)
    remk = (-m) % bk
    if remk:
        Cp = jnp.pad(Cp, ((0, 0), (0, remk)))
    stats = dense_block_stats(Cp, block_rows)
    if mesh is not None:
        Cp = jax.device_put(Cp, NamedSharding(mesh, P(axis_name, None)))
        stats = jax.device_put(stats, NamedSharding(mesh, P()))
    return APSSIndex(
        Cp, stats, None, None,
        n=n, m=m, block_rows=block_rows, kind="dense",
        normalized=normalized, mesh=mesh, axis_name=axis_name,
    )


def _build_sparse(
    sp, *, block_rows, normalize, normalized, mesh, axis_name, lane_pad
) -> APSSIndex:
    n = sp.n
    if normalize:
        sp = normalize_sparse(sp)
    spp, _ = pad_rows_sparse(sp, _row_multiple(block_rows, mesh, axis_name))
    stats = sparse_block_stats(spp, block_rows)
    if mesh is not None:
        # Sharded placement: the CSR triple splits over row blocks; the
        # per-shard scoring path gathers only its LIVE CSR blocks (worklist
        # from the replicated stats) with gather_dot, so the
        # (replicated-size) bdims/bx compaction is not built at all.
        sharded = NamedSharding(mesh, P(axis_name, None))
        triple = (
            jax.device_put(spp.indices, sharded),
            jax.device_put(spp.values, sharded),
            jax.device_put(spp.nnz, NamedSharding(mesh, P(axis_name))),
        )
        stats = jax.device_put(stats, NamedSharding(mesh, P()))
        bdims = bx = None
    else:
        bd, bxx = block_support_gather(spp, block_rows, pad_to=lane_pad)
        bdims, bx = jnp.asarray(bd), jnp.asarray(bxx)
        triple = (spp.indices, spp.values, spp.nnz)
    return APSSIndex(
        triple, stats, bdims, bx,
        n=n, m=sp.m, block_rows=block_rows, kind="sparse",
        normalized=normalized, mesh=mesh, axis_name=axis_name,
    )


def index_nbytes(index: APSSIndex) -> int:
    """Total bytes across index leaves (benchmark accounting)."""
    return int(
        sum(
            np.asarray(x).nbytes
            for x in jax.tree_util.tree_leaves(index)
        )
    )
