"""query_topk: rectangular pruned scoring against a prebuilt APSSIndex.

The self-join scoring paths rebuild ``bdims``/``bx`` supports and pruning
bounds on every call; this module is the query-time half of the split:
per call it computes ONLY the query-side block stats (one cheap summary
pass over the padded batch), evaluates the paper's maxweight + minsize
bounds against the index's precomputed corpus block maxima — which is the
inverted-index candidacy test in weighted form, so candidates come from
the prebuilt posting-list supports — and scores exactly the live
``(query_block, corpus_block)`` tiles through a rectangular generalization
of the fused/compacted worklist path (Pallas kernel on TPU, XLA scan
fallback elsewhere). No n×n symmetry assumption anywhere: no mirror
packets, no self-pair exclusion, no triangular worklist cut.

Retrace discipline (the server's hot loop must not recompile):

- every index structure enters the jit'd inners as pytree ARGUMENTS —
  nothing corpus-sized is rebuilt or re-traced per call,
- the live-tile worklist is bucket-padded to a power of two
  (``ops.pad_worklist``) so varying live-tile counts reuse compiled code,
- every jitted inner marks the public retrace registry
  (``repro.obs.compile``) at trace time only; ``tests/test_serving.py``
  asserts under an ``assert_no_retrace`` contract that a second query
  compiles nothing.

Sharded indexes (``build_index(mesh=...)``) take the per-shard path: one
``shard_map`` scores the replicated query batch against each device's
corpus rows (global column ids via the shard offset), per-shard top-k
partials come back stacked, and the host merges them (``merge_matches``
over disjoint column ranges — exact).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import pvary, shard_map
from repro.core.matches import (
    Matches,
    empty_matches,
    extract_matches,
    merge_matches,
)
from repro.core.pruning import dense_block_stats, live_tile_mask
from repro.core.sparse import SparseCorpus, gather_dot, to_dense
from repro.kernels.apss_block.fused import (
    _rect_tile_packets,
    _topk_sort,
    rect_tile_candidates_pallas,
)
from repro.kernels.apss_block.ops import (
    _on_tpu,
    _pick_bk,
    compact_rect_worklist,
    fold_rect_packets,
    pad_worklist,
)
from repro.kernels.apss_block.sparse import rect_sparse_tile_candidates_pallas
from repro.obs import compile as obs_compile
from repro.obs import metrics, trace
from repro.planner import telemetry
from repro.serving.index import APSSIndex

# Trace-time retrace counters, owned by the public registry
# (repro.obs.compile.MONITOR). The serving contract is "build once, query
# many": after the first call of a given shape these must not move —
# enforced by assert_no_retrace("serving.query") contracts in
# tests/test_serving.py. This name is a back-compat alias to the SAME
# Counter object, so legacy dict-snapshot readers keep working.
TRACE_COUNTS = obs_compile.MONITOR.counts

obs_compile.register_entry_points(
    "serving.query",
    "query_mask", "dense_inner", "sparse_inner", "sharded_query",
)


def query_topk(
    index: APSSIndex,
    Q,
    threshold: float,
    k: int = 32,
    *,
    block_q: int = 128,
    use_kernel: bool = False,
    use_minsize: bool = True,
    interpret: bool | None = None,
) -> Matches:
    """Top-k corpus neighbors ≥ ``threshold`` for a batch of queries.

    ``Q`` is ``(B, m)`` dense (a :class:`SparseCorpus` batch is densified —
    query batches are small) and is scored AS GIVEN (no normalization here;
    the server normalizes on ingest). Returns :class:`Matches` with global
    corpus row ids; exact vs the brute-force rectangular oracle
    (``extract_matches(Q @ Cᵀ, t, k, exclude_self=False)``) at every
    threshold, including ``t ≤ 0``, because pruned tiles are provably
    matchless (``core.pruning``).

    The live worklist is compacted host-side (same contract as
    ``apss_fused_compacted``), ordered by upper bound descending, and
    bucket-padded so repeat calls hit the jit cache. ``use_kernel`` routes
    tile scoring through the rectangular Pallas kernels (TPU; interpret
    off-TPU); the default XLA scan is the production path off-TPU.
    """
    with trace.span("serving/query", use_kernel=use_kernel):
        return _query_topk_impl(
            index, Q, threshold, k, block_q=block_q, use_kernel=use_kernel,
            use_minsize=use_minsize, interpret=interpret,
        )


def _query_topk_impl(
    index: APSSIndex,
    Q,
    threshold: float,
    k: int = 32,
    *,
    block_q: int = 128,
    use_kernel: bool = False,
    use_minsize: bool = True,
    interpret: bool | None = None,
) -> Matches:
    if interpret is None:
        interpret = not _on_tpu()
    if isinstance(Q, SparseCorpus):
        if Q.m != index.m:
            raise ValueError(f"dimension mismatch: Q.m={Q.m} vs index m={index.m}")
        Q = to_dense(Q)
    Q = jnp.asarray(Q)
    if Q.ndim != 2 or Q.shape[1] != index.m:
        raise ValueError(f"Q must be (B, {index.m}); got {Q.shape}")
    B = Q.shape[0]
    if not index.is_sparse:
        # Dense corpora are lane-padded once at build time; match the
        # query batch (query-sized work) so the jitted inners see aligned
        # operands and never re-pad the corpus.
        remk = index.corpus.shape[1] - index.m
        if remk:
            Q = jnp.pad(Q, ((0, 0), (0, remk)))

    if index.mesh is not None:
        if use_kernel:
            raise NotImplementedError(
                "sharded query path scores with the XLA blocked scorer "
                "(per-shard column validity); use_kernel applies to "
                "single-host indexes"
            )
        if telemetry.enabled():
            p = index.mesh.shape[index.axis_name]
            depth = (
                index.corpus[0].shape[1] if index.is_sparse
                else index.corpus.shape[1]
            )
            flops = (
                telemetry.sparse_join_flops(B, index.n_padded // p, depth)
                if index.is_sparse
                else telemetry.dense_join_flops(B, index.n_padded // p, depth)
            )
            telemetry.record(telemetry.ApssStats(
                variant="serving/query-sharded",
                n=index.n, m=index.m, devices=p,
                block_rows=index.block_rows, sparse=index.is_sparse,
                flops=flops, extra={"batch": B},
            ))
        # No block_q row padding here: the per-shard scorer tiles by the
        # index's block_rows, so padding would only add dead scored rows.
        out = _sharded_query(
            Q, index.corpus,
            mesh=index.mesh, axis_name=index.axis_name, kind=index.kind,
            threshold=float(threshold), k=k,
            block_rows=index.block_rows, n_valid=index.n,
        )
        parts = [jax.tree.map(lambda x: x[i], out) for i in range(out.counts.shape[0])]
        return functools.reduce(merge_matches, parts)

    rem = (-B) % block_q
    Qp = jnp.pad(Q, ((0, rem), (0, 0))) if rem else Q
    grid_q = Qp.shape[0] // block_q
    mask, ub = _query_mask(
        Qp, index.stats, threshold=float(threshold), block_q=block_q,
        use_minsize=use_minsize, normalized=index.normalized,
    )
    wl = compact_rect_worklist(np.asarray(mask), np.asarray(ub))
    if telemetry.enabled() or metrics.enabled():
        mk = np.asarray(mask)
        live = 0 if wl is None else int(wl.shape[1])
        depth = (
            int(index.bdims.shape[1]) if index.is_sparse
            else int(index.corpus.shape[1])
        )
        if telemetry.enabled():
            telemetry.record(telemetry.ApssStats(
                variant="serving/query",
                n=index.n, m=index.m, block_rows=index.block_rows,
                sparse=index.is_sparse,
                flops=2.0 * live * block_q * index.block_rows * depth,
                live_tiles=live, total_tiles=int(mk.size),
                tile_counts=tuple(int(x) for x in mk.sum(axis=1)),
                extra={"batch": B, "use_kernel": use_kernel},
            ))
        if metrics.enabled():
            metrics.observe(
                "serving.live_tile_fraction", live / max(1, mk.size)
            )
        trace.annotate(batch=B, live_tiles=live, total_tiles=int(mk.size))
    if wl is None:
        return empty_matches(B, k)
    ij, tvalid = pad_worklist(wl)
    ij, tvalid = jnp.asarray(ij), jnp.asarray(tvalid)

    if index.is_sparse:
        inner_kwargs = dict(
            threshold=float(threshold), k=k, block_q=block_q,
            block_c=index.block_rows, nc_valid=index.n, grid_q=grid_q,
            use_kernel=use_kernel, interpret=interpret,
        )
        obs_compile.offer_capture(
            "serving.sparse_inner", _rect_sparse_inner,
            Qp, index.bdims, index.bx, ij, tvalid, **inner_kwargs,
        )
        values, indices, counts = _rect_sparse_inner(
            Qp, index.bdims, index.bx, ij, tvalid, **inner_kwargs,
        )
    else:
        inner_kwargs = dict(
            threshold=float(threshold), k=k, block_q=block_q,
            block_c=index.block_rows, nc_valid=index.n, grid_q=grid_q,
            use_kernel=use_kernel, interpret=interpret,
        )
        obs_compile.offer_capture(
            "serving.dense_inner", _rect_dense_inner,
            Qp, index.corpus, ij, tvalid, **inner_kwargs,
        )
        values, indices, counts = _rect_dense_inner(
            Qp, index.corpus, ij, tvalid, **inner_kwargs,
        )
    return Matches(values=values[:B], indices=indices[:B], counts=counts[:B])


@functools.partial(
    jax.jit,
    static_argnames=("threshold", "block_q", "use_minsize", "normalized"),
)
def _query_mask(Qp, corpus_stats, *, threshold, block_q, use_minsize, normalized):
    """Query-side block stats + live mask vs PREBUILT corpus stats.

    The only per-call bound computation: ``O(B·m)`` for the query summary
    and one ``(B/bq × nb)`` matmul for the upper bounds. Corpus-side stats
    arrive as index leaves — never recomputed here.
    """
    obs_compile.mark("query_mask")
    qstats = dense_block_stats(Qp.astype(jnp.float32), block_q)
    return live_tile_mask(
        qstats, corpus_stats, threshold,
        use_minsize=use_minsize, normalized=normalized, return_ub=True,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "threshold", "k", "block_q", "block_c", "nc_valid", "grid_q",
        "use_kernel", "interpret",
    ),
)
def _rect_dense_inner(
    Qp, C, ij, tvalid, *,
    threshold, k, block_q, block_c, nc_valid, grid_q, use_kernel, interpret,
):
    """Score live rectangular tiles of a DENSE index; fold to Matches."""
    obs_compile.mark("dense_inner")
    m = Qp.shape[1]
    if use_kernel:
        bk = _pick_bk(m, 512)
        padk = (-m) % bk
        Qk = jnp.pad(Qp, ((0, 0), (0, padk))) if padk else Qp
        Ck = jnp.pad(C, ((0, 0), (0, padk))) if padk else C
        fv, fi, fc = rect_tile_candidates_pallas(
            Qk, Ck, ij, threshold, k,
            block_q=block_q, block_c=block_c, block_k=bk,
            nc_valid=nc_valid, interpret=interpret,
        )
    else:
        Qb = Qp.reshape(grid_q, block_q, m)
        Cb = C.reshape(-1, block_c, m)

        def tile(_, t):
            s = jnp.einsum(
                "qm,cm->qc", Qb[ij[0, t]], Cb[ij[1, t]],
                preferred_element_type=jnp.float32,
            )
            return _, _rect_tile_packets(
                s, ij[1, t], threshold=threshold, k=k,
                block_q=block_q, block_c=block_c, nc_valid=nc_valid,
                topk=_topk_sort,
            )

        _, (fv, fi, fc) = lax.scan(tile, 0, jnp.arange(ij.shape[1]))
    return fold_rect_packets(
        ij, tvalid, fv, fi, fc[..., 0], grid_q=grid_q, block_q=block_q, k=k
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "threshold", "k", "block_q", "block_c", "nc_valid", "grid_q",
        "use_kernel", "interpret",
    ),
)
def _rect_sparse_inner(
    Qp, bdims, bx, ij, tvalid, *,
    threshold, k, block_q, block_c, nc_valid, grid_q, use_kernel, interpret,
):
    """Score live rectangular tiles of a SPARSE index; fold to Matches.

    Per live tile ``(qi, cj)``: the query block's components at the corpus
    block's support dims (``bdims[cj]``) are gathered — the sentinel pad
    ``m`` hits an appended zero column — and contracted against the
    support-densified corpus block ``bx[cj]``. Exact, because every corpus
    nonzero lies inside its own block support (DESIGN.md §5/§6); MXU work
    is ``O(bq · bm · S)``, never ``O(bq · bm · m)``.
    """
    obs_compile.mark("sparse_inner")
    Qext = jnp.pad(Qp.astype(jnp.float32), ((0, 0), (0, 1)))
    Qb = Qext.reshape(grid_q, block_q, -1)

    def gather_t(t):
        return jnp.take(Qb[ij[0, t]], bdims[ij[1, t]], axis=1)  # (bq, S)

    if use_kernel:
        _, qg = lax.scan(
            lambda _, t: (_, gather_t(t)), 0, jnp.arange(ij.shape[1])
        )
        fv, fi, fc = rect_sparse_tile_candidates_pallas(
            qg, bx, ij, threshold, k,
            block_q=block_q, block_c=block_c, nc_valid=nc_valid,
            interpret=interpret,
        )
    else:

        def tile(_, t):
            s = jnp.einsum(
                "qs,cs->qc", gather_t(t), bx[ij[1, t]],
                preferred_element_type=jnp.float32,
            )
            return _, _rect_tile_packets(
                s, ij[1, t], threshold=threshold, k=k,
                block_q=block_q, block_c=block_c, nc_valid=nc_valid,
                topk=_topk_sort,
            )

        _, (fv, fi, fc) = lax.scan(tile, 0, jnp.arange(ij.shape[1]))
    return fold_rect_packets(
        ij, tvalid, fv, fi, fc[..., 0], grid_q=grid_q, block_q=block_q, k=k
    )


# ---------------------------------------------------------------------------
# Sharded per-shard scoring (mesh-placed indexes)
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit,
    static_argnames=(
        "mesh", "axis_name", "kind", "threshold", "k", "block_rows",
        "n_valid",
    ),
)
def _sharded_query(
    Qp, corpus, *, mesh, axis_name, kind, threshold, k, block_rows, n_valid
):
    """One shard_map: replicated queries × per-device corpus row shard.

    Returns per-shard partial Matches STACKED on a leading ``(p,)`` axis —
    the caller merges them host-side (the partials' column ranges are
    disjoint by construction, so ``merge_matches`` is exact). Column
    validity is evaluated against GLOBAL row ids, so corpus padding rows
    (which live only in the last shard) never match.
    """
    obs_compile.mark("sharded_query")

    def dense_body(Qr, C_loc):
        from repro.core.apss import similarity_topk

        nc_loc = C_loc.shape[0]
        col_off = lax.axis_index(axis_name) * nc_loc
        ids = jnp.arange(nc_loc, dtype=jnp.int32) + col_off
        mm = similarity_topk(
            Qr, C_loc, threshold, k,
            block_rows=min(block_rows, Qr.shape[0]),
            exclude_self=False, col_offset=col_off, col_valid=ids < n_valid,
        )
        return jax.tree.map(lambda x: x[None], mm)

    def sparse_body(Qr, idxL, valL, nnzL):
        del nnzL  # scoring sums every (0-padded) slot; nnz not needed
        nc_loc, cap = idxL.shape
        bm = min(block_rows, nc_loc)
        ncb = nc_loc // bm
        col_off = lax.axis_index(axis_name) * nc_loc
        Ci = idxL.reshape(ncb, bm, cap)
        Cv = valL.reshape(ncb, bm, cap)

        def c_block(mm, ci):
            s = gather_dot(Qr.astype(jnp.float32), Ci[ci], Cv[ci])
            ids = jnp.arange(bm, dtype=jnp.int32) + col_off + ci * bm
            m_new = extract_matches(
                s, threshold, k, col_offset=col_off + ci * bm,
                exclude_self=False, col_valid=ids < n_valid,
            )
            return merge_matches(mm, m_new), None

        mm0 = jax.tree.map(
            lambda x: pvary(x, axis_name), empty_matches(Qr.shape[0], k)
        )
        mm, _ = lax.scan(c_block, mm0, jnp.arange(ncb))
        return jax.tree.map(lambda x: x[None], mm)

    stacked = Matches(
        values=P(axis_name, None, None),
        indices=P(axis_name, None, None),
        counts=P(axis_name, None),
    )
    if kind == "dense":
        return shard_map(
            dense_body, mesh=mesh,
            in_specs=(P(None, None), P(axis_name, None)),
            out_specs=stacked, check_vma=False,
        )(Qp, corpus)
    idx, val, nnz = corpus
    return shard_map(
        sparse_body, mesh=mesh,
        in_specs=(
            P(None, None), P(axis_name, None), P(axis_name, None), P(axis_name),
        ),
        out_specs=stacked, check_vma=False,
    )(Qp, idx, val, nnz)
