"""query_topk: rectangular pruned scoring against a prebuilt APSSIndex.

The self-join scoring paths rebuild ``bdims``/``bx`` supports and pruning
bounds on every call; this module is the query-time half of the split:
per call it computes ONLY the query-side block stats (one cheap summary
pass over the padded batch), evaluates the paper's maxweight + minsize
bounds against the index's precomputed corpus block maxima — which is the
inverted-index candidacy test in weighted form, so candidates come from
the prebuilt posting-list supports — and scores exactly the live
``(query_block, corpus_block)`` tiles through a rectangular generalization
of the fused/compacted worklist path (Pallas kernel on TPU, XLA scan
fallback elsewhere). No n×n symmetry assumption anywhere: no mirror
packets, no self-pair exclusion, no triangular worklist cut.

Beyond pruning, the worklist's upper-bound-DESCENDING order is itself
exploitable (``early_exit=True``): the scan carries each query row's
running k-th value, and once every live row's k-th beats the next tile's
upper bound, no remaining tile can contribute — the ``lax.while_loop``
stops and the ordering becomes skipped FLOPs. Exact by construction for
top-k values and indices (a skipped tile's candidates are ≤ the bound ≤
every row's k-th, and ties lose to the buffer under the stable merge);
the only concession is that match COUNTS saturate at k — a row proven to
hold k matches stops counting the tail. DESIGN.md §12 has the full
soundness argument.

Retrace discipline (the server's hot loop must not recompile):

- every index structure enters the jit'd inners as pytree ARGUMENTS —
  nothing corpus-sized is rebuilt or re-traced per call,
- the live-tile worklist is bucket-padded to a power of two
  (``ops.pad_worklist``) so varying live-tile counts reuse compiled code,
- every jitted inner marks the public retrace registry
  (``repro.obs.compile``) at trace time only; ``tests/test_serving.py``
  asserts under an ``assert_no_retrace`` contract that a second query
  compiles nothing.

Sharded indexes (``build_index(mesh=...)``) take the per-shard path with
the SAME pruning as single-host serving: the corpus-side ``BlockStats``
are replicated, so the host evaluates the global live mask once, slices
each shard's block range out of it, and ships every device its own
compacted, bucket-padded worklist through one ``shard_map`` — a shard
scores only its live tiles (XLA scan or the rect Pallas kernel), emits
packets with GLOBAL column ids, and folds them locally. Per-shard top-k
partials come back stacked and the host merges them (``merge_matches``
over disjoint column ranges — exact).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.matches import (
    NEG_INF,
    Matches,
    empty_matches,
    merge_matches,
)
from repro.core.pruning import dense_block_stats, live_tile_mask
from repro.core.sparse import SparseCorpus, gather_dot, to_dense
from repro.kernels.apss_block.fused import (
    NEG_LARGE,
    _rect_tile_packets,
    _topk_sort,
    rect_tile_candidates_early_exit_pallas,
    rect_tile_candidates_pallas,
)
from repro.kernels.apss_block.ops import (
    _merge_packet,
    _on_tpu,
    _pick_bk,
    compact_rect_worklist,
    fold_rect_packets,
    pad_worklist,
)
from repro.kernels.apss_block.sparse import rect_sparse_tile_candidates_pallas
from repro.obs import compile as obs_compile
from repro.obs import metrics, trace
from repro.planner import telemetry
from repro.serving.index import APSSIndex

# Trace-time retrace counters, owned by the public registry
# (repro.obs.compile.MONITOR). The serving contract is "build once, query
# many": after the first call of a given shape these must not move —
# enforced by assert_no_retrace("serving.query") contracts in
# tests/test_serving.py. This name is a back-compat alias to the SAME
# Counter object, so legacy dict-snapshot readers keep working.
TRACE_COUNTS = obs_compile.MONITOR.counts

obs_compile.register_entry_points(
    "serving.query",
    "query_mask", "dense_inner", "sparse_inner", "sharded_query",
    "dense_ee_inner", "sparse_ee_inner", "dense_ee_kernel",
)


def query_topk(
    index: APSSIndex,
    Q,
    threshold: float,
    k: int = 32,
    *,
    block_q: int = 128,
    use_kernel: bool = False,
    use_minsize: bool = True,
    early_exit: bool = False,
    plan=None,
    interpret: bool | None = None,
) -> Matches:
    """Top-k corpus neighbors ≥ ``threshold`` for a batch of queries.

    ``Q`` is ``(B, m)`` dense (a :class:`SparseCorpus` batch is densified —
    query batches are small) and is scored AS GIVEN (no normalization here;
    the server normalizes on ingest). Returns :class:`Matches` with global
    corpus row ids; exact vs the brute-force rectangular oracle
    (``extract_matches(Q @ Cᵀ, t, k, exclude_self=False)``) at every
    threshold, including ``t ≤ 0``, because pruned tiles are provably
    matchless (``core.pruning``).

    The live worklist is compacted host-side (same contract as
    ``apss_fused_compacted``), ordered by upper bound descending, and
    bucket-padded so repeat calls hit the jit cache. ``use_kernel`` routes
    tile scoring through the rectangular Pallas kernels (TPU; interpret
    off-TPU); the default XLA scan is the production path off-TPU.

    ``early_exit=True`` additionally stops the worklist scan once every
    query row's running k-th value beats the remaining upper bounds (see
    module doc): top-k values/indices stay bit-identical to
    ``early_exit=False``; counts saturate at ``min(count, k)``.

    ``plan="auto"`` (or an explicit ``planner.costmodel.QueryPlan``)
    delegates the ``block_q`` / kernel-vs-scan choice to the cost model,
    priced per batch from the index's exact ``BlockStats`` — the
    planner's decision overrides the ``block_q``/``use_kernel`` arguments.
    """
    with trace.span(
        "serving/query", use_kernel=use_kernel, early_exit=early_exit
    ):
        return _query_topk_impl(
            index, Q, threshold, k, block_q=block_q, use_kernel=use_kernel,
            use_minsize=use_minsize, early_exit=early_exit, plan=plan,
            interpret=interpret,
        )


def _query_topk_impl(
    index: APSSIndex,
    Q,
    threshold: float,
    k: int = 32,
    *,
    block_q: int = 128,
    use_kernel: bool = False,
    use_minsize: bool = True,
    early_exit: bool = False,
    plan=None,
    interpret: bool | None = None,
) -> Matches:
    if interpret is None:
        interpret = not _on_tpu()
    if isinstance(Q, SparseCorpus):
        if Q.m != index.m:
            raise ValueError(f"dimension mismatch: Q.m={Q.m} vs index m={index.m}")
        Q = to_dense(Q)
    Q = jnp.asarray(Q)
    if Q.ndim != 2 or Q.shape[1] != index.m:
        raise ValueError(f"Q must be (B, {index.m}); got {Q.shape}")
    B = Q.shape[0]
    if plan is not None:
        from repro.planner.costmodel import plan_query_topk

        if isinstance(plan, str):
            if plan != "auto":
                raise ValueError(f"plan must be 'auto' or a QueryPlan; got {plan!r}")
            plan = plan_query_topk(index, B, float(threshold), k)
        block_q = int(plan.block_q)
        use_kernel = bool(plan.use_kernel)
    if not index.is_sparse:
        # Dense corpora are lane-padded once at build time; match the
        # query batch (query-sized work) so the jitted inners see aligned
        # operands and never re-pad the corpus.
        remk = index.corpus.shape[1] - index.m
        if remk:
            Q = jnp.pad(Q, ((0, 0), (0, remk)))

    if index.mesh is not None:
        if early_exit:
            raise NotImplementedError(
                "early_exit is a single-host worklist optimization; the "
                "sharded path prunes per shard but scans its full live "
                "worklist"
            )
        return _sharded_query_pruned(
            index, Q, threshold, k, block_q=block_q, use_kernel=use_kernel,
            use_minsize=use_minsize, interpret=interpret,
        )

    rem = (-B) % block_q
    Qp = jnp.pad(Q, ((0, rem), (0, 0))) if rem else Q
    grid_q = Qp.shape[0] // block_q
    mask, ub = _query_mask(
        Qp, index.stats, threshold=float(threshold), block_q=block_q,
        use_minsize=use_minsize, normalized=index.normalized,
    )
    mk = np.asarray(mask)
    ubh = np.asarray(ub)
    wl = compact_rect_worklist(mk, ubh)
    live = 0 if wl is None else int(wl.shape[1])
    if telemetry.enabled() or metrics.enabled():
        depth = (
            int(index.bdims.shape[1]) if index.is_sparse
            else int(index.corpus.shape[1])
        )
        if telemetry.enabled():
            telemetry.record(telemetry.ApssStats(
                variant="serving/query",
                n=index.n, m=index.m, block_rows=index.block_rows,
                sparse=index.is_sparse,
                flops=2.0 * live * block_q * index.block_rows * depth,
                live_tiles=live, total_tiles=int(mk.size),
                tile_counts=tuple(int(x) for x in mk.sum(axis=1)),
                extra={"batch": B, "use_kernel": use_kernel},
            ))
        if metrics.enabled():
            metrics.observe(
                "serving.live_tile_fraction", live / max(1, mk.size)
            )
        trace.annotate(batch=B, live_tiles=live, total_tiles=int(mk.size))
    if wl is None:
        return empty_matches(B, k)
    ij_np, tv_np = pad_worklist(wl)
    ij, tvalid = jnp.asarray(ij_np), jnp.asarray(tv_np)
    ubw = None
    if early_exit:
        # Per-worklist-entry upper bounds, in worklist (descending) order;
        # bucket-padding entries get NEG_LARGE so they are always skipped
        # and never gate the global stop.
        u = np.full((tv_np.shape[0],), NEG_LARGE, np.float32)
        u[: wl.shape[1]] = ubh[wl[0], wl[1]].astype(np.float32)
        ubw = jnp.asarray(u)

    scored = None
    if index.is_sparse:
        if early_exit:
            inner_kwargs = dict(
                threshold=float(threshold), k=k, block_q=block_q,
                block_c=index.block_rows, nc_valid=index.n, grid_q=grid_q,
            )
            nqv = jnp.asarray(B, jnp.int32)
            obs_compile.offer_capture(
                "serving.sparse_ee_inner", _rect_sparse_ee_inner,
                Qp, index.bdims, index.bx, ij, tvalid, ubw, nqv,
                **inner_kwargs,
            )
            values, indices, counts, scored = _rect_sparse_ee_inner(
                Qp, index.bdims, index.bx, ij, tvalid, ubw, nqv,
                **inner_kwargs,
            )
        else:
            inner_kwargs = dict(
                threshold=float(threshold), k=k, block_q=block_q,
                block_c=index.block_rows, nc_valid=index.n, grid_q=grid_q,
                use_kernel=use_kernel, interpret=interpret,
            )
            obs_compile.offer_capture(
                "serving.sparse_inner", _rect_sparse_inner,
                Qp, index.bdims, index.bx, ij, tvalid, **inner_kwargs,
            )
            values, indices, counts = _rect_sparse_inner(
                Qp, index.bdims, index.bx, ij, tvalid, **inner_kwargs,
            )
    elif early_exit and use_kernel:
        inner_kwargs = dict(
            threshold=float(threshold), k=k, block_q=block_q,
            block_c=index.block_rows, nc_valid=index.n, grid_q=grid_q,
            nq_valid=B, interpret=interpret,
        )
        obs_compile.offer_capture(
            "serving.dense_ee_kernel", _rect_dense_ee_kernel,
            Qp, index.corpus, ij, tvalid, ubw, **inner_kwargs,
        )
        values, indices, counts, scored = _rect_dense_ee_kernel(
            Qp, index.corpus, ij, tvalid, ubw, **inner_kwargs,
        )
    elif early_exit:
        inner_kwargs = dict(
            threshold=float(threshold), k=k, block_q=block_q,
            block_c=index.block_rows, nc_valid=index.n, grid_q=grid_q,
        )
        nqv = jnp.asarray(B, jnp.int32)
        obs_compile.offer_capture(
            "serving.dense_ee_inner", _rect_dense_ee_inner,
            Qp, index.corpus, ij, tvalid, ubw, nqv, **inner_kwargs,
        )
        values, indices, counts, scored = _rect_dense_ee_inner(
            Qp, index.corpus, ij, tvalid, ubw, nqv, **inner_kwargs,
        )
    else:
        inner_kwargs = dict(
            threshold=float(threshold), k=k, block_q=block_q,
            block_c=index.block_rows, nc_valid=index.n, grid_q=grid_q,
            use_kernel=use_kernel, interpret=interpret,
        )
        obs_compile.offer_capture(
            "serving.dense_inner", _rect_dense_inner,
            Qp, index.corpus, ij, tvalid, **inner_kwargs,
        )
        values, indices, counts = _rect_dense_inner(
            Qp, index.corpus, ij, tvalid, **inner_kwargs,
        )

    if scored is not None and (telemetry.enabled() or metrics.enabled()):
        skipped = live - int(scored)
        if metrics.enabled():
            metrics.incr("serving.early_exit_skipped_tiles", skipped)
        if telemetry.enabled():
            telemetry.record(telemetry.ApssStats(
                variant="serving/early-exit",
                n=index.n, m=index.m, block_rows=index.block_rows,
                sparse=index.is_sparse,
                live_tiles=live, total_tiles=int(mk.size),
                extra={"batch": B, "skipped_tiles": skipped},
            ))
        trace.annotate(early_exit_skipped_tiles=skipped)
    return Matches(values=values[:B], indices=indices[:B], counts=counts[:B])


@functools.partial(
    jax.jit,
    static_argnames=("threshold", "block_q", "use_minsize", "normalized"),
)
def _query_mask(Qp, corpus_stats, *, threshold, block_q, use_minsize, normalized):
    """Query-side block stats + live mask vs PREBUILT corpus stats.

    The only per-call bound computation: ``O(B·m)`` for the query summary
    and one ``(B/bq × nb)`` matmul for the upper bounds. Corpus-side stats
    arrive as index leaves — never recomputed here.
    """
    obs_compile.mark("query_mask")
    qstats = dense_block_stats(Qp.astype(jnp.float32), block_q)
    return live_tile_mask(
        qstats, corpus_stats, threshold,
        use_minsize=use_minsize, normalized=normalized, return_ub=True,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "threshold", "k", "block_q", "block_c", "nc_valid", "grid_q",
        "use_kernel", "interpret",
    ),
)
def _rect_dense_inner(
    Qp, C, ij, tvalid, *,
    threshold, k, block_q, block_c, nc_valid, grid_q, use_kernel, interpret,
):
    """Score live rectangular tiles of a DENSE index; fold to Matches."""
    obs_compile.mark("dense_inner")
    m = Qp.shape[1]
    if use_kernel:
        bk = _pick_bk(m, 512)
        padk = (-m) % bk
        Qk = jnp.pad(Qp, ((0, 0), (0, padk))) if padk else Qp
        Ck = jnp.pad(C, ((0, 0), (0, padk))) if padk else C
        fv, fi, fc = rect_tile_candidates_pallas(
            Qk, Ck, ij, threshold, k,
            block_q=block_q, block_c=block_c, block_k=bk,
            nc_valid=nc_valid, interpret=interpret,
        )
    else:
        Qb = Qp.reshape(grid_q, block_q, m)
        Cb = C.reshape(-1, block_c, m)

        def tile(_, t):
            s = jnp.einsum(
                "qm,cm->qc", Qb[ij[0, t]], Cb[ij[1, t]],
                preferred_element_type=jnp.float32,
            )
            return _, _rect_tile_packets(
                s, ij[1, t], threshold=threshold, k=k,
                block_q=block_q, block_c=block_c, nc_valid=nc_valid,
                topk=_topk_sort,
            )

        _, (fv, fi, fc) = lax.scan(tile, 0, jnp.arange(ij.shape[1]))
    return fold_rect_packets(
        ij, tvalid, fv, fi, fc[..., 0], grid_q=grid_q, block_q=block_q, k=k
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "threshold", "k", "block_q", "block_c", "nc_valid", "grid_q",
        "use_kernel", "interpret",
    ),
)
def _rect_sparse_inner(
    Qp, bdims, bx, ij, tvalid, *,
    threshold, k, block_q, block_c, nc_valid, grid_q, use_kernel, interpret,
):
    """Score live rectangular tiles of a SPARSE index; fold to Matches.

    Per live tile ``(qi, cj)``: the query block's components at the corpus
    block's support dims (``bdims[cj]``) are gathered — the sentinel pad
    ``m`` hits an appended zero column — and contracted against the
    support-densified corpus block ``bx[cj]``. Exact, because every corpus
    nonzero lies inside its own block support (DESIGN.md §5/§6); MXU work
    is ``O(bq · bm · S)``, never ``O(bq · bm · m)``.
    """
    obs_compile.mark("sparse_inner")
    Qext = jnp.pad(Qp.astype(jnp.float32), ((0, 0), (0, 1)))
    Qb = Qext.reshape(grid_q, block_q, -1)

    def gather_t(t):
        return jnp.take(Qb[ij[0, t]], bdims[ij[1, t]], axis=1)  # (bq, S)

    if use_kernel:
        _, qg = lax.scan(
            lambda _, t: (_, gather_t(t)), 0, jnp.arange(ij.shape[1])
        )
        fv, fi, fc = rect_sparse_tile_candidates_pallas(
            qg, bx, ij, threshold, k,
            block_q=block_q, block_c=block_c, nc_valid=nc_valid,
            interpret=interpret,
        )
    else:

        def tile(_, t):
            s = jnp.einsum(
                "qs,cs->qc", gather_t(t), bx[ij[1, t]],
                preferred_element_type=jnp.float32,
            )
            return _, _rect_tile_packets(
                s, ij[1, t], threshold=threshold, k=k,
                block_q=block_q, block_c=block_c, nc_valid=nc_valid,
                topk=_topk_sort,
            )

        _, (fv, fi, fc) = lax.scan(tile, 0, jnp.arange(ij.shape[1]))
    return fold_rect_packets(
        ij, tvalid, fv, fi, fc[..., 0], grid_q=grid_q, block_q=block_q, k=k
    )


# ---------------------------------------------------------------------------
# Early-exit scoring: fused while_loop over the ub-descending worklist
# ---------------------------------------------------------------------------


def _ee_fold(score_tile, ij, tvalid, ub, nq_valid, *, grid_q, block_q, k):
    """Fused score+fold with early exit (the traced half of ``early_exit``).

    Replays ``fold_rect_packets``'s exact merge (same ``_merge_packet``,
    same worklist order) inside a ``lax.while_loop`` that carries the
    running top-k buffers, and adds two sound skips derived from the
    worklist's upper-bound-descending order:

    - tile skip — every live row of the tile's query block already holds
      k real values ≥ this tile's bound, so no candidate in it (value ≤
      bound) can displace a buffer entry (ties lose to the buffer under
      the stable merge): the tile's score/packet work is skipped;
    - global stop — the minimum k-th over ALL live rows beats this tile's
      bound, which bounds every remaining tile (descending order): the
      loop terminates.

    Padded query rows (flat id ≥ ``nq_valid``) are masked to +LARGE so an
    eternally-unfilled padding row can never pin the scan; bucket-padding
    worklist entries carry ``ub = NEG_LARGE`` and are always skipped.
    Returns ``(values, indices, counts, scored)`` with counts saturated at
    k (a skipped tile's matches beyond the k already held are not
    counted — deterministically ``min(exact_count, k)``).
    """
    T = ij.shape[1]
    ubv = jnp.where(tvalid, ub.astype(jnp.float32), jnp.float32(NEG_LARGE))
    rows = jnp.arange(grid_q * block_q, dtype=jnp.int32).reshape(
        grid_q, block_q
    )
    row_live = rows < nq_valid
    big = -jnp.float32(NEG_LARGE)

    def cond(state):
        t, done = state[0], state[1]
        return (t < T) & ~done

    def body(state):
        t, _done, cv, ci, cc, scored = state
        kth = jnp.where(row_live, cv[:, :, k - 1], big)
        u = ubv[t]
        qi = ij[0, t]
        blk_kth = lax.dynamic_index_in_dim(kth, qi, 0, keepdims=False)
        tile_skip = (~tvalid[t]) | (jnp.min(blk_kth) >= u)
        done = (~tvalid[t]) | (jnp.min(kth) >= u)

        def merge(args):
            cv, ci, cc = args
            fv, fi, fc = score_tile(t)
            return _merge_packet(cv, ci, cc, qi, fv, fi, fc[:, 0], k)

        cv, ci, cc = lax.cond(tile_skip, lambda a: a, merge, (cv, ci, cc))
        scored = scored + jnp.where(tile_skip, 0, 1).astype(jnp.int32)
        return (t + 1, done, cv, ci, cc, scored)

    state = (
        jnp.int32(0),
        jnp.zeros((), jnp.bool_),
        jnp.full((grid_q, block_q, k), -jnp.inf, jnp.float32),
        jnp.full((grid_q, block_q, k), -1, jnp.int32),
        jnp.zeros((grid_q, block_q), jnp.int32),
        jnp.int32(0),
    )
    _t, _d, cv, ci, cc, scored = lax.while_loop(cond, body, state)
    values = jnp.where(ci >= 0, cv, NEG_INF).reshape(grid_q * block_q, k)
    indices = ci.reshape(grid_q * block_q, k)
    counts = jnp.minimum(cc, k).reshape(grid_q * block_q)
    return values, indices, counts, scored


@functools.partial(
    jax.jit,
    static_argnames=(
        "threshold", "k", "block_q", "block_c", "nc_valid", "grid_q",
    ),
)
def _rect_dense_ee_inner(
    Qp, C, ij, tvalid, ub, nq_valid, *,
    threshold, k, block_q, block_c, nc_valid, grid_q,
):
    """Early-exit dense scoring: while_loop over the ub-ordered worklist.

    ``nq_valid`` is a TRACED scalar (not static) so varying batch sizes
    inside one ``block_q`` bucket share a single compilation, exactly like
    the non-early-exit inners.
    """
    obs_compile.mark("dense_ee_inner")
    m = Qp.shape[1]
    Qb = Qp.reshape(grid_q, block_q, m)
    Cb = C.reshape(-1, block_c, m)

    def score_tile(t):
        s = jnp.einsum(
            "qm,cm->qc", Qb[ij[0, t]], Cb[ij[1, t]],
            preferred_element_type=jnp.float32,
        )
        return _rect_tile_packets(
            s, ij[1, t], threshold=threshold, k=k,
            block_q=block_q, block_c=block_c, nc_valid=nc_valid,
            topk=_topk_sort,
        )

    return _ee_fold(
        score_tile, ij, tvalid, ub, nq_valid,
        grid_q=grid_q, block_q=block_q, k=k,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "threshold", "k", "block_q", "block_c", "nc_valid", "grid_q",
    ),
)
def _rect_sparse_ee_inner(
    Qp, bdims, bx, ij, tvalid, ub, nq_valid, *,
    threshold, k, block_q, block_c, nc_valid, grid_q,
):
    """Early-exit sparse scoring: a skipped tile skips its support gather
    AND its contraction (``use_kernel`` requests also land here — the
    gather/score loop is the early-exit seam for sparse indexes)."""
    obs_compile.mark("sparse_ee_inner")
    Qext = jnp.pad(Qp.astype(jnp.float32), ((0, 0), (0, 1)))
    Qb = Qext.reshape(grid_q, block_q, -1)

    def score_tile(t):
        qg = jnp.take(Qb[ij[0, t]], bdims[ij[1, t]], axis=1)  # (bq, S)
        s = jnp.einsum(
            "qs,cs->qc", qg, bx[ij[1, t]],
            preferred_element_type=jnp.float32,
        )
        return _rect_tile_packets(
            s, ij[1, t], threshold=threshold, k=k,
            block_q=block_q, block_c=block_c, nc_valid=nc_valid,
            topk=_topk_sort,
        )

    return _ee_fold(
        score_tile, ij, tvalid, ub, nq_valid,
        grid_q=grid_q, block_q=block_q, k=k,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "threshold", "k", "block_q", "block_c", "nc_valid", "grid_q",
        "nq_valid", "interpret",
    ),
)
def _rect_dense_ee_kernel(
    Qp, C, ij, tvalid, ub, *,
    threshold, k, block_q, block_c, nc_valid, grid_q, nq_valid, interpret,
):
    """Early-exit dense scoring through the Pallas rect kernel.

    The kernel carries the running top-k VALUES in VMEM scratch and gates
    each tile's MXU work on the same skip test as :func:`_ee_fold`
    (``kernels.apss_block.fused``); the grid itself cannot stop early, so
    skipped tiles emit neutral packets plus a flag. ``nq_valid`` is static
    here (it is baked into the kernel) — TPU serving uses fixed batches.
    """
    obs_compile.mark("dense_ee_kernel")
    m = Qp.shape[1]
    bk = _pick_bk(m, 512)
    padk = (-m) % bk
    Qk = jnp.pad(Qp, ((0, 0), (0, padk))) if padk else Qp
    Ck = jnp.pad(C, ((0, 0), (0, padk))) if padk else C
    ubk = jnp.where(tvalid, ub.astype(jnp.float32), jnp.float32(NEG_LARGE))
    fv, fi, fc, sk = rect_tile_candidates_early_exit_pallas(
        Qk, Ck, ij, ubk, threshold, k,
        block_q=block_q, block_c=block_c, block_k=bk,
        nc_valid=nc_valid, nq_valid=nq_valid, interpret=interpret,
    )
    values, indices, counts = fold_rect_packets(
        ij, tvalid, fv, fi, fc[..., 0], grid_q=grid_q, block_q=block_q, k=k
    )
    counts = jnp.minimum(counts, k)
    scored = jnp.sum(jnp.where(tvalid, 1 - sk[:, 0], 0).astype(jnp.int32))
    return values, indices, counts, scored


# ---------------------------------------------------------------------------
# Sharded per-shard scoring (mesh-placed indexes)
# ---------------------------------------------------------------------------


def _sharded_query_pruned(
    index, Q, threshold, k, *, block_q, use_kernel, use_minsize, interpret
):
    """Host half of sharded serving: per-shard worklists from global stats.

    One ``_query_mask`` against the replicated corpus stats yields the
    GLOBAL live mask + bounds; each shard's contiguous block range
    (``index.shard_block_range``) is sliced out and compacted into its own
    ub-descending worklist in LOCAL block coordinates. All shards pad to a
    COMMON power-of-two bucket (retrace discipline: one jit cache entry
    per bucket, shared by every shard), stack to ``(p, 2, T)`` /
    ``(p, T)``, and enter one ``shard_map``.
    """
    if index.is_sparse and use_kernel:
        raise NotImplementedError(
            "sharded sparse indexes score via the XLA gather path (no "
            "bdims/bx support compaction is built per shard); use_kernel "
            "applies to dense shards"
        )
    B = Q.shape[0]
    p = index.n_shards
    rem = (-B) % block_q
    Qp = jnp.pad(Q, ((0, rem), (0, 0))) if rem else Q
    grid_q = Qp.shape[0] // block_q
    mask, ub = _query_mask(
        Qp, index.stats, threshold=float(threshold), block_q=block_q,
        use_minsize=use_minsize, normalized=index.normalized,
    )
    mk = np.asarray(mask)
    ubh = np.asarray(ub)
    wls = []
    for s in range(p):
        lo, hi = index.shard_block_range(s)
        wls.append(compact_rect_worklist(mk[:, lo:hi], ubh[:, lo:hi]))
    live = sum(0 if w is None else int(w.shape[1]) for w in wls)
    if telemetry.enabled() or metrics.enabled():
        depth = (
            int(index.corpus[0].shape[1]) if index.is_sparse
            else int(index.corpus.shape[1])
        )
        if telemetry.enabled():
            telemetry.record(telemetry.ApssStats(
                variant="serving/query-sharded",
                n=index.n, m=index.m, devices=p,
                block_rows=index.block_rows, sparse=index.is_sparse,
                flops=2.0 * live * block_q * index.block_rows * depth,
                live_tiles=live, total_tiles=int(mk.size),
                tile_counts=tuple(
                    0 if w is None else int(w.shape[1]) for w in wls
                ),
                extra={"batch": B, "use_kernel": use_kernel},
            ))
        if metrics.enabled():
            metrics.observe(
                "serving.live_tile_fraction", live / max(1, mk.size)
            )
        trace.annotate(
            batch=B, live_tiles=live, total_tiles=int(mk.size), shards=p
        )
    if live == 0:
        return empty_matches(B, k)
    Tmax = max(int(w.shape[1]) for w in wls if w is not None)
    Tb = 1 << max(0, (Tmax - 1).bit_length())
    ij_all = np.zeros((p, 2, Tb), np.int32)
    tv_all = np.zeros((p, Tb), bool)
    for s, w in enumerate(wls):
        if w is None:
            continue
        ij_all[s, :, : w.shape[1]] = w
        tv_all[s, : w.shape[1]] = True
    out = _sharded_query(
        Qp, index.corpus, jnp.asarray(ij_all), jnp.asarray(tv_all),
        mesh=index.mesh, axis_name=index.axis_name, kind=index.kind,
        threshold=float(threshold), k=k, block_q=block_q, grid_q=grid_q,
        block_rows=index.block_rows, nb_loc=index.nb_local,
        n_valid=index.n, use_kernel=use_kernel, interpret=interpret,
    )
    parts = [jax.tree.map(lambda x: x[i], out) for i in range(p)]
    mm = functools.reduce(merge_matches, parts)
    return Matches(mm.values[:B], mm.indices[:B], mm.counts[:B])


@functools.partial(
    jax.jit,
    static_argnames=(
        "mesh", "axis_name", "kind", "threshold", "k", "block_q", "grid_q",
        "block_rows", "nb_loc", "n_valid", "use_kernel", "interpret",
    ),
)
def _sharded_query(
    Qp, corpus, ij, tvalid, *, mesh, axis_name, kind, threshold, k,
    block_q, grid_q, block_rows, nb_loc, n_valid, use_kernel, interpret,
):
    """One shard_map: replicated queries × per-device PRUNED worklist.

    Each device receives its own compacted worklist slice (local corpus
    block coordinates) and scores exactly those tiles — the XLA tile scan
    off-TPU, the rect Pallas kernel with ``use_kernel`` (the worklist rides
    a 3-row scalar-prefetch: rows 0–1 index local DMA blocks, row 2
    carries the GLOBAL block id so packet column ids and validity are
    global). Returns per-shard partial Matches STACKED on a leading
    ``(p,)`` axis — the caller merges them host-side (the partials' column
    ranges are disjoint by construction, so ``merge_matches`` is exact).
    Corpus padding rows (which live only in the last shard) never match:
    validity is evaluated against GLOBAL row ids.
    """
    obs_compile.mark("sharded_query")

    def dense_body(Qr, C_loc, ij_s, tv_s):
        ij_l = ij_s[0]
        tv = tv_s[0]
        nb_off = lax.axis_index(axis_name) * nb_loc
        mloc = C_loc.shape[1]
        if use_kernel:
            bk = _pick_bk(mloc, 512)
            padk = (-mloc) % bk
            Qk = jnp.pad(Qr, ((0, 0), (0, padk))) if padk else Qr
            Ck = jnp.pad(C_loc, ((0, 0), (0, padk))) if padk else C_loc
            ij3 = jnp.concatenate([ij_l, ij_l[1:2] + nb_off], axis=0)
            fv, fi, fc = rect_tile_candidates_pallas(
                Qk, Ck, ij3, threshold, k,
                block_q=block_q, block_c=block_rows, block_k=bk,
                nc_valid=n_valid, interpret=interpret,
            )
        else:
            Qb = Qr.reshape(grid_q, block_q, mloc)
            Cb = C_loc.reshape(nb_loc, block_rows, mloc)

            def tile(_, t):
                s = jnp.einsum(
                    "qm,cm->qc", Qb[ij_l[0, t]], Cb[ij_l[1, t]],
                    preferred_element_type=jnp.float32,
                )
                return _, _rect_tile_packets(
                    s, ij_l[1, t] + nb_off, threshold=threshold, k=k,
                    block_q=block_q, block_c=block_rows, nc_valid=n_valid,
                    topk=_topk_sort,
                )

            _, (fv, fi, fc) = lax.scan(tile, 0, jnp.arange(ij_l.shape[1]))
        v, i, c = fold_rect_packets(
            ij_l, tv, fv, fi, fc[..., 0],
            grid_q=grid_q, block_q=block_q, k=k,
        )
        return Matches(v[None], i[None], c[None])

    def sparse_body(Qr, idxL, valL, nnzL, ij_s, tv_s):
        del nnzL  # scoring sums every (0-padded) slot; nnz not needed
        ij_l = ij_s[0]
        tv = tv_s[0]
        cap = idxL.shape[1]
        nb_off = lax.axis_index(axis_name) * nb_loc
        Ci = idxL.reshape(nb_loc, block_rows, cap)
        Cv = valL.reshape(nb_loc, block_rows, cap)
        Qb = Qr.astype(jnp.float32).reshape(grid_q, block_q, -1)

        def tile(_, t):
            s = gather_dot(Qb[ij_l[0, t]], Ci[ij_l[1, t]], Cv[ij_l[1, t]])
            return _, _rect_tile_packets(
                s, ij_l[1, t] + nb_off, threshold=threshold, k=k,
                block_q=block_q, block_c=block_rows, nc_valid=n_valid,
                topk=_topk_sort,
            )

        _, (fv, fi, fc) = lax.scan(tile, 0, jnp.arange(ij_l.shape[1]))
        v, i, c = fold_rect_packets(
            ij_l, tv, fv, fi, fc[..., 0],
            grid_q=grid_q, block_q=block_q, k=k,
        )
        return Matches(v[None], i[None], c[None])

    stacked = Matches(
        values=P(axis_name, None, None),
        indices=P(axis_name, None, None),
        counts=P(axis_name, None),
    )
    if kind == "dense":
        return shard_map(
            dense_body, mesh=mesh,
            in_specs=(
                P(None, None), P(axis_name, None),
                P(axis_name, None, None), P(axis_name, None),
            ),
            out_specs=stacked, check_vma=False,
        )(Qp, corpus, ij, tvalid)
    idx, val, nnz = corpus
    return shard_map(
        sparse_body, mesh=mesh,
        in_specs=(
            P(None, None), P(axis_name, None), P(axis_name, None),
            P(axis_name), P(axis_name, None, None), P(axis_name, None),
        ),
        out_specs=stacked, check_vma=False,
    )(Qp, idx, val, nnz, ij, tvalid)
