"""RecSys model family: two-tower retrieval, BERT4Rec, DIN, BST.

The hot path in every ranking/retrieval model is the sparse embedding
lookup. JAX has no native EmbeddingBag, so :func:`embedding_bag` /
:func:`embedding_bag_ragged` build it from ``jnp.take`` +
``jax.ops.segment_sum`` — this IS part of the system (tables are
vocab-sharded over the ``model`` axis via ``param_specs``; GSPMD turns the
row gather into collectives).

``retrieval_scores`` (1 query × 10⁶ candidates) routes through
``repro.core.similarity_topk`` — candidate retrieval is literally the
paper's similarity problem, and the distributed corpus scoring reuses the
paper's horizontal distribution.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.apss import similarity_topk
from repro.core.matches import Matches
from repro.models.layers import chunked_attention, dense_init, embed_init, mlp, rms_norm

# ---------------------------------------------------------------------------
# EmbeddingBag (take + segment_sum — JAX has no native one)
# ---------------------------------------------------------------------------


def embedding_bag(
    table: jax.Array,            # (V, E)
    ids: jax.Array,              # (B, L) int32, -1 = padding
    weights: jax.Array | None = None,  # (B, L)
    *,
    mode: str = "sum",
) -> jax.Array:
    """Fixed-width multi-hot bag lookup: gather rows, mask, reduce."""
    valid = (ids >= 0).astype(table.dtype)
    emb = jnp.take(table, jnp.maximum(ids, 0), axis=0)       # (B, L, E)
    w = valid if weights is None else weights * valid
    emb = emb * w[..., None]
    s = jnp.sum(emb, axis=1)
    if mode == "sum":
        return s
    if mode == "mean":
        return s / jnp.maximum(jnp.sum(w, axis=1, keepdims=True), 1.0)
    raise ValueError(mode)


def embedding_bag_ragged(
    table: jax.Array,        # (V, E)
    flat_ids: jax.Array,     # (N,) int32
    segment_ids: jax.Array,  # (N,) int32 bag index per id
    num_segments: int,
    weights: jax.Array | None = None,
) -> jax.Array:
    """Ragged EmbeddingBag: ``jnp.take`` + ``jax.ops.segment_sum``."""
    emb = jnp.take(table, flat_ids, axis=0)
    if weights is not None:
        emb = emb * weights[:, None]
    return jax.ops.segment_sum(emb, segment_ids, num_segments=num_segments)


# ---------------------------------------------------------------------------
# Two-tower retrieval (Yi et al., RecSys'19)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TwoTowerConfig:
    name: str = "two-tower-retrieval"
    embed_dim: int = 256
    tower_dims: tuple = (1024, 512, 256)
    n_items: int = 10_000_000
    n_user_fields: int = 8
    user_vocab: int = 1_000_000
    history_len: int = 50
    temperature: float = 0.05
    dtype: Any = jnp.float32


def init_two_tower(key, cfg: TwoTowerConfig) -> dict:
    ks = jax.random.split(key, 8)
    def tower(k, d_in):
        kk = jax.random.split(k, len(cfg.tower_dims))
        ws, bs, d = [], [], d_in
        for i, dout in enumerate(cfg.tower_dims):
            ws.append(dense_init(kk[i], d, dout, cfg.dtype))
            bs.append(jnp.zeros((dout,), cfg.dtype))
            d = dout
        return {"w": ws, "b": bs}
    d_user_in = cfg.embed_dim * (cfg.n_user_fields + 1)  # fields + history bag
    d_item_in = cfg.embed_dim
    return {
        "item_table": embed_init(ks[0], cfg.n_items, cfg.embed_dim, cfg.dtype),
        "user_table": embed_init(ks[1], cfg.user_vocab, cfg.embed_dim, cfg.dtype),
        "user_tower": tower(ks[2], d_user_in),
        "item_tower": tower(ks[3], d_item_in),
    }


def two_tower_param_specs(cfg: TwoTowerConfig) -> dict:
    return {
        "item_table": P("model", None),
        "user_table": P("model", None),
        "user_tower": {"w": [P(None, "model")] * 3, "b": [P("model")] * 3},
        "item_tower": {"w": [P(None, "model")] * 3, "b": [P("model")] * 3},
    }


def _l2norm(x):
    n = jnp.linalg.norm(x.astype(jnp.float32), axis=-1, keepdims=True)
    return x / jnp.maximum(n, 1e-6).astype(x.dtype)


def user_embedding(params, cfg: TwoTowerConfig, batch) -> jax.Array:
    fields = jnp.take(
        params["user_table"], batch["user_fields"], axis=0
    )                                                   # (B, F, E)
    hist = embedding_bag(
        params["item_table"], batch["history"], mode="mean"
    )                                                   # (B, E)
    x = jnp.concatenate(
        [fields.reshape(fields.shape[0], -1), hist], axis=-1
    )
    t = params["user_tower"]
    return _l2norm(mlp(x, t["w"], t["b"]))


def item_embedding(params, cfg: TwoTowerConfig, item_ids) -> jax.Array:
    x = jnp.take(params["item_table"], item_ids, axis=0)
    t = params["item_tower"]
    return _l2norm(mlp(x, t["w"], t["b"]))


def two_tower_loss(params, cfg: TwoTowerConfig, batch) -> tuple[jax.Array, dict]:
    """In-batch sampled softmax with logQ correction."""
    u = user_embedding(params, cfg, batch)              # (B, E)
    i = item_embedding(params, cfg, batch["item_ids"])  # (B, E)
    logits = (u @ i.T).astype(jnp.float32) / cfg.temperature
    logq = batch.get("sampling_logq")
    if logq is not None:
        logits = logits - logq[None, :]
    labels = jnp.arange(u.shape[0])
    nll = -jax.nn.log_softmax(logits, axis=-1)[labels, labels]
    loss = jnp.mean(nll)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, {"loss": loss, "in_batch_acc": acc}


def two_tower_score(params, cfg: TwoTowerConfig, batch) -> jax.Array:
    """Pointwise (user, item) scores — serve_p99 / serve_bulk shapes."""
    u = user_embedding(params, cfg, batch)
    i = item_embedding(params, cfg, batch["item_ids"])
    return jnp.sum(u * i, axis=-1) / cfg.temperature


def retrieval_scores(
    params, cfg: TwoTowerConfig, batch, candidate_ids, *, k: int = 256,
    threshold: float = 0.0, block_rows: int = 4096,
) -> Matches:
    """Score one (or few) queries against a large candidate corpus.

    This is the paper's similarity join: corpus embeddings are computed
    tower-side, then ``similarity_topk`` streams MXU-sized blocks — on a
    mesh, candidates shard over ``data`` like the horizontal algorithm.
    """
    u = user_embedding(params, cfg, batch)              # (Q, E)
    c = item_embedding(params, cfg, candidate_ids)      # (N, E)
    return similarity_topk(
        u, c, threshold, k=k, block_rows=u.shape[0], exclude_self=False
    )


# ---------------------------------------------------------------------------
# BERT4Rec (arXiv:1904.06690) — bidirectional masked sequence model
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Bert4RecConfig:
    name: str = "bert4rec"
    embed_dim: int = 64
    n_blocks: int = 2
    n_heads: int = 2
    seq_len: int = 200
    n_items: int = 60_000
    d_ff: int = 256
    dtype: Any = jnp.float32

    @property
    def mask_token(self) -> int:
        return self.n_items  # first padding row of the padded table

    @property
    def padded_items(self) -> int:
        """Table rows incl. [MASK], padded to 512 for vocab sharding."""
        return ((self.n_items + 1 + 511) // 512) * 512


def init_bert4rec(key, cfg: Bert4RecConfig) -> dict:
    ks = jax.random.split(key, 2 + 6 * cfg.n_blocks)
    d, h = cfg.embed_dim, cfg.n_heads
    blocks = []
    for i in range(cfg.n_blocks):
        kk = jax.random.split(ks[2 + i], 8)
        blocks.append({
            "attn_norm": jnp.ones((d,), cfg.dtype),
            "wq": dense_init(kk[0], d, d, cfg.dtype),
            "wk": dense_init(kk[1], d, d, cfg.dtype),
            "wv": dense_init(kk[2], d, d, cfg.dtype),
            "wo": dense_init(kk[3], d, d, cfg.dtype),
            "ffn_norm": jnp.ones((d,), cfg.dtype),
            "w1": dense_init(kk[4], d, cfg.d_ff, cfg.dtype),
            "b1": jnp.zeros((cfg.d_ff,), cfg.dtype),
            "w2": dense_init(kk[5], cfg.d_ff, d, cfg.dtype),
            "b2": jnp.zeros((d,), cfg.dtype),
        })
    return {
        "item_table": embed_init(ks[0], cfg.padded_items, d, cfg.dtype),
        "pos_table": embed_init(ks[1], cfg.seq_len, d, cfg.dtype),
        "blocks": blocks,
        "final_norm": jnp.ones((d,), cfg.dtype),
    }


def bert4rec_param_specs(cfg: Bert4RecConfig) -> dict:
    blk = {
        "attn_norm": P(None), "wq": P(None, "model"), "wk": P(None, "model"),
        "wv": P(None, "model"), "wo": P("model", None), "ffn_norm": P(None),
        "w1": P(None, "model"), "b1": P("model"),
        "w2": P("model", None), "b2": P(None),
    }
    return {
        "item_table": P("model", None),
        "pos_table": P(None, None),
        "blocks": [dict(blk) for _ in range(cfg.n_blocks)],
        "final_norm": P(None),
    }


def bert4rec_encode(params, cfg: Bert4RecConfig, item_ids) -> jax.Array:
    b, s = item_ids.shape
    x = jnp.take(params["item_table"], item_ids, axis=0)
    x = x + params["pos_table"][None, :s]
    d, h = cfg.embed_dim, cfg.n_heads
    hd = d // h
    for p in params["blocks"]:
        xn = rms_norm(x, p["attn_norm"])
        q = jnp.einsum("bsd,de->bse", xn, p["wq"]).reshape(b, s, h, hd)
        k = jnp.einsum("bsd,de->bse", xn, p["wk"]).reshape(b, s, h, hd)
        v = jnp.einsum("bsd,de->bse", xn, p["wv"]).reshape(b, s, h, hd)
        o = chunked_attention(
            jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
            causal=False, q_chunk=min(128, s), kv_chunk=min(128, s),
        )
        o = jnp.swapaxes(o, 1, 2).reshape(b, s, d)
        x = x + jnp.einsum("bsd,de->bse", o, p["wo"])
        xn = rms_norm(x, p["ffn_norm"])
        hh = jax.nn.gelu(jnp.einsum("bsd,df->bsf", xn, p["w1"]) + p["b1"])
        x = x + jnp.einsum("bsf,fd->bsd", hh, p["w2"]) + p["b2"]
    return rms_norm(x, params["final_norm"])


def bert4rec_loss(params, cfg: Bert4RecConfig, batch) -> tuple[jax.Array, dict]:
    """Masked-item prediction (cloze) CE over masked positions."""
    h = bert4rec_encode(params, cfg, batch["item_ids"])     # (B, S, d)
    logits = jnp.einsum(
        "bsd,vd->bsv", h, params["item_table"][: cfg.n_items],
        preferred_element_type=jnp.float32,
    )
    labels = batch["labels"]
    mask = batch["mask"].astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    loss = jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, {"loss": loss}


def bert4rec_score(params, cfg: Bert4RecConfig, batch) -> jax.Array:
    """Next-item scores from the final position (serving)."""
    h = bert4rec_encode(params, cfg, batch["item_ids"])
    return jnp.einsum(
        "bd,vd->bv", h[:, -1], params["item_table"][: cfg.n_items],
        preferred_element_type=jnp.float32,
    )


# ---------------------------------------------------------------------------
# DIN (arXiv:1706.06978) — target attention over user history
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DINConfig:
    name: str = "din"
    embed_dim: int = 18
    seq_len: int = 100
    attn_dims: tuple = (80, 40)
    mlp_dims: tuple = (200, 80)
    n_items: int = 1_000_000
    dtype: Any = jnp.float32


def init_din(key, cfg: DINConfig) -> dict:
    ks = jax.random.split(key, 10)
    e = cfg.embed_dim
    attn_w, attn_b, d = [], [], 4 * e
    for i, dout in enumerate((*cfg.attn_dims, 1)):
        attn_w.append(dense_init(ks[1 + i], d, dout, cfg.dtype))
        attn_b.append(jnp.zeros((dout,), cfg.dtype))
        d = dout
    mlp_w, mlp_b, d = [], [], 2 * e
    for i, dout in enumerate((*cfg.mlp_dims, 1)):
        mlp_w.append(dense_init(ks[5 + i], d, dout, cfg.dtype))
        mlp_b.append(jnp.zeros((dout,), cfg.dtype))
        d = dout
    return {
        "item_table": embed_init(ks[0], cfg.n_items, e, cfg.dtype),
        "attn": {"w": attn_w, "b": attn_b},
        "mlp": {"w": mlp_w, "b": mlp_b},
    }


def din_param_specs(cfg: DINConfig) -> dict:
    return {
        "item_table": P("model", None),
        "attn": {"w": [P(None, None)] * 3, "b": [P(None)] * 3},
        "mlp": {"w": [P(None, None)] * 3, "b": [P(None)] * 3},
    }


def din_logits(params, cfg: DINConfig, batch) -> jax.Array:
    hist = jnp.take(  # (B,S,E)
        params["item_table"], jnp.maximum(batch["history"], 0), axis=0
    )
    valid = (batch["history"] >= 0).astype(jnp.float32)
    target = jnp.take(params["item_table"], batch["item_ids"], axis=0)  # (B,E)
    t = jnp.broadcast_to(target[:, None, :], hist.shape)
    ai = jnp.concatenate([hist, t, hist - t, hist * t], axis=-1)        # (B,S,4E)
    score = mlp(ai, params["attn"]["w"], params["attn"]["b"], act=jax.nn.sigmoid)[
        ..., 0
    ]
    score = score * valid  # DIN: no softmax
    pooled = jnp.einsum("bs,bse->be", score, hist)
    x = jnp.concatenate([pooled, target], axis=-1)
    return mlp(x, params["mlp"]["w"], params["mlp"]["b"])[..., 0]


def din_loss(params, cfg: DINConfig, batch) -> tuple[jax.Array, dict]:
    logits = din_logits(params, cfg, batch)
    y = batch["click"].astype(jnp.float32)
    loss = jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )
    return loss, {"loss": loss}


# ---------------------------------------------------------------------------
# BST (arXiv:1905.06874) — Behavior Sequence Transformer
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BSTConfig:
    name: str = "bst"
    embed_dim: int = 32
    seq_len: int = 20           # history (seq_len-1) + target
    n_blocks: int = 1
    n_heads: int = 8
    mlp_dims: tuple = (1024, 512, 256)
    n_items: int = 1_000_000
    d_ff: int = 128
    dtype: Any = jnp.float32


def init_bst(key, cfg: BSTConfig) -> dict:
    ks = jax.random.split(key, 4 + cfg.n_blocks)
    e = cfg.embed_dim
    blocks = []
    for i in range(cfg.n_blocks):
        kk = jax.random.split(ks[2 + i], 8)
        blocks.append({
            "wq": dense_init(kk[0], e, e, cfg.dtype),
            "wk": dense_init(kk[1], e, e, cfg.dtype),
            "wv": dense_init(kk[2], e, e, cfg.dtype),
            "wo": dense_init(kk[3], e, e, cfg.dtype),
            "norm1": jnp.ones((e,), cfg.dtype),
            "w1": dense_init(kk[4], e, cfg.d_ff, cfg.dtype),
            "b1": jnp.zeros((cfg.d_ff,), cfg.dtype),
            "w2": dense_init(kk[5], cfg.d_ff, e, cfg.dtype),
            "b2": jnp.zeros((e,), cfg.dtype),
            "norm2": jnp.ones((e,), cfg.dtype),
        })
    mlp_w, mlp_b, d = [], [], cfg.seq_len * e
    kk = jax.random.split(ks[-1], len(cfg.mlp_dims) + 1)
    for i, dout in enumerate((*cfg.mlp_dims, 1)):
        mlp_w.append(dense_init(kk[i], d, dout, cfg.dtype))
        mlp_b.append(jnp.zeros((dout,), cfg.dtype))
        d = dout
    return {
        "item_table": embed_init(ks[0], cfg.n_items, e, cfg.dtype),
        "pos_table": embed_init(ks[1], cfg.seq_len, e, cfg.dtype),
        "blocks": blocks,
        "mlp": {"w": mlp_w, "b": mlp_b},
    }


def bst_param_specs(cfg: BSTConfig) -> dict:
    blk = {
        "wq": P(None, "model"), "wk": P(None, "model"), "wv": P(None, "model"),
        "wo": P("model", None), "norm1": P(None),
        "w1": P(None, "model"), "b1": P("model"),
        "w2": P("model", None), "b2": P(None), "norm2": P(None),
    }
    return {
        "item_table": P("model", None),
        "pos_table": P(None, None),
        "blocks": [dict(blk) for _ in range(cfg.n_blocks)],
        "mlp": {"w": [P(None, "model"), P("model", None), P(None, None), P(None, None)],
                "b": [P("model"), P(None), P(None), P(None)]},
    }


def bst_logits(params, cfg: BSTConfig, batch) -> jax.Array:
    """Sequence = history ++ target item; transformer; concat → MLP → logit."""
    seq = jnp.concatenate(
        [batch["history"], batch["item_ids"][:, None]], axis=1
    )                                                    # (B, S)
    b, s = seq.shape
    e, h = cfg.embed_dim, cfg.n_heads
    hd = e // h
    x = jnp.take(params["item_table"], jnp.maximum(seq, 0), axis=0)
    x = x + params["pos_table"][None, :s]
    for p in params["blocks"]:
        q = jnp.einsum("bsd,de->bse", x, p["wq"]).reshape(b, s, h, hd)
        k = jnp.einsum("bsd,de->bse", x, p["wk"]).reshape(b, s, h, hd)
        v = jnp.einsum("bsd,de->bse", x, p["wv"]).reshape(b, s, h, hd)
        logits = jnp.einsum("bqhe,bkhe->bhqk", q, k) / (hd ** 0.5)
        w = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(x.dtype)
        o = jnp.einsum("bhqk,bkhe->bqhe", w, v).reshape(b, s, e)
        x = rms_norm(x + jnp.einsum("bsd,de->bse", o, p["wo"]), p["norm1"])
        ff = jnp.einsum("bsf,fd->bsd", jax.nn.relu(
            jnp.einsum("bsd,df->bsf", x, p["w1"]) + p["b1"]), p["w2"]) + p["b2"]
        x = rms_norm(x + ff, p["norm2"])
    flat = x.reshape(b, s * e)
    return mlp(flat, params["mlp"]["w"], params["mlp"]["b"], act=jax.nn.leaky_relu)[
        ..., 0
    ]


def bst_loss(params, cfg: BSTConfig, batch) -> tuple[jax.Array, dict]:
    logits = bst_logits(params, cfg, batch)
    y = batch["click"].astype(jnp.float32)
    loss = jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )
    return loss, {"loss": loss}
