"""Model zoo: LM transformers (GQA / qk-norm / MLA / MoE), recsys models,
and GNNs — all pure-function JAX (params as pytrees, explicit RNG)."""

from repro.models.transformer import (  # noqa: F401
    TransformerConfig,
    init_transformer,
    transformer_loss,
    transformer_logits,
    prefill,
    decode_step,
)
from repro.models import recsys, gnn  # noqa: F401
