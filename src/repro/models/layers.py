"""Shared neural-net layers (pure functions over param pytrees).

Attention comes in three memory regimes:

- :func:`chunked_attention` — the flash algorithm expressed in XLA (scan over
  KV chunks with running (m, l, acc)); O(S·chunk) memory instead of O(S²).
  This is the train/prefill path everywhere the Pallas kernel isn't used
  (the CPU dry-run compiles this).
- :func:`repro.kernels.flash_attention` — the Pallas TPU kernel (same math).
- :func:`decode_attention_xla` (+ sequence-sharded variant in
  ``transformer.py``) — single-token decode over a KV cache.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

NEG_LARGE = -0.5e30


# -- norms -------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


def head_rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """QK-norm: RMS over the head dim of ``(..., H, D)`` activations."""
    return rms_norm(x, scale, eps)


# -- rotary position embedding -------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(
    x: jax.Array,            # (B, S, H, D)
    positions: jax.Array,    # (B, S)
    theta: float = 1e6,
) -> jax.Array:
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- attention ----------------------------------------------------------------


def chunked_attention(
    q: jax.Array,   # (B, Hq, S, D)
    k: jax.Array,   # (B, Hkv, S, D)
    v: jax.Array,   # (B, Hkv, S, D)
    *,
    causal: bool = True,
    scale: float | None = None,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    probs_dtype=None,
) -> jax.Array:
    """Flash attention in XLA: O(S·kv_chunk) live memory, exact softmax.

    Supports distinct QK and V head dims (MLA: qk = nope+rope, v smaller).
    ``probs_dtype=bf16`` stores the per-chunk probability tensors in bf16
    (halving the dominant chunk-score HBM traffic; §Perf) with f32
    accumulation — softmax statistics (m, l) stay f32, so the error is one
    rounding of p only.
    """
    b, hq, s, d = q.shape
    dv = v.shape[-1]
    hkv = k.shape[1]
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)

    def divisor_chunk(n, target):
        c = min(target, n)
        while n % c:
            c -= 1
        return c

    qc = divisor_chunk(s, q_chunk)
    kc = divisor_chunk(s, kv_chunk)
    nq, nk = s // qc, s // kc

    # (B, Hkv, group, S, D) view so the kernel is a plain batched matmul.
    qg = q.reshape(b, hkv, group, s, d)

    def q_block(carry, qi):
        qq = lax.dynamic_slice_in_dim(qg, qi * qc, qc, axis=3) * scale

        def kv_block(inner, ki):
            m_prev, l_prev, acc = inner
            kk = lax.dynamic_slice_in_dim(k, ki * kc, kc, axis=2)
            vv = lax.dynamic_slice_in_dim(v, ki * kc, kc, axis=2)
            sij = jnp.einsum(
                "bhgqd,bhkd->bhgqk", qq, kk,
                preferred_element_type=jnp.float32,
            )
            if causal:
                qpos = qi * qc + jnp.arange(qc)
                kpos = ki * kc + jnp.arange(kc)
                mask = qpos[:, None] >= kpos[None, :]
                sij = jnp.where(mask[None, None, None], sij, NEG_LARGE)
            m_new = jnp.maximum(m_prev, jnp.max(sij, axis=-1, keepdims=True))
            alpha = jnp.exp(m_prev - m_new)
            p = jnp.exp(sij - m_new)
            l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
            pv = p if probs_dtype is None else p.astype(probs_dtype)
            acc_new = acc * alpha + jnp.einsum(
                "bhgqk,bhkd->bhgqd", pv, vv,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, group, qc, 1), NEG_LARGE, jnp.float32)
        l0 = jnp.zeros((b, hkv, group, qc, 1), jnp.float32)
        a0 = jnp.zeros((b, hkv, group, qc, dv), jnp.float32)
        # Causal: KV chunks strictly above the diagonal contribute nothing —
        # XLA cannot skip them data-dependently inside scan, so we bound the
        # loop with the static chunk count (full sweep; the Pallas kernel is
        # where the skip actually saves FLOPs).
        (m_, l_, acc), _ = lax.scan(kv_block, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.where(l_ == 0.0, 1.0, l_)
        return carry, out.astype(q.dtype)

    _, outs = lax.scan(q_block, None, jnp.arange(nq))
    # outs: (nq, B, Hkv, group, qc, Dv) → (B, Hq, S, Dv)
    outs = jnp.moveaxis(outs, 0, 3)                    # (B, Hkv, group, nq, qc, Dv)
    return outs.reshape(b, hq, s, dv)


def decode_attention_xla(
    q: jax.Array,        # (B, Hq, D)
    k: jax.Array,        # (B, Hkv, L, D)
    v: jax.Array,        # (B, Hkv, L, D)
    lengths: jax.Array,  # (B,)
    *,
    scale: float | None = None,
    with_partials: bool = False,
):
    """Single-token decode attention (XLA path; matvec-bound)."""
    b, hq, d = q.shape
    hkv, L = k.shape[1], k.shape[2]
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    qg = q.reshape(b, hkv, group, d).astype(jnp.float32) * scale
    s = jnp.einsum(
        "bhgd,bhld->bhgl", qg, k.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    valid = jnp.arange(L)[None, None, None, :] < lengths[:, None, None, None]
    s = jnp.where(valid, s, NEG_LARGE)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.where(valid, jnp.exp(s - m), 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    acc = jnp.einsum("bhgl,bhld->bhgd", p, v.astype(jnp.float32))
    if with_partials:
        return (
            acc.reshape(b, hq, d),
            m.reshape(b, hq),
            l.reshape(b, hq),
        )
    out = acc / jnp.where(l == 0.0, 1.0, l)
    return out.reshape(b, hq, d).astype(q.dtype)


# -- MLP ----------------------------------------------------------------------


def swiglu(
    x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array
) -> jax.Array:
    """SwiGLU MLP (LLaMA/Qwen FFN)."""
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, w_down)


def mlp(
    x: jax.Array, ws: list[jax.Array], bs: list[jax.Array], act=jax.nn.relu
) -> jax.Array:
    """Plain MLP tower (recsys): act on every layer but the last."""
    h = x
    for i, (w, b) in enumerate(zip(ws, bs)):
        h = jnp.einsum("...d,df->...f", h, w) + b
        if i < len(ws) - 1:
            h = act(h)
    return h


# -- init helpers ---------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype=jnp.bfloat16) -> jax.Array:
    scale = (2.0 / (d_in + d_out)) ** 0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.bfloat16) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)
