"""Decoder-only LM transformer covering all five assigned LM architectures.

Feature matrix:
- GQA attention with optional QK-norm (qwen3-1.7b / qwen3-8b)
- MLA latent attention with absorbed decode (minicpm3-4b)
- MoE FFN: top-k routed experts + optional shared experts (deepseek-moe-16b)
  and optional parallel dense-residual FFN (arctic-480b)
- layer stacking via ``lax.scan`` over stacked params (compile-time sanity at
  512 devices) with per-layer remat
- memory-efficient chunked attention (flash-in-XLA) for train/prefill
- KV-cache decode with *sequence-parallel* attention: the cache's sequence
  dim is sharded over mesh axes and GSPMD turns the softmax reductions into
  all-reduces — the logsumexp analogue of the paper's vertical partial-score
  accumulation. This is how ``long_500k`` (524288-token cache) decodes with
  full attention at linear cost.

Params are plain nested dicts; sharding is annotated via
``repro.distributed.sharding.shard`` (no-op on a single device) and
``param_specs`` (consumed by the launcher / dry-run for in_shardings).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import shard, shard_batch
from repro.models.layers import (
    apply_rope,
    chunked_attention,
    decode_attention_xla,
    dense_init,
    embed_init,
    rms_norm,
    swiglu,
)
from repro.models.moe import (
    MoEParams,
    init_moe,
    moe_ffn,
    moe_ffn_ep,
    moe_param_specs,
)


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # attention
    attention: str = "gqa"          # "gqa" | "mla"
    qk_norm: bool = False
    rope_theta: float = 1e6
    # MLA dims (minicpm3/deepseek style)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # MoE
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    n_shared_experts: int = 0
    dense_residual: bool = False    # arctic: dense FFN in parallel with MoE
    first_k_dense: int = 0          # deepseek: leading dense layers
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    # numerics / memory
    dtype: Any = jnp.bfloat16
    remat: bool = True
    q_chunk: int = 512
    kv_chunk: int = 1024
    loss_chunk: int = 2048
    # §Perf knobs (EXPERIMENTS.md): bf16 attention probabilities halve the
    # chunk-score HBM traffic; "ep" MoE dispatch replaces the GSPMD scatter
    # all-reduce with one TP-shaped psum per layer.
    bf16_probs: bool = False
    moe_impl: str = "gspmd"      # "gspmd" | "ep"
    grad_accum: int = 1          # microbatches per step (activation memory ÷N)
    # parallelism
    fsdp: bool = False

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to 256 for TP divisibility (MaxText-style table
        padding; padded ids are never emitted as labels/tokens)."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def qk_head_dim(self) -> int:
        if self.attention == "mla":
            return self.qk_nope_dim + self.qk_rope_dim
        return self.head_dim

    @property
    def v_dim(self) -> int:
        return self.v_head_dim if self.attention == "mla" else self.head_dim


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_attn(key, cfg: TransformerConfig) -> dict:
    dt = cfg.dtype
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    if cfg.attention == "mla":
        p = {
            "wq_a": dense_init(ks[0], d, cfg.q_lora_rank, dt),
            "q_norm": jnp.ones((cfg.q_lora_rank,), dt),
            "wq_b": dense_init(
                ks[1], cfg.q_lora_rank, cfg.n_heads * cfg.qk_head_dim, dt
            ),
            "wkv_a": dense_init(
                ks[2], d, cfg.kv_lora_rank + cfg.qk_rope_dim, dt
            ),
            "kv_norm": jnp.ones((cfg.kv_lora_rank,), dt),
            "wkv_b": dense_init(
                ks[3],
                cfg.kv_lora_rank,
                cfg.n_heads * (cfg.qk_nope_dim + cfg.v_head_dim),
                dt,
            ),
            "wo": dense_init(ks[4], cfg.n_heads * cfg.v_head_dim, d, dt),
        }
    else:
        p = {
            "wq": dense_init(ks[0], d, cfg.n_heads * cfg.head_dim, dt),
            "wk": dense_init(ks[1], d, cfg.n_kv_heads * cfg.head_dim, dt),
            "wv": dense_init(ks[2], d, cfg.n_kv_heads * cfg.head_dim, dt),
            "wo": dense_init(ks[3], cfg.n_heads * cfg.head_dim, d, dt),
        }
        if cfg.qk_norm:
            p["q_scale"] = jnp.ones((cfg.head_dim,), dt)
            p["k_scale"] = jnp.ones((cfg.head_dim,), dt)
    return p


def _init_ffn(key, cfg: TransformerConfig, *, dense_only: bool = False) -> dict:
    dt = cfg.dtype
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    if not cfg.moe or dense_only:
        return {
            "w_gate": dense_init(ks[0], d, cfg.d_ff, dt),
            "w_up": dense_init(ks[1], d, cfg.d_ff, dt),
            "w_down": dense_init(ks[2], cfg.d_ff, d, dt),
        }
    p: dict = {"moe": init_moe(ks[0], d, cfg.d_ff_expert, cfg.n_experts, dt)}
    if cfg.n_shared_experts:
        f_sh = cfg.n_shared_experts * cfg.d_ff_expert
        p["shared"] = {
            "w_gate": dense_init(ks[1], d, f_sh, dt),
            "w_up": dense_init(ks[2], d, f_sh, dt),
            "w_down": dense_init(ks[3], f_sh, d, dt),
        }
    if cfg.dense_residual:
        p["dense"] = {
            "w_gate": dense_init(ks[1], d, cfg.d_ff, dt),
            "w_up": dense_init(ks[2], d, cfg.d_ff, dt),
            "w_down": dense_init(ks[3], cfg.d_ff, d, dt),
        }
    return p


def _init_block(key, cfg: TransformerConfig, *, dense_only: bool = False) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": jnp.ones((cfg.d_model,), cfg.dtype),
        "attn": _init_attn(k1, cfg),
        "ffn_norm": jnp.ones((cfg.d_model,), cfg.dtype),
        "ffn": _init_ffn(k2, cfg, dense_only=dense_only),
    }


def init_transformer(key, cfg: TransformerConfig) -> dict:
    k_emb, k_layers, k_head, k_dense = jax.random.split(key, 4)
    n_scanned = cfg.n_layers - cfg.first_k_dense
    layer_keys = jax.random.split(k_layers, n_scanned)
    stacked = jax.vmap(lambda k: _init_block(k, cfg))(layer_keys)
    params = {
        "embed": embed_init(k_emb, cfg.padded_vocab, cfg.d_model, cfg.dtype),
        "layers": stacked,
        "final_norm": jnp.ones((cfg.d_model,), cfg.dtype),
        "lm_head": dense_init(k_head, cfg.d_model, cfg.padded_vocab, cfg.dtype),
    }
    if cfg.first_k_dense:
        dk = jax.random.split(k_dense, cfg.first_k_dense)
        params["dense_layers"] = [
            _init_block(dk[i], cfg, dense_only=True)
            for i in range(cfg.first_k_dense)
        ]
    return params


def param_specs(cfg: TransformerConfig) -> dict:
    """PartitionSpecs for every param (model/tensor parallel [+ FSDP]).

    FSDP shards over BOTH batch axes (pod, data) — forgetting `pod` would
    silently replicate params across pods and cap ZeRO scaling at one pod
    (caught by the multi-pod dry-run memory table).
    """
    f = ("pod", "data") if cfg.fsdp else None
    def attn_specs():
        if cfg.attention == "mla":
            s = {
                "wq_a": P(f, None),
                "q_norm": P(None),
                "wq_b": P(f, "model"),
                "wkv_a": P(f, None),
                "kv_norm": P(None),
                "wkv_b": P(f, "model"),
                "wo": P("model", f),
            }
        else:
            s = {
                "wq": P(f, "model"),
                "wk": P(f, "model"),
                "wv": P(f, "model"),
                "wo": P("model", f),
            }
            if cfg.qk_norm:
                s["q_scale"] = P(None)
                s["k_scale"] = P(None)
        return s

    def dense_ffn_specs():
        return {
            "w_gate": P(f, "model"),
            "w_up": P(f, "model"),
            "w_down": P("model", f),
        }

    def ffn_specs(dense_only=False):
        if not cfg.moe or dense_only:
            return dense_ffn_specs()
        s: dict = {"moe": moe_param_specs(P)._replace(
            router=P(f, None),
            w_gate=P("model", f, None),
            w_up=P("model", f, None),
            w_down=P("model", f, None),
        )}
        if cfg.n_shared_experts:
            s["shared"] = dense_ffn_specs()
        if cfg.dense_residual:
            s["dense"] = dense_ffn_specs()
        return s

    def block_specs(dense_only=False):
        return {
            "attn_norm": P(None),
            "attn": attn_specs(),
            "ffn_norm": P(None),
            "ffn": ffn_specs(dense_only),
        }

    # scanned layers: prepend the stacking dim
    stacked = jax.tree.map(
        lambda s: P(None, *s), block_specs(), is_leaf=lambda x: isinstance(x, P)
    )
    specs = {
        "embed": P(None, "model"),
        "layers": stacked,
        "final_norm": P(None),
        "lm_head": P(None, "model"),
    }
    if cfg.first_k_dense:
        specs["dense_layers"] = [
            block_specs(dense_only=True) for _ in range(cfg.first_k_dense)
        ]
    return specs


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _gqa_qkv(p, cfg: TransformerConfig, x, positions):
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(
        b, s, cfg.n_heads, cfg.head_dim
    )
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"]).reshape(
        b, s, cfg.n_kv_heads, cfg.head_dim
    )
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"]).reshape(
        b, s, cfg.n_kv_heads, cfg.head_dim
    )
    if cfg.qk_norm:
        q = rms_norm(q, p["q_scale"])
        k = rms_norm(k, p["k_scale"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mla_qkv(p, cfg: TransformerConfig, x, positions):
    """MLA projections (train/prefill path, explicit K/V)."""
    b, s, _ = x.shape
    h, dn, dr, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    cq = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["wq_a"]), p["q_norm"])
    q = jnp.einsum("bsr,rh->bsh", cq, p["wq_b"]).reshape(b, s, h, dn + dr)
    q_nope, q_pe = q[..., :dn], q[..., dn:]
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)

    kv_a = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    c_kv, k_pe = kv_a[..., : cfg.kv_lora_rank], kv_a[..., cfg.kv_lora_rank :]
    c_kv = rms_norm(c_kv, p["kv_norm"])
    k_pe = apply_rope(k_pe[:, :, None, :], positions, cfg.rope_theta)  # 1 shared head
    kv = jnp.einsum("bsr,rh->bsh", c_kv, p["wkv_b"]).reshape(b, s, h, dn + dv)
    k_nope, v = kv[..., :dn], kv[..., dn:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe, (b, s, h, dr))], axis=-1
    )
    q = jnp.concatenate([q_nope, q_pe], axis=-1)
    return q, k, v, (c_kv, k_pe[:, :, 0, :])


def _attention(p, cfg: TransformerConfig, x, positions):
    b, s, _ = x.shape
    if cfg.attention == "mla":
        q, k, v, _ = _mla_qkv(p, cfg, x, positions)
        hkv = cfg.n_heads
    else:
        q, k, v = _gqa_qkv(p, cfg, x, positions)
        hkv = cfg.n_kv_heads
    # (B, S, H, D) → (B, H, S, D), heads sharded over model
    q = shard(jnp.swapaxes(q, 1, 2), ("pod", "data"), "model", None, None)
    kv_axis = "model" if hkv == cfg.n_heads else None
    k = shard(jnp.swapaxes(k, 1, 2), ("pod", "data"), kv_axis, None, None)
    v = shard(jnp.swapaxes(v, 1, 2), ("pod", "data"), kv_axis, None, None)
    scale = 1.0 / (cfg.qk_head_dim ** 0.5)
    o = chunked_attention(
        q, k, v, causal=True, scale=scale,
        q_chunk=min(cfg.q_chunk, s), kv_chunk=min(cfg.kv_chunk, s),
        probs_dtype=jnp.bfloat16 if cfg.bf16_probs else None,
    )
    o = jnp.swapaxes(o, 1, 2).reshape(b, s, cfg.n_heads * cfg.v_dim)
    return jnp.einsum("bsh,hd->bsd", o, p["wo"])


def _ffn(p, cfg: TransformerConfig, x):
    """FFN: dense, or MoE (+shared experts / +dense residual)."""
    if "w_gate" in p:  # dense
        return swiglu(x, p["w_gate"], p["w_up"], p["w_down"]), jnp.float32(0)
    b, s, d = x.shape
    flat = x.reshape(b * s, d)
    from repro.distributed.sharding import active_mesh, data_axes

    mesh = active_mesh()
    if cfg.moe_impl == "ep" and mesh is not None and "model" in mesh.shape:
        out = moe_ffn_ep(
            p["moe"], flat, top_k=cfg.top_k,
            capacity_factor=cfg.capacity_factor, mesh=mesh,
            data_axes=data_axes(),
        )
    else:
        out = moe_ffn(
            p["moe"], flat, top_k=cfg.top_k,
            capacity_factor=cfg.capacity_factor,
        )
    y = out.y.reshape(b, s, d)
    if "shared" in p:
        sh = p["shared"]
        y = y + swiglu(x, sh["w_gate"], sh["w_up"], sh["w_down"])
    if "dense" in p:
        de = p["dense"]
        y = y + swiglu(x, de["w_gate"], de["w_up"], de["w_down"])
    return y, out.aux_loss


def _block(p, cfg: TransformerConfig, x, positions):
    h = x + _attention(p["attn"], cfg, rms_norm(x, p["attn_norm"]), positions)
    f, aux = _ffn(p["ffn"], cfg, rms_norm(h, p["ffn_norm"]))
    out = shard_batch(h + f, None, None)
    return out, aux


def _backbone(params, cfg: TransformerConfig, tokens):
    """Embed + all blocks + final norm → hidden states (B, S, d)."""
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = jnp.take(params["embed"], tokens, axis=0)
    x = shard_batch(x, None, None)

    aux_total = jnp.float32(0)
    for p_dense in params.get("dense_layers", []):
        blk = jax.checkpoint(_block, static_argnums=(1,)) if cfg.remat else _block
        x, aux = blk(p_dense, cfg, x, positions)
        aux_total += aux

    def body(carry, layer_params):
        x, aux_total = carry
        blk = jax.checkpoint(_block, static_argnums=(1,)) if cfg.remat else _block
        x, aux = blk(layer_params, cfg, x, positions)
        return (x, aux_total + aux), None

    (x, aux_total), _ = lax.scan(body, (x, aux_total), params["layers"])
    return rms_norm(x, params["final_norm"]), aux_total


def transformer_logits(params, cfg: TransformerConfig, tokens) -> jax.Array:
    """Full logits (small configs / tests only — O(B·S·V) memory)."""
    x, _ = _backbone(params, cfg, tokens)
    return jnp.einsum("bsd,dv->bsv", x, params["lm_head"])


def transformer_loss(params, cfg: TransformerConfig, batch) -> tuple[jax.Array, dict]:
    """Next-token CE, computed in sequence chunks so the (tokens × vocab)
    logits tile never exceeds ``loss_chunk × V / mesh`` per device."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x, aux = _backbone(params, cfg, tokens)
    labels = batch.get("labels")
    if labels is None:
        labels = jnp.concatenate(
            [tokens[:, 1:], jnp.zeros((b, 1), tokens.dtype)], axis=1
        )
    mask = batch.get(
        "loss_mask",
        jnp.concatenate(
            [jnp.ones((b, s - 1), jnp.float32), jnp.zeros((b, 1), jnp.float32)],
            axis=1,
        ),
    )

    chunk = min(cfg.loss_chunk, s)
    assert s % chunk == 0, (s, chunk)
    nchunks = s // chunk

    def chunk_loss(carry, i):
        tot, cnt = carry
        xs = lax.dynamic_slice_in_dim(x, i * chunk, chunk, axis=1)
        ls = lax.dynamic_slice_in_dim(labels, i * chunk, chunk, axis=1)
        ms = lax.dynamic_slice_in_dim(mask, i * chunk, chunk, axis=1)
        logits = jnp.einsum(
            "bsd,dv->bsv", xs, params["lm_head"],
            preferred_element_type=jnp.float32,
        )
        logits = shard_batch(logits, None, "model")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ls[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * ms
        return (tot + jnp.sum(nll), cnt + jnp.sum(ms)), None

    (tot, cnt), _ = lax.scan(
        chunk_loss, (jnp.float32(0), jnp.float32(0)), jnp.arange(nchunks)
    )
    loss = tot / jnp.maximum(cnt, 1.0)
    total = loss + cfg.aux_loss_weight * aux
    return total, {"ce_loss": loss, "aux_loss": aux, "tokens": cnt}


# ---------------------------------------------------------------------------
# serving: prefill + decode with KV cache
# ---------------------------------------------------------------------------


def make_cache(cfg: TransformerConfig, batch: int, max_len: int) -> dict:
    """Allocate an empty KV cache (stacked over scanned layers).

    GQA: k/v ``(L, B, Hkv, S, D)``. MLA: latent ``c_kv (L, B, S, r)`` +
    ``k_pe (L, B, S, dr)`` — the MLA serving memory win (r+dr ≪ 2·H·D).
    """
    n_scanned = cfg.n_layers - cfg.first_k_dense
    n_dense = cfg.first_k_dense
    def zeros(*shape):
        return jnp.zeros(shape, cfg.dtype)
    if cfg.attention == "mla":
        cache = {
            "c_kv": zeros(n_scanned, batch, max_len, cfg.kv_lora_rank),
            "k_pe": zeros(n_scanned, batch, max_len, cfg.qk_rope_dim),
        }
        if n_dense:
            cache["dense_c_kv"] = zeros(n_dense, batch, max_len, cfg.kv_lora_rank)
            cache["dense_k_pe"] = zeros(n_dense, batch, max_len, cfg.qk_rope_dim)
    else:
        cache = {
            "k": zeros(n_scanned, batch, cfg.n_kv_heads, max_len, cfg.head_dim),
            "v": zeros(n_scanned, batch, cfg.n_kv_heads, max_len, cfg.head_dim),
        }
        if n_dense:
            cache["dense_k"] = zeros(
                n_dense, batch, cfg.n_kv_heads, max_len, cfg.head_dim
            )
            cache["dense_v"] = zeros(
                n_dense, batch, cfg.n_kv_heads, max_len, cfg.head_dim
            )
    cache["length"] = jnp.zeros((batch,), jnp.int32)
    return cache


def cache_specs(
    cfg: TransformerConfig, *, seq_axes=("model",), batch_axes=("pod", "data")
) -> dict:
    """Cache PartitionSpecs: batch over data axes, sequence over seq_axes —
    sequence-parallel decode attention (GSPMD inserts the softmax
    all-reduces; see module docstring)."""
    n_dense = cfg.first_k_dense
    if cfg.attention == "mla":
        specs = {
            "c_kv": P(None, batch_axes, seq_axes, None),
            "k_pe": P(None, batch_axes, seq_axes, None),
        }
        if n_dense:
            specs["dense_c_kv"] = specs["c_kv"]
            specs["dense_k_pe"] = specs["k_pe"]
    else:
        specs = {
            "k": P(None, batch_axes, None, seq_axes, None),
            "v": P(None, batch_axes, None, seq_axes, None),
        }
        if n_dense:
            specs["dense_k"] = specs["k"]
            specs["dense_v"] = specs["v"]
    specs["length"] = P(batch_axes)
    return specs


def _gqa_decode_attn(p, cfg, x, k_cache, v_cache, lengths):
    """One-token GQA attention against the cache (+ current token)."""
    b = x.shape[0]
    pos = lengths  # (B,) new token position
    q = jnp.einsum("bd,dh->bh", x, p["wq"]).reshape(b, 1, cfg.n_heads, cfg.head_dim)
    kv_shape = (b, 1, cfg.n_kv_heads, cfg.head_dim)
    k_new = jnp.einsum("bd,dh->bh", x, p["wk"]).reshape(kv_shape)
    v_new = jnp.einsum("bd,dh->bh", x, p["wv"]).reshape(kv_shape)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_scale"])
        k_new = rms_norm(k_new, p["k_scale"])
    posb = pos[:, None]
    q = apply_rope(q, posb, cfg.rope_theta)[:, 0]            # (B, H, D)
    k_new = apply_rope(k_new, posb, cfg.rope_theta)[:, 0]    # (B, Hkv, D)
    v_new = v_new[:, 0]

    # Insert the new K/V at each sequence's position (scatter over seq dim).
    bidx = jnp.arange(b)
    k_cache = k_cache.at[bidx, :, pos, :].set(k_new.astype(k_cache.dtype))
    v_cache = v_cache.at[bidx, :, pos, :].set(v_new.astype(v_cache.dtype))

    o = decode_attention_xla(
        q, k_cache, v_cache, lengths + 1,
        scale=1.0 / (cfg.head_dim ** 0.5),
    )
    o = o.reshape(b, cfg.n_heads * cfg.head_dim).astype(x.dtype)
    return jnp.einsum("bh,hd->bd", o, p["wo"]), k_cache, v_cache


def _mla_decode_attn(p, cfg, x, c_cache, pe_cache, lengths):
    """Absorbed MLA decode: attention entirely in latent space.

    Scores ``s[b,h,l] = (q_nope·W_kᵀ)·c_kv[l] + q_pe·k_pe[l]``; output
    ``o[b,h] = (Σ_l p_l·c_kv[l])·W_v`` — K/V are never materialized.
    """
    b = x.shape[0]
    h, dn, dr, dv, r = (
        cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim,
        cfg.kv_lora_rank,
    )
    pos = lengths
    posb = pos[:, None]

    cq = rms_norm(jnp.einsum("bd,dr->br", x, p["wq_a"]), p["q_norm"])
    q = jnp.einsum("br,rh->bh", cq, p["wq_b"]).reshape(b, h, dn + dr)
    q_nope, q_pe = q[..., :dn], q[..., dn:]
    q_pe = apply_rope(q_pe[:, None], posb, cfg.rope_theta)[:, 0]

    kv_a = jnp.einsum("bd,dr->br", x, p["wkv_a"])
    c_new = rms_norm(kv_a[..., :r], p["kv_norm"])
    pe_new = apply_rope(
        kv_a[..., r:][:, None, None, :], posb, cfg.rope_theta
    )[:, 0, 0]

    bidx = jnp.arange(b)
    c_cache = c_cache.at[bidx, pos, :].set(c_new.astype(c_cache.dtype))
    pe_cache = pe_cache.at[bidx, pos, :].set(pe_new.astype(pe_cache.dtype))

    wkv_b = p["wkv_b"].reshape(r, h, dn + dv)
    w_k = wkv_b[..., :dn]                                 # (r, h, dn)
    w_v = wkv_b[..., dn:]                                 # (r, h, dv)
    q_lat = jnp.einsum(
        "bhn,rhn->bhr", q_nope.astype(jnp.float32), w_k.astype(jnp.float32)
    )
    scale = 1.0 / (cfg.qk_head_dim ** 0.5)
    pe32 = pe_cache.astype(jnp.float32)
    s = (
        jnp.einsum("bhr,blr->bhl", q_lat, c_cache.astype(jnp.float32))
        + jnp.einsum("bhr,blr->bhl", q_pe.astype(jnp.float32), pe32)
    ) * scale
    L = c_cache.shape[1]
    valid = jnp.arange(L)[None, None, :] < (lengths + 1)[:, None, None]
    s = jnp.where(valid, s, -0.5e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    pr = jnp.where(valid, jnp.exp(s - m), 0.0)
    pr = pr / jnp.maximum(jnp.sum(pr, axis=-1, keepdims=True), 1e-30)
    o_lat = jnp.einsum("bhl,blr->bhr", pr, c_cache.astype(jnp.float32))
    o = jnp.einsum("bhr,rhv->bhv", o_lat, w_v.astype(jnp.float32))
    o = o.reshape(b, h * dv).astype(x.dtype)
    return jnp.einsum("bh,hd->bd", o, p["wo"]), c_cache, pe_cache


def _decode_block(p, cfg, x, cache_slice, lengths):
    xn = rms_norm(x, p["attn_norm"])
    if cfg.attention == "mla":
        attn_out, c, pe = _mla_decode_attn(
            p["attn"], cfg, xn, cache_slice["c_kv"], cache_slice["k_pe"], lengths
        )
        new_slice = {"c_kv": c, "k_pe": pe}
    else:
        attn_out, kc, vc = _gqa_decode_attn(
            p["attn"], cfg, xn, cache_slice["k"], cache_slice["v"], lengths
        )
        new_slice = {"k": kc, "v": vc}
    h = x + attn_out
    hn = rms_norm(h, p["ffn_norm"])
    f, _ = _ffn(p["ffn"], cfg, hn[:, None, :])
    return h + f[:, 0, :], new_slice


def decode_step(params, cfg: TransformerConfig, cache: dict, tokens: jax.Array):
    """One decode step: ``tokens (B,)`` → next-token logits ``(B, V)``.

    The per-sequence cache length lives in ``cache["length"]``.
    """
    lengths = cache["length"]
    x = jnp.take(params["embed"], tokens, axis=0)  # (B, d)

    new_cache = dict(cache)
    for i, p_dense in enumerate(params.get("dense_layers", [])):
        if cfg.attention == "mla":
            sl = {"c_kv": cache["dense_c_kv"][i], "k_pe": cache["dense_k_pe"][i]}
        else:
            sl = {"k": cache["dense_k"][i], "v": cache["dense_v"][i]}
        x, ns = _decode_block(p_dense, cfg, x, sl, lengths)
        if cfg.attention == "mla":
            new_cache["dense_c_kv"] = new_cache["dense_c_kv"].at[i].set(ns["c_kv"])
            new_cache["dense_k_pe"] = new_cache["dense_k_pe"].at[i].set(ns["k_pe"])
        else:
            new_cache["dense_k"] = new_cache["dense_k"].at[i].set(ns["k"])
            new_cache["dense_v"] = new_cache["dense_v"].at[i].set(ns["v"])

    if cfg.attention == "mla":
        scan_cache = {"c_kv": cache["c_kv"], "k_pe": cache["k_pe"]}
    else:
        scan_cache = {"k": cache["k"], "v": cache["v"]}

    def body(x, layer):
        layer_params, cache_slice = layer
        x, new_slice = _decode_block(layer_params, cfg, x, cache_slice, lengths)
        return x, new_slice

    x, new_slices = lax.scan(body, x, (params["layers"], scan_cache))
    new_cache.update(new_slices)
    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum(
        "bd,dv->bv", x, params["lm_head"], preferred_element_type=jnp.float32
    )
    new_cache["length"] = lengths + 1
    return logits, new_cache


def prefill(params, cfg: TransformerConfig, tokens: jax.Array):
    """Prefill: run the backbone over a prompt, return last-position logits.

    (The cache-filling variant is a straightforward extension; the dry-run
    exercises the compute-dominant backbone pass, which is what the roofline
    needs. Serving beyond the dry-run uses decode_step's incremental cache.)
    """
    x, _ = _backbone(params, cfg, tokens)
    last = x[:, -1, :]
    return jnp.einsum(
        "bd,dv->bv", last, params["lm_head"], preferred_element_type=jnp.float32
    )


# ---------------------------------------------------------------------------
# accounting
# ---------------------------------------------------------------------------


def count_params(cfg: TransformerConfig) -> int:
    import math

    shapes = jax.eval_shape(
        lambda k: init_transformer(k, cfg), jax.random.key(0)
    )
    return sum(math.prod(x.shape) for x in jax.tree.leaves(shapes))


def count_active_params(cfg: TransformerConfig) -> int:
    """Active params per token (MoE: top_k + shared of the routed pool)."""
    total = count_params(cfg)
    if not cfg.moe:
        return total
    per_expert = 3 * cfg.d_model * cfg.d_ff_expert
    n_scanned = cfg.n_layers - cfg.first_k_dense
    routed_all = n_scanned * cfg.n_experts * per_expert
    routed_active = n_scanned * cfg.top_k * per_expert
    return total - routed_all + routed_active
