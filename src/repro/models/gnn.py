"""GNN family: GAT (arXiv:1710.10903) via edge-index message passing.

JAX sparse is BCOO-only, so message passing is built from first principles
on padded edge lists: SDDMM-style per-edge attention scores →
segment-softmax over destination nodes (``segment_max`` + ``segment_sum``)
→ scatter-sum aggregation. This IS the system's sparse kernel layer (see
kernel_taxonomy §GNN: GAT = SDDMM → segment-softmax → SpMM).

The similarity-graph connection (the paper's headline application): edges
can come straight from ``core.apss`` matches via
``core.graph.coo_to_padded_edges`` — `examples/similarity_graph.py` builds
the ε-neighborhood graph with the paper's algorithm and trains this GAT on
it.

Graph batches are dicts of padded arrays:
  features (N, F) · edge_src (E,) · edge_dst (E,) · edge_mask (E,) ·
  labels (N,) · label_mask (N,)
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import dense_init


@dataclasses.dataclass(frozen=True)
class GATConfig:
    name: str = "gat-cora"
    n_layers: int = 2
    d_hidden: int = 8
    n_heads: int = 8
    d_feat: int = 1433
    n_classes: int = 7
    negative_slope: float = 0.2
    dtype: Any = jnp.float32


def init_gat(key, cfg: GATConfig) -> dict:
    layers = []
    d_in = cfg.d_feat
    for i in range(cfg.n_layers):
        k1, k2, k3, key = jax.random.split(key, 4)
        last = i == cfg.n_layers - 1
        d_out = cfg.n_classes if last else cfg.d_hidden
        heads = 1 if last else cfg.n_heads
        layers.append({
            "w": dense_init(k1, d_in, heads * d_out, cfg.dtype),
            "a_src": (
                jax.random.normal(k2, (heads, d_out), jnp.float32) * 0.1
            ).astype(cfg.dtype),
            "a_dst": (
                jax.random.normal(k3, (heads, d_out), jnp.float32) * 0.1
            ).astype(cfg.dtype),
        })
        d_in = d_out if last else cfg.d_hidden * cfg.n_heads
    return {"layers": layers}


def gat_param_specs(cfg: GATConfig) -> dict:
    # GAT params are tiny (d_hidden=8) — replicate them; the parallelism in
    # GNN training lives in the node/edge data sharding, not the weights.
    return {
        "layers": [
            {"w": P(None, None), "a_src": P(None, None), "a_dst": P(None, None)}
            for _ in range(cfg.n_layers)
        ]
    }


def segment_softmax(
    scores: jax.Array,       # (E, H)
    segments: jax.Array,     # (E,) destination node per edge
    num_segments: int,
    edge_mask: jax.Array,    # (E,)
) -> jax.Array:
    """Numerically-stable softmax over incoming edges of each node."""
    neg = jnp.float32(-1e30)
    s = jnp.where(edge_mask[:, None] > 0, scores.astype(jnp.float32), neg)
    smax = jax.ops.segment_max(s, segments, num_segments=num_segments)
    smax = jnp.maximum(smax, neg)  # empty segments
    ex = jnp.exp(s - smax[segments]) * edge_mask[:, None]
    denom = jax.ops.segment_sum(ex, segments, num_segments=num_segments)
    return ex / jnp.maximum(denom[segments], 1e-16)


def gat_layer(
    p: dict,
    x: jax.Array,          # (N, F)
    edge_src: jax.Array,   # (E,)
    edge_dst: jax.Array,   # (E,)
    edge_mask: jax.Array,  # (E,)
    *,
    heads: int,
    d_out: int,
    negative_slope: float,
    concat: bool,
) -> jax.Array:
    n = x.shape[0]
    h = jnp.einsum("nf,fe->ne", x, p["w"]).reshape(n, heads, d_out)
    # SDDMM: per-edge attention logits from endpoint projections.
    h32 = h.astype(jnp.float32)
    alpha_src = jnp.einsum("nhd,hd->nh", h32, p["a_src"].astype(jnp.float32))
    alpha_dst = jnp.einsum("nhd,hd->nh", h32, p["a_dst"].astype(jnp.float32))
    e = alpha_src[edge_src] + alpha_dst[edge_dst]                 # (E, H)
    e = jax.nn.leaky_relu(e, negative_slope)
    att = segment_softmax(e, edge_dst, n, edge_mask)              # (E, H)
    # SpMM: attention-weighted scatter-sum of source features.
    msg = h[edge_src].astype(jnp.float32) * att[..., None]        # (E, H, D)
    agg = jax.ops.segment_sum(msg, edge_dst, num_segments=n)      # (N, H, D)
    if concat:
        return agg.reshape(n, heads * d_out).astype(x.dtype)
    return jnp.mean(agg, axis=1).astype(x.dtype)


def gat_forward(params, cfg: GATConfig, batch) -> jax.Array:
    x = batch["features"]
    src, dst, mask = batch["edge_src"], batch["edge_dst"], batch["edge_mask"]
    for i, p in enumerate(params["layers"]):
        last = i == cfg.n_layers - 1
        heads = 1 if last else cfg.n_heads
        d_out = cfg.n_classes if last else cfg.d_hidden
        x = gat_layer(
            p, x, src, dst, mask,
            heads=heads, d_out=d_out,
            negative_slope=cfg.negative_slope, concat=not last,
        )
        if not last:
            x = jax.nn.elu(x)
    return x  # (N, n_classes) logits


def gat_loss(params, cfg: GATConfig, batch) -> tuple[jax.Array, dict]:
    logits = gat_forward(params, cfg, batch).astype(jnp.float32)
    labels = batch["labels"]
    mask = batch["label_mask"].astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    nll = (lse - gold) * mask
    loss = jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    pred = jnp.argmax(logits, axis=-1)
    acc = jnp.sum((pred == labels) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, {"loss": loss, "acc": acc}
