"""Mixture-of-Experts layer: top-k routing with sort-based capacity dispatch.

Supports the two assigned MoE architectures:
- arctic-480b: 128 experts, top-2, **plus a parallel dense residual FFN**
  (handled in ``transformer.py``).
- deepseek-moe-16b: 64 fine-grained routed experts, top-6, **plus 2 shared
  experts** that every token passes through.

Dispatch is the static-shape sort/capacity scheme (GShard-style capacity,
MegaBlocks-style sorted grouping): tokens are ranked within their expert via
a stable argsort, truncated at ``capacity = ceil(T·k·cf / E)``, scattered
into an ``(E, C, d)`` buffer, batch-matmul'd through the stacked expert
weights (einsum over the expert dim — shardable over the ``model`` axis for
expert parallelism), and combined back with the router gates. Dropped
(over-capacity) tokens fall back to zero expert output for that slot, as in
GShard; aux load-balancing loss keeps drops rare.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.compat import shard_map
from repro.distributed.sharding import shard
from repro.models.layers import dense_init


class MoEParams(NamedTuple):
    router: jax.Array    # (d, E)
    w_gate: jax.Array    # (E, d, f)
    w_up: jax.Array      # (E, d, f)
    w_down: jax.Array    # (E, f, d)


def init_moe(
    key, d_model: int, d_ff: int, n_experts: int, dtype=jnp.bfloat16
) -> MoEParams:
    ks = jax.random.split(key, 4)
    def ex(k, a, b):
        scale = (2.0 / (a + b)) ** 0.5
        return (
            jax.random.normal(k, (n_experts, a, b), jnp.float32) * scale
        ).astype(dtype)
    return MoEParams(
        router=dense_init(ks[0], d_model, n_experts, jnp.float32),
        w_gate=ex(ks[1], d_model, d_ff),
        w_up=ex(ks[2], d_model, d_ff),
        w_down=ex(ks[3], d_ff, d_model),
    )


def moe_param_specs(P):
    """PartitionSpecs: experts over the model axis (expert parallelism)."""
    return MoEParams(
        router=P(None, None),
        w_gate=P("model", None, None),
        w_up=P("model", None, None),
        w_down=P("model", None, None),
    )


class MoEOut(NamedTuple):
    y: jax.Array
    aux_loss: jax.Array     # load-balancing loss (Switch-style)
    dropped_frac: jax.Array


def moe_ffn(
    params: MoEParams,
    x: jax.Array,            # (T, d) flattened tokens
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    router_dtype=jnp.float32,
) -> MoEOut:
    T, d = x.shape
    E = params.router.shape[1]
    C = max(1, int(T * top_k * capacity_factor / E))

    logits = jnp.einsum(
        "td,de->te", x.astype(router_dtype), params.router.astype(router_dtype)
    )
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = lax.top_k(probs, top_k)       # (T, k)

    # Load-balance aux loss: E * Σ_e f_e·p_e  (Switch Transformer eq. 4).
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jax.nn.one_hot(expert_ids[:, 0], E, dtype=jnp.float32), axis=0
    )
    aux = E * jnp.sum(me * ce)

    # ---- sort-based capacity assignment (static shapes) ----
    flat_expert = expert_ids.reshape(-1)                  # (T*k,)
    flat_gate = gate_vals.reshape(-1)
    flat_token = jnp.repeat(jnp.arange(T, dtype=jnp.int32), top_k)

    order = jnp.argsort(flat_expert, stable=True)         # group by expert
    sorted_expert = flat_expert[order]
    # Rank within expert group = position - group start.
    counts = jnp.bincount(flat_expert, length=E)          # (E,)
    starts = jnp.concatenate([jnp.zeros(1, counts.dtype), jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(T * top_k, dtype=jnp.int32) - starts[sorted_expert].astype(
        jnp.int32
    )
    keep = rank < C
    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))

    slot = jnp.where(keep, sorted_expert * C + rank, E * C)  # E*C = trash row
    # Scatter tokens into the (E*C, d) dispatch buffer.
    buf = jnp.zeros((E * C + 1, d), x.dtype).at[slot].set(x[flat_token[order]])
    buf = buf[: E * C].reshape(E, C, d)
    buf = shard(buf, "model", None, None)

    # ---- expert compute (einsum over stacked experts; EP-shardable) ----
    g = jnp.einsum("ecd,edf->ecf", buf, params.w_gate)
    u = jnp.einsum("ecd,edf->ecf", buf, params.w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    y_buf = jnp.einsum("ecf,efd->ecd", h, params.w_down)
    y_buf = shard(y_buf, "model", None, None)

    # ---- combine: gather expert outputs back to token slots ----
    y_flat = y_buf.reshape(E * C, d)
    gathered = jnp.where(
        keep[:, None], y_flat[jnp.clip(slot, 0, E * C - 1)], 0.0
    )                                                     # (T*k, d) sorted order
    weighted = gathered.astype(jnp.float32) * flat_gate[order][:, None]
    y = jnp.zeros((T, d), jnp.float32).at[flat_token[order]].add(weighted)
    return MoEOut(y=y.astype(x.dtype), aux_loss=aux, dropped_frac=dropped)


# ---------------------------------------------------------------------------
# Expert-parallel dispatch (shard_map) — the §Perf fix for the GSPMD scatter
# ---------------------------------------------------------------------------


def moe_ffn_ep(
    params: MoEParams,
    x: jax.Array,            # (T, d) GLOBAL flattened tokens (sharded on T)
    *,
    top_k: int,
    capacity_factor: float,
    mesh,
    data_axes: tuple,
    model_axis: str = "model",
    router_dtype=jnp.float32,
) -> MoEOut:
    """Replicated-activation expert parallelism.

    The GSPMD lowering of the scatter-based dispatch materializes the full
    ``(E·C, d)`` buffer on every data shard and all-reduces it (measured:
    29 TB/device/step on arctic-480b train_4k — EXPERIMENTS.md §Perf). Here
    each ``(data, model)`` device routes its LOCAL tokens, keeps only the
    slots owned by its LOCAL experts (``E/p_model``), runs the expert
    matmuls, and contributes a partial combine — so the ONLY collective is
    one ``psum(T_loc × d)`` over the model axis per layer, identical in
    shape to a tensor-parallel FFN reduction.

    Capacity is per (expert × data shard): ``C = T_loc·k·cf/E`` (standard
    EP semantics; equal to the global-capacity dispatch whenever nothing
    drops — asserted by tests).
    """
    from jax.sharding import PartitionSpec as P

    E = params.router.shape[1]
    p_m = mesh.shape[model_axis]
    assert E % p_m == 0, (E, p_m)

    def inner(x_loc, router, w_gate, w_up, w_down):
        T_loc, d = x_loc.shape
        E_loc = w_gate.shape[0]
        me = lax.axis_index(model_axis)
        C = max(1, int(T_loc * top_k * capacity_factor / E))

        logits = jnp.einsum(
            "td,de->te", x_loc.astype(router_dtype), router.astype(router_dtype)
        )
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_ids = lax.top_k(probs, top_k)

        me_probs = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(expert_ids[:, 0], E, dtype=jnp.float32), axis=0)
        aux = E * jnp.sum(me_probs * ce)
        for a in data_axes:
            aux = lax.pmean(aux, a)

        flat_e = expert_ids.reshape(-1)
        flat_gate = gate_vals.reshape(-1)
        flat_tok = jnp.repeat(jnp.arange(T_loc, dtype=jnp.int32), top_k)

        owned = (flat_e // E_loc) == me
        local_e = jnp.where(owned, flat_e - me * E_loc, E_loc)  # E_loc = trash
        order = jnp.argsort(local_e, stable=True)
        sorted_e = local_e[order]
        counts = jnp.bincount(local_e, length=E_loc + 1)
        starts = jnp.concatenate(
            [jnp.zeros(1, counts.dtype), jnp.cumsum(counts)[:-1]]
        )
        rank = (
            jnp.arange(T_loc * top_k, dtype=jnp.int32)
            - starts[sorted_e].astype(jnp.int32)
        )
        keep = (sorted_e < E_loc) & (rank < C)
        drop_local = jnp.sum(
            (flat_e // E_loc) == me, dtype=jnp.float32
        ) - jnp.sum(keep, dtype=jnp.float32)
        dropped = drop_local / jnp.maximum(T_loc * top_k / p_m, 1.0)
        for a in data_axes:
            dropped = lax.pmean(dropped, a)
        dropped = lax.pmean(dropped, model_axis)

        slot = jnp.where(keep, sorted_e * C + rank, E_loc * C)
        buf = (
            jnp.zeros((E_loc * C + 1, d), x_loc.dtype)
            .at[slot]
            .set(x_loc[flat_tok[order]])
        )[: E_loc * C].reshape(E_loc, C, d)

        g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
        u = jnp.einsum("ecd,edf->ecf", buf, w_up)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x_loc.dtype) * u
        y_buf = jnp.einsum("ecf,efd->ecd", h, w_down).reshape(E_loc * C, d)

        gathered = jnp.where(
            keep[:, None], y_buf[jnp.clip(slot, 0, E_loc * C - 1)], 0.0
        )
        weighted = gathered.astype(jnp.float32) * flat_gate[order][:, None]
        y_part = jnp.zeros((T_loc, d), jnp.float32).at[flat_tok[order]].add(weighted)
        # psum in the activation dtype: halves the only cross-shard traffic
        # (top-k expert partials are disjoint per token up to shared/residual
        # paths, so bf16 psum rounding matches a bf16 combine).
        y = lax.psum(y_part.astype(x_loc.dtype), model_axis)
        return y, aux, dropped

    daxes = tuple(a for a in data_axes if a in mesh.shape) or None
    y, aux, dropped = shard_map(
        inner,
        mesh=mesh,
        in_specs=(
            P(daxes, None),
            P(None, None),
            P("model", None, None),
            P("model", None, None),
            P("model", None, None),
        ),
        out_specs=(P(daxes, None), P(), P()),
        check_vma=False,
    )(x, params.router, params.w_gate, params.w_up, params.w_down)
    return MoEOut(y=y, aux_loss=aux, dropped_frac=dropped)
