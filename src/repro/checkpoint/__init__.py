from repro.checkpoint.checkpointer import (  # noqa: F401
    save_checkpoint,
    load_checkpoint,
    CheckpointCorruptionError,
    CheckpointManager,
)
