"""Sharded, atomic, async checkpointing with keep-last-k + auto-resume.

Fault-tolerance contract (DESIGN.md §5, §8):

- **Atomic**: a checkpoint is written to ``step_XXXX.tmp/`` and renamed to
  ``step_XXXX/`` only after every leaf and the manifest are fsync'd — a
  crash mid-write can never corrupt the restore path.
- **Verified**: every leaf carries a blake2b digest in the manifest;
  ``load_checkpoint`` re-hashes on read, so a truncated or bit-flipped
  leaf raises :class:`CheckpointCorruptionError` instead of reshaping
  garbage into the restored state. Pre-digest checkpoints (no ``blake2b``
  key) still load.
- **Async, never silent**: ``CheckpointManager.save(..., blocking=False)``
  snapshots to host memory on the step path and writes on a background
  thread. A failed background write (disk full, permissions) is captured
  and re-raised on the NEXT ``wait()``/``save()`` call — an async failure
  can surface one step late, but it always surfaces.
- **Keep-last-k** with monotonic step directories; ``latest_step()`` +
  ``restore()`` give crash auto-resume. ``restore(fallback=True)`` walks
  back to the newest UNcorrupted kept step, so one bad write costs
  ``keep``-window progress, not the job.
- **Preemption**: ``install_preemption_handler`` checkpoints on
  SIGTERM/SIGINT before the scheduler reclaims the node.
- **Elastic**: checkpoints store full (unsharded) host arrays per leaf, so
  ``distributed.elastic.reshard_tree`` can re-place them on any mesh shape.

Multi-host note: on a real cluster each host writes only the shards it owns
(``jax.experimental.multihost_utils``); in this single-process container the
owner set is "everything", which is the degenerate case of the same code
path.
"""

from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import signal
import threading
import warnings
from typing import Any, Callable

import jax
import numpy as np

from repro.obs import recorder as _recorder
from repro.obs import trace as _trace

_MANIFEST = "manifest.json"
_STEP_RE = re.compile(r"^step_(\d{10})$")


class CheckpointCorruptionError(RuntimeError):
    """A checkpoint leaf failed its integrity check (digest/shape/read)."""


def _dtype_from_name(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))


def _flatten_with_names(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        out.append((name, leaf))
    return out


def save_checkpoint(state, directory: str, step: int) -> str:
    """Write one atomic checkpoint; returns the final directory."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves = _flatten_with_names(state)
    manifest = {"step": step, "leaves": []}
    for name, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        fname = name.replace("/", "__") + ".npy"
        # Raw-byte serialization: np.save cannot round-trip ml_dtypes
        # (bfloat16 etc.), so store bytes + record the true dtype.
        payload = np.ascontiguousarray(arr).tobytes()
        raw = np.frombuffer(payload, np.uint8)
        with open(os.path.join(tmp, fname), "wb") as f:
            np.save(f, raw)
            f.flush()
            os.fsync(f.fileno())
        manifest["leaves"].append(
            {"name": name, "file": fname, "shape": list(arr.shape),
             "dtype": str(arr.dtype),
             "blake2b": hashlib.blake2b(payload, digest_size=16).hexdigest()}
        )
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def load_checkpoint(directory: str, step: int, like=None):
    """Load a checkpoint as a pytree of numpy arrays.

    With ``like`` (a pytree of the same structure, e.g. from
    ``jax.eval_shape``), the result is unflattened into that structure;
    otherwise a flat ``{name: array}`` dict is returned.

    Every leaf is verified against its manifest blake2b digest before
    reshaping; a digest mismatch, unreadable file, or byte-count mismatch
    raises :class:`CheckpointCorruptionError` naming the offending leaf.
    """
    path = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    by_name = {}
    for leaf in manifest["leaves"]:
        fpath = os.path.join(path, leaf["file"])
        try:
            raw = np.load(fpath)
            payload = raw.tobytes()
        except Exception as e:
            raise CheckpointCorruptionError(
                f"unreadable checkpoint leaf {fpath}: {e}"
            ) from e
        digest = leaf.get("blake2b")  # absent in pre-digest checkpoints
        if digest is not None:
            got = hashlib.blake2b(payload, digest_size=16).hexdigest()
            if got != digest:
                raise CheckpointCorruptionError(
                    f"checksum mismatch for leaf {fpath}: "
                    f"manifest {digest}, file {got}"
                )
        dtype = _dtype_from_name(leaf["dtype"])
        expect = int(np.prod(leaf["shape"])) * dtype.itemsize
        if len(payload) != expect:
            raise CheckpointCorruptionError(
                f"truncated checkpoint leaf {fpath}: "
                f"{len(payload)} bytes, expected {expect}"
            )
        by_name[leaf["name"]] = (
            np.frombuffer(payload, dtype=dtype)
            .reshape(leaf["shape"])
            .copy()
        )
    if like is None:
        return by_name
    names = [n for n, _ in _flatten_with_names(like)]
    assert set(names) == set(by_name), (
        f"checkpoint/tree mismatch: {set(names) ^ set(by_name)}"
    )
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, [by_name[n] for n in names])


class CheckpointManager:
    """keep-last-k manager with async writes and preemption handling."""

    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._writer: threading.Thread | None = None
        self._async_error: BaseException | None = None
        os.makedirs(directory, exist_ok=True)

    # -- discovery ---------------------------------------------------------

    def all_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.directory):
            m = _STEP_RE.match(name)
            if m:
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # -- save/restore ------------------------------------------------------

    def save(self, state, step: int, *, blocking: bool = True) -> None:
        # Serialize against any in-flight async writer (same-step collisions
        # would otherwise race on the .tmp directory). wait() also re-raises
        # any captured async-write failure, so a silent disk-full/permission
        # error from a previous background write surfaces here.
        self.wait()
        if step in self.all_steps():
            return
        if blocking:
            with _trace.span("checkpoint/save", step=step):
                save_checkpoint(state, self.directory, step)
            self._gc()
            return
        # Snapshot to host on the caller's thread (cheap device→host copy),
        # then write in the background so the step path never blocks on IO.
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        self._writer = threading.Thread(
            target=self._write_and_gc, args=(host_state, step), daemon=True
        )
        self._writer.start()

    def _write_and_gc(self, host_state, step: int) -> None:
        # Capture, never swallow: a daemon thread's uncaught exception is
        # lost forever, so stash it for the next wait()/save() to re-raise.
        try:
            save_checkpoint(host_state, self.directory, step)
            self._gc()
        except BaseException as e:  # noqa: BLE001 — re-raised on wait()
            self._async_error = e

    def wait(self) -> None:
        """Join any in-flight async write; re-raise a captured write error."""
        if self._writer is not None and self._writer.is_alive():
            self._writer.join()
        if self._async_error is not None:
            err, self._async_error = self._async_error, None
            raise err

    def restore(self, like=None, step: int | None = None, *,
                fallback: bool = False):
        """Load ``step`` (default: latest). Returns ``(state, step)``.

        With ``fallback=True``, a step that fails integrity checks
        (:class:`CheckpointCorruptionError`) is skipped with a warning and
        the next-older kept step is tried — resume costs one checkpoint
        window instead of the job. Raises only when every kept step is
        corrupt; returns ``(None, None)`` when none exist at all.
        """
        self.wait()
        if step is not None:
            candidates = [step]
        else:
            candidates = sorted(self.all_steps(), reverse=True)
        if not candidates:
            return None, None
        last_err: Exception | None = None
        with _trace.span("checkpoint/restore", directory=self.directory):
            for s in candidates:
                try:
                    return load_checkpoint(self.directory, s, like=like), s
                except CheckpointCorruptionError as e:
                    if not fallback:
                        raise
                    warnings.warn(
                        f"checkpoint step {s} corrupt ({e}); "
                        f"falling back to previous kept step",
                        stacklevel=2,
                    )
                    _trace.event("corruption_fallback", step=s)
                    _recorder.trigger(
                        "checkpoint.corruption_fallback", step=s,
                        error=str(e),
                    )
                    last_err = e
        raise CheckpointCorruptionError(
            f"every kept checkpoint in {self.directory} is corrupt"
        ) from last_err

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s:010d}"),
                ignore_errors=True,
            )

    # -- preemption --------------------------------------------------------

    def install_preemption_handler(
        self, get_state: Callable[[], tuple[Any, int]]
    ) -> None:
        """Checkpoint on SIGTERM/SIGINT (cluster preemption notice)."""

        def handler(signum, frame):
            state, step = get_state()
            save_checkpoint(state, self.directory, step)
            self._gc()
            raise SystemExit(128 + signum)

        signal.signal(signal.SIGTERM, handler)
        signal.signal(signal.SIGINT, handler)
