"""Sharded, atomic, async checkpointing with keep-last-k + auto-resume.

Fault-tolerance contract (DESIGN.md §5):

- **Atomic**: a checkpoint is written to ``step_XXXX.tmp/`` and renamed to
  ``step_XXXX/`` only after every leaf and the manifest are fsync'd — a
  crash mid-write can never corrupt the restore path.
- **Async**: ``CheckpointManager.save(..., blocking=False)`` snapshots to
  host memory on the step path and writes on a background thread (the write
  never blocks the training step; the snapshot is a device→host copy).
- **Keep-last-k** with monotonic step directories; ``latest_step()`` +
  ``restore()`` give crash auto-resume.
- **Preemption**: ``install_preemption_handler`` checkpoints on
  SIGTERM/SIGINT before the scheduler reclaims the node.
- **Elastic**: checkpoints store full (unsharded) host arrays per leaf, so
  ``distributed.elastic.reshard_tree`` can re-place them on any mesh shape.

Multi-host note: on a real cluster each host writes only the shards it owns
(``jax.experimental.multihost_utils``); in this single-process container the
owner set is "everything", which is the degenerate case of the same code
path.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import signal
import threading
from typing import Any, Callable

import jax
import numpy as np

_MANIFEST = "manifest.json"
_STEP_RE = re.compile(r"^step_(\d{10})$")


def _dtype_from_name(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))


def _flatten_with_names(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        out.append((name, leaf))
    return out


def save_checkpoint(state, directory: str, step: int) -> str:
    """Write one atomic checkpoint; returns the final directory."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves = _flatten_with_names(state)
    manifest = {"step": step, "leaves": []}
    for name, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        fname = name.replace("/", "__") + ".npy"
        # Raw-byte serialization: np.save cannot round-trip ml_dtypes
        # (bfloat16 etc.), so store bytes + record the true dtype.
        raw = np.frombuffer(np.ascontiguousarray(arr).tobytes(), np.uint8)
        with open(os.path.join(tmp, fname), "wb") as f:
            np.save(f, raw)
            f.flush()
            os.fsync(f.fileno())
        manifest["leaves"].append(
            {"name": name, "file": fname, "shape": list(arr.shape),
             "dtype": str(arr.dtype)}
        )
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def load_checkpoint(directory: str, step: int, like=None):
    """Load a checkpoint as a pytree of numpy arrays.

    With ``like`` (a pytree of the same structure, e.g. from
    ``jax.eval_shape``), the result is unflattened into that structure;
    otherwise a flat ``{name: array}`` dict is returned.
    """
    path = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    by_name = {}
    for leaf in manifest["leaves"]:
        raw = np.load(os.path.join(path, leaf["file"]))
        dtype = _dtype_from_name(leaf["dtype"])
        by_name[leaf["name"]] = (
            np.frombuffer(raw.tobytes(), dtype=dtype)
            .reshape(leaf["shape"])
            .copy()
        )
    if like is None:
        return by_name
    names = [n for n, _ in _flatten_with_names(like)]
    assert set(names) == set(by_name), (
        f"checkpoint/tree mismatch: {set(names) ^ set(by_name)}"
    )
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, [by_name[n] for n in names])


class CheckpointManager:
    """keep-last-k manager with async writes and preemption handling."""

    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._writer: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # -- discovery ---------------------------------------------------------

    def all_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.directory):
            m = _STEP_RE.match(name)
            if m:
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # -- save/restore ------------------------------------------------------

    def save(self, state, step: int, *, blocking: bool = True) -> None:
        # Serialize against any in-flight async writer (same-step collisions
        # would otherwise race on the .tmp directory).
        self.wait()
        if step in self.all_steps():
            return
        if blocking:
            save_checkpoint(state, self.directory, step)
            self._gc()
            return
        # Snapshot to host on the caller's thread (cheap device→host copy),
        # then write in the background so the step path never blocks on IO.
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        self.wait()
        self._writer = threading.Thread(
            target=self._write_and_gc, args=(host_state, step), daemon=True
        )
        self._writer.start()

    def _write_and_gc(self, host_state, step: int) -> None:
        save_checkpoint(host_state, self.directory, step)
        self._gc()

    def wait(self) -> None:
        if self._writer is not None and self._writer.is_alive():
            self._writer.join()

    def restore(self, like=None, step: int | None = None):
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        return load_checkpoint(self.directory, step, like=like), step

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s:010d}"),
                ignore_errors=True,
            )

    # -- preemption --------------------------------------------------------

    def install_preemption_handler(
        self, get_state: Callable[[], tuple[Any, int]]
    ) -> None:
        """Checkpoint on SIGTERM/SIGINT (cluster preemption notice)."""

        def handler(signum, frame):
            state, step = get_state()
            save_checkpoint(state, self.directory, step)
            self._gc()
            raise SystemExit(128 + signum)

        signal.signal(signal.SIGTERM, handler)
        signal.signal(signal.SIGINT, handler)
