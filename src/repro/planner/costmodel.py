"""Closed-form cost models for every APSS variant.

DISCO's lesson is that communication volume is the right currency for
distributed similarity; the adaptive-join line's lesson is that the right
partitioning depends on the observed data distribution. This module prices
each candidate ``(variant, block_rows, use_kernel)`` configuration as

- ``wire`` — the per-device collective bytes + hop count of its schedule
  (the SAME formulas ``planner.telemetry`` records at execution time, so
  the wire-volume tests validate the model), converted to seconds with the
  calibrated interconnect bandwidth/latency,
- ``compute`` — modeled MXU/gather FLOPs (density- and live-fraction-aware)
  over the calibrated throughput of the matching primitive (dense matmul vs
  sparse gather-dot; per-device throughput under a mesh), scaled by the
  live-tile **imbalance** of the sampled worklist histogram (the busiest
  device bounds wall time),

combined as ``max(compute, comm)`` for schedules that overlap sends with
compute (ring-family) and ``compute + comm`` for those that cannot
(allgather, the accumulation collectives). All parameters come from a
:class:`CalibrationProfile` — measured once by ``planner.calibrate`` and
cached to JSON keyed by device kind — so the model is falsifiable:
``benchmarks/bench_planner.py`` records predicted vs measured per variant.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Optional

from repro.planner import telemetry


# ---------------------------------------------------------------------------
# Calibration profile
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CalibrationProfile:
    """Hardware constants the cost models are parameterized by.

    ``matmul_gflops``/``gather_gflops`` are achieved single-device
    throughputs of the two scoring primitives; ``sharded_matmul_gflops`` is
    the per-device throughput under a full-mesh shard_map (captures
    oversubscription on virtual-device hosts — on real hardware it ≈
    ``matmul_gflops``); ``collective_gbps`` is per-device interconnect
    bandwidth; ``collective_latency_us`` the per-hop launch latency.
    """

    device_kind: str = "uncalibrated"
    matmul_gflops: float = 40.0
    gather_gflops: float = 2.0
    sharded_matmul_gflops: float = 0.0   # 0 → fall back to matmul_gflops
    sharded_gather_gflops: float = 0.0   # 0 → fall back to gather_gflops
    score_cost_ns: float = 2.0           # per-score extraction (threshold+topk)
    collective_gbps: float = 4.0
    collective_latency_us: float = 50.0
    overhead_us: float = 200.0           # per-call dispatch/launch floor
    # Serving-side (query_topk) terms. Older cached profiles lack these
    # keys; from_json fills the defaults, so calibration files never go
    # stale. serving_overhead_us is the per-CALL fixed cost of the query
    # path (mask eval + host worklist compaction + dispatch); the per-tile
    # terms are priced through the primitive throughputs above.
    serving_overhead_us: float = 150.0
    serving_tile_fixed_ns: float = 1500.0   # per live tile: scan/DMA step cost
    kernel_tile_fixed_ns: float = 400.0     # same, rect Pallas kernel path

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2) + "\n"

    @classmethod
    def from_json(cls, text: str) -> "CalibrationProfile":
        d = json.loads(text)
        fields = dataclasses.fields(cls)
        return cls(**{f.name: d[f.name] for f in fields if f.name in d})

    def throughput(self, *, sparse: bool, distributed: bool) -> float:
        """FLOPs/s of the matching scoring primitive."""
        if sparse:
            g = (self.sharded_gather_gflops or self.gather_gflops) if distributed \
                else self.gather_gflops
        else:
            g = (self.sharded_matmul_gflops or self.matmul_gflops) if distributed \
                else self.matmul_gflops
        return max(g, 1e-3) * 1e9


def default_profile() -> CalibrationProfile:
    """Deterministic fallback constants (no microbenchmark).

    The *ratios* — matmul ≫ gather throughput, bandwidth ≪ arithmetic —
    carry the ranking; absolute seconds are only trustworthy after
    ``planner.calibrate.calibrate()``.
    """
    return CalibrationProfile()


# ---------------------------------------------------------------------------
# Corpus summary (planner-side sampled statistics)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CorpusSummary:
    """What the planner knows about a corpus — sampled, never densified.

    ``live_fraction``/``tile_counts`` come from block-stats bounds evaluated
    on a row sample at the query threshold (``plan.summarize_corpus``);
    ``zipf_alpha`` is fitted to the sampled posting-list histogram (the
    paper's "almost irreducible" dimension skew).
    """

    n: int
    m: int
    threshold: float
    sparse_input: bool
    density: float
    cap: int                 # realized max row nnz (== m when truly dense)
    avg_nnz: float
    zipf_alpha: float
    live_fraction: float
    tile_counts: tuple[int, ...] = ()
    itemsize: int = 4

    def imbalance(self, p: int) -> float:
        """max/mean live tiles over ``p`` contiguous row-block groups."""
        tc = self.tile_counts
        if not tc or p <= 1:
            return 1.0
        groups = [0.0] * p
        for i, c in enumerate(tc):
            groups[min(p - 1, i * p // len(tc))] += c
        mean = sum(groups) / p
        if mean <= 0:
            return 1.0
        return max(groups) / mean

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["tile_counts"] = list(self.tile_counts)
        return d


# ---------------------------------------------------------------------------
# Variant configurations + cost estimates
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class VariantConfig:
    """One candidate execution configuration the planner can rank/dispatch."""

    kind: str  # "blocked" | "horizontal" | "vertical" | "2d" | "hierarchical"
    sparse: bool
    block_rows: int
    use_kernel: bool = False
    schedule: Optional[str] = None       # horizontal: allgather | ring | halfring
    accumulation: Optional[str] = None   # vertical / 2d

    @property
    def name(self) -> str:
        base = self.kind
        if self.schedule:
            base += f"/{self.schedule}"
        if self.accumulation:
            base += f"/{self.accumulation}"
        tags = ["sparse" if self.sparse else "dense", f"b={self.block_rows}"]
        if self.use_kernel:
            tags.append("kernel")
        return f"{base}[{','.join(tags)}]"


@dataclasses.dataclass
class CostEstimate:
    """Modeled cost of one configuration on one corpus/mesh."""

    config: VariantConfig
    wire_bytes: int
    hop_count: int
    flops: float
    compute_s: float
    comm_s: float
    total_s: float
    imbalance: float = 1.0
    measured_s: Optional[float] = None   # filled by autotune / bench_planner

    def as_dict(self) -> dict:
        return {
            "config": self.config.name,
            "wire_bytes": int(self.wire_bytes),
            "hop_count": int(self.hop_count),
            "flops": float(self.flops),
            "compute_s": float(self.compute_s),
            "comm_s": float(self.comm_s),
            "predicted_s": float(self.total_s),
            "imbalance": float(self.imbalance),
            "measured_s": None if self.measured_s is None else float(self.measured_s),
        }


def _capacity(k: int) -> int:
    """The compressed accumulations' candidate-capacity default — the ONE
    definition, shared with every telemetry record site."""
    from repro.core.distributed import default_candidate_capacity

    return default_candidate_capacity(k)


def variant_hops(
    cfg: VariantConfig,
    s: CorpusSummary,
    mesh_sizes: dict[str, int],
    k: int,
) -> tuple[telemetry.CollectiveHop, ...]:
    """The schedule's hop list — same formulas telemetry records at runtime."""
    axes = list(mesh_sizes)
    p = 1
    for v in mesh_sizes.values():
        p *= v
    if cfg.kind == "blocked" or p <= 1:
        return ()
    n_loc = s.n // p
    block_bytes = (
        telemetry.csr_block_bytes(n_loc, s.cap)
        if cfg.sparse
        else telemetry.dense_block_bytes(n_loc, s.m, s.itemsize)
    )
    if cfg.kind == "horizontal":
        return telemetry.horizontal_hops(
            cfg.schedule, p, "+".join(axes), block_bytes,
            telemetry.matches_bytes(n_loc, k),
            payload="csr_block" if cfg.sparse else "dense_block",
        )
    if cfg.kind == "hierarchical":
        return telemetry.hierarchical_hops(
            tuple(mesh_sizes.values()), tuple(axes), block_bytes,
            payload="csr_block" if cfg.sparse else "dense_block",
        )
    if cfg.kind == "vertical":
        return telemetry.vertical_hops(
            cfg.accumulation, axes[-1], p, s.n,
            min(cfg.block_rows, s.n), _capacity(k),
        )
    if cfg.kind == "2d":
        q, r = mesh_sizes[axes[0]], mesh_sizes[axes[1]]
        n_loc = s.n // q
        return telemetry.twod_hops(
            q, r, axes[0], axes[1], n_loc, s.m, s.itemsize,
            min(cfg.block_rows, n_loc), _capacity(k), cfg.accumulation,
            cap_loc=_cell_cap(s, r) if cfg.sparse else None,
        )
    raise ValueError(f"unknown variant kind: {cfg.kind}")


def _cell_cap(s: CorpusSummary, r: int) -> int:
    """Planner-side estimate of the 2-D cell capacity ``cap_loc`` — the
    balanced split ``⌈cap/r⌉``. The realized value (known only after the
    host ``shard_dims`` pre-split) is the max per-slice row count and grows
    toward ``cap`` under posting-list skew; the runtime telemetry record
    carries the exact number, so ``bench_planner`` surfaces any drift as a
    predicted-vs-measured gap rather than silent model error."""
    return max(1, -(-s.cap // r))


def variant_flops(cfg: VariantConfig, s: CorpusSummary, p: int) -> float:
    """Modeled per-device scoring FLOPs of one configuration."""
    depth = s.cap if cfg.sparse else s.m
    rows = s.n // max(1, p)
    full = (
        telemetry.sparse_join_flops(rows, s.n, depth)
        if cfg.sparse
        else telemetry.dense_join_flops(rows, s.n, depth)
    )
    if cfg.kind == "vertical":
        # dimension split: every device sees all rows in an m/p (cap/p) slice
        return full  # rows·n·depth/p == (n/p)·n·depth
    if (
        cfg.kind == "horizontal" and cfg.schedule == "halfring"
        and not cfg.use_kernel and not cfg.sparse
    ):
        # dense XLA halfring: each cross tile scored once, read in both
        # orientations (sparse/kernel halfrings re-score the mirror —
        # ring-level compute, halved wire; see core.distributed)
        return full * 0.55
    if cfg.kind == "blocked" and cfg.use_kernel:
        # worklist paths skip dead tiles (dense @pl.when / sparse compaction)
        return full * max(s.live_fraction, 1.0 / max(1, s.n // cfg.block_rows))
    return full


def variant_scores(cfg: VariantConfig, s: CorpusSummary, p: int) -> float:
    """Per-device SCORED ELEMENTS — the extraction (threshold + top-k)
    term's driver. Distinct from FLOPs: the dense XLA halfring halves MXU
    work but still extracts BOTH orientations of every cross tile, so its
    score count matches the ring's."""
    scored = (s.n // max(1, p)) * s.n
    if cfg.kind == "blocked" and cfg.use_kernel:
        scored *= max(s.live_fraction, 1.0 / max(1, s.n // cfg.block_rows))
    return scored


def estimate_cost(
    cfg: VariantConfig,
    s: CorpusSummary,
    mesh_sizes: Optional[dict[str, int]],
    profile: CalibrationProfile,
    k: int = 32,
) -> CostEstimate:
    """Price one configuration: wire + compute + imbalance → seconds."""
    mesh_sizes = mesh_sizes or {}
    p = 1
    for v in mesh_sizes.values():
        p *= v
    # A blocked config runs ALL rows on one device regardless of any mesh:
    # it must be priced single-device, else it reads p× too cheap and the
    # planner biases against the distributed variants.
    if cfg.kind == "blocked":
        p = 1
    hops = variant_hops(cfg, s, mesh_sizes, k) if p > 1 else ()
    wire = sum(h.total_bytes for h in hops)
    nhops = sum(h.hops for h in hops)
    comm_s = nhops * profile.collective_latency_us * 1e-6 + wire / (
        max(profile.collective_gbps, 1e-3) * 1e9
    )
    flops = variant_flops(cfg, s, p)
    imb = s.imbalance(p) if cfg.sparse else 1.0
    # Two compute terms: scoring FLOPs over the primitive's calibrated
    # throughput, plus the depth-independent per-score extraction cost
    # (threshold + top-k merge) — which dominates for narrow/sparse depths.
    scores = variant_scores(cfg, s, p)
    compute_s = imb * (
        flops / profile.throughput(sparse=cfg.sparse, distributed=p > 1)
        + scores * profile.score_cost_ns * 1e-9
    )
    overlapped = cfg.kind in ("hierarchical", "2d") or (
        cfg.kind == "horizontal" and cfg.schedule in ("ring", "halfring")
    )
    body = max(compute_s, comm_s) if overlapped else compute_s + comm_s
    return CostEstimate(
        config=cfg,
        wire_bytes=wire,
        hop_count=nhops,
        flops=flops,
        compute_s=compute_s,
        comm_s=comm_s,
        total_s=body + profile.overhead_us * 1e-6,
        imbalance=imb,
    )


# ---------------------------------------------------------------------------
# Per-batch serving plans (query_topk(plan="auto"))
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class QueryPlan:
    """One priced ``query_topk`` execution choice for one batch.

    Unlike the offline :class:`VariantConfig` ranking — which prices whole
    self-join sweeps from SAMPLED corpus summaries — a query plan is priced
    from the index's exact :class:`~repro.core.pruning.BlockStats`: the
    corpus-side bounds are already materialized, so the live-tile estimate
    costs one tiny host pass over ``(mw, max_nnz)`` and involves zero
    sampling. ``block_q`` trades mask granularity (smaller blocks prune
    tighter) against per-tile fixed cost (fewer, fatter tiles amortize
    better); ``use_kernel`` is offered only where the rect Pallas kernels
    are the real path (TPU).
    """

    batch: int
    block_q: int
    use_kernel: bool
    predicted_us: float
    live_block_fraction: float

    def as_dict(self) -> dict:
        return {
            "batch": int(self.batch),
            "block_q": int(self.block_q),
            "use_kernel": bool(self.use_kernel),
            "predicted_us": float(self.predicted_us),
            "live_block_fraction": float(self.live_block_fraction),
        }


_QUERY_BLOCK_CANDIDATES = (8, 16, 32, 64, 128)


def _corpus_live_fraction(index, threshold: float) -> float:
    """Fraction of corpus blocks that can pass the bounds for a UNIT-NORM
    query block, from the index's exact per-block stats.

    For a normalized query row, the tile bound is ≤ ``mw_c · √max_nnz_c``
    (Cauchy–Schwarz against the corpus block's weight/size maxima — the
    same minsize-style term ``core.pruning`` evaluates), so blocks with
    ``mw · √max_nnz < t`` are dead for EVERY query block and the live
    fraction is query-independent. Queries are normalized on server ingest,
    making this sound in the serving path; for an unnormalized index the
    bound degenerates and we price the conservative 1.0.
    """
    if threshold <= 0 or not getattr(index, "normalized", False):
        return 1.0
    import numpy as np

    mw, max_nnz = index.stats_host()
    ub = np.asarray(mw) * np.sqrt(np.maximum(np.asarray(max_nnz, np.float64), 0))
    return float((ub >= threshold).mean()) if ub.size else 1.0


def plan_query_topk(
    index,
    batch: int,
    threshold: float,
    k: int = 32,
    *,
    profile: Optional[CalibrationProfile] = None,
    allow_kernel: Optional[bool] = None,
) -> QueryPlan:
    """Price the ``(block_q, use_kernel)`` grid for ONE query batch.

    Cost per candidate: fixed serving overhead + per-live-tile fixed cost
    (scan step / kernel grid step) + scoring FLOPs over the calibrated
    primitive throughput, where the live-tile count is
    ``ceil(batch/block_q) · nb · live_fraction`` from the exact corpus
    stats. The batch pads up to ``block_q``, so oversized blocks pay for
    padding rows — which is exactly why small batches pick small blocks.

    Records a ``serving/plan`` telemetry event when telemetry is enabled
    (the decision trail the drift tests and bench_serve surface).
    """
    if profile is None:
        profile = default_profile()
    if allow_kernel is None:
        from repro.kernels.apss_block.ops import _on_tpu

        allow_kernel = _on_tpu()
    live_frac = _corpus_live_fraction(index, threshold)
    nb = max(1, index.n_blocks)
    depth = (
        int(index.bdims.shape[1])
        if (index.is_sparse and index.bdims is not None)
        else int(index.m)
    )
    thr = profile.throughput(sparse=index.is_sparse, distributed=False)
    best = None
    for block_q in _QUERY_BLOCK_CANDIDATES:
        grid_q = -(-max(1, batch) // block_q)
        tiles = grid_q * nb * live_frac
        flops = 2.0 * tiles * block_q * index.block_rows * depth
        for use_kernel in ((False, True) if allow_kernel else (False,)):
            if use_kernel and index.is_sparse and index.mesh is not None:
                continue
            fixed_ns = (
                profile.kernel_tile_fixed_ns
                if use_kernel
                else profile.serving_tile_fixed_ns
            )
            us = (
                profile.serving_overhead_us
                + tiles * fixed_ns * 1e-3
                + flops / thr * 1e6
            )
            cand = QueryPlan(
                batch=int(batch), block_q=block_q, use_kernel=use_kernel,
                predicted_us=us, live_block_fraction=live_frac,
            )
            if best is None or cand.predicted_us < best.predicted_us:
                best = cand
    if telemetry.enabled():
        telemetry.record(telemetry.ApssStats(
            variant="serving/plan",
            n=index.n, m=index.m, block_rows=index.block_rows,
            sparse=index.is_sparse,
            flops=0.0,
            extra={"plan": best.as_dict(), "threshold": float(threshold), "k": int(k)},
        ))
    return best
