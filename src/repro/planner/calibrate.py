"""One-shot hardware calibration for the planner's cost models.

Measures the three constants the cost models need — dense matmul
throughput, sparse gather-dot throughput, and interconnect
bandwidth/latency (plus per-device throughput under a full-mesh shard_map,
which captures oversubscription on virtual-device hosts) — and caches them
to a JSON profile keyed by device kind, so calibration runs once per
machine, not once per plan.

``get_profile()`` never benchmarks: it returns the cached profile if one
exists, else the deterministic :func:`~repro.planner.costmodel.default_profile`
(ranking-safe constants). Run :func:`calibrate` explicitly (or via
``launch/serve.py --mode auto`` / ``benchmarks/bench_planner.py``) to
measure.

Cache location: ``$REPRO_CALIB_DIR`` or ``~/.cache/repro_apss/``.
"""

from __future__ import annotations

import os
import time
from pathlib import Path
from typing import Optional

import numpy as np

from repro.planner.costmodel import CalibrationProfile, default_profile

_MEMO: dict[str, CalibrationProfile] = {}


def device_kind() -> str:
    """Cache key: device kind × device count (virtual-CPU meshes differ)."""
    import jax

    kind = jax.devices()[0].device_kind.replace(" ", "_").replace("/", "_")
    return f"{kind}_x{jax.device_count()}"


def profile_path(kind: Optional[str] = None) -> Path:
    base = Path(
        os.environ.get("REPRO_CALIB_DIR", Path.home() / ".cache" / "repro_apss")
    )
    return base / f"calibration_{kind or device_kind()}.json"


def _median_time(fn, iters: int = 3) -> float:
    import jax

    jax.block_until_ready(fn())  # compile + warm
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def calibrate(
    mesh=None,
    *,
    n: int = 512,
    m: int = 512,
    cap: int = 32,
    m_sparse: int = 4096,
    iters: int = 3,
    save: bool = True,
) -> CalibrationProfile:
    """Run the microbenchmarks and (by default) cache the resulting profile.

    ``mesh=None`` builds a mesh over every visible device for the
    sharded/collective measurements; pass a mesh to pin the axis layout.
    Single-device hosts skip the collective benchmarks (the constants are
    then irrelevant: no variant with collectives is reachable).
    """
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.sharding import PartitionSpec as P

    from repro.compat import make_mesh, shard_map
    from repro.core.apss import similarity_topk
    from repro.core.sparse import SparseCorpus, sparse_similarity_topk

    prof = default_profile()
    prof.device_kind = device_kind()

    # Achieved cost of the REAL blocked scoring path = per-FLOP contraction
    # cost + per-SCORE extraction cost (threshold + top-k merge, depth-
    # independent). Timing the same join at two depths m and m/8 separates
    # the two constants. Threshold 2.0 > any cosine keeps the match buffers
    # empty without changing the work done.
    A = jnp.asarray(np.random.default_rng(0).standard_normal((n, m)), jnp.float32)
    m2 = max(32, m // 8)
    mm = jax.jit(lambda x: similarity_topk(x, x, 2.0, 32, block_rows=128))
    t1 = _median_time(lambda: mm(A), iters)
    t2 = _median_time(lambda: mm(A[:, :m2]), iters)
    per_flop = max((t1 - t2) / (2.0 * n * n * (m - m2)), 1e-15)
    prof.matmul_gflops = 1.0 / per_flop / 1e9
    score_cost = max(0.0, (t2 - 2.0 * n * n * m2 * per_flop) / (n * n))
    prof.score_cost_ns = score_cost * 1e9

    # Sparse blocked-join throughput (densify_rows + gather_dot + extract):
    # subtract the just-measured extraction cost so gather_gflops prices
    # only the CSR contraction. Benchmarked at ``m_sparse`` dimensions —
    # the gather's cache locality degrades with the dense-table width, and
    # sparse corpora live at large m (the whole point of the CSR path).
    rng = np.random.default_rng(1)
    sp = SparseCorpus(
        jnp.asarray(rng.integers(0, m_sparse, size=(n, cap)), jnp.int32),
        jnp.asarray(rng.standard_normal((n, cap)), jnp.float32),
        jnp.full((n,), cap, jnp.int32),
        m_sparse,
    )
    gd = jax.jit(lambda s: sparse_similarity_topk(s, s, 2.0, 32, block_rows=128))
    t_g = _median_time(lambda: gd(sp), iters)
    denom = max(t_g - n * n * score_cost, 0.1 * t_g)
    prof.gather_gflops = 2.0 * n * n * cap / denom / 1e9

    ndev = jax.device_count()
    if ndev > 1:
        if mesh is None:
            mesh = make_mesh((ndev,), ("data",))
        axis = mesh.axis_names[0]
        p = mesh.shape[axis]

        # Per-device throughput under a full-mesh shard_map: on real
        # hardware ≈ the single-device number; on virtual-device hosts it
        # exposes the oversubscription that makes "parallel" variants
        # slower. Measured as a RATIO on the bare matmul (same op single
        # vs sharded), then applied to the end-to-end constants so both
        # stay in the same units.
        rows = max(8, n // p)
        base = jnp.asarray(
            np.random.default_rng(2).standard_normal((rows, m)), jnp.float32
        )
        raw = jax.jit(
            lambda x: jnp.einsum(
                "im,jm->ij", x, x, preferred_element_type=jnp.float32
            )
        )
        t_one = _median_time(lambda: raw(base), iters)
        As = jnp.asarray(
            np.random.default_rng(2).standard_normal((rows * p, m)), jnp.float32
        )
        smm = jax.jit(
            shard_map(
                lambda x: jnp.einsum(
                    "im,jm->ij", x, x, preferred_element_type=jnp.float32
                ),
                mesh=mesh, in_specs=P(axis, None), out_specs=P(axis, None),
            )
        )
        t_all = _median_time(lambda: smm(As), iters)
        scale = max(1e-3, min(1.5, t_one / t_all))  # per-device slowdown
        prof.sharded_matmul_gflops = prof.matmul_gflops * scale
        prof.sharded_gather_gflops = prof.gather_gflops * scale

        # Interconnect: time `hops` ring ppermutes of a block (bandwidth)
        # and of a 4-byte scalar (latency floor).
        perm = [(i, (i + 1) % p) for i in range(p)]
        words = max(1, (1 << 20) // 4)  # 1 MiB/device payload
        buf = jnp.asarray(
            np.random.default_rng(3).standard_normal((p, words)), jnp.float32
        )
        hops = 8

        def ring(x):
            def body(_, b):
                return lax.ppermute(b, axis, perm=perm)
            return lax.fori_loop(0, hops, body, x)

        big = jax.jit(
            shard_map(
                ring, mesh=mesh,
                in_specs=P(axis, None), out_specs=P(axis, None),
            )
        )
        t = _median_time(lambda: big(buf), iters)
        prof.collective_gbps = hops * words * 4 / t / 1e9

        tiny = jnp.zeros((p, 1), jnp.float32)
        small = jax.jit(
            shard_map(
                ring, mesh=mesh,
                in_specs=P(axis, None), out_specs=P(axis, None),
            )
        )
        t = _median_time(lambda: small(tiny), iters)
        prof.collective_latency_us = t / hops * 1e6

    _MEMO[prof.device_kind] = prof
    if save:
        path = profile_path(prof.device_kind)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(prof.to_json())
    return prof


def get_profile(*, refresh: bool = False) -> CalibrationProfile:
    """Cached profile for this device kind, else deterministic defaults.

    Never runs a microbenchmark (planning must be cheap and deterministic);
    ``refresh=True`` only bypasses the in-process memo and re-reads the
    JSON cache.
    """
    kind = device_kind()
    if not refresh and kind in _MEMO:
        return _MEMO[kind]
    path = profile_path(kind)
    if path.exists():
        try:
            prof = CalibrationProfile.from_json(path.read_text())
        except (ValueError, KeyError, TypeError):
            prof = default_profile()
            prof.device_kind = kind
    else:
        prof = default_profile()
        prof.device_kind = kind
    _MEMO[kind] = prof
    return prof
