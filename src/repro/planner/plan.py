"""plan_apss: turn variant choice from folklore into a measured decision.

The paper's closing finding — "the performance depends on the dataset,
therefore a variety of parallelizations is useful" — left the *choice*
among the variety to the caller. This module closes that loop:

1. :func:`summarize_corpus` samples the corpus (never densifying a sparse
   one): density, realized row cap, Zipf skew of the posting-list
   histogram, and the live-tile fraction + per-block histogram of the
   paper's pruning bounds at the query threshold.
2. :func:`candidate_configs` enumerates every valid
   ``(variant, block_rows, use_kernel)`` configuration for the given mesh
   (divisibility and backend constraints applied here, not at dispatch).
3. :func:`plan_apss` prices each candidate with the closed-form cost
   models (``planner.costmodel``, parameterized by the calibrated
   hardware profile) and returns a ranked :class:`Plan`; with
   ``autotune=True`` the best-predicted config of each of the top
   ``autotune_top`` (default 3) variant families is additionally
   microbenchmarked and the measured winner is chosen.

``core.apss.similarity_topk(..., variant="auto")``,
``core.distributed.apss(..., distribution="auto")`` and
``serving.build_index(..., plan=...)`` dispatch through here.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Optional, Sequence

import jax
import numpy as np

from repro.obs import trace
from repro.planner import calibrate as _calibrate
from repro.planner.costmodel import (
    CalibrationProfile,
    CorpusSummary,
    CostEstimate,
    VariantConfig,
    estimate_cost,
)


# ---------------------------------------------------------------------------
# Corpus summary — sampled statistics, never densified
# ---------------------------------------------------------------------------


def _fit_zipf(hist: np.ndarray) -> float:
    """Least-squares Zipf exponent of a posting-list (document-frequency)
    histogram: slope of log(freq) vs log(rank) over the populated lists."""
    freq = np.sort(hist[hist > 0])[::-1].astype(np.float64)
    if freq.size < 4 or freq[0] == freq[-1]:
        return 0.0
    rank = np.arange(1, freq.size + 1, dtype=np.float64)
    x, y = np.log(rank), np.log(freq)
    slope = float(np.polyfit(x, y, 1)[0])
    return float(np.clip(-slope, 0.0, 4.0))


def _sample_rows(n: int, sample_rows: int, seed: int) -> np.ndarray:
    if n <= sample_rows:
        return np.arange(n)
    rng = np.random.default_rng(seed)
    return np.sort(rng.choice(n, size=sample_rows, replace=False))


def _live_profile(stats, threshold: float) -> tuple[float, tuple[int, ...]]:
    """Self-join live fraction + per-row-block live counts from BlockStats."""
    import numpy as _np

    from repro.core.pruning import live_tile_mask

    mask = _np.asarray(live_tile_mask(stats, stats, threshold))
    return float(mask.mean()), tuple(int(c) for c in mask.sum(axis=1))


def summarize_corpus(
    corpus,
    threshold: float,
    *,
    sample_rows: int = 2048,
    stats_block: int = 64,
    seed: int = 0,
) -> CorpusSummary:
    """Sampled planner-side statistics (see module doc).

    ``corpus`` is a dense ``(n, m)`` array, a
    :class:`~repro.core.sparse.SparseCorpus`, or a prebuilt
    :class:`~repro.serving.index.APSSIndex` (whose corpus-side
    :class:`~repro.core.pruning.BlockStats` give the live profile exactly,
    with no sampling pass at all).
    """
    from repro.core.sparse import SparseCorpus
    from repro.serving.index import APSSIndex

    if isinstance(corpus, APSSIndex):
        return _summarize_index(corpus, threshold)
    if isinstance(corpus, SparseCorpus):
        return _summarize_sparse(
            corpus, threshold, sample_rows=sample_rows,
            stats_block=stats_block, seed=seed,
        )
    return _summarize_dense(
        corpus, threshold, sample_rows=sample_rows,
        stats_block=stats_block, seed=seed,
    )


def _summarize_sparse(sp, threshold, *, sample_rows, stats_block, seed):
    import jax.numpy as jnp

    from repro.core.pruning import sparse_block_stats
    from repro.core.sparse import SparseCorpus, pad_rows_sparse

    nnz = np.asarray(sp.nnz)
    n, m = sp.n, sp.m
    sel = _sample_rows(n, sample_rows, seed)
    idx = np.asarray(sp.indices)[sel]
    val = np.asarray(sp.values)[sel]
    valid = np.arange(sp.cap)[None, :] < nnz[sel, None]
    hist = np.bincount(idx[valid].ravel(), minlength=m) if valid.any() else np.zeros(m)
    sub = SparseCorpus(
        jnp.asarray(idx), jnp.asarray(val), jnp.asarray(nnz[sel]), m
    )
    bs = min(stats_block, max(1, len(sel)))
    sub, _ = pad_rows_sparse(sub, bs)
    live, tiles = _live_profile(sparse_block_stats(sub, bs), threshold)
    return CorpusSummary(
        n=n, m=m, threshold=float(threshold), sparse_input=True,
        density=float(nnz.sum()) / float(n * m), cap=sp.cap,
        avg_nnz=float(nnz.mean()), zipf_alpha=_fit_zipf(hist),
        live_fraction=live, tile_counts=tiles, itemsize=4,
    )


def _summarize_dense(D, threshold, *, sample_rows, stats_block, seed):
    import jax.numpy as jnp

    from repro.core.pruning import dense_block_stats

    n, m = D.shape
    itemsize = np.dtype(D.dtype).itemsize if np.dtype(D.dtype).itemsize in (2, 4) else 4
    sel = _sample_rows(n, sample_rows, seed)
    S = np.asarray(D[np.asarray(sel)], np.float32)
    nnzs = (S != 0).sum(axis=1)
    hist = (S != 0).sum(axis=0)
    bs = min(stats_block, max(1, len(sel)))
    rem = (-len(sel)) % bs
    Sp = np.pad(S, ((0, rem), (0, 0))) if rem else S
    live, tiles = _live_profile(dense_block_stats(jnp.asarray(Sp), bs), threshold)
    return CorpusSummary(
        n=n, m=m, threshold=float(threshold), sparse_input=False,
        density=float(nnzs.mean()) / float(m), cap=int(max(1, nnzs.max(initial=1))),
        avg_nnz=float(nnzs.mean()), zipf_alpha=_fit_zipf(hist),
        live_fraction=live, tile_counts=tiles, itemsize=itemsize,
    )


def _summarize_index(index, threshold) -> CorpusSummary:
    live, tiles = _live_profile(index.stats, threshold)
    if index.is_sparse:
        idx_arr, _, nnz = index.corpus
        nnz = np.asarray(nnz)[: index.n]
        m = index.m
        valid = np.arange(idx_arr.shape[1])[None, :] < nnz[:, None]
        hist = np.bincount(
            np.asarray(idx_arr)[: index.n][valid].ravel(), minlength=m
        )
        return CorpusSummary(
            n=index.n, m=m, threshold=float(threshold), sparse_input=True,
            density=float(nnz.sum()) / float(index.n * m),
            cap=int(idx_arr.shape[1]), avg_nnz=float(nnz.mean()),
            zipf_alpha=_fit_zipf(hist), live_fraction=live,
            tile_counts=tiles, itemsize=4,
        )
    D = np.asarray(index.corpus)[: index.n, : index.m]
    nnzs = (D != 0).sum(axis=1)
    return CorpusSummary(
        n=index.n, m=index.m, threshold=float(threshold), sparse_input=False,
        density=float(nnzs.mean()) / float(index.m),
        cap=int(max(1, nnzs.max(initial=1))), avg_nnz=float(nnzs.mean()),
        zipf_alpha=_fit_zipf((D != 0).sum(axis=0)), live_fraction=live,
        tile_counts=tiles, itemsize=4,
    )


# ---------------------------------------------------------------------------
# Candidate enumeration (validity constraints live HERE, not at dispatch)
# ---------------------------------------------------------------------------

# Densifying a sparse corpus for a dense variant is capped at this many
# bytes — beyond it the dense representation is not a candidate at all.
MAX_DENSIFY_BYTES = 512 * 1024 * 1024

# A dense input is only offered sparse candidates below this density
# (above it padded CSR stores ~the dense array with extra indices).
SPARSE_REP_MAX_DENSITY = 0.25


def candidate_configs(
    s: CorpusSummary,
    mesh=None,
    k: int = 32,
    *,
    block_rows_choices: Sequence[int] = (128, 256, 512),
    include_kernel: Optional[bool] = None,
) -> list[VariantConfig]:
    """Every valid configuration for this corpus/mesh (see module doc)."""
    if include_kernel is None:
        from repro.kernels.apss_block.ops import _on_tpu

        include_kernel = _on_tpu()
    reps: list[bool] = []
    if s.sparse_input or s.density <= SPARSE_REP_MAX_DENSITY:
        reps.append(True)
    if not s.sparse_input or s.n * s.m * 4 <= MAX_DENSIFY_BYTES:
        reps.append(False)

    blocks = [b for b in dict.fromkeys(block_rows_choices) if b <= max(s.n, 1)]
    blocks = blocks or [min(128, s.n)]
    cfgs: list[VariantConfig] = []
    for sparse in reps:
        for b in blocks:
            cfgs.append(VariantConfig("blocked", sparse, b, use_kernel=False))
            if include_kernel:
                cfgs.append(VariantConfig("blocked", sparse, b, use_kernel=True))
    if mesh is None:
        return cfgs

    names = tuple(mesh.axis_names)
    sizes = dict(mesh.shape)
    p = 1
    for v in sizes.values():
        p *= v
    if p <= 1 or s.n % p:
        return cfgs

    for sparse in reps:
        kern = [False] + ([True] if include_kernel and not sparse else [])
        for b in blocks:
            if len(names) == 1:
                for sched in ("allgather", "ring", "halfring"):
                    for uk in kern:
                        cfgs.append(
                            VariantConfig(
                                "horizontal", sparse, b, use_kernel=uk,
                                schedule=sched,
                            )
                        )
            else:
                for uk in kern:
                    cfgs.append(
                        VariantConfig("hierarchical", sparse, b, use_kernel=uk)
                    )
        if len(names) == 1 and s.m % p == 0:
            # both representations shard the dimension axis: dense as
            # P(None, axis) columns, sparse as shard_dims posting slices —
            # m must divide either way
            for b in blocks:
                if s.n % b:
                    continue
                for acc in ("allreduce", "scatter", "compressed", "recursive"):
                    if acc == "scatter" and b % p:
                        continue
                    if acc == "recursive" and p & (p - 1):
                        continue
                    cfgs.append(
                        VariantConfig(
                            "vertical", sparse, b, accumulation=acc,
                        )
                    )
    if len(names) == 2:
        q, r = sizes[names[0]], sizes[names[1]]
        # Both representations split the dimension axis r ways: dense as
        # P(row, col) column shards, sparse as shard_dims posting slices —
        # m must divide either way.
        if s.n % q == 0 and s.m % r == 0:
            n_loc = s.n // q
            for sparse in reps:
                for b in blocks:
                    for acc in ("allreduce", "compressed"):
                        cfgs.append(
                            VariantConfig(
                                "2d", sparse, min(b, n_loc), accumulation=acc,
                            )
                        )
    return list(dict.fromkeys(cfgs))


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------


def _index_valid_corpus(index):
    """The index's corpus restricted to its VALID rows/dims (indexes pad
    rows to the block multiple and lane-pad dense feature axes; planning
    and dispatch must see the real ``(n, m)`` — phantom padded rows would
    leak into results and break the n-divisibility gates)."""
    from repro.core.sparse import SparseCorpus

    if index.is_sparse:
        idx, val, nnz = index.corpus
        return SparseCorpus(
            idx[: index.n], val[: index.n], nnz[: index.n], index.m
        )
    return index.corpus[: index.n, : index.m]


def _to_representation(corpus, sparse: bool):
    """Convert the corpus to the representation a config wants (host-side,
    one-off — conversion cost is not part of the per-call model)."""
    import jax.numpy as jnp

    from repro.core.sparse import SparseCorpus, from_dense, to_dense

    if isinstance(corpus, SparseCorpus):
        return corpus if sparse else to_dense(corpus)
    return from_dense(np.asarray(corpus)) if sparse else jnp.asarray(corpus)


def _dispatch(cfg: VariantConfig, data, threshold: float, k: int, mesh):
    """Raw variant dispatch (``data`` already in the config's representation)."""
    from repro.core.apss import apss_blocked
    from repro.core import distributed

    if cfg.kind == "blocked":
        return apss_blocked(
            data, threshold, k, block_rows=cfg.block_rows,
            use_kernel=cfg.use_kernel,
        )
    if mesh is None:
        raise ValueError(f"config {cfg.name} needs a mesh")
    names = tuple(mesh.axis_names)
    if cfg.kind == "horizontal":
        axis = names[0] if len(names) == 1 else names
        return distributed.apss_horizontal(
            data, threshold, k, mesh, axis, schedule=cfg.schedule,
            block_rows=cfg.block_rows, use_kernel=cfg.use_kernel,
        )
    if cfg.kind == "hierarchical":
        return distributed.apss_horizontal_hierarchical(
            data, threshold, k, mesh, names, block_rows=cfg.block_rows,
            use_kernel=cfg.use_kernel,
        )
    if cfg.kind == "vertical":
        return distributed.apss_vertical(
            data, threshold, k, mesh, names[-1],
            accumulation=cfg.accumulation, block_rows=cfg.block_rows,
        )
    if cfg.kind == "2d":
        return distributed.apss_2d(
            data, threshold, k, mesh, names[0], names[1],
            accumulation=cfg.accumulation, block_rows=cfg.block_rows,
        )
    raise ValueError(f"unknown variant kind: {cfg.kind}")


def _has_host_stage(cfg: VariantConfig) -> bool:
    """Configs whose dispatch runs host-side stages (worklist compaction,
    ``shard_dims``) and therefore cannot be traced under jit."""
    if cfg.kind == "blocked" and cfg.sparse and cfg.use_kernel:
        return True  # apss_sparse_compacted: host-compacted worklist
    if cfg.kind in ("vertical", "2d") and cfg.sparse:
        return True  # shard_dims: host posting-list split
    return False


def execute(
    cfg: VariantConfig,
    corpus,
    threshold: float,
    k: int = 32,
    mesh=None,
    *,
    prepared: bool = False,
):
    """Run one configuration: representation conversion + jitted dispatch.

    Traceable configs go through one module-level jit (static over the
    frozen ``VariantConfig``), so repeated executions — the autotuner, the
    benchmark, a caller's request loop — pay compilation once per config,
    exactly like the hand-written call sites. Host-staged configs (sparse
    worklist / ``shard_dims``) run eagerly, as they do everywhere else.

    ``prepared=True`` declares ``corpus`` already in the config's
    representation: timed callers (autotune, ``bench_planner``) convert
    once per representation up front, so measurements compare the join the
    cost model prices — not a per-call ``to_dense``/``from_dense``.
    """
    data = corpus if prepared else _to_representation(corpus, cfg.sparse)
    with trace.span("execute", config=cfg.name):
        if _has_host_stage(cfg):
            return _dispatch(cfg, data, threshold, k, mesh)
        return _execute_traced(data, cfg, float(threshold), k, mesh)


@functools.partial(
    jax.jit, static_argnames=("cfg", "threshold", "k", "mesh")
)
def _execute_traced(data, cfg, threshold, k, mesh):
    return _dispatch(cfg, data, threshold, k, mesh)


# ---------------------------------------------------------------------------
# Plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Plan:
    """A ranked execution decision: chosen config + every priced alternative."""

    config: VariantConfig
    cost: CostEstimate
    estimates: list[CostEstimate]
    summary: CorpusSummary
    profile: CalibrationProfile
    threshold: float
    k: int
    mesh: object = None
    corpus: object = dataclasses.field(default=None, repr=False)
    autotuned: bool = False

    def run(self, corpus=None):
        """Execute the chosen configuration (on the planned corpus by default)."""
        data = corpus if corpus is not None else self.corpus
        if data is None:
            raise ValueError("Plan holds no corpus; pass one to run()")
        return execute(self.config, data, self.threshold, self.k, self.mesh)

    def describe(self, top: int = 8) -> str:
        s = self.summary
        mesh_s = dict(self.mesh.shape) if self.mesh is not None else None
        lines = [
            f"Plan: {self.config.name}"
            + (f" on mesh {mesh_s}" if mesh_s else " (single device)")
            + ("  [autotuned]" if self.autotuned else ""),
            f"corpus: n={s.n} m={s.m} density={s.density:.4f} cap={s.cap} "
            f"zipf={s.zipf_alpha:.2f} live_tiles={s.live_fraction:.3f} "
            f"t={s.threshold}",
            f"profile: {self.profile.device_kind} "
            f"matmul={self.profile.matmul_gflops:.1f}GF "
            f"gather={self.profile.gather_gflops:.1f}GF "
            f"wire={self.profile.collective_gbps:.1f}GB/s",
            f"{'rank':>4}  {'config':<42} {'predicted':>10} {'compute':>10} "
            f"{'comm':>10} {'wire':>10}",
        ]
        for i, e in enumerate(self.estimates[:top]):
            meas = (
                f"  measured={e.measured_s * 1e3:.1f}ms"
                if e.measured_s is not None
                else ""
            )
            lines.append(
                f"{i + 1:>4}  {e.config.name:<42} {e.total_s * 1e3:>8.2f}ms "
                f"{e.compute_s * 1e3:>8.2f}ms {e.comm_s * 1e3:>8.2f}ms "
                f"{e.wire_bytes / 1e6:>8.2f}MB{meas}"
            )
        return "\n".join(lines)

    def as_dict(self) -> dict:
        return {
            "chosen": self.config.name,
            "autotuned": self.autotuned,
            "summary": self.summary.as_dict(),
            "estimates": [e.as_dict() for e in self.estimates],
        }


def plan_apss(
    corpus,
    threshold: float,
    k: int = 32,
    mesh=None,
    *,
    profile: Optional[CalibrationProfile] = None,
    block_rows_choices: Sequence[int] = (128, 256, 512),
    include_kernel: Optional[bool] = None,
    autotune: bool = False,
    autotune_top: int = 3,
    sample_rows: int = 2048,
    seed: int = 0,
) -> Plan:
    """Rank every valid configuration by modeled cost; return a :class:`Plan`.

    ``corpus`` may be dense, sparse, or a prebuilt ``APSSIndex`` (planned
    from its exact corpus-side stats). ``profile=None`` loads the cached
    calibration for this device kind (deterministic defaults when none has
    been measured — run ``planner.calibrate.calibrate()`` once for real
    numbers). ``autotune=True`` additionally microbenchmarks the
    ``autotune_top`` best-predicted configurations and promotes the
    measured winner — the escape hatch for backend quirks (eager overhead,
    collective implementations) no closed-form model carries.
    """
    with trace.span("plan", autotune=autotune):
        p = _plan_apss_impl(
            corpus, threshold, k, mesh, profile=profile,
            block_rows_choices=block_rows_choices,
            include_kernel=include_kernel, autotune=autotune,
            autotune_top=autotune_top, sample_rows=sample_rows, seed=seed,
        )
        trace.annotate(chosen=p.config.name, candidates=len(p.estimates))
        return p


def _plan_apss_impl(
    corpus, threshold, k, mesh, *, profile, block_rows_choices,
    include_kernel, autotune, autotune_top, sample_rows, seed,
) -> Plan:
    from repro.serving.index import APSSIndex

    s = summarize_corpus(
        corpus, threshold, sample_rows=sample_rows, seed=seed
    )
    if profile is None:
        profile = _calibrate.get_profile()
    cfgs = candidate_configs(
        s, mesh, k, block_rows_choices=block_rows_choices,
        include_kernel=include_kernel,
    )
    if not cfgs:
        raise ValueError("no valid configuration for this corpus/mesh")
    mesh_sizes = dict(mesh.shape) if mesh is not None else None
    ests = sorted(
        (estimate_cost(c, s, mesh_sizes, profile, k) for c in cfgs),
        key=lambda e: e.total_s,
    )
    run_corpus = (
        _index_valid_corpus(corpus) if isinstance(corpus, APSSIndex) else corpus
    )
    autotuned = False
    if autotune and len(ests) > 1:
        # Measure the best-predicted config of the top `autotune_top`
        # DISTINCT variant families (block-size ties within a family are
        # modeled identically — measuring three of them would burn the
        # budget on noise), each on a pre-converted corpus so the timing
        # covers exactly the join the model priced.
        seen: set = set()
        picked: list[CostEstimate] = []
        for e in ests:
            fam = (e.config.kind, e.config.schedule,
                   e.config.accumulation, e.config.sparse)
            if fam in seen:
                continue
            seen.add(fam)
            picked.append(e)
            if len(picked) >= max(2, autotune_top):
                break
        rep_cache: dict = {}
        for e in picked:
            if e.config.sparse not in rep_cache:
                rep_cache[e.config.sparse] = _to_representation(
                    run_corpus, e.config.sparse
                )
            data = rep_cache[e.config.sparse]
            try:
                jax.block_until_ready(
                    execute(e.config, data, threshold, k, mesh, prepared=True)
                )  # compile + warm
                t0 = time.perf_counter()
                jax.block_until_ready(
                    execute(e.config, data, threshold, k, mesh, prepared=True)
                )
                e.measured_s = time.perf_counter() - t0
            except Exception:  # pragma: no cover - autotune is best-effort
                e.measured_s = float("inf")
        # Measured winner first; unmeasured keep their predicted order.
        ests.sort(
            key=lambda e: (
                (0, e.measured_s) if e.measured_s is not None
                else (1, e.total_s)
            )
        )
        autotuned = True
    return Plan(
        config=ests[0].config, cost=ests[0], estimates=ests, summary=s,
        profile=profile, threshold=float(threshold), k=k, mesh=mesh,
        corpus=run_corpus, autotuned=autotuned,
    )
