"""Execution planner + telemetry subsystem (DESIGN.md §7).

- ``planner.telemetry`` — :class:`CommLog` counter seam: per-call
  :class:`ApssStats` records (collective bytes per hop, modeled FLOPs,
  live-tile fraction/imbalance) emitted by every APSS entry point.
- ``planner.costmodel`` — closed-form per-variant cost models sharing the
  telemetry hop formulas, parameterized by a :class:`CalibrationProfile`.
- ``planner.calibrate`` — one-shot hardware microbenchmark cached to JSON
  keyed by device kind.
- ``planner.plan`` — :func:`plan_apss` ranks every valid
  ``(variant, block_rows, use_kernel)`` configuration by modeled cost;
  ``similarity_topk(..., variant="auto")`` / ``apss(...,
  distribution="auto")`` / ``build_index(..., plan=...)`` dispatch
  through it.

Exports resolve lazily so that ``core``/``serving`` modules can import
``planner.telemetry`` without dragging the planner (and its ``core``
imports) into every cold start.
"""

from __future__ import annotations

import importlib

_EXPORTS = {
    "CommLog": ".telemetry",
    "ApssStats": ".telemetry",
    "CalibrationProfile": ".costmodel",
    "CorpusSummary": ".costmodel",
    "CostEstimate": ".costmodel",
    "VariantConfig": ".costmodel",
    "default_profile": ".costmodel",
    "estimate_cost": ".costmodel",
    "Plan": ".plan",
    "plan_apss": ".plan",
    "execute": ".plan",
    "summarize_corpus": ".plan",
    "candidate_configs": ".plan",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    try:
        mod = _EXPORTS[name]
    except KeyError:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    return getattr(importlib.import_module(mod, __name__), name)
