"""Telemetry seam: per-call communication/compute accounting for every variant.

The ROADMAP's multi-host item notes the sparse ring's wire-volume claims were
"verified by construction, not measured". This module is the measurement
seam: every APSS entry point (``core.apss``, ``core.distributed``,
``serving.query``) records one :class:`ApssStats` per call into the active
:class:`CommLog` — bytes moved per collective hop (ppermute / all_gather /
psum, dense block vs CSR caravan), modeled MXU FLOPs, live-tile fraction
after pruning, and per-block live-tile counts for imbalance accounting.

Everything is computed from **static shapes plus already-materialized
worklists** at the Python (trace-time) level of each wrapper, so recording
costs no device work and adds nothing to the compiled computation. The
same hop formulas parameterize the planner's cost models
(``planner.costmodel``), so the wire-volume tests
(``tests/test_telemetry.py``) that assert e.g. "halfring moves ~half the
ring's bytes" validate the predictions too.

Collective byte models (per participating device, standard ring-algorithm
costs):

- ``ppermute``: the payload itself, once per hop.
- ``all_gather`` (tiled): receive ``p-1`` remote shards → ``(p-1) · local``.
- ``psum`` (ring all-reduce): reduce-scatter + all-gather →
  ``2·(p-1)/p · payload``.
- ``psum_scatter``: reduce-scatter half only → ``(p-1)/p · payload``.

Caveat: wrappers record when their Python body runs. Under an outer
``jax.jit`` that is trace time — cached executions of an already-compiled
function do not re-record (the numbers would be identical anyway; they
depend only on static shapes).

Note: :class:`ApssStats` here is the telemetry record; the (older)
``core.distributed.ApssStats`` is the overflow-exactness counter returned
by the compressed accumulations — different objects for different jobs.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class CollectiveHop:
    """One class of collective transfer inside a schedule.

    ``bytes_per_hop`` is the per-device payload of ONE hop; ``hops`` is how
    many sequential hops of this payload the schedule performs.
    """

    op: str          # "ppermute" | "all_gather" | "psum" | "psum_scatter"
    payload: str     # "dense_block" | "csr_block" | "caravan" | "candidates" | ...
    axis: str
    bytes_per_hop: int
    hops: int

    @property
    def total_bytes(self) -> int:
        return self.bytes_per_hop * self.hops


@dataclasses.dataclass
class ApssStats:
    """Per-call accounting record for one APSS/serving invocation.

    ``flops`` is the modeled per-device MXU work (2·rows·cols·depth per
    scored tile); ``tile_counts`` is the live-tile histogram (per row block
    or per device) where a worklist was actually materialized, else None.
    """

    variant: str                 # e.g. "horizontal/ring", "blocked/sparse-kernel"
    n: int
    m: int
    devices: int = 1
    block_rows: int = 0
    sparse: bool = False
    hops: tuple[CollectiveHop, ...] = ()
    flops: float = 0.0
    live_tiles: Optional[int] = None
    total_tiles: Optional[int] = None
    tile_counts: Optional[tuple[int, ...]] = None
    extra: dict = dataclasses.field(default_factory=dict)
    # Per-step wall-time collector (distributed.straggler.StepTicker) wired
    # by the sweep drivers: records are created at trace time, but the
    # ticker's host callbacks fire during execution, so read `step_times`
    # only after the sweep's outputs are ready.
    step_ticker: Optional[object] = dataclasses.field(
        default=None, compare=False, repr=False
    )

    @property
    def wire_bytes(self) -> int:
        return sum(h.total_bytes for h in self.hops)

    @property
    def hop_count(self) -> int:
        return sum(h.hops for h in self.hops)

    def bytes_by_payload(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for h in self.hops:
            out[h.payload] = out.get(h.payload, 0) + h.total_bytes
        return out

    def bytes_by_op(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for h in self.hops:
            out[h.op] = out.get(h.op, 0) + h.total_bytes
        return out

    @property
    def live_fraction(self) -> Optional[float]:
        if self.live_tiles is None or not self.total_tiles:
            return None
        return self.live_tiles / self.total_tiles

    @property
    def imbalance(self) -> Optional[float]:
        """max/mean of the live-tile histogram (1.0 = perfectly balanced)."""
        if not self.tile_counts:
            return None
        mean = sum(self.tile_counts) / len(self.tile_counts)
        if mean == 0:
            return 1.0
        return max(self.tile_counts) / mean

    @property
    def step_times(self) -> Optional[tuple[float, ...]]:
        """Measured per-ring-step wall times (max over ranks), one entry per
        step, or None for variants without a wired ticker. Blocks on the
        runtime's effects barrier so every host tick has landed."""
        if self.step_ticker is None:
            return None
        return self.step_ticker.step_times()


class CommLog:
    """Context manager collecting :class:`ApssStats` from instrumented calls.

    ::

        with CommLog() as log:
            apss_horizontal(D, t, k, mesh, schedule="halfring")
        print(log.last.wire_bytes, log.last.bytes_by_payload())

    Nested logs each receive every record emitted inside them.
    """

    def __init__(self) -> None:
        self.records: list[ApssStats] = []
        # Robustness counters (serving.shed / serving.degraded /
        # serving.retries / sweep.resumed_steps ...) incremented through
        # :func:`incr` by the serving ladder and the resumable sweeps.
        self.counters: collections.Counter = collections.Counter()

    def __enter__(self) -> "CommLog":
        _STACK.append(self)
        return self

    def __exit__(self, *exc) -> None:
        # LIFO pop, asserted: ``remove(self)`` would strip the *first*
        # occurrence, so re-entering the same log nested (legal — each
        # entry just means "receive records") corrupted the stack order.
        if not _STACK or _STACK[-1] is not self:
            raise RuntimeError(
                "CommLog exited out of LIFO order (another log — or another "
                "entry of this log — is still active above it)"
            )
        _STACK.pop()

    @property
    def last(self) -> ApssStats:
        if not self.records:
            raise ValueError("CommLog is empty: no instrumented call ran")
        return self.records[-1]

    @property
    def total_wire_bytes(self) -> int:
        return sum(r.wire_bytes for r in self.records)

    def by_variant(self, variant: str) -> list[ApssStats]:
        return [r for r in self.records if r.variant == variant]


_STACK: list[CommLog] = []

# Observability hooks (``repro.obs``): the tracer subscribes to records
# (to pin each ApssStats onto its enclosing span) and the metrics registry
# to counters (so ``CommLog.counters`` and the registry never diverge —
# one ``incr`` call feeds both). Hooks keep the dependency one-way:
# ``repro.obs`` imports this module, never the reverse.
_RECORD_HOOKS: list = []
_COUNTER_HOOKS: list = []


def add_record_hook(fn) -> None:
    _RECORD_HOOKS.append(fn)


def remove_record_hook(fn) -> None:
    if fn in _RECORD_HOOKS:
        _RECORD_HOOKS.remove(fn)


def add_counter_hook(fn) -> None:
    _COUNTER_HOOKS.append(fn)


def remove_counter_hook(fn) -> None:
    if fn in _COUNTER_HOOKS:
        _COUNTER_HOOKS.remove(fn)


def enabled() -> bool:
    """True iff at least one CommLog is active (instrumentation guard)."""
    return bool(_STACK)


def active() -> Optional[CommLog]:
    return _STACK[-1] if _STACK else None


def record(stats: ApssStats) -> None:
    """Append ``stats`` to every active log (no-op when none is active)."""
    for log in _STACK:
        log.records.append(stats)
    for fn in list(_RECORD_HOOKS):
        fn(stats)


def incr(name: str, n: int = 1) -> None:
    """Increment counter ``name`` in every active log (no-op when none).

    The robustness layer's event counters flow through here — the serving
    degradation ladder (``serving.shed`` / ``serving.degraded`` /
    ``serving.retries`` / ``serving.stale``) and the resumable sweeps
    (``sweep.resumed_steps`` / ``sweep.checkpoints``), and the mutable
    index (``serving.appends`` / ``serving.deletes`` /
    ``serving.compactions``, plus recovery events ``mutable.replayed_ops``
    / ``mutable.restore_fallback`` / ``mutable.log_walkback``). Host-side
    events only: unlike :func:`record`, these fire at execution time,
    never inside traced code.
    """
    for log in _STACK:
        log.counters[name] += n
    for fn in list(_COUNTER_HOOKS):
        fn(name, n)


# ---------------------------------------------------------------------------
# Payload sizes
# ---------------------------------------------------------------------------


def dense_block_bytes(rows: int, m: int, itemsize: int = 4) -> int:
    """Wire bytes of a traveling dense row block (bf16 stays 2 B/elt —
    ``core.distributed._to_wire``)."""
    return rows * m * itemsize


def csr_block_bytes(rows: int, cap: int) -> int:
    """Wire bytes of a traveling CSR triple: (idx i32 + val f32)·cap + nnz i32."""
    return rows * cap * 8 + rows * 4


def csr_cell_bytes(rows: int, cap: int) -> int:
    """Wire bytes of a traveling 2-D checkerboard CSR cell: (idx i32 + val
    f32)·cap_loc only — the sparse 2-D ring ships no nnz vector (scoring
    sums every slot and padding slots are arithmetically inert)."""
    return rows * cap * 8


def matches_bytes(rows: int, k: int) -> int:
    """Wire bytes of a Matches caravan: values f32 + indices i32 + counts i32."""
    return rows * (8 * k + 4)


# ---------------------------------------------------------------------------
# FLOP models (per device)
# ---------------------------------------------------------------------------


def dense_join_flops(rows: int, cols: int, m: int) -> float:
    """MXU work of a dense blocked join: one (rows × cols × m) contraction."""
    return 2.0 * rows * cols * m


def sparse_join_flops(rows: int, cols: int, cap: int) -> float:
    """gather_dot work: 2·rows·cols·cap — the true sparse-dot cost."""
    return 2.0 * rows * cols * cap


def delta_join_flops(delta_rows: int, corpus_rows: int, depth: float) -> float:
    """Unpruned work of a mutable-index delta join: the delta must be
    scored against every live row in both directions, but (new × new) is
    covered once — 2·delta·corpus·depth, where ``depth`` is ``mlanes``
    (dense) or the ELL ``cap`` (sparse). The measured ``flops`` on a
    ``serving/delta-join`` record is the post-pruning fraction of this."""
    return 2.0 * delta_rows * corpus_rows * depth


# ---------------------------------------------------------------------------
# Hop formulas per schedule (shared with planner.costmodel)
# ---------------------------------------------------------------------------


def horizontal_hops(
    schedule: str,
    p: int,
    axis: str,
    block_bytes: int,
    caravan_bytes: int,
    payload: str = "dense_block",
) -> tuple[CollectiveHop, ...]:
    """Per-device hop list of the 1-D horizontal schedules.

    - ``allgather``: one tiled all-gather of the row shard → receives
      ``p-1`` remote blocks.
    - ``ring``: ``p-1`` block rotations.
    - ``halfring``: ``p//2`` block rotations (S = Sᵀ) plus the backward-match
      caravan: ``p//2`` in-ring hops + 1 homeward shift.
    """
    if p <= 1:
        return ()
    if schedule == "allgather":
        return (CollectiveHop("all_gather", payload, axis, block_bytes, p - 1),)
    if schedule == "ring":
        return (CollectiveHop("ppermute", payload, axis, block_bytes, p - 1),)
    if schedule == "halfring":
        return (
            CollectiveHop("ppermute", payload, axis, block_bytes, p // 2),
            CollectiveHop("ppermute", "caravan", axis, caravan_bytes, p // 2 + 1),
        )
    raise ValueError(f"unknown horizontal schedule: {schedule}")


def hierarchical_hops(
    sizes: tuple[int, ...],
    axes: tuple[str, ...],
    block_bytes: int,
    payload: str = "dense_block",
) -> tuple[CollectiveHop, ...]:
    """Nested ring: axis ``i`` hops ``(sizes[i]-1) · ∏_{j<i} sizes[j]`` times
    (each inner sweep completes before the next outer hop); the 4-byte owner
    id travels with the block."""
    out = []
    outer = 1
    for ax, s in zip(axes, sizes):
        if s > 1:
            out.append(
                CollectiveHop("ppermute", payload, ax, block_bytes + 4, outer * (s - 1))
            )
        outer *= s
    return tuple(out)


def vertical_hops(
    accumulation: str,
    axis: str,
    p: int,
    n: int,
    block_rows: int,
    capacity: int,
    cols: int | None = None,
) -> tuple[CollectiveHop, ...]:
    """Per-device hop list of the vertical accumulations, per full pass.

    ``cols`` is the accumulated score-tile width (defaults to ``n`` — the
    self-join; the 2-D composition passes its local column count).
    """
    if p <= 1:
        return ()
    cols = n if cols is None else cols
    nb = max(1, n // block_rows)
    b = block_rows
    if accumulation == "allreduce":
        per = int(2 * (p - 1) / p * b * cols * 4)
        return (CollectiveHop("psum", "scores", axis, per, nb),)
    if accumulation == "scatter":
        per = int((p - 1) / p * b * cols * 4)
        return (CollectiveHop("psum_scatter", "scores", axis, per, nb),)
    gather_b = (p - 1) * b * capacity * 4
    psum_b = 2 * (p - 1) * b * capacity * 4
    if accumulation == "compressed":
        return (
            CollectiveHop("all_gather", "candidate_ids", axis, gather_b, nb),
            CollectiveHop("psum", "candidate_scores", axis, psum_b, nb),
        )
    if accumulation == "recursive":
        levels = max(1, p.bit_length() - 1)
        perm_b = 3 * b * capacity * 4
        return (
            CollectiveHop("ppermute", "candidates", axis, perm_b, levels * nb),
            CollectiveHop("all_gather", "candidate_ids", axis, gather_b, nb),
            CollectiveHop("psum", "candidate_scores", axis, psum_b, nb),
        )
    raise ValueError(f"unknown vertical accumulation: {accumulation}")


def twod_hops(
    q: int,
    r: int,
    row_axis: str,
    col_axis: str,
    n_loc: int,
    m: int,
    itemsize: int,
    block_rows: int,
    capacity: int,
    accumulation: str,
    cap_loc: int | None = None,
) -> tuple[CollectiveHop, ...]:
    """2-D checkerboard: a row-axis ring of per-cell corpus blocks composed
    with a vertical accumulation of each ``(bs, n_loc)`` partial tile per ring
    step (paper Alg. 7).

    ``cap_loc`` switches the ring payload to the sparse cell: a dense cell is
    ``(n_loc, m/r)`` values, a sparse cell the per-cell CSR pair of width
    ``cap_loc`` (the realized max per-cell row count after ``shard_dims``).
    The inner accumulation hops are representation-agnostic either way — they
    carry candidate ids/scores, never corpus payloads."""
    hops: list[CollectiveHop] = []
    if q > 1:
        if cap_loc is None:
            block = dense_block_bytes(n_loc, m // r, itemsize)
            payload = "dense_block"
        else:
            block = csr_cell_bytes(n_loc, cap_loc)
            payload = "csr_cell"
        hops.append(
            CollectiveHop("ppermute", payload, row_axis, block, q - 1)
        )
    inner = vertical_hops(
        accumulation, col_axis, r, n_loc, block_rows, capacity, cols=n_loc
    )
    # The inner accumulation runs once per ring step (q total).
    hops.extend(
        CollectiveHop(h.op, h.payload, h.axis, h.bytes_per_hop, h.hops * q)
        for h in inner
    )
    return tuple(hops)
