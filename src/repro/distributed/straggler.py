"""Straggler detection: per-step timing ledger + slow-rank reporting.

On a synchronous SPMD cluster one slow host stalls every step. The launcher
records per-step wall times (and, multi-host, per-host times gathered out of
band); ranks consistently slower than ``median × tolerance`` are reported so
the orchestration layer can drain/replace them at the next elastic
checkpoint boundary (see ``distributed.elastic``).

Mitigations wired into the training loop:
- timing ledger + exponential moving averages per rank,
- a step-deadline watchdog (flag, not kill — SPMD can't preempt a peer),
- checkpoint-boundary remap recommendation (``StragglerReport.evict``).
"""

from __future__ import annotations

import dataclasses
import time
from collections import defaultdict, deque


@dataclasses.dataclass
class StragglerReport:
    rank_ema: dict
    median_ema: float
    evict: list
    tolerance: float

    def __str__(self) -> str:
        bad = ", ".join(f"rank{r}: {t*1e3:.1f}ms" for r, t in self.rank_ema.items() if r in self.evict)
        return (
            f"StragglerReport(median={self.median_ema*1e3:.1f}ms, "
            f"tolerance={self.tolerance}x, evict=[{bad}])"
        )


class StepTimer:
    """Per-rank step-time ledger with EMA-based straggler detection."""

    def __init__(self, *, ema: float = 0.9, tolerance: float = 1.5, window: int = 64):
        self.ema_coeff = ema
        self.tolerance = tolerance
        self.rank_ema: dict = {}
        self.history: dict = defaultdict(lambda: deque(maxlen=window))
        self._start: float | None = None

    def start(self) -> None:
        self._start = time.perf_counter()

    def stop(self, rank: int = 0) -> float:
        assert self._start is not None, "start() not called"
        dt = time.perf_counter() - self._start
        self._start = None
        self.record(rank, dt)
        return dt

    def record(self, rank: int, step_time: float) -> None:
        prev = self.rank_ema.get(rank)
        self.rank_ema[rank] = (
            step_time
            if prev is None
            else self.ema_coeff * prev + (1 - self.ema_coeff) * step_time
        )
        self.history[rank].append(step_time)

    def report(self) -> StragglerReport:
        if not self.rank_ema:
            return StragglerReport({}, 0.0, [], self.tolerance)
        times = sorted(self.rank_ema.values())
        median = times[len(times) // 2]
        evict = [
            r for r, t in self.rank_ema.items() if t > self.tolerance * median
        ]
        return StragglerReport(dict(self.rank_ema), median, evict, self.tolerance)
