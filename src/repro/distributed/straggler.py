"""Straggler detection: per-step timing ledger + slow-rank reporting.

On a synchronous SPMD cluster one slow host stalls every step. The launcher
records per-step wall times (and, multi-host, per-host times gathered out of
band); ranks consistently slower than ``median × tolerance`` are reported so
the orchestration layer can drain/replace them at the next elastic
checkpoint boundary (see ``distributed.elastic``).

Mitigations wired into the training loop:
- timing ledger + exponential moving averages per rank,
- a step-deadline watchdog (flag, not kill — SPMD can't preempt a peer),
- checkpoint-boundary remap recommendation (``StragglerReport.evict``).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import defaultdict, deque

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class StragglerReport:
    rank_ema: dict
    median_ema: float
    evict: list
    tolerance: float

    def __str__(self) -> str:
        bad = ", ".join(
            f"rank{r}: {t*1e3:.1f}ms"
            for r, t in self.rank_ema.items()
            if r in self.evict
        )
        return (
            f"StragglerReport(median={self.median_ema*1e3:.1f}ms, "
            f"tolerance={self.tolerance}x, evict=[{bad}])"
        )


class StepTimer:
    """Per-rank step-time ledger with EMA-based straggler detection."""

    def __init__(self, *, ema: float = 0.9, tolerance: float = 1.5, window: int = 64):
        self.ema_coeff = ema
        self.tolerance = tolerance
        self.rank_ema: dict = {}
        self.history: dict = defaultdict(lambda: deque(maxlen=window))
        self._start: float | None = None

    def start(self) -> None:
        self._start = time.perf_counter()

    def stop(self, rank: int = 0) -> float:
        assert self._start is not None, "start() not called"
        dt = time.perf_counter() - self._start
        self._start = None
        self.record(rank, dt)
        return dt

    def record(self, rank: int, step_time: float) -> None:
        prev = self.rank_ema.get(rank)
        self.rank_ema[rank] = (
            step_time
            if prev is None
            else self.ema_coeff * prev + (1 - self.ema_coeff) * step_time
        )
        self.history[rank].append(step_time)

    def report(self) -> StragglerReport:
        if not self.rank_ema:
            return StragglerReport({}, 0.0, [], self.tolerance)
        times = sorted(self.rank_ema.values())
        median = times[len(times) // 2]
        evict = [
            r for r, t in self.rank_ema.items() if t > self.tolerance * median
        ]
        return StragglerReport(dict(self.rank_ema), median, evict, self.tolerance)


class StepTicker:
    """Per-step per-rank host ticks from INSIDE traced ring sweeps.

    The distributed sweeps run their whole ring as one traced ``fori_loop``
    under ``shard_map`` — no host code runs between steps, so :class:`StepTimer`
    alone cannot see them. :meth:`emit` plants a ``jax.debug.callback`` in the
    traced step body; at execution time each rank's callback lands here with
    ``(step, rank)`` and is stamped with host ``perf_counter``. The ``dep``
    argument must be a value computed BY the step (e.g. the merged match
    counts' sum) — the data dependence keeps XLA from hoisting the callback
    out of the loop.

    Timing semantics: tick arrival approximates step completion on the host
    timeline. ``step_times()[s]`` is the gap between the latest rank tick of
    step ``s`` and of step ``s-1`` (step 0 is measured from ticker creation,
    so on a first call it absorbs compile time — compare later steps, not
    step 0). :meth:`to_step_timer` folds per-rank deltas into the
    :class:`StepTimer` ledger so ``report().evict`` can feed the elastic
    resume-on-smaller-mesh path (``robust.sweep.mesh_after_eviction``).
    """

    def __init__(self):
        self._lock = threading.Lock()
        self.ticks: list[tuple[int, int, float]] = []  # (rank, step, t)
        self._created = time.perf_counter()

    # -- trace-time side ---------------------------------------------------

    def emit(self, step, rank, dep) -> None:
        """Plant the host tick in traced code (call inside the step body)."""
        jax.debug.callback(self._tick, step, rank, jnp.asarray(dep))

    def _tick(self, step, rank, dep) -> None:
        with self._lock:
            self.ticks.append((int(rank), int(step), time.perf_counter()))

    # -- host side ---------------------------------------------------------

    def _settled(self) -> list[tuple[int, int, float]]:
        jax.effects_barrier()  # every planted callback has landed
        with self._lock:
            return list(self.ticks)

    @property
    def created(self) -> float:
        """``perf_counter`` stamp at ticker creation (step 0's baseline)."""
        return self._created

    def tick_log(self) -> list[tuple[int, int, float]]:
        """Settled ``(rank, step, t)`` ticks on the host ``perf_counter``
        timeline — the seam ``obs.trace`` adapts into per-ring-step child
        spans after the sweep's outputs are ready."""
        return self._settled()

    @property
    def n_steps(self) -> int:
        ticks = self._settled()
        return 1 + max((s for _, s, _ in ticks), default=-1)

    def step_times(self) -> tuple[float, ...]:
        """One wall-time entry per ring step (slowest rank sets the pace)."""
        ticks = self._settled()
        by_step: dict[int, float] = {}
        for _, s, t in ticks:
            by_step[s] = max(t, by_step.get(s, -1.0))
        out, prev = [], self._created
        for s in sorted(by_step):
            out.append(by_step[s] - prev)
            prev = by_step[s]
        return tuple(out)

    def to_step_timer(self, **timer_kwargs) -> StepTimer:
        """Fold per-rank step deltas into a :class:`StepTimer` ledger."""
        timer = StepTimer(**timer_kwargs)
        per_rank: dict[int, dict[int, float]] = defaultdict(dict)
        for r, s, t in self._settled():
            per_rank[r][s] = max(t, per_rank[r].get(s, -1.0))
        for r, by_s in per_rank.items():
            steps = sorted(by_s)
            if len(steps) == 1:
                timer.record(r, by_s[steps[0]] - self._created)
            for a, b in zip(steps, steps[1:]):
                timer.record(r, by_s[b] - by_s[a])
        return timer
