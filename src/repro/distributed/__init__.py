"""Distributed runtime helpers: sharding context, elastic re-mesh,
straggler tracking."""

from repro.distributed.sharding import (  # noqa: F401
    active_mesh,
    use_mesh,
    shard,
    shard_params,
    data_axes,
    model_axis,
)
from repro.distributed.elastic import reshard_tree, ElasticPlan  # noqa: F401
from repro.distributed.straggler import StepTimer, StragglerReport  # noqa: F401
