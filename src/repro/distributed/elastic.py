"""Elastic re-mesh: reshard a checkpointed state to a different device count.

The fault-tolerance story at 1000+ nodes (DESIGN.md §5): when a pod/node is
lost, training resumes from the latest checkpoint on a *smaller* mesh, and
scales back up when capacity returns. Checkpoints are stored as full
(unsharded) host arrays per leaf (``repro.checkpoint``), so resharding is a
pure placement problem: build the new mesh, re-derive the PartitionSpecs
(they are mesh-shape-agnostic by construction — axis names are filtered
against the mesh), and ``device_put`` each leaf.

``ElasticPlan`` also re-derives the data-pipeline sharding so global batch
and RNG streams stay consistent across a rescale (same global batch, new
per-device slice).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    """A concrete rescale: old mesh shape → new mesh shape."""

    new_mesh: Mesh
    reason: str = ""

    def describe(self) -> str:
        return (
            f"ElasticPlan(mesh={dict(self.new_mesh.shape)}, "
            f"devices={self.new_mesh.devices.size}, reason={self.reason!r})"
        )


def make_elastic_mesh(
    n_devices: int,
    *,
    model_parallel: int,
    axis_names=("data", "model"),
    devices=None,
) -> Mesh:
    """Largest mesh of the requested shape family that fits ``n_devices``.

    Keeps the model axis fixed (TP degree is a property of the model, not of
    cluster capacity) and shrinks the data axis — the standard elastic
    policy: losing nodes costs data parallelism, never model correctness.
    """
    if devices is None:
        devices = jax.devices()
    devices = devices[:n_devices]
    data = len(devices) // model_parallel
    if data < 1:
        raise ValueError(
            f"{len(devices)} devices cannot host model_parallel={model_parallel}"
        )
    usable = devices[: data * model_parallel]
    arr = np.array(usable).reshape(data, model_parallel)
    return Mesh(arr, axis_names)


def _filter_spec_for(mesh: Mesh, spec: P) -> P:
    def keep(part):
        if part is None:
            return None
        if isinstance(part, (tuple, list)):
            kept = tuple(a for a in part if a in mesh.shape)
            return kept if kept else None
        return part if part in mesh.shape else None

    return P(*(keep(part) for part in spec))


def reshard_tree(
    host_state: Any,
    specs: Any,
    new_mesh: Mesh,
) -> Any:
    """Place a host-side (numpy) state pytree onto a new mesh.

    ``specs`` is the pytree of PartitionSpecs used at the original scale;
    axis names missing from the new mesh degrade to replication, so the same
    spec tree drives every scale (including single-host debugging).
    """

    def place(x, spec):
        ns = NamedSharding(new_mesh, _filter_spec_for(new_mesh, spec))
        return jax.device_put(x, ns)

    return jax.tree.map(
        place, host_state, specs, is_leaf=lambda x: isinstance(x, P)
    )


def rescale(
    checkpoint_load: Callable[[], Any],
    specs: Any,
    plan: ElasticPlan,
) -> Any:
    """Full elastic rescale: load latest checkpoint → place on the new mesh."""
    state = checkpoint_load()
    return reshard_tree(state, specs, plan.new_mesh)
