"""Sharding context: mesh-aware ``with_sharding_constraint`` that degrades to
a no-op on a single device (so model code is identical in smoke tests and on
the production mesh).

Conventions (DESIGN.md §5):
  - batch-like dims        → ``("pod", "data")`` (whichever exist)
  - hidden/feature dims    → ``"model"`` (tensor parallel)
  - expert dim             → ``"model"`` (expert parallel)
  - decode KV cache (500k) → sequence dim over ``"data"``
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def active_mesh() -> Mesh | None:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Mesh | None):
    """Activate a mesh for :func:`shard` constraints within the block."""
    prev = active_mesh()
    _state.mesh = mesh
    try:
        yield mesh
    finally:
        _state.mesh = prev


def _filter_spec(mesh: Mesh, spec: P) -> P:
    """Drop axis names the mesh does not have (lets one model definition run
    on (data, model), (pod, data, model) and single-device meshes)."""
    def keep(part):
        if part is None:
            return None
        if isinstance(part, (tuple, list)):
            kept = tuple(a for a in part if a in mesh.shape)
            return kept if kept else None
        return part if part in mesh.shape else None

    return P(*(keep(part) for part in spec))


def shard(x: jax.Array, *spec_parts) -> jax.Array:
    """``with_sharding_constraint(x, P(*spec_parts))`` under the active mesh;
    identity when no mesh is active (single-device tests)."""
    mesh = active_mesh()
    if mesh is None or len(mesh.shape) == 0:
        return x
    spec = _filter_spec(mesh, P(*spec_parts))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def data_axes() -> tuple:
    """Batch-sharding axes present in the active mesh."""
    mesh = active_mesh()
    if mesh is None:
        return ()
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def model_axis() -> str | None:
    mesh = active_mesh()
    if mesh is None or "model" not in mesh.shape:
        return None
    return "model"


def batch_spec(*trailing) -> P:
    """P(("pod","data"), *trailing) filtered to the active mesh."""
    axes = data_axes()
    lead = axes if axes else None
    return P(lead, *trailing)


def shard_batch(x: jax.Array, *trailing) -> jax.Array:
    axes = data_axes()
    if not axes:
        return x
    return shard(x, axes, *trailing)


def shard_params(params, specs):
    """Apply a pytree of PartitionSpecs as constraints (no-op w/o mesh)."""
    mesh = active_mesh()
    if mesh is None:
        return params
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, _filter_spec(mesh, s))
        ),
        params,
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def named_sharding(spec: P) -> NamedSharding | None:
    mesh = active_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, _filter_spec(mesh, spec))
