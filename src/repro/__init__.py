"""repro: a multi-pod JAX framework for the All-Pairs Similarity Problem.

Reproduction (and TPU-native extension) of:
    Özkural & Aykanat, "1-D and 2-D Parallel Algorithms for All-Pairs
    Similarity Problem" (CS.IR 2014).

Subpackages:

- :mod:`repro.core`        — the paper's contribution (APSS + distributions)
- :mod:`repro.kernels`     — Pallas TPU kernels (apss_block, flash_attention,
                             decode_attention)
- :mod:`repro.models`      — transformer / recsys / GNN model zoo
- :mod:`repro.data`        — data pipeline (synthetic corpora, LM batches,
                             APSS dedup)
- :mod:`repro.optim`       — optimizers, schedules, gradient compression
- :mod:`repro.checkpoint`  — sharded checkpointing + fault tolerance
- :mod:`repro.distributed` — mesh/collective helpers, elastic re-mesh
- :mod:`repro.configs`     — assigned architecture configs
- :mod:`repro.launch`      — mesh, dry-run, train and serve entry points
- :mod:`repro.serving`     — build-once APSS index + batched query-time
                             top-k retrieval server

NOTE: this module is import-side-effect free (no jax import at package
import time) so that ``launch/dryrun.py`` can set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before jax/jaxlib
parse the environment. Public names are re-exported lazily.
"""

__version__ = "1.0.0"

_LAZY = {
    "apss_reference": ("repro.core.apss", "apss_reference"),
    "apss_blocked": ("repro.core.apss", "apss_blocked"),
    "similarity_topk": ("repro.core.apss", "similarity_topk"),
    "normalize_rows": ("repro.core.apss", "normalize_rows"),
    "Matches": ("repro.core.matches", "Matches"),
    "extract_matches": ("repro.core.matches", "extract_matches"),
    "merge_matches": ("repro.core.matches", "merge_matches"),
    "apss": ("repro.core.distributed", "apss"),
    "apss_horizontal": ("repro.core.distributed", "apss_horizontal"),
    "apss_vertical": ("repro.core.distributed", "apss_vertical"),
    "apss_2d": ("repro.core.distributed", "apss_2d"),
    "APSSIndex": ("repro.serving.index", "APSSIndex"),
    "build_index": ("repro.serving.index", "build_index"),
    "query_topk": ("repro.serving.query", "query_topk"),
    "RetrievalServer": ("repro.serving.server", "RetrievalServer"),
}


def __getattr__(name: str):
    if name in _LAZY:
        import importlib

        mod, attr = _LAZY[name]
        return getattr(importlib.import_module(mod), attr)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_LAZY))
