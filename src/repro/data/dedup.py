"""Near-duplicate detection for the data pipeline — the paper's own
application ("near-duplicate detection by using a high threshold").

Documents (as normalized feature vectors) are self-joined with the APSS
core at a high threshold; of every duplicate cluster only the lowest-index
row survives. Plugged in front of LM training data to scrub duplicated
crawl content — APSS as a first-class pipeline stage.
"""

from __future__ import annotations

import numpy as np

from repro.core.apss import apss_blocked, normalize_rows
from repro.core.graph import matches_to_coo


def dedup_corpus(
    D: np.ndarray,
    *,
    threshold: float = 0.95,
    k: int = 64,
    block_rows: int = 512,
) -> tuple[np.ndarray, np.ndarray]:
    """Return (keep_mask, duplicate_of) for rows of D.

    ``duplicate_of[i] = j < i`` for dropped rows, else -1.
    """
    import jax.numpy as jnp

    Dn = normalize_rows(jnp.asarray(D))
    matches = apss_blocked(Dn, threshold, k, block_rows=block_rows)
    rows, cols, _ = matches_to_coo(matches, undirected=True)
    n = D.shape[0]
    keep = np.ones(n, bool)
    dup_of = np.full(n, -1, np.int32)
    # rows < cols by construction: later row is the duplicate.
    order = np.argsort(cols, kind="stable")
    for r, c in zip(rows[order], cols[order]):
        if keep[c] and keep[r]:
            keep[c] = False
            dup_of[c] = r
    return keep, dup_of
