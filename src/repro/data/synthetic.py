"""Synthetic corpora with the paper's dataset statistics.

The paper's five real-world datasets (Table 1) are text corpora with
Zipf-distributed dimension (term) frequencies — the property the paper
identifies as the source of "almost irreducible complexity" (a few dense
dimensions dominate the quadratic work) and the reason its dimension-wise
load balancing bins by ``|I_d|²``. ``synthetic_corpus`` reproduces exactly
that structure: dimension popularity ~ Zipf(alpha), TF-IDF-like positive
weights, L2-normalized rows — so benchmarks exercise the same skew regime
the paper measured without shipping the (unavailable) WebBase crawls.
"""

from __future__ import annotations

import dataclasses

import numpy as np

# Table 1 of the paper (name → n, m, nnz). Used to scale synthetic stand-ins.
PAPER_DATASETS = {
    "radikal": dict(n=6883, m=136447, nnz=1072472, t=0.2),
    "20-newsgroups": dict(n=20001, m=313389, nnz=2984809, t=0.4),
    "wikipedia": dict(n=70115, m=1350761, nnz=43285850, t=0.9),
    "facebook": dict(n=66568, m=4618973, nnz=14277455, t=0.99),
    "virginia-tech": dict(n=85653, m=367098, nnz=25827347, t=0.99),
}


def synthetic_corpus(
    n: int,
    m: int,
    avg_nnz: float,
    *,
    zipf_alpha: float = 1.1,
    seed: int = 0,
    dense: bool = True,
) -> np.ndarray:
    """Power-law sparse corpus as a dense (n, m) float32 array, row-normalized.

    Dimension d is chosen with prob ∝ (d+1)^-alpha (Zipf over dims); weights
    are |N(0,1)|·idf-ish. Rows are L2-normalized (paper's assumption).
    """
    rng = np.random.default_rng(seed)
    D = np.zeros((n, m), np.float32)
    # Zipf-ish popularity over dims.
    pop = (np.arange(1, m + 1, dtype=np.float64)) ** (-zipf_alpha)
    pop /= pop.sum()
    nnz_per_row = np.maximum(
        1, rng.poisson(avg_nnz, size=n)
    )
    for i in range(n):
        k = min(int(nnz_per_row[i]), m)
        dims = rng.choice(m, size=k, replace=False, p=pop)
        w = np.abs(rng.standard_normal(k)).astype(np.float32) + 0.05
        D[i, dims] = w
    norms = np.linalg.norm(D, axis=1, keepdims=True)
    D /= np.maximum(norms, 1e-12)
    return D


def clustered_corpus(
    n: int,
    m: int,
    avg_nnz: float,
    *,
    n_clusters: int = 32,
    zipf_alpha: float = 1.1,
    seed: int = 0,
) -> np.ndarray:
    """Topic-clustered Zipfian corpus — the block-pruning-friendly regime.

    Rows are grouped into ``n_clusters`` contiguous clusters, each drawing
    its dimensions (Zipf-popular *within* the cluster's band) from a
    disjoint band of ``m / n_clusters`` dims — the structure of
    topic-/language-/tenant-sharded text corpora, and the block-granular
    analogue of the paper's maxweight-sorted vector ordering: tiles that
    cross cluster boundaries share no support, so the maxweight upper bound
    proves them dead and tile pruning actually fires. (On a randomly
    ordered corpus 256-row block maxima saturate and no tile is provably
    dead — ordering, not sparsity, is what makes block bounds bite.)
    """
    rng = np.random.default_rng(seed)
    D = np.zeros((n, m), np.float32)
    band = m // n_clusters
    rows_per = -(-n // n_clusters)
    pop = np.arange(1, band + 1, dtype=np.float64) ** (-zipf_alpha)
    pop /= pop.sum()
    nnz_per_row = np.maximum(1, rng.poisson(avg_nnz, size=n))
    for i in range(n):
        c = min(i // rows_per, n_clusters - 1)
        k = min(int(nnz_per_row[i]), band)
        dims = c * band + rng.choice(band, size=k, replace=False, p=pop)
        D[i, dims] = np.abs(rng.standard_normal(k)).astype(np.float32) + 0.05
    D /= np.maximum(np.linalg.norm(D, axis=1, keepdims=True), 1e-12)
    return D


def paper_like_corpus(
    name: str, *, scale: float = 0.02, seed: int = 0
) -> tuple[np.ndarray, float]:
    """A scaled-down stand-in for one of the paper's Table-1 datasets.

    ``scale`` shrinks n and m (nnz shrinks ~quadratically less); returns
    ``(D, threshold)`` with the paper's per-dataset similarity threshold.
    """
    spec = PAPER_DATASETS[name]
    n = max(64, int(spec["n"] * scale))
    m = max(128, int(spec["m"] * scale))
    avg_nnz = max(4.0, spec["nnz"] / spec["n"] * min(1.0, scale * 4))
    return synthetic_corpus(n, m, avg_nnz, seed=seed), spec["t"]


@dataclasses.dataclass
class CorpusStats:
    n: int
    m: int
    nnz: int
    avg_vector_size: float
    avg_dim_size: float
    sparsity: float

    def row(self) -> str:
        return (
            f"n={self.n} m={self.m} nnz={self.nnz} "
            f"avg|x|={self.avg_vector_size:.1f} avg|I_d|={self.avg_dim_size:.1f} "
            f"sparsity={self.sparsity:.2e}"
        )


def corpus_stats(D: np.ndarray) -> CorpusStats:
    """The paper's Table-1 columns for any corpus."""
    nz = D != 0
    nnz = int(nz.sum())
    n, m = D.shape
    dims_used = max(int((nz.sum(0) > 0).sum()), 1)
    return CorpusStats(
        n=n,
        m=m,
        nnz=nnz,
        avg_vector_size=nnz / n,
        avg_dim_size=nnz / dims_used,
        sparsity=nnz / (n * m),
    )
