"""Neighbor sampling for mini-batch GNN training (GraphSAGE-style fanout).

``minibatch_lg`` (232k nodes / 114M edges / fanout 15-10) cannot train
full-batch; this sampler draws a fixed-fanout k-hop subgraph around the seed
nodes and emits *statically shaped* padded arrays (JAX jit contract).

Output layout matches the GAT batch dict: local node ids are
``[seeds | hop-1 samples | hop-2 samples]`` with edges pointing sample →
parent (message flows toward the seeds). Padding edges carry mask 0.
"""

from __future__ import annotations

import numpy as np


def neighbor_sample(
    indptr: np.ndarray,       # CSR (n+1,)
    indices: np.ndarray,      # CSR (nnz,)
    seeds: np.ndarray,        # (B,) seed node ids
    fanouts: tuple,           # e.g. (15, 10)
    features: np.ndarray,     # (n, F) global features
    labels: np.ndarray,       # (n,)
    *,
    seed: int = 0,
) -> dict:
    rng = np.random.default_rng(seed)
    B = len(seeds)

    layers = [seeds.astype(np.int64)]
    edge_src_l, edge_dst_l, edge_mask_l = [], [], []

    # Local index bookkeeping: node k of layer L sits at offset(L) + k.
    offsets = [0]
    total = B
    frontier = seeds.astype(np.int64)
    for hop, fanout in enumerate(fanouts):
        n_par = len(frontier)
        samples = np.empty((n_par, fanout), np.int64)
        mask = np.zeros((n_par, fanout), np.float32)
        for i, node in enumerate(frontier):
            lo, hi = int(indptr[node]), int(indptr[node + 1])
            deg = hi - lo
            if deg == 0:
                samples[i] = node  # self-fallback, masked out
                continue
            take = rng.integers(lo, hi, size=fanout)
            samples[i] = indices[take]
            mask[i] = 1.0
        flat = samples.reshape(-1)
        layers.append(flat)
        offsets.append(total)
        # edges: sampled child (this layer) → parent (previous layer)
        child_local = total + np.arange(len(flat))
        parent_local = offsets[hop] + np.repeat(np.arange(n_par), fanout)
        edge_src_l.append(child_local)
        edge_dst_l.append(parent_local)
        edge_mask_l.append(mask.reshape(-1))
        total += len(flat)
        frontier = flat

    all_nodes = np.concatenate(layers)
    # self-loops so every node sees itself
    loops = np.arange(total, dtype=np.int64)
    edge_src = np.concatenate(edge_src_l + [loops]).astype(np.int32)
    edge_dst = np.concatenate(edge_dst_l + [loops]).astype(np.int32)
    edge_mask = np.concatenate(edge_mask_l + [np.ones(total, np.float32)])

    label_mask = np.zeros(total, np.float32)
    label_mask[:B] = 1.0
    return {
        "features": features[all_nodes].astype(np.float32),
        "edge_src": edge_src,
        "edge_dst": edge_dst,
        "edge_mask": edge_mask.astype(np.float32),
        "labels": labels[all_nodes].astype(np.int32),
        "label_mask": label_mask,
        "node_ids": all_nodes.astype(np.int64),
    }


def sampled_shape(batch: int, fanouts: tuple) -> tuple[int, int]:
    """Static (n_nodes, n_edges) of a sampled batch (excl. nothing)."""
    total, frontier, edges = batch, batch, 0
    for f in fanouts:
        frontier = frontier * f
        total += frontier
        edges += frontier
    edges += total  # self loops
    return total, edges
