from repro.data.synthetic import (  # noqa: F401
    synthetic_corpus,
    corpus_stats,
    PAPER_DATASETS,
    paper_like_corpus,
)
from repro.data.sparse import (  # noqa: F401
    sparse_clustered_corpus,
    sparse_zipfian_corpus,
)
from repro.data.pipeline import (  # noqa: F401
    LMDataPipeline,
    RecsysPipeline,
    GraphPipeline,
)
from repro.data.sampler import neighbor_sample  # noqa: F401
from repro.data.dedup import dedup_corpus  # noqa: F401
