"""Deterministic, resumable, shardable batch pipelines.

Every pipeline is a pure function of ``(seed, step)`` — resuming from a
checkpoint at step ``s`` regenerates exactly the batch stream from ``s``
with no iterator state to persist (the checkpoint only stores the step).
Batches are produced as host numpy and placed with the mesh batch sharding
by the launcher.

Real deployments swap the synthesis for file readers behind the same
``get_batch(step)`` contract; determinism-by-construction (counter-based
RNG) is the production property this module demonstrates.
"""

from __future__ import annotations

import dataclasses

import numpy as np


def _rng(seed: int, step: int, stream: int = 0) -> np.random.Generator:
    # Counter-based: an independent stream per (seed, step, stream) triple.
    return np.random.default_rng(
        np.random.SeedSequence(entropy=seed, spawn_key=(step, stream))
    )


@dataclasses.dataclass
class LMDataPipeline:
    vocab_size: int
    batch_size: int          # GLOBAL batch
    seq_len: int
    seed: int = 0

    def get_batch(self, step: int) -> dict:
        rng = _rng(self.seed, step)
        tokens = rng.integers(
            0, self.vocab_size, size=(self.batch_size, self.seq_len),
            dtype=np.int32,
        )
        return {"tokens": tokens}


@dataclasses.dataclass
class RecsysPipeline:
    n_items: int
    batch_size: int
    history_len: int = 50
    n_user_fields: int = 8
    user_vocab: int = 1_000_000
    seed: int = 0
    kind: str = "two-tower"   # "two-tower" | "seq" | "ctr"

    def get_batch(self, step: int) -> dict:
        rng = _rng(self.seed, step)
        b = self.batch_size
        hist = rng.integers(
            -1, self.n_items, size=(b, self.history_len), dtype=np.int32
        )
        items = rng.integers(0, self.n_items, size=(b,), dtype=np.int32)
        if self.kind == "two-tower":
            return {
                "user_fields": rng.integers(
                    0, self.user_vocab, size=(b, self.n_user_fields), dtype=np.int32
                ),
                "history": hist,
                "item_ids": items,
            }
        if self.kind == "seq":  # bert4rec masked cloze
            ids = rng.integers(
                0, self.n_items, size=(b, self.history_len), dtype=np.int32
            )
            mask = rng.random((b, self.history_len)) < 0.15
            labels = ids.copy()
            masked = ids.copy()
            masked[mask] = self.n_items  # [MASK] token row
            return {"item_ids": masked, "labels": labels, "mask": mask}
        if self.kind == "ctr":  # din / bst
            return {
                "history": hist,
                "item_ids": items,
                "click": rng.integers(0, 2, size=(b,), dtype=np.int32),
            }
        raise ValueError(self.kind)


@dataclasses.dataclass
class GraphPipeline:
    """Synthetic graphs with power-law degree (GNN shapes, incl. sampled)."""

    n_nodes: int
    n_edges: int
    d_feat: int
    n_classes: int = 7
    seed: int = 0

    def full_graph(self) -> dict:
        rng = _rng(self.seed, 0)
        # Power-law-ish degree: preferential attachment approximation.
        src = rng.zipf(1.3, size=self.n_edges) % self.n_nodes
        dst = rng.integers(0, self.n_nodes, size=self.n_edges)
        feats = rng.standard_normal((self.n_nodes, self.d_feat)).astype(np.float32)
        labels = rng.integers(0, self.n_classes, size=(self.n_nodes,), dtype=np.int32)
        return {
            "features": feats,
            "edge_src": src.astype(np.int32),
            "edge_dst": dst.astype(np.int32),
            "edge_mask": np.ones(self.n_edges, np.float32),
            "labels": labels,
            "label_mask": (rng.random(self.n_nodes) < 0.1).astype(np.float32),
        }

    def csr(self) -> tuple[np.ndarray, np.ndarray]:
        """CSR adjacency (indptr, indices) for the neighbor sampler."""
        g = self.full_graph()
        order = np.argsort(g["edge_src"], kind="stable")
        dst = g["edge_dst"][order]
        counts = np.bincount(g["edge_src"], minlength=self.n_nodes)
        indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        return indptr, dst

    def batched_small_graphs(
        self, batch: int, nodes: int, edges: int, step: int
    ) -> dict:
        """`molecule` shape: a batch of small graphs, block-diagonal packed."""
        rng = _rng(self.seed, step, stream=2)
        N, E = batch * nodes, batch * edges
        offs = np.repeat(np.arange(batch) * nodes, edges)
        src = rng.integers(0, nodes, size=E) + offs
        dst = rng.integers(0, nodes, size=E) + offs
        return {
            "features": rng.standard_normal((N, self.d_feat)).astype(np.float32),
            "edge_src": src.astype(np.int32),
            "edge_dst": dst.astype(np.int32),
            "edge_mask": np.ones(E, np.float32),
            "labels": rng.integers(0, self.n_classes, size=(N,), dtype=np.int32),
            "label_mask": np.ones(N, np.float32),
        }
