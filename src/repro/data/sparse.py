"""Sparse corpus generators that never materialize ``(n, m)``.

The dense generators in ``data.synthetic`` build the full array and zero
most of it — fine at benchmark scale, hostile at the paper's (``m`` up to
4.6M, density ≲ 0.03%). These build the padded-CSR ``SparseCorpus``
directly: memory is ``O(n · cap)``, so web-scale shapes cost what their
payload costs.

Same statistical structure as their dense twins (see ``data.synthetic``
for the rationale): Zipf-distributed dimension popularity — the skew the
paper identifies as the source of "almost irreducible complexity" — and a
topic-clustered/banded variant where tile pruning actually fires. Rows are
L2-normalized in CSR form; coordinates are unique per row.
"""

from __future__ import annotations

import numpy as np

from repro.core.sparse import SparseCorpus


def _finish(indices, values, nnz, m) -> SparseCorpus:
    import jax.numpy as jnp

    from repro.core.sparse import normalize_sparse

    sp = SparseCorpus(
        jnp.asarray(indices), jnp.asarray(values), jnp.asarray(nnz), m
    )
    return normalize_sparse(sp)


def _zipf_pop(size: int, alpha: float) -> np.ndarray:
    pop = np.arange(1, size + 1, dtype=np.float64) ** (-alpha)
    return pop / pop.sum()


def sparse_zipfian_corpus(
    n: int,
    m: int,
    avg_nnz: float,
    *,
    zipf_alpha: float = 1.1,
    seed: int = 0,
) -> SparseCorpus:
    """Power-law sparse corpus, CSR-direct (the paper's Table-1 regime).

    Dimension ``d`` is drawn with prob ∝ ``(d+1)^-alpha``; per-row
    coordinates are unique; rows L2-normalized. ``cap`` is the realized max
    row nnz — memory never scales with ``m``.
    """
    rng = np.random.default_rng(seed)
    pop = _zipf_pop(m, zipf_alpha)
    nnz = np.minimum(np.maximum(1, rng.poisson(avg_nnz, size=n)), m).astype(
        np.int32
    )
    cap = int(nnz.max())
    indices = np.zeros((n, cap), np.int32)
    values = np.zeros((n, cap), np.float32)
    for i in range(n):
        k = int(nnz[i])
        dims = np.sort(rng.choice(m, size=k, replace=False, p=pop))
        indices[i, :k] = dims
        values[i, :k] = np.abs(rng.standard_normal(k)).astype(np.float32) + 0.05
    return _finish(indices, values, nnz, m)


def perturbed_queries(
    sp: SparseCorpus,
    nq: int,
    *,
    noise: float = 0.02,
    start: int | None = None,
    seed: int = 1,
) -> np.ndarray:
    """Dense query batch: perturbed rows from one contiguous corpus range.

    The serving benchmark/demo traffic model — near-duplicate, topical
    queries (the regime where a prebuilt inverted index prunes hardest).
    Rows ``[start, start+nq)`` are densified, their nonzeros jittered by
    ``noise``, and the batch L2-renormalized. Returns ``(nq, m)`` f32.
    """
    import jax.numpy as jnp

    from repro.core.apss import normalize_rows
    from repro.core.sparse import densify_rows

    rng = np.random.default_rng(seed)
    if start is None:
        start = int(rng.integers(0, max(1, sp.n - nq)))
    qd = np.asarray(densify_rows(sp, start, min(nq, sp.n)))
    if qd.shape[0] < nq:  # tiny corpora: repeat rows to fill the batch
        reps = -(-nq // qd.shape[0])
        qd = np.tile(qd, (reps, 1))[:nq]
    jitter = noise * np.abs(rng.standard_normal(qd.shape)).astype(np.float32)
    qd = qd + jitter * (qd > 0)
    return np.asarray(normalize_rows(jnp.asarray(qd)))


def sparse_clustered_corpus(
    n: int,
    m: int,
    avg_nnz: float,
    *,
    n_clusters: int = 32,
    zipf_alpha: float = 1.1,
    seed: int = 0,
    overlap_dims: int = 0,
    overlap_scale: float = 0.25,
) -> SparseCorpus:
    """Topic-clustered Zipfian corpus, CSR-direct (pruning-friendly regime).

    Contiguous row clusters draw dimensions from disjoint bands of
    ``m / n_clusters`` dims (see ``data.synthetic.clustered_corpus`` for
    why this is the regime where tile bounds bite — and where the inverted
    index proves cross-cluster tiles share no dimension support at all).

    ``overlap_dims > 0`` reserves that many leading dimensions as a shared
    background vocabulary: every row adds two low-weight (``overlap_scale``)
    nonzeros there, so cross-cluster tiles get a SMALL but nonzero upper
    bound instead of a zero one. That is the early-exit regime (DESIGN.md
    §12): at a low threshold those tiles stay live — the mask alone cannot
    drop them — but any query whose top-k fills within its own cluster
    beats their bound, so the ub-ordered scan stops instead of scoring
    them. Default 0 keeps the historical fully-disjoint shape.
    """
    rng = np.random.default_rng(seed)
    ov = int(overlap_dims)
    n_sh = 2 if ov >= 2 else ov
    band = (m - ov) // n_clusters
    rows_per = -(-n // n_clusters)
    pop = _zipf_pop(band, zipf_alpha)
    nnz = np.minimum(np.maximum(1, rng.poisson(avg_nnz, size=n)), band).astype(
        np.int32
    )
    cap = int(nnz.max()) + n_sh
    indices = np.zeros((n, cap), np.int32)
    values = np.zeros((n, cap), np.float32)
    for i in range(n):
        c = min(i // rows_per, n_clusters - 1)
        k = int(nnz[i])
        dims = ov + c * band + rng.choice(band, size=k, replace=False, p=pop)
        vals = np.abs(rng.standard_normal(k)).astype(np.float32) + 0.05
        if n_sh:
            sh = rng.choice(ov, size=n_sh, replace=False)
            shv = overlap_scale * (
                np.abs(rng.standard_normal(n_sh)).astype(np.float32) + 0.05
            )
            dims = np.concatenate([sh, dims])
            vals = np.concatenate([shv, vals])
            k += n_sh
        order = np.argsort(dims)
        indices[i, :k] = dims[order]
        values[i, :k] = vals[order]
    if n_sh:
        nnz = nnz + n_sh
    return _finish(indices, values, nnz, m)
