"""The paper's own workload as a selectable arch: distributed APSS cells.

These cells are the paper-representative entries for §Roofline/§Perf: each
lowers one distributed APSS variant at the scale of a paper Table-4 dataset
(padded to mesh-divisible shapes). Thresholds follow Table 4.

Variants (paper §5-§6 + TPU extensions):
  h_allgather   1-D horizontal, paper-faithful Alg. 6 (corpus all-gather)
  h_ring        1-D horizontal, hierarchical nested ring (beyond-paper)
  v_compressed  1-D vertical w/ local pruning (Lemma 1) + top-C compaction
  grid_2d       2-D checkerboard (Alg. 7), compressed accumulation

Scales (chosen so every per-device shard fits a 16 GB v5e):
  wikipedia-like  n=71680  m=1351680  t=0.9   (horizontal / 2-D)
  20news-like     n=20480  m=315392   t=0.4   (vertical — the paper also
                  found the vertical distribution viable only at smaller n)
"""

from __future__ import annotations

import functools

import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import (
    ArchDef, CellBuild, ShapeCell, register, sds, shardings_for,
)
from repro.core.distributed import (
    apss_2d,
    apss_horizontal,
    apss_horizontal_hierarchical,
    apss_vertical,
)

K_MATCHES = 64


def config() -> dict:
    return {
        "wikipedia": dict(n=71680, m=1351680, t=0.9),
        "20news": dict(n=20480, m=315392, t=0.4),
        "dtype": "f32",       # §Perf knob: "bf16" halves block traffic
        "block_rows": 512,    # §Perf knob: n_loc reads each ring block once
    }


def smoke_config() -> dict:
    return {"synthetic": dict(n=256, m=192, t=0.35)}


def _row_axes(mesh):
    return tuple(a for a in ("pod", "data", "model") if a in mesh.shape)


def _dtype(cfg):
    return jnp.bfloat16 if cfg.get("dtype") == "bf16" else jnp.float32


def _h_allgather_cell(cfg, mesh) -> CellBuild:
    spec = cfg["wikipedia"]
    axes = _row_axes(mesh)
    D = sds((spec["n"], spec["m"]), _dtype(cfg))
    fn = functools.partial(
        _run_h_allgather, mesh=mesh, axes=axes, t=spec["t"], k=K_MATCHES
    )
    return CellBuild(
        fn=fn, args=(D,),
        in_shardings=(shardings_for(mesh, P(axes, None)),),
        out_shardings=None,
        static_info={
            "kind": "apss", "model_flops": 2 * spec["n"] ** 2 * spec["m"],
            "n": spec["n"], "m": spec["m"],
        },
    )


def _run_h_allgather(D, *, mesh, axes, t, k):
    return apss_horizontal(
        D, t, k, mesh, axis_name=axes, schedule="allgather", block_rows=512
    )


def _h_ring_cell(cfg, mesh) -> CellBuild:
    spec = cfg["wikipedia"]
    axes = _row_axes(mesh)
    D = sds((spec["n"], spec["m"]), _dtype(cfg))
    fn = functools.partial(
        _run_h_ring, mesh=mesh, axes=axes, t=spec["t"], k=K_MATCHES
    )
    return CellBuild(
        fn=fn, args=(D,),
        in_shardings=(shardings_for(mesh, P(axes, None)),),
        out_shardings=None,
        static_info={
            "kind": "apss", "model_flops": 2 * spec["n"] ** 2 * spec["m"],
            "n": spec["n"], "m": spec["m"],
        },
    )


def _run_h_ring(D, *, mesh, axes, t, k):
    return apss_horizontal_hierarchical(
        D, t, k, mesh, axes, block_rows=512
    )


def _v_compressed_cell(cfg, mesh) -> CellBuild:
    spec = cfg["20news"]
    D = sds((spec["n"], spec["m"]), _dtype(cfg))
    fn = functools.partial(
        _run_v_compressed, mesh=mesh, t=spec["t"], k=K_MATCHES
    )
    return CellBuild(
        fn=fn, args=(D,),
        in_shardings=(shardings_for(mesh, P(None, "model")),),
        out_shardings=None,
        static_info={
            "kind": "apss", "model_flops": 2 * spec["n"] ** 2 * spec["m"],
            "n": spec["n"], "m": spec["m"],
        },
    )


def _run_v_compressed(D, *, mesh, t, k):
    return apss_vertical(
        D, t, k, mesh, axis_name="model", accumulation="compressed",
        block_rows=512, candidate_capacity=256,
    )


def _2d_cell(cfg, mesh) -> CellBuild:
    spec = cfg["wikipedia"]
    D = sds((spec["n"], spec["m"]), _dtype(cfg))
    fn = functools.partial(
        _run_2d, mesh=mesh, t=spec["t"], k=K_MATCHES,
        block_rows=int(cfg.get("block_rows", 512)),
    )
    return CellBuild(
        fn=fn, args=(D,),
        in_shardings=(shardings_for(mesh, P("data", "model")),),
        out_shardings=None,
        static_info={
            "kind": "apss", "model_flops": 2 * spec["n"] ** 2 * spec["m"],
            "n": spec["n"], "m": spec["m"],
        },
    )


def _run_2d(D, *, mesh, t, k, block_rows=512):
    return apss_2d(
        D, t, k, mesh, row_axis="data", col_axis="model",
        accumulation="compressed", block_rows=block_rows,
        candidate_capacity=256,
    )


ARCH = register(ArchDef(
    name="apss",
    family="apss",
    source="this paper (Özkural & Aykanat 2014)",
    make_config=config,
    make_smoke_config=smoke_config,
    shapes={
        "h_allgather": ShapeCell(
            kind="apss", desc="1-D horizontal Alg.6 (paper-faithful), "
            "wikipedia-scale n=71680 m=1351680 t=0.9",
            build=_h_allgather_cell,
        ),
        "h_ring": ShapeCell(
            kind="apss", desc="1-D horizontal hierarchical ring "
            "(beyond-paper), wikipedia-scale",
            build=_h_ring_cell,
        ),
        "v_compressed": ShapeCell(
            kind="apss", desc="1-D vertical + local pruning (Lemma 1), "
            "20news-scale n=20480 m=315392 t=0.4",
            build=_v_compressed_cell,
        ),
        "grid_2d": ShapeCell(
            kind="apss", desc="2-D checkerboard Alg.7, wikipedia-scale",
            build=_2d_cell,
        ),
    },
))
