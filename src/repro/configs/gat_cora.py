"""gat-cora [gnn] — 2L d_hidden=8 n_heads=8 attention aggregator
[arXiv:1710.10903; paper].

Shapes:
  full_graph_sm   n=2708  e=10556     d_feat=1433  (full-batch, Cora)
  minibatch_lg    n=232965 e=114.6M   batch=1024 fanout 15-10 (sampled)
  ogb_products    n=2449029 e=61.9M   d_feat=100   (full-batch-large)
  molecule        n=30 e=64 batch=128              (batched-small-graphs)

Message passing is segment_sum/segment_max over padded edge lists (JAX has
no CSR SpMM — DESIGN.md §3). Sampled shapes use the real neighbor sampler
(``repro.data.sampler``); the dry-run lowers the statically-shaped padded
batch it emits.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import (
    ArchDef, CellBuild, ShapeCell, data_axes_of, register, sds, sds_like,
    shardings_for,
)
from repro.data.sampler import sampled_shape
from repro.launch.train import make_gat_train_step
from repro.models.gnn import GATConfig, gat_param_specs, init_gat
from repro.optim import adamw_init
from repro.optim.optimizer import AdamWState


def config() -> GATConfig:
    return GATConfig(
        name="gat-cora", n_layers=2, d_hidden=8, n_heads=8,
        d_feat=1433, n_classes=7,
    )


def smoke_config() -> GATConfig:
    return GATConfig(
        name="gat-cora-smoke", n_layers=2, d_hidden=4, n_heads=2,
        d_feat=32, n_classes=5,
    )


def _graph_sds(n_nodes: int, n_edges: int, d_feat: int) -> dict:
    return {
        "features": sds((n_nodes, d_feat), jnp.float32),
        "edge_src": sds((n_edges,), jnp.int32),
        "edge_dst": sds((n_edges,), jnp.int32),
        "edge_mask": sds((n_edges,), jnp.float32),
        "labels": sds((n_nodes,), jnp.int32),
        "label_mask": sds((n_nodes,), jnp.float32),
    }


def _build_graph_cell(
    cfg: GATConfig, mesh, *, n_nodes: int, n_edges: int, d_feat: int,
    shard_edges: bool,
) -> CellBuild:
    cfg = dataclasses.replace(cfg, d_feat=d_feat)
    params = sds_like(jax.eval_shape(lambda k: init_gat(k, cfg), jax.random.key(0)))
    opt = sds_like(jax.eval_shape(adamw_init, params))
    batch = _graph_sds(n_nodes, n_edges, d_feat)
    daxes = data_axes_of(mesh)
    e_ax = daxes if shard_edges else None
    n_ax = daxes if shard_edges else None
    batch_sh = {
        # nodes sharded over data; edges sharded over data; GSPMD inserts
        # the scatter-add partials + all-reduce for cross-shard aggregation.
        "features": shardings_for(mesh, P(n_ax, None)),
        "edge_src": shardings_for(mesh, P(e_ax)),
        "edge_dst": shardings_for(mesh, P(e_ax)),
        "edge_mask": shardings_for(mesh, P(e_ax)),
        "labels": shardings_for(mesh, P(n_ax)),
        "label_mask": shardings_for(mesh, P(n_ax)),
    }
    p_sh = shardings_for(mesh, gat_param_specs(cfg))
    o_sh = AdamWState(
        step=shardings_for(mesh, P()),
        m=shardings_for(mesh, gat_param_specs(cfg)),
        v=shardings_for(mesh, gat_param_specs(cfg)),
    )
    fn = make_gat_train_step(cfg)
    # model flops: per edge per layer ~ attention+message ops; dominated by
    # the dense projections: 2·nnz(W)·n_nodes per layer, ×3 for train.
    l1 = 2 * n_nodes * d_feat * cfg.d_hidden * cfg.n_heads
    l2 = 2 * n_nodes * cfg.d_hidden * cfg.n_heads * cfg.n_classes
    edge_work = 4 * n_edges * cfg.n_heads * cfg.d_hidden
    return CellBuild(
        fn=fn,
        args=(params, opt, batch),
        in_shardings=(p_sh, o_sh, batch_sh),
        out_shardings=(p_sh, o_sh, None),
        static_info={
            "model_flops": 3 * (l1 + l2 + edge_work),
            "kind": "train",
            "n_nodes": n_nodes,
            "n_edges": n_edges,
        },
    )


_SAMPLED_N, _SAMPLED_E = sampled_shape(1024, (15, 10))
_MOL_N, _MOL_E = 30 * 128, 64 * 128 + 30 * 128  # + self loops

ARCH = register(ArchDef(
    name="gat-cora",
    family="gnn",
    source="arXiv:1710.10903",
    make_config=config,
    make_smoke_config=smoke_config,
    shapes={
        "full_graph_sm": ShapeCell(
            kind="train",
            desc="n=2708 e=10556 d_feat=1433 (full-batch); graph replicated "
                 "(Cora is tiny), params/compute sharded",
            build=lambda cfg, mesh: _build_graph_cell(
                cfg, mesh, n_nodes=2708, n_edges=2 * 10556 + 2708,
                d_feat=1433, shard_edges=False,
            ),
        ),
        "minibatch_lg": ShapeCell(
            kind="train",
            desc=f"sampled batch_nodes=1024 fanout 15-10 → padded "
                 f"n={_SAMPLED_N} e={_SAMPLED_E} (real neighbor sampler)",
            build=lambda cfg, mesh: _build_graph_cell(
                cfg, mesh, n_nodes=_SAMPLED_N, n_edges=_SAMPLED_E,
                d_feat=602, shard_edges=True,   # reddit-like d_feat
            ),
        ),
        "ogb_products": ShapeCell(
            kind="train",
            desc="n=2449029 e=61859140 d_feat=100 (full-batch-large), "
                 "nodes+edges sharded over data axes",
            build=lambda cfg, mesh: _build_graph_cell(
                cfg, mesh, n_nodes=2449408,      # padded to /512
                n_edges=61859840, d_feat=100, shard_edges=True,
            ),
        ),
        "molecule": ShapeCell(
            kind="train",
            desc="batch=128 graphs of n=30 e=64 (block-diagonal packing)",
            build=lambda cfg, mesh: _build_graph_cell(
                cfg, mesh, n_nodes=_MOL_N, n_edges=_MOL_E,
                d_feat=30, shard_edges=True,
            ),
        ),
    },
))
