"""Shared cell builders for the LM-family architectures.

Shapes (assigned): train_4k (train), prefill_32k (inference-prefill),
decode_32k (inference-decode: 1 new token, 32k KV cache, batch 128),
long_500k (long-context decode: 1 new token, 524288 KV cache, batch 1).

``long_500k`` note (DESIGN.md §4): decode cost is linear in cache length, so
full attention is exact and affordable — the cache's sequence dim is sharded
over (data, model) and GSPMD inserts the logsumexp-style softmax reductions
(flash-decoding). Nothing is approximated and nothing is skipped.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import (
    ArchDef,
    CellBuild,
    ShapeCell,
    data_axes_of,
    sds,
    sds_like,
    shardings_for,
)
from repro.launch.train import make_lm_train_step
from repro.models.transformer import (
    TransformerConfig,
    cache_specs,
    count_active_params,
    count_params,
    decode_step,
    init_transformer,
    make_cache,
    param_specs,
    prefill,
)
from repro.optim import adamw_init
from repro.optim.optimizer import AdamWState


def _params_sds(cfg):
    return sds_like(
        jax.eval_shape(lambda k: init_transformer(k, cfg), jax.random.key(0))
    )


def _param_shardings(mesh, cfg):
    return shardings_for(mesh, param_specs(cfg))


def _opt_shardings(mesh, cfg):
    ps = param_specs(cfg)
    return AdamWState(
        step=shardings_for(mesh, P()),
        m=shardings_for(mesh, ps),
        v=shardings_for(mesh, ps),
    )


def _lm_static_info(cfg, *, tokens: int, kind: str, cache_len: int = 0) -> dict:
    n_total = count_params(cfg)
    n_active = count_active_params(cfg)
    fwd = 2 * n_active * tokens
    flops = 3 * fwd if kind == "train" else fwd
    # attention score+value FLOPs (not in 6·N·D): 2 matmuls × 2 FLOP/MAC
    s_eff = cache_len if cache_len else 0
    return {
        "params_total": n_total,
        "params_active": n_active,
        "tokens": tokens,
        "model_flops": flops,
        "kind": kind,
    }


def build_train_cell(
    cfg: TransformerConfig, mesh, *, global_batch: int, seq_len: int
) -> CellBuild:
    cfg = dataclasses.replace(cfg, fsdp=True)
    params = _params_sds(cfg)
    opt = sds_like(jax.eval_shape(adamw_init, params))
    batch = {"tokens": sds((global_batch, seq_len), jnp.int32)}
    daxes = data_axes_of(mesh)
    batch_sh = {"tokens": shardings_for(mesh, P(daxes, None))}
    p_sh = _param_shardings(mesh, cfg)
    o_sh = _opt_shardings(mesh, cfg)
    fn = make_lm_train_step(cfg)
    return CellBuild(
        fn=fn,
        args=(params, opt, batch),
        in_shardings=(p_sh, o_sh, batch_sh),
        out_shardings=(p_sh, o_sh, None),
        static_info=_lm_static_info(
            cfg, tokens=global_batch * seq_len, kind="train"
        ),
    )


def build_prefill_cell(
    cfg: TransformerConfig, mesh, *, global_batch: int, seq_len: int
) -> CellBuild:
    cfg = dataclasses.replace(cfg, fsdp=False, remat=False)
    params = _params_sds(cfg)
    tokens = sds((global_batch, seq_len), jnp.int32)
    daxes = data_axes_of(mesh)
    return CellBuild(
        fn=functools.partial(_prefill_fn, cfg),
        args=(params, tokens),
        in_shardings=(_param_shardings(mesh, cfg), shardings_for(mesh, P(daxes, None))),
        out_shardings=None,
        static_info=_lm_static_info(
            cfg, tokens=global_batch * seq_len, kind="prefill"
        ),
    )


def _prefill_fn(cfg, params, tokens):
    return prefill(params, cfg, tokens)


def _decode_fn(cfg, params, cache, tokens):
    return decode_step(params, cfg, cache, tokens)


def build_decode_cell(
    cfg: TransformerConfig, mesh, *, global_batch: int, cache_len: int,
    seq_axes=("model",), batch_axes=("pod", "data"),
) -> CellBuild:
    cfg = dataclasses.replace(cfg, fsdp=False, remat=False)
    params = _params_sds(cfg)
    cache = sds_like(
        jax.eval_shape(lambda: make_cache(cfg, global_batch, cache_len))
    )
    tokens = sds((global_batch,), jnp.int32)
    c_specs = cache_specs(cfg, seq_axes=seq_axes, batch_axes=batch_axes)
    c_sh = shardings_for(mesh, c_specs)
    tok_sh = shardings_for(mesh, P(batch_axes))
    return CellBuild(
        fn=functools.partial(_decode_fn, cfg),
        args=(params, cache, tokens),
        in_shardings=(_param_shardings(mesh, cfg), c_sh, tok_sh),
        out_shardings=(None, c_sh),
        static_info=_lm_static_info(
            cfg, tokens=global_batch, kind="decode", cache_len=cache_len
        ),
    )


def lm_shapes(train_batch=256, train_seq=4096) -> dict:
    return {
        "train_4k": ShapeCell(
            kind="train",
            desc=f"seq_len=4096 global_batch={train_batch} (training)",
            build=lambda cfg, mesh: build_train_cell(
                cfg, mesh, global_batch=train_batch, seq_len=train_seq
            ),
        ),
        "prefill_32k": ShapeCell(
            kind="prefill",
            desc="seq_len=32768 global_batch=32 (inference-prefill)",
            build=lambda cfg, mesh: build_prefill_cell(
                cfg, mesh, global_batch=32, seq_len=32768
            ),
        ),
        "decode_32k": ShapeCell(
            kind="decode",
            desc="KV cache 32768, global_batch=128 (inference-decode)",
            build=lambda cfg, mesh: build_decode_cell(
                cfg, mesh, global_batch=128, cache_len=32768,
                seq_axes=("model",), batch_axes=("pod", "data"),
            ),
        ),
        "long_500k": ShapeCell(
            kind="decode",
            desc="KV cache 524288, global_batch=1 (long-context decode, "
                 "sequence-parallel full attention)",
            build=lambda cfg, mesh: build_decode_cell(
                cfg, mesh, global_batch=1, cache_len=524288,
                seq_axes=("data", "model"), batch_axes=(),
            ),
        ),
    }


def lm_arch(name: str, source: str, make_config, make_smoke_config) -> ArchDef:
    return ArchDef(
        name=name,
        family="lm",
        source=source,
        make_config=make_config,
        make_smoke_config=make_smoke_config,
        shapes=lm_shapes(),
    )
