"""din [recsys] — embed_dim=18 seq_len=100 attn_mlp=80-40 mlp=200-80,
target attention over user history. [arXiv:1706.06978; paper]"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchDef, register
from repro.configs.recsys_common import recsys_shapes
from repro.models import recsys


def config() -> recsys.DINConfig:
    return recsys.DINConfig(
        name="din", embed_dim=18, seq_len=100,
        attn_dims=(80, 40), mlp_dims=(200, 80), n_items=1_000_000,
    )


def smoke_config() -> recsys.DINConfig:
    return recsys.DINConfig(
        name="din-smoke", embed_dim=8, seq_len=12,
        attn_dims=(16, 8), mlp_dims=(32, 16), n_items=500,
    )


def _score(cfg, params, batch):
    return recsys.din_logits(params, cfg, batch)


def _retrieve(cfg, params, batch, candidate_ids):
    """Pointwise CTR scoring of 1M candidates against one user history."""
    n = candidate_ids.shape[0]
    hist = jnp.broadcast_to(batch["history"], (n, cfg.seq_len))
    logits = recsys.din_logits(
        params, cfg, {"history": hist, "item_ids": candidate_ids}
    )
    return jax.lax.top_k(logits, 256)


ARCH = register(ArchDef(
    name="din",
    family="recsys",
    source="arXiv:1706.06978",
    make_config=config,
    make_smoke_config=smoke_config,
    shapes=recsys_shapes(
        "din", recsys.init_din, recsys.din_param_specs, _score, _retrieve,
    ),
))
