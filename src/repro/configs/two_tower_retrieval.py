"""two-tower-retrieval [recsys] — embed_dim=256 tower_mlp=1024-512-256
dot-product interaction, sampled-softmax retrieval. Item table 10M×256,
user-feature table 1M×256 (hashed), both vocab-sharded over `model`.
[RecSys'19 (YouTube/Yi et al.)]"""

from __future__ import annotations

from repro.configs.base import ArchDef, register
from repro.configs.recsys_common import recsys_shapes
from repro.models import recsys


def config() -> recsys.TwoTowerConfig:
    return recsys.TwoTowerConfig(
        name="two-tower-retrieval",
        embed_dim=256,
        tower_dims=(1024, 512, 256),
        n_items=10_000_000,
        n_user_fields=8,
        user_vocab=1_000_000,
        history_len=50,
    )


def smoke_config() -> recsys.TwoTowerConfig:
    return recsys.TwoTowerConfig(
        name="two-tower-smoke",
        embed_dim=16,
        tower_dims=(64, 32, 16),
        n_items=1000,
        n_user_fields=4,
        user_vocab=500,
        history_len=10,
    )


def _score(cfg, params, batch):
    return recsys.two_tower_score(params, cfg, batch)


def _retrieve(cfg, params, batch, candidate_ids):
    return recsys.retrieval_scores(params, cfg, batch, candidate_ids, k=256)


ARCH = register(ArchDef(
    name="two-tower-retrieval",
    family="recsys",
    source="RecSys'19 (Yi et al.)",
    make_config=config,
    make_smoke_config=smoke_config,
    shapes=recsys_shapes(
        "two-tower-retrieval", recsys.init_two_tower,
        recsys.two_tower_param_specs, _score, _retrieve,
    ),
))
