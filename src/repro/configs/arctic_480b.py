"""arctic-480b [moe] — 35L d_model=7168 56H (GQA kv=8) d_ff=4864
vocab=32000, MoE 128 experts top-2 **+ dense residual FFN** (Arctic's
dense-MoE hybrid). [hf:Snowflake/snowflake-arctic-base; hf]"""

import jax.numpy as jnp

from repro.configs.base import register
from repro.configs.lm_common import lm_arch
from repro.models.transformer import TransformerConfig


def config() -> TransformerConfig:
    return TransformerConfig(
        name="arctic-480b",
        n_layers=35,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        head_dim=128,
        d_ff=4864,
        vocab_size=32000,
        moe=True,
        n_experts=128,
        top_k=2,
        d_ff_expert=4864,
        dense_residual=True,
        capacity_factor=1.25,
        rope_theta=1e6,
        dtype=jnp.bfloat16,
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="arctic-480b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        head_dim=8,
        d_ff=96,
        vocab_size=512,
        moe=True,
        n_experts=8,
        top_k=2,
        d_ff_expert=96,
        dense_residual=True,
        capacity_factor=2.0,
        dtype=jnp.float32,
        q_chunk=32, kv_chunk=32, loss_chunk=32,
    )


ARCH = register(
    lm_arch("arctic-480b", "hf:Snowflake/snowflake-arctic-base", config, smoke_config)
)
