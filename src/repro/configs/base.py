"""Config/arch registry plumbing: every assigned architecture registers an
``ArchDef`` whose shape cells build ``(fn, args, in_shardings, out_shardings)``
lowering specs for the dry-run (DESIGN.md §e).

Builders return abstract ``jax.ShapeDtypeStruct`` argument trees — no host
allocation ever happens for the full configs (they are exercised ONLY via
``launch/dryrun.py``); smoke tests use each arch's ``smoke_config``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _filter_spec(mesh: Mesh, spec: P) -> P:
    def keep(part):
        if part is None:
            return None
        if isinstance(part, (tuple, list)):
            kept = tuple(a for a in part if a in mesh.shape)
            return kept if kept else None
        return part if part in mesh.shape else None

    return P(*(keep(part) for part in spec))


def shardings_for(mesh: Mesh, specs):
    """Pytree of PartitionSpec → pytree of NamedSharding (mesh-filtered)."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, _filter_spec(mesh, s)),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def data_axes_of(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def sds_like(tree):
    """eval_shape result → plain ShapeDtypeStruct pytree."""
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


@dataclasses.dataclass(frozen=True)
class CellBuild:
    """Everything the dry-run needs to lower one (arch × shape × mesh)."""

    fn: Callable
    args: tuple                 # ShapeDtypeStruct pytrees
    in_shardings: tuple
    out_shardings: Any          # pytree or None (auto)
    static_info: dict           # model flops etc. for the roofline


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    kind: str                   # train | prefill | decode | serve | retrieval
    desc: str
    build: Callable[[Any, Mesh], CellBuild]   # (full_config, mesh) -> CellBuild


@dataclasses.dataclass(frozen=True)
class ArchDef:
    name: str
    family: str                 # lm | gnn | recsys
    source: str                 # public-literature citation tag
    make_config: Callable[[], Any]
    make_smoke_config: Callable[[], Any]
    shapes: dict

    def cell(self, shape: str) -> ShapeCell:
        return self.shapes[shape]


REGISTRY: dict = {}


def register(arch: ArchDef) -> ArchDef:
    REGISTRY[arch.name] = arch
    return arch


def get_arch(name: str) -> ArchDef:
    if name not in REGISTRY:
        # Import side effects populate the registry lazily.
        import repro.configs  # noqa: F401
    return REGISTRY[name]


def all_arch_names() -> list:
    import repro.configs  # noqa: F401

    return sorted(REGISTRY)
