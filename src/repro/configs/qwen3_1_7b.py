"""qwen3-1.7b [dense] — 28L d_model=2048 16H (GQA kv=8) d_ff=6144
vocab=151936, qk_norm. [hf:Qwen/Qwen3-8B family; hf]"""

import jax.numpy as jnp

from repro.configs.base import register
from repro.configs.lm_common import lm_arch
from repro.models.transformer import TransformerConfig


def config() -> TransformerConfig:
    return TransformerConfig(
        name="qwen3-1.7b",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        head_dim=128,
        d_ff=6144,
        vocab_size=151936,
        qk_norm=True,
        rope_theta=1e6,
        dtype=jnp.bfloat16,
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="qwen3-1.7b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        qk_norm=True,
        dtype=jnp.float32,
        q_chunk=32, kv_chunk=32, loss_chunk=32,
    )


ARCH = register(lm_arch("qwen3-1.7b", "hf:Qwen/Qwen3-1.7B", config, smoke_config))
