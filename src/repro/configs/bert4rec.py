"""bert4rec [recsys] — embed_dim=64 n_blocks=2 n_heads=2 seq_len=200,
bidirectional masked sequence model. [arXiv:1904.06690; paper]"""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ArchDef, register
from repro.configs.recsys_common import recsys_shapes
from repro.core.apss import similarity_topk
from repro.models import recsys


def config() -> recsys.Bert4RecConfig:
    return recsys.Bert4RecConfig(
        name="bert4rec", embed_dim=64, n_blocks=2, n_heads=2,
        seq_len=200, n_items=60_000, d_ff=256,
    )


def smoke_config() -> recsys.Bert4RecConfig:
    return recsys.Bert4RecConfig(
        name="bert4rec-smoke", embed_dim=16, n_blocks=1, n_heads=2,
        seq_len=16, n_items=500, d_ff=32,
    )


def _score(cfg, params, batch):
    return recsys.bert4rec_score(params, cfg, batch)


def _retrieve(cfg, params, batch, candidate_ids):
    """Next-item retrieval: encode the session, APSS-score vs candidates."""
    h = recsys.bert4rec_encode(params, cfg, batch["item_ids"])[:, -1]  # (1, d)
    cand = jnp.take(params["item_table"], candidate_ids, axis=0)       # (N, d)
    return similarity_topk(
        h, cand, threshold=0.0, k=256, block_rows=h.shape[0],
        exclude_self=False,
    )


ARCH = register(ArchDef(
    name="bert4rec",
    family="recsys",
    source="arXiv:1904.06690",
    make_config=config,
    make_smoke_config=smoke_config,
    shapes=recsys_shapes(
        "bert4rec", recsys.init_bert4rec, recsys.bert4rec_param_specs,
        _score, _retrieve,
    ),
))
