"""minicpm3-4b [dense] — 62L d_model=2560 40H (MLA) d_ff=6400 vocab=73448.
MLA dims follow the released model: q_lora 768, kv_lora 256, nope 64,
rope 32, v 64. [hf:openbmb/MiniCPM3-4B; hf]"""

import jax.numpy as jnp

from repro.configs.base import register
from repro.configs.lm_common import lm_arch
from repro.models.transformer import TransformerConfig


def config() -> TransformerConfig:
    return TransformerConfig(
        name="minicpm3-4b",
        n_layers=62,
        d_model=2560,
        n_heads=40,
        n_kv_heads=40,
        head_dim=64,
        d_ff=6400,
        vocab_size=73448,
        attention="mla",
        q_lora_rank=768,
        kv_lora_rank=256,
        qk_nope_dim=64,
        qk_rope_dim=32,
        v_head_dim=64,
        rope_theta=1e6,
        dtype=jnp.bfloat16,
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="minicpm3-4b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        attention="mla",
        q_lora_rank=32,
        kv_lora_rank=16,
        qk_nope_dim=16,
        qk_rope_dim=8,
        v_head_dim=16,
        dtype=jnp.float32,
        q_chunk=32, kv_chunk=32, loss_chunk=32,
    )


ARCH = register(lm_arch("minicpm3-4b", "hf:openbmb/MiniCPM3-4B", config, smoke_config))
