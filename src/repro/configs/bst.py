"""bst [recsys] — embed_dim=32 seq_len=20 n_blocks=1 n_heads=8
mlp=1024-512-256, Behavior Sequence Transformer (Alibaba).
[arXiv:1905.06874; paper]"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchDef, register
from repro.configs.recsys_common import recsys_shapes
from repro.models import recsys


def config() -> recsys.BSTConfig:
    return recsys.BSTConfig(
        name="bst", embed_dim=32, seq_len=20, n_blocks=1, n_heads=8,
        mlp_dims=(1024, 512, 256), n_items=1_000_000, d_ff=128,
    )


def smoke_config() -> recsys.BSTConfig:
    return recsys.BSTConfig(
        name="bst-smoke", embed_dim=16, seq_len=8, n_blocks=1, n_heads=2,
        mlp_dims=(32, 16, 8), n_items=500, d_ff=32,
    )


def _score(cfg, params, batch):
    return recsys.bst_logits(params, cfg, batch)


def _retrieve(cfg, params, batch, candidate_ids):
    """Pointwise CTR scoring of 1M candidates against one user history."""
    n = candidate_ids.shape[0]
    hist = jnp.broadcast_to(batch["history"], (n, cfg.seq_len - 1))
    logits = recsys.bst_logits(
        params, cfg, {"history": hist, "item_ids": candidate_ids}
    )
    return jax.lax.top_k(logits, 256)


ARCH = register(ArchDef(
    name="bst",
    family="recsys",
    source="arXiv:1905.06874",
    make_config=config,
    make_smoke_config=smoke_config,
    shapes=recsys_shapes(
        "bst", recsys.init_bst, recsys.bst_param_specs, _score, _retrieve,
    ),
))
