"""deepseek-moe-16b [moe] — 28L d_model=2048 16H (GQA kv=16, i.e. MHA)
d_ff=1408(dense ffn 10944 in layer 0... fine-grained: d_ff_expert=1408),
vocab=102400, MoE: 2 shared + 64 routed top-6, layer 0 dense.
[arXiv:2401.06066; hf]"""

import jax.numpy as jnp

from repro.configs.base import register
from repro.configs.lm_common import lm_arch
from repro.models.transformer import TransformerConfig


def config() -> TransformerConfig:
    return TransformerConfig(
        name="deepseek-moe-16b",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=1408 * 8,          # layer-0 dense FFN (10944≈8 experts wide)
        vocab_size=102400,
        moe=True,
        n_experts=64,
        top_k=6,
        d_ff_expert=1408,
        n_shared_experts=2,
        first_k_dense=1,
        capacity_factor=1.25,
        rope_theta=1e4,
        dtype=jnp.bfloat16,
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="deepseek-moe-16b-smoke",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        moe=True,
        n_experts=8,
        top_k=3,
        d_ff_expert=32,
        n_shared_experts=2,
        first_k_dense=1,
        capacity_factor=2.0,
        dtype=jnp.float32,
        q_chunk=32, kv_chunk=32, loss_chunk=32,
    )


ARCH = register(
    lm_arch("deepseek-moe-16b", "arXiv:2401.06066", config, smoke_config)
)
