"""Architecture registry: importing this package registers every assigned
architecture (+ the paper's own APSS workload) into ``configs.base.REGISTRY``.
"""

from repro.configs.base import (  # noqa: F401
    REGISTRY,
    ArchDef,
    CellBuild,
    ShapeCell,
    all_arch_names,
    get_arch,
)

# Assigned architectures (10) — importing registers them.
from repro.configs import (  # noqa: F401
    qwen3_1_7b,
    minicpm3_4b,
    qwen3_8b,
    arctic_480b,
    deepseek_moe_16b,
    gat_cora,
    two_tower_retrieval,
    bert4rec,
    din,
    bst,
    apss_paper,
)

ASSIGNED = [
    "qwen3-1.7b",
    "minicpm3-4b",
    "qwen3-8b",
    "arctic-480b",
    "deepseek-moe-16b",
    "gat-cora",
    "two-tower-retrieval",
    "bert4rec",
    "din",
    "bst",
]
