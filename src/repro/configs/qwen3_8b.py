"""qwen3-8b [dense] — 36L d_model=4096 32H (GQA kv=8) d_ff=12288
vocab=151936, qk_norm. [hf:Qwen/Qwen3-8B; hf]"""

import jax.numpy as jnp

from repro.configs.base import register
from repro.configs.lm_common import lm_arch
from repro.models.transformer import TransformerConfig


def config() -> TransformerConfig:
    return TransformerConfig(
        name="qwen3-8b",
        n_layers=36,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=12288,
        vocab_size=151936,
        qk_norm=True,
        rope_theta=1e6,
        dtype=jnp.bfloat16,
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="qwen3-8b-smoke",
        n_layers=2,
        d_model=96,
        n_heads=6,
        n_kv_heads=2,
        head_dim=16,
        d_ff=192,
        vocab_size=512,
        qk_norm=True,
        dtype=jnp.float32,
        q_chunk=32, kv_chunk=32, loss_chunk=32,
    )


ARCH = register(lm_arch("qwen3-8b", "hf:Qwen/Qwen3-8B", config, smoke_config))
