"""Shared cell builders for the recsys architectures.

Shapes (assigned): train_batch (batch=65536 training), serve_p99 (batch=512
online), serve_bulk (batch=262144 offline scoring), retrieval_cand (batch=1
query × 1,000,000 candidates).

``retrieval_cand`` routes through the APSS core (``similarity_topk`` — the
paper's algorithm IS retrieval scoring); candidates shard over the data axes
exactly like the horizontal distribution's corpus rows.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import (
    CellBuild, ShapeCell, data_axes_of, sds, sds_like, shardings_for,
)
from repro.launch.train import make_recsys_train_step
from repro.models import recsys
from repro.optim import adamw_init
from repro.optim.optimizer import AdamWState

N_CANDIDATES = 1_000_000


def _params_and_opt(init_fn, cfg, mesh, spec_fn):
    params = sds_like(jax.eval_shape(lambda k: init_fn(k, cfg), jax.random.key(0)))
    opt = sds_like(jax.eval_shape(adamw_init, params))
    specs = spec_fn(cfg)
    p_sh = shardings_for(mesh, specs)
    o_sh = AdamWState(
        step=shardings_for(mesh, P()),
        m=shardings_for(mesh, specs),
        v=shardings_for(mesh, specs),
    )
    return params, opt, p_sh, o_sh


def _batch_sds(cfg, batch: int, kind: str) -> dict:
    if isinstance(cfg, recsys.TwoTowerConfig):
        b = {
            "user_fields": sds((batch, cfg.n_user_fields), jnp.int32),
            "history": sds((batch, cfg.history_len), jnp.int32),
            "item_ids": sds((batch,), jnp.int32),
        }
    elif isinstance(cfg, recsys.Bert4RecConfig):
        b = {"item_ids": sds((batch, cfg.seq_len), jnp.int32)}
        if kind == "train":
            b["labels"] = sds((batch, cfg.seq_len), jnp.int32)
            b["mask"] = sds((batch, cfg.seq_len), jnp.bool_)
    elif isinstance(cfg, recsys.DINConfig):
        b = {
            "history": sds((batch, cfg.seq_len), jnp.int32),
            "item_ids": sds((batch,), jnp.int32),
        }
        if kind == "train":
            b["click"] = sds((batch,), jnp.int32)
    else:  # BST
        b = {
            "history": sds((batch, cfg.seq_len - 1), jnp.int32),
            "item_ids": sds((batch,), jnp.int32),
        }
        if kind == "train":
            b["click"] = sds((batch,), jnp.int32)
    return b


def _batch_shardings(mesh, batch_sds):
    daxes = data_axes_of(mesh)
    return jax.tree.map(
        lambda s: shardings_for(mesh, P(daxes, *([None] * (len(s.shape) - 1)))),
        batch_sds,
    )


def _flops_per_example(cfg) -> int:
    """Dense-layer MAC count ×2 (embedding lookups are bandwidth, not FLOPs)."""
    if isinstance(cfg, recsys.TwoTowerConfig):
        d_in_u = cfg.embed_dim * (cfg.n_user_fields + 1)
        dims_u = (d_in_u, *cfg.tower_dims)
        dims_i = (cfg.embed_dim, *cfg.tower_dims)
        f = sum(a * b for a, b in zip(dims_u[:-1], dims_u[1:]))
        f += sum(a * b for a, b in zip(dims_i[:-1], dims_i[1:]))
        return 2 * f
    if isinstance(cfg, recsys.Bert4RecConfig):
        d = cfg.embed_dim
        per_tok = 4 * d * d + 2 * d * cfg.d_ff
        attn = 2 * cfg.seq_len * d
        return 2 * cfg.n_blocks * cfg.seq_len * (per_tok + attn)
    if isinstance(cfg, recsys.DINConfig):
        e = cfg.embed_dim
        attn = cfg.seq_len * (4 * e * 80 + 80 * 40 + 40)
        head = 2 * e * 200 + 200 * 80 + 80
        return 2 * (attn + head)
    cfg_b: recsys.BSTConfig = cfg
    e = cfg_b.embed_dim
    s = cfg_b.seq_len
    blk = s * (4 * e * e + 2 * e * cfg_b.d_ff) + s * s * e * 2
    m_dims = (s * e, *cfg_b.mlp_dims, 1)
    head = sum(a * b for a, b in zip(m_dims[:-1], m_dims[1:]))
    return 2 * (cfg_b.n_blocks * blk + head)


def _build_train(cfg, mesh, init_fn, spec_fn, batch: int) -> CellBuild:
    params, opt, p_sh, o_sh = _params_and_opt(init_fn, cfg, mesh, spec_fn)
    bs = _batch_sds(cfg, batch, "train")
    fn = make_recsys_train_step(cfg)
    return CellBuild(
        fn=fn,
        args=(params, opt, bs),
        in_shardings=(p_sh, o_sh, _batch_shardings(mesh, bs)),
        out_shardings=(p_sh, o_sh, None),
        static_info={
            "kind": "train",
            "model_flops": 3 * batch * _flops_per_example(cfg),
            "batch": batch,
        },
    )


def _build_serve(cfg, mesh, init_fn, spec_fn, score_fn, batch: int) -> CellBuild:
    params, _, p_sh, _ = _params_and_opt(init_fn, cfg, mesh, spec_fn)
    bs = _batch_sds(cfg, batch, "serve")
    return CellBuild(
        fn=functools.partial(score_fn, cfg),
        args=(params, bs),
        in_shardings=(p_sh, _batch_shardings(mesh, bs)),
        out_shardings=None,
        static_info={
            "kind": "serve",
            "model_flops": batch * _flops_per_example(cfg),
            "batch": batch,
        },
    )


def _build_retrieval(cfg, mesh, init_fn, spec_fn, retrieval_fn) -> CellBuild:
    params, _, p_sh, _ = _params_and_opt(init_fn, cfg, mesh, spec_fn)
    bs = _batch_sds(cfg, 1, "serve")
    daxes = data_axes_of(mesh)
    cand = sds((N_CANDIDATES,), jnp.int32)
    cand_sh = shardings_for(mesh, P(daxes))
    # The single query replicates; only the 1M-candidate corpus shards
    # (the paper's horizontal distribution of the similarity join corpus).
    q_sh = jax.tree.map(lambda s: shardings_for(mesh, P()), bs)
    return CellBuild(
        fn=functools.partial(retrieval_fn, cfg),
        args=(params, bs, cand),
        in_shardings=(p_sh, q_sh, cand_sh),
        out_shardings=None,
        static_info={
            "kind": "retrieval",
            "model_flops": _flops_per_example(cfg)
            + 2 * N_CANDIDATES * getattr(cfg, "embed_dim", 64),
            "batch": N_CANDIDATES,
        },
    )


def recsys_shapes(arch, init_fn, spec_fn, score_fn, retrieval_fn) -> dict:
    return {
        "train_batch": ShapeCell(
            kind="train", desc="batch=65536 (training)",
            build=lambda cfg, mesh: _build_train(cfg, mesh, init_fn, spec_fn, 65536),
        ),
        "serve_p99": ShapeCell(
            kind="serve", desc="batch=512 (online-inference)",
            build=lambda cfg, mesh: _build_serve(
                cfg, mesh, init_fn, spec_fn, score_fn, 512
            ),
        ),
        "serve_bulk": ShapeCell(
            kind="serve", desc="batch=262144 (offline-scoring)",
            build=lambda cfg, mesh: _build_serve(
                cfg, mesh, init_fn, spec_fn, score_fn, 262144
            ),
        ),
        "retrieval_cand": ShapeCell(
            kind="retrieval",
            desc="batch=1 n_candidates=1,000,000 (APSS-backed retrieval)",
            build=lambda cfg, mesh: _build_retrieval(
                cfg, mesh, init_fn, spec_fn, retrieval_fn
            ),
        ),
    }
