"""Execution tracing: a context-propagated span tree with zero idle cost.

The planner's :class:`~repro.planner.telemetry.ApssStats` records are
trace-time *models* — static shapes, modeled FLOPs, zero wall-clock (except
the :class:`~repro.distributed.straggler.StepTicker`). This module adds the
measured half: a :class:`Tracer` collects a tree of :class:`Span` objects
(monotonic ``perf_counter`` wall-clock, nesting, per-span attributes) from
every instrumented execution path — ``plan_apss``/``execute``, the
distributed ring sweeps (whose per-step times arrive through the existing
``StepTicker``/``jax.debug.callback`` seam and are adapted into
``ring_step`` child spans at finalize), the serving request lifecycle
(admit → batch → score → merge, with shed/degrade/retry events), the
mutable-index WAL ops, and checkpoint save/restore.

Guard discipline mirrors ``telemetry.enabled()``: with no active
:class:`Tracer`, :func:`span` returns one shared no-op context manager and
:func:`event`/:func:`annotate` return immediately — instrumented hot paths
allocate nothing, plant no callbacks, and add zero device work
(``tests/test_obs.py`` asserts this with ``TRACE_COUNTS`` and jaxprs).

Entering a :class:`Tracer` also enters a private ``telemetry.CommLog``:
tracing alone is enough to turn on the record/ticker seams, and every
``ApssStats`` emitted during a span is pinned to it (the join key
``drift.py`` uses for predicted-vs-measured residuals).
"""

from __future__ import annotations

import threading
import time
from typing import Iterator, Optional

from repro.obs import recorder as _recorder
from repro.planner import telemetry


class Span:
    """One timed node of the trace tree (times on the ``perf_counter``
    timeline of the owning :class:`Tracer`)."""

    __slots__ = (
        "name", "attrs", "t0", "t1", "parent", "children", "events",
        "status", "error", "records",
    )

    def __init__(self, name: str, attrs: dict, t0: float,
                 parent: Optional["Span"] = None):
        self.name = name
        self.attrs = dict(attrs)
        self.t0 = t0
        self.t1: Optional[float] = None
        self.parent = parent
        self.children: list[Span] = []
        # point events: (t, name, attrs)
        self.events: list[tuple[float, str, dict]] = []
        self.status = "ok"
        self.error: Optional[str] = None
        # ApssStats emitted while this span was current (telemetry hook)
        self.records: list = []

    @property
    def duration_s(self) -> float:
        end = self.t1 if self.t1 is not None else self.t0
        return max(0.0, end - self.t0)

    def walk(self) -> Iterator["Span"]:
        yield self
        for c in self.children:
            yield from c.walk()

    def as_dict(self) -> dict:
        return {
            "name": self.name,
            "t0": self.t0,
            "t1": self.t1,
            "duration_s": self.duration_s,
            "status": self.status,
            **({"error": self.error} if self.error else {}),
            "attrs": self.attrs,
            "events": [
                {"t": t, "name": n, "attrs": a} for t, n, a in self.events
            ],
            "children": [c.as_dict() for c in self.children],
        }


class Tracer:
    """Collects a span tree; context manager, stacked like ``CommLog``.

    ::

        with Tracer() as tr:
            with span("plan"):
                plan = plan_apss(D, 0.5, 32, mesh)
            with span("execute", config=plan.config.name):
                out = plan.run(D)
        export.write_chrome_trace("trace.json", tr)

    Entering also enters a private ``CommLog`` so the distributed sweeps
    create their ``StepTicker`` and emit ``ApssStats`` records; on exit
    (:meth:`finalize`) each recorded ticker is adapted into ``ring_step``
    child spans of the span that was current when its record fired, and
    per-step skew is observed into the active metrics registry (if any).
    """

    def __init__(self, *, clock=time.perf_counter):
        self.clock = clock
        self.root = Span("trace", {}, clock())
        self._open: list[Span] = [self.root]
        self._lock = threading.Lock()
        self._log: Optional[telemetry.CommLog] = None
        self.finalized = False

    # -- context ------------------------------------------------------------

    def __enter__(self) -> "Tracer":
        _STACK.append(self)
        self._log = telemetry.CommLog()
        self._log.__enter__()
        telemetry.add_record_hook(self._on_record)
        return self

    def __exit__(self, *exc) -> None:
        telemetry.remove_record_hook(self._on_record)
        if self._log is not None:
            self._log.__exit__(*exc)
            self._log = None
        if _STACK and _STACK[-1] is self:
            _STACK.pop()
        else:  # defensive: never leave a dead tracer active
            if self in _STACK:
                _STACK.remove(self)
        self.finalize()

    @property
    def log(self) -> Optional[telemetry.CommLog]:
        return self._log

    # -- span lifecycle ------------------------------------------------------

    def start(self, name: str, attrs: dict) -> Span:
        with self._lock:
            parent = self._open[-1]
            s = Span(name, attrs, self.clock(), parent)
            parent.children.append(s)
            self._open.append(s)
        return s

    def end(self, s: Span, *, error: Optional[str] = None) -> None:
        with self._lock:
            s.t1 = self.clock()
            if error is not None:
                s.status = "error"
                s.error = error
            # pop to (and including) s — tolerates a child left open by an
            # exception that skipped its __exit__
            while len(self._open) > 1:
                top = self._open.pop()
                if top.t1 is None:
                    top.t1 = s.t1
                if top is s:
                    break
        if _recorder.enabled():
            _recorder.note(
                "span", s.name, duration_s=s.duration_s, status=s.status,
                **s.attrs,
            )

    def current(self) -> Span:
        return self._open[-1]

    def add_event(self, name: str, attrs: dict) -> None:
        with self._lock:
            self._open[-1].events.append((self.clock(), name, attrs))

    # -- telemetry join ------------------------------------------------------

    def _on_record(self, stats) -> None:
        with self._lock:
            self._open[-1].records.append(stats)

    def finalize(self) -> None:
        """Close the root and adapt recorded StepTickers into ``ring_step``
        child spans (safe only after execution: blocks on the effects
        barrier so every ``jax.debug.callback`` tick has landed)."""
        if self.finalized:
            return
        self.finalized = True
        if self.root.t1 is None:
            self.root.t1 = self.clock()
        from repro.obs import metrics as _metrics
        for sp in list(self.root.walk()):
            for stats in sp.records:
                ticker = getattr(stats, "step_ticker", None)
                if ticker is None:
                    continue
                self._materialize_ring_steps(sp, stats, ticker, _metrics)

    def _materialize_ring_steps(self, sp: Span, stats, ticker, _metrics):
        by_step: dict[int, dict[int, float]] = {}
        for rank, step, t in ticker.tick_log():
            per = by_step.setdefault(step, {})
            per[rank] = max(t, per.get(rank, -1.0))
        prev = ticker.created
        for step in sorted(by_step):
            per = by_step[step]
            end = max(per.values())
            skew = (max(per.values()) - min(per.values())) if len(per) > 1 else 0.0
            child = Span(
                "ring_step",
                {"i": step, "variant": stats.variant, "skew_s": skew,
                 "ranks": len(per)},
                prev, sp,
            )
            child.t1 = end
            sp.children.append(child)
            if _metrics.enabled():
                _metrics.observe("sweep.step_time_s", end - prev)
                _metrics.observe("sweep.step_skew_s", skew)
            prev = end

    def walk(self) -> Iterator[Span]:
        return self.root.walk()

    def as_dict(self) -> dict:
        return self.root.as_dict()


_STACK: list[Tracer] = []


def enabled() -> bool:
    """True iff a :class:`Tracer` is active (instrumentation guard)."""
    return bool(_STACK)


def active() -> Optional[Tracer]:
    return _STACK[-1] if _STACK else None


class _NullSpanCtx:
    """Shared no-op: the disabled-path ``span()`` result. One instance for
    the whole process — the hot-path cost of disabled tracing is a list
    truthiness check plus returning this singleton."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpanCtx()


class _SpanCtx:
    __slots__ = ("_name", "_attrs", "_span")

    def __init__(self, name: str, attrs: dict):
        self._name = name
        self._attrs = attrs
        self._span: Optional[Span] = None

    def __enter__(self) -> Optional[Span]:
        t = active()
        if t is None:
            return None
        self._span = t.start(self._name, self._attrs)
        return self._span

    def __exit__(self, exc_type, exc, tb) -> bool:
        t = active()
        if t is not None and self._span is not None:
            err = None if exc is None else repr(exc)
            t.end(self._span, error=err)
        return False


def span(name: str, **attrs):
    """Open a child span of the current span (no-op when tracing is off)."""
    if not _STACK:
        return NULL_SPAN
    return _SpanCtx(name, attrs)


def event(name: str, **attrs) -> None:
    """Record a point event on the current span (and into any active flight
    recorder). No-op when neither sink is active."""
    t = active()
    if t is not None:
        t.add_event(name, attrs)
    if _recorder.enabled():
        _recorder.note("event", name, **attrs)


def annotate(**attrs) -> None:
    """Merge attributes into the current span (no-op when tracing is off)."""
    t = active()
    if t is not None:
        t.current().attrs.update(attrs)
