"""Observability layer: tracing, metrics, flight recording, drift detection.

Five small modules, one guard discipline (``enabled()`` stacks, mirrored
from ``planner.telemetry`` — zero cost when no sink is active):

- :mod:`repro.obs.trace` — context-propagated span tree over every
  execution path (plan/execute, ring steps via ``StepTicker``, serving
  lifecycle, mutable WAL ops, checkpoints);
- :mod:`repro.obs.metrics` — counters/gauges/exponential histograms,
  absorbing the ``telemetry.incr`` namespace;
- :mod:`repro.obs.export` — Chrome trace-event JSON (Perfetto) + metrics
  snapshots;
- :mod:`repro.obs.recorder` — bounded flight recorder auto-dumped on
  fault firing, tier-down, and corruption fallback;
- :mod:`repro.obs.drift` — predicted-vs-measured residuals and stale-
  calibration flagging;
- :mod:`repro.obs.compile` — retrace registry (``CompileMonitor``),
  ``assert_no_retrace`` budget contracts, AOT lower/compile spans with
  ``memory_analysis()`` bytes;
- :mod:`repro.obs.audit` — model-vs-HLO audit over every plannable
  variant family (lazy import: it pulls in the planner and serving
  layers, which the runtime hot paths must not).

See DESIGN.md §10 for the span taxonomy and metrics catalog, §11 for the
compile-time half (retrace contracts, audit ratio semantics).
"""

from repro.obs import (  # noqa: F401,A004
    compile,
    drift,
    export,
    metrics,
    recorder,
    trace,
)
from repro.obs.compile import (  # noqa: F401
    CompileMonitor,
    CompileRecord,
    RetraceError,
    assert_no_retrace,
)
from repro.obs.drift import DriftReport, Residual, drift_report  # noqa: F401
from repro.obs.export import write_chrome_trace, write_metrics  # noqa: F401
from repro.obs.metrics import Histogram, MetricsRegistry  # noqa: F401
from repro.obs.recorder import FlightRecorder  # noqa: F401
from repro.obs.trace import Span, Tracer, annotate, event, span  # noqa: F401

__all__ = [
    "trace", "metrics", "export", "recorder", "drift", "compile", "audit",
    "Tracer", "Span", "span", "event", "annotate",
    "MetricsRegistry", "Histogram",
    "FlightRecorder",
    "CompileMonitor", "CompileRecord", "RetraceError", "assert_no_retrace",
    "DriftReport", "Residual", "drift_report",
    "write_chrome_trace", "write_metrics",
]


def __getattr__(name):
    if name == "audit":
        import importlib

        return importlib.import_module("repro.obs.audit")
    raise AttributeError(f"module 'repro.obs' has no attribute {name!r}")
