"""Process-local metrics registry: counters, gauges, exponential histograms.

The registry absorbs the existing ``telemetry.incr`` counter namespace: on
``__enter__`` it subscribes to the telemetry counter hook, so every
``serving.shed`` / ``sweep.checkpoints`` / ``mutable.replayed_ops``
increment lands in both the active :class:`~repro.planner.telemetry.CommLog`
and here — the two views never diverge. On top of counters it adds gauges
and exponential-bucket histograms for the measured distributions the
CommLog cannot hold: serving latency (p50/p95/p99), batch occupancy, cache
hit rate, live-tile fraction, per-ring-step time and skew.

Histogram design: bucket ``i`` covers ``(base**(i-1), base**i]`` with
``base = 2**0.25`` (≈ 19 % wide), so any quantile read off the geometric
bucket midpoint is within ~9 % relative error of the true sample quantile
— asserted against numpy in ``tests/test_obs.py``. Non-positive samples
land in a dedicated zero bucket (latencies and fractions are ≥ 0).

Snapshots: :meth:`MetricsRegistry.snapshot` (JSON-ready dict) and
:meth:`MetricsRegistry.to_prometheus` (text exposition format; histograms
as quantile summaries). Module-level :func:`incr`/:func:`observe`/
:func:`gauge` no-op when no registry is active — same guard discipline as
``telemetry``.
"""

from __future__ import annotations

import math
from typing import Optional

from repro.planner import telemetry

_DEFAULT_BASE = 2.0 ** 0.25


class Histogram:
    """Exponential-bucket histogram over positive samples."""

    __slots__ = ("base", "_log_base", "buckets", "zeros", "count", "total",
                 "min", "max")

    def __init__(self, base: float = _DEFAULT_BASE):
        if base <= 1.0:
            raise ValueError("histogram base must be > 1")
        self.base = base
        self._log_base = math.log(base)
        self.buckets: dict[int, int] = {}
        self.zeros = 0            # samples ≤ 0
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, value: float) -> None:
        v = float(value)
        self.count += 1
        self.total += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        if v <= 0.0:
            self.zeros += 1
            return
        # bucket i covers (base**(i-1), base**i]
        i = math.ceil(math.log(v) / self._log_base - 1e-9)
        self.buckets[i] = self.buckets.get(i, 0) + 1

    def quantile(self, q: float) -> float:
        """Sample quantile from the bucket CDF (geometric bucket midpoint,
        clamped to the observed [min, max])."""
        if self.count == 0:
            return math.nan
        q = min(1.0, max(0.0, q))
        target = q * (self.count - 1) + 1  # 1-indexed rank, linear in q
        cum = self.zeros
        if cum >= target:
            return max(self.min, 0.0) if self.zeros < self.count else self.min
        for i in sorted(self.buckets):
            cum += self.buckets[i]
            if cum >= target:
                mid = self.base ** (i - 0.5)
                return min(self.max, max(self.min, mid))
        return self.max

    def merge(self, other: "Histogram") -> "Histogram":
        """Fold ``other``'s samples into this histogram, in place.

        Bucket-wise exact (same-``base`` histograms partition the axis
        identically, so merged quantiles equal the quantiles of the
        concatenated sample streams up to the usual bucket-midpoint
        error). Used to aggregate per-CI-matrix-cell metrics artifacts.
        """
        if abs(other.base - self.base) > 1e-12:
            raise ValueError(
                f"cannot merge histograms with different bases "
                f"({self.base} vs {other.base})"
            )
        for i, c in other.buckets.items():
            self.buckets[i] = self.buckets.get(i, 0) + c
        self.zeros += other.zeros
        self.count += other.count
        self.total += other.total
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)
        return self

    def snapshot(self) -> dict:
        if self.count == 0:
            return {"count": 0}
        return {
            "count": self.count,
            "sum": self.total,
            "mean": self.total / self.count,
            "min": self.min,
            "max": self.max,
            "p50": self.quantile(0.50),
            "p90": self.quantile(0.90),
            "p95": self.quantile(0.95),
            "p99": self.quantile(0.99),
        }


class MetricsRegistry:
    """Stacked context manager holding counters/gauges/histograms.

    ::

        with MetricsRegistry() as reg:
            server.serve(queries)           # telemetry.incr -> reg.counters
            metrics.observe("serving.latency_s", dt)
        print(reg.snapshot()["histograms"]["serving.latency_s"]["p99"])
    """

    def __init__(self, *, base: float = _DEFAULT_BASE):
        self._base = base
        self.counters: dict[str, float] = {}
        self.gauges: dict[str, float] = {}
        self.histograms: dict[str, Histogram] = {}

    # -- context -------------------------------------------------------------

    def __enter__(self) -> "MetricsRegistry":
        _STACK.append(self)
        telemetry.add_counter_hook(self._on_incr)
        return self

    def __exit__(self, *exc) -> None:
        telemetry.remove_counter_hook(self._on_incr)
        if _STACK and _STACK[-1] is self:
            _STACK.pop()
        elif self in _STACK:
            _STACK.remove(self)

    def _on_incr(self, name: str, n: float) -> None:
        self.counters[name] = self.counters.get(name, 0) + n

    # -- instruments ---------------------------------------------------------

    def incr(self, name: str, n: float = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + n

    def gauge(self, name: str, value: float) -> None:
        self.gauges[name] = float(value)

    def observe(self, name: str, value: float) -> None:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(self._base)
        h.observe(value)

    def histogram(self, name: str) -> Optional[Histogram]:
        return self.histograms.get(name)

    # -- aggregation ---------------------------------------------------------

    def merge(self, other: "MetricsRegistry") -> "MetricsRegistry":
        """Fold ``other`` into this registry, in place: counters add,
        histograms bucket-merge, gauges take ``other``'s value when both
        set one (last-writer-wins — gauges are point-in-time readings,
        not accumulable). Aggregates per-shard / per-CI-matrix-cell
        metrics artifacts into one fleet view."""
        for name, v in other.counters.items():
            self.counters[name] = self.counters.get(name, 0) + v
        self.gauges.update(other.gauges)
        for name, h in other.histograms.items():
            mine = self.histograms.get(name)
            if mine is None:
                mine = self.histograms[name] = Histogram(h.base)
            mine.merge(h)
        return self

    # -- exposition ----------------------------------------------------------

    def snapshot(self) -> dict:
        """JSON-ready snapshot: counters, gauges, histogram summaries, and
        derived ratios (cache hit rate) when their inputs are present."""
        out = {
            "counters": dict(sorted(self.counters.items())),
            "gauges": dict(sorted(self.gauges.items())),
            "histograms": {
                k: h.snapshot() for k, h in sorted(self.histograms.items())
            },
        }
        hits = self.counters.get("serving.cache_hits")
        reqs = self.counters.get("serving.requests")
        if hits is not None and reqs:
            out["derived"] = {"serving.cache_hit_rate": hits / reqs}
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition (counters as ``_total``, histograms
        as quantile summaries)."""
        lines: list[str] = []
        for name, v in sorted(self.counters.items()):
            mn = _prom_name(name) + "_total"
            lines.append(f"# TYPE {mn} counter")
            lines.append(f"{mn} {_prom_num(v)}")
        for name, v in sorted(self.gauges.items()):
            mn = _prom_name(name)
            lines.append(f"# TYPE {mn} gauge")
            lines.append(f"{mn} {_prom_num(v)}")
        for name, h in sorted(self.histograms.items()):
            mn = _prom_name(name)
            lines.append(f"# TYPE {mn} summary")
            for q in (0.5, 0.9, 0.95, 0.99):
                lines.append(
                    f'{mn}{{quantile="{q}"}} {_prom_num(h.quantile(q))}'
                )
            lines.append(f"{mn}_sum {_prom_num(h.total)}")
            lines.append(f"{mn}_count {h.count}")
        return "\n".join(lines) + "\n"


def _prom_name(name: str) -> str:
    out = "".join(c if c.isalnum() or c == "_" else "_" for c in name)
    if out and out[0].isdigit():
        out = "_" + out
    return "repro_" + out


def _prom_num(v: float) -> str:
    if isinstance(v, float) and math.isnan(v):
        return "NaN"
    return repr(float(v)) if not float(v).is_integer() else str(int(v))


_STACK: list[MetricsRegistry] = []


def enabled() -> bool:
    """True iff a registry is active (instrumentation guard)."""
    return bool(_STACK)


def active() -> Optional[MetricsRegistry]:
    return _STACK[-1] if _STACK else None


def incr(name: str, n: float = 1) -> None:
    for reg in _STACK:
        reg.incr(name, n)


def gauge(name: str, value: float) -> None:
    for reg in _STACK:
        reg.gauge(name, value)


def observe(name: str, value: float) -> None:
    """Observe ``value`` into histogram ``name`` in every active registry
    (no-op when none is active)."""
    for reg in _STACK:
        reg.observe(name, value)
