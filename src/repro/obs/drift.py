"""Cost-model drift detection: predicted-vs-measured residuals per variant.

``planner.costmodel`` prices every variant from a
:class:`~repro.planner.costmodel.CalibrationProfile` measured once and
cached to JSON. Hardware changes, JAX upgrades, and corpus regimes the
calibration never saw all rot that profile silently — the planner keeps
ranking with stale constants and the within-2× gate only catches it a
benchmark later. This module closes the loop at *run* time:

- :func:`predict_seconds` prices a single **runtime**
  :class:`~repro.planner.telemetry.ApssStats` record with a profile —
  same formula shape as ``estimate_cost`` (latency·hops + bytes/bw for
  comm; FLOPs/throughput for compute; ``max`` when the schedule overlaps)
  but fed by the hops/FLOPs the call actually recorded, not corpus
  summaries;
- :func:`residuals_from_trace` joins each record with its measured span
  (the span it was pinned to by ``obs.trace``) into
  :class:`Residual` rows — ``ratio = measured / predicted``;
- :func:`residuals_from_estimates` does the same join for planner
  :class:`~repro.planner.costmodel.CostEstimate` lists that carry
  ``measured_s`` (the ``bench_planner`` path);
- :func:`drift_report` folds residuals into a :class:`DriftReport`:
  per-variant median ratios, an overall median, and ``stale=True`` when
  the overall median leaves ``[1/band, band]`` — with a recalibration
  recommendation naming the worst offenders.

Residual convention: ratios, not differences — a profile that is uniformly
2× optimistic is *consistent* (the argmin ranking survives) but shows up
as median ratio ≈ 2; the band is therefore a statement about how much
uniform error the within-2× planner gate can absorb.
"""

from __future__ import annotations

import dataclasses
import statistics
from typing import Iterable, Optional

from repro.planner.costmodel import CalibrationProfile, CostEstimate
from repro.planner.telemetry import ApssStats
from repro.obs.trace import Tracer

# Schedules that overlap collective hops with compute (ring family, the
# checkerboard and the nested hierarchical rings) — same set as
# ``costmodel.estimate_cost``, keyed here by runtime variant string.
_OVERLAPPED_PREFIXES = (
    "horizontal/ring", "horizontal/halfring", "hierarchical", "2d/",
)


@dataclasses.dataclass
class Residual:
    """One predicted-vs-measured pair."""

    variant: str
    predicted_s: float
    measured_s: float
    source: str = "trace"   # "trace" | "estimate"

    @property
    def ratio(self) -> float:
        return self.measured_s / max(self.predicted_s, 1e-12)


@dataclasses.dataclass
class DriftReport:
    """Aggregated residuals + staleness verdict for one profile."""

    residuals: list[Residual]
    band: float
    per_variant: dict[str, float]          # median ratio per variant
    median_ratio: float
    stale: bool
    profile_kind: str
    recommendation: str

    def as_dict(self) -> dict:
        return {
            "band": self.band,
            "median_ratio": self.median_ratio,
            "stale": self.stale,
            "profile_kind": self.profile_kind,
            "per_variant": dict(sorted(self.per_variant.items())),
            "n_residuals": len(self.residuals),
            "recommendation": self.recommendation,
            "residuals": [
                {
                    "variant": r.variant,
                    "predicted_s": r.predicted_s,
                    "measured_s": r.measured_s,
                    "ratio": r.ratio,
                    "source": r.source,
                }
                for r in self.residuals
            ],
        }

    def describe(self) -> str:
        lines = [
            f"DriftReport(profile={self.profile_kind}, "
            f"median ratio {self.median_ratio:.2f}x, band {self.band:.1f}x, "
            f"{'STALE' if self.stale else 'fresh'})"
        ]
        for v, r in sorted(self.per_variant.items()):
            lines.append(f"  {v:<44} median measured/predicted {r:8.2f}x")
        lines.append(f"  {self.recommendation}")
        return "\n".join(lines)


def predict_seconds(stats: ApssStats,
                    profile: CalibrationProfile) -> float:
    """Price one runtime record with ``profile`` (see module docstring)."""
    comm_s = (
        stats.hop_count * profile.collective_latency_us * 1e-6
        + stats.wire_bytes / (max(profile.collective_gbps, 1e-3) * 1e9)
    )
    compute_s = stats.flops / profile.throughput(
        sparse=stats.sparse, distributed=stats.devices > 1
    )
    if stats.imbalance is not None:
        compute_s *= stats.imbalance
    overlapped = stats.variant.startswith(_OVERLAPPED_PREFIXES)
    body = max(compute_s, comm_s) if overlapped else compute_s + comm_s
    return body + profile.overhead_us * 1e-6


def residuals_from_trace(tracer: Tracer,
                         profile: CalibrationProfile) -> list[Residual]:
    """Join each ``ApssStats`` with its enclosing measured span.

    A span's wall-clock is attributed evenly across the records pinned to
    it (one record per span in every instrumented path today); spans whose
    ticker children extend past the span close (async dispatch) use the
    children's extent instead, so the measurement covers the device work.
    """
    tracer.finalize()
    out: list[Residual] = []
    for sp in tracer.walk():
        if not sp.records:
            continue
        end = sp.t1 if sp.t1 is not None else sp.t0
        for c in sp.children:
            if c.t1 is not None:
                end = max(end, c.t1)
        measured = max(0.0, end - sp.t0) / len(sp.records)
        for stats in sp.records:
            out.append(Residual(
                variant=stats.variant,
                predicted_s=predict_seconds(stats, profile),
                measured_s=measured,
                source="trace",
            ))
    return out


def residuals_from_estimates(
    estimates: Iterable[CostEstimate],
) -> list[Residual]:
    """Residuals from planner estimates that carry ``measured_s`` (filled
    by autotuning / ``bench_planner``); unmeasured entries are skipped."""
    out = []
    for e in estimates:
        if e.measured_s is None:
            continue
        out.append(Residual(
            variant=e.config.name,
            predicted_s=e.total_s,
            measured_s=e.measured_s,
            source="estimate",
        ))
    return out


def drift_report(
    residuals: list[Residual],
    *,
    band: float = 4.0,
    profile: Optional[CalibrationProfile] = None,
) -> DriftReport:
    """Fold residuals into a :class:`DriftReport` (see module docstring).

    ``band`` is the acceptable median measured/predicted ratio envelope:
    ``stale`` iff the overall median falls outside ``[1/band, band]``.
    """
    kind = profile.device_kind if profile is not None else "unknown"
    if not residuals:
        return DriftReport(
            residuals=[], band=band, per_variant={}, median_ratio=1.0,
            stale=False, profile_kind=kind,
            recommendation="no measured spans joined any model record",
        )
    per_variant: dict[str, list[float]] = {}
    for r in residuals:
        per_variant.setdefault(r.variant, []).append(r.ratio)
    medians = {v: statistics.median(rs) for v, rs in per_variant.items()}
    overall = statistics.median([r.ratio for r in residuals])
    stale = overall > band or overall < 1.0 / band
    if stale:
        worst = sorted(
            medians.items(),
            key=lambda kv: abs(_log(kv[1])),
            reverse=True,
        )[:3]
        names = ", ".join(f"{v} ({r:.1f}x)" for v, r in worst)
        recommendation = (
            f"calibration profile '{kind}' looks stale "
            f"(median measured/predicted {overall:.2f}x outside "
            f"[{1/band:.2f}, {band:.2f}]); worst: {names}. "
            "Re-run repro.planner.calibrate.calibrate(save=True) on this "
            "hardware before trusting plan rankings."
        )
    else:
        recommendation = (
            f"profile '{kind}' within band "
            f"(median measured/predicted {overall:.2f}x)"
        )
    return DriftReport(
        residuals=residuals, band=band, per_variant=medians,
        median_ratio=overall, stale=stale, profile_kind=kind,
        recommendation=recommendation,
    )


def _log(x: float) -> float:
    import math
    return math.log(max(x, 1e-12))
