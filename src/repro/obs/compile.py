"""Compile-time observability: retrace registry, no-retrace contracts,
lower/compile spans with XLA memory analysis.

The repo's retrace discipline ("build once, query many" — DESIGN.md §6/§9)
was enforced by test-only ``TRACE_COUNTS`` dicts scattered in
``serving/query.py`` / ``serving/mutable.py``. This module promotes them to
ONE public registry:

- :class:`CompileMonitor` (module singleton :data:`MONITOR`) holds the
  per-entry-point retrace :attr:`~CompileMonitor.counts`. Every jitted
  entry point calls :func:`mark` at trace time (a Python side effect runs
  only when jit re-traces, so the counter IS the compilation count);
  ``serving.query.TRACE_COUNTS`` remains a back-compat alias to the same
  ``Counter`` object.
- :func:`assert_no_retrace` is the budget contract: inside the context any
  watched entry point that re-traces fires every active
  :class:`~repro.obs.recorder.FlightRecorder` (reason
  ``compile.retrace.<name>``) and raises :class:`RetraceError` — at mark
  time, so the violating call is still on the stack. Hot-path groups are
  registered by name (:func:`register_entry_points`): ``"serving.query"``
  and ``"serving.mutable"``.
- :meth:`CompileMonitor.lower_and_compile` is the AOT seam: times
  ``fn.lower(...)`` / ``.compile()`` under a ``compile/<name>`` span
  (PR-8 ``Tracer``), captures ``compiled.memory_analysis()`` argument/
  output/temp bytes into a :class:`CompileRecord`, and returns
  ``(compiled, record)`` — the workhorse of :mod:`repro.obs.audit`.
- :func:`capture_calls` / :func:`offer_capture` let host-staged call sites
  (the serving/mutable inners, whose worklist arguments are built host-
  side) hand one real ``(fn, args, kwargs)`` triple to the audit, which
  can then lower the exact program the hot path runs.

Guard discipline matches the rest of ``obs``: counting is always on (one
``Counter`` increment per *compilation*, not per call); contracts, spans,
metrics and recorder notes cost nothing unless their sink is active.
"""

from __future__ import annotations

import collections
import contextlib
import dataclasses
import time
from typing import Iterator, Optional

from repro.obs import metrics, recorder, trace


class RetraceError(RuntimeError):
    """An entry point re-traced under an active no-retrace contract."""


@dataclasses.dataclass
class CompileRecord:
    """One measured lower+compile of a jitted entry point."""

    name: str
    t_lower_s: float
    t_compile_s: float
    argument_bytes: int = 0
    output_bytes: int = 0
    temp_bytes: int = 0
    code_bytes: int = 0

    @property
    def total_bytes(self) -> int:
        """Peak live-buffer footprint: arguments + outputs + temporaries."""
        return self.argument_bytes + self.output_bytes + self.temp_bytes

    def as_dict(self) -> dict:
        return {
            "name": self.name,
            "t_lower_s": self.t_lower_s,
            "t_compile_s": self.t_compile_s,
            "argument_bytes": self.argument_bytes,
            "output_bytes": self.output_bytes,
            "temp_bytes": self.temp_bytes,
            "code_bytes": self.code_bytes,
            "total_bytes": self.total_bytes,
        }


@dataclasses.dataclass
class CapturedCall:
    """One jitted call site offered to :func:`capture_calls`."""

    name: str
    fn: object
    args: tuple
    kwargs: dict


class _NoRetraceContract:
    """Snapshot-on-enter budget: watched counters must not move."""

    __slots__ = ("monitor", "names", "baseline", "watch_all", "violated")

    def __init__(self, monitor: "CompileMonitor", names: tuple):
        self.monitor = monitor
        self.names = names
        self.watch_all = not names
        self.baseline: dict = {}
        self.violated: set = set()

    def __enter__(self) -> "_NoRetraceContract":
        counts = self.monitor.counts
        watched = self.names if self.names else tuple(counts)
        self.baseline = {n: counts[n] for n in watched}
        self.monitor._contracts.append(self)
        return self

    def __exit__(self, exc_type, *exc) -> None:
        stack = self.monitor._contracts
        if stack and stack[-1] is self:
            stack.pop()
        elif self in stack:
            stack.remove(self)
        if exc_type is not None:
            return  # already failing (possibly with our own RetraceError)
        # Belt-and-braces: catch legacy `TRACE_COUNTS[x] += 1` bumps that
        # bypassed mark() (the alias shares the Counter object). Names
        # whose mark-time violation was already raised (and possibly
        # caught by the caller) are not re-raised here.
        counts = self.monitor.counts
        for n in (counts if self.watch_all else self.names):
            if n not in self.violated and counts[n] > self.baseline.get(n, 0):
                self.violated.add(n)
                self.monitor._violate(n, self.baseline.get(n, 0))

    def check(self, name: str) -> None:
        if not self.watch_all and name not in self.names:
            return
        if name in self.violated:
            return
        allowed = self.baseline.get(name, 0)
        if self.monitor.counts[name] > allowed:
            self.violated.add(name)
            self.monitor._violate(name, allowed)


class CompileMonitor:
    """Public registry of retrace counts, contracts, and compile records."""

    def __init__(self) -> None:
        # Per-entry-point compilation counts. serving.query.TRACE_COUNTS
        # aliases this object — legacy readers keep working unchanged.
        self.counts: collections.Counter = collections.Counter()
        self.records: list[CompileRecord] = []
        self.groups: dict[str, tuple[str, ...]] = {}
        self._contracts: list[_NoRetraceContract] = []

    # -- retrace registry ----------------------------------------------------

    def mark(self, name: str) -> None:
        """Count one (re)trace of ``name``; called at trace time only."""
        self.counts[name] += 1
        if metrics.enabled():
            metrics.incr(f"compile.traces.{name}")
        if recorder.enabled():
            recorder.note("compile", name, count=self.counts[name])
        for c in reversed(self._contracts):
            c.check(name)

    def snapshot(self) -> dict:
        """Plain dict copy of the current counts (the public read API)."""
        return dict(self.counts)

    def register_entry_points(self, group: str, *names: str) -> None:
        """Declare a named hot-path group for :meth:`assert_no_retrace`."""
        self.groups[group] = tuple(names)

    def _resolve(self, names: tuple) -> tuple:
        out: list[str] = []
        for n in names:
            out.extend(self.groups.get(n, (n,)))
        return tuple(dict.fromkeys(out))

    def assert_no_retrace(self, *names: str) -> _NoRetraceContract:
        """Context manager: watched entry points must not re-trace inside.

        ``names`` are counter names and/or registered group names
        (``"serving.query"``, ``"serving.mutable"``); with no names, EVERY
        entry point is watched. A violation fires the flight recorder
        (reason ``compile.retrace.<name>``) and raises
        :class:`RetraceError` at the re-tracing call.
        """
        return _NoRetraceContract(self, self._resolve(names))

    def _violate(self, name: str, allowed: int) -> None:
        count = self.counts[name]
        if metrics.enabled():
            metrics.incr("compile.retrace_violations")
        recorder.trigger(
            f"compile.retrace.{name}",
            entry_point=name, count=count, allowed=allowed,
        )
        raise RetraceError(
            f"entry point '{name}' re-traced under a no-retrace contract "
            f"(compilations {count} > budget {allowed}): a traced-shape or "
            "static-argument change leaked into the hot path (see the "
            "flight-record dump for the lead-up)"
        )

    # -- AOT lower/compile ---------------------------------------------------

    def lower_and_compile(self, fn, *args, name: Optional[str] = None,
                          **kwargs):
        """``fn.lower(*args, **kwargs).compile()`` with full accounting.

        Emits a ``compile/<name>`` span carrying lower/compile wall times,
        captures ``memory_analysis()`` bytes (zeros where the backend
        offers none), appends a :class:`CompileRecord`, and returns
        ``(compiled, record)``.
        """
        label = name or getattr(fn, "__name__", None) or repr(fn)
        with trace.span(f"compile/{label}"):
            t0 = time.perf_counter()
            lowered = fn.lower(*args, **kwargs)
            t_lower = time.perf_counter() - t0
            t0 = time.perf_counter()
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0
            trace.annotate(t_lower_s=t_lower, t_compile_s=t_compile)
        rec = CompileRecord(
            name=label, t_lower_s=t_lower, t_compile_s=t_compile,
            **_memory_bytes(compiled),
        )
        self.records.append(rec)
        if metrics.enabled():
            metrics.observe("compile.lower_s", t_lower)
            metrics.observe("compile.compile_s", t_compile)
        if recorder.enabled():
            recorder.note(
                "compile.aot", label,
                t_compile_s=t_compile, total_bytes=rec.total_bytes,
            )
        return compiled, rec

    def reset(self) -> None:
        """Drop counts and records (test isolation only — the serving
        no-retrace tests rely on counts persisting across calls)."""
        self.counts.clear()
        self.records.clear()


def _memory_bytes(compiled) -> dict:
    """``memory_analysis()`` fields, zeros when the backend lacks them."""
    out = {
        "argument_bytes": 0, "output_bytes": 0, "temp_bytes": 0,
        "code_bytes": 0,
    }
    try:
        mem = compiled.memory_analysis()
    except Exception:
        return out
    if mem is None:
        return out
    for key, attr in (
        ("argument_bytes", "argument_size_in_bytes"),
        ("output_bytes", "output_size_in_bytes"),
        ("temp_bytes", "temp_size_in_bytes"),
        ("code_bytes", "generated_code_size_in_bytes"),
    ):
        try:
            out[key] = int(getattr(mem, attr, 0) or 0)
        except Exception:
            pass
    return out


# ---------------------------------------------------------------------------
# Module singleton + functional API
# ---------------------------------------------------------------------------

MONITOR = CompileMonitor()


def mark(name: str) -> None:
    """Count one (re)trace of ``name`` on the module :data:`MONITOR`."""
    MONITOR.mark(name)


def snapshot() -> dict:
    return MONITOR.snapshot()


def register_entry_points(group: str, *names: str) -> None:
    MONITOR.register_entry_points(group, *names)


def entry_points(group: str) -> tuple[str, ...]:
    """The registered counter names of a hot-path group."""
    return MONITOR.groups.get(group, ())


def assert_no_retrace(*names: str) -> _NoRetraceContract:
    return MONITOR.assert_no_retrace(*names)


def lower_and_compile(fn, *args, name: Optional[str] = None, **kwargs):
    return MONITOR.lower_and_compile(fn, *args, name=name, **kwargs)


# ---------------------------------------------------------------------------
# Call-site capture (audit seam)
# ---------------------------------------------------------------------------

_CAPTURE: Optional[dict] = None


@contextlib.contextmanager
def capture_calls() -> Iterator[dict]:
    """Collect ``offer_capture``'d call sites into the yielded dict.

    The first offer per name wins (the audit wants one representative
    call, not every batch). Nests by shadowing: the inner context sees a
    fresh dict, the outer resumes on exit.
    """
    global _CAPTURE
    prev, _CAPTURE = _CAPTURE, {}
    try:
        yield _CAPTURE
    finally:
        _CAPTURE = prev


def offer_capture(name: str, fn, *args, **kwargs) -> None:
    """Record a jitted call site for later AOT lowering (no-op unless a
    :func:`capture_calls` context is active — one ``is None`` check)."""
    if _CAPTURE is not None and name not in _CAPTURE:
        _CAPTURE[name] = CapturedCall(name, fn, args, dict(kwargs))
