"""Flight recorder: bounded ring buffer of recent events, auto-dumped on
failure triggers — postmortems for the chaos lane.

A :class:`FlightRecorder` keeps the last ``capacity`` observability events
(span closures and point events fed by ``obs.trace``, plus anything noted
directly) in a ``deque``. It costs O(1) per event and never grows; when a
failure trigger fires the buffer is snapshotted — to a JSON artifact under
``directory`` when one is configured, and always onto ``.dumps`` in
memory — so the *lead-up* to the failure survives even though nobody was
watching.

Wired triggers (each calls :func:`trigger` only when a recorder is active,
so the instrumented paths stay free when observability is off):

- ``robust.faults.FaultPlan`` firing any armed fault (kill/delay/error/
  corruption) — reason ``fault:<kind>:<scope>``;
- the serving degradation ladder moving down a tier — reason
  ``serving.tier_down``;
- ``CheckpointManager.restore(fallback=True)`` skipping a corrupt step —
  reason ``checkpoint.corruption_fallback``.
"""

from __future__ import annotations

import collections
import json
import os
import time
from typing import Optional


class FlightRecorder:
    """Stacked context manager; see module docstring.

    Args:
      capacity: ring-buffer length (events beyond it are dropped oldest
        first).
      directory: where trigger dumps are written as
        ``flight_<seq>_<reason>.json``; None keeps dumps in memory only
        (``.dumps``).
    """

    def __init__(self, *, capacity: int = 512,
                 directory: Optional[str] = None,
                 clock=time.perf_counter):
        self.capacity = int(capacity)
        self.directory = directory
        self.clock = clock
        self.buffer: collections.deque = collections.deque(maxlen=capacity)
        # (reason, payload dict, path | None), newest last
        self.dumps: list[tuple[str, dict, Optional[str]]] = []

    def __enter__(self) -> "FlightRecorder":
        _STACK.append(self)
        return self

    def __exit__(self, *exc) -> None:
        if _STACK and _STACK[-1] is self:
            _STACK.pop()
        elif self in _STACK:
            _STACK.remove(self)

    def note(self, kind: str, name: str, **attrs) -> None:
        self.buffer.append(
            {"t": self.clock(), "kind": kind, "name": name, "attrs": attrs}
        )

    def trigger(self, reason: str, **attrs) -> dict:
        """Snapshot the ring buffer now; returns the dump payload."""
        payload = {
            "reason": reason,
            "t": self.clock(),
            "attrs": attrs,
            "events": list(self.buffer),
        }
        path = None
        if self.directory is not None:
            os.makedirs(self.directory, exist_ok=True)
            safe = "".join(
                c if c.isalnum() or c in "._-" else "_" for c in reason
            )
            path = os.path.join(
                self.directory, f"flight_{len(self.dumps):03d}_{safe}.json"
            )
            with open(path, "w") as f:
                json.dump(payload, f, indent=1)
        self.dumps.append((reason, payload, path))
        self.note("dump", reason, **attrs)
        return payload


_STACK: list[FlightRecorder] = []


def enabled() -> bool:
    """True iff a recorder is active (instrumentation guard)."""
    return bool(_STACK)


def active() -> Optional[FlightRecorder]:
    return _STACK[-1] if _STACK else None


def note(kind: str, name: str, **attrs) -> None:
    for r in _STACK:
        r.note(kind, name, **attrs)


def trigger(reason: str, **attrs) -> None:
    """Fire every active recorder's dump (no-op when none is active)."""
    for r in _STACK:
        r.trigger(reason, **attrs)
