"""Model-vs-HLO audit: does the cost model price the program XLA built?

``planner.costmodel`` prices variants from closed-form FLOP / wire-byte
formulas; ``obs.drift`` checks those predictions against *measured wall
time* — which needs a run, a warm device, and a calibrated profile. This
module adds the third, zero-run leg: AOT-compile every plannable variant
family (``CompileMonitor.lower_and_compile``), run the loop-aware static
analyzer ``launch.hlo_analysis.analyze`` over the post-SPMD HLO, and
compare

- model FLOPs          vs HLO dot FLOPs (trip-count-weighted),
- model collective B   vs HLO link bytes (per-device, same wire convention
  as ``telemetry.CollectiveHop.total_bytes``),
- a streaming HBM lower bound vs HLO-billed HBM traffic,

as per-family ratios in an :class:`AuditReport`. A family whose HLO FLOPs
drift from the model (an XLA upgrade re-fusing a scan, a schedule change
doubling a mirror score) shows up here at *compile* time, before any
benchmark. Ratios also feed :func:`AuditReport.residuals` →
``obs.drift.drift_report`` as ``source="audit"`` rows (unit-free: the
Residual convention is ratios, so FLOPs work as well as seconds).

Coverage: every family ``candidate_configs`` can plan on the given meshes
— dense/sparse × blocked / horizontal allgather / ring / halfring /
vertical / hierarchical / 2-D checkerboard — plus the serving
``query_topk`` inners and the mutable delta join, captured from REAL call
sites via ``obs.compile.capture_calls`` (their worklist arguments are
built host-side, so the audit lowers the exact program the hot path
runs). Host-staged sparse families (``shard_dims`` pre-split) lower
through the post-split seams ``core.distributed._vertical_sparse_post_split``
/ ``_2d_sparse_post_split``.

Known, documented gaps (reported as entry notes, not failures):

- the sparse XLA scan materializes a ``(block, S)`` gathered support slab
  per worklist tile — ≈ ``2·T·b·S·4`` bytes of HBM the streaming model
  does not charge (ROADMAP: in-kernel gather);
- HBM ratios are informational: the analyzer bills fusion call sites,
  which legitimately re-read operands the streaming bound counts once.

CLI: ``python -m repro.obs.audit [--n N] [--m M] [--json PATH]``.
"""

from __future__ import annotations

import dataclasses
import functools
import json
from typing import Optional

import numpy as np

from repro.obs import compile as obs_compile
from repro.obs import drift, trace

# Families whose HLO-derived FLOPs must sit within this factor of the
# model (both directions). Only the dense families XLA compiles to plain
# dot chains are gated — sparse gather-dot FLOPs are partially hidden in
# scatter/gather ops the dot census cannot see.
FLOP_RATIO_BAND = 1.5
GATED_FAMILIES = ("blocked[dense]", "horizontal/ring[dense]")


@dataclasses.dataclass
class AuditEntry:
    """One variant family: model prediction vs HLO-derived measurement."""

    family: str
    config: str
    mesh: Optional[dict]
    predicted_flops: float
    hlo_flops: float
    predicted_link_bytes: float
    hlo_link_bytes: float
    predicted_hbm_bytes: float
    hlo_hbm_bytes: float
    record: obs_compile.CompileRecord
    notes: tuple = ()

    @staticmethod
    def _ratio(hlo: float, predicted: float) -> Optional[float]:
        if predicted <= 0:
            return None
        return hlo / predicted

    @property
    def flop_ratio(self) -> Optional[float]:
        return self._ratio(self.hlo_flops, self.predicted_flops)

    @property
    def link_ratio(self) -> Optional[float]:
        return self._ratio(self.hlo_link_bytes, self.predicted_link_bytes)

    @property
    def hbm_ratio(self) -> Optional[float]:
        return self._ratio(self.hlo_hbm_bytes, self.predicted_hbm_bytes)

    def as_dict(self) -> dict:
        return {
            "family": self.family,
            "config": self.config,
            "mesh": self.mesh,
            "predicted_flops": self.predicted_flops,
            "hlo_flops": self.hlo_flops,
            "flop_ratio": self.flop_ratio,
            "predicted_link_bytes": self.predicted_link_bytes,
            "hlo_link_bytes": self.hlo_link_bytes,
            "link_ratio": self.link_ratio,
            "predicted_hbm_bytes": self.predicted_hbm_bytes,
            "hlo_hbm_bytes": self.hlo_hbm_bytes,
            "hbm_ratio": self.hbm_ratio,
            "compile": self.record.as_dict(),
            "notes": list(self.notes),
        }


@dataclasses.dataclass
class AuditReport:
    """Every audited family + the corpus/mesh context they compiled for."""

    entries: list
    n: int
    m: int
    k: int
    threshold: float
    meshes: list

    def families(self) -> list:
        return [e.family for e in self.entries]

    def entry(self, family: str) -> AuditEntry:
        for e in self.entries:
            if e.family == family:
                return e
        raise KeyError(family)

    def gated_ok(self, band: float = FLOP_RATIO_BAND) -> bool:
        """Do the gated dense families' HLO FLOPs sit within ``band``?"""
        for fam in GATED_FAMILIES:
            try:
                r = self.entry(fam).flop_ratio
            except KeyError:
                return False
            if r is None or r > band or r < 1.0 / band:
                return False
        return True

    def residuals(self) -> list:
        """FLOP-ratio rows for ``obs.drift.drift_report`` (``source="audit"``,
        unit-free by the Residual ratio convention)."""
        out = []
        for e in self.entries:
            if e.predicted_flops > 0 and e.hlo_flops > 0:
                out.append(drift.Residual(
                    variant=e.family,
                    predicted_s=e.predicted_flops,
                    measured_s=e.hlo_flops,
                    source="audit",
                ))
        return out

    def as_dict(self) -> dict:
        return {
            "n": self.n, "m": self.m, "k": self.k,
            "threshold": self.threshold,
            "meshes": self.meshes,
            "flop_ratio_band": FLOP_RATIO_BAND,
            "gated_families": list(GATED_FAMILIES),
            "gated_ok": self.gated_ok(),
            "entries": [e.as_dict() for e in self.entries],
        }

    def describe(self) -> str:
        lines = [
            f"AuditReport: n={self.n} m={self.m} k={self.k} "
            f"t={self.threshold} meshes={self.meshes}",
            f"{'family':<36} {'flopsx':>7} {'linkx':>7} {'hbmx':>7} "
            f"{'peakMB':>8} {'compile':>8}",
        ]
        fmt = lambda r: "   -  " if r is None else f"{r:6.2f}"  # noqa: E731
        for e in self.entries:
            lines.append(
                f"{e.family:<36} {fmt(e.flop_ratio):>7} "
                f"{fmt(e.link_ratio):>7} {fmt(e.hbm_ratio):>7} "
                f"{e.record.total_bytes / 1e6:>7.1f}M "
                f"{e.record.t_compile_s * 1e3:>6.0f}ms"
            )
            for note in e.notes:
                lines.append(f"    note: {note}")
        gate = "PASS" if self.gated_ok() else "FAIL"
        lines.append(
            f"gate[{', '.join(GATED_FAMILIES)}] within "
            f"{FLOP_RATIO_BAND}x: {gate}"
        )
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Prediction helpers
# ---------------------------------------------------------------------------


def _family_name(cfg) -> str:
    base = cfg.kind
    if cfg.schedule:
        base += f"/{cfg.schedule}"
    if cfg.accumulation:
        base += f"/{cfg.accumulation}"
    return f"{base}[{'sparse' if cfg.sparse else 'dense'}]"


def _predicted_hbm(cfg, s, p: int, k: int) -> float:
    """Streaming lower bound: each device scores a ``rows × n`` strip by
    reading its resident row block plus every counterpart block once, and
    writes its matches. Deliberately optimistic — the HLO side bills
    fusion operand re-reads on top — so ``hbm_ratio ≥ 1`` is the healthy
    regime and the ratio is informational, not gated."""
    from repro.planner import telemetry

    depth = s.cap if cfg.sparse else s.m
    itemb = 8 if cfg.sparse else s.itemsize  # CSR slot = i32 idx + f32 val
    rows = s.n if cfg.kind == "vertical" else s.n // max(1, p)
    corpus_pass = (rows + s.n) * depth * itemb
    return float(corpus_pass + telemetry.matches_bytes(rows, k))


def _sparse_scan_note(cfg, s) -> str:
    """Quantify the sparse XLA scan's per-tile gathered support slab —
    the ``(T, block, S)`` HBM intermediate the streaming model does not
    charge (ROADMAP: in-kernel gather)."""
    b = cfg.block_rows
    total_tiles = max(1, (s.n // max(b, 1)) ** 2)
    live_tiles = max(1, int(round(s.live_fraction * total_tiles)))
    support = min(s.m, b * s.cap)
    slab = 2 * live_tiles * b * support * 4
    return (
        f"sparse scan gather intermediate ~(T={live_tiles}, b={b}, "
        f"S<={support}) x2 slabs = {slab / 1e6:.1f}MB HBM not in the "
        "streaming model (ROADMAP: in-kernel gather)"
    )


# ---------------------------------------------------------------------------
# Lowering seams per family
# ---------------------------------------------------------------------------


def _lower_planned(cfg, data, threshold: float, k: int, mesh):
    """AOT-compile one planner config through its lowerable seam."""
    import jax

    from repro.core import distributed as dist
    from repro.core.sparse import shard_dims
    from repro.planner import plan as planner_plan

    name = _family_name(cfg)
    if not planner_plan._has_host_stage(cfg):
        return obs_compile.lower_and_compile(
            planner_plan._execute_traced, data, cfg, float(threshold), k,
            mesh if cfg.kind != "blocked" else None, name=name,
        )
    names = tuple(mesh.axis_names)
    if cfg.kind == "vertical":
        p = mesh.shape[names[-1]]
        idx_s, val_s, nnz_s, m_loc = shard_dims(data, p)
        del nnz_s
        seam = functools.partial(
            dist._vertical_sparse_post_split,
            n=data.n, m_loc=m_loc, threshold=float(threshold), k=k,
            mesh=mesh, axis_name=names[-1], accumulation=cfg.accumulation,
            block_rows=cfg.block_rows, candidate_capacity=None,
            return_stats=False,
        )
        return obs_compile.lower_and_compile(
            jax.jit(seam), idx_s, val_s, name=name,
        )
    if cfg.kind == "2d":
        r = mesh.shape[names[1]]
        idx_s, val_s, nnz_s, m_loc = shard_dims(data, r)
        seam = functools.partial(
            dist._2d_sparse_post_split,
            m_loc=m_loc, threshold=float(threshold), k=k, mesh=mesh,
            row_axis=names[0], col_axis=names[1],
            accumulation=cfg.accumulation, block_rows=cfg.block_rows,
            candidate_capacity=dist.default_candidate_capacity(k),
        )
        return obs_compile.lower_and_compile(
            jax.jit(seam), idx_s, val_s, nnz_s, name=name,
        )
    raise ValueError(f"no lowering seam for host-staged config {cfg.name}")


def _audit_planned(cfg, s, corpus, threshold: float, k: int, mesh,
                   mesh_sizes, analyze) -> AuditEntry:
    from repro.planner import costmodel
    from repro.planner.plan import _to_representation

    p = 1
    for v in (mesh_sizes or {}).values():
        p *= v
    if cfg.kind == "blocked":
        p = 1
    data = _to_representation(corpus, cfg.sparse)
    compiled, record = _lower_planned(cfg, data, threshold, k, mesh)
    analysis = analyze(compiled.as_text())
    hops = (
        costmodel.variant_hops(cfg, s, mesh_sizes, k)
        if mesh_sizes and p > 1 else ()
    )
    notes = []
    if cfg.sparse and cfg.kind in ("blocked", "horizontal"):
        notes.append(_sparse_scan_note(cfg, s))
    return AuditEntry(
        family=_family_name(cfg),
        config=cfg.name,
        mesh=dict(mesh_sizes) if mesh_sizes else None,
        predicted_flops=costmodel.variant_flops(cfg, s, p),
        hlo_flops=analysis["flops"],
        predicted_link_bytes=float(sum(h.total_bytes for h in hops)),
        hlo_link_bytes=analysis["link_bytes"],
        predicted_hbm_bytes=_predicted_hbm(cfg, s, p, k),
        hlo_hbm_bytes=analysis["hbm_bytes"],
        record=record,
        notes=tuple(notes),
    )


def _audit_serving(corpus, threshold: float, k: int, analyze) -> list:
    """query_topk inner + mutable forward delta join, from REAL call
    sites (``capture_calls``) so the audit lowers exactly what serving
    runs — worklist length ``T`` included."""
    from repro.core.sparse import from_dense
    from repro.serving import build_index, query_topk
    from repro.serving.mutable import MutableAPSSIndex

    D = np.asarray(corpus, np.float32)
    n, m = D.shape
    entries = []

    Q = D[: min(32, n)]
    calls: dict = {}
    for data in (D, from_dense(D)):
        index = build_index(data, block_rows=min(64, n))
        with obs_compile.capture_calls() as got:
            query_topk(index, Q, threshold, k)
        calls.update(got)
    for cap_name, fam in (
        ("serving.dense_inner", "serving.query_topk[dense]"),
        ("serving.sparse_inner", "serving.query_topk[sparse]"),
    ):
        call = calls.get(cap_name)
        if call is None:
            continue
        entries.append(_audit_captured(call, fam, m, analyze))

    mut = MutableAPSSIndex(
        D[: n // 2], threshold=threshold, k=k, kind="dense",
        block_rows=min(64, 1 << (n // 2 - 1).bit_length()),
    )
    with obs_compile.capture_calls() as calls:
        mut.append(D[n // 2:])  # append runs the forward delta join
    for cap_name, fam in (
        ("mutable.dense_inner", "mutable.delta_join[dense]"),
        ("mutable.sparse_inner", "mutable.delta_join[sparse]"),
    ):
        call = calls.get(cap_name)
        if call is None:
            continue
        entries.append(_audit_captured(call, fam, m, analyze))
    return entries


def _audit_captured(call, family: str, m: int, analyze) -> AuditEntry:
    """Worklist-path prediction: ``2·T·block_q·block_c·depth`` FLOPs over
    the captured tile list (``ij`` is ``(2, T)``), one gathered
    query-block + corpus-block read per tile for HBM."""
    kw = call.kwargs
    T = 0
    for a in call.args:
        shp = getattr(a, "shape", ())
        if len(shp) == 2 and shp[0] == 2:
            T = int(shp[1])
            break
    bq, bc = int(kw["block_q"]), int(kw["block_c"])
    predicted_flops = 2.0 * T * bq * bc * m
    predicted_hbm = float(T * (bq + bc) * m * 4 + T * bq * bc * 4)
    compiled, record = obs_compile.lower_and_compile(
        call.fn, *call.args, name=family, **call.kwargs,
    )
    analysis = analyze(compiled.as_text())
    return AuditEntry(
        family=family,
        config=f"{call.name}(T={T}, block_q={bq}, block_c={bc})",
        mesh=None,
        predicted_flops=predicted_flops,
        hlo_flops=analysis["flops"],
        predicted_link_bytes=0.0,
        hlo_link_bytes=analysis["link_bytes"],
        predicted_hbm_bytes=predicted_hbm,
        hlo_hbm_bytes=analysis["hbm_bytes"],
        record=record,
        notes=(),
    )


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------


def run_audit(
    corpus=None,
    *,
    n: int = 64,
    m: int = 64,
    k: int = 8,
    threshold: float = 0.3,
    density: float = 0.2,
    seed: int = 0,
    meshes=None,
    include_serving: bool = True,
) -> AuditReport:
    """Audit every plannable variant family (one config per family — block
    sizes within a family lower to the same program shape).

    ``meshes=None`` builds a 1-axis mesh over all devices plus (when the
    device count is an even composite) a 2-axis ``(q, 2)`` mesh, matching
    the families ``candidate_configs`` can plan. Pass ``corpus`` to audit
    real data; the default is the synthetic power-law corpus at a size
    every family's divisibility gates accept.
    """
    import jax
    from jax.sharding import Mesh

    from repro.data.synthetic import synthetic_corpus
    from repro.launch.hlo_analysis import analyze
    from repro.planner.plan import candidate_configs, summarize_corpus

    if corpus is None:
        corpus = synthetic_corpus(n, m, density * m, seed=seed)
    D = np.asarray(corpus, np.float32)
    n, m = D.shape

    if meshes is None:
        devs = jax.devices()
        meshes = [Mesh(np.array(devs), ("data",))]
        if len(devs) >= 4 and len(devs) % 2 == 0:
            meshes.append(
                Mesh(np.array(devs).reshape(len(devs) // 2, 2),
                     ("data", "model"))
            )

    s = summarize_corpus(D, threshold)
    entries: list = []
    seen: set = set()
    mesh_list = []
    with trace.span("obs/audit", n=n, m=m, k=k):
        for mesh in [None] + list(meshes):
            mesh_sizes = dict(mesh.shape) if mesh is not None else None
            if mesh_sizes:
                mesh_list.append(mesh_sizes)
            for cfg in candidate_configs(s, mesh, k, include_kernel=False):
                fam = (cfg.kind, cfg.schedule, cfg.accumulation, cfg.sparse)
                if fam in seen:
                    continue
                if cfg.kind == "blocked" and mesh is not None:
                    continue  # identical program regardless of mesh
                seen.add(fam)
                entries.append(_audit_planned(
                    cfg, s, D, threshold, k, mesh, mesh_sizes, analyze,
                ))
        if include_serving:
            entries.extend(_audit_serving(D, threshold, k, analyze))
    return AuditReport(
        entries=entries, n=n, m=m, k=k, threshold=float(threshold),
        meshes=mesh_list,
    )


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="model-vs-HLO audit over every plannable variant family"
    )
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--m", type=int, default=64)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--threshold", type=float, default=0.3)
    ap.add_argument("--density", type=float, default=0.2)
    ap.add_argument("--json", default=None, help="write AuditReport JSON here")
    args = ap.parse_args(argv)
    report = run_audit(
        n=args.n, m=args.m, k=args.k,
        threshold=args.threshold, density=args.density,
    )
    print(report.describe())
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report.as_dict(), f, indent=2)
            f.write("\n")
    rep = drift.drift_report(report.residuals(), band=4.0)
    print(rep.describe())
    return 0 if report.gated_ok() else 1


if __name__ == "__main__":
    raise SystemExit(main())
