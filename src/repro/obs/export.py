"""Chrome trace-event export: span trees → Perfetto / ``chrome://tracing``.

Emits the JSON *object* flavor of the trace-event format: a
``traceEvents`` list of complete (``"ph": "X"``) events — one per span,
``ts``/``dur`` in microseconds relative to the trace root — plus instant
(``"ph": "i"``) events for span point events, and thread-name metadata
(``"ph": "M"``) rows. Tracks (``tid``) are assigned one per *device-visible
phase*: the first path segment of each top-level span name (``plan``,
``execute``, ``serving``, ``sweep``, ``mutable``, ``checkpoint`` …), so
ring steps nest visually under their sweep while serving steps get their
own lane. A metrics snapshot (when a registry is passed) rides in
``otherData.metrics`` — Perfetto preserves it and ``jq`` can read it.

Spans whose ticker-derived children outlive them (async dispatch: the
wrapper returns before the device finishes) are widened to cover their
children, so the nesting renders correctly.
"""

from __future__ import annotations

import json
from typing import Optional

from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Span, Tracer


def _jsonable(v):
    if isinstance(v, (str, int, bool)) or v is None:
        return v
    if isinstance(v, float):
        return v
    return str(v)


def _span_end(s: Span) -> float:
    end = s.t1 if s.t1 is not None else s.t0
    for c in s.children:
        end = max(end, _span_end(c))
    return end


def chrome_trace(tracer: Tracer,
                 registry: Optional[MetricsRegistry] = None) -> dict:
    """Build the trace-event JSON object for ``tracer`` (finalized or not;
    an unfinalized tracer is finalized first so ring-step children exist)."""
    tracer.finalize()
    t0 = tracer.root.t0
    events: list[dict] = []
    tracks: dict[str, int] = {}

    def tid_for(name: str) -> int:
        phase = name.split("/", 1)[0]
        if phase not in tracks:
            tracks[phase] = len(tracks) + 1
        return tracks[phase]

    def emit(s: Span, tid: Optional[int]) -> None:
        my_tid = tid_for(s.name) if tid is None else tid
        args = {k: _jsonable(v) for k, v in s.attrs.items()}
        if s.status != "ok":
            args["status"] = s.status
            if s.error:
                args["error"] = s.error
        if s.records:
            args["records"] = [r.variant for r in s.records]
        events.append({
            "name": s.name,
            "ph": "X",
            "ts": (s.t0 - t0) * 1e6,
            "dur": (_span_end(s) - s.t0) * 1e6,
            "pid": 1,
            "tid": my_tid,
            "cat": s.name.split("/", 1)[0],
            "args": args,
        })
        for t, name, attrs in s.events:
            events.append({
                "name": name,
                "ph": "i",
                "s": "t",
                "ts": (t - t0) * 1e6,
                "pid": 1,
                "tid": my_tid,
                "cat": "event",
                "args": {k: _jsonable(v) for k, v in attrs.items()},
            })
        for c in s.children:
            emit(c, my_tid)

    for top in tracer.root.children:
        emit(top, None)

    meta = [
        {
            "name": "thread_name",
            "ph": "M",
            "pid": 1,
            "tid": tid,
            "args": {"name": phase},
        }
        for phase, tid in sorted(tracks.items(), key=lambda kv: kv[1])
    ]
    events.sort(key=lambda e: e["ts"])
    out = {
        "traceEvents": meta + events,
        "displayTimeUnit": "ms",
        "otherData": {"producer": "repro.obs"},
    }
    if registry is not None:
        out["otherData"]["metrics"] = registry.snapshot()
    return out


def write_chrome_trace(path: str, tracer: Tracer,
                       registry: Optional[MetricsRegistry] = None) -> dict:
    """Write :func:`chrome_trace` to ``path``; returns the object."""
    doc = chrome_trace(tracer, registry)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    return doc


def write_metrics(path: str, registry: MetricsRegistry) -> dict:
    """Write a registry snapshot (JSON, or Prometheus text when ``path``
    ends in ``.prom``/``.txt``); returns the snapshot dict."""
    snap = registry.snapshot()
    if path.endswith((".prom", ".txt")):
        with open(path, "w") as f:
            f.write(registry.to_prometheus())
    else:
        with open(path, "w") as f:
            json.dump(snap, f, indent=1)
            f.write("\n")
    return snap
