"""CSR tile kernels: the sparse APSS worklist path.

The dense worklist path (``ops.apss_fused_compacted``) still does
``O(bm·bn·m)`` MXU work per live tile — mostly zeros at the paper's
densities. This module adds the sparse twin, built on **per-block support
compaction** (the gather-densify-per-tile form of the paper's partial
indexing):

1. Host side, each row block ``B`` gets its sorted unique dimension list
   ``bdims[B] (S,)`` (``S`` = max support size over blocks, lane-padded)
   and its rows densified onto that list: ``bx[B] (bm, S)``. For
   support-coherent corpora ``S « m``.
2. The live-tile worklist comes from ``core.pruning.sparse_block_prune_mask``
   — inverted-index candidacy ∧ maxweight ∧ exact minsize — computed from
   CSR only.
3. Per live tile ``(I, J)``: block ``J``'s CSR rows are gathered onto
   ``bdims[I]`` (binary search + scatter, XLA) giving ``yg (bn, S)``; tile
   scores are then the **dense** matmul ``bx[I] · ygᵀ`` — exact, because
   every nonzero of block ``I`` lies inside its own support and dimensions
   outside it contribute zero. MXU work per tile drops from ``O(bm·bn·m)``
   to ``O(bm·bn·S)``.
4. :func:`sparse_tile_candidates_pallas` consumes ``(bx, yg)`` on a
   scalar-prefetched 1-D worklist grid and emits forward/mirror candidate
   packets exactly like the dense ``apss_tile_candidates_pallas``
   (S = Sᵀ halves work; ``ops.fold_packets`` folds them into ``Matches``).
   ``use_kernel=False`` runs the same tiles through an XLA scan instead —
   that is the production path off-TPU (Pallas interpret mode is a
   debugger, not a backend).

Exactness contract: identical ``match_set``/``counts`` to
``apss_reference`` on the densified corpus (``tests/test_sparse.py``),
duplicates-sum semantics included (duplicate coordinates land in the same
gathered slot and accumulate).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import pallas as pl

from repro.core.matches import Matches, empty_matches
from repro.core.pruning import sparse_block_prune_mask
from repro.core.sparse import SparseCorpus, pad_rows_sparse
from repro.kernels._compat import tpu_compiler_params
from repro.kernels.apss_block.fused import (
    _rect_tile_packets,
    _tile_packets,
    _topk_sort,
)
from repro.kernels.apss_block.ops import _on_tpu, compact_worklist, fold_packets


def block_support_gather(
    sp: SparseCorpus, block_m: int, *, pad_to: int = 128
) -> tuple[np.ndarray, np.ndarray]:
    """Host-side per-block support compaction.

    Returns ``bdims (nb, S)`` — sorted unique dims per row block, padded
    with the sentinel ``m`` (sorts last, matches nothing) — and
    ``bx (nb, bm, S)`` — the block's rows densified onto its own support.
    ``S`` is lane-padded (``pad_to``) for MXU alignment.
    """
    idx = np.asarray(sp.indices)
    val = np.asarray(sp.values)
    nnz = np.asarray(sp.nnz)
    n, cap = idx.shape
    assert n % block_m == 0, (n, block_m)
    nb = n // block_m
    valid = np.arange(cap)[None, :] < nnz[:, None]
    uniq = []
    for b in range(nb):
        sl = slice(b * block_m, (b + 1) * block_m)
        uniq.append(np.unique(idx[sl][valid[sl]]))
    S = max(1, max((len(u) for u in uniq), default=1))
    S = -(-S // pad_to) * pad_to
    bdims = np.full((nb, S), sp.m, np.int32)
    bx = np.zeros((nb, block_m, S), np.float32)
    rows = np.arange(block_m)[:, None]
    for b, u in enumerate(uniq):
        if len(u) == 0:
            continue
        bdims[b, : len(u)] = u
        sl = slice(b * block_m, (b + 1) * block_m)
        pos = np.searchsorted(u, idx[sl])
        pos = np.minimum(pos, len(u) - 1)
        hit = (u[pos] == idx[sl]) & valid[sl]
        np.add.at(bx[b], (rows, pos), np.where(hit, val[sl], 0.0))
    return bdims, bx


def _gather_block(bd: jax.Array, idx: jax.Array, val: jax.Array) -> jax.Array:
    """Gather one CSR block onto a support list ``bd (S,)`` → ``(bn, S)``.

    Binary search into the sorted support; misses (dims outside ``bd``,
    padding slots) contribute 0; duplicate coordinates accumulate.
    """
    S = bd.shape[0]
    pos = jnp.searchsorted(bd, idx)  # (bn, cap), in [0, S]
    in_range = jnp.minimum(pos, S - 1)
    hit = jnp.take(bd, in_range) == idx
    contrib = jnp.where(hit, val.astype(jnp.float32), 0.0)
    r = jnp.arange(idx.shape[0], dtype=jnp.int32)[:, None]
    return jnp.zeros((idx.shape[0], S), jnp.float32).at[r, in_range].add(contrib)


# ---------------------------------------------------------------------------
# Pallas kernel: 1-D worklist grid over support-compacted tiles
# ---------------------------------------------------------------------------


def _sparse_tile_kernel(
    ij_ref,     # scalar-prefetch (2, T) i32 — live (i, j) tile coordinates
    bx_ref,     # (1, bm, S) — row block densified on its own support
    yg_ref,     # (1, bm, S) — col block gathered onto the row block support
    fv_ref,     # out (1, bm, k) f32 — forward candidates (tile rows)
    fi_ref,     # out (1, bm, k) i32
    fc_ref,     # out (1, bm, 1) i32
    bv_ref,     # out (1, bm, k) f32 — backward candidates (mirror rows)
    bi_ref,     # out (1, bm, k) i32
    bc_ref,     # out (1, bm, 1) i32
    *,
    threshold: float,
    k: int,
    block_m: int,
    n_valid: int,
):
    t = pl.program_id(0)
    s = jax.lax.dot_general(
        bx_ref[0],
        yg_ref[0],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    fv, fi, fc, bv, bi, bc = _tile_packets(
        s, ij_ref[0, t], ij_ref[1, t],
        threshold=threshold, k=k, block_m=block_m, block_n=block_m,
        n_valid=n_valid,
    )
    fv_ref[0] = fv
    fi_ref[0] = fi
    fc_ref[0] = fc
    bv_ref[0] = bv
    bi_ref[0] = bi
    bc_ref[0] = bc


def sparse_tile_candidates_pallas(
    bx: jax.Array,
    yg: jax.Array,
    ij: jax.Array,
    threshold: float,
    k: int,
    *,
    block_m: int,
    n_valid: int,
    interpret: bool = False,
):
    """Per-live-tile candidate packets from support-compacted operands.

    ``bx (nb, bm, S)`` rides the scalar-prefetched row-block index
    ``ij[0, t]``; ``yg (T, bm, S)`` is per-worklist-tile. One grid step per
    live tile, one ``(bm, S)×(S, bm)`` MXU contraction each — the sparse
    analogue of ``apss_tile_candidates_pallas`` with ``S`` in place of
    ``m``.
    """
    from jax.experimental.pallas import tpu as pltpu

    nb, bm, S = bx.shape
    T = ij.shape[1]
    assert yg.shape == (T, bm, S), (yg.shape, (T, bm, S))
    assert ij.shape == (2, T)

    kernel = functools.partial(
        _sparse_tile_kernel,
        threshold=threshold, k=k, block_m=block_m, n_valid=n_valid,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(T,),
        in_specs=[
            pl.BlockSpec((1, bm, S), lambda t, ij: (ij[0, t], 0, 0)),
            pl.BlockSpec((1, bm, S), lambda t, ij: (t, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bm, k), lambda t, ij: (t, 0, 0)),
            pl.BlockSpec((1, bm, k), lambda t, ij: (t, 0, 0)),
            pl.BlockSpec((1, bm, 1), lambda t, ij: (t, 0, 0)),
            pl.BlockSpec((1, bm, k), lambda t, ij: (t, 0, 0)),
            pl.BlockSpec((1, bm, k), lambda t, ij: (t, 0, 0)),
            pl.BlockSpec((1, bm, 1), lambda t, ij: (t, 0, 0)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((T, bm, k), jnp.float32),
            jax.ShapeDtypeStruct((T, bm, k), jnp.int32),
            jax.ShapeDtypeStruct((T, bm, 1), jnp.int32),
            jax.ShapeDtypeStruct((T, bm, k), jnp.float32),
            jax.ShapeDtypeStruct((T, bm, k), jnp.int32),
            jax.ShapeDtypeStruct((T, bm, 1), jnp.int32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",)
        ),
        interpret=interpret,
    )(ij.astype(jnp.int32), bx, yg)


def _rect_sparse_tile_kernel(
    ij_ref,     # scalar-prefetch (2, T) i32 — live (qi, cj) tile coordinates
    qg_ref,     # (1, bq, S) — query block gathered onto the corpus support
    bx_ref,     # (1, bm, S) — corpus block densified on its own support
    fv_ref,     # out (1, bq, k) f32
    fi_ref,     # out (1, bq, k) i32
    fc_ref,     # out (1, bq, 1) i32
    *,
    threshold: float,
    k: int,
    block_q: int,
    block_c: int,
    nc_valid: int,
):
    t = pl.program_id(0)
    s = jax.lax.dot_general(
        qg_ref[0],
        bx_ref[0],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    fv, fi, fc = _rect_tile_packets(
        s, ij_ref[1, t],
        threshold=threshold, k=k, block_q=block_q, block_c=block_c,
        nc_valid=nc_valid,
    )
    fv_ref[0] = fv
    fi_ref[0] = fi
    fc_ref[0] = fc


def rect_sparse_tile_candidates_pallas(
    qg: jax.Array,
    bx: jax.Array,
    ij: jax.Array,
    threshold: float,
    k: int,
    *,
    block_q: int,
    block_c: int,
    nc_valid: int,
    interpret: bool = False,
):
    """Rectangular (query × sparse-corpus) per-live-tile forward packets.

    The serving twin of :func:`sparse_tile_candidates_pallas`: scoring a
    dense query block against a CSR corpus block reduces to the dense tile
    contraction ``qg · bxᵀ`` over the corpus block's OWN support — exact
    because every corpus nonzero lies inside its block support and query
    components outside it multiply stored zeros. ``qg (T, bq, S)`` is
    per-worklist-tile (the query rows' components at ``bdims[cj]``,
    gathered in XLA); ``bx (nb, bm, S)`` rides the scalar-prefetched
    corpus-block index. Forward packets only — no mirror, no self-pairs.
    """
    from jax.experimental.pallas import tpu as pltpu

    nb, bm, S = bx.shape
    T = ij.shape[1]
    assert qg.shape == (T, block_q, S), (qg.shape, (T, block_q, S))
    assert bm == block_c, (bm, block_c)
    assert ij.shape == (2, T)

    kernel = functools.partial(
        _rect_sparse_tile_kernel,
        threshold=threshold, k=k, block_q=block_q, block_c=block_c,
        nc_valid=nc_valid,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(T,),
        in_specs=[
            pl.BlockSpec((1, block_q, S), lambda t, ij: (t, 0, 0)),
            pl.BlockSpec((1, block_c, S), lambda t, ij: (ij[1, t], 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, k), lambda t, ij: (t, 0, 0)),
            pl.BlockSpec((1, block_q, k), lambda t, ij: (t, 0, 0)),
            pl.BlockSpec((1, block_q, 1), lambda t, ij: (t, 0, 0)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((T, block_q, k), jnp.float32),
            jax.ShapeDtypeStruct((T, block_q, k), jnp.int32),
            jax.ShapeDtypeStruct((T, block_q, 1), jnp.int32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",)
        ),
        interpret=interpret,
    )(ij.astype(jnp.int32), qg, bx)


# ---------------------------------------------------------------------------
# The jitted inner: gather → score → packets (XLA scan or Pallas kernel)
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit,
    static_argnames=(
        "threshold", "k", "block_m", "n_valid", "grid_m", "use_kernel",
        "interpret",
    ),
)
def _sparse_compacted_inner(
    bx, bdims, idxb, valb, ij, *,
    threshold, k, block_m, n_valid, grid_m, use_kernel, interpret,
):
    T = ij.shape[1]

    def gather_t(t):
        return _gather_block(bdims[ij[0, t]], idxb[ij[1, t]], valb[ij[1, t]])

    if use_kernel:
        # The kernel consumes per-tile gathered operands as a streamed
        # input, so the (T, bm, S) buffer is materialized; moving the
        # binary-search gather in-kernel would remove it (ROADMAP).
        _, yg = lax.scan(lambda _, t: (_, gather_t(t)), 0, jnp.arange(T))
        fv, fi, fc, bv, bi, bc = sparse_tile_candidates_pallas(
            bx, yg, ij, float(threshold), k,
            block_m=block_m, n_valid=n_valid, interpret=interpret,
        )
    else:
        # XLA path gathers INSIDE the tile scan: peak extra memory is one
        # (bm, S) tile, never O(T · bm · S).
        def tile(_, t):
            s = jnp.einsum(
                "rs,cs->rc", bx[ij[0, t]], gather_t(t),
                preferred_element_type=jnp.float32,
            )
            return _, _tile_packets(
                s, ij[0, t], ij[1, t],
                threshold=threshold, k=k, block_m=block_m, block_n=block_m,
                n_valid=n_valid, topk=_topk_sort,
            )

        _, (fv, fi, fc, bv, bi, bc) = lax.scan(tile, 0, jnp.arange(T))

    return fold_packets(
        ij, fv, fi, fc[..., 0], bv, bi, bc[..., 0],
        grid_m=grid_m, block_m=block_m, k=k,
    )


def apss_sparse_compacted(
    sp: SparseCorpus,
    threshold: float,
    k: int,
    *,
    block_m: int = 256,
    block_mask: jax.Array | None = None,
    block_ub: jax.Array | None = None,
    use_minsize: bool = True,
    use_kernel: bool = False,
    interpret: bool | None = None,
    lane_pad: int = 128,
) -> Matches:
    """Sparse self-join via inverted-index worklist + CSR tile scoring.

    The sparse twin of ``ops.apss_fused_compacted``: the live mask comes
    from CSR-only bounds (inverted-index candidacy included), the worklist
    is host-compacted (upper-triangular, S = Sᵀ mirrors, upper-bound
    ordered), and each live tile costs ``O(bm² · S)`` instead of
    ``O(bm² · m)``. ``use_kernel`` selects the Pallas worklist kernel
    (TPU; interpret off-TPU) over the jitted XLA scan. Host compaction
    makes the entry non-traceable — same contract as the dense compacted
    path. ``block_mask`` (``(nb, nb)`` LIVE bools over the row-padded
    corpus) skips the internal bound computation when the caller already
    has it (same convention as the dense ``apss_fused``); it must be
    conservative or exactness is lost. ``block_ub`` optionally carries the
    matching tile upper bounds for the adaptive worklist ordering.
    """
    if interpret is None:
        interpret = not _on_tpu()
    n = sp.n
    spp, _ = pad_rows_sparse(sp, block_m)
    grid_m = spp.n // block_m

    if block_mask is not None:
        mask, ub = block_mask, block_ub
    else:
        mask, ub = sparse_block_prune_mask(
            spp, spp, threshold, block_m, use_minsize=use_minsize,
            return_ub=True,
        )
    wl = compact_worklist(mask, ub)
    if wl is None:
        return empty_matches(n, k)
    ij = jnp.asarray(wl)

    bdims, bx = block_support_gather(spp, block_m, pad_to=lane_pad)
    idxb = spp.indices.reshape(grid_m, block_m, spp.cap)
    valb = spp.values.reshape(grid_m, block_m, spp.cap)
    values, indices, counts = _sparse_compacted_inner(
        jnp.asarray(bx), jnp.asarray(bdims), idxb, valb, ij,
        threshold=float(threshold), k=k, block_m=block_m, n_valid=n,
        grid_m=grid_m, use_kernel=use_kernel, interpret=interpret,
    )
    return Matches(values=values[:n], indices=indices[:n], counts=counts[:n])
