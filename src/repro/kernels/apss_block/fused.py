"""Pallas TPU kernels: streaming fused APSS match extraction.

The seed ``apss_block`` kernel materializes the full thresholded ``n×n``
score matrix in HBM and leaves match extraction to XLA — exactly the memory
behaviour the paper's blocking/pruning lesson says must not scale with n².
The two kernels here keep the dense score tile **VMEM-resident only** and
emit a ``Matches``-shaped ``O(n·k)`` result, so HBM traffic is proportional
to surviving candidates:

1. :func:`apss_fused_pallas` — grid ``(i, j, kf)`` with column tiles scanned
   innermost-but-one per row block. A VMEM running top-k buffer (values,
   global column ids) plus exact per-row match counts persists across the
   ``j`` axis; each tile fuses matmul → threshold → top-k merge → count.
   The ``block_prune_mask`` gates MXU work per tile with ``@pl.when``
   (a pruned tile still burns a pipeline slot — see kernel 2).

2. :func:`apss_tile_candidates_pallas` — **live-tile compaction**: a 1-D
   grid over a dense worklist of live ``(i, j)`` tile coordinates, driven
   by scalar prefetch (``PrefetchScalarGridSpec``), so pruned tiles cost
   zero pipeline slots. The worklist enumerates only upper-triangular tiles
   of the self-join (S = Sᵀ) and the kernel emits per-tile top-k candidate
   packets for BOTH orientations (forward = tile rows, backward = the
   mirrored tile columns), halving MXU work; a small XLA scan folds the
   packets into per-row-block ``Matches`` (``ops.apss_fused_compacted``).

In-kernel top-k uses iterative max-extraction (max / first-argmax / mask),
VPU-only ops that lower on Mosaic — ``lax.top_k``/sort do not. Cost is
``k`` passes over ``(bm, k + bn)`` per tile, « the tile's MXU FLOPs.

VMEM per step (defaults 256×256×512, f32): x+y tiles 1 MB, acc 256 KB,
top-k buffers 2·256·k·4B « 16 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._compat import tpu_compiler_params, vmem

# Finite stand-in for -inf inside the kernel (keeps Mosaic select/max
# NaN-free); converted to true -inf at the ops boundary. Any real similarity
# is a dot product of normalized rows, |s| « 1e30.
NEG_LARGE = -0.5e30
_VALID = -0.25e30  # values above this are real candidates


def _merge_topk(topv, topi, cand_v, cand_i, k: int):
    """Merge candidate columns into a per-row top-k buffer. Exact.

    ``topv/topi``: ``(bm, kb)`` running buffer (NEG_LARGE / -1 empty slots);
    ``cand_v/cand_i``: ``(bm, c)`` new candidates with *disjoint* ids.
    Returns the new ``(bm, kb)`` buffer holding the k best of the union
    (slots beyond k stay empty). Iterative max-extraction: k rounds of
    row-max, first-position select, mask-out — no sort, no lax.top_k.
    """
    bm, kb = topv.shape
    allv = jnp.concatenate([topv, cand_v], axis=1)
    alli = jnp.concatenate([topi, cand_i], axis=1)
    cols = allv.shape[1]
    colid = jax.lax.broadcasted_iota(jnp.int32, allv.shape, 1)
    outv, outi = [], []
    for _ in range(min(k, cols)):
        m = jnp.max(allv, axis=1, keepdims=True)
        pos = jnp.min(jnp.where(allv >= m, colid, cols), axis=1, keepdims=True)
        sel = colid == pos
        idx = jnp.sum(jnp.where(sel, alli, 0), axis=1, keepdims=True)
        valid = m > _VALID
        outv.append(jnp.where(valid, m, NEG_LARGE))
        outi.append(jnp.where(valid, idx, -1))
        allv = jnp.where(sel, NEG_LARGE, allv)
    pad = kb - len(outv)
    if pad:
        outv.append(jnp.full((bm, pad), NEG_LARGE, jnp.float32))
        outi.append(jnp.full((bm, pad), -1, jnp.int32))
    return jnp.concatenate(outv, axis=1), jnp.concatenate(outi, axis=1)


def _empty_buffers(bm: int, k: int):
    return (
        jnp.full((bm, k), NEG_LARGE, jnp.float32),
        jnp.full((bm, k), -1, jnp.int32),
    )


def _topk_sort(topv, topi, cand_v, cand_i, k: int):
    """``_merge_topk`` contract implemented with ``lax.top_k``.

    Exact same result (k best of the union, NEG_LARGE/-1 empties); for XLA
    consumers only — ``lax.top_k`` does not lower on Mosaic, which is why
    the kernels use the iterative ``_merge_topk`` instead.
    """
    allv = jnp.concatenate([topv, cand_v], axis=1)
    alli = jnp.concatenate([topi, cand_i], axis=1)
    kk = min(k, allv.shape[1])
    v, sel = jax.lax.top_k(allv, kk)
    i = jnp.take_along_axis(alli, sel, axis=1)
    valid = v > _VALID
    v = jnp.where(valid, v, NEG_LARGE)
    i = jnp.where(valid, i, -1)
    kb = topv.shape[1]
    if kk < kb:
        v = jnp.pad(v, ((0, 0), (0, kb - kk)), constant_values=NEG_LARGE)
        i = jnp.pad(i, ((0, 0), (0, kb - kk)), constant_values=-1)
    return v, i


def _tile_packets(
    s, ib, jb, *, threshold: float, k: int, block_m: int, block_n: int,
    n_valid: int, topk=_merge_topk,
):
    """One self-join tile's forward + mirror candidate packets (pure).

    THE single implementation of the exactness-critical packet convention —
    mirror candidate ids are ``grow.T``, NOT ``gcol.T`` (``gcol.T`` holds
    the mirrored row's own id); diagonal tiles emit an empty mirror (a copy
    would double-count). Shared verbatim by the dense worklist kernel
    (``_tile_cand_kernel``), the sparse CSR tile kernel, and the sparse XLA
    worklist scan (``kernels.apss_block.sparse``), so the Pallas and XLA
    paths cannot diverge. Only the top-k *selection primitive* is
    pluggable (``topk``): the default ``_merge_topk`` lowers on Mosaic,
    while XLA consumers pass :func:`_topk_sort` (same contract, faster
    under XLA) — the masking/id/mirror convention is not.

    Diagonal tiles compute and then discard the mirror selection
    (``jnp.where(diag, ...)``) — ~``k·bn·(k+bm)`` VPU ops, « the tile's
    MXU matmul — the deliberate price of keeping this branch-free and
    usable from both Pallas and XLA.

    Returns ``(fv, fi, fc, bv, bi, bc)`` with counts shaped ``(block, 1)``.
    """
    grow = ib * block_m + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    gcol = jb * block_n + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    ok = (
        (s >= jnp.float32(threshold))
        & (grow != gcol)
        & (grow < n_valid)
        & (gcol < n_valid)
    )
    empty_v, empty_i = _empty_buffers(block_m, k)
    fv, fi = topk(
        empty_v, empty_i,
        jnp.where(ok, s, NEG_LARGE), jnp.where(ok, gcol, -1), k,
    )
    fc = jnp.sum(ok, axis=1, keepdims=True, dtype=jnp.int32)

    # S = Sᵀ: the same tile scores the mirrored pairs — rows become the
    # y-block's vectors, candidate ids the x-block's.
    diag = ib == jb
    ev, ei = _empty_buffers(block_n, k)
    mv, mi = topk(
        ev, ei,
        jnp.where(ok.T, s.T, NEG_LARGE), jnp.where(ok.T, grow.T, -1), k,
    )
    bv = jnp.where(diag, ev, mv)
    bi = jnp.where(diag, ei, mi)
    bc = jnp.where(
        diag,
        jnp.int32(0),
        jnp.sum(ok.T, axis=1, keepdims=True, dtype=jnp.int32),
    )
    return fv, fi, fc, bv, bi, bc


def _rect_tile_packets(
    s, jb, *, threshold: float, k: int, block_q: int, block_c: int,
    nc_valid: int, topk=_merge_topk,
):
    """One rectangular (query-block × corpus-block) tile's candidate packet.

    The asymmetric sibling of :func:`_tile_packets` for the serving path
    (``serving.query``): queries are NOT corpus members, so there is no
    self-pair to exclude and no S = Sᵀ mirror to emit — forward packets
    only. Column validity (``gcol < nc_valid``) masks corpus row padding;
    query-row padding needs no masking here because padded rows are sliced
    off after the fold (per-row results are independent).

    Returns ``(fv (block_q, k), fi (block_q, k), fc (block_q, 1))``.
    """
    gcol = jb * block_c + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    ok = (s >= jnp.float32(threshold)) & (gcol < nc_valid)
    empty_v, empty_i = _empty_buffers(block_q, k)
    fv, fi = topk(
        empty_v, empty_i,
        jnp.where(ok, s, NEG_LARGE), jnp.where(ok, gcol, -1), k,
    )
    fc = jnp.sum(ok, axis=1, keepdims=True, dtype=jnp.int32)
    return fv, fi, fc


# ---------------------------------------------------------------------------
# Kernel 1: streaming fused extraction, (i, j, kf) grid
# ---------------------------------------------------------------------------


def _fused_kernel(
    mask_ref,   # (1, 1) i32 — live flag for this (i, j) tile
    meta_ref,   # (1, 2) i32 — [row_offset, col_offset] (dynamic)
    x_ref,      # (bm, bk)
    y_ref,      # (bn, bk)
    v_ref,      # out (bm, k) f32
    i_ref,      # out (bm, k) i32
    c_ref,      # out (bm, 1) i32
    acc_ref,    # scratch (bm, bn) f32
    topv_ref,   # scratch (bm, k) f32
    topi_ref,   # scratch (bm, k) i32
    cnt_ref,    # scratch (bm, 1) i32
    *,
    threshold: float,
    k: int,
    block_m: int,
    block_n: int,
    n_valid_cols: int,
    exclude_self: bool,
):
    i = pl.program_id(0)
    j = pl.program_id(1)
    kf = pl.program_id(2)
    nj = pl.num_programs(1)
    nkf = pl.num_programs(2)
    live = mask_ref[0, 0] != 0

    @pl.when((j == 0) & (kf == 0))
    def _init_row_block():
        topv_ref[...] = jnp.full_like(topv_ref, NEG_LARGE)
        topi_ref[...] = jnp.full_like(topi_ref, -1)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    @pl.when(kf == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(live)
    def _accumulate():
        acc_ref[...] += jax.lax.dot_general(
            x_ref[...],
            y_ref[...],
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when((kf == nkf - 1) & live)
    def _merge_tile():
        row_off = meta_ref[0, 0]
        col_off = meta_ref[0, 1]
        s = acc_ref[...]
        lcol = j * block_n + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        gcol = lcol + col_off
        ok = (s >= jnp.float32(threshold)) & (lcol < n_valid_cols)
        if exclude_self:
            grow = (
                row_off
                + i * block_m
                + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            )
            ok &= grow != gcol
        cnt_ref[...] += jnp.sum(ok, axis=1, keepdims=True, dtype=jnp.int32)
        cand_v = jnp.where(ok, s, NEG_LARGE)
        cand_i = jnp.where(ok, gcol, -1)
        newv, newi = _merge_topk(topv_ref[...], topi_ref[...], cand_v, cand_i, k)
        topv_ref[...] = newv
        topi_ref[...] = newi

    @pl.when((j == nj - 1) & (kf == nkf - 1))
    def _emit():
        v_ref[...] = topv_ref[...]
        i_ref[...] = topi_ref[...]
        c_ref[...] = cnt_ref[...]


def apss_fused_pallas(
    x: jax.Array,
    y: jax.Array,
    block_mask: jax.Array,
    meta: jax.Array,
    threshold: float,
    k: int,
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    n_valid_cols: int,
    exclude_self: bool = True,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Raw pallas_call; shapes must be tile-divisible (see ops.py wrapper).

    Args:
      x: ``(n_rows, m)`` query rows (padded).
      y: ``(n_cols, m)`` corpus rows (padded).
      block_mask: ``(n_rows/bm, n_cols/bn)`` int32; 0 ⇒ tile provably dead.
      meta: ``(1, 2)`` int32 ``[row_offset, col_offset]`` — global ids of
        ``x[0]`` / ``y[0]`` (dynamic, for self-exclusion + global indices).
      n_valid_cols: number of non-padding rows of ``y`` (static).

    Returns ``(values (n_rows, k) f32, indices (n_rows, k) i32,
    counts (n_rows, 1) i32)``. Empty slots are ``NEG_LARGE`` / ``-1``.
    """
    n_rows, m = x.shape
    n_cols, m2 = y.shape
    assert m == m2, (m, m2)
    assert n_rows % block_m == 0, (n_rows, block_m)
    assert n_cols % block_n == 0, (n_cols, block_n)
    assert m % block_k == 0, (m, block_k)
    grid = (n_rows // block_m, n_cols // block_n, m // block_k)
    assert block_mask.shape == grid[:2], (block_mask.shape, grid)

    kernel = functools.partial(
        _fused_kernel,
        threshold=threshold, k=k, block_m=block_m, block_n=block_n,
        n_valid_cols=n_valid_cols, exclude_self=exclude_self,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, kf: (i, j)),           # mask
            pl.BlockSpec((1, 2), lambda i, j, kf: (0, 0)),           # meta
            pl.BlockSpec((block_m, block_k), lambda i, j, kf: (i, kf)),
            pl.BlockSpec((block_n, block_k), lambda i, j, kf: (j, kf)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, k), lambda i, j, kf: (i, 0)),
            pl.BlockSpec((block_m, k), lambda i, j, kf: (i, 0)),
            pl.BlockSpec((block_m, 1), lambda i, j, kf: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_rows, k), jnp.float32),
            jax.ShapeDtypeStruct((n_rows, k), jnp.int32),
            jax.ShapeDtypeStruct((n_rows, 1), jnp.int32),
        ],
        scratch_shapes=[
            vmem((block_m, block_n), jnp.float32),
            vmem((block_m, k), jnp.float32),
            vmem((block_m, k), jnp.int32),
            vmem((block_m, 1), jnp.int32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")
        ),
        interpret=interpret,
    )(block_mask.astype(jnp.int32), meta.astype(jnp.int32), x, y)


# ---------------------------------------------------------------------------
# Kernel 2: live-tile compacted worklist, 1-D grid via scalar prefetch
# ---------------------------------------------------------------------------


def _tile_cand_kernel(
    ij_ref,     # scalar-prefetch (2, T) i32 — live (i, j) tile coordinates
    x_ref,      # (bm, bk)
    y_ref,      # (bn, bk)
    fv_ref,     # out (1, bm, k) f32 — forward candidates (tile rows)
    fi_ref,     # out (1, bm, k) i32
    fc_ref,     # out (1, bm, 1) i32
    bv_ref,     # out (1, bn, k) f32 — backward candidates (mirror rows)
    bi_ref,     # out (1, bn, k) i32
    bc_ref,     # out (1, bn, 1) i32
    acc_ref,    # scratch (bm, bn) f32
    *,
    threshold: float,
    k: int,
    block_m: int,
    block_n: int,
    n_valid: int,
):
    t = pl.program_id(0)
    kf = pl.program_id(1)
    nkf = pl.num_programs(1)

    @pl.when(kf == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Every worklist tile is live: no @pl.when gate, no wasted pipeline slot.
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...],
        y_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(kf == nkf - 1)
    def _emit():
        fv, fi, fc, bv, bi, bc = _tile_packets(
            acc_ref[...], ij_ref[0, t], ij_ref[1, t],
            threshold=threshold, k=k, block_m=block_m, block_n=block_n,
            n_valid=n_valid,
        )
        fv_ref[0] = fv
        fi_ref[0] = fi
        fc_ref[0] = fc
        bv_ref[0] = bv
        bi_ref[0] = bi
        bc_ref[0] = bc


def _rect_cand_kernel(
    ij_ref,     # scalar-prefetch (2|3, T) i32 — live (qi, cj[, gj]) coords
    x_ref,      # (bq, bk) query tile
    y_ref,      # (bc, bk) corpus tile
    fv_ref,     # out (1, bq, k) f32
    fi_ref,     # out (1, bq, k) i32
    fc_ref,     # out (1, bq, 1) i32
    acc_ref,    # scratch (bq, bc) f32
    *,
    threshold: float,
    k: int,
    block_q: int,
    block_c: int,
    nc_valid: int,
):
    t = pl.program_id(0)
    kf = pl.program_id(1)
    nkf = pl.num_programs(1)

    @pl.when(kf == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...],
        y_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(kf == nkf - 1)
    def _emit():
        # Packet column ids come from the LAST worklist row: for a (2, T)
        # worklist that is the local block id itself; a (3, T) worklist
        # (sharded serving) carries a separate GLOBAL block id so ids and
        # validity are evaluated in global coordinates while the DMA index
        # map still uses the device-local row 1.
        fv, fi, fc = _rect_tile_packets(
            acc_ref[...], ij_ref[ij_ref.shape[0] - 1, t],
            threshold=threshold, k=k, block_q=block_q, block_c=block_c,
            nc_valid=nc_valid,
        )
        fv_ref[0] = fv
        fi_ref[0] = fi
        fc_ref[0] = fc


def rect_tile_candidates_pallas(
    Q: jax.Array,
    C: jax.Array,
    ij: jax.Array,
    threshold: float,
    k: int,
    *,
    block_q: int = 128,
    block_c: int = 256,
    block_k: int = 512,
    nc_valid: int,
    interpret: bool = False,
):
    """Per-live-tile forward packets for the rectangular (serving) join.

    The query-time analogue of :func:`apss_tile_candidates_pallas`: ``ij``
    is the dense ``(2, T)`` worklist of live ``(query_block, corpus_block)``
    coordinates — no upper-triangular structure, no mirror packets (queries
    aren't corpus rows). The serving path bucket-pads ``T`` to a power of
    two so repeat queries never retrace; padding entries are masked at fold
    time (``ops.fold_rect_packets``), so the kernel just computes them.
    """
    from jax.experimental.pallas import tpu as pltpu

    nq, m = Q.shape
    nc, m2 = C.shape
    assert m == m2, (m, m2)
    assert nq % block_q == 0 and nc % block_c == 0, (nq, nc, block_q, block_c)
    assert m % block_k == 0, (m, block_k)
    T = ij.shape[1]
    assert ij.shape[0] in (2, 3), ij.shape
    nkf = m // block_k

    kernel = functools.partial(
        _rect_cand_kernel,
        threshold=threshold, k=k, block_q=block_q, block_c=block_c,
        nc_valid=nc_valid,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(T, nkf),
        in_specs=[
            pl.BlockSpec((block_q, block_k), lambda t, kf, ij: (ij[0, t], kf)),
            pl.BlockSpec((block_c, block_k), lambda t, kf, ij: (ij[1, t], kf)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, k), lambda t, kf, ij: (t, 0, 0)),
            pl.BlockSpec((1, block_q, k), lambda t, kf, ij: (t, 0, 0)),
            pl.BlockSpec((1, block_q, 1), lambda t, kf, ij: (t, 0, 0)),
        ],
        scratch_shapes=[vmem((block_q, block_c), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((T, block_q, k), jnp.float32),
            jax.ShapeDtypeStruct((T, block_q, k), jnp.int32),
            jax.ShapeDtypeStruct((T, block_q, 1), jnp.int32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(ij.astype(jnp.int32), Q, C)


def _rect_ee_cand_kernel(
    ij_ref,     # scalar-prefetch (2, T) i32 — live (qi, cj) tile coordinates
    x_ref,      # (bq, bk) query tile
    y_ref,      # (bc, bk) corpus tile
    ub_ref,     # (1, 1) f32 — this tile's upper bound (NEG_LARGE on padding)
    fv_ref,     # out (1, bq, k) f32
    fi_ref,     # out (1, bq, k) i32
    fc_ref,     # out (1, bq, 1) i32
    sk_ref,     # out (1, 1) i32 — 1 iff this tile was early-exit skipped
    acc_ref,    # scratch (bq, bc) f32
    topv_ref,   # scratch (nq, k) f32 — running top-k VALUES per query row
    *,
    threshold: float,
    k: int,
    block_q: int,
    block_c: int,
    nc_valid: int,
    nq_valid: int,
):
    t = pl.program_id(0)
    kf = pl.program_id(1)
    nkf = pl.num_programs(1)
    qi = ij_ref[0, t]

    @pl.when((t == 0) & (kf == 0))
    def _init_topv():
        topv_ref[...] = jnp.full_like(topv_ref, NEG_LARGE)

    # Early-exit test (recomputed per kf step — topv only moves at the last
    # kf of a *scored* tile, so every step of tile t sees the same answer):
    # the worklist is ordered by upper bound DESCENDING, so once every live
    # row of this query block already holds k real values ≥ this tile's
    # bound, no candidate in it (value ≤ ub) can enter any top-k buffer —
    # ties lose to the buffer under the stable merge. Padding rows
    # (global row ≥ nq_valid) are excluded or an unfull row would pin the
    # block forever; padding worklist entries carry ub = NEG_LARGE and are
    # always skipped.
    cur = pl.load(topv_ref, (pl.ds(qi * block_q, block_q), slice(None)))
    kth = cur[:, k - 1:k]                                   # (bq, 1)
    rows = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, 1), 0
    )
    kth = jnp.where(rows < nq_valid, kth, -NEG_LARGE)
    skip = jnp.min(kth) >= ub_ref[0, 0]

    @pl.when(~skip & (kf == 0))
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(~skip)
    def _accumulate():
        acc_ref[...] += jax.lax.dot_general(
            x_ref[...],
            y_ref[...],
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when((kf == nkf - 1) & skip)
    def _emit_neutral():
        fv_ref[0] = jnp.full((block_q, k), NEG_LARGE, jnp.float32)
        fi_ref[0] = jnp.full((block_q, k), -1, jnp.int32)
        fc_ref[0] = jnp.zeros((block_q, 1), jnp.int32)
        sk_ref[0, 0] = jnp.int32(1)

    @pl.when((kf == nkf - 1) & ~skip)
    def _emit():
        fv, fi, fc = _rect_tile_packets(
            acc_ref[...], ij_ref[1, t],
            threshold=threshold, k=k, block_q=block_q, block_c=block_c,
            nc_valid=nc_valid,
        )
        fv_ref[0] = fv
        fi_ref[0] = fi
        fc_ref[0] = fc
        sk_ref[0, 0] = jnp.int32(0)
        dummy = jnp.zeros((block_q, k), jnp.int32)
        merged_v, _ = _merge_topk(cur, dummy, fv, dummy, k)
        pl.store(
            topv_ref,
            (pl.ds(qi * block_q, block_q), slice(None)),
            merged_v,
        )


def rect_tile_candidates_early_exit_pallas(
    Q: jax.Array,
    C: jax.Array,
    ij: jax.Array,
    ub: jax.Array,
    threshold: float,
    k: int,
    *,
    block_q: int = 128,
    block_c: int = 256,
    block_k: int = 512,
    nc_valid: int,
    nq_valid: int,
    interpret: bool = False,
):
    """Early-exit-aware variant of :func:`rect_tile_candidates_pallas`.

    Carries a per-query-row running top-k VALUES buffer in VMEM scratch
    across the (sequential) tile axis; a tile whose upper bound ``ub[t]`` is
    beaten by every live row's current k-th value skips its MXU work via
    ``@pl.when`` and emits a neutral packet plus a skip flag. A Pallas grid
    cannot terminate early, so — unlike the XLA while_loop path — skipped
    tiles still occupy pipeline slots; the win is the gated matmul.

    Returns ``(fv, fi, fc, skipped)`` where ``skipped`` is ``(T, 1)`` i32.
    Exactness contract matches the XLA early-exit fold: top-k values and
    indices are bit-identical to the non-early-exit path; only counts
    beyond k are lost (the caller saturates them at k).
    """
    from jax.experimental.pallas import tpu as pltpu

    nq, m = Q.shape
    nc, m2 = C.shape
    assert m == m2, (m, m2)
    assert nq % block_q == 0 and nc % block_c == 0, (nq, nc, block_q, block_c)
    assert m % block_k == 0, (m, block_k)
    T = ij.shape[1]
    assert ij.shape == (2, T)
    nkf = m // block_k
    ub2 = ub.astype(jnp.float32).reshape(T, 1)

    kernel = functools.partial(
        _rect_ee_cand_kernel,
        threshold=threshold, k=k, block_q=block_q, block_c=block_c,
        nc_valid=nc_valid, nq_valid=nq_valid,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(T, nkf),
        in_specs=[
            pl.BlockSpec((block_q, block_k), lambda t, kf, ij: (ij[0, t], kf)),
            pl.BlockSpec((block_c, block_k), lambda t, kf, ij: (ij[1, t], kf)),
            pl.BlockSpec((1, 1), lambda t, kf, ij: (t, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, k), lambda t, kf, ij: (t, 0, 0)),
            pl.BlockSpec((1, block_q, k), lambda t, kf, ij: (t, 0, 0)),
            pl.BlockSpec((1, block_q, 1), lambda t, kf, ij: (t, 0, 0)),
            pl.BlockSpec((1, 1), lambda t, kf, ij: (t, 0)),
        ],
        scratch_shapes=[
            vmem((block_q, block_c), jnp.float32),
            vmem((nq, k), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((T, block_q, k), jnp.float32),
            jax.ShapeDtypeStruct((T, block_q, k), jnp.int32),
            jax.ShapeDtypeStruct((T, block_q, 1), jnp.int32),
            jax.ShapeDtypeStruct((T, 1), jnp.int32),
        ],
        compiler_params=tpu_compiler_params(
            # Both axes "arbitrary": the running top-k scratch carried across
            # tiles makes the t axis order-dependent (vs "parallel" in the
            # non-early-exit kernel).
            dimension_semantics=("arbitrary", "arbitrary")
        ),
        interpret=interpret,
    )(ij.astype(jnp.int32), Q, C, ub2)


def apss_tile_candidates_pallas(
    D: jax.Array,
    ij: jax.Array,
    threshold: float,
    k: int,
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    n_valid: int,
    interpret: bool = False,
):
    """Per-live-tile candidate packets for the self-join (see ops wrapper).

    ``ij`` is the dense ``(2, T)`` worklist of live upper-triangular tile
    coordinates (scalar-prefetched: the (i, j) → DMA index computation runs
    before the kernel body, so the pipeline streams exactly the live tiles
    and nothing else).

    Returns forward packets ``(T, bm, k)×2 + (T, bm, 1)`` and backward
    (mirror) packets ``(T, bn, k)×2 + (T, bn, 1)``. Total output is
    ``O(live_tiles · block · k)`` — candidate-proportional, never n².
    """
    from jax.experimental.pallas import tpu as pltpu

    n, m = D.shape
    assert n % block_m == 0 and n % block_n == 0, (n, block_m, block_n)
    assert m % block_k == 0, (m, block_k)
    T = ij.shape[1]
    assert ij.shape == (2, T)
    nkf = m // block_k

    kernel = functools.partial(
        _tile_cand_kernel,
        threshold=threshold, k=k, block_m=block_m, block_n=block_n,
        n_valid=n_valid,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(T, nkf),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda t, kf, ij: (ij[0, t], kf)),
            pl.BlockSpec((block_n, block_k), lambda t, kf, ij: (ij[1, t], kf)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_m, k), lambda t, kf, ij: (t, 0, 0)),
            pl.BlockSpec((1, block_m, k), lambda t, kf, ij: (t, 0, 0)),
            pl.BlockSpec((1, block_m, 1), lambda t, kf, ij: (t, 0, 0)),
            pl.BlockSpec((1, block_n, k), lambda t, kf, ij: (t, 0, 0)),
            pl.BlockSpec((1, block_n, k), lambda t, kf, ij: (t, 0, 0)),
            pl.BlockSpec((1, block_n, 1), lambda t, kf, ij: (t, 0, 0)),
        ],
        scratch_shapes=[vmem((block_m, block_n), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((T, block_m, k), jnp.float32),
            jax.ShapeDtypeStruct((T, block_m, k), jnp.int32),
            jax.ShapeDtypeStruct((T, block_m, 1), jnp.int32),
            jax.ShapeDtypeStruct((T, block_n, k), jnp.float32),
            jax.ShapeDtypeStruct((T, block_n, k), jnp.int32),
            jax.ShapeDtypeStruct((T, block_n, 1), jnp.int32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(ij.astype(jnp.int32), D, D)
