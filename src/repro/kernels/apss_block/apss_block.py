"""Pallas TPU kernel: fused blocked ``X·Yᵀ`` + threshold + tile skipping.

This is the paper's compute hot-spot (the score-accumulation inner loop of
all-pairs-0-array) as a TPU kernel. The three fused pieces:

1. **Tile matmul** over the feature axis with an f32 VMEM accumulator
   (``bm×bn`` scratch), grid ``(i, j, kf)`` with the feature axis innermost —
   the MXU-native realization of the paper's dense score array.
2. **Threshold filter** applied in-register before the single HBM write:
   sub-threshold scores are never materialized at full precision in HBM
   (the paper's "filter during accumulation" carried to the memory
   hierarchy: HBM sees only the thresholded result).
3. **Block pruning**: a ``(grid_m, grid_n)`` live mask — from
   ``core.pruning.block_prune_mask`` (maxweight / minsize bounds at tile
   granularity) — gates the matmul with ``@pl.when``, so dead tiles issue no
   MXU work and no X/Y VMEM reads beyond the pipelined fetch.

TPU sizing (v5e): default tiles 256×256×512 → VMEM footprint
2·(256·512·2B) + 256·256·4B ≈ 0.8 MB « 16 MB VMEM, MXU-aligned (multiples
of 128 on every contraction/output dim).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _apss_block_kernel(
    mask_ref,  # (1, 1) i32 — live flag for this (i, j) tile
    x_ref,     # (bm, bk)
    y_ref,     # (bn, bk)
    o_ref,     # (bm, bn)
    acc_ref,   # VMEM scratch (bm, bn) f32
    *,
    threshold: float,
    out_dtype,
):
    kf = pl.program_id(2)
    nkf = pl.num_programs(2)
    live = mask_ref[0, 0] != 0

    @pl.when(kf == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(live)
    def _accumulate():
        acc_ref[...] += jax.lax.dot_general(
            x_ref[...],
            y_ref[...],
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(kf == nkf - 1)
    def _emit():
        acc = acc_ref[...]
        keep = (acc >= jnp.float32(threshold)) & live
        o_ref[...] = jnp.where(keep, acc, 0.0).astype(out_dtype)


def apss_block_pallas(
    x: jax.Array,
    y: jax.Array,
    block_mask: jax.Array,
    threshold: float,
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """Raw pallas_call; shapes must be tile-divisible (see ops.py wrapper).

    Args:
      x: ``(n_rows, m)`` query rows.
      y: ``(n_cols, m)`` corpus rows.
      block_mask: ``(n_rows/bm, n_cols/bn)`` int32; 0 ⇒ tile provably dead.
      threshold: similarity threshold ``t`` (static).
    """
    n_rows, m = x.shape
    n_cols, m2 = y.shape
    assert m == m2, (m, m2)
    assert n_rows % block_m == 0, (n_rows, block_m)
    assert n_cols % block_n == 0, (n_cols, block_n)
    assert m % block_k == 0, (m, block_k)
    grid = (n_rows // block_m, n_cols // block_n, m // block_k)
    assert block_mask.shape == grid[:2], (block_mask.shape, grid)

    kernel = functools.partial(
        _apss_block_kernel, threshold=threshold, out_dtype=out_dtype
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, kf: (i, j)),          # mask
            pl.BlockSpec((block_m, block_k), lambda i, j, kf: (i, kf)),
            pl.BlockSpec((block_n, block_k), lambda i, j, kf: (j, kf)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kf: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_rows, n_cols), out_dtype),
        scratch_shapes=[_vmem((block_m, block_n), jnp.float32)],
        compiler_params=_tpu_params(),
        interpret=interpret,
    )(block_mask.astype(jnp.int32), x, y)


def _vmem(shape, dtype):
    from repro.kernels._compat import vmem

    return vmem(shape, dtype)


def _tpu_params():
    """Mark (i, j) parallel and the feature axis sequential for the TPU
    pipeline; harmless under interpret mode."""
    from repro.kernels._compat import tpu_compiler_params

    return tpu_compiler_params(
        dimension_semantics=("parallel", "parallel", "arbitrary")
    )
