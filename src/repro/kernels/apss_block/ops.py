"""Public jit'd wrappers for the apss_block kernels.

Handles padding to tile multiples, optional automatic bound-mask computation
(``core.pruning``), and the CPU/TPU dispatch (interpret mode off-TPU).

Three entry points:

- :func:`apss_block_matmul` — the seed dense-output kernel: thresholded
  ``n×n`` score matrix in HBM (kept for benchmarks/validation; O(n²) HBM).
- :func:`apss_fused` — streaming fused extraction: matmul → threshold →
  top-k merge → count in one kernel, ``Matches``-shaped ``O(n·k)`` output.
  The ``n×n`` score matrix never exists in HBM.
- :func:`apss_fused_compacted` — fused extraction driven by a dense
  worklist of live upper-triangular tiles (scalar prefetch): pruned tiles
  cost zero pipeline slots and S = Sᵀ halves MXU work. Self-join only;
  the live mask is compacted on the host, so the call is not traceable
  under jit (the inner per-worklist computation is).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.matches import NEG_INF, Matches, empty_matches
from repro.core.pruning import block_prune_mask
from repro.kernels.apss_block.apss_block import apss_block_pallas
from repro.kernels.apss_block.fused import (
    _VALID,
    apss_fused_pallas,
    apss_tile_candidates_pallas,
)


def _pad_to(x: jax.Array, mult0: int, mult1: int) -> jax.Array:
    p0 = (-x.shape[0]) % mult0
    p1 = (-x.shape[1]) % mult1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit,
    static_argnames=(
        "threshold", "block_m", "block_n", "block_k",
        "auto_mask", "interpret",
    ),
)
def apss_block_matmul(
    x: jax.Array,
    y: jax.Array,
    threshold: float,
    *,
    block_mask: jax.Array | None = None,
    auto_mask: bool = True,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Thresholded similarity tile ``where(X·Yᵀ ≥ t, ·, 0)`` with tile
    skipping.

    If ``block_mask`` is None and ``auto_mask``, the maxweight/minsize bound
    mask is computed on the fly (one cheap summary matmul); pass
    ``auto_mask=False`` to run fully dense.
    """
    if interpret is None:
        interpret = not _on_tpu()

    n_rows, m = x.shape
    n_cols = y.shape[0]
    xp = _pad_to(x, block_m, block_k)
    yp = _pad_to(y, block_n, block_k)
    grid_m = xp.shape[0] // block_m
    grid_n = yp.shape[0] // block_n

    if block_mask is None:
        if auto_mask:
            block_mask = block_prune_mask(
                xp, yp, threshold, block_m, block_n, use_minsize=False
            )
        else:
            block_mask = jnp.ones((grid_m, grid_n), jnp.int32)

    out = apss_block_pallas(
        xp, yp, block_mask, threshold,
        block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=interpret,
    )
    return out[:n_rows, :n_cols]


def _pick_bk(m: int, block_k: int) -> int:
    """Feature-axis tile: requested size, shrunk for narrow inputs so the
    zero-padding stays < one tile (MXU lane alignment: multiples of 128)."""
    return min(block_k, max(128, -(-m // 128) * 128))


@functools.partial(
    jax.jit,
    static_argnames=(
        "threshold", "k", "block_m", "block_n", "block_k",
        "auto_mask", "exclude_self", "interpret",
    ),
)
def apss_fused(
    x: jax.Array,
    y: jax.Array,
    threshold: float,
    k: int,
    *,
    block_mask: jax.Array | None = None,
    auto_mask: bool = True,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    row_offset: jax.Array | int = 0,
    col_offset: jax.Array | int = 0,
    exclude_self: bool = True,
    interpret: bool | None = None,
) -> Matches:
    """Fused streaming similarity join: ``Matches`` straight from the kernel.

    The thresholded score matrix never reaches HBM — the kernel scans column
    tiles per row block with a VMEM-resident running top-k + exact counts,
    so output memory is ``O(n_rows · k)``. Offsets are dynamic (traced), so
    this drops into the distributed ring/halfring schedules where the
    column offset depends on the ring step.
    """
    if interpret is None:
        interpret = not _on_tpu()

    nq, m = x.shape
    nc = y.shape[0]
    bk = _pick_bk(m, block_k)
    xp = _pad_to(x, block_m, bk)
    yp = _pad_to(y, block_n, bk)

    if block_mask is None:
        if auto_mask:
            block_mask = block_prune_mask(
                xp, yp, threshold, block_m, block_n, use_minsize=False
            )
        else:
            block_mask = jnp.ones(
                (xp.shape[0] // block_m, yp.shape[0] // block_n), jnp.int32
            )

    meta = jnp.stack(
        [jnp.asarray(row_offset, jnp.int32), jnp.asarray(col_offset, jnp.int32)]
    ).reshape(1, 2)
    values, indices, counts = apss_fused_pallas(
        xp, yp, block_mask, meta, float(threshold), k,
        block_m=block_m, block_n=block_n, block_k=bk,
        n_valid_cols=nc, exclude_self=exclude_self, interpret=interpret,
    )
    values = jnp.where(indices >= 0, values, NEG_INF)
    return Matches(
        values=values[:nq],
        indices=indices[:nq],
        counts=counts[:nq, 0],
    )


def _merge_packet(cv, ci, cc, blk, pv, pi, pc, k: int):
    """Fold one tile candidate packet into the per-row-block running top-k.

    Packet ids are disjoint from the buffer's (each column block is visited
    once per row block; forward/backward packets for the same row block come
    from disjoint column ranges), so a plain top-k over the concat is exact.
    """
    cur_v = jax.lax.dynamic_index_in_dim(cv, blk, 0, keepdims=False)
    cur_i = jax.lax.dynamic_index_in_dim(ci, blk, 0, keepdims=False)
    cur_c = jax.lax.dynamic_index_in_dim(cc, blk, 0, keepdims=False)
    vals = jnp.concatenate([cur_v, pv], axis=1)
    idxs = jnp.concatenate([cur_i, pi], axis=1)
    tv, sel = jax.lax.top_k(vals, k)
    ti = jnp.take_along_axis(idxs, sel, axis=1)
    ti = jnp.where(tv > _VALID, ti, -1)
    cv = jax.lax.dynamic_update_index_in_dim(cv, tv, blk, 0)
    ci = jax.lax.dynamic_update_index_in_dim(ci, ti, blk, 0)
    cc = jax.lax.dynamic_update_index_in_dim(cc, cur_c + pc, blk, 0)
    return cv, ci, cc


def compact_worklist(mask, ub=None) -> np.ndarray | None:
    """Host-side live-mask → dense upper-triangular worklist ``(2, T)``.

    Symmetrizes first (the minsize bound is asymmetric: a pair is live if
    either orientation is) then keeps ``j ≥ i`` only — each off-diagonal
    tile is computed once for both orientations (S = Sᵀ). Returns None when
    nothing is live. Shared by the dense and sparse compacted paths so the
    exactness-critical mirror convention lives in one place.

    ``ub`` (``(nb, nb)`` f32 tile upper bounds, as returned by
    ``core.pruning.live_tile_mask(return_ub=True)``) enables the paper's
    maxweight **adaptive ordering**: live tiles are sorted by upper bound
    descending, so the tiles most likely to carry matches run first and
    any future early-exit threshold tightens fastest while the worklist
    drains. Results are order-invariant (each tile's packet is folded into
    an exact running top-k — asserted by ``tests/test_apss_fused.py``);
    ordering only shifts WHERE the matches are found early.
    """
    live = np.asarray(mask)
    live = np.triu(live | live.T)
    iu, ju = np.nonzero(live)
    if iu.size == 0:
        return None
    if ub is not None:
        u = np.asarray(ub, np.float64)
        u = np.maximum(u, u.T)  # match the symmetrized liveness
        order = np.argsort(-u[iu, ju], kind="stable")
        iu, ju = iu[order], ju[order]
    return np.stack([iu, ju]).astype(np.int32)


def compact_rect_worklist(mask, ub=None) -> np.ndarray | None:
    """Host-side live-mask → dense rectangular worklist ``(2, T)``.

    The serving-path sibling of :func:`compact_worklist`: no symmetry, no
    triangular cut — every live ``(query_block, corpus_block)`` tile is
    listed once. Same optional upper-bound descending order.
    """
    live = np.asarray(mask)
    iu, ju = np.nonzero(live)
    if iu.size == 0:
        return None
    if ub is not None:
        order = np.argsort(-np.asarray(ub, np.float64)[iu, ju], kind="stable")
        iu, ju = iu[order], ju[order]
    return np.stack([iu, ju]).astype(np.int32)


def pad_worklist(wl: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Bucket-pad a ``(2, T)`` worklist to the next power of two.

    Serving calls see a different live-tile count per query batch; without
    bucketing every new ``T`` would retrace (and recompile) the jitted
    scoring path. Padding entries repeat tile ``(0, 0)`` (always valid
    memory) and are masked out of the fold by the returned ``(Tb,)`` bool
    validity vector — so the amortized trace count is O(log live-tiles),
    not O(distinct worklist lengths).
    """
    T = wl.shape[1]
    Tb = 1 << max(0, (T - 1).bit_length())
    valid = np.zeros((Tb,), bool)
    valid[:T] = True
    if Tb == T:
        return wl, valid
    pad = np.zeros((2, Tb - T), np.int32)
    return np.concatenate([wl, pad], axis=1), valid


def fold_rect_packets(ij, tvalid, fv, fi, fc, *, grid_q, block_q, k):
    """XLA scan folding rectangular forward packets into flat buffers.

    The serving twin of :func:`fold_packets`: forward packets only (no
    mirror — queries aren't corpus rows), plus a ``(T,)`` validity mask for
    bucket padding (``pad_worklist``): invalid entries are neutralized
    (values → −∞, ids → −1, counts → 0) BEFORE the merge so a padding
    entry that aliases a real tile can never double-count.
    """
    dead = ~tvalid
    fv = jnp.where(dead[:, None, None], NEG_INF, fv)
    fi = jnp.where(dead[:, None, None], -1, fi)
    fc = jnp.where(dead[:, None], 0, fc)

    def step(carry, inp):
        cv, ci, cc = carry
        ib, fv_t, fi_t, fc_t = inp
        cv, ci, cc = _merge_packet(cv, ci, cc, ib, fv_t, fi_t, fc_t, k)
        return (cv, ci, cc), None

    carry0 = (
        jnp.full((grid_q, block_q, k), -jnp.inf, jnp.float32),
        jnp.full((grid_q, block_q, k), -1, jnp.int32),
        jnp.zeros((grid_q, block_q), jnp.int32),
    )
    (cv, ci, cc), _ = jax.lax.scan(step, carry0, (ij[0], fv, fi, fc))
    values = jnp.where(ci >= 0, cv, NEG_INF).reshape(grid_q * block_q, k)
    indices = ci.reshape(grid_q * block_q, k)
    counts = cc.reshape(grid_q * block_q)
    return values, indices, counts


def fold_packets(ij, fv, fi, fc, bv, bi, bc, *, grid_m, block_m, k):
    """XLA scan folding per-live-tile candidate packets into flat buffers.

    ``ij (2, T)`` worklist of upper-triangular tile coordinates; ``f*`` are
    the forward packets (rows of block ``ij[0, t]``), ``b*`` the mirror
    packets (rows of block ``ij[1, t]``; empty on diagonal tiles). Counts
    are ``(T, block_m)``. Exactness relies on the worklist contract: packet
    ids entering one row block come from disjoint column ranges. Shared by
    the dense (:func:`apss_fused_compacted`) and sparse
    (``kernels.apss_block.sparse``) worklist paths.
    """

    def step(carry, inp):
        cv, ci, cc = carry
        ib, jb, fv_t, fi_t, fc_t, bv_t, bi_t, bc_t = inp
        cv, ci, cc = _merge_packet(cv, ci, cc, ib, fv_t, fi_t, fc_t, k)
        # Mirror packet (empty for diagonal tiles): rows of block jb.
        cv, ci, cc = _merge_packet(cv, ci, cc, jb, bv_t, bi_t, bc_t, k)
        return (cv, ci, cc), None

    carry0 = (
        jnp.full((grid_m, block_m, k), -jnp.inf, jnp.float32),
        jnp.full((grid_m, block_m, k), -1, jnp.int32),
        jnp.zeros((grid_m, block_m), jnp.int32),
    )
    (cv, ci, cc), _ = jax.lax.scan(
        step, carry0, (ij[0], ij[1], fv, fi, fc, bv, bi, bc)
    )
    values = jnp.where(ci >= 0, cv, NEG_INF).reshape(grid_m * block_m, k)
    indices = ci.reshape(grid_m * block_m, k)
    counts = cc.reshape(grid_m * block_m)
    return values, indices, counts


@functools.partial(
    jax.jit,
    static_argnames=(
        "threshold", "k", "block_m", "block_k", "n_valid", "grid_m",
        "interpret",
    ),
)
def _compacted_inner(
    Dp, ij, *, threshold, k, block_m, block_k, n_valid, grid_m, interpret
):
    fv, fi, fc, bv, bi, bc = apss_tile_candidates_pallas(
        Dp, ij, float(threshold), k,
        block_m=block_m, block_n=block_m, block_k=block_k,
        n_valid=n_valid, interpret=interpret,
    )
    return fold_packets(
        ij, fv, fi, fc[..., 0], bv, bi, bc[..., 0],
        grid_m=grid_m, block_m=block_m, k=k,
    )


def apss_fused_compacted(
    D: jax.Array,
    threshold: float,
    k: int,
    *,
    block_m: int = 256,
    block_k: int = 512,
    use_minsize: bool = True,
    interpret: bool | None = None,
) -> Matches:
    """Self-join via the live-tile worklist kernel (maximum pruning win).

    The block bound mask is compacted ON THE HOST into a dense list of live
    upper-triangular ``(i, j)`` tile coordinates; the kernel's 1-D grid then
    runs exactly ``live`` steps (a pruned tile costs nothing, vs. a masked
    no-op pipeline slot in :func:`apss_fused`) and each off-diagonal tile is
    computed once for both orientations (S = Sᵀ). Host compaction makes this
    entry non-traceable; everything downstream of the worklist is jitted.
    """
    if interpret is None:
        interpret = not _on_tpu()
    n, m = D.shape
    bk = _pick_bk(m, block_k)
    Dp = _pad_to(D, block_m, bk)
    grid_m = Dp.shape[0] // block_m

    mask, ub = block_prune_mask(
        Dp, Dp, threshold, block_m, block_m, use_minsize=use_minsize,
        return_ub=True,
    )
    wl = compact_worklist(mask, ub)
    if wl is None:
        return empty_matches(n, k)
    ij = jnp.asarray(wl)

    values, indices, counts = _compacted_inner(
        Dp, ij, threshold=float(threshold), k=k, block_m=block_m,
        block_k=bk, n_valid=n, grid_m=grid_m, interpret=interpret,
    )
    return Matches(
        values=values[:n], indices=indices[:n], counts=counts[:n]
    )
