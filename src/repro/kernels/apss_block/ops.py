"""Public jit'd wrapper for the apss_block kernel.

Handles padding to tile multiples, optional automatic bound-mask computation
(``core.pruning``), and the CPU/TPU dispatch (interpret mode off-TPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.pruning import block_prune_mask
from repro.kernels.apss_block.apss_block import apss_block_pallas


def _pad_to(x: jax.Array, mult0: int, mult1: int) -> jax.Array:
    p0 = (-x.shape[0]) % mult0
    p1 = (-x.shape[1]) % mult1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit,
    static_argnames=(
        "threshold", "block_m", "block_n", "block_k",
        "auto_mask", "interpret",
    ),
)
def apss_block_matmul(
    x: jax.Array,
    y: jax.Array,
    threshold: float,
    *,
    block_mask: jax.Array | None = None,
    auto_mask: bool = True,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Thresholded similarity tile ``where(X·Yᵀ ≥ t, ·, 0)`` with tile
    skipping.

    If ``block_mask`` is None and ``auto_mask``, the maxweight/minsize bound
    mask is computed on the fly (one cheap summary matmul); pass
    ``auto_mask=False`` to run fully dense.
    """
    if interpret is None:
        interpret = not _on_tpu()

    n_rows, m = x.shape
    n_cols = y.shape[0]
    xp = _pad_to(x, block_m, block_k)
    yp = _pad_to(y, block_n, block_k)
    grid_m = xp.shape[0] // block_m
    grid_n = yp.shape[0] // block_n

    if block_mask is None:
        if auto_mask:
            block_mask = block_prune_mask(
                xp, yp, threshold, block_m, block_n, use_minsize=False
            )
        else:
            block_mask = jnp.ones((grid_m, grid_n), jnp.int32)

    out = apss_block_pallas(
        xp, yp, block_mask, threshold,
        block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=interpret,
    )
    return out[:n_rows, :n_cols]
