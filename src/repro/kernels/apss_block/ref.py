"""Pure-jnp oracle for the apss_block kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def apss_block_reference(
    x: jax.Array,
    y: jax.Array,
    threshold: float,
    *,
    block_mask: jax.Array | None = None,
    block_m: int = 128,
    block_n: int = 128,
) -> jax.Array:
    """Thresholded similarity scores: ``where(S ≥ t, S, 0)`` with optional
    block masking.

    ``block_mask[i, j] == 0`` declares tile ``(i, j)`` dead (the kernel skips
    its matmul); the oracle zeroes the same region so kernel and oracle agree
    for any mask. When the mask comes from ``core.pruning.block_prune_mask``
    the masked tiles provably contain no score ≥ t, so the masked and unmasked
    oracles coincide (asserted by the property tests).
    """
    s = jnp.einsum(
        "im,jm->ij", x, y, preferred_element_type=jnp.float32
    )
    out = jnp.where(s >= jnp.float32(threshold), s, 0.0)
    if block_mask is not None:
        live = jnp.repeat(
            jnp.repeat(block_mask.astype(bool), block_m, axis=0),
            block_n,
            axis=1,
        )
        out = jnp.where(live, out, 0.0)
    return out
