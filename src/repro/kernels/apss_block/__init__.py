from repro.kernels.apss_block.ops import apss_block_matmul  # noqa: F401
from repro.kernels.apss_block.ref import apss_block_reference  # noqa: F401
