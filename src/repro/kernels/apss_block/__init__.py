from repro.kernels.apss_block.ops import (  # noqa: F401
    apss_block_matmul,
    apss_fused,
    apss_fused_compacted,
)
from repro.kernels.apss_block.ref import apss_block_reference  # noqa: F401
from repro.kernels.apss_block.sparse import apss_sparse_compacted  # noqa: F401
