from repro.kernels.flash_attention.ops import flash_attention  # noqa: F401
from repro.kernels.flash_attention.ref import attention_reference  # noqa: F401
