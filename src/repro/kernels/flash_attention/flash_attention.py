"""Pallas TPU kernel: causal GQA flash attention (forward / prefill path).

IO-aware attention (FlashAttention, arXiv:2205.14135) adapted to the TPU
memory hierarchy: Q/K/V stream HBM→VMEM in MXU-aligned tiles, the softmax
running statistics (row max ``m``, row sum ``l``) and the output accumulator
live in VMEM scratch across the KV grid axis, and causally-dead KV tiles are
skipped with ``@pl.when`` (the same tile-predication idea as the APSS block
kernel — the APSS block bound mask and the causal mask are both
tile-granular pruning).

Grid: ``(batch, q_heads, q_blocks, kv_blocks)``, KV innermost.
GQA is handled in the K/V index maps (``kv_head = q_head // group``), so no
repeated K/V materialization in HBM.

VMEM per step (defaults bq=bk=512, D=128, bf16 in / f32 acc):
q,k,v tiles 3·512·128·2B ≈ 0.4 MB + acc 512·128·4B ≈ 0.26 MB « 16 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_LARGE = -0.5e30  # finite stand-in for -inf (keeps exp() NaN-free)


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref,
    acc_ref, m_ref, l_ref,
    *,
    scale: float,
    block_q: int,
    block_k: int,
    causal: bool,
):
    i = pl.program_id(2)
    j = pl.program_id(3)
    nj = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_LARGE)
        l_ref[...] = jnp.zeros_like(l_ref)

    # Causal tile skip: KV tile strictly above the diagonal band is dead.
    live = (j * block_k <= (i + 1) * block_q - 1) if causal else True

    @pl.when(live)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32) * scale   # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)           # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                             # (bq, bk)
        if causal:
            qi = i * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            ki = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(qi >= ki, s, NEG_LARGE)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        m_ref[...] = m_new
        v = v_ref[0, 0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32
        )

    @pl.when(j == nj - 1)
    def _emit():
        l = l_ref[...]
        o = acc_ref[...] / jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = o.astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,  # (B, Hq, S, D)
    k: jax.Array,  # (B, Hkv, S, D)
    v: jax.Array,  # (B, Hkv, S, D)
    *,
    scale: float | None = None,
    causal: bool = True,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    grid = (b, hq, s // block_q, s // block_k)

    kernel = functools.partial(
        _flash_kernel,
        scale=scale, block_q=block_q, block_k=block_k, causal=causal,
    )
    from jax.experimental.pallas import tpu as pltpu

    from repro.kernels._compat import tpu_compiler_params

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (1, 1, block_q, d), lambda b_, h, i, j: (b_, h, i, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_k, d),
                lambda b_, h, i, j, group=group: (b_, h // group, j, 0),
            ),
            pl.BlockSpec(
                (1, 1, block_k, d),
                lambda b_, h, i, j, group=group: (b_, h // group, j, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, d), lambda b_, h, i, j: (b_, h, i, 0)
        ),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(q, k, v)
