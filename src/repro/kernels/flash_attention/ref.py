"""Pure-jnp oracle for causal GQA attention."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_reference(
    q: jax.Array,  # (B, Hq, S, D)
    k: jax.Array,  # (B, Hkv, S, D)
    v: jax.Array,  # (B, Hkv, S, D)
    *,
    causal: bool = True,
    scale: float | None = None,
) -> jax.Array:
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum(
        "bhqd,bhkd->bhqk", q, kx, preferred_element_type=jnp.float32
    ) * scale
    if causal:
        qi = jnp.arange(s)[:, None]
        ki = jnp.arange(s)[None, :]
        logits = jnp.where(qi >= ki, logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum(
        "bhqk,bhkd->bhqd", w, vx.astype(jnp.float32)
    )
    return out.astype(q.dtype)
