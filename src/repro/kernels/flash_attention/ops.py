"""Public jit'd wrapper for flash attention (padding + dispatch)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Causal GQA flash attention ``(B, Hq, S, D) → (B, Hq, S, D)``.

    Sequence lengths are padded to the tile size internally; for causal
    attention trailing padded queries attend only to themselves and are
    sliced away, so padding never changes visible outputs.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, hq, s, d = q.shape
    bq = min(block_q, max(128, 1 << (s - 1).bit_length()))
    bk = min(block_k, bq)
    pad = (-s) % max(bq, bk)
    if pad and not causal:
        # Zero-padded keys are only provably masked under causal attention.
        raise ValueError("non-causal flash_attention requires tile-divisible S")
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    out = flash_attention_pallas(
        q, k, v, scale=scale, causal=causal,
        block_q=bq, block_k=bk, interpret=interpret,
    )
    return out[:, :, :s, :]
