"""Pallas TPU kernels for the performance-critical compute layers.

Kernels (each subpackage has ``<name>.py`` with the ``pl.pallas_call`` +
BlockSpec implementation, ``ops.py`` with the jit'd public wrapper, and
``ref.py`` with the pure-jnp oracle):

- :mod:`repro.kernels.apss_block` — the paper's kernel: fused blocked
  ``X·Yᵀ`` with threshold filtering and ``@pl.when`` tile skipping driven by
  the maxweight block-bound mask (partial-indexing/minsize pruning at MXU
  tile granularity).
- :mod:`repro.kernels.flash_attention` — causal GQA flash attention
  (prefill path of the LM architectures).
- :mod:`repro.kernels.decode_attention` — flash-decode partials
  (local max / sum-exp / weighted accumulator) for sequence-sharded KV
  caches; the cross-device combine mirrors the paper's vertical partial-score
  accumulation with the softmax monoid instead of (+).

The container is CPU-only, so kernels are *validated* with
``interpret=True`` (Python/CPU execution of the kernel body) and *targeted*
at TPU v5e (MXU-aligned 128-multiple tiles, VMEM-sized working sets).
"""

from repro.kernels.apss_block.ops import apss_block_matmul  # noqa: F401
from repro.kernels.flash_attention.ops import flash_attention  # noqa: F401
from repro.kernels.decode_attention.ops import (  # noqa: F401
    decode_attention,
    combine_partials,
)
