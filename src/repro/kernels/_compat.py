"""Pallas-TPU API shims shared by all kernels.

``pltpu.CompilerParams`` (new name) vs ``pltpu.TPUCompilerParams`` (old
name) — identical fields, renamed across JAX releases. Every kernel's
``compiler_params=`` goes through :func:`tpu_compiler_params` so the
try/except lives once instead of per kernel.
"""

from __future__ import annotations


def tpu_compiler_params(**kwargs):
    """Build TPU compiler params under whichever name this JAX exposes.

    Returns None when neither class exists (e.g. a CPU-only Pallas build);
    ``pl.pallas_call(compiler_params=None)`` is accepted everywhere.
    """
    try:
        from jax.experimental.pallas import tpu as pltpu
    except Exception:  # pragma: no cover - pallas without a TPU plugin
        return None
    cls = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams", None
    )
    if cls is None:  # pragma: no cover
        return None
    return cls(**kwargs)


def vmem(shape, dtype):
    """VMEM scratch allocation (TPU); plain buffer under interpret mode."""
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
