"""Public wrappers: single-shard decode attention + shard combine."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.decode_attention import (
    decode_attention_pallas,
)


@functools.partial(
    jax.jit, static_argnames=("scale", "block_k", "interpret")
)
def decode_attention(
    q: jax.Array,        # (B, Hq, D)
    k: jax.Array,        # (B, Hkv, L, D)
    v: jax.Array,        # (B, Hkv, L, D)
    lengths: jax.Array,  # (B,)
    *,
    scale: float | None = None,
    block_k: int = 1024,
    interpret: bool | None = None,
) -> jax.Array:
    """Normalized decode attention over one cache shard ``(B, Hq, D)``."""
    acc, m, l = decode_attention_partials(
        q, k, v, lengths, scale=scale, block_k=block_k, interpret=interpret
    )
    return acc / jnp.where(l == 0.0, 1.0, l)[..., None]


def decode_attention_partials(
    q, k, v, lengths, *, scale=None, block_k=1024, interpret=None
):
    """Unnormalized flash-decode partials ``(acc, m, l)`` for shard combine."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    L = k.shape[2]
    bk = min(block_k, L)
    pad = (-L) % bk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    return decode_attention_pallas(
        q, k, v, lengths.astype(jnp.int32),
        scale=scale, block_k=bk, interpret=interpret,
    )


def combine_partials(
    accs: jax.Array,  # (P, B, Hq, D)
    ms: jax.Array,    # (P, B, Hq)
    ls: jax.Array,    # (P, B, Hq)
) -> jax.Array:
    """Exact logsumexp-monoid merge of per-shard decode partials."""
    m_star = jnp.max(ms, axis=0)
    w = jnp.exp(ms - m_star[None])
    num = jnp.sum(accs * w[..., None], axis=0)
    den = jnp.sum(ls * w, axis=0)
    return num / jnp.where(den == 0.0, 1.0, den)[..., None]
