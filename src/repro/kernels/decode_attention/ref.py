"""Pure-jnp oracles for flash-decode attention partials and their combine."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_partials_reference(
    q: jax.Array,        # (B, Hq, D) one new token per sequence
    k: jax.Array,        # (B, Hkv, L, D) local KV-cache shard
    v: jax.Array,        # (B, Hkv, L, D)
    lengths: jax.Array,  # (B,) valid cache length per sequence (local shard)
    *,
    scale: float | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Unnormalized partial attention over a local cache shard.

    Returns ``(acc, m, l)`` with ``acc = Σ exp(s - m)·v``, ``m = max s``,
    ``l = Σ exp(s - m)`` — the logsumexp-monoid partial that
    :func:`combine_partials_reference` merges across shards. This mirrors the
    paper's vertical partial-score accumulation with (max, Σexp) replacing
    (+).
    """
    b, hq, d = q.shape
    hkv, L = k.shape[1], k.shape[2]
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    kx = jnp.repeat(k, group, axis=1)  # (B, Hq, L, D)
    vx = jnp.repeat(v, group, axis=1)
    s = jnp.einsum(
        "bhd,bhld->bhl", q.astype(jnp.float32), kx.astype(jnp.float32)
    ) * scale
    valid = jnp.arange(L)[None, None, :] < lengths[:, None, None]
    s = jnp.where(valid, s, -0.5e30)
    m = jnp.max(s, axis=-1, keepdims=True)          # (B, Hq, 1)
    p = jnp.exp(s - m)
    p = jnp.where(valid, p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)          # (B, Hq, 1)
    acc = jnp.einsum("bhl,bhld->bhd", p, vx.astype(jnp.float32))
    return acc, m[..., 0], l[..., 0]


def combine_partials_reference(
    accs: jax.Array,  # (P, B, Hq, D)
    ms: jax.Array,    # (P, B, Hq)
    ls: jax.Array,    # (P, B, Hq)
) -> jax.Array:
    m_star = jnp.max(ms, axis=0)                       # (B, Hq)
    w = jnp.exp(ms - m_star[None])                     # (P, B, Hq)
    num = jnp.sum(accs * w[..., None], axis=0)
    den = jnp.sum(ls * w, axis=0)
    return num / jnp.where(den == 0.0, 1.0, den)[..., None]


def decode_attention_reference(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    lengths: jax.Array,
    *,
    scale: float | None = None,
) -> jax.Array:
    """Full (single-shard) decode attention oracle ``(B, Hq, D)``."""
    acc, m, l = decode_partials_reference(q, k, v, lengths, scale=scale)
    return acc / jnp.where(l == 0.0, 1.0, l)[..., None]
