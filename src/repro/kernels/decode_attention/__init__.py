from repro.kernels.decode_attention.ops import (  # noqa: F401
    decode_attention,
    combine_partials,
)
from repro.kernels.decode_attention.ref import (  # noqa: F401
    decode_attention_reference,
    decode_partials_reference,
)
