"""Pallas TPU kernel: flash-decode partial attention over a KV-cache shard.

One new token attends to a (possibly sequence-sharded) KV cache. The kernel
streams KV tiles HBM→VMEM with a running (max, sum-exp, accumulator) state
and emits the *unnormalized* partial ``(acc, m, l)`` so that shards combine
exactly with the logsumexp monoid (``ops.combine_partials`` /
``lax`` collectives in the model decode path). This is the long_500k serving
path: each `data`-axis device holds L/p cache positions; partials are the
softmax analogue of the paper's vertical partial-score accumulation.

Grid: ``(batch, q_heads, kv_blocks)``, KV innermost. VMEM per step at
defaults (bk=1024, D=128): k,v tiles 2·1024·128·2B = 0.5 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_LARGE = -0.5e30


def _decode_kernel(
    len_ref,   # (1, 1) i32 valid cache length for this batch row
    q_ref,     # (1, 1, d)
    k_ref,     # (1, 1, bk, d)
    v_ref,     # (1, 1, bk, d)
    acc_o_ref,  # (1, 1, d) f32 out
    m_o_ref,    # (1, 1) f32 out
    l_o_ref,    # (1, 1) f32 out
    acc_ref, m_ref, l_ref,  # scratch
    *,
    scale: float,
    block_k: int,
):
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_LARGE)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[0, 0]
    live = j * block_k < length

    @pl.when(live)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (d,)
        k = k_ref[0, 0].astype(jnp.float32)                # (bk, d)
        s = jnp.sum(k * q[None, :], axis=-1)[None, :]      # (1, bk)
        pos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_LARGE)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                             # (1, bk)
        p = jnp.where(pos < length, p, 0.0)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
        m_ref[...] = m_new
        vv = v_ref[0, 0].astype(jnp.float32)               # (bk, d)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p, vv, preferred_element_type=jnp.float32
        )

    @pl.when(j == nj - 1)
    def _emit():
        acc_o_ref[0, 0] = acc_ref[0, :]
        m_o_ref[0, 0] = m_ref[0, 0]
        l_o_ref[0, 0] = l_ref[0, 0]


def decode_attention_pallas(
    q: jax.Array,        # (B, Hq, D)
    k: jax.Array,        # (B, Hkv, L, D)
    v: jax.Array,        # (B, Hkv, L, D)
    lengths: jax.Array,  # (B,) i32
    *,
    scale: float | None = None,
    block_k: int = 1024,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    b, hq, d = q.shape
    hkv, L = k.shape[1], k.shape[2]
    assert hq % hkv == 0
    group = hq // hkv
    assert L % block_k == 0, (L, block_k)
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    grid = (b, hq, L // block_k)

    from jax.experimental.pallas import tpu as pltpu

    from repro.kernels._compat import tpu_compiler_params

    kernel = functools.partial(_decode_kernel, scale=scale, block_k=block_k)
    lengths2d = lengths.reshape(b, 1).astype(jnp.int32)
    acc, m, l = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b_, h, j: (b_, 0)),            # lengths
            pl.BlockSpec((1, 1, d), lambda b_, h, j: (b_, h, 0)),      # q
            pl.BlockSpec(
                (1, 1, block_k, d),
                lambda b_, h, j, group=group: (b_, h // group, j, 0),
            ),
            pl.BlockSpec(
                (1, 1, block_k, d),
                lambda b_, h, j, group=group: (b_, h // group, j, 0),
            ),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, d), lambda b_, h, j: (b_, h, 0)),
            pl.BlockSpec((1, 1), lambda b_, h, j: (b_, h)),
            pl.BlockSpec((1, 1), lambda b_, h, j: (b_, h)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, d), jnp.float32),
            jax.ShapeDtypeStruct((b, hq), jnp.float32),
            jax.ShapeDtypeStruct((b, hq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(lengths2d, q, k, v)
    return acc, m, l
