"""Single-device APSS: the reference oracle and the blocked production path.

``apss_reference`` is the pure-jnp oracle every other implementation (blocked,
Pallas kernel, all distributed variants) is validated against. It is the moral
equivalent of the paper's *all-pairs-0-array*: a dense score accumulator
(``S = D·Dᵀ``) filtered at threshold ``t`` — which the paper measured to be the
fastest sequential algorithm, and which maps 1:1 onto the MXU.

``apss_blocked`` is the tiled form (the paper's §5.1.9 "processing in vector
blocks", which also is the natural TPU shape): queries are processed in row
blocks of ``block_rows`` against the full corpus, with a running top-k match
buffer. Block-level pruning masks (``core.pruning``) can be threaded through to
account (and, in the Pallas kernel, actually skip) provably-dead tiles.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.matches import Matches, extract_matches
from repro.core.pruning import (
    PruneStats,
    block_prune_mask,
    live_tile_mask,
    prune_stats,
    sparse_block_stats,
)
from repro.core.sparse import (
    SparseCorpus,
    pad_rows_sparse,
    sparse_similarity_topk,
)
from repro.planner import telemetry


def _mask_counts(mask):
    """Host-side live-tile accounting from a mask — (live, total, per-row
    counts), or Nones when the mask is traced (cannot be read without
    forcing device work, which telemetry never does)."""
    if mask is None:
        return None, None, None
    try:
        mk = np.asarray(mask)
    except Exception:  # jax tracer: leave unaccounted
        return None, None, None
    return int(mk.sum()), int(mk.size), tuple(int(x) for x in mk.sum(axis=1))


def normalize_rows(D: jax.Array, eps: float = 1e-12) -> jax.Array:
    """L2-normalize rows (the paper assumes ``||x|| = 1``)."""
    nrm = jnp.linalg.norm(D.astype(jnp.float32), axis=-1, keepdims=True)
    return (D / jnp.maximum(nrm, eps)).astype(D.dtype)


def pad_rows(x: jax.Array, multiple: int) -> tuple[jax.Array, int]:
    """Zero-pad axis 0 to a multiple; returns (padded, original_len)."""
    n = x.shape[0]
    rem = (-n) % multiple
    if rem:
        x = jnp.pad(x, ((0, rem),) + ((0, 0),) * (x.ndim - 1))
    return x, n


def apss_reference(
    D: jax.Array,
    threshold: float,
    k: int = 32,
    *,
    exclude_self: bool = True,
) -> Matches:
    """Oracle APSS self-join: dense ``D·Dᵀ``, threshold, per-row top-k.

    O(n²m) FLOPs, O(n²) memory — only for validation-scale inputs.
    """
    S = jnp.einsum(
        "im,jm->ij", D, D, preferred_element_type=jnp.float32
    )
    return extract_matches(S, threshold, k, exclude_self=exclude_self)


def similarity_topk(
    Q: jax.Array,
    C: jax.Array,
    threshold: float,
    k: int = 32,
    *,
    block_rows: int = 512,
    exclude_self: bool = False,
    row_offset: jax.Array | int = 0,
    col_offset: jax.Array | int = 0,
    col_valid: Optional[jax.Array] = None,
    use_kernel: bool = False,
    variant: Optional[str] = None,
    mesh=None,
) -> Matches:
    """Blocked similarity join of queries ``Q (nq, m)`` vs corpus ``C (nc, m)``.

    Streams ``block_rows`` queries at a time so peak memory is
    ``O(block_rows · nc)`` instead of ``O(nq · nc)``. This is the workhorse for
    both the APSS self-join (``Q is C``) and retrieval scoring
    (1 query × 10⁶ candidates: ``nq = 1`` padded to a block).

    ``use_kernel=True`` routes the whole join through the fused Pallas
    kernel (``kernels.apss_block.apss_fused``): score tiles stay VMEM-only,
    the output is the ``O(nq·k)`` match buffer, and the maxweight bound mask
    gates per-tile MXU work. Offsets stay dynamic, so this is the path the
    distributed ring/halfring schedules take. ``col_valid`` masks are not
    supported by the kernel (only contiguous-prefix validity, which the
    kernel derives from the unpadded corpus length).

    ``variant="auto"`` hands the whole self-join to the execution planner
    (``planner.plan_apss``): ``Q`` must be ``C`` (the same object) with
    ``exclude_self=True``, and ``mesh`` (optional) opens the distributed
    variants to the candidate set. Every other argument is chosen by the
    planner from sampled corpus statistics and the calibrated cost models.
    """
    if variant not in (None, "auto"):
        raise ValueError(f"unknown variant: {variant!r} (only 'auto')")
    if variant == "auto":
        if Q is not C:
            raise ValueError(
                "variant='auto' plans the APSS self-join: pass the same "
                "object as Q and C (rectangular retrieval is served by "
                "serving.query_topk against a prebuilt index)"
            )
        if not exclude_self:
            raise ValueError(
                "variant='auto' dispatches to self-join variants, which "
                "exclude self-pairs; pass exclude_self=True"
            )
        from repro.planner.plan import plan_apss

        return plan_apss(Q, threshold, k, mesh).run()
    if isinstance(Q, SparseCorpus) != isinstance(C, SparseCorpus):
        raise ValueError(
            "Q and C must use the same representation "
            "(both SparseCorpus or both dense arrays)"
        )
    if isinstance(Q, SparseCorpus):
        if use_kernel:
            raise ValueError(
                "sparse use_kernel is self-join only: call apss_blocked on a "
                "SparseCorpus (kernels.apss_block.sparse.apss_sparse_compacted)"
            )
        if col_valid is not None:
            raise ValueError("sparse similarity_topk derives col validity "
                             "from the unpadded corpus length")
        return sparse_similarity_topk(
            Q, C, threshold, k, block_rows=block_rows,
            exclude_self=exclude_self,
            row_offset=row_offset, col_offset=col_offset,
        )
    if use_kernel:
        if col_valid is not None:
            raise ValueError("use_kernel=True does not support col_valid")
        from repro.kernels.apss_block.ops import apss_fused

        bm = _kernel_tile(block_rows)
        return apss_fused(
            Q, C, float(threshold), k,
            block_m=bm, block_n=bm,
            row_offset=row_offset, col_offset=col_offset,
            exclude_self=exclude_self,
        )
    nq = Q.shape[0]
    Qp, _ = pad_rows(Q, block_rows)
    nblocks = Qp.shape[0] // block_rows
    Qb = Qp.reshape(nblocks, block_rows, Q.shape[1])

    def body(carry, inputs):
        blk_idx, q_blk = inputs
        s = jnp.einsum("im,jm->ij", q_blk, C, preferred_element_type=jnp.float32)
        m = extract_matches(
            s,
            threshold,
            k,
            row_offset=row_offset + blk_idx * block_rows,
            col_offset=col_offset,
            exclude_self=exclude_self,
            col_valid=col_valid,
        )
        return carry, m

    _, ms = jax.lax.scan(body, 0, (jnp.arange(nblocks), Qb))
    out = jax.tree.map(lambda x: x.reshape(-1, *x.shape[2:]), ms)
    return jax.tree.map(lambda x: x[:nq], out)


def _kernel_tile(block_rows: int) -> int:
    """Clamp the user's row-block knob to an MXU-aligned kernel tile."""
    return min(max(128, block_rows), 256)


def apss_blocked(
    D: jax.Array,
    threshold: float,
    k: int = 32,
    *,
    block_rows: int = 512,
    with_prune_stats: bool = False,
    use_kernel: bool = False,
) -> Matches | tuple[Matches, PruneStats]:
    """Blocked APSS self-join with optional block-prune accounting.

    ``use_kernel=True`` routes the self-join through the fused streaming
    Pallas kernel (``kernels.apss_block.apss_fused``): matmul → threshold →
    top-k merge → count in one kernel, ``@pl.when`` tile skipping from the
    maxweight bound mask, and an ``O(n·k)`` ``Matches`` output — the ``n×n``
    score matrix is never materialized in HBM (TPU compiled; interpret mode
    on CPU). The XLA path computes every tile and uses the mask for
    accounting only. Exactness is independent of the mask; see
    ``core.pruning``.

    ``D`` may be a :class:`~repro.core.sparse.SparseCorpus`: the self-join
    then takes the sparse path — inverted-index worklist + CSR tile
    scoring (``use_kernel=True``; host-compacted, so not traceable) or the
    blocked gather-dot join (``use_kernel=False``, fully traceable). Both
    are exact on the densified corpus; see DESIGN.md §5.
    """
    if isinstance(D, SparseCorpus):
        return _apss_blocked_sparse(
            D, threshold, k, block_rows=block_rows,
            with_prune_stats=with_prune_stats, use_kernel=use_kernel,
        )
    if use_kernel:
        from repro.kernels.apss_block.ops import apss_fused

        bm = _kernel_tile(block_rows)
        m = apss_fused(
            D, D, float(threshold), k, block_m=bm, block_n=bm,
            exclude_self=True,
        )
    else:
        m = similarity_topk(
            D, D, threshold, k, block_rows=block_rows, exclude_self=True
        )
    mask = None
    if with_prune_stats:
        Dp, _ = pad_rows(D, block_rows)
        mask = block_prune_mask(Dp, Dp, threshold, block_rows)
    if telemetry.enabled():
        n, mdim = D.shape
        live, total, counts = _mask_counts(mask)
        flops = telemetry.dense_join_flops(n, n, mdim)
        if use_kernel and live is not None and total:
            flops *= live / total  # @pl.when skips dead tiles
        telemetry.record(telemetry.ApssStats(
            variant="blocked/dense-kernel" if use_kernel else "blocked/dense-xla",
            n=n, m=mdim, block_rows=block_rows, sparse=False, flops=flops,
            live_tiles=live, total_tiles=total, tile_counts=counts,
        ))
    if not with_prune_stats:
        return m
    return m, prune_stats(mask)


def _apss_blocked_sparse(
    D: SparseCorpus,
    threshold: float,
    k: int,
    *,
    block_rows: int,
    with_prune_stats: bool,
    use_kernel: bool,
) -> Matches | tuple[Matches, PruneStats]:
    mask = ub = None
    bs = _kernel_tile(block_rows) if use_kernel else block_rows
    if with_prune_stats or use_kernel:
        # Index-build half (block stats) separated from the scoring-time
        # mask so the bounds are computed exactly once here and shared by
        # the worklist AND the accounting (serving builds the same stats
        # once per corpus — see serving/index.py).
        Dp, _ = pad_rows_sparse(D, bs)
        stats = sparse_block_stats(Dp, bs)
        mask, ub = live_tile_mask(stats, stats, threshold, return_ub=True)
    if use_kernel:
        from repro.kernels.apss_block.sparse import apss_sparse_compacted

        m = apss_sparse_compacted(
            D, float(threshold), k,
            block_m=bs, block_mask=mask, block_ub=ub, use_kernel=True,
        )
    else:
        m = sparse_similarity_topk(
            D, D, threshold, k, block_rows=block_rows, exclude_self=True
        )
    if telemetry.enabled():
        live, total, counts = _mask_counts(mask)
        flops = telemetry.sparse_join_flops(D.n, D.n, D.cap)
        if use_kernel and live is not None and total:
            flops *= live / total  # worklist compaction skips dead tiles
        telemetry.record(telemetry.ApssStats(
            variant="blocked/sparse-kernel" if use_kernel else "blocked/sparse-xla",
            n=D.n, m=D.m, block_rows=bs, sparse=True, flops=flops,
            live_tiles=live, total_tiles=total, tile_counts=counts,
            extra={"cap": D.cap},
        ))
    if not with_prune_stats:
        return m
    return m, prune_stats(mask)


@functools.partial(jax.jit, static_argnames=("threshold", "k", "block_rows"))
def apss_blocked_jit(D, threshold: float, k: int = 32, block_rows: int = 512):
    return apss_blocked(D, threshold, k, block_rows=block_rows)
