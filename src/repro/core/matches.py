"""Fixed-capacity match extraction for APSS on static-shape accelerators.

The paper emits a variable-length list of ``(i, j, sim)`` matches. On a TPU the
output must be statically shaped, so we represent matches per query row as a
top-``k`` buffer plus an *exact* per-row match count:

- ``values[i, :]``  the ``k`` highest similarities ≥ ``t`` for row ``i``
  (padded with ``-inf``),
- ``indices[i, :]`` their global column ids (padded with ``-1``),
- ``counts[i]``     the exact number of matches ≥ ``t`` (may exceed ``k``; a
  count larger than ``k`` flags truncation — never silent).

This mirrors the paper's all-pairs-0-array design decision: a dense score
accumulator with post-hoc filtering, instead of a hash table.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

NEG_INF = jnp.float32(-jnp.inf)


class Matches(NamedTuple):
    """Top-k thresholded matches for a block of query rows."""

    values: jax.Array   # (rows, k) f32
    indices: jax.Array  # (rows, k) i32, -1 = empty slot
    counts: jax.Array   # (rows,)   i32, exact #matches ≥ t

    @property
    def capacity(self) -> int:
        return self.values.shape[-1]

    def overflowed(self) -> jax.Array:
        """Rows whose exact count exceeds the top-k capacity."""
        return self.counts > self.capacity


def empty_matches(rows: int, k: int) -> Matches:
    return Matches(
        values=jnp.full((rows, k), NEG_INF, dtype=jnp.float32),
        indices=jnp.full((rows, k), -1, dtype=jnp.int32),
        counts=jnp.zeros((rows,), dtype=jnp.int32),
    )


def extract_matches(
    scores: jax.Array,
    threshold: jax.Array | float,
    k: int,
    *,
    row_offset: jax.Array | int = 0,
    col_offset: jax.Array | int = 0,
    exclude_self: bool = True,
    col_valid: jax.Array | None = None,
) -> Matches:
    """Extract per-row thresholded top-k matches from a dense score tile.

    Args:
      scores: ``(rows, cols)`` dense similarity tile, f32.
      threshold: similarity threshold ``t``.
      k: static match capacity per row.
      row_offset / col_offset: global ids of ``scores[0, 0]`` — used for
        self-pair exclusion and for emitting global column indices.
      exclude_self: mask the ``i == j`` diagonal (APSS self-join semantics).
      col_valid: optional ``(cols,)`` bool mask for padded corpus columns.
    """
    rows, cols = scores.shape
    scores = scores.astype(jnp.float32)
    gcol = jnp.arange(cols, dtype=jnp.int32) + jnp.asarray(col_offset, jnp.int32)
    ok = scores >= jnp.asarray(threshold, jnp.float32)
    if exclude_self:
        grow = jnp.arange(rows, dtype=jnp.int32) + jnp.asarray(row_offset, jnp.int32)
        ok &= grow[:, None] != gcol[None, :]
    if col_valid is not None:
        ok &= col_valid[None, :]

    masked = jnp.where(ok, scores, NEG_INF)
    kk = min(k, cols)
    vals, local_idx = jax.lax.top_k(masked, kk)
    idx = jnp.take(gcol, local_idx, axis=0)
    idx = jnp.where(vals > NEG_INF, idx, -1)
    if kk < k:  # corpus tile narrower than capacity: pad out to k
        vals = jnp.pad(vals, ((0, 0), (0, k - kk)), constant_values=-jnp.inf)
        idx = jnp.pad(idx, ((0, 0), (0, k - kk)), constant_values=-1)
    counts = jnp.sum(ok, axis=-1, dtype=jnp.int32)
    return Matches(values=vals, indices=idx, counts=counts)


def merge_matches(a: Matches, b: Matches) -> Matches:
    """Merge two match sets over *disjoint* column ranges for the same rows.

    Counts add; the top-k buffers are re-selected from the union. Used to fold
    ring steps / column blocks into a running result.
    """
    vals = jnp.concatenate([a.values, b.values], axis=-1)
    idx = jnp.concatenate([a.indices, b.indices], axis=-1)
    k = a.capacity
    top_vals, sel = jax.lax.top_k(vals, k)
    top_idx = jnp.take_along_axis(idx, sel, axis=-1)
    top_idx = jnp.where(top_vals > NEG_INF, top_idx, -1)
    return Matches(
        values=top_vals,
        indices=top_idx,
        counts=a.counts + b.counts,
    )


def dedupe_candidates(
    values: jax.Array, indices: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Deduplicate per-row ``(value, index)`` candidate lists by index.

    Duplicates arise in the vertical compressed accumulation when several
    devices propose the same candidate column (each copy carries the identical
    fully-accumulated score). Keeps the first occurrence of every index;
    duplicate slots are invalidated to ``(-inf, -1)``.

    Args:
      values: ``(rows, c)`` scores.
      indices: ``(rows, c)`` int32 column ids, -1 = empty.
    """
    order = jnp.argsort(indices, axis=-1)  # -1 sentinels sort first
    s_idx = jnp.take_along_axis(indices, order, axis=-1)
    s_val = jnp.take_along_axis(values, order, axis=-1)
    prev = jnp.concatenate(
        [jnp.full_like(s_idx[:, :1], -2), s_idx[:, :-1]], axis=-1
    )
    first = (s_idx != prev) & (s_idx >= 0)
    out_val = jnp.where(first, s_val, NEG_INF)
    out_idx = jnp.where(first, s_idx, -1)
    return out_val, out_idx


def matches_from_candidates(
    values: jax.Array,
    indices: jax.Array,
    threshold: jax.Array | float,
    k: int,
    *,
    row_offset: jax.Array | int = 0,
    exclude_self: bool = True,
    dedupe: bool = True,
) -> Matches:
    """Build :class:`Matches` from sparse per-row candidate lists.

    Used by the vertical compressed/recursive accumulators whose final scores
    live in compacted ``(value, index)`` form rather than a dense tile.
    """
    values = values.astype(jnp.float32)
    if dedupe:
        values, indices = dedupe_candidates(values, indices)
    ok = (values >= jnp.asarray(threshold, jnp.float32)) & (indices >= 0)
    if exclude_self:
        rows = values.shape[0]
        grow = jnp.arange(rows, dtype=jnp.int32) + jnp.asarray(row_offset, jnp.int32)
        ok &= indices != grow[:, None]
    masked = jnp.where(ok, values, NEG_INF)
    kk = min(k, values.shape[-1])
    vals, sel = jax.lax.top_k(masked, kk)
    idx = jnp.take_along_axis(jnp.where(ok, indices, -1), sel, axis=-1)
    idx = jnp.where(vals > NEG_INF, idx, -1)
    if kk < k:
        vals = jnp.pad(vals, ((0, 0), (0, k - kk)), constant_values=-jnp.inf)
        idx = jnp.pad(idx, ((0, 0), (0, k - kk)), constant_values=-1)
    counts = jnp.sum(ok, axis=-1, dtype=jnp.int32)
    return Matches(values=vals, indices=idx, counts=counts)


def total_matches(m: Matches) -> jax.Array:
    """Total directed match count (each unordered pair counted twice)."""
    return jnp.sum(m.counts)
