"""Similarity-graph construction from APSS matches.

The paper positions APSS output as an undirected similarity graph
``G_S(V, t) = (V, M)`` consumed by transduction / clustering / k-nn algorithms.
These helpers convert the fixed-capacity :class:`Matches` representation into
COO edge lists (host-side numpy — graph consumers are host programs) and into
the padded edge arrays our GNN models take as input.
"""

from __future__ import annotations

import numpy as np

from repro.core.matches import Matches


def matches_to_coo(
    m: Matches, *, undirected: bool = True
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Convert matches to COO ``(rows, cols, weights)``.

    With ``undirected=True``, keeps each unordered pair once (``i < j``).
    """
    vals = np.asarray(m.values)
    idx = np.asarray(m.indices)
    n, k = idx.shape
    rows = np.repeat(np.arange(n, dtype=np.int32), k)
    cols = idx.reshape(-1)
    w = vals.reshape(-1)
    keep = cols >= 0
    if undirected:
        keep &= rows < cols
    return rows[keep], cols[keep], w[keep].astype(np.float32)


def coo_to_padded_edges(
    rows: np.ndarray,
    cols: np.ndarray,
    weights: np.ndarray,
    max_edges: int,
    *,
    add_reverse: bool = True,
    add_self_loops_n: int | None = None,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Pad a COO edge list to a static size for jit'd GNN consumption.

    Returns ``(src, dst, weight, edge_mask)`` each of length ``max_edges``.
    Padding edges point at node 0 with mask 0 (GNN segment ops weight them 0).
    """
    if add_reverse:
        rows, cols = np.concatenate([rows, cols]), np.concatenate([cols, rows])
        weights = np.concatenate([weights, weights])
    if add_self_loops_n is not None:
        loop = np.arange(add_self_loops_n, dtype=rows.dtype)
        rows = np.concatenate([rows, loop])
        cols = np.concatenate([cols, loop])
        weights = np.concatenate([weights, np.ones_like(loop, dtype=weights.dtype)])
    e = len(rows)
    if e > max_edges:
        raise ValueError(f"{e} edges exceed static capacity {max_edges}")
    pad = max_edges - e
    src = np.pad(rows.astype(np.int32), (0, pad))
    dst = np.pad(cols.astype(np.int32), (0, pad))
    w = np.pad(weights.astype(np.float32), (0, pad))
    mask = np.pad(np.ones(e, np.float32), (0, pad))
    return src, dst, w, mask


def match_set(m: Matches) -> set[tuple[int, int]]:
    """Unordered match pairs as a python set (test/debug utility)."""
    rows, cols, _ = matches_to_coo(m, undirected=True)
    return {(int(i), int(j)) for i, j in zip(rows, cols)}
