"""The paper's primary contribution: all-pairs similarity search (APSS).

Layout:

- :mod:`repro.core.apss`        single-device APSS (reference oracle + blocked)
- :mod:`repro.core.matches`     fixed-capacity match extraction / merging
- :mod:`repro.core.pruning`     maxweight / minsize block bounds, local pruning,
                                sparse-exact bounds + inverted-index candidacy
- :mod:`repro.core.sparse`      padded-CSR SparseCorpus + sparse scoring
- :mod:`repro.core.distributed` 1-D horizontal, 1-D vertical, 2-D shard_map
                                algorithms (paper Algs. 3-7) + TPU extensions
- :mod:`repro.core.graph`       similarity-graph (COO) construction utilities
"""

from repro.core.apss import (  # noqa: F401
    apss_reference,
    apss_blocked,
    similarity_topk,
    normalize_rows,
)
from repro.core.matches import Matches, extract_matches, merge_matches  # noqa: F401
from repro.core.pruning import (  # noqa: F401
    BlockStats,
    block_maxweight_bounds,
    block_minsize_bounds,
    block_prune_mask,
    dense_block_stats,
    live_tile_mask,
    local_threshold,
    sparse_block_prune_mask,
    sparse_block_stats,
    sparse_candidate_mask,
)
from repro.core.sparse import (  # noqa: F401
    SparseCorpus,
    from_dense,
    normalize_sparse,
    sparse_similarity_topk,
    to_dense,
)
from repro.core.distributed import (  # noqa: F401
    apss_horizontal,
    apss_vertical,
    apss_2d,
)
