"""Sparse corpus representation and sparse APSS scoring primitives.

The paper's entire experimental regime is sparse text (density ≲ 1%, Table
1), and its fast sequential algorithm lives on *partial indexing* — an
inverted index over dimensions. A dense ``(n, m)`` array wastes both memory
(``n·m`` floats for ``n·avg_nnz`` payload) and MXU work (mostly-zero tiles).

:class:`SparseCorpus` is the statically-shaped sparse layout JAX needs:
padded CSR (a.k.a. ELL) — every row stores exactly ``cap`` ``(index,
value)`` slots, real entries first, padding slots holding ``(0, 0.0)`` so
they are arithmetically inert in every consumer (scatter adds 0, gathers
multiply by 0, maxweight maxes with 0). ``nnz`` keeps the exact per-row
count, which also makes the paper's minsize bound exact instead of a dense
Cauchy–Schwarz surrogate (see ``core.pruning``).

Scoring never materializes ``(n, m)``; the two primitives are

- :func:`densify_rows` — scatter ONE row block to dense ``(block, m)``
  (the all-pairs-0-array score accumulator, built per block, not per
  corpus), and
- :func:`gather_dot` — CSR×dense tile scores ``s[r, c] = Σ_k
  qd[r, idx[c, k]] · val[c, k]`` in ``O(rows · cols · cap)`` FLOPs — the
  true sparse-dot cost, a factor ``m / cap ≈ 1/density`` below the dense
  tile matmul.

:func:`sparse_similarity_topk` composes them into the blocked join that
backs ``apss_blocked`` / ``apss_horizontal`` / ``apss_vertical`` for sparse
inputs. The maximally-pruned single-device path (inverted-index worklist +
CSR tile kernel) lives in ``kernels/apss_block/sparse.py``.

Duplicate coordinates within a row are legal and mean *summation* (the COO
convention): ``to_dense`` scatter-adds and ``gather_dot`` sums every slot,
so all consumers agree. Consumers that need per-*component* magnitudes
(row norms, maxweight pruning bounds) combine duplicate slots first via
:func:`dedupe_rows`.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.compat import pvary
from repro.core.matches import Matches, empty_matches, extract_matches, merge_matches


@jax.tree_util.register_pytree_node_class
class SparseCorpus:
    """Padded-CSR (ELL) corpus: statically shaped, JAX-transformable.

    Attributes:
      indices: ``(n, cap)`` int32 dimension ids; padding slots hold 0.
      values:  ``(n, cap)`` float32 weights; padding slots hold 0.0.
      nnz:     ``(n,)`` int32 exact per-row stored-entry count.
      m:       number of dimensions (static aux data — survives tracing).
    """

    def __init__(self, indices, values, nnz, m: int):
        self.indices = indices
        self.values = values
        self.nnz = nnz
        self.m = int(m)

    def tree_flatten(self):
        return (self.indices, self.values, self.nnz), self.m

    @classmethod
    def tree_unflatten(cls, m, children):
        return cls(*children, m)

    @property
    def n(self) -> int:
        return self.indices.shape[0]

    @property
    def cap(self) -> int:
        return self.indices.shape[1]

    @property
    def shape(self) -> tuple[int, int]:
        return (self.n, self.m)

    def __repr__(self) -> str:
        return f"SparseCorpus(n={self.n}, m={self.m}, cap={self.cap})"


def from_dense(D, cap: int | None = None) -> SparseCorpus:
    """Host-side dense → padded-CSR conversion (row indices sorted).

    ``cap`` may only widen the layout (extra inert padding slots); a cap
    below the realized max row nnz would silently drop values and break the
    exact-``nnz`` contract, so it raises instead.
    """
    D = np.asarray(D)
    n, m = D.shape
    nz = D != 0
    nnz = nz.sum(axis=1).astype(np.int32)
    need = int(max(1, nnz.max(initial=1)))
    if cap is not None and cap < need:
        raise ValueError(f"cap={cap} would truncate rows (max nnz {need})")
    cap = int(cap if cap is not None else need)
    indices = np.zeros((n, cap), np.int32)
    values = np.zeros((n, cap), np.float32)
    for i in range(n):
        cols = np.nonzero(nz[i])[0]
        indices[i, : len(cols)] = cols
        values[i, : len(cols)] = D[i, cols]
    return SparseCorpus(
        jnp.asarray(indices), jnp.asarray(values), jnp.asarray(nnz), m
    )


def to_dense(sp: SparseCorpus) -> jax.Array:
    """Jittable CSR → dense scatter; duplicate coordinates sum."""
    rows = jnp.arange(sp.n, dtype=jnp.int32)[:, None]
    out = jnp.zeros(sp.shape, jnp.float32)
    return out.at[rows, sp.indices].add(sp.values)


def dedupe_rows(indices: jax.Array, values: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Combine duplicate coordinates within each row: run-sums in place.

    Returns same-shape ``(indices, values)`` where each distinct dimension's
    slots are summed into the run's last slot and every other slot becomes
    the inert ``(0, 0.0)`` padding convention. Sort + cumsum, ``O(cap log
    cap)`` per row — never densifies. Consumers needing per-*component*
    quantities (norms, maxweight bounds) go through this; scoring paths
    don't need to (they sum every slot by construction).
    """
    order = jnp.argsort(indices, axis=1)
    si = jnp.take_along_axis(indices, order, axis=1)
    sv = jnp.take_along_axis(values.astype(jnp.float32), order, axis=1)
    c = jnp.cumsum(sv, axis=1)
    pos = jax.lax.broadcasted_iota(jnp.int32, si.shape, 1)
    first = jnp.concatenate(
        [jnp.ones_like(si[:, :1], bool), si[:, 1:] != si[:, :-1]], axis=1
    )
    last = jnp.concatenate(
        [si[:, 1:] != si[:, :-1], jnp.ones_like(si[:, :1], bool)], axis=1
    )
    start = jax.lax.cummax(jnp.where(first, pos, 0), axis=1)
    run_sum = c - jnp.take_along_axis(c - sv, start, axis=1)  # Σ of the run
    return (
        jnp.where(last, si, 0),
        jnp.where(last, run_sum, 0.0),
    )


def normalize_sparse(sp: SparseCorpus, eps: float = 1e-12) -> SparseCorpus:
    """L2-normalize rows in CSR form (the paper's ``||x|| = 1``).

    Duplicate-correct: norms are taken over per-*component* sums
    (:func:`dedupe_rows`), and uniform slot scaling scales every effective
    component uniformly.
    """
    _, comp = dedupe_rows(sp.indices, sp.values)
    nrm = jnp.sqrt(jnp.sum(comp * comp, axis=1))
    scale = 1.0 / jnp.maximum(nrm, eps)
    return SparseCorpus(sp.indices, sp.values * scale[:, None], sp.nnz, sp.m)


def pad_rows_sparse(sp: SparseCorpus, multiple: int) -> tuple[SparseCorpus, int]:
    """Zero-pad rows to a multiple; padding rows are empty (nnz 0)."""
    n = sp.n
    rem = (-n) % multiple
    if rem:
        sp = SparseCorpus(
            jnp.pad(sp.indices, ((0, rem), (0, 0))),
            jnp.pad(sp.values, ((0, rem), (0, 0))),
            jnp.pad(sp.nnz, (0, rem)),
            sp.m,
        )
    return sp, n


def density(sp: SparseCorpus) -> float:
    """Host-side exact density (stored entries / n·m)."""
    return float(np.asarray(sp.nnz).sum()) / float(sp.n * sp.m)


def shard_dims(
    sp: SparseCorpus, p: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Host-side vertical (dimension) split into ``p`` contiguous slices.

    The paper's 1-D vertical distribution in its natural habitat: device
    ``d`` owns dimensions ``[d·m/p, (d+1)·m/p)`` — a contiguous shard of
    the inverted index — and sees every row restricted to that slice.

    Returns stacked ``(p, n, cap_loc)`` indices (LOCAL, slice-relative) and
    values, ``(p, n)`` local nnz, and ``m_loc = m // p``. ``cap_loc`` is
    the max per-device per-row count (uniform so the stack is rectangular).
    """
    if sp.m % p:
        raise ValueError(f"m={sp.m} must be a multiple of p={p}")
    m_loc = sp.m // p
    idx = np.asarray(sp.indices)
    val = np.asarray(sp.values)
    nnz = np.asarray(sp.nnz)
    n, cap = idx.shape
    valid = np.arange(cap)[None, :] < nnz[:, None]
    owner = idx // m_loc
    counts = np.stack(
        [(valid & (owner == d)).sum(axis=1) for d in range(p)]
    )  # (p, n)
    cap_loc = max(1, int(counts.max(initial=1)))
    out_idx = np.zeros((p, n, cap_loc), np.int32)
    out_val = np.zeros((p, n, cap_loc), np.float32)
    for d in range(p):
        sel = valid & (owner == d)
        # Stable-pack selected slots to the front of each row.
        order = np.argsort(~sel, axis=1, kind="stable")[:, :cap_loc]
        packed = np.take_along_axis(sel, order, axis=1)
        gi = np.take_along_axis(idx, order, axis=1)
        gv = np.take_along_axis(val, order, axis=1)
        out_idx[d] = np.where(packed, gi - d * m_loc, 0)
        out_val[d] = np.where(packed, gv, 0.0)
    return out_idx, out_val, counts.astype(np.int32), m_loc


def dim_slices(sp: SparseCorpus, p: int) -> list[SparseCorpus]:
    """The ``p`` per-slice corpora of :func:`shard_dims` as SparseCorpus views.

    Slice ``d`` holds every row restricted to dimensions ``[d·m/p,
    (d+1)·m/p)`` with SLICE-RELATIVE indices and ``m = m/p`` — exactly the
    cell contents of one checkerboard column in ``apss_2d``. Used by the
    per-cell pruning bounds (``core.pruning.checkerboard_live_mask``) and
    tests; the distributed path consumes the stacked arrays directly.
    """
    idx_s, val_s, nnz_s, m_loc = shard_dims(sp, p)
    return [
        SparseCorpus(
            jnp.asarray(idx_s[d]), jnp.asarray(val_s[d]),
            jnp.asarray(nnz_s[d]), m_loc,
        )
        for d in range(p)
    ]


# ---------------------------------------------------------------------------
# Scoring primitives
# ---------------------------------------------------------------------------


def densify_rows(sp: SparseCorpus, start, rows: int) -> jax.Array:
    """Scatter one row block to dense ``(rows, m)`` (traced ``start`` ok).

    This is the only densification the sparse path ever performs — one
    query block at a time, never the corpus.
    """
    idx = lax.dynamic_slice_in_dim(sp.indices, start, rows, axis=0)
    val = lax.dynamic_slice_in_dim(sp.values, start, rows, axis=0)
    r = jnp.arange(rows, dtype=jnp.int32)[:, None]
    return jnp.zeros((rows, sp.m), jnp.float32).at[r, idx].add(val)


def gather_dot(
    qd: jax.Array, idx: jax.Array, val: jax.Array, *, chunk: int = 32
) -> jax.Array:
    """Sparse tile scores: dense query block × CSR corpus block.

    ``s[r, c] = Σ_k qd[r, idx[c, k]] · val[c, k]`` — exactly the sparse
    dot product cost ``O(rows · cols · cap)`` FLOPs; padding slots (val 0)
    contribute nothing, duplicate coordinates sum. The cap axis is folded
    in ``chunk``-sized pieces so the gathered intermediate peaks at
    ``O(rows · cols · chunk)`` — bounded regardless of corpus density —
    instead of materializing the full ``(rows, cols, cap)`` tensor.
    """
    rows = qd.shape[0]
    cols, cap = idx.shape
    rem = (-cap) % chunk
    if rem:  # pad with inert (0, 0.0) slots to a chunk multiple
        idx = jnp.pad(idx, ((0, 0), (0, rem)))
        val = jnp.pad(val, ((0, 0), (0, rem)))
    nch = (cap + rem) // chunk
    idxc = jnp.moveaxis(idx.reshape(cols, nch, chunk), 1, 0)
    valc = jnp.moveaxis(
        val.astype(jnp.float32).reshape(cols, nch, chunk), 1, 0
    )

    def step(acc, iv):
        i, v = iv  # (cols, chunk) each
        g = jnp.take(qd, i, axis=1)  # (rows, cols, chunk)
        return acc + jnp.einsum(
            "rck,ck->rc", g, v, preferred_element_type=jnp.float32
        ), None

    acc, _ = lax.scan(step, jnp.zeros((rows, cols), jnp.float32), (idxc, valc))
    return acc


def sparse_similarity_topk(
    Q: SparseCorpus,
    C: SparseCorpus,
    threshold: float,
    k: int = 32,
    *,
    block_rows: int = 512,
    exclude_self: bool = False,
    row_offset: jax.Array | int = 0,
    col_offset: jax.Array | int = 0,
    vary_axes: Sequence[str] = (),
) -> Matches:
    """Blocked sparse similarity join of ``Q (nq, m)`` vs ``C (nc, m)``.

    The sparse twin of ``core.apss.similarity_topk``: query blocks are
    densified one at a time (``densify_rows``), corpus blocks stay CSR and
    are scored with :func:`gather_dot`, so FLOPs and peak memory are
    ``O(block² · cap)`` and ``O(block · m)`` — never ``O(n · m)``.

    Fully traceable (offsets may be traced), so it drops into the
    shard_map'd distributed schedules; ``vary_axes`` marks internal carry
    inits as device-varying there (same role as ``_pvary`` in
    ``core.distributed``).
    """
    if Q.m != C.m:
        # Fail loudly like the dense einsum would: out-of-range gathers
        # would otherwise quietly NaN every affected score.
        raise ValueError(f"dimension mismatch: Q.m={Q.m} vs C.m={C.m}")
    nq = Q.n
    Qp, _ = pad_rows_sparse(Q, block_rows)
    Cp, nc = pad_rows_sparse(C, block_rows)
    nqb = Qp.n // block_rows
    ncb = Cp.n // block_rows
    Ci = Cp.indices.reshape(ncb, block_rows, Cp.cap)
    Cv = Cp.values.reshape(ncb, block_rows, Cp.cap)

    def _vary(tree):
        for ax in vary_axes:
            tree = jax.tree.map(lambda a: pvary(a, ax), tree)
        return tree

    def q_block(carry, qi):
        qd = densify_rows(Qp, qi * block_rows, block_rows)

        def c_block(mm, ci):
            s = gather_dot(qd, Ci[ci], Cv[ci])
            col_valid = (
                jnp.arange(block_rows, dtype=jnp.int32) + ci * block_rows
            ) < nc
            m_new = extract_matches(
                s, threshold, k,
                row_offset=row_offset + qi * block_rows,
                col_offset=col_offset + ci * block_rows,
                exclude_self=exclude_self,
                col_valid=col_valid,
            )
            return merge_matches(mm, m_new), None

        m0 = _vary(empty_matches(block_rows, k))
        mm, _ = lax.scan(c_block, m0, jnp.arange(ncb))
        return carry, mm

    _, ms = lax.scan(q_block, 0, jnp.arange(nqb))
    out = jax.tree.map(lambda x: x.reshape(-1, *x.shape[2:]), ms)
    return jax.tree.map(lambda x: x[:nq], out)
