"""Candidate pruning bounds, lifted from element- to block-granularity.

The sequential optimizations of Bayardo et al. (partial indexing / remscore /
minsize) all exploit per-dimension ``maxweight`` upper bounds to skip work.
Element-granular conditionals are poison for a systolic array, so we evaluate
the same bounds at *tile* granularity: a cheap summary matmul yields a
``(row_blocks × col_blocks)`` boolean mask of provably-below-threshold block
pairs, which the Pallas kernel skips with ``@pl.when`` (and which the roofline
accounting credits as saved FLOPs).

All bounds are conservative: a pruned block pair can contain **no** match, so
pruned execution remains exact (asserted by the property tests).

Local pruning (paper Lemma 1): if ``sim(x, y) ≥ t`` then at least one of the
``p`` dimension-shards sees a partial score ``≥ t/p``. :func:`local_threshold`
is that bound; the vertical distributed algorithm uses it to compact partial
scores before accumulation.

For CSR corpora (``core.sparse``) the same bounds are computed straight from
the sparse layout (:func:`sparse_block_prune_mask`): block maxima by
scatter-max, per-row sizes from the stored ``nnz`` (exact, not a densified
recount), plus the inverted-index candidacy test — tiles whose blocks share
no dimension support are never candidates at all (the paper's partial
indexing, DESIGN.md §5).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class BlockStats(NamedTuple):
    """Per-row-block pruning summaries — the *index-build* half of pruning.

    Everything the maxweight/minsize/inverted-index bounds need to know
    about one side of a join, separated from the scoring-time mask
    evaluation (:func:`live_tile_mask`) so a serving index can compute the
    corpus side ONCE and reuse it across queries (``serving.index``).

    Attributes:
      maxw:    ``(nb, m)`` per-block per-dimension max ``|weight|`` — the
               paper's ``maxweight_d(V)`` at tile granularity. ``maxw > 0``
               is exactly the tile-granular posting-list support.
      mw:      ``(nb,)`` per-block max weight (max of ``maxw`` over dims).
      max_nnz: ``(nb,)`` per-block max row nnz (the paper's ``|y|``).
    """

    maxw: jax.Array
    mw: jax.Array
    max_nnz: jax.Array


def dense_block_stats(D: jax.Array, block_rows: int, eps: float = 0.0) -> BlockStats:
    """Block pruning summaries from a dense ``(n, m)`` array."""
    maxw = block_maxweight_bounds(D, block_rows)
    mw, max_nnz = block_minsize_bounds(D, block_rows, eps)
    return BlockStats(maxw=maxw, mw=mw, max_nnz=max_nnz)


def sparse_block_stats(sp, block_rows: int) -> BlockStats:
    """Block pruning summaries straight from padded CSR (never densified).

    ``max_nnz`` uses the corpus's EXACT stored per-row nnz (the dense path
    has to recount from a densified array); stored nnz over-counts
    duplicate coordinates, which only loosens (never unsounds) the minsize
    bound.
    """
    maxw = sparse_block_maxweight(sp, block_rows)
    max_nnz = jnp.max(sp.nnz.reshape(-1, block_rows), axis=1)
    return BlockStats(maxw=maxw, mw=jnp.max(maxw, axis=1), max_nnz=max_nnz)


def live_tile_mask(
    stats_rows: BlockStats,
    stats_cols: BlockStats,
    threshold: jax.Array | float,
    *,
    use_minsize: bool = True,
    normalized: bool = True,
    return_ub: bool = False,
):
    """``(n_row_blocks, n_col_blocks)`` LIVE mask from precomputed stats.

    The *scoring-time* half of pruning: a cheap summary matmul over
    whatever :class:`BlockStats` the caller has — freshly computed (the
    self-join paths) or prebuilt once per corpus (the serving index).
    Semantics identical to :func:`block_prune_mask` /
    :func:`sparse_block_prune_mask`, which are now thin wrappers.

    The maxweight upper bound IS the inverted-index candidacy test in
    weighted form: blocks sharing no posting list have ``ub = 0`` and die
    for any ``t > 0``. ``normalized`` refers to the COLUMN side (the
    minsize bound needs ``||y|| = 1``; query rows may be anything).

    ``return_ub=True`` additionally returns the ``(nb_r, nb_c)`` f32 upper
    bounds — the adaptive worklist ordering key (``compact_worklist``).
    """
    t = jnp.asarray(threshold, jnp.float32)
    ub = block_upper_bounds(stats_rows.maxw, stats_cols.maxw)
    live = ub >= t
    if use_minsize and normalized:
        ms_ub = (
            stats_rows.mw[:, None]
            * jnp.sqrt(stats_cols.max_nnz.astype(jnp.float32))[None, :]
        )
        live &= ms_ub >= t
        ub = jnp.minimum(ub, ms_ub)
    if return_ub:
        return live, ub
    return live


def block_maxweight_bounds(D: jax.Array, block_rows: int) -> jax.Array:
    """Per-block, per-dimension max absolute weight: ``(n/b, m)``.

    ``maxw[B, d] = max_{i in block B} |D[i, d]|`` — the block-granular analogue
    of the paper's ``maxweight_d(V)``.
    """
    n, m = D.shape
    assert n % block_rows == 0, (n, block_rows)
    return jnp.max(
        jnp.abs(D).reshape(n // block_rows, block_rows, m), axis=1
    )


def block_upper_bounds(maxw_rows: jax.Array, maxw_cols: jax.Array) -> jax.Array:
    """Upper bound on any cross-block similarity: ``ub[I,J] ≥ max sim``.

    ``sim(x, y) = Σ_d x[d]·y[d] ≤ Σ_d maxw_I[d]·maxw_J[d]`` for ``x ∈ I``,
    ``y ∈ J``. One small matmul over block summaries (paper's partial-indexing
    bound at tile granularity).
    """
    return jnp.einsum(
        "im,jm->ij", maxw_rows, maxw_cols, preferred_element_type=jnp.float32
    )


def row_nnz(D: jax.Array, eps: float = 0.0) -> jax.Array:
    """Number of non-zero components per row (paper's ``|x|``)."""
    return jnp.sum(jnp.abs(D) > eps, axis=-1, dtype=jnp.int32)


def block_minsize_bounds(
    D: jax.Array, block_rows: int, eps: float = 0.0
) -> tuple[jax.Array, jax.Array]:
    """Block summaries for the minsize bound.

    Returns ``(max_weight, max_nnz)`` per row block, where ``max_weight[B] =
    max_{x∈B} maxweight(x)`` and ``max_nnz[B] = max_{y∈B} |y|``. For rows
    normalized to unit L2 norm, Cauchy-Schwarz over the nonzero support gives
    ``sim(x, y) ≤ maxweight(x) · sqrt(|y|)`` — a strictly tighter form of the
    paper's ``|y| ≥ t / maxweight(x)`` minsize test.
    """
    n, m = D.shape
    assert n % block_rows == 0
    absD = jnp.abs(D).reshape(n // block_rows, block_rows, m)
    max_weight = jnp.max(absD, axis=(1, 2))
    nnz = jnp.sum(absD > eps, axis=-1, dtype=jnp.int32)
    max_nnz = jnp.max(nnz, axis=1)
    return max_weight, max_nnz


def block_prune_mask(
    D_rows: jax.Array,
    D_cols: jax.Array,
    threshold: jax.Array | float,
    block_rows: int,
    block_cols: int | None = None,
    *,
    use_minsize: bool = True,
    normalized: bool = True,
    return_ub: bool = False,
) -> jax.Array | tuple[jax.Array, jax.Array]:
    """``(n_row_blocks, n_col_blocks)`` bool mask; True = block pair is LIVE.

    A False entry certifies every pair in that tile has ``sim < t`` and may be
    skipped. Combines the maxweight bound with the (optional) minsize bound.

    ``D_rows`` are query rows, ``D_cols`` corpus rows (self-join: same array).
    Thin wrapper over :func:`dense_block_stats` + :func:`live_tile_mask`
    (the separable index-build / scoring-time halves).
    """
    block_cols = block_cols or block_rows
    stats_r = dense_block_stats(D_rows, block_rows)
    stats_c = (
        stats_r
        if D_cols is D_rows and block_cols == block_rows
        else dense_block_stats(D_cols, block_cols)
    )
    return live_tile_mask(
        stats_r, stats_c, threshold,
        use_minsize=use_minsize, normalized=normalized, return_ub=return_ub,
    )


class PruneStats(NamedTuple):
    live_blocks: jax.Array    # scalar i32
    total_blocks: jax.Array   # scalar i32
    live_fraction: jax.Array  # scalar f32


def prune_stats(mask: jax.Array) -> PruneStats:
    total = jnp.int32(mask.size)
    live = jnp.sum(mask, dtype=jnp.int32)
    return PruneStats(
        live_blocks=live,
        total_blocks=total,
        live_fraction=live.astype(jnp.float32) / total.astype(jnp.float32),
    )


# ---------------------------------------------------------------------------
# Sparse-exact bounds: inverted-index candidate generation + tile bounds
# computed straight from the padded-CSR corpus (core.sparse.SparseCorpus),
# never from a densified array.
# ---------------------------------------------------------------------------


def sparse_block_maxweight(sp, block_rows: int) -> jax.Array:
    """Per-block per-dimension max weight ``(n/b, m)`` from CSR, by scatter-max.

    The exact analogue of :func:`block_maxweight_bounds` without
    densification. Duplicate coordinates are combined first
    (``core.sparse.dedupe_rows``) so the bound sees the effective
    per-component magnitude ``|Σ slots|``, not per-slot values — a per-slot
    max would UNDER-bound concentrated duplicates and unsoundly prune.
    Padding slots carry ``(index 0, value 0)`` and are inert under
    max-with-0.
    """
    from repro.core.sparse import dedupe_rows

    n = sp.n
    assert n % block_rows == 0, (n, block_rows)
    idx, comp = dedupe_rows(sp.indices, sp.values)
    blk = (jnp.arange(n, dtype=jnp.int32) // block_rows)[:, None]
    out = jnp.zeros((n // block_rows, sp.m), jnp.float32)
    return out.at[blk, idx].max(jnp.abs(comp))


def sparse_block_support(sp, block_rows: int) -> jax.Array:
    """Tile-granular posting lists: ``sup[B, d]`` ⇔ dimension ``d``'s posting
    list intersects row block ``B`` (the paper's inverted index ``I_d``,
    quantized to blocks)."""
    return sparse_block_maxweight(sp, block_rows) > 0


def sparse_candidate_mask(sup_rows: jax.Array, sup_cols: jax.Array) -> jax.Array:
    """Inverted-index candidate generation at tile granularity.

    A tile ``(I, J)`` is a candidate iff some dimension's posting list hits
    both blocks — the paper's partial indexing: pairs sharing no indexed
    dimension are never generated at all. One boolean-as-f32 matmul over the
    block-support summaries; everything else is provably zero-similarity.

    Inside :func:`sparse_block_prune_mask` this test is enforced through the
    weighted maxweight bound instead (no-shared-support ⇒ ``ub = 0 < t`` for
    any ``t > 0``), which also stays sound at ``t ≤ 0`` where zero-similarity
    pairs DO match; this boolean form exists for index statistics and
    candidate accounting.
    """
    hits = jnp.einsum(
        "im,jm->ij",
        sup_rows.astype(jnp.float32),
        sup_cols.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    return hits > 0


def sparse_block_prune_mask(
    sp_rows,
    sp_cols,
    threshold: jax.Array | float,
    block_rows: int,
    block_cols: int | None = None,
    *,
    use_minsize: bool = True,
    normalized: bool = True,
    return_ub: bool = False,
) -> jax.Array | tuple[jax.Array, jax.Array]:
    """``(n_row_blocks, n_col_blocks)`` LIVE mask from CSR inputs only.

    Conjunction of two exact certificates (False ⇒ no pair in the tile
    reaches ``t``; pruned execution stays exact):

    1. the maxweight upper bound ``Σ_d maxw_I[d]·maxw_J[d] ≥ t`` over
       sparse block maxima — which IS the inverted-index candidacy test in
       weighted form: blocks sharing no posting list have ``ub = 0`` and
       die for any ``t > 0`` (identical bound to the dense path, no
       densification),
    2. the minsize bound ``maxweight(I) · √(max nnz in J) ≥ t`` using the
       corpus's EXACT stored per-row nnz — the dense path has to recount
       nonzeros from a densified array; here ``|y|`` is native. Stored nnz
       over-counts duplicate coordinates, which only loosens (never
       unsounds) the bound. Like its dense twin, the minsize bound assumes
       unit row norms and is gated on ``normalized`` (pass False for
       unnormalized corpora). A symmetrized self-join worklist
       (``live | live.T``, as built by ``apss_sparse_compacted``) is
       additionally sound for any ``t < 1`` even unnormalized: both
       orientations failing implies ``sim ≤ mw_I·√|J| · mw_J·√|I| < t² <
       t``.

    Both certificates are trivially live at ``t ≤ 0`` (their left sides are
    ≥ 0), where every pair — including zero-similarity ones — matches.
    Thin wrapper over :func:`sparse_block_stats` + :func:`live_tile_mask`.
    """
    block_cols = block_cols or block_rows
    stats_r = sparse_block_stats(sp_rows, block_rows)
    stats_c = (
        stats_r  # self-join: skip the second dedupe + scatter-max pass
        if sp_cols is sp_rows and block_cols == block_rows
        else sparse_block_stats(sp_cols, block_cols)
    )
    return live_tile_mask(
        stats_r, stats_c, threshold,
        use_minsize=use_minsize, normalized=normalized, return_ub=return_ub,
    )


def checkerboard_live_mask(
    cells,
    threshold: jax.Array | float,
    block_rows: int,
    *,
    use_minsize: bool = True,
) -> jax.Array:
    """Self-join LIVE mask under a 2-D checkerboard dimension split.

    ``cells`` are the ``r`` dimension slices of one corpus
    (:func:`~repro.core.sparse.dim_slices`) — each cell column of the
    checkerboard sees only its ``m/r`` posting lists. The composed mask is
    the OR over cells of the per-cell :func:`sparse_block_prune_mask` at the
    Lemma-1 **local threshold** ``t/r``:

    - *Lemma 1 (local pruning)*: a pair with global ``sim ≥ t`` has partial
      similarity ``≥ t/r`` in at least one dimension slice, so its tile is
      live in that cell's mask and survives the union — the bound stays
      sound under the composition.
    - *Per-cell minsize*: the minsize certificate assumes unit row norms,
      but a cell of a normalized corpus has ``||y_cell|| ≤ 1`` and
      Cauchy–Schwarz gives ``partial ≤ maxw_cell(x)·√|y_cell|·||y_cell||``,
      so the unit-norm form only over-bounds — per-cell evaluation remains
      conservative (asserted by ``tests/test_sparse_2d.py``).

    The exact distributed rescoring (``_accumulate_block_scores``) needs
    every cell's partials at the candidate union, so this mask cannot skip
    partial-score compute without breaking exactness. It is the candidacy /
    soundness view of the composed schedule — exercised by the soundness
    tests and the hook for a future per-cell worklist path; it is NOT part
    of the telemetry record (evaluating it costs device work, which
    telemetry never performs).
    """
    t_local = local_threshold(threshold, len(cells))
    live = None
    for cell in cells:
        cell_live = sparse_block_prune_mask(
            cell, cell, t_local, block_rows,
            use_minsize=use_minsize, normalized=True,
        )
        live = cell_live if live is None else (live | cell_live)
    return live


def local_threshold(threshold: float | jax.Array, num_shards: int) -> jax.Array:
    """Paper Lemma 1: local pruning threshold ``t_local = t / p``.

    For any partition of the dimensions into ``num_shards`` parts, every global
    match ``sim(x,y) ≥ t`` has local partial similarity ``≥ t/p`` on at least
    one shard (otherwise the total would be ``< p·(t/p) = t``).
    """
    return jnp.asarray(threshold, jnp.float32) / num_shards
