"""Candidate pruning bounds, lifted from element- to block-granularity.

The sequential optimizations of Bayardo et al. (partial indexing / remscore /
minsize) all exploit per-dimension ``maxweight`` upper bounds to skip work.
Element-granular conditionals are poison for a systolic array, so we evaluate
the same bounds at *tile* granularity: a cheap summary matmul yields a
``(row_blocks × col_blocks)`` boolean mask of provably-below-threshold block
pairs, which the Pallas kernel skips with ``@pl.when`` (and which the roofline
accounting credits as saved FLOPs).

All bounds are conservative: a pruned block pair can contain **no** match, so
pruned execution remains exact (asserted by the property tests).

Local pruning (paper Lemma 1): if ``sim(x, y) ≥ t`` then at least one of the
``p`` dimension-shards sees a partial score ``≥ t/p``. :func:`local_threshold`
is that bound; the vertical distributed algorithm uses it to compact partial
scores before accumulation.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


def block_maxweight_bounds(D: jax.Array, block_rows: int) -> jax.Array:
    """Per-block, per-dimension max absolute weight: ``(n/b, m)``.

    ``maxw[B, d] = max_{i in block B} |D[i, d]|`` — the block-granular analogue
    of the paper's ``maxweight_d(V)``.
    """
    n, m = D.shape
    assert n % block_rows == 0, (n, block_rows)
    return jnp.max(
        jnp.abs(D).reshape(n // block_rows, block_rows, m), axis=1
    )


def block_upper_bounds(maxw_rows: jax.Array, maxw_cols: jax.Array) -> jax.Array:
    """Upper bound on any cross-block similarity: ``ub[I,J] ≥ max sim``.

    ``sim(x, y) = Σ_d x[d]·y[d] ≤ Σ_d maxw_I[d]·maxw_J[d]`` for ``x ∈ I``,
    ``y ∈ J``. One small matmul over block summaries (paper's partial-indexing
    bound at tile granularity).
    """
    return jnp.einsum(
        "im,jm->ij", maxw_rows, maxw_cols, preferred_element_type=jnp.float32
    )


def row_nnz(D: jax.Array, eps: float = 0.0) -> jax.Array:
    """Number of non-zero components per row (paper's ``|x|``)."""
    return jnp.sum(jnp.abs(D) > eps, axis=-1, dtype=jnp.int32)


def block_minsize_bounds(
    D: jax.Array, block_rows: int, eps: float = 0.0
) -> tuple[jax.Array, jax.Array]:
    """Block summaries for the minsize bound.

    Returns ``(max_weight, max_nnz)`` per row block, where ``max_weight[B] =
    max_{x∈B} maxweight(x)`` and ``max_nnz[B] = max_{y∈B} |y|``. For rows
    normalized to unit L2 norm, Cauchy-Schwarz over the nonzero support gives
    ``sim(x, y) ≤ maxweight(x) · sqrt(|y|)`` — a strictly tighter form of the
    paper's ``|y| ≥ t / maxweight(x)`` minsize test.
    """
    n, m = D.shape
    assert n % block_rows == 0
    absD = jnp.abs(D).reshape(n // block_rows, block_rows, m)
    max_weight = jnp.max(absD, axis=(1, 2))
    nnz = jnp.sum(absD > eps, axis=-1, dtype=jnp.int32)
    max_nnz = jnp.max(nnz, axis=1)
    return max_weight, max_nnz


def block_prune_mask(
    D_rows: jax.Array,
    D_cols: jax.Array,
    threshold: jax.Array | float,
    block_rows: int,
    block_cols: int | None = None,
    *,
    use_minsize: bool = True,
    normalized: bool = True,
) -> jax.Array:
    """``(n_row_blocks, n_col_blocks)`` bool mask; True = block pair is LIVE.

    A False entry certifies every pair in that tile has ``sim < t`` and may be
    skipped. Combines the maxweight bound with the (optional) minsize bound.

    ``D_rows`` are query rows, ``D_cols`` corpus rows (self-join: same array).
    """
    block_cols = block_cols or block_rows
    t = jnp.asarray(threshold, jnp.float32)

    maxw_r = block_maxweight_bounds(D_rows, block_rows)
    maxw_c = block_maxweight_bounds(D_cols, block_cols)
    ub = block_upper_bounds(maxw_r, maxw_c)
    live = ub >= t

    if use_minsize and normalized:
        mw_r, _ = block_minsize_bounds(D_rows, block_rows)
        _, nnz_c = block_minsize_bounds(D_cols, block_cols)
        ms_ub = mw_r[:, None] * jnp.sqrt(nnz_c.astype(jnp.float32))[None, :]
        live &= ms_ub >= t
    return live


class PruneStats(NamedTuple):
    live_blocks: jax.Array    # scalar i32
    total_blocks: jax.Array   # scalar i32
    live_fraction: jax.Array  # scalar f32


def prune_stats(mask: jax.Array) -> PruneStats:
    total = jnp.int32(mask.size)
    live = jnp.sum(mask, dtype=jnp.int32)
    return PruneStats(
        live_blocks=live,
        total_blocks=total,
        live_fraction=live.astype(jnp.float32) / total.astype(jnp.float32),
    )


def local_threshold(threshold: float | jax.Array, num_shards: int) -> jax.Array:
    """Paper Lemma 1: local pruning threshold ``t_local = t / p``.

    For any partition of the dimensions into ``num_shards`` parts, every global
    match ``sim(x,y) ≥ t`` has local partial similarity ``≥ t/p`` on at least
    one shard (otherwise the total would be ``< p·(t/p) = t``).
    """
    return jnp.asarray(threshold, jnp.float32) / num_shards
