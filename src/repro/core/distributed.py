"""Distributed APSS: the paper's 1-D and 2-D data distributions on a TPU mesh.

Paper → TPU mapping (see DESIGN.md §2 for the full table):

- **1-D horizontal** (paper Alg. 6, vectors/rows distributed):
  ``schedule="allgather"`` is the paper-faithful variant — every device
  all-gathers the full corpus and matches its local rows (MPI_Allgather of
  query blocks ≡ all-gather of row shards, the block-processing optimization
  taken to its limit). ``schedule="ring"`` is the beyond-paper variant:
  ``lax.ppermute`` rotates row blocks so peak memory is O(n/p · m) and the
  send of step s+1 overlaps the matmul of step s. ``schedule="halfring"``
  additionally exploits S = Sᵀ: only ⌈p/2⌉ block rotations, with small
  top-k "backward match" packets returned to the transposed owner.

- **1-D vertical** (paper Alg. 3/4, dimensions/columns distributed): every
  device computes partial scores in its dimension slice.
  ``accumulation="allreduce"`` ≈ paper's vertical-noopt (communicate all
  scores); ``accumulation="scatter"`` is the paper's flat accumulation §5.1.7
  (result partitioned over processors); ``accumulation="compressed"``
  implements **local pruning (Lemma 1)**: partials are thresholded at ``t/p``,
  compacted to top-C (value, index) candidates, all-gathered, and exactly
  re-scored with one small psum — the collective volume drops from O(n) to
  O(p·C) per query row, exactly the 10-100× score-volume reduction of paper
  Tables 5-6. ``accumulation="recursive"`` is the recursive pruning /
  hypercube algorithm (paper §5.1.5-5.1.8, Alg. 5): log₂p pairwise exchanges
  with per-level thresholds ``t·s/p`` and upper-bound tracking for exactness.

- **2-D** (paper Alg. 7): checkerboard over ``(data, model)``; a ring over the
  row axis composes with the vertical accumulation over the column axis —
  the same elegant reuse as the paper's Alg. 7 (which passes the row
  communicator into the vertical code).

Row blocks (``block_rows``) are the paper's §5.1.9 block-processing knob: all
variants process a block of query rows per collective step.

Every variant is exact (validated against ``apss_reference``); the compressed
variants carry explicit overflow counters so capacity truncation is visible,
never silent.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import pvary, shard_map
from repro.core.apss import similarity_topk
from repro.core.matches import (
    Matches,
    NEG_INF,
    extract_matches,
    matches_from_candidates,
    merge_matches,
)
from repro.core.pruning import local_threshold
from repro.core.sparse import (
    SparseCorpus,
    densify_rows,
    gather_dot,
    shard_dims,
    sparse_similarity_topk,
)
from repro.planner import telemetry


class ApssStats(NamedTuple):
    """Exactness accounting for capacity-bounded candidate sets."""

    overflow_rows: jax.Array  # i32 scalar: rows whose candidate set was truncated


def _matches_specs(axis) -> Matches:
    return Matches(values=P(axis, None), indices=P(axis, None), counts=P(axis))


def _pvary(tree, axis_name):
    """Mark constants as device-varying over `axis_name` (loop-carry typing)."""
    return jax.tree.map(lambda a: pvary(a, axis_name), tree)


def _to_wire(x: jax.Array) -> jax.Array:
    """Bitcast bf16 ring buffers to u16 for transport.

    Forces the *wire format* of traveling blocks to stay 2 bytes/element:
    without this, backends lacking native bf16 matmuls (the CPU dry-run)
    legally convert the loop carry to f32 once and permute 4-byte payloads,
    which would misrepresent the TPU collective volume (the MXU consumes
    bf16 directly). A no-op for f32 inputs.
    """
    if x.dtype == jnp.bfloat16:
        return lax.bitcast_convert_type(x, jnp.uint16)
    return x


def _from_wire(x: jax.Array, dtype) -> jax.Array:
    if x.dtype == jnp.uint16:
        return lax.bitcast_convert_type(x, dtype)
    return x


def default_candidate_capacity(k: int) -> int:
    """Candidate-capacity default of the compressed/recursive accumulations
    — the single definition shared by dispatch, telemetry records, and the
    planner's cost models (``planner.costmodel``)."""
    return max(4 * k, 32)


def _wire_itemsize(dtype) -> int:
    """Bytes/element a traveling block occupies on the wire (bf16 → 2)."""
    return 2 if dtype == jnp.bfloat16 else jnp.dtype(dtype).itemsize


def _axis_label(axis_name) -> str:
    if isinstance(axis_name, (tuple, list)):
        return "+".join(axis_name)
    return str(axis_name)


def _ring_perm(p: int) -> list[tuple[int, int]]:
    return [(i, (i + 1) % p) for i in range(p)]


def _shift_perm(p: int, s: int) -> list[tuple[int, int]]:
    return [(i, (i - s) % p) for i in range(p)]


# ---------------------------------------------------------------------------
# 1-D horizontal (paper Alg. 6): vectors distributed over `axis_name`
# ---------------------------------------------------------------------------


def apss_horizontal(
    D: jax.Array,
    threshold: float,
    k: int,
    mesh: Mesh,
    axis_name: str = "data",
    *,
    schedule: str = "ring",
    block_rows: int = 512,
    use_kernel: bool = False,
) -> Matches:
    """Distributed APSS with row (vector) sharding.

    ``D (n, m)`` global; rows sharded over ``axis_name`` (a name or tuple of
    names — tuples treat the axes jointly/row-major); ``n`` must divide
    evenly. Returns global :class:`Matches` with rows sharded the same way.

    ``use_kernel=True`` scores every local×visiting block pair with the
    fused streaming Pallas kernel (``O(rows·k)`` output, VMEM-resident score
    tiles) instead of the XLA einsum + ``extract_matches`` pair — the ring
    step's dynamic column offset feeds the kernel directly.

    ``D`` may be a :class:`~repro.core.sparse.SparseCorpus` (allgather,
    ring and halfring schedules): the CSR triple shards/travels instead of
    dense rows — collective volume drops from ``O(n_loc · m)`` to
    ``O(n_loc · cap)`` per hop, a factor ``≈ 1/density`` — and every block
    pair is scored with the gather-dot sparse tile primitive.
    """
    if isinstance(D, SparseCorpus):
        return _apss_horizontal_sparse(
            D, threshold, k, mesh, axis_name,
            schedule=schedule, block_rows=block_rows, use_kernel=use_kernel,
        )
    if isinstance(axis_name, (tuple, list)):
        axis_name = tuple(axis_name)
        p = 1
        for a in axis_name:
            p *= mesh.shape[a]
    else:
        p = mesh.shape[axis_name]

    if isinstance(axis_name, tuple) and schedule != "allgather":
        raise ValueError(
            "ring/halfring need a single axis; use "
            "apss_horizontal_hierarchical for multi-axis row sharding"
        )
    if schedule == "allgather":
        body = functools.partial(
            _horizontal_allgather, threshold=threshold, k=k,
            axis_name=axis_name, block_rows=block_rows,
            use_kernel=use_kernel,
        )
    elif schedule == "ring":
        body = functools.partial(
            _horizontal_ring, threshold=threshold, k=k,
            axis_name=axis_name, p=p, block_rows=block_rows,
            use_kernel=use_kernel,
        )
    elif schedule == "halfring":
        body = functools.partial(
            _horizontal_halfring, threshold=threshold, k=k,
            axis_name=axis_name, p=p, block_rows=block_rows,
            use_kernel=use_kernel,
        )
    else:
        raise ValueError(f"unknown horizontal schedule: {schedule}")

    if telemetry.enabled():
        n, m = D.shape
        n_loc = n // p
        telemetry.record(telemetry.ApssStats(
            variant=f"horizontal/{schedule}",
            n=n, m=m, devices=p, block_rows=block_rows, sparse=False,
            hops=telemetry.horizontal_hops(
                schedule, p, _axis_label(axis_name),
                telemetry.dense_block_bytes(n_loc, m, _wire_itemsize(D.dtype)),
                telemetry.matches_bytes(n_loc, k),
            ),
            flops=telemetry.dense_join_flops(n_loc, n, m)
            * (0.55 if schedule == "halfring" and not use_kernel else 1.0),
            extra={"use_kernel": use_kernel},
        ))
    # The replication checker has no rule for pallas_call on some JAX
    # versions; the kernel path is verified numerically by tests instead.
    return shard_map(
        body,
        mesh=mesh,
        in_specs=P(axis_name, None),
        out_specs=_matches_specs(axis_name),
        check_vma=not use_kernel,
    )(D)


def _flat_axis_index(axis_name):
    """Row-major flat rank over one axis name or a tuple of axis names."""
    if isinstance(axis_name, tuple):
        flat = jnp.int32(0)
        for a in axis_name:
            flat = flat * lax.psum(1, a) + lax.axis_index(a)
        return flat
    return lax.axis_index(axis_name)


def _horizontal_allgather(
    D_loc, *, threshold, k, axis_name, block_rows, use_kernel=False
):
    """Paper-faithful Alg. 6: all-gather the corpus, match local rows."""
    n_loc = D_loc.shape[0]
    me = _flat_axis_index(axis_name)
    D_all = lax.all_gather(D_loc, axis_name, axis=0, tiled=True)
    return similarity_topk(
        D_loc,
        D_all,
        threshold,
        k,
        block_rows=min(block_rows, n_loc),
        exclude_self=True,
        row_offset=me * n_loc,
        use_kernel=use_kernel,
    )


def _horizontal_ring(
    D_loc, *, threshold, k, axis_name, p, block_rows, use_kernel=False
):
    """Ring schedule: rotate row blocks; overlap send with compute."""
    n_loc, m = D_loc.shape
    me = lax.axis_index(axis_name)
    row_off = me * n_loc
    bs = min(block_rows, n_loc)

    def compute(buf, s, matches):
        src = jnp.mod(me - s, p)
        m_new = similarity_topk(
            D_loc, buf, threshold, k,
            block_rows=bs, exclude_self=True,
            row_offset=row_off, col_offset=src * n_loc,
            use_kernel=use_kernel,
        )
        return merge_matches(matches, m_new)

    def step(s, carry):
        buf, matches = carry
        # Send the current block onward *before* using it: XLA overlaps the
        # collective-permute with the (much longer) local matmul.
        nxt = lax.ppermute(buf, axis_name, perm=_ring_perm(p))
        matches = compute(buf, s, matches)
        return nxt, matches

    matches0 = _pvary(_empty_local_matches(n_loc, k), axis_name)
    buf, matches = lax.fori_loop(0, p - 1, step, (D_loc, matches0))
    matches = compute(buf, p - 1, matches)  # last block: no trailing send
    return matches


def _horizontal_halfring(
    D_loc, *, threshold, k, axis_name, p, block_rows, use_kernel=False
):
    """Half-ring: exploit S = Sᵀ — only ⌈(p-1)/2⌉ block hops.

    Each traveling block carries a "return caravan": the top-k backward
    (transposed) matches accumulated by every visitor. At offset ``s`` the
    visitor computes the cross tile once, keeps forward matches (its own
    rows), and folds backward matches (the block owner's rows) into the
    caravan, which hops along with the block. After ``p//2`` hops one static
    shift delivers the caravan home. Halves the large block traffic of the
    full ring; the caravan adds only O(k) words/row/hop.

    Kernel path: the fused kernel extracts matches in-flight (the score
    tile never leaves VMEM), so the two orientations are two kernel joins
    with swapped offsets instead of one XLA einsum read twice. That keeps
    the schedule's halved *wire* traffic but recomputes the tile's MXU work
    for the mirror (≈ ring-fused compute); folding both orientations into
    one kernel needs per-tile candidate packets + a cross-tile merge (the
    ``apss_fused_compacted`` architecture) lifted into the ring loop — an
    open item, see DESIGN.md §3.
    """
    n_loc, m = D_loc.shape
    me = lax.axis_index(axis_name)
    row_off = me * n_loc
    bs = min(block_rows, n_loc)
    half = p // 2

    # Step 0: self block.
    matches = similarity_topk(
        D_loc, D_loc, threshold, k, block_rows=bs,
        exclude_self=True, row_offset=row_off, col_offset=row_off,
        use_kernel=use_kernel,
    )
    if p == 1:
        return matches

    def cross_tile(buf, s, need_bwd=True):
        src = jnp.mod(me - s, p)  # owner of `buf`
        col_off = src * n_loc
        if use_kernel:
            fwd = similarity_topk(
                D_loc, buf, threshold, k, block_rows=bs,
                exclude_self=True, row_offset=row_off, col_offset=col_off,
                use_kernel=True,
            )
            if not need_bwd:
                # The kernel path's backward orientation is a second full
                # fused join, not a cheap transposed extraction — skip it
                # deterministically when the caller discards it (even-p
                # final step) instead of hoping XLA DCEs a custom-call.
                return fwd, None
            bwd = similarity_topk(
                buf, D_loc, threshold, k, block_rows=bs,
                exclude_self=True, row_offset=col_off, col_offset=row_off,
                use_kernel=True,
            )
            return fwd, bwd
        S = jnp.einsum(
            "im,jm->ij", D_loc, buf, preferred_element_type=jnp.float32
        )
        fwd = extract_matches(
            S, threshold, k, row_offset=row_off, col_offset=col_off,
            exclude_self=True,
        )
        bwd = extract_matches(
            S.T, threshold, k, row_offset=col_off, col_offset=row_off,
            exclude_self=True,
        )
        return fwd, bwd

    def hop(x):
        return lax.ppermute(x, axis_name, perm=_ring_perm(p))

    def step(s, carry):
        buf, caravan, mm = carry
        buf = hop(buf)
        caravan = jax.tree.map(hop, caravan)
        fwd, bwd = cross_tile(buf, s)
        return buf, merge_matches(caravan, bwd), merge_matches(mm, fwd)

    caravan = _pvary(_empty_local_matches(n_loc, k), axis_name)
    buf, caravan, matches = lax.fori_loop(
        1, half, step, (D_loc, caravan, matches)
    )
    # Final offset s = half: forward always; backward only when p is odd
    # (for even p both orientations of the antipodal pair are covered
    # forward, and a backward copy would double-count).
    if p % 2 == 1:
        buf, caravan, matches = step(half, (buf, caravan, matches))
    else:
        buf = hop(buf)
        caravan = jax.tree.map(hop, caravan)
        fwd, _ = cross_tile(buf, jnp.int32(half), need_bwd=False)
        matches = merge_matches(matches, fwd)
    # Send the caravan home: its rows belong to device (me - half).
    home = jax.tree.map(
        lambda x: lax.ppermute(x, axis_name, perm=_shift_perm(p, half)),
        caravan,
    )
    return merge_matches(matches, home)


def _empty_local_matches(rows: int, k: int) -> Matches:
    return Matches(
        values=jnp.full((rows, k), NEG_INF, jnp.float32),
        indices=jnp.full((rows, k), -1, jnp.int32),
        counts=jnp.zeros((rows,), jnp.int32),
    )


# ---------------------------------------------------------------------------
# 1-D horizontal, sparse corpora: the CSR triple shards/travels
# ---------------------------------------------------------------------------


def _apss_horizontal_sparse(
    D: SparseCorpus, threshold, k, mesh, axis_name, *,
    schedule, block_rows, use_kernel,
):
    if use_kernel:
        raise ValueError(
            "sparse use_kernel is the self-join worklist path "
            "(kernels.apss_block.sparse); distributed sparse schedules "
            "score with the XLA gather-dot primitive"
        )
    if isinstance(axis_name, (tuple, list)):
        raise ValueError("sparse horizontal needs a single axis name")
    p = mesh.shape[axis_name]
    if schedule == "allgather":
        body = functools.partial(
            _sparse_horizontal_allgather, m=D.m, threshold=threshold, k=k,
            axis_name=axis_name, block_rows=block_rows,
        )
    elif schedule == "ring":
        body = functools.partial(
            _sparse_horizontal_ring, m=D.m, threshold=threshold, k=k,
            axis_name=axis_name, p=p, block_rows=block_rows,
        )
    elif schedule == "halfring":
        body = functools.partial(
            _sparse_horizontal_halfring, m=D.m, threshold=threshold, k=k,
            axis_name=axis_name, p=p, block_rows=block_rows,
        )
    else:
        raise ValueError(
            f"sparse horizontal supports allgather|ring|halfring, "
            f"got: {schedule}"
        )
    if telemetry.enabled():
        n, n_loc = D.n, D.n // p
        telemetry.record(telemetry.ApssStats(
            variant=f"horizontal/{schedule}",
            n=n, m=D.m, devices=p, block_rows=block_rows, sparse=True,
            hops=telemetry.horizontal_hops(
                schedule, p, _axis_label(axis_name),
                telemetry.csr_block_bytes(n_loc, D.cap),
                telemetry.matches_bytes(n_loc, k),
                payload="csr_block",
            ),
            flops=telemetry.sparse_join_flops(n_loc, n, D.cap),
            extra={"cap": D.cap},
        ))
    # The VMA checker has no rule for the scatter/gather ops inside the
    # sparse tile primitive on some JAX versions; verified numerically.
    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axis_name, None), P(axis_name, None), P(axis_name)),
        out_specs=_matches_specs(axis_name),
        check_vma=False,
    )(D.indices, D.values, D.nnz)


def _sparse_horizontal_allgather(
    idx, val, nnz, *, m, threshold, k, axis_name, block_rows
):
    """Paper-faithful Alg. 6 on CSR: all-gather the (small) CSR triple."""
    n_loc = idx.shape[0]
    me = _flat_axis_index(axis_name)

    def g(x):
        return lax.all_gather(x, axis_name, axis=0, tiled=True)

    return sparse_similarity_topk(
        SparseCorpus(idx, val, nnz, m),
        SparseCorpus(g(idx), g(val), g(nnz), m),
        threshold,
        k,
        block_rows=min(block_rows, n_loc),
        exclude_self=True,
        row_offset=me * n_loc,
        vary_axes=(axis_name,),
    )


def _sparse_horizontal_ring(
    idx, val, nnz, *, m, threshold, k, axis_name, p, block_rows
):
    """Ring schedule on CSR: the traveling block is the CSR triple, so each
    hop moves ``O(n_loc · cap)`` words instead of ``O(n_loc · m)``."""
    n_loc = idx.shape[0]
    me = lax.axis_index(axis_name)
    row_off = me * n_loc
    bs = min(block_rows, n_loc)
    loc = SparseCorpus(idx, val, nnz, m)

    def compute(buf, s, matches):
        src = jnp.mod(me - s, p)
        m_new = sparse_similarity_topk(
            loc, SparseCorpus(*buf, m), threshold, k,
            block_rows=bs, exclude_self=True,
            row_offset=row_off, col_offset=src * n_loc,
            vary_axes=(axis_name,),
        )
        return merge_matches(matches, m_new)

    def step(s, carry):
        buf, matches = carry
        nxt = jax.tree.map(
            lambda x: lax.ppermute(x, axis_name, perm=_ring_perm(p)), buf
        )
        matches = compute(buf, s, matches)
        return nxt, matches

    matches0 = _pvary(_empty_local_matches(n_loc, k), axis_name)
    buf, matches = lax.fori_loop(0, p - 1, step, ((idx, val, nnz), matches0))
    return compute(buf, p - 1, matches)


def _sparse_horizontal_halfring(
    idx, val, nnz, *, m, threshold, k, axis_name, p, block_rows
):
    """Half-ring on CSR: the traveling CSR triple makes only ⌈(p-1)/2⌉ hops.

    Identical caravan structure to the dense ``_horizontal_halfring`` —
    the S = Sᵀ wire-halving is schedule-level, so the CSR triple rides it
    unchanged: each hop moves ``O(n_loc · cap)`` words (the triple) plus
    the ``O(n_loc · k)`` caravan of backward matches, half as many block
    hops as the sparse ring. Like the dense *kernel* halfring path, the
    two orientations of a cross tile are two sparse joins with swapped
    arguments rather than one score matrix read twice (the blocked sparse
    scorer never materializes the tile's scores to transpose), so compute
    matches the ring while wire traffic halves. Parity with the sparse
    ring is asserted by ``tests/test_sparse.py``.
    """
    n_loc = idx.shape[0]
    me = lax.axis_index(axis_name)
    row_off = me * n_loc
    bs = min(block_rows, n_loc)
    half = p // 2
    loc = SparseCorpus(idx, val, nnz, m)

    def join(Q, C, row_o, col_o):
        return sparse_similarity_topk(
            Q, C, threshold, k, block_rows=bs, exclude_self=True,
            row_offset=row_o, col_offset=col_o, vary_axes=(axis_name,),
        )

    # Step 0: self block.
    matches = join(loc, loc, row_off, row_off)
    if p == 1:
        return matches

    def cross_tile(buf, s, need_bwd=True):
        src = jnp.mod(me - s, p)  # owner of `buf`
        col_off = src * n_loc
        cur = SparseCorpus(*buf, m)
        fwd = join(loc, cur, row_off, col_off)
        if not need_bwd:  # even-p final step: mirror covered forward
            return fwd, None
        bwd = join(cur, loc, col_off, row_off)
        return fwd, bwd

    def hop(x):
        return lax.ppermute(x, axis_name, perm=_ring_perm(p))

    def step(s, carry):
        buf, caravan, mm = carry
        buf = jax.tree.map(hop, buf)
        caravan = jax.tree.map(hop, caravan)
        fwd, bwd = cross_tile(buf, s)
        return buf, merge_matches(caravan, bwd), merge_matches(mm, fwd)

    caravan = _pvary(_empty_local_matches(n_loc, k), axis_name)
    buf, caravan, matches = lax.fori_loop(
        1, half, step, ((idx, val, nnz), caravan, matches)
    )
    # Final offset s = half: forward always; backward only when p is odd
    # (for even p both orientations of the antipodal pair are covered
    # forward, and a backward copy would double-count).
    if p % 2 == 1:
        buf, caravan, matches = step(half, (buf, caravan, matches))
    else:
        buf = jax.tree.map(hop, buf)
        caravan = jax.tree.map(hop, caravan)
        fwd, _ = cross_tile(buf, jnp.int32(half), need_bwd=False)
        matches = merge_matches(matches, fwd)
    # Send the caravan home: its rows belong to device (me - half).
    home = jax.tree.map(
        lambda x: lax.ppermute(x, axis_name, perm=_shift_perm(p, half)),
        caravan,
    )
    return merge_matches(matches, home)


# ---------------------------------------------------------------------------
# 1-D vertical (paper Algs. 3-5): dimensions distributed over `axis_name`
# ---------------------------------------------------------------------------


def apss_vertical(
    D: jax.Array,
    threshold: float,
    k: int,
    mesh: Mesh,
    axis_name: str = "model",
    *,
    accumulation: str = "compressed",
    block_rows: int = 512,
    candidate_capacity: int | None = None,
    return_stats: bool = False,
) -> Matches | tuple[Matches, ApssStats]:
    """Distributed APSS with dimension (feature) sharding.

    ``D (n, m)`` global; columns sharded over ``axis_name``; every device sees
    all rows in an ``m/p`` dimension slice and computes *partial* scores which
    are then accumulated (paper's score-accumulation phase).

    ``D`` may be a :class:`~repro.core.sparse.SparseCorpus`: dimension
    sharding then splits the **inverted index** — each device owns a
    contiguous slice of posting lists (host-side ``shard_dims``, so the
    sparse entry is not traceable) and computes partials with the sparse
    gather-dot primitive. All four accumulations apply unchanged: they
    only ever see the ``(block, n)`` partial-score tiles.
    """
    if isinstance(D, SparseCorpus):
        return _apss_vertical_sparse(
            D, threshold, k, mesh, axis_name,
            accumulation=accumulation, block_rows=block_rows,
            candidate_capacity=candidate_capacity, return_stats=return_stats,
        )
    n = D.shape[0]

    def make_partials(D_loc):
        return functools.partial(_partial_scores, D_loc, block_rows=block_rows)

    out = _vertical_dispatch(
        D, make_partials, n, threshold, k, mesh, axis_name,
        accumulation=accumulation, block_rows=block_rows,
        candidate_capacity=candidate_capacity, return_stats=return_stats,
        in_specs=P(None, axis_name), strict_vma=True,
    )
    if telemetry.enabled():
        p = mesh.shape[axis_name]
        C = candidate_capacity or default_candidate_capacity(k)
        telemetry.record(telemetry.ApssStats(
            variant=f"vertical/{accumulation}",
            n=n, m=D.shape[1], devices=p, block_rows=block_rows, sparse=False,
            hops=telemetry.vertical_hops(
                accumulation, str(axis_name), p, n, block_rows, C
            ),
            flops=telemetry.dense_join_flops(n, n, D.shape[1]) / p,
            extra={"capacity": C},
        ))
    return out


def _vertical_dispatch(
    args, make_partials, n, threshold, k, mesh, axis_name, *,
    accumulation, block_rows, candidate_capacity, return_stats, in_specs,
    strict_vma,
):
    """Shared accumulation dispatch for dense and sparse vertical inputs.

    ``make_partials(*local_args) -> (blk -> (block_rows, n) partials)``
    builds the per-device partial-score closure; everything downstream
    (Lemma-1 compaction, flat/recursive accumulation) is representation-
    agnostic.
    """
    p = mesh.shape[axis_name]
    C = candidate_capacity or default_candidate_capacity(k)
    if n % block_rows != 0:
        raise ValueError(f"n={n} must be a multiple of block_rows={block_rows}")
    args = args if isinstance(args, tuple) else (args,)

    if accumulation == "allreduce":
        def fn(*local):
            return _vertical_allreduce(
                make_partials(*local), n, threshold=threshold, k=k,
                axis_name=axis_name, block_rows=block_rows,
            )
        out = shard_map(
            fn, mesh=mesh, in_specs=in_specs,
            out_specs=Matches(values=P(), indices=P(), counts=P()),
            check_vma=strict_vma,
        )(*args)
        stats = ApssStats(overflow_rows=jnp.int32(0))
    elif accumulation == "scatter":
        if block_rows % p != 0:
            raise ValueError("scatter accumulation needs block_rows % p == 0")
        def fn(*local):
            return _vertical_scatter(
                make_partials(*local), n, threshold=threshold, k=k,
                axis_name=axis_name, p=p, block_rows=block_rows,
            )
        stacked = shard_map(
            fn, mesh=mesh, in_specs=in_specs,
            out_specs=Matches(
                values=P(None, axis_name, None),
                indices=P(None, axis_name, None),
                counts=P(None, axis_name),
            ),
            check_vma=strict_vma,
        )(*args)
        out = jax.tree.map(lambda x: x.reshape(n, *x.shape[2:]), stacked)
        stats = ApssStats(overflow_rows=jnp.int32(0))
    elif accumulation == "compressed":
        def fn(*local):
            return _vertical_compressed(
                make_partials(*local), n, threshold=threshold, k=k,
                axis_name=axis_name, p=p, block_rows=block_rows, capacity=C,
            )
        # NOTE: outputs are value-replicated (all devices compute the same
        # candidate union and psum-accumulated scores) but the static VMA
        # checker cannot see through all_gather-derived indexing; verified
        # numerically by tests instead.
        out, stats = shard_map(
            fn, mesh=mesh, in_specs=in_specs,
            out_specs=(
                Matches(values=P(), indices=P(), counts=P()),
                ApssStats(overflow_rows=P()),
            ),
            check_vma=False,
        )(*args)
    elif accumulation == "recursive":
        if p & (p - 1):
            raise ValueError("recursive accumulation needs power-of-two shards")
        def fn(*local):
            return _vertical_recursive(
                make_partials(*local), n, threshold=threshold, k=k,
                axis_name=axis_name, p=p, block_rows=block_rows, capacity=C,
            )
        out, stats = shard_map(
            fn, mesh=mesh, in_specs=in_specs,
            out_specs=(
                Matches(values=P(), indices=P(), counts=P()),
                ApssStats(overflow_rows=P()),
            ),
            check_vma=False,
        )(*args)
    else:
        raise ValueError(f"unknown vertical accumulation: {accumulation}")

    if return_stats:
        return out, stats
    return out


def _vertical_sparse_post_split(
    idx_s, val_s, *, n, m_loc, threshold, k, mesh, axis_name,
    accumulation, block_rows, candidate_capacity, return_stats,
):
    """Everything AFTER the host ``shard_dims`` split — pure array-in
    computation, so it is jit-lowerable (the compile audit AOT-compiles
    the sparse vertical family through this seam; the public entry stays
    host-staged because the split itself shapes by data)."""
    ncb = n // block_rows  # divisibility validated by _vertical_dispatch
    cap_loc = idx_s.shape[-1]

    def make_partials(idxL, valL):
        idxL, valL = idxL[0], valL[0]  # shard dim (1, n, cap_loc) → local
        sp_loc = SparseCorpus(idxL, valL, jnp.zeros((n,), jnp.int32), m_loc)
        Ci = idxL.reshape(ncb, block_rows, cap_loc)
        Cv = valL.reshape(ncb, block_rows, cap_loc)

        def partials(blk):
            qd = densify_rows(sp_loc, blk * block_rows, block_rows)

            def chunk(_, ci):
                return _, gather_dot(qd, Ci[ci], Cv[ci])

            _, ss = lax.scan(chunk, 0, jnp.arange(ncb))  # (ncb, b, block)
            return jnp.moveaxis(ss, 0, 1).reshape(block_rows, n)

        return partials

    return _vertical_dispatch(
        (jnp.asarray(idx_s), jnp.asarray(val_s)), make_partials, n,
        threshold, k, mesh, axis_name,
        accumulation=accumulation, block_rows=block_rows,
        candidate_capacity=candidate_capacity, return_stats=return_stats,
        in_specs=(P(axis_name, None, None), P(axis_name, None, None)),
        # The VMA checker has no rule for the scatter/gather ops inside the
        # sparse partial-score primitive; verified numerically by tests.
        strict_vma=False,
    )


def _apss_vertical_sparse(
    D: SparseCorpus, threshold, k, mesh, axis_name, *,
    accumulation, block_rows, candidate_capacity, return_stats,
):
    p = mesh.shape[axis_name]
    n = D.n
    idx_s, val_s, nnz_s, m_loc = shard_dims(D, p)  # host split: not traceable
    del nnz_s  # scoring needs only the 0-padded (idx, val) slots
    cap_loc = idx_s.shape[-1]

    out = _vertical_sparse_post_split(
        idx_s, val_s, n=n, m_loc=m_loc, threshold=threshold, k=k,
        mesh=mesh, axis_name=axis_name, accumulation=accumulation,
        block_rows=block_rows, candidate_capacity=candidate_capacity,
        return_stats=return_stats,
    )
    if telemetry.enabled():
        C = candidate_capacity or default_candidate_capacity(k)
        telemetry.record(telemetry.ApssStats(
            variant=f"vertical/{accumulation}",
            n=n, m=D.m, devices=p, block_rows=block_rows, sparse=True,
            hops=telemetry.vertical_hops(
                accumulation, str(axis_name), p, n, block_rows, C
            ),
            flops=telemetry.sparse_join_flops(n, n, cap_loc),
            extra={"capacity": C, "cap_loc": cap_loc},
        ))
    return out


def _partial_scores(D_loc, blk, block_rows):
    """Partial similarity of one query row block in the local dim slice."""
    q = lax.dynamic_slice_in_dim(D_loc, blk * block_rows, block_rows, axis=0)
    return jnp.einsum("im,jm->ij", q, D_loc, preferred_element_type=jnp.float32)


def _vertical_allreduce(partials_fn, n, *, threshold, k, axis_name, block_rows):
    """vertical-noopt: all-reduce the full dense score block (paper baseline)."""
    nb = n // block_rows

    def body(_, blk):
        A = partials_fn(blk)
        S = lax.psum(A, axis_name)
        m = extract_matches(
            S, threshold, k, row_offset=blk * block_rows, exclude_self=True
        )
        return _, m

    _, ms = lax.scan(body, None, jnp.arange(nb))
    return jax.tree.map(lambda x: x.reshape(n, *x.shape[2:]), ms)


def _vertical_scatter(partials_fn, n, *, threshold, k, axis_name, p, block_rows):
    """Paper §5.1.7 flat accumulation: scores reduced AND partitioned."""
    nb = n // block_rows
    rows_per_dev = block_rows // p
    me = lax.axis_index(axis_name)

    def body(_, blk):
        A = partials_fn(blk)  # (b, n)
        S_slice = lax.psum_scatter(A, axis_name, scatter_dimension=0, tiled=True)
        m = extract_matches(
            S_slice, threshold, k,
            row_offset=blk * block_rows + me * rows_per_dev,
            exclude_self=True,
        )
        return _, m

    _, ms = lax.scan(body, None, jnp.arange(nb))
    return ms  # stacked (nb, rows_per_dev, ...) per device


def _local_candidates(A, t_local, capacity):
    """Top-`capacity` local candidates at the Lemma-1 threshold ``t/p``."""
    masked = jnp.where(A >= t_local, A, NEG_INF)
    cc = min(capacity, A.shape[-1])
    c_val, c_idx = lax.top_k(masked, cc)
    c_idx = jnp.where(c_val > NEG_INF, c_idx, -1).astype(jnp.int32)
    n_cand = jnp.sum(masked > NEG_INF, axis=-1, dtype=jnp.int32)
    overflow = jnp.sum(n_cand > cc, dtype=jnp.int32)
    return c_val, c_idx, overflow


def _vertical_compressed(
    partials_fn, n, *, threshold, k, axis_name, p, block_rows, capacity
):
    """Local pruning (Lemma 1) + candidate compaction (paper §5.1.3-5.1.4).

    Per query block: threshold partials at ``t/p``; compact to top-C
    ``(idx, val)``; all-gather the candidate ids (volume p·C « n); every
    device contributes its partial at the union via one small psum; filter
    exactly at ``t``. Matches paper's two-step accumulate: candidate-set
    union (Reduce-All ∪) then parallel score addition.
    """
    nb = n // block_rows
    t_local = local_threshold(threshold, p)

    def body(carry, blk):
        A = partials_fn(blk)  # (b, n) partials
        c_val, c_idx, overflow = _local_candidates(A, t_local, capacity)
        # Union of candidate ids across dimension shards (small all-gather).
        all_idx = lax.all_gather(c_idx, axis_name, axis=1, tiled=True)  # (b, p*C)
        safe = jnp.maximum(all_idx, 0)
        mine = jnp.take_along_axis(A, safe, axis=1)
        mine = jnp.where(all_idx >= 0, mine, 0.0)
        total = lax.psum(mine, axis_name)  # exact scores at the union
        m = matches_from_candidates(
            total, all_idx, threshold, k,
            row_offset=blk * block_rows, exclude_self=True, dedupe=True,
        )
        return carry + overflow, m

    overflow, ms = lax.scan(body, _pvary(jnp.int32(0), axis_name), jnp.arange(nb))
    out = jax.tree.map(lambda x: x.reshape(n, *x.shape[2:]), ms)
    # Overflow counts are device-local; expose the global max (any truncation
    # anywhere invalidates the exactness guarantee for affected rows).
    overflow = lax.pmax(overflow, axis_name)
    return out, ApssStats(overflow_rows=overflow)


def _pairwise_merge_candidates(idx_a, val_a, ub_a, idx_b, val_b, ub_b, capacity):
    """Merge two per-row candidate lists, summing values on shared indices.

    Inputs are ``(rows, C)`` each; at most two copies of any index exist, so a
    sort + adjacent-combine is exact. Keeps the top-`capacity` by upper bound.
    """
    idx = jnp.concatenate([idx_a, idx_b], axis=-1)
    val = jnp.concatenate([val_a, val_b], axis=-1)
    ub = jnp.concatenate([ub_a, ub_b], axis=-1)
    order = jnp.argsort(idx, axis=-1)
    idx = jnp.take_along_axis(idx, order, axis=-1)
    val = jnp.take_along_axis(val, order, axis=-1)
    ub = jnp.take_along_axis(ub, order, axis=-1)
    nxt_same = jnp.concatenate(
        [idx[:, 1:] == idx[:, :-1], jnp.zeros_like(idx[:, :1], bool)], axis=-1
    )
    prv_same = jnp.concatenate(
        [jnp.zeros_like(idx[:, :1], bool), idx[:, 1:] == idx[:, :-1]], axis=-1
    )
    # Push duplicates' contribution into the *second* copy, invalidate first.
    val_shift = jnp.concatenate([jnp.zeros_like(val[:, :1]), val[:, :-1]], axis=-1)
    ub_shift = jnp.concatenate([jnp.zeros_like(ub[:, :1]), ub[:, :-1]], axis=-1)
    val = jnp.where(prv_same, val + val_shift, val)
    ub = jnp.where(prv_same, ub + ub_shift, ub)
    dead = nxt_same | (idx < 0)
    ub = jnp.where(dead, NEG_INF, ub)
    sel_ub, sel = lax.top_k(ub, capacity)
    out_idx = jnp.take_along_axis(idx, sel, axis=-1)
    out_val = jnp.take_along_axis(val, sel, axis=-1)
    live = sel_ub > NEG_INF
    # Capacity truncation breaks the exactness argument (an absent candidate
    # no longer implies it was below the level threshold) — count it.
    n_live = jnp.sum(~dead, axis=-1, dtype=jnp.int32)
    overflow = jnp.sum(n_live > capacity, dtype=jnp.int32)
    return (
        jnp.where(live, out_idx, -1),
        jnp.where(live, out_val, 0.0),
        jnp.where(live, sel_ub, NEG_INF),
        overflow,
    )


def _vertical_recursive(
    partials_fn, n, *, threshold, k, axis_name, p, block_rows, capacity
):
    """Recursive local pruning on a hypercube (paper §5.1.5-5.1.6, Alg. 5).

    log₂p pairwise exchanges; at level ℓ (subcube of s=2^{ℓ+1} shards) the
    candidate filter is the subcube threshold ``t·s/p`` (pigeonhole over the
    partition: every true match survives along its strongest branch). To stay
    exact with one-sided candidate knowledge we track an *upper bound*
    ``ub = val + (missing half's threshold)`` and filter on ``ub`` — the
    paper's "completing partial scores" problem solved bound-side. A final
    psum over the (replicated) top-level candidate set yields exact scores.
    """
    nb = n // block_rows
    t = jnp.float32(threshold)
    t_leaf = local_threshold(threshold, p)
    me = lax.axis_index(axis_name)
    levels = p.bit_length() - 1

    def body(carry, blk):
        A = partials_fn(blk)
        c_val, c_idx, overflow = _local_candidates(A, t_leaf, capacity)
        c_ub = jnp.where(c_idx >= 0, c_val, NEG_INF)

        for lvl in range(levels):
            bit = 1 << lvl
            sub_t = t * (2.0 * bit) / p      # threshold of the merged subcube
            half_t = t * float(bit) / p      # missing-half bound
            perm = [(i, i ^ bit) for i in range(p)]
            o_idx, o_val, o_ub = (
                lax.ppermute(x, axis_name, perm=perm)
                for x in (c_idx, c_val, c_ub)
            )
            # One-sided candidates get the partner-half headroom added to ub.
            c_ub_adj = jnp.where(c_idx >= 0, c_ub + half_t, NEG_INF)
            o_ub_adj = jnp.where(o_idx >= 0, o_ub + half_t, NEG_INF)
            # Two-sided duplicates: pairwise merge sums val and adjusted ub,
            # double-counting the +half_t headroom — looser but still sound
            # (ub only ever overestimates the true subcube partial).
            m_idx, m_val, m_ub, merge_ovf = _pairwise_merge_candidates(
                c_idx, c_val, c_ub_adj, o_idx, o_val, o_ub_adj, capacity
            )
            overflow = overflow + merge_ovf
            # A summed pair has ub = ub_a + ub_b + 2*half_t but no missing
            # half: we cannot tell pairs apart post-merge, so keep the looser
            # bound (still sound: ub only ever overestimates).
            keep = m_ub >= sub_t
            c_idx = jnp.where(keep, m_idx, -1)
            c_val = jnp.where(keep, m_val, 0.0)
            c_ub = jnp.where(keep, m_ub, NEG_INF)

        # Top level: candidate ids are level-merged but may still differ per
        # device (capacity effects); take the union once, then exact-rescore.
        all_idx = lax.all_gather(c_idx, axis_name, axis=1, tiled=True)
        safe = jnp.maximum(all_idx, 0)
        mine = jnp.take_along_axis(A, safe, axis=1)
        mine = jnp.where(all_idx >= 0, mine, 0.0)
        total = lax.psum(mine, axis_name)
        m = matches_from_candidates(
            total, all_idx, threshold, k,
            row_offset=blk * block_rows, exclude_self=True, dedupe=True,
        )
        return carry + overflow, m

    overflow, ms = lax.scan(body, _pvary(jnp.int32(0), axis_name), jnp.arange(nb))
    out = jax.tree.map(lambda x: x.reshape(n, *x.shape[2:]), ms)
    overflow = lax.pmax(overflow, axis_name)
    return out, ApssStats(overflow_rows=overflow)


# ---------------------------------------------------------------------------
# 2-D checkerboard (paper Alg. 7)
# ---------------------------------------------------------------------------


def apss_2d(
    D: jax.Array,
    threshold: float,
    k: int,
    mesh: Mesh,
    row_axis: str = "data",
    col_axis: str = "model",
    *,
    accumulation: str = "compressed",
    block_rows: int = 512,
    candidate_capacity: int | None = None,
    return_stats: bool = False,
) -> Matches | tuple[Matches, ApssStats]:
    """2-D distribution: rows over ``row_axis``, dimensions over ``col_axis``.

    Ring over the row axis (horizontal outer loop) composed with vertical
    score accumulation over the column axis per ring step — paper Alg. 7's
    re-use of the vertical algorithm with the row communicator, verbatim in
    mesh-axis form.

    ``D`` may be a :class:`~repro.core.sparse.SparseCorpus`: the checkerboard
    cell ``(i, j)`` holds row shard ``i`` restricted to posting-list slice
    ``j`` (host-side ``shard_dims`` pre-split — see ``_apss_2d_sparse`` for
    the traced-vs-host tradeoff), the per-cell CSR pair rides the row-axis
    ring, and the identical column-axis accumulations apply — both
    representations run the one checkerboard driver through its
    ``partials_fn`` seam (``_checkerboard_sweep``).
    """
    if isinstance(D, SparseCorpus):
        return _apss_2d_sparse(
            D, threshold, k, mesh, row_axis, col_axis,
            accumulation=accumulation, block_rows=block_rows,
            candidate_capacity=candidate_capacity, return_stats=return_stats,
        )
    q = mesh.shape[row_axis]
    r = mesh.shape[col_axis]
    C = candidate_capacity or default_candidate_capacity(k)

    ticker = None
    if telemetry.enabled():
        from repro.distributed.straggler import StepTicker

        ticker = StepTicker()
        n, m = D.shape
        n_loc = n // q
        bs = min(block_rows, n_loc)
        while n_loc % bs:  # mirror _apss_2d_local's block clamp
            bs -= 1
        telemetry.record(telemetry.ApssStats(
            variant=f"2d/{accumulation}",
            n=n, m=m, devices=q * r, block_rows=bs, sparse=False,
            hops=telemetry.twod_hops(
                q, r, str(row_axis), str(col_axis), n_loc, m,
                _wire_itemsize(D.dtype), bs, C, accumulation,
            ),
            flops=telemetry.dense_join_flops(n_loc, n, m) / r,
            extra={"mesh": {str(row_axis): q, str(col_axis): r}},
            step_ticker=ticker,
        ))

    fn = functools.partial(
        _apss_2d_local,
        threshold=threshold, k=k, row_axis=row_axis, col_axis=col_axis,
        q=q, r=r, block_rows=block_rows, capacity=C, accumulation=accumulation,
        ticker=ticker,
    )
    out, stats = shard_map(
        fn,
        mesh=mesh,
        in_specs=P(row_axis, col_axis),
        out_specs=(
            Matches(
                values=P(row_axis, None),
                indices=P(row_axis, None),
                counts=P(row_axis),
            ),
            ApssStats(overflow_rows=P()),
        ),
        check_vma=False,
    )(D)
    if return_stats:
        return out, stats
    return out


def _accumulate_block_scores(
    A, *, col_axis, r, threshold, k, capacity, accumulation,
    row_offset, col_offset,
):
    """Vertical accumulation of one (rows × cols) partial tile over col_axis."""
    if accumulation == "allreduce":
        S = lax.psum(A, col_axis)
        m = extract_matches(
            S, threshold, k, row_offset=row_offset, col_offset=col_offset,
            exclude_self=True,
        )
        return m, jnp.int32(0)
    if accumulation == "compressed":
        t_local = local_threshold(threshold, r)
        c_val, c_idx, overflow = _local_candidates(A, t_local, capacity)
        all_idx = lax.all_gather(c_idx, col_axis, axis=1, tiled=True)
        safe = jnp.maximum(all_idx, 0)
        mine = jnp.take_along_axis(A, safe, axis=1)
        mine = jnp.where(all_idx >= 0, mine, 0.0)
        total = lax.psum(mine, col_axis)
        # Candidate ids are tile-local columns; globalize before extraction.
        gidx = jnp.where(all_idx >= 0, all_idx + col_offset, -1)
        m = matches_from_candidates(
            total, gidx, threshold, k, row_offset=row_offset,
            exclude_self=True, dedupe=True,
        )
        return m, overflow
    raise ValueError(f"unknown 2-D accumulation: {accumulation}")


def _block_clamp(block_rows: int, n_loc: int) -> int:
    """Largest divisor of ``n_loc`` not exceeding ``block_rows``."""
    bs = min(block_rows, n_loc)
    while n_loc % bs:
        bs -= 1
    return bs


def _checkerboard_sweep(
    partials_fn, buf0, n_loc, *, threshold, k, row_axis, col_axis, q, r,
    bs, capacity, accumulation, ticker=None,
):
    """The one 2-D checkerboard driver both representations run through.

    Ring over ``row_axis`` of an opaque traveling pytree ``buf0`` (a dense
    wire-format cell or a sparse CSR pair); per ring step,
    ``partials_fn(buf, blk) -> (bs, n_loc)`` scores local query block
    ``blk`` against the traveling corpus cell in the local dimension slice
    (einsum or gather-dot — the same seam the vertical dispatch uses), and
    ``_accumulate_block_scores`` composes the column-axis accumulation.

    ``ticker`` (a ``distributed.straggler.StepTicker``) plants one host
    tick per rank per ring step — the dep argument is data computed by the
    step, so the callback cannot be hoisted out of the loop.
    """
    nb = n_loc // bs
    me_r = lax.axis_index(row_axis)
    row_off = me_r * n_loc

    def compute_vs(buf, s, matches, overflow):
        """Match my rows against the row block owned by (me_r - s)."""
        src = jnp.mod(me_r - s, q)
        col_off = src * n_loc

        def body(carry, blk):
            A = partials_fn(buf, blk)
            mm, o = _accumulate_block_scores(
                A, col_axis=col_axis, r=r, threshold=threshold, k=k,
                capacity=capacity, accumulation=accumulation,
                row_offset=row_off + blk * bs, col_offset=col_off,
            )
            return carry + o, mm

        ov, ms = lax.scan(body, jnp.int32(0), jnp.arange(nb))
        m_new = jax.tree.map(lambda x: x.reshape(n_loc, *x.shape[2:]), ms)
        if ticker is not None:
            rank = me_r * r + lax.axis_index(col_axis)
            ticker.emit(s, rank, jnp.sum(m_new.counts) + ov)
        return merge_matches(matches, m_new), overflow + ov

    def step(s, carry):
        buf, matches, overflow = carry
        nxt = jax.tree.map(
            lambda x: lax.ppermute(x, row_axis, perm=_ring_perm(q)), buf
        )
        matches, overflow = compute_vs(buf, s, matches, overflow)
        return nxt, matches, overflow

    matches0 = _pvary(_empty_local_matches(n_loc, k), (row_axis, col_axis))
    buf, matches, overflow = lax.fori_loop(
        0, q - 1, step,
        (buf0, matches0, _pvary(jnp.int32(0), (row_axis, col_axis))),
    )
    matches, overflow = compute_vs(buf, q - 1, matches, overflow)
    overflow = lax.pmax(lax.pmax(overflow, col_axis), row_axis)
    return matches, ApssStats(overflow_rows=overflow)


def _apss_2d_local(
    D_loc, *, threshold, k, row_axis, col_axis, q, r, block_rows,
    capacity, accumulation, ticker=None,
):
    n_loc, _ = D_loc.shape
    bs = _block_clamp(block_rows, n_loc)

    def partials(buf, blk):
        qrows = lax.dynamic_slice_in_dim(D_loc, blk * bs, bs, axis=0)
        return jnp.einsum(
            "im,jm->ij", qrows, _from_wire(buf, D_loc.dtype),
            preferred_element_type=jnp.float32,
        )

    return _checkerboard_sweep(
        partials, _to_wire(D_loc), n_loc,
        threshold=threshold, k=k, row_axis=row_axis, col_axis=col_axis,
        q=q, r=r, bs=bs, capacity=capacity, accumulation=accumulation,
        ticker=ticker,
    )


def _apss_2d_sparse(
    D: SparseCorpus, threshold, k, mesh, row_axis, col_axis, *,
    accumulation, block_rows, candidate_capacity, return_stats,
):
    """Sparse 2-D checkerboard: sparse row ring ∘ posting-list-sharded
    accumulation (the last cell of the variant matrix).

    The dimension split is a HOST pre-split (``shard_dims``, like the sparse
    vertical path): each checkerboard cell gets slice-relative indices and
    the exact realized per-cell capacity ``cap_loc``, so the traveling CSR
    pair is as narrow as the data allows. The alternative — a traced-side
    split — would have to pad every cell to the GLOBAL row cap (traced
    shapes cannot depend on the data), inflating ring wire volume by
    ``≈ r·cap/cap_loc`` and scoring FLOPs to match; the price of the host
    split is that (like sparse vertical) the entry is not traceable under
    an outer ``jit`` (``planner.plan._has_host_stage``). See DESIGN.md §5.

    Local (Lemma-1) pruning survives the composition: the compressed
    accumulation thresholds per-cell partials at ``t/r`` exactly as the 1-D
    vertical algorithm does, and the per-cell tile bounds stay conservative
    (``core.pruning.checkerboard_live_mask``).
    """
    q = mesh.shape[row_axis]
    r = mesh.shape[col_axis]
    n = D.n
    if n % q:
        raise ValueError(f"n={n} must be a multiple of {row_axis}={q}")
    C = candidate_capacity or default_candidate_capacity(k)
    # Host split: (r, n, cap_loc) slice-relative indices/values + (r, n) nnz.
    idx_s, val_s, nnz_s, m_loc = shard_dims(D, r)
    cap_loc = idx_s.shape[-1]
    n_loc = n // q
    bs = _block_clamp(block_rows, n_loc)

    ticker = None
    if telemetry.enabled():
        from repro.distributed.straggler import StepTicker

        ticker = StepTicker()
        telemetry.record(telemetry.ApssStats(
            variant=f"2d/{accumulation}",
            n=n, m=D.m, devices=q * r, block_rows=bs, sparse=True,
            hops=telemetry.twod_hops(
                q, r, str(row_axis), str(col_axis), n_loc, D.m, 4, bs, C,
                accumulation, cap_loc=cap_loc,
            ),
            flops=telemetry.sparse_join_flops(n_loc, n, cap_loc),
            extra={
                "mesh": {str(row_axis): q, str(col_axis): r},
                "cap_loc": cap_loc,
            },
            step_ticker=ticker,
        ))

    out, stats = _2d_sparse_post_split(
        idx_s, val_s, nnz_s, m_loc=m_loc, threshold=threshold, k=k,
        mesh=mesh, row_axis=row_axis, col_axis=col_axis,
        accumulation=accumulation, block_rows=block_rows,
        candidate_capacity=C, ticker=ticker,
    )
    if return_stats:
        return out, stats
    return out


def _2d_sparse_post_split(
    idx_s, val_s, nnz_s, *, m_loc, threshold, k, mesh, row_axis, col_axis,
    accumulation, block_rows, candidate_capacity, ticker=None,
):
    """Everything AFTER the host ``shard_dims`` split — jit-lowerable, so
    the compile audit can AOT-compile the sparse checkerboard family
    through this seam (mirrors ``_vertical_sparse_post_split``)."""
    q = mesh.shape[row_axis]
    r = mesh.shape[col_axis]
    fn = functools.partial(
        _apss_2d_sparse_local,
        m_loc=m_loc, threshold=threshold, k=k, row_axis=row_axis,
        col_axis=col_axis, q=q, r=r, block_rows=block_rows,
        capacity=candidate_capacity, accumulation=accumulation,
        ticker=ticker,
    )
    # Same VMA caveat as every sparse schedule: no checker rule for the
    # scatter/gather ops inside the sparse tile primitive.
    return shard_map(
        fn,
        mesh=mesh,
        in_specs=(
            P(col_axis, row_axis, None),
            P(col_axis, row_axis, None),
            P(col_axis, row_axis),
        ),
        out_specs=(
            Matches(
                values=P(row_axis, None),
                indices=P(row_axis, None),
                counts=P(row_axis),
            ),
            ApssStats(overflow_rows=P()),
        ),
        check_vma=False,
    )(jnp.asarray(idx_s), jnp.asarray(val_s), jnp.asarray(nnz_s))


def _apss_2d_sparse_local(
    idx, val, nnz, *, m_loc, threshold, k, row_axis, col_axis, q, r,
    block_rows, capacity, accumulation, ticker=None,
):
    # Shard dims (1, n_loc, cap_loc) / (1, n_loc) → local cell.
    idx, val, nnz = idx[0], val[0], nnz[0]
    n_loc = idx.shape[0]
    bs = _block_clamp(block_rows, n_loc)
    sp_loc = SparseCorpus(idx, val, nnz, m_loc)

    def partials(buf, blk):
        qd = densify_rows(sp_loc, blk * bs, bs)  # (bs, m_loc)
        bi, bv = buf
        return gather_dot(qd, bi, bv)            # (bs, n_loc)

    # The traveling cell is the CSR pair only: scoring sums every slot and
    # padding is inert, so the nnz vector never needs to ride the ring.
    return _checkerboard_sweep(
        partials, (idx, val), n_loc,
        threshold=threshold, k=k, row_axis=row_axis, col_axis=col_axis,
        q=q, r=r, bs=bs, capacity=capacity, accumulation=accumulation,
        ticker=ticker,
    )


# ---------------------------------------------------------------------------
# Multi-pod hierarchical horizontal schedule
# ---------------------------------------------------------------------------


def _nested_ring_sweep(mesh, axes, carry0, join, *, ticker=None, rank=None):
    """Shared N-level nested-ring driver (dense blocks or CSR triples).

    ``carry0 = (buf, owner, matches)``: ``buf`` is an arbitrary pytree that
    hops with its 1-element i32 ``owner`` id; ``join(buf, owner, matches)``
    scores the local rows against the traveling block. The innermost axis
    rings most often; each outer axis hops once per full inner sweep.

    ``ticker`` (with ``rank``, the caller's flat rank) plants one host tick
    per rank per compute — ``∏ sizes`` ticks per rank for a full sweep. A
    traced step counter rides the carry to number them; it is replicated
    (identical on every rank), so it never perturbs the VMA analysis.
    """
    sizes = [mesh.shape[a] for a in axes]

    def compute(carry):
        buf, own, matches, stepno = carry
        matches = join(buf, own, matches)
        if ticker is not None:
            ticker.emit(stepno, rank, jnp.sum(matches.counts))
        return buf, own, matches, stepno + 1

    def hop(carry, axis):
        buf, own, matches, stepno = carry
        perm = _ring_perm(mesh.shape[axis])
        pp = functools.partial(lax.ppermute, axis_name=axis, perm=perm)
        return jax.tree.map(pp, buf), pp(own), matches, stepno

    def sweep(level, carry):
        if level == len(axes):
            return compute(carry)
        axis, p = axes[level], sizes[level]

        def step(_, c):
            c = sweep(level + 1, c)
            return hop(c, axis)

        carry = lax.fori_loop(0, p - 1, step, carry)
        return sweep(level + 1, carry)  # last sub-sweep: no trailing hop

    _, _, matches, _ = sweep(0, (*carry0, jnp.int32(0)))
    return matches


def apss_horizontal_hierarchical(
    D: jax.Array,
    threshold: float,
    k: int,
    mesh: Mesh,
    axes: Sequence[str] = ("pod", "data"),
    *,
    block_rows: int = 512,
    use_kernel: bool = False,
) -> Matches:
    """N-level nested ring for hierarchical interconnects.

    Rows shard over ``axes`` jointly (row-major); the innermost axis rings
    most often (cheap ICI hops), each outer axis hops once per full inner
    sweep — so slow links (pod-to-pod DCN) carry ``∏inner`` fewer transfers
    than they would in a flat ring, each overlapping an entire inner sweep
    of compute.

    The traveling block carries its **owner id** (a 1-element i32 that hops
    with it), which replaces all modular-offset bookkeeping: the column
    offset of the current block is simply ``owner · n_loc``.

    ``D`` may be a :class:`~repro.core.sparse.SparseCorpus`: the CSR triple
    rides the nested ring exactly like the flat sparse ring/halfring — each
    hop moves ``O(n_loc · cap)`` words instead of ``O(n_loc · m)`` — and
    every block pair is scored with the gather-dot sparse tile primitive
    (parity with the sparse ring asserted by ``tests/test_sparse.py``).
    """
    axes = tuple(axes)
    sizes = [mesh.shape[a] for a in axes]
    ptot = 1
    for s in sizes:
        ptot *= s
    if isinstance(D, SparseCorpus) and use_kernel:
        # validate BEFORE the telemetry record: a raising call must not
        # log wire bytes for an execution that never happens
        raise ValueError(
            "sparse use_kernel is the self-join worklist path "
            "(kernels.apss_block.sparse); distributed sparse schedules "
            "score with the XLA gather-dot primitive"
        )

    ticker = None
    if telemetry.enabled():
        from repro.distributed.straggler import StepTicker

        ticker = StepTicker()
        n = D.shape[0]
        n_loc = n // ptot
        sparse_in = isinstance(D, SparseCorpus)
        block_bytes = (
            telemetry.csr_block_bytes(n_loc, D.cap) if sparse_in
            else telemetry.dense_block_bytes(
                n_loc, D.shape[1], _wire_itemsize(D.dtype)
            )
        )
        telemetry.record(telemetry.ApssStats(
            variant="hierarchical",
            n=n, m=D.shape[1], devices=ptot, block_rows=block_rows,
            sparse=sparse_in,
            hops=telemetry.hierarchical_hops(
                tuple(sizes), axes, block_bytes,
                payload="csr_block" if sparse_in else "dense_block",
            ),
            flops=(
                telemetry.sparse_join_flops(n_loc, n, D.cap) if sparse_in
                else telemetry.dense_join_flops(n_loc, n, D.shape[1])
            ),
            extra={"axes": dict(zip(axes, sizes)), "use_kernel": use_kernel},
            step_ticker=ticker,
        ))

    if isinstance(D, SparseCorpus):
        return _sparse_horizontal_hierarchical(
            D, threshold, k, mesh, axes, block_rows=block_rows, ticker=ticker
        )

    def body(D_loc):
        n_loc = D_loc.shape[0]
        bs = min(block_rows, n_loc)
        flat = _flat_axis_index(axes)  # row-major rank over `axes`
        row_off = flat * n_loc

        def join(buf, own, matches):
            m_new = similarity_topk(
                D_loc, _from_wire(buf, D_loc.dtype), threshold, k,
                block_rows=bs, exclude_self=True, row_offset=row_off,
                col_offset=own[0] * n_loc, use_kernel=use_kernel,
            )
            return merge_matches(matches, m_new)

        matches0 = _pvary(_empty_local_matches(n_loc, k), axes)
        return _nested_ring_sweep(
            mesh, axes, (_to_wire(D_loc), flat[None], matches0), join,
            ticker=ticker, rank=flat,
        )

    return shard_map(
        body,
        mesh=mesh,
        in_specs=P(axes, None),
        out_specs=_matches_specs(axes),
        check_vma=not use_kernel,
    )(D)


def _sparse_horizontal_hierarchical(
    D: SparseCorpus, threshold, k, mesh, axes, *, block_rows, ticker=None,
):
    """Nested pod ring on CSR: the sparse twin of the dense hierarchical.

    The traveling block is the CSR triple (plus its owner id), hopping the
    same nested-ring pattern via the shared :func:`_nested_ring_sweep`
    driver — the wire-volume win of the sparse ring (``O(n_loc · cap)``
    words/hop) composed with the hierarchical schedule's hop economy on
    slow links. Every block pair is scored with the fully-traceable blocked
    gather-dot join; exactness and parity with the flat sparse ring are
    asserted by ``tests/test_sparse.py``.
    """
    m = D.m

    def body(idx, val, nnz):
        n_loc = idx.shape[0]
        bs = min(block_rows, n_loc)
        loc = SparseCorpus(idx, val, nnz, m)
        flat = _flat_axis_index(axes)  # row-major rank over `axes`
        row_off = flat * n_loc

        def join(buf, own, matches):
            m_new = sparse_similarity_topk(
                loc, SparseCorpus(*buf, m), threshold, k,
                block_rows=bs, exclude_self=True, row_offset=row_off,
                col_offset=own[0] * n_loc, vary_axes=axes,
            )
            return merge_matches(matches, m_new)

        matches0 = _pvary(_empty_local_matches(n_loc, k), axes)
        return _nested_ring_sweep(
            mesh, axes, ((idx, val, nnz), flat[None], matches0), join,
            ticker=ticker, rank=flat,
        )

    # Same VMA caveat as every sparse schedule: the scatter/gather ops in
    # the sparse tile primitive have no checker rule; verified numerically.
    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axes, None), P(axes, None), P(axes)),
        out_specs=_matches_specs(axes),
        check_vma=False,
    )(D.indices, D.values, D.nnz)


# ---------------------------------------------------------------------------
# Dispatcher
# ---------------------------------------------------------------------------


def apss(
    D: jax.Array,
    threshold: float,
    k: int,
    mesh: Mesh,
    *,
    distribution: str = "2d",
    **kwargs,
) -> Matches | tuple[Matches, ApssStats]:
    """Top-level entry: pick a data distribution (the paper's core finding is
    that the best one is dataset-dependent, so all are first-class).

    ``distribution="auto"`` hands the choice to the execution planner
    (``planner.plan_apss``): corpus statistics are sampled, every valid
    ``(variant, block_rows, use_kernel)`` configuration is priced by the
    calibrated cost models, and the cheapest one runs. Extra ``kwargs``
    (``profile=``, ``autotune=``, ``block_rows_choices=`` …) are forwarded
    to the planner.
    """
    # Span wrap covers dispatch (trace time under jit, dispatch+execute in
    # eager callers); per-ring-step child spans arrive via the StepTicker
    # on the ApssStats record each entry point emits inside this span.
    from repro.obs import trace

    with trace.span("apss", distribution=distribution):
        if distribution == "auto":
            from repro.planner.plan import plan_apss

            return plan_apss(D, threshold, k, mesh, **kwargs).run()
        if distribution == "horizontal":
            return apss_horizontal(D, threshold, k, mesh, **kwargs)
        if distribution == "vertical":
            return apss_vertical(D, threshold, k, mesh, **kwargs)
        if distribution == "2d":
            return apss_2d(D, threshold, k, mesh, **kwargs)
        if distribution == "hierarchical":
            return apss_horizontal_hierarchical(
                D, threshold, k, mesh, **kwargs
            )
        raise ValueError(f"unknown distribution: {distribution}")
