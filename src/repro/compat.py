"""Version-tolerant shims over drifting JAX public APIs.

The repo targets the newest JAX mesh/shard_map surface (``jax.shard_map``,
``jax.sharding.AxisType``, ``lax.pcast``) but must run on older installs
where those names live elsewhere or don't exist. Every mesh construction
and shard_map entry in src/, tests/ and benchmarks/ routes through this
module so the drift is handled in exactly one place.

Pallas compiler-params drift is handled separately in
``repro.kernels._compat`` (kernel code should not import distributed
helpers).
"""

from __future__ import annotations

import inspect
from typing import Sequence

import jax
from jax import lax


def make_mesh(
    axis_shapes: Sequence[int],
    axis_names: Sequence[str],
    *,
    devices=None,
):
    """``jax.make_mesh`` with explicit Auto axis types when supported.

    Newer JAX requires (or defaults differently) ``axis_types``; older JAX
    (≤0.4.x) has neither ``axis_types`` nor ``jax.sharding.AxisType``. All
    our meshes are Auto-typed, so on old versions the plain call is
    equivalent.
    """
    shapes = tuple(axis_shapes)
    names = tuple(axis_names)
    mk = getattr(jax, "make_mesh", None)
    if mk is None:  # pre-0.4.35: build the Mesh directly
        import numpy as np

        if devices is None:
            from jax.experimental import mesh_utils

            devices = mesh_utils.create_device_mesh(shapes)
        return jax.sharding.Mesh(np.asarray(devices).reshape(shapes), names)
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None and (
        "axis_types" in inspect.signature(mk).parameters
    ):
        kwargs["axis_types"] = (axis_type.Auto,) * len(names)
    return mk(shapes, names, **kwargs)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` on new JAX, ``jax.experimental.shard_map`` on old.

    The replication-checker kwarg renamed ``check_rep`` → ``check_vma``;
    callers use the new name and we translate.
    """
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    params = inspect.signature(sm).parameters
    check_kwarg = "check_vma" if "check_vma" in params else "check_rep"
    return sm(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        **{check_kwarg: check_vma},
    )


def pvary(x, axis_names):
    """Mark ``x`` device-varying over ``axis_names`` (VMA loop-carry typing).

    ``lax.pcast`` only exists on JAX versions that track varying-manual-axes
    in the type system; where it doesn't, the annotation is meaningless and
    the identity is correct.
    """
    names = axis_names if isinstance(axis_names, tuple) else (axis_names,)
    pcast = getattr(lax, "pcast", None)
    if pcast is None:
        return x
    return pcast(x, names, to="varying")
