"""AdamW (+ schedules, global-norm clipping, grad accumulation) as pure
functions over param pytrees.

Optimizer moments are f32 regardless of param dtype (bf16-safe). With
``cfg.fsdp`` the moments inherit the FSDP'd param specs, i.e. ZeRO-style
optimizer-state sharding falls out of the same PartitionSpec tree.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def adamw_init(params) -> AdamWState:
    # m and v must be DISTINCT buffers (donation would otherwise see the
    # same buffer twice).
    def zeros():
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros(), v=zeros())


def clip_by_global_norm(grads, max_norm: float):
    norm = jnp.sqrt(
        sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)
        )
    )
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    clipped = jax.tree.map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads
    )
    return clipped, norm


def adamw_update(
    grads,
    state: AdamWState,
    params,
    *,
    lr: jax.Array | float,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: float | None = 1.0,
):
    """One AdamW step; returns (new_params, new_state, metrics)."""
    metrics = {}
    if clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        metrics["grad_norm"] = gnorm
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * gf
        v_new = b2 * v + (1 - b2) * gf * gf
        mh = m_new / bc1
        vh = v_new / bc2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics["lr"] = jnp.asarray(lr, jnp.float32)
    return new_params, AdamWState(step=step, m=new_m, v=new_v), metrics


def linear_warmup(step, base_lr: float, warmup_steps: int):
    s = jnp.asarray(step, jnp.float32)
    return base_lr * jnp.minimum(1.0, (s + 1) / max(warmup_steps, 1))


def cosine_schedule(
    step, base_lr: float, warmup_steps: int, total_steps: int,
    final_frac: float = 0.1,
):
    s = jnp.asarray(step, jnp.float32)
    warm = linear_warmup(step, base_lr, warmup_steps)
    prog = jnp.clip(
        (s - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0
    )
    cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(s < warmup_steps, warm, base_lr * cos)
