from repro.optim.optimizer import (  # noqa: F401
    AdamWState,
    adamw_init,
    adamw_update,
    cosine_schedule,
    linear_warmup,
    clip_by_global_norm,
)
from repro.optim.compression import (  # noqa: F401
    CompressionState,
    compression_init,
    compressed_psum_mean,
    compress_tree,
)
