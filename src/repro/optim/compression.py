"""Top-k gradient compression with error feedback.

The paper's local-pruning insight — threshold partial scores locally, then
communicate only the survivors — applied to data-parallel gradient
synchronization: each device keeps its top-k gradient coordinates (by
magnitude, after adding the error-feedback residual), all-gathers the
compacted ``(index, value)`` pairs (volume ``2·k·p`` instead of the dense
``n``), and scatter-adds them into the synchronized gradient. The dropped
mass is carried to the next step (error feedback), which preserves
convergence (Stich et al., arXiv:1809.07599; Lin et al. DGC,
arXiv:1712.01887).

Used by the ``grad_compression`` train-step variant through ``shard_map``
over the data axis; collective volume shows up directly in the roofline's
collective term (EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax


class CompressionState(NamedTuple):
    error: Any  # pytree like grads (f32): un-transmitted residual per device


def compression_init(grads_like) -> CompressionState:
    return CompressionState(
        error=jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads_like
        )
    )


def _topk_sparsify(flat: jax.Array, k: int):
    """Keep the k largest-|.| entries of a flat vector; return (vals, idx)."""
    mag = jnp.abs(flat)
    vals, idx = lax.top_k(mag, k)
    return flat[idx], idx.astype(jnp.int32)


def compressed_psum_mean(
    g: jax.Array,
    error: jax.Array,
    axis_name: str,
    *,
    ratio: float = 0.01,
    min_size: int = 4096,
):
    """Mean-reduce one gradient leaf over `axis_name` with top-k compression.

    Must run inside ``shard_map``. Leaves smaller than ``min_size`` use a
    dense psum (compression bookkeeping would cost more than it saves).
    Returns ``(g_synced_mean, new_error)``.
    """
    p = lax.psum(1, axis_name)
    n = g.size
    if n < min_size:
        return lax.pmean(g.astype(jnp.float32), axis_name), jnp.zeros_like(error)

    k = max(1, int(n * ratio))
    acc = g.astype(jnp.float32).reshape(-1) + error.reshape(-1)
    vals, idx = _topk_sparsify(acc, k)
    # Residual: what this device did NOT transmit (error feedback).
    transmitted = jnp.zeros((n,), jnp.float32).at[idx].set(vals)
    new_error = (acc - transmitted).reshape(error.shape)
    # Exchange compacted coordinates: 2·k·p words vs n dense.
    all_vals = lax.all_gather(vals, axis_name, axis=0, tiled=True)   # (p*k,)
    all_idx = lax.all_gather(idx, axis_name, axis=0, tiled=True)    # (p*k,)
    dense = jnp.zeros((n,), jnp.float32).at[all_idx].add(all_vals)
    return (dense / p).reshape(g.shape), new_error


def compress_tree(
    grads,
    state: CompressionState,
    axis_name: str,
    *,
    ratio: float = 0.01,
    min_size: int = 4096,
):
    """Apply :func:`compressed_psum_mean` leaf-wise; returns (synced, state)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(state.error)
    synced, errs = [], []
    for g, e in zip(flat_g, flat_e):
        s, ne = compressed_psum_mean(
            g, e, axis_name, ratio=ratio, min_size=min_size
        )
        synced.append(s.astype(g.dtype))
        errs.append(ne)
    return treedef.unflatten(synced), CompressionState(
        error=treedef.unflatten(errs)
    )


def compression_comm_bytes(
    grads, *, ratio: float = 0.01, min_size: int = 4096, p: int = 2
) -> dict:
    """Napkin accounting: dense vs compressed collective volume (bytes)."""
    dense = 0
    compressed = 0
    for g in jax.tree.leaves(grads):
        n = g.size
        if n < min_size:
            dense += 4 * n
            compressed += 4 * n
        else:
            k = max(1, int(n * ratio))
            dense += 4 * n
            compressed += 8 * k * p  # idx + val, gathered from p ranks
    return {"dense_bytes": dense, "compressed_bytes": compressed,
            "ratio": compressed / max(dense, 1)}
