"""Live-corpus demo: a MutableAPSSIndex under continuous mutation.

Walks the whole ISSUE-7 surface on a synthetic corpus — build, streamed
appends (delta join), deletes (tombstones + exact graph repair),
compaction, queries through a version-cache-invalidating
:class:`~repro.serving.server.RetrievalServer`, and a WAL kill/replay
round-trip — printing per-op latency and the telemetry counters. The
closing check rebuilds the final corpus from scratch and asserts the
standing graph is bit-identical (the metamorphic invariant, live).

CPU-scale demo:
    PYTHONPATH=src python -m repro.launch.live --n 2048 --m 512 --deltas 32
"""

from __future__ import annotations

import argparse
import contextlib
import os
import tempfile
import time

import numpy as np

from repro.obs import MetricsRegistry, Tracer, export
from repro.planner import telemetry
from repro.serving import MutableAPSSIndex, RetrievalServer


def _tick(label: str, fn):
    t0 = time.perf_counter()
    out = fn()
    print(f"  {label:<38} {1e3 * (time.perf_counter() - t0):8.1f} ms")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--m", type=int, default=512)
    ap.add_argument("--deltas", type=int, default=32,
                    help="rows per append batch")
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--threshold", type=float, default=0.2)
    ap.add_argument("--k", type=int, default=16)
    ap.add_argument("--block", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome/Perfetto trace-event JSON of the"
                         " mutation/serve loop to PATH")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write a metrics snapshot to PATH (.prom/.txt ->"
                         " Prometheus text, otherwise JSON)")
    args = ap.parse_args()

    tracer = Tracer() if args.trace_out else None
    registry = MetricsRegistry() if args.metrics_out else None
    with contextlib.ExitStack() as stack:
        if registry is not None:
            stack.enter_context(registry)
        if tracer is not None:
            stack.enter_context(tracer)
        _run(args)
    if tracer is not None:
        export.write_chrome_trace(args.trace_out, tracer, registry)
        print(f"[obs] trace -> {args.trace_out}")
    if registry is not None:
        export.write_metrics(args.metrics_out, registry)
        print(f"[obs] metrics -> {args.metrics_out}")


def _run(args) -> None:
    rng = np.random.default_rng(args.seed)
    D = rng.normal(size=(args.n, args.m)).astype(np.float32)
    kept: list[tuple[int, np.ndarray]] = []

    with tempfile.TemporaryDirectory() as td, telemetry.CommLog() as log:
        wal = os.path.join(td, "live")
        print(f"live corpus: n={args.n} m={args.m} t={args.threshold} "
              f"k={args.k} (WAL at {wal})")
        idx = _tick(
            f"build ({args.n} rows)",
            lambda: MutableAPSSIndex(
                D, threshold=args.threshold, k=args.k,
                block_rows=args.block, directory=wal,
            ),
        )
        kept += [(g, D[g]) for g in range(args.n)]
        srv = RetrievalServer(idx, threshold=args.threshold, k=args.k,
                              max_batch=8)
        Q = rng.normal(size=(8, args.m)).astype(np.float32)

        for r in range(args.rounds):
            new = rng.normal(size=(args.deltas, args.m)).astype(np.float32)
            gids = _tick(
                f"round {r}: append {args.deltas} (delta join)",
                lambda: idx.append(new),
            )
            kept += list(zip(gids, new))
            live = [g for g, _ in kept]
            victims = sorted(
                int(g)
                for g in rng.choice(live, size=args.deltas // 2,
                                    replace=False)
            )
            _tick(
                f"round {r}: delete {len(victims)} (graph repair)",
                lambda: idx.delete(victims),
            )
            kept = [(g, row) for g, row in kept if g not in set(victims)]
            res = _tick(
                f"round {r}: serve 8 queries (cache ver {idx.version})",
                lambda: srv.serve(list(Q)),
            )
            assert all(x.status == "ok" for x in res)

        _tick("compact (tombstone rewrite)", idx.compact)
        before = idx.graph()

        # durability round-trip: reopen from WAL + snapshots
        reopened = _tick(
            "reopen from WAL (restore + replay)",
            lambda: MutableAPSSIndex(
                corpus=None, threshold=args.threshold, k=args.k,
                block_rows=args.block, directory=wal,
            ),
        )
        after = reopened.graph()
        assert np.array_equal(before[0], after[0])
        assert np.array_equal(before[1].values, after[1].values)
        assert np.array_equal(before[1].indices, after[1].indices)

        # the metamorphic invariant, live: fresh rebuild == mutated index
        surv = np.asarray([g for g, _ in kept], np.int64)
        fresh = _tick(
            f"oracle rebuild ({len(kept)} surviving rows)",
            lambda: MutableAPSSIndex(
                np.stack([row for _, row in kept]),
                threshold=args.threshold, k=args.k, block_rows=args.block,
            ),
        )
        _, og = fresh.graph()
        ti = np.where(og.indices >= 0, surv[np.maximum(og.indices, 0)], -1)
        assert np.array_equal(before[1].values, og.values)
        assert np.array_equal(before[1].indices, ti)
        print(f"graph bit-identical to fresh rebuild over {idx.n} live rows "
              f"(version {idx.version})")
        counters = {k: v for k, v in sorted(log.counters.items())}
        print(f"counters: {counters}")
        joins = log.by_variant("serving/delta-join")
        if joins:
            lf = [j.live_fraction for j in joins if j.live_fraction]
            print(f"delta joins: {len(joins)} recorded, "
                  f"mean live-tile fraction "
                  f"{np.mean(lf):.2f}" if lf else "delta joins: recorded")


if __name__ == "__main__":
    main()
