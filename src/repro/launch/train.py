"""Training step factories + the end-to-end training driver.

``make_*_train_step`` return pure ``(params, opt_state, batch) → (params,
opt_state, metrics)`` functions; the driver composes them with the data
pipeline, checkpoint manager (async, keep-k, auto-resume), straggler timer
and (optionally) APSS-dedup of the input stream.

CLI (CPU-scale, reduced configs):
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --steps 20 --batch 8 --seq 128 --smoke
"""

from __future__ import annotations

import argparse
import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.distributed import StepTimer, use_mesh
from repro.models import gnn, recsys
from repro.models.transformer import (
    TransformerConfig,
    init_transformer,
    transformer_loss,
)
from repro.optim import adamw_init, adamw_update, cosine_schedule


@dataclasses.dataclass(frozen=True)
class TrainHyperparams:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    b1: float = 0.9
    b2: float = 0.95


def make_train_step(
    loss_fn: Callable[[Any, Any], tuple],
    hp: TrainHyperparams = TrainHyperparams(),
    *,
    accum_steps: int = 1,
) -> Callable:
    """Generic train step from a ``loss_fn(params, batch) -> (loss, aux)``.

    With ``accum_steps > 1`` the global batch is split into microbatches
    scanned sequentially with f32 gradient accumulation: live activation
    memory divides by N at the cost of re-reading weights N× (the classic
    memory↔bandwidth trade, measured in EXPERIMENTS.md §Perf).
    """

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            (loss, aux), grads = grads_of(params, batch)
        else:
            # Microbatches via dynamic_slice on the batch dim (NOT reshape:
            # reshaping a data-sharded leading dim trips SPMD partitioning).
            def micro_slice(i):
                return jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * (x.shape[0] // accum_steps),
                        x.shape[0] // accum_steps, axis=0,
                    ),
                    batch,
                )

            def body(carry, i):
                g_acc, loss_acc = carry
                (loss, aux), g = grads_of(params, micro_slice(i))
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g
                )
                return (g_acc, loss_acc + loss), aux

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (g_sum, loss_sum), auxs = jax.lax.scan(
                body, (g0, jnp.float32(0)), jnp.arange(accum_steps)
            )
            grads = jax.tree.map(lambda g: g / accum_steps, g_sum)
            loss = loss_sum / accum_steps
            aux = jax.tree.map(lambda x: x[-1], auxs)
        lr = cosine_schedule(
            opt_state.step, hp.lr, hp.warmup_steps, hp.total_steps
        )
        new_params, new_opt, opt_metrics = adamw_update(
            grads, opt_state, params,
            lr=lr, b1=hp.b1, b2=hp.b2,
            weight_decay=hp.weight_decay, clip_norm=hp.clip_norm,
        )
        metrics = {"loss": loss, **aux, **opt_metrics}
        return new_params, new_opt, metrics

    return train_step


def make_lm_train_step(
    cfg: TransformerConfig, hp: TrainHyperparams = TrainHyperparams()
):
    return make_train_step(
        lambda p, b: transformer_loss(p, cfg, b), hp,
        accum_steps=getattr(cfg, "grad_accum", 1),
    )


def make_gat_train_step(cfg: gnn.GATConfig, hp: TrainHyperparams = TrainHyperparams()):
    return make_train_step(lambda p, b: gnn.gat_loss(p, cfg, b), hp)


def make_recsys_train_step(cfg, hp: TrainHyperparams = TrainHyperparams()):
    for kind, fn in (
        (recsys.TwoTowerConfig, recsys.two_tower_loss),
        (recsys.Bert4RecConfig, recsys.bert4rec_loss),
        (recsys.DINConfig, recsys.din_loss),
        (recsys.BSTConfig, recsys.bst_loss),
    ):
        if isinstance(cfg, kind):
            return make_train_step(lambda p, b: fn(p, cfg, b), hp)
    raise TypeError(type(cfg))


# ---------------------------------------------------------------------------
# end-to-end driver
# ---------------------------------------------------------------------------


def train_loop(
    *,
    arch: str,
    steps: int,
    ckpt_dir: str | None = None,
    ckpt_every: int = 50,
    mesh=None,
    smoke_overrides: dict | None = None,
    log_every: int = 10,
) -> dict:
    """Run a real training loop on the current devices (reduced configs).

    Returns the final metrics. This is the runnable end-to-end example used
    by ``examples/train_lm.py`` — data pipeline → jit'd step → async
    checkpoints → auto-resume → straggler ledger.
    """
    from repro.configs.base import get_arch
    from repro.data import LMDataPipeline, RecsysPipeline, GraphPipeline

    arch_def = get_arch(arch)
    cfg = arch_def.make_smoke_config()
    if smoke_overrides:
        cfg = dataclasses.replace(cfg, **smoke_overrides)

    key = jax.random.key(0)
    hp = TrainHyperparams(warmup_steps=max(2, steps // 10), total_steps=steps)

    if arch_def.family == "lm":
        params = init_transformer(key, cfg)
        step_fn = make_lm_train_step(cfg, hp)
        pipe = LMDataPipeline(
            vocab_size=cfg.vocab_size, batch_size=4,
            seq_len=min(128, 4 * cfg.loss_chunk), seed=0,
        )
        def get_batch(s):
            return jax.tree.map(jnp.asarray, pipe.get_batch(s))
    elif arch_def.family == "gnn":
        params = gnn.init_gat(key, cfg)
        step_fn = make_gat_train_step(cfg, hp)
        pipe = GraphPipeline(n_nodes=512, n_edges=4096, d_feat=cfg.d_feat,
                             n_classes=cfg.n_classes)
        g = jax.tree.map(jnp.asarray, pipe.full_graph())
        def get_batch(s):
            return g
    else:
        if isinstance(cfg, recsys.TwoTowerConfig):
            params = recsys.init_two_tower(key, cfg)
            pipe = RecsysPipeline(n_items=cfg.n_items, batch_size=32,
                                  history_len=cfg.history_len,
                                  n_user_fields=cfg.n_user_fields,
                                  user_vocab=cfg.user_vocab, kind="two-tower")
        elif isinstance(cfg, recsys.Bert4RecConfig):
            params = recsys.init_bert4rec(key, cfg)
            pipe = RecsysPipeline(n_items=cfg.n_items, batch_size=32,
                                  history_len=cfg.seq_len, kind="seq")
        elif isinstance(cfg, recsys.DINConfig):
            params = recsys.init_din(key, cfg)
            pipe = RecsysPipeline(n_items=cfg.n_items, batch_size=32,
                                  history_len=cfg.seq_len, kind="ctr")
        else:
            params = recsys.init_bst(key, cfg)
            pipe = RecsysPipeline(n_items=cfg.n_items, batch_size=32,
                                  history_len=cfg.seq_len - 1, kind="ctr")
        step_fn = make_recsys_train_step(cfg, hp)

        def get_batch(s):
            return jax.tree.map(jnp.asarray, pipe.get_batch(s))

    opt_state = adamw_init(params)
    start_step = 0
    mgr = None
    if ckpt_dir:
        mgr = CheckpointManager(ckpt_dir, keep=3)
        like = {"params": params, "opt": opt_state}
        restored, at = mgr.restore(like=like)
        if restored is not None:
            params = jax.tree.map(jnp.asarray, restored["params"])
            opt_state = jax.tree.map(jnp.asarray, restored["opt"])
            start_step = at
            print(f"[train] resumed from step {at}")

    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
    timer = StepTimer()
    metrics = {}
    with use_mesh(mesh):
        for s in range(start_step, steps):
            batch = get_batch(s)
            timer.start()
            params, opt_state, metrics = jit_step(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            timer.stop(0)
            if s % log_every == 0 or s == steps - 1:
                print(
                    f"[train] step {s} loss={float(metrics['loss']):.4f} "
                    f"gnorm={float(metrics.get('grad_norm', 0)):.2f} "
                    f"({timer.rank_ema.get(0, 0)*1e3:.0f} ms/step)"
                )
            if mgr and (s + 1) % ckpt_every == 0:
                mgr.save({"params": params, "opt": opt_state}, s + 1, blocking=False)
    if mgr:
        mgr.save({"params": params, "opt": opt_state}, steps, blocking=True)
    return {k: float(v) for k, v in metrics.items() if jnp.ndim(v) == 0}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    out = train_loop(arch=args.arch, steps=args.steps, ckpt_dir=args.ckpt_dir)
    print("[train] final:", out)


if __name__ == "__main__":
    main()
