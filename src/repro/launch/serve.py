"""Serving entry point: batched request loops with per-request latching.

Three modes:

- ``--mode lm`` (default): continuous-batch LM decode over the transformer
  stack (:class:`LMServer`).
- ``--mode retrieval``: the APSS serving path — build a
  :class:`~repro.serving.index.APSSIndex` ONCE over a synthetic sparse
  corpus, then stream query batches through a
  :class:`~repro.serving.server.RetrievalServer` (one jit'd ``query_topk``
  per step boundary, LRU cache, per-query latency/QPS report).
- ``--mode auto``: the execution planner end-to-end — calibrate the
  hardware profile (one-shot, cached), plan the APSS self-join over the
  synthetic corpus (``planner.plan_apss``: every variant priced by the
  cost models), print the chosen Plan + the ranked predictions, then run
  it and report predicted vs measured.

CPU-scale demos (reduced configs):
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --requests 4
    PYTHONPATH=src python -m repro.launch.serve --mode retrieval \\
        --corpus-n 4096 --corpus-m 2048 --requests 64 --batch 8
    PYTHONPATH=src python -m repro.launch.serve --mode auto \\
        --corpus-n 2048 --corpus-m 8192 --threshold 0.5
"""

from __future__ import annotations

import argparse
import contextlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import (
    decode_step,
    init_transformer,
    make_cache,
)


class LMServer:
    """Minimal batched LM server: continuous batch of decode slots.

    Requests join the running batch at the next step boundary; finished
    slots are recycled. Decode is one jit'd step for the whole batch —
    the production pattern behind the decode_32k / long_500k shapes.
    """

    def __init__(self, cfg, *, max_batch: int = 8, max_len: int = 256, seed: int = 0):
        self.cfg = cfg
        self.params = init_transformer(jax.random.key(seed), cfg)
        self.max_batch = max_batch
        self.max_len = max_len
        self.cache = make_cache(cfg, max_batch, max_len)
        self.active = np.zeros(max_batch, bool)
        self.outputs: list = [[] for _ in range(max_batch)]
        self._decode = jax.jit(
            lambda p, c, t: decode_step(p, cfg, c, t)
        )

    def add_request(self, prompt_tokens: np.ndarray) -> int:
        slot = int(np.argmin(self.active))
        assert not self.active[slot], "server full"
        self.active[slot] = True
        self.outputs[slot] = []
        # feed the prompt through decode steps (simple; a production server
        # would run a batched prefill into the cache region)
        for tok in prompt_tokens:
            self.step_token(slot, int(tok))
        return slot

    def step_token(self, slot: int, token: int) -> int:
        tokens = np.zeros(self.max_batch, np.int32)
        tokens[slot] = token
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(tokens)
        )
        nxt = int(jnp.argmax(logits[slot]))
        self.outputs[slot].append(nxt)
        return nxt

    def generate(self, slot: int, n: int) -> list:
        tok = self.outputs[slot][-1]
        for _ in range(n):
            tok = self.step_token(slot, tok)
        return self.outputs[slot][-n:]


def run_retrieval(args) -> None:
    """Retrieval mode: index once, serve query batches, report QPS."""
    from repro.data.sparse import perturbed_queries, sparse_clustered_corpus
    from repro.serving import (
        ContinuousRetrievalServer,
        RetrievalServer,
        build_index,
    )

    t0 = time.time()
    sp = sparse_clustered_corpus(
        args.corpus_n, args.corpus_m, args.avg_nnz, n_clusters=16, seed=0
    )
    t_gen = time.time() - t0

    t0 = time.time()
    index = build_index(sp, block_rows=args.block, normalize=False)
    t_build = time.time() - t0

    # Perturbed corpus rows: realistic near-duplicate, topical traffic.
    qs = list(perturbed_queries(sp, args.requests, seed=1))

    deadline_s = args.deadline_ms / 1e3 if args.deadline_ms else None

    def make_server(chaos: bool = False):
        # Chaos lane: a fresh seeded FaultPlan per server (plans are
        # consumable) — injected step delays + transient scoring-tier
        # errors exercise the shed/degrade/retry machinery under the same
        # traffic the clean lane measures.
        plan = None
        if chaos:
            from repro.robust import FaultPlan

            steps = max(1, args.requests // args.batch)
            # Delays hit the serving step loop; transient errors hit the
            # XLA scoring tier (always present — the kernel tier is
            # TPU-only) so the retry/degrade machinery actually exercises.
            plan = FaultPlan.chaos(args.chaos_seed, steps=steps,
                                   kernel_errors=2, scope="serving",
                                   error_scope="serving.xla")
        kwargs = dict(
            threshold=args.threshold, k=args.k, max_batch=args.batch,
            deadline_s=deadline_s, fault_plan=plan,
            max_retries=2, backoff_s=0.001,
        )
        if args.server == "continuous":
            return ContinuousRetrievalServer(
                index, workers=args.workers, **kwargs
            )
        return RetrievalServer(index, **kwargs)

    # Warm up compile caches on a THROWAWAY server (the jitted scoring
    # paths are module-level, so compilation carries over), then time a
    # fresh one — otherwise the warmup batch sits in the LRU cache and
    # inflates the measured QPS.
    with contextlib.closing(make_server()) as warm:
        warm.serve(qs[: args.batch])
    srv = make_server(chaos=args.chaos)
    with contextlib.closing(srv):
        t0 = time.time()
        results = srv.serve(qs)
        dt = time.time() - t0
    n_match = sum(r.count for r in results)
    served = [r for r in results if r.status == "ok"]
    print(
        f"[serve] corpus n={sp.n} m={sp.m} (gen {t_gen:.1f}s) "
        f"index build {t_build:.2f}s"
        + (f" chaos seed={args.chaos_seed}" if args.chaos else "")
    )
    print(
        f"[serve] {args.server} server: {len(results)} queries in {dt:.3f}s "
        f"({len(results)/dt:.1f} QPS, batch {args.batch}, "
        f"{1e3*dt/len(results):.2f} ms/query), {n_match} matches, "
        f"{len(served)} exact, stats={srv.stats}"
    )


def run_auto(args) -> None:
    """Auto mode: calibrate → plan (print it) → run the chosen variant."""
    import numpy as np

    from repro.compat import make_mesh
    from repro.data.sparse import sparse_clustered_corpus
    from repro.planner.calibrate import calibrate, profile_path
    from repro.planner.plan import plan_apss

    t0 = time.time()
    sp = sparse_clustered_corpus(
        args.corpus_n, args.corpus_m, args.avg_nnz, n_clusters=16, seed=0
    )
    print(f"[auto] corpus n={sp.n} m={sp.m} cap={sp.cap} "
          f"(gen {time.time() - t0:.1f}s)")

    t0 = time.time()
    profile = calibrate(save=True)
    print(
        f"[auto] calibrated {profile.device_kind} in {time.time() - t0:.1f}s "
        f"(matmul {profile.matmul_gflops:.1f} GF/s, gather "
        f"{profile.gather_gflops:.2f} GF/s, wire "
        f"{profile.collective_gbps:.2f} GB/s) -> {profile_path()}"
    )

    mesh = (
        make_mesh((jax.device_count(),), ("data",))
        if jax.device_count() > 1
        else None
    )
    t0 = time.time()
    plan = plan_apss(
        sp, args.threshold, args.k, mesh, profile=profile,
        autotune=args.autotune,
    )
    print(f"[auto] planned in {time.time() - t0:.2f}s")
    print(plan.describe())

    t0 = time.time()
    res = jax.block_until_ready(plan.run())
    cold = time.time() - t0
    t0 = time.time()
    res = jax.block_until_ready(plan.run())
    warm = time.time() - t0
    n_match = int(np.asarray(res.counts).sum())
    print(
        f"[auto] ran {plan.config.name}: {warm * 1e3:.1f}ms warm "
        f"({cold * 1e3:.0f}ms cold), {n_match} matches; predicted "
        f"{plan.cost.total_s * 1e3:.1f}ms "
        f"({plan.cost.total_s / max(warm, 1e-9):.2f}x of measured)"
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["lm", "retrieval", "auto"], default="lm")
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=2)
    ap.add_argument("--gen-tokens", type=int, default=8)
    ap.add_argument("--corpus-n", type=int, default=4096)
    ap.add_argument("--corpus-m", type=int, default=2048)
    ap.add_argument("--avg-nnz", type=float, default=16.0)
    ap.add_argument("--block", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--server", choices=["step", "continuous"],
                    default="continuous",
                    help="retrieval mode: step-boundary batching or"
                         " slot-granularity continuous batching")
    ap.add_argument("--workers", type=int, default=2,
                    help="continuous server: concurrent scoring workers")
    ap.add_argument("--threshold", type=float, default=0.5)
    ap.add_argument("--k", type=int, default=16)
    ap.add_argument("--autotune", action="store_true",
                    help="auto mode: microbenchmark the top-3 plans")
    ap.add_argument("--chaos", action="store_true",
                    help="retrieval mode: inject seeded faults (step delays"
                         " + transient scoring errors) and report the"
                         " shed/degraded/retries counters")
    ap.add_argument("--chaos-seed", type=int, default=0)
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="retrieval mode: per-request deadline; late"
                         " requests are shed, not served")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome/Perfetto trace-event JSON of the"
                         " run (plan/execute/serving spans) to PATH")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write a metrics snapshot to PATH (.prom/.txt ->"
                         " Prometheus text, otherwise JSON)")
    args = ap.parse_args()

    from repro.obs import MetricsRegistry, Tracer, export

    tracer = Tracer() if args.trace_out else None
    registry = MetricsRegistry() if args.metrics_out else None
    with contextlib.ExitStack() as stack:
        # Registry first so finalize() (tracer exit) can observe sweep
        # step-time/skew histograms into it.
        if registry is not None:
            stack.enter_context(registry)
        if tracer is not None:
            stack.enter_context(tracer)
        _run_mode(args)
    if tracer is not None:
        export.write_chrome_trace(args.trace_out, tracer, registry)
        print(f"[obs] trace -> {args.trace_out}")
    if registry is not None:
        export.write_metrics(args.metrics_out, registry)
        print(f"[obs] metrics -> {args.metrics_out}")


def _run_mode(args) -> None:
    if args.mode == "retrieval":
        run_retrieval(args)
        return
    if args.mode == "auto":
        run_auto(args)
        return

    from repro.configs import get_arch

    arch = get_arch(args.arch)
    if arch.family != "lm":
        raise SystemExit("serve demo supports LM archs; use examples/ for recsys")
    cfg = arch.make_smoke_config()
    srv = LMServer(cfg, max_batch=max(2, args.requests))
    rng = np.random.default_rng(0)
    t0 = time.time()
    for r in range(args.requests):
        slot = srv.add_request(rng.integers(0, cfg.vocab_size, size=4))
        out = srv.generate(slot, args.gen_tokens)
        print(f"[serve] request {r} slot {slot} → {out}")
    dt = time.time() - t0
    total = args.requests * (args.gen_tokens + 4)
    print(f"[serve] {total} tokens in {dt:.2f}s ({total/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
