import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) on the production
mesh and extract the roofline terms from the compiled artifact.

MUST be executed as its own process (``python -m repro.launch.dryrun``): the
XLA_FLAGS line above runs before any other import so jax initializes with
512 placeholder host devices. Smoke tests / benches never import this module.

Per cell it records (JSON under --out):
  - compile wall time, per-device memory_analysis (args/outputs/temps)
  - per-device HLO FLOPs + bytes accessed (cost_analysis)
  - per-collective link-byte accounting parsed from the post-SPMD HLO
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute, ring algorithm factors, replica-group aware)
  - the three roofline terms (v5e: 197 TF/s bf16, 819 GB/s HBM,
    50 GB/s/link ICI) and the dominant term.

Usage:
  python -m repro.launch.dryrun --list
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all [--multi-pod] [--subprocess]
"""

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import subprocess  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

# --- hardware constants (TPU v5e) ---
PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link (~per-chip per-direction)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(tok: str) -> int:
    m = _SHAPE_RE.match(tok)
    if not m:
        return 0
    dt, dims = m.groups()
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))  # [n_groups, group_size]<=[...]
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return 1


# `%name = RESULT_TYPE op-name(...)` — operands are printed as %refs without
# types in optimized HLO, so byte accounting uses the RESULT type(s).
_OP_LINE_RE = re.compile(
    r"=\s*(?P<result>\([^)]*\)|\S+)\s+"
    r"(?P<kind>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<variant>-start|-done)?\("
)


def collective_stats(hlo_text: str) -> dict:
    """Per-kind (count, link_bytes, payload_bytes) from post-SPMD HLO.

    Ring-algorithm link factors (per participating chip):
      all-gather      (g-1)/g · S_result
      all-reduce      2(g-1)/g · S_result
      reduce-scatter  (g-1)/g · (S_result · g)   (= input size)
      all-to-all      (g-1)/g · S_result
      permute         1 · S_result
    ``-done`` lines are skipped (their ``-start`` was already counted; for
    async starts the output buffer is the last tuple element).
    """
    stats = {
        k: {"count": 0, "link_bytes": 0.0, "payload_bytes": 0.0}
        for k in _COLLECTIVES
    }
    for line in hlo_text.splitlines():
        m = _OP_LINE_RE.search(line)
        if not m:
            continue
        kind, variant = m.group("kind"), m.group("variant")
        if variant == "-done":
            continue
        shapes = [
            _shape_bytes(t)
            for t in re.findall(r"\w+\[[\d,]*\]", m.group("result"))
        ]
        shapes = [s for s in shapes if s > 0]
        if not shapes:
            continue
        if variant == "-start" and len(shapes) > 1:
            # async start result = (input buf(s), output buf(s), ...)
            payload = shapes[len(shapes) // 2] if kind != "all-reduce" else shapes[-1]
        else:
            payload = sum(shapes)
        g = max(_group_size(line), 1)
        if kind == "all-gather":
            link = payload * (g - 1) / g
        elif kind == "all-reduce":
            link = 2 * payload * (g - 1) / g
        elif kind == "reduce-scatter":
            link = payload * (g - 1)  # = (payload·g)·(g-1)/g
        elif kind == "all-to-all":
            link = payload * (g - 1) / g
        else:  # collective-permute
            link = payload
        stats[kind]["count"] += 1
        stats[kind]["link_bytes"] += link
        stats[kind]["payload_bytes"] += payload
    return stats


def roofline_terms(flops: float, hbm_bytes: float, link_bytes: float) -> dict:
    compute = flops / PEAK_FLOPS
    memory = hbm_bytes / HBM_BW
    collective = link_bytes / ICI_BW
    dominant = max(
        ("compute", compute), ("memory", memory), ("collective", collective),
        key=lambda kv: kv[1],
    )[0]
    return {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "dominant": dominant,
    }


def _parse_override(kv: str):
    k, v = kv.split("=", 1)
    for cast in (int, float):
        try:
            return k, cast(v)
        except ValueError:
            pass
    if v in ("True", "true"):
        return k, True
    if v in ("False", "false"):
        return k, False
    return k, v


def apply_overrides(cfg, overrides: list):
    """``key=value`` overrides onto dataclass or dict configs (§Perf)."""
    import dataclasses

    kv = dict(_parse_override(o) for o in overrides)
    if not kv:
        return cfg
    if isinstance(cfg, dict):
        out = dict(cfg)
        out.update(kv)
        return out
    return dataclasses.replace(cfg, **kv)


def run_cell(
    arch_name: str, shape_name: str, *, multi_pod: bool, overrides: list = ()
) -> dict:
    import jax
    from repro.configs import get_arch
    from repro.distributed.sharding import use_mesh
    from repro.launch.mesh import make_production_mesh

    arch = get_arch(arch_name)
    cell = arch.cell(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size

    cfg = apply_overrides(arch.make_config(), list(overrides))
    t0 = time.time()
    build = cell.build(cfg, mesh)
    with use_mesh(mesh):
        jitted = jax.jit(
            build.fn,
            in_shardings=build.in_shardings,
            out_shardings=build.out_shardings,
        )
        lowered = jitted.lower(*build.args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()

    # Loop-aware per-device accounting (XLA cost_analysis counts while
    # bodies once — see hlo_analysis module docstring).
    from repro.launch.hlo_analysis import analyze

    han = analyze(hlo)
    colls = han["collectives"]
    link_bytes = han["link_bytes"]
    flops = han["flops"]
    hbm_bytes = han["hbm_bytes"]
    terms = roofline_terms(flops, hbm_bytes, link_bytes)

    model_flops = build.static_info.get("model_flops", 0)
    result = {
        "arch": arch_name,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "status": "ok",
        "t_lower_s": round(t_lower, 2),
        "t_compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
            "total_bytes": (
                mem.argument_size_in_bytes
                + mem.output_size_in_bytes
                + mem.temp_size_in_bytes
            ),
        },
        "per_device_flops": flops,
        "per_device_hbm_bytes": hbm_bytes,
        "per_device_link_bytes": link_bytes,
        "collectives": colls,
        "xla_cost_analysis": {
            "flops_body_once": float(cost.get("flops", 0.0)),
            "bytes_accessed_body_once": float(cost.get("bytes accessed", 0.0)),
        },
        "hlo_analysis_meta": {
            "n_computations": han["n_computations"],
            "max_loop_multiplier": han["max_multiplier"],
        },
        "roofline": terms,
        "static_info": {
            k: v for k, v in build.static_info.items() if not callable(v)
        },
        "model_flops_total": model_flops,
        "model_flops_per_device": model_flops / chips if chips else 0,
        "useful_flops_ratio": (
            (model_flops / chips) / flops if flops else 0.0
        ),
    }
    return result


def cell_list(arch_names=None) -> list:
    from repro.configs import ASSIGNED, get_arch

    names = arch_names or (ASSIGNED + ["apss"])
    cells = []
    for a in names:
        for s in get_arch(a).shapes:
            cells.append((a, s))
    return cells


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--subprocess", action="store_true",
                    help="run each cell in an isolated subprocess (with --all)")
    ap.add_argument("--timeout", type=int, default=3600)
    ap.add_argument("--override", action="append", default=[],
                    help="config overrides key=value (perf variants)")
    ap.add_argument("--tag", default="",
                    help="suffix for the output json (perf variants)")
    args = ap.parse_args()

    if args.list:
        for a, s in cell_list():
            print(f"{a:24s} {s}")
        return

    os.makedirs(args.out, exist_ok=True)

    def out_path(a, s, mp):
        mesh = "2x16x16" if mp else "16x16"
        tag = f"__{args.tag}" if args.tag else ""
        return os.path.join(args.out, f"{a}__{s}__{mesh}{tag}.json")

    if args.all:
        failures = []
        for a, s in cell_list():
            path = out_path(a, s, args.multi_pod)
            if os.path.exists(path):
                print(f"[dryrun] skip (cached): {a} × {s}")
                continue
            if args.subprocess:
                cmd = [
                    sys.executable, "-m", "repro.launch.dryrun",
                    "--arch", a, "--shape", s, "--out", args.out,
                ] + (["--multi-pod"] if args.multi_pod else [])
                print(f"[dryrun] {' '.join(cmd[3:])}")
                r = subprocess.run(cmd, timeout=args.timeout)
                if r.returncode != 0:
                    failures.append((a, s))
            else:
                try:
                    res = run_cell(a, s, multi_pod=args.multi_pod)
                    with open(path, "w") as f:
                        json.dump(res, f, indent=1)
                    print(_summary(res))
                except Exception:
                    traceback.print_exc()
                    failures.append((a, s))
        if failures:
            print("FAILURES:", failures)
            sys.exit(1)
        print("[dryrun] all cells compiled OK")
        return

    assert args.arch and args.shape, "--arch/--shape or --all required"
    res = run_cell(
        args.arch, args.shape, multi_pod=args.multi_pod,
        overrides=args.override,
    )
    if args.tag:
        res["variant"] = {"tag": args.tag, "overrides": args.override}
    with open(out_path(args.arch, args.shape, args.multi_pod), "w") as f:
        json.dump(res, f, indent=1)
    print(_summary(res))
    print(json.dumps(res["collectives"], indent=1))


def _summary(res: dict) -> str:
    r = res["roofline"]
    gb = res["memory"]["total_bytes"] / 2**30
    return (
        f"[dryrun] {res['arch']} × {res['shape']} @ {res['mesh']}: "
        f"compile {res['t_compile_s']}s | mem/dev {gb:.2f} GiB | "
        f"flops/dev {res['per_device_flops']:.3e} | "
        f"compute {r['compute_s']*1e3:.2f}ms memory {r['memory_s']*1e3:.2f}ms "
        f"collective {r['collective_s']*1e3:.2f}ms → {r['dominant']}"
    )


if __name__ == "__main__":
    main()
