"""Loop-aware cost analysis over optimized (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts ``while`` bodies ONCE (verified
empirically: a scan of 10 matmuls reports the flops of 1), which silently
underestimates every scanned-layer transformer and every ring-step loop by
the trip count. This analyzer re-derives per-device costs from the HLO text
with loop multipliers:

- builds a per-computation instruction table (result shapes from definition
  lines, including tuple types),
- extracts trip counts from each ``while``'s condition computation (max
  integer constant; +1 for ``direction=LE``),
- propagates multipliers entry→callees through ``body=/condition=/calls=/
  to_apply=`` edges (nested loops multiply),
- FLOPs: ``dot`` ops as 2 · result_elems · contracted_extent (the MXU work;
  elementwise flops are noise at these scales),
- HBM bytes: Σ (operands + result) over memory-moving op kinds (fusion
  call-site model: fused interiors stay in registers/VMEM),
- collectives: payload/link bytes per op kind with ring algorithm factors
  (× loop multipliers).

All numbers are per device (the HLO module is one SPMD program).
"""

from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# Op kinds whose operands+results approximate HBM traffic under a
# TPU-fusion model: matmuls, data movement, reductions/sorts, collectives
# and explicit fusion call sites. Plain elementwise ops (add/multiply/
# convert/broadcast/...) are EXCLUDED — the CPU pipeline leaves them
# unfused, but on the TPU target they fuse into neighbors, so counting
# them would overstate HBM traffic by ~10×. Known biases are documented
# in EXPERIMENTS.md §Roofline (method).
_HBM_OPS = {
    "dot", "fusion", "copy", "transpose", "reduce", "scatter",
    "gather", "dynamic-slice", "dynamic-update-slice", "pad", "concatenate",
    "slice", "sort", "select-and-scatter", "reduce-window",
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
}

_SHAPE_TOKEN = re.compile(r"(\w+)\[([\d,]*)\]")
# Result types may be tuples containing layout braces and /*index=N*/
# comments; they never contain parentheses, so `\([^)]*\)` is safe.
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%(?P<name>[\w.\-]+)\s*=\s*(?P<type>\([^)]*\)|[\w\[\],{} ]+?)\s+"
    r"(?P<op>[\w\-]+)\((?P<args>[^)]*)\)(?P<rest>.*)$"
)
_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_CALLEE = re.compile(r"(?:condition|body|calls|to_apply)=%([\w.\-]+)")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONSTANT_INT = re.compile(r"=\s*[su]\d+\[\]\s+constant\((\d+)\)")


def _parse_shapes(type_str: str) -> list:
    """[(dtype, [dims...]), ...] from a (possibly tuple) HLO type string."""
    out = []
    for m in _SHAPE_TOKEN.finditer(type_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(shapes: list) -> int:
    return sum(
        _DTYPE_BYTES[dt] * math.prod(dims or [1]) for dt, dims in shapes
    )


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    shapes: list          # result shapes [(dtype, dims)]
    args: list            # operand names
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list
    table: dict           # name -> Instr


def parse_module(text: str) -> dict:
    comps: dict = {}
    current: Computation | None = None
    for line in text.splitlines():
        h = _COMP_HEADER.match(line.strip())
        if h and ("->" in line):
            current = Computation(h.group(1), [], {})
            comps[current.name] = current
            continue
        if current is None:
            continue
        if line.strip() == "}":
            current = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        args = re.findall(r"%([\w.\-]+)", m.group("args"))
        ins = Instr(
            name=m.group("name"),
            op=m.group("op"),
            shapes=_parse_shapes(m.group("type")),
            args=args,
            line=line,
        )
        current.instrs.append(ins)
        current.table[ins.name] = ins
    return comps


def _trip_count(cond: Computation) -> int:
    best = 1
    for ins in cond.instrs:
        m = _CONSTANT_INT.search(ins.line)
        if m:
            best = max(best, int(m.group(1)))
    if any("direction=LE" in i.line for i in cond.instrs):
        best += 1
    return best


def _multipliers(comps: dict) -> dict:
    """Execution-count multiplier per computation.

    Roots (the ENTRY, i.e. computations referenced by no one) start at 1;
    ``while`` edges multiply by the trip count, plain call edges by 1.
    The call graph is a DAG, so a bounded fixpoint converges exactly.
    """
    referenced = set()
    fused_interior = set()      # reached via calls=/to_apply= (fusion bodies)
    edges = defaultdict(list)   # caller -> [(callee, factor)]
    for cname, comp in comps.items():
        for ins in comp.instrs:
            if ins.op == "while":
                body = re.search(r"body=%([\w.\-]+)", ins.line)
                cond = re.search(r"condition=%([\w.\-]+)", ins.line)
                trip = 1
                if cond and cond.group(1) in comps:
                    trip = _trip_count(comps[cond.group(1)])
                if body:
                    edges[cname].append((body.group(1), trip))
                    referenced.add(body.group(1))
                if cond:
                    edges[cname].append((cond.group(1), trip + 1))
                    referenced.add(cond.group(1))
            else:
                for c in _CALLEE.findall(ins.line):
                    edges[cname].append((c, 1))
                    referenced.add(c)
                    fused_interior.add(c)
    roots = [n for n in comps if n not in referenced]
    mult = defaultdict(float)
    for r in roots:
        mult[r] = 1.0
    for _ in range(len(comps) + 1):
        nxt = defaultdict(float)
        for r in roots:
            nxt[r] = 1.0
        for caller, outs in edges.items():
            for callee, f in outs:
                nxt[callee] += mult[caller] * f
        if dict(nxt) == dict(mult):
            break
        mult = nxt
    return mult, fused_interior


def _dot_flops(ins: Instr, table: dict) -> float:
    if not ins.shapes:
        return 0.0
    result_elems = math.prod(ins.shapes[0][1] or [1])
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    if not m or not ins.args:
        return 2.0 * result_elems  # degenerate
    lhs = table.get(ins.args[0])
    if lhs is None or not lhs.shapes:
        return 2.0 * result_elems
    lhs_dims = lhs.shapes[0][1]
    contracted = 1
    for d in m.group(1).split(","):
        if d and int(d) < len(lhs_dims):
            contracted *= lhs_dims[int(d)]
    return 2.0 * result_elems * contracted


def _hbm_bytes(ins: Instr, comp: Computation, comps: dict) -> float:
    """HBM traffic of one top-level instruction.

    Slicing ops touch only the slice, not the whole operand — critical for
    scan-stacked loop carries (a [L, ...] activation stack read layer-wise
    must be billed per-slice, not L × full-stack). Fusions whose interior
    slices/updates a parameter get the same treatment via the callee's
    parameter table.
    """
    result = _shape_bytes(ins.shapes)
    if ins.op == "dynamic-slice" or ins.op == "slice" or ins.op == "gather":
        return 2.0 * result
    if ins.op == "dynamic-update-slice":
        # args: (operand, update, indices...): read update + write region
        upd = comp.table.get(ins.args[1]) if len(ins.args) > 1 else None
        ub = _shape_bytes(upd.shapes) if upd else result
        return 2.0 * ub
    if ins.op == "fusion":
        callee = _CALLEE.search(ins.line)
        inner = comps.get(callee.group(1)) if callee else None
        total = result
        sliced_params, dus_root = _fusion_slice_info(inner) if inner else ({}, None)
        if dus_root is not None:
            total = dus_root  # in-place stack update: bill the slice
        for idx, a in enumerate(ins.args):
            if a not in comp.table:
                continue
            if idx in sliced_params:
                total += sliced_params[idx]
            else:
                total += _shape_bytes(comp.table[a].shapes)
        return float(total)
    operand_bytes = sum(
        _shape_bytes(comp.table[a].shapes)
        for a in ins.args
        if a in comp.table
    )
    return float(operand_bytes + result)


def _fusion_slice_info(inner: Computation):
    """(param_index → slice bytes) for params only consumed via slicing,
    plus the update size if the fusion root is a dynamic-update-slice."""
    param_index = {}
    uses = defaultdict(list)    # param name -> [instr]
    alias = {}
    # NB: `convert` counts as pass-through here: a TPU fusion performs the
    # dtype cast slice-wise inside the DUS/slice kernel, whereas CPU HLO
    # materializes a full-buffer convert (which would distort the billing).
    for i in inner.instrs:
        if i.op == "parameter":
            m = re.search(r"parameter\((\d+)\)", i.line)
            if m:
                param_index[i.name] = int(m.group(1))
        for a in i.args:
            uses[a].append(i)
        if i.op in ("bitcast", "reshape", "convert") and i.args:
            alias[i.name] = i.args[0]
    def canon(n):
        seen = set()
        while n in alias and n not in seen:
            seen.add(n)
            n = alias[n]
        return n
    sliced = {}
    for pname, idx in param_index.items():
        consumers = []
        for i in inner.instrs:
            if any(canon(a) == pname for a in i.args):
                consumers.append(i)
        slicers = [
            i for i in consumers
            if i.op in ("dynamic-slice", "slice", "gather")
            or (i.op == "dynamic-update-slice" and canon(i.args[0]) == pname)
        ]
        passthrough = [
            i for i in consumers if i.op in ("bitcast", "reshape", "convert")
        ]
        if slicers and len(slicers) + len(passthrough) == len(consumers):
            b = 0
            for s in slicers:
                if s.op == "dynamic-update-slice" and len(s.args) > 1:
                    upd = inner.table.get(s.args[1])
                    b += _shape_bytes(upd.shapes) if upd else 0
                else:
                    b += _shape_bytes(s.shapes)
            sliced[idx] = b
    dus_root = None
    root = None
    for i in inner.instrs:
        if "ROOT" in i.line.split("=")[0] or i.line.lstrip().startswith("ROOT"):
            root = i
            break
    if root is None and inner.instrs:
        root = inner.instrs[-1]
    # Walk back through convert/bitcast/reshape wrappers around the root.
    seen = set()
    while (
        root is not None
        and root.op in ("convert", "bitcast", "reshape")
        and root.args
        and root.name not in seen
    ):
        seen.add(root.name)
        root = inner.table.get(root.args[0])
    if root is not None and root.op == "dynamic-update-slice" and len(root.args) > 1:
        upd = inner.table.get(root.args[1])
        if upd:
            dus_root = _shape_bytes(upd.shapes)
    return sliced, dus_root


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return 1


def _collective_link_bytes(ins: Instr) -> tuple:
    """(kind, payload_bytes, link_bytes) for a collective instruction."""
    kind = ins.op.replace("-start", "")
    if kind not in _COLLECTIVES:
        return None
    shapes = ins.shapes
    if ins.op.endswith("-start") and len(shapes) > 1:
        shapes = shapes[len(shapes) // 2 :]
    payload = _shape_bytes(shapes)
    g = max(_group_size(ins.line), 1)
    if kind == "all-gather":
        link = payload * (g - 1) / g
    elif kind == "all-reduce":
        link = 2 * payload * (g - 1) / g
    elif kind == "reduce-scatter":
        link = payload * (g - 1)
    elif kind == "all-to-all":
        link = payload * (g - 1) / g
    else:
        link = payload
    return kind, payload, link


def analyze(text: str) -> dict:
    comps = parse_module(text)
    mult, fused_interior = _multipliers(comps)

    flops = 0.0
    hbm = 0.0
    coll = {
        k: {"count": 0.0, "link_bytes": 0.0, "payload_bytes": 0.0}
        for k in _COLLECTIVES
    }
    for cname, comp in comps.items():
        w = mult.get(cname, 0.0)
        if w <= 0:
            continue
        # fusion interiors: dots still burn MXU flops, but their memory
        # traffic is covered by the fusion call site (operands+result).
        interior = cname in fused_interior
        for ins in comp.instrs:
            if ins.op == "dot":
                flops += w * _dot_flops(ins, comp.table)
            if ins.op.replace("-start", "") in _COLLECTIVES and not ins.op.endswith(
                "-done"
            ):
                kind, payload, link = _collective_link_bytes(ins)
                coll[kind]["count"] += w
                coll[kind]["payload_bytes"] += w * payload
                coll[kind]["link_bytes"] += w * link
            base = ins.op.replace("-start", "").replace("-done", "")
            if base in _HBM_OPS and not interior:
                hbm += w * _hbm_bytes(ins, comp, comps)
    return {
        "flops": flops,
        "hbm_bytes": hbm,
        "collectives": coll,
        "link_bytes": sum(v["link_bytes"] for v in coll.values()),
        "n_computations": len(comps),
        "max_multiplier": max(mult.values()) if mult else 1.0,
    }
