"""Production mesh construction.

Defined as a FUNCTION (not module-level state) so importing this module never
touches jax device initialization — critical because the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init,
while smoke tests must see the 1 real device.

All mesh construction routes through ``repro.compat.make_mesh`` so the
``axis_types`` / ``AxisType`` API drift is absorbed in one place.
"""

from __future__ import annotations

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips) mesh.

    Axes: ``pod`` (DCN between pods), ``data`` (DP / batch / APSS rows),
    ``model`` (TP / EP / APSS dims).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for multi-device unit tests (8 forced host devices)."""
    return make_mesh(shape, axes)
