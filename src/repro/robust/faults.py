"""Deterministic fault injection for APSS sweeps and serving.

The paper's schedules are fully synchronous: one lost or slow rank stalls an
entire n²-scale sweep. To *test* the recovery machinery (resumable sweeps,
checkpoint fallback, serving degradation) we need faults that are

- **deterministic** — a seeded :class:`FaultPlan` fires the same faults at
  the same points on every run, so a recovery test is reproducible and a
  chaos-bench lane is comparable across commits;
- **injected at seams, not monkeypatched** — production code calls the
  plan's hook methods (:meth:`FaultPlan.kill_point`, :meth:`FaultPlan.delay`,
  :meth:`FaultPlan.fail_point`, :meth:`FaultPlan.corrupt_array`) which are
  all no-ops when no matching fault is armed, so the instrumented paths ARE
  the tested paths.

Fault kinds and what real-world failure each models (DESIGN.md §8):

- ``kill`` — the process dies between checkpoint steps (preemption, OOM
  kill, a dropped rank taking down the SPMD sweep). Raises
  :class:`SweepKilled`; recovery = resume from the last checkpoint, on the
  same mesh or — for a genuinely lost rank — a smaller one
  (``robust.sweep.mesh_after_eviction``).
- ``delay`` — a slow shard / straggling rank: sleeps ``seconds`` at the
  matching step. Drives straggler detection and serving-deadline tests.
- ``error`` — a transient failure of one execution tier (e.g. the Pallas
  kernel path): raises :class:`InjectedFault` for the first ``times``
  matching calls, then stops. Drives retry-with-backoff and the serving
  degradation ladder.
- ``corrupt`` — bit-rot in flight or at rest: :meth:`corrupt_array`
  perturbs a traveling packet (e.g. a Matches caravan) deterministically;
  :meth:`corrupt_file` flips a byte of a checkpoint leaf on disk to
  exercise ``CheckpointCorruptionError`` + fallback.
"""

from __future__ import annotations

import collections
import dataclasses
import time

import numpy as np

from repro.obs import recorder as _recorder


class InjectedFault(RuntimeError):
    """A planned fault fired — raised only by fault-injection hooks."""


class SweepKilled(InjectedFault):
    """The sweep 'process' died between checkpoint steps (kill fault)."""


@dataclasses.dataclass
class Fault:
    """One armed fault. ``step``/``rank`` of None match any step/rank;
    ``times`` bounds firings (<= 0 means unlimited)."""

    kind: str                 # "kill" | "delay" | "error" | "corrupt"
    scope: str = "sweep"      # seam name, e.g. "serving.kernel", "sweep.caravan"
    step: int | None = None
    rank: int | None = None
    seconds: float = 0.0      # delay duration
    times: int = 1


class FaultPlan:
    """A seeded, consumable set of faults; all hooks no-op when nothing arms.

    ``fired`` counts firings per ``"kind:scope"`` key so tests can assert a
    fault actually triggered (a recovery test that never faulted proves
    nothing).
    """

    def __init__(self, faults=(), *, seed: int = 0):
        self.faults: list[Fault] = list(faults)
        self.seed = int(seed)
        self.fired: collections.Counter = collections.Counter()
        self._remaining = [f.times for f in self.faults]

    # -- matching ----------------------------------------------------------

    def _take(self, kind: str, scope: str, step=None, rank=None) -> Fault | None:
        for i, f in enumerate(self.faults):
            if f.kind != kind or f.scope != scope:
                continue
            if f.step is not None and f.step != step:
                continue
            if f.rank is not None and rank is not None and f.rank != rank:
                continue
            if self._remaining[i] == 0:
                continue
            if self._remaining[i] > 0:
                self._remaining[i] -= 1
            self.fired[f"{kind}:{scope}"] += 1
            # Flight-recorder seam: a firing fault is exactly the moment a
            # postmortem wants the recent-event buffer frozen.
            if _recorder.enabled():
                _recorder.trigger(
                    f"fault:{kind}:{scope}", step=step, rank=rank,
                )
            return f
        return None

    # -- hooks (called from production seams) ------------------------------

    def kill_point(self, step: int, scope: str = "sweep") -> None:
        """Die here if a kill fault matches (models preemption mid-sweep)."""
        if self._take("kill", scope, step=step) is not None:
            raise SweepKilled(f"injected kill at {scope} step {step}")

    def delay(self, scope: str, step=None, rank=None) -> float:
        """Sleep out a matching delay fault; returns seconds slept."""
        f = self._take("delay", scope, step=step, rank=rank)
        if f is None:
            return 0.0
        time.sleep(f.seconds)
        return f.seconds

    def fail_point(self, scope: str, step=None) -> None:
        """Raise a transient :class:`InjectedFault` if an error fault matches."""
        f = self._take("error", scope, step=step)
        if f is not None:
            raise InjectedFault(f"injected transient error in {scope}")

    def corrupt_array(self, x, step=None, scope: str = "sweep.caravan"):
        """Deterministically perturb one element of a traveling packet.

        Returns ``x`` untouched when no corrupt fault matches; otherwise a
        numpy copy with a single element overwritten — seeded from
        ``(plan.seed, step)`` so the damage is identical across runs.
        """
        f = self._take("corrupt", scope, step=step)
        if f is None:
            return x
        out = np.array(x)
        rng = np.random.default_rng((self.seed, 0 if step is None else int(step)))
        flat = out.reshape(-1)
        i = int(rng.integers(flat.size))
        if np.issubdtype(out.dtype, np.floating):
            flat[i] = np.float64(rng.uniform(2.0, 4.0))  # out-of-range cosine
        else:
            flat[i] = flat[i] ^ np.asarray(0x5A5A, dtype=out.dtype)
        return out

    def corrupt_file(self, path: str) -> int:
        """Flip one mid-file byte in place (bit-rot at rest); returns offset.

        Always fires — disk corruption is injected by tests directly, not
        gated on an armed fault — but the flipped offset is seed-stable.
        """
        rng = np.random.default_rng(self.seed)
        with open(path, "r+b") as f:
            f.seek(0, 2)
            size = f.tell()
            # Stay clear of the .npy header so the file still *parses* and
            # corruption must be caught by the digest, not a parse error.
            lo = min(size - 1, 128)
            off = int(rng.integers(lo, size))
            f.seek(off)
            b = f.read(1)
            f.seek(off)
            f.write(bytes([b[0] ^ 0xFF]))
        return off

    # -- convenience -------------------------------------------------------

    def armed(self, kind: str, scope: str) -> bool:
        """True iff a matching fault could still fire (cheap pre-check so
        hot paths skip host round-trips when nothing is armed)."""
        return any(
            f.kind == kind and f.scope == scope and r != 0
            for f, r in zip(self.faults, self._remaining)
        )

    @property
    def total_fired(self) -> int:
        return sum(self.fired.values())

    @classmethod
    def chaos(
        cls,
        seed: int,
        *,
        steps: int,
        delay_prob: float = 0.3,
        max_delay: float = 0.003,
        kernel_errors: int = 1,
        kill: bool = False,
        scope: str = "sweep",
        error_scope: str = "serving.kernel",
    ) -> "FaultPlan":
        """A random-but-seeded plan for the chaos bench lane / ``--chaos``.

        Sprinkles sub-millisecond shard delays across the steps of ``scope``
        (``"sweep"`` or ``"serving"``), arms ``kernel_errors`` transient
        scoring-tier failures at ``error_scope``, and (with ``kill=True``)
        one mid-sweep kill at a seeded step — everything derived from
        ``seed`` only.
        """
        rng = np.random.default_rng(seed)
        faults: list[Fault] = []
        for s in range(steps):
            if rng.random() < delay_prob:
                faults.append(
                    Fault("delay", scope=scope, step=s,
                          seconds=float(rng.uniform(0.0, max_delay)))
                )
        if kernel_errors:
            faults.append(
                Fault("error", scope=error_scope, times=kernel_errors)
            )
        if kill and steps > 1:
            faults.append(
                Fault("kill", scope="sweep", step=int(rng.integers(1, steps)))
            )
        return cls(faults, seed=seed)
