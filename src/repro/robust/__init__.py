from repro.robust.faults import (  # noqa: F401
    Fault,
    FaultPlan,
    InjectedFault,
    SweepKilled,
)
from repro.robust.sweep import (  # noqa: F401
    ResumableSweep,
    mesh_after_eviction,
)
